"""L2 model tests: shapes, stage decomposition == full model, training
actually learns, flash vs reference A/B."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

CFG = model.Config(n_layers=4, hidden=64, heads=2, intermediate=256,
                   vocab=512, seq=32)


@pytest.fixture(scope="module")
def params():
    return model.init_params(jax.random.PRNGKey(0), CFG)


def _batch(seed, mbs=2, vocab=None, seq=None):
    vocab = vocab or CFG.vocab
    seq = seq or CFG.seq
    key = jax.random.PRNGKey(seed)
    x = jax.random.randint(key, (mbs, seq), 0, vocab, jnp.int32)
    # Deterministic successor task: t+1 = (3t + 7) mod vocab (same
    # synthetic language the Rust trainer generates).
    y = (3 * x + 7) % vocab
    return x, y


def test_forward_shapes(params):
    x, _ = _batch(0)
    logits = model.forward(params, x, CFG)
    assert logits.shape == (2, CFG.seq, CFG.vocab)
    assert bool(jnp.isfinite(logits).all())


def test_param_count_formula():
    # init_params tree must match Config.param_count().
    p = model.init_params(jax.random.PRNGKey(1), CFG)
    n = sum(x.size for x in jax.tree_util.tree_leaves(p))
    assert n == CFG.param_count()


def test_initial_loss_near_uniform(params):
    x, y = _batch(1)
    loss = model.loss_fn(params, x, y, CFG)
    expect = np.log(CFG.vocab)
    assert abs(float(loss) - expect) / expect < 0.15


def test_stage_decomposition_matches_full(params):
    """Pipeline-split forward+loss must equal the monolithic model."""
    cuts = [0, 2, 4, CFG.n_layers + 2]
    n_stages = len(cuts) - 1
    x, y = _batch(2)
    full = model.loss_fn(params, x, y, CFG)

    h = x
    for k in range(n_stages):
        sp = model.stage_params(params, CFG, cuts, k)
        fwd, _ = model.make_stage_fns(CFG, cuts, k, n_stages)
        if k == n_stages - 1:
            h = fwd(sp, h, y)
        else:
            h = fwd(sp, h)
    np.testing.assert_allclose(float(h), float(full), rtol=1e-5)


def test_stage_backward_chain_matches_full_grad(params):
    """Chained per-stage VJPs must equal the monolithic gradient."""
    cuts = [0, 3, CFG.n_layers + 2]
    n_stages = 2
    x, y = _batch(3)

    full_grads = jax.grad(lambda p: model.loss_fn(p, x, y, CFG))(params)

    sp0 = model.stage_params(params, CFG, cuts, 0)
    sp1 = model.stage_params(params, CFG, cuts, 1)
    fwd0, bwd0 = model.make_stage_fns(CFG, cuts, 0, n_stages)
    _, bwd1 = model.make_stage_fns(CFG, cuts, 1, n_stages)

    h0 = fwd0(sp0, x)
    loss, gsp1, gx1 = bwd1(sp1, h0, y)
    gsp0, _ = bwd0(sp0, x, gx1)

    np.testing.assert_allclose(
        gsp0["embed"], full_grads["embed"], rtol=2e-4, atol=1e-6
    )
    np.testing.assert_allclose(
        gsp1["head"], full_grads["head"], rtol=2e-4, atol=1e-6
    )
    np.testing.assert_allclose(
        gsp0["blocks"][0]["wqkv"],
        full_grads["blocks"][0]["wqkv"],
        rtol=2e-4,
        atol=1e-6,
    )


def test_training_learns_successor_task():
    """A few hundred steps on t+1 = (3t+7) mod V must cut the loss."""
    cfg = model.Config(n_layers=2, hidden=64, heads=2, intermediate=128,
                       vocab=64, seq=16)
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    m, v = model.adam_init(params)
    step_fn = jax.jit(
        lambda p, x, y, m, v, s: model.train_step(p, x, y, m, v, s, cfg)
    )
    losses = []
    for i in range(150):
        x, y = _batch(i, mbs=8, vocab=cfg.vocab, seq=cfg.seq)
        loss, params, m, v = step_fn(params, x, y, m, v, jnp.int32(i + 1))
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.5, f"{losses[0]} -> {losses[-1]}"


def test_flash_and_ref_models_agree(params):
    x, y = _batch(4)
    cfg_ref = model.Config(**{**CFG.__dict__, "use_flash": False})
    l1 = model.loss_fn(params, x, y, CFG)
    l2 = model.loss_fn(params, x, y, cfg_ref)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def test_adam_update_moves_params(params):
    x, y = _batch(5)
    grads = jax.grad(lambda p: model.loss_fn(p, x, y, CFG))(params)
    m, v = model.adam_init(params)
    new_p, m2, v2 = model.adam_update(params, grads, m, v, jnp.int32(1))
    assert not np.allclose(new_p["head"], params["head"])
    assert bool(jnp.isfinite(new_p["head"]).all())
    # Momentum captured the gradient direction.
    np.testing.assert_allclose(m2["head"], 0.1 * grads["head"], rtol=1e-5)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
