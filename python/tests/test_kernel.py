"""L1 kernel correctness: Pallas flash attention vs the pure-jnp oracle.

Hypothesis sweeps shapes/dtypes; assert_allclose against ref.py — the
core correctness signal for the kernel that every HLO artifact embeds.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import flash, ref

TOL = dict(rtol=2e-5, atol=2e-5)


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


def _qkv(seed, b, h, s, d, dtype=jnp.float32):
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    return [_rand(k, (b, h, s, d), dtype) for k in keys]


class TestFlashMatchesRef:
    @settings(max_examples=25, deadline=None)
    @given(
        b=st.integers(1, 3),
        h=st.integers(1, 4),
        s_pow=st.integers(4, 8),  # seq 16..256
        d_pow=st.integers(3, 7),  # head_dim 8..128
        causal=st.booleans(),
        seed=st.integers(0, 2**16),
    )
    def test_shape_sweep(self, b, h, s_pow, d_pow, causal, seed):
        s, d = 2**s_pow, 2**d_pow
        q, k, v = _qkv(seed, b, h, s, d)
        out = flash.flash_attention(q, k, v, causal)
        expect = ref.attention_ref(q, k, v, causal)
        np.testing.assert_allclose(out, expect, **TOL)

    @settings(max_examples=10, deadline=None)
    @given(
        bq=st.sampled_from([16, 32, 64, 128, 256]),
        bk=st.sampled_from([16, 32, 64, 128]),
        seed=st.integers(0, 2**16),
    )
    def test_block_size_invariance(self, bq, bk, seed):
        q, k, v = _qkv(seed, 2, 2, 128, 32)
        out = flash.flash_attention(q, k, v, True, bq, bk)
        expect = ref.attention_ref(q, k, v, True)
        np.testing.assert_allclose(out, expect, **TOL)

    def test_non_pow2_seq_via_block_shrink(self):
        # seq 96 = 32·3: _pick_blocks must shrink to a divisor.
        q, k, v = _qkv(7, 1, 2, 96, 32)
        out = flash.flash_attention(q, k, v, True)
        np.testing.assert_allclose(out, ref.attention_ref(q, k, v, True), **TOL)

    def test_bf16_runs_and_is_close(self):
        q, k, v = _qkv(3, 1, 2, 64, 32, jnp.bfloat16)
        out = flash.flash_attention(q, k, v, True).astype(jnp.float32)
        expect = ref.attention_ref(
            q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32), True
        )
        np.testing.assert_allclose(out, expect, rtol=5e-2, atol=5e-2)


class TestGradients:
    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 2**16), causal=st.booleans())
    def test_grads_match_ref(self, seed, causal):
        q, k, v = _qkv(seed, 1, 2, 64, 16)

        def f_flash(q, k, v):
            return jnp.sum(flash.flash_attention(q, k, v, causal) ** 2)

        def f_ref(q, k, v):
            return jnp.sum(ref.attention_ref(q, k, v, causal) ** 2)

        g1 = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
        g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(g1, g2):
            np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


class TestNumericalEdges:
    def test_large_scores_stable(self):
        # Online softmax must not overflow with large logits.
        q, k, v = _qkv(0, 1, 1, 64, 16)
        q = q * 100.0
        out = flash.flash_attention(q, k, v, True)
        assert bool(jnp.isfinite(out).all())
        np.testing.assert_allclose(out, ref.attention_ref(q, k, v, True), **TOL)

    def test_first_row_causal_is_v0(self):
        # Token 0 attends only to itself under the causal mask.
        q, k, v = _qkv(1, 1, 1, 32, 8)
        out = flash.flash_attention(q, k, v, True)
        np.testing.assert_allclose(out[0, 0, 0], v[0, 0, 0], **TOL)

    def test_identical_kv_rows_average(self):
        q, k, _ = _qkv(2, 1, 1, 32, 8)
        v = jnp.ones((1, 1, 32, 8), jnp.float32) * 3.5
        out = flash.flash_attention(q, k, v, False)
        np.testing.assert_allclose(out, jnp.full_like(out, 3.5), **TOL)


def test_lowering_contains_no_custom_call():
    # interpret=True must lower to plain HLO the CPU PJRT client can run.
    q = jax.ShapeDtypeStruct((2, 128, 32), jnp.float32)
    lowered = jax.jit(
        lambda q, k, v: flash._flash_call(q, k, v, 64, 64, True)
    ).lower(q, q, q)
    hlo = lowered.compiler_ir("hlo").as_hlo_text()
    assert "custom-call" not in hlo.lower() or "mosaic" not in hlo.lower()


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
