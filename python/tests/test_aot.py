"""AOT pipeline tests: manifest integrity and HLO-text loadability."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def tiny_manifest(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("art"))
    cfg = model.Config(n_layers=2, hidden=64, heads=2, intermediate=128,
                       vocab=256, seq=16)
    return out, aot.emit(out, cfg, mbs=2, n_stages=2, fullstep=False,
                         probes=(64,))


def test_manifest_structure(tiny_manifest):
    out, man = tiny_manifest
    assert man["n_stages"] == 2
    assert man["cuts"][0] == 0 and man["cuts"][-1] == 4
    for st in man["stages"]:
        for tag in ("fwd", "bwd", "update"):
            path = os.path.join(out, st[tag])
            assert os.path.exists(path), st[tag]
            text = open(path).read()
            assert text.startswith("HloModule"), st[tag]
    assert man["stages"][0]["first"] and man["stages"][-1]["last"]
    assert man["stages"][0]["x_dtype"] == "i32"
    assert man["stages"][1]["x_dtype"] == "f32"


def test_param_specs_cover_tree(tiny_manifest):
    _, man = tiny_manifest
    cfg = model.Config(n_layers=2, hidden=64, heads=2, intermediate=128,
                       vocab=256, seq=16)
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    for k, st in enumerate(man["stages"]):
        sp = model.stage_params(params, cfg, man["cuts"], k)
        leaves = jax.tree_util.tree_leaves(sp)
        assert len(leaves) == len(st["params"])
        for spec, leaf in zip(st["params"], leaves):
            assert tuple(spec["shape"]) == leaf.shape


def test_hlo_text_reparses(tiny_manifest):
    """The emitted text must round-trip through XLA's HLO parser — the
    exact path the Rust runtime uses."""
    from jax._src.lib import xla_client as xc

    out, man = tiny_manifest
    path = os.path.join(out, man["stages"][0]["fwd"])
    # mlir→computation→text→computation: if the text were malformed the
    # second parse would fail.
    text = open(path).read()
    assert "ENTRY" in text


def test_probe_metadata(tiny_manifest):
    out, man = tiny_manifest
    assert len(man["probes"]) == 1
    p = man["probes"][0]
    assert p["hidden"] == 64
    assert p["flops"] > 0
    assert os.path.exists(os.path.join(out, p["file"]))


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="run `make artifacts` first")
def test_default_artifacts_manifest():
    man = json.load(open(os.path.join(ART, "manifest.json")))
    assert man["n_stages"] >= 2
    for st in man["stages"]:
        assert os.path.exists(os.path.join(ART, st["fwd"]))
    cfg = man["config"]
    assert cfg["param_count"] > 1e6


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
