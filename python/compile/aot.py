"""AOT lowering: JAX (L2) + Pallas (L1) → HLO text artifacts for Rust (L3).

Run once at build time (``make artifacts``); Python never appears on the
request path. Emits into ``artifacts/``:

* ``stage{k}_fwd|bwd|update.hlo.txt`` — per-pipeline-stage forward,
  backward (stage-recompute VJP), and Adam-update computations for the
  Rust 1F1B trainer;
* ``train_step.hlo.txt`` — the full single-device train step (smoke
  path / single-device throughput reference);
* ``probe_h{H}.hlo.txt`` — single transformer-block forwards at several
  widths, parameters baked in, used by the Rust profiler to calibrate
  the analytical compute model;
* ``manifest.json`` — shapes/dtypes/arg-order/FLOP metadata for all of
  the above.

HLO **text** is the interchange format (not serialized protos): jax ≥0.5
emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
parser reassigns ids (see /opt/xla-example/README.md).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ref


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_tag(dt) -> str:
    return {"float32": "f32", "int32": "i32", "uint32": "u32"}[jnp.dtype(dt).name]


def _leaf_specs(tree):
    """Flattened (path, shape, dtype) list in jit argument order."""
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in leaves_with_paths:
        name = ".".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out.append(
            {
                "path": name,
                "shape": list(leaf.shape),
                "dtype": _dtype_tag(leaf.dtype),
            }
        )
    return out


def _shaped(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree
    )


def block_fwd_flops(cfg: model.Config, tokens: int) -> float:
    """Analytical matmul FLOPs of one block forward (profiler metadata)."""
    h, i, s = cfg.hidden, cfg.intermediate, cfg.seq
    proj = 2.0 * tokens * (4 * h * h + 2 * h * i)
    attn = 4.0 * tokens * s * h
    return proj + attn


def emit(out_dir: str, cfg: model.Config, mbs: int, n_stages: int,
         fullstep: bool = True, probes=(128, 256, 512)) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    rng = jax.random.PRNGKey(0)
    chain = cfg.n_layers + 2
    assert 1 <= n_stages <= chain // 2 or n_stages <= chain
    cuts = [round(k * chain / n_stages) for k in range(n_stages + 1)]
    # Ensure strictly increasing cuts.
    for k in range(1, n_stages + 1):
        cuts[k] = max(cuts[k], cuts[k - 1] + 1)
    cuts[-1] = chain

    params = model.init_params(rng, cfg)
    manifest = {
        "config": {
            "n_layers": cfg.n_layers,
            "hidden": cfg.hidden,
            "heads": cfg.heads,
            "intermediate": cfg.intermediate,
            "vocab": cfg.vocab,
            "seq": cfg.seq,
            "mbs": mbs,
            "param_count": cfg.param_count(),
        },
        "cuts": cuts,
        "n_stages": n_stages,
        "stages": [],
        "probes": [],
    }

    tokens_spec = jax.ShapeDtypeStruct((mbs, cfg.seq), jnp.int32)
    hidden_spec = jax.ShapeDtypeStruct((mbs, cfg.seq, cfg.hidden), jnp.float32)

    for k in range(n_stages):
        sp = model.stage_params(params, cfg, cuts, k)
        sp_spec = _shaped(sp)
        first, last = k == 0, k == n_stages - 1
        x_spec = tokens_spec if first else hidden_spec
        fwd, bwd = model.make_stage_fns(cfg, cuts, k, n_stages)

        entry = {
            "index": k,
            "first": first,
            "last": last,
            "params": _leaf_specs(sp),
            "x_shape": list(x_spec.shape),
            "x_dtype": _dtype_tag(x_spec.dtype),
        }

        if last:
            lowered_f = jax.jit(fwd, keep_unused=True).lower(sp_spec, x_spec, tokens_spec)
            lowered_b = jax.jit(bwd, keep_unused=True).lower(sp_spec, x_spec, tokens_spec)
            entry["y_shape"] = []  # scalar loss
        else:
            lowered_f = jax.jit(fwd, keep_unused=True).lower(sp_spec, x_spec)
            y_spec = jax.eval_shape(fwd, sp_spec, x_spec)
            lowered_b = jax.jit(bwd, keep_unused=True).lower(sp_spec, x_spec, y_spec)
            entry["y_shape"] = list(y_spec.shape)

        m0, v0 = model.adam_init(sp)
        step_spec = jax.ShapeDtypeStruct((), jnp.int32)
        lowered_u = jax.jit(model.adam_update, keep_unused=True).lower(
            sp_spec, sp_spec, _shaped(m0), _shaped(v0), step_spec
        )

        for tag, lowered in (("fwd", lowered_f), ("bwd", lowered_b), ("update", lowered_u)):
            fname = f"stage{k}_{tag}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(to_hlo_text(lowered))
            entry[tag] = fname
        manifest["stages"].append(entry)

    if fullstep:
        m0, v0 = model.adam_init(params)
        lowered = jax.jit(
            lambda p, x, t, m, v, s: model.train_step(p, x, t, m, v, s, cfg),
            keep_unused=True,
        ).lower(
            _shaped(params), tokens_spec, tokens_spec, _shaped(m0), _shaped(v0),
            jax.ShapeDtypeStruct((), jnp.int32),
        )
        with open(os.path.join(out_dir, "train_step.hlo.txt"), "w") as f:
            f.write(to_hlo_text(lowered))
        manifest["train_step"] = {
            "file": "train_step.hlo.txt",
            "params": _leaf_specs(params),
        }

    # Profiler probes: one block forward, params baked as constants.
    for h in probes:
        pcfg = model.Config(
            n_layers=1, hidden=h, heads=max(h // 64, 1),
            intermediate=4 * h, vocab=256, seq=cfg.seq,
        )
        bp = model.init_block(jax.random.fold_in(rng, h), pcfg)
        x_spec = jax.ShapeDtypeStruct((mbs, pcfg.seq, h), jnp.float32)
        lowered = jax.jit(lambda x, bp=bp, pcfg=pcfg: model.block_fwd(bp, x, pcfg)).lower(x_spec)
        fname = f"probe_h{h}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(to_hlo_text(lowered))
        manifest["probes"].append(
            {
                "file": fname,
                "hidden": h,
                "tokens": mbs * pcfg.seq,
                "x_shape": list(x_spec.shape),
                "flops": block_fwd_flops(pcfg, mbs * pcfg.seq),
            }
        )

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--layers", type=int, default=6)
    ap.add_argument("--hidden", type=int, default=256)
    ap.add_argument("--heads", type=int, default=4)
    ap.add_argument("--intermediate", type=int, default=1024)
    ap.add_argument("--vocab", type=int, default=4096)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--mbs", type=int, default=4)
    ap.add_argument("--stages", type=int, default=4)
    ap.add_argument("--no-fullstep", action="store_true")
    args = ap.parse_args()

    cfg = model.Config(
        n_layers=args.layers, hidden=args.hidden, heads=args.heads,
        intermediate=args.intermediate, vocab=args.vocab, seq=args.seq,
    )
    manifest = emit(
        args.out, cfg, args.mbs, args.stages, fullstep=not args.no_fullstep
    )
    n_files = 3 * manifest["n_stages"] + len(manifest["probes"]) + (
        1 if "train_step" in manifest else 0
    )
    print(
        f"wrote {n_files} HLO artifacts + manifest.json to {args.out} "
        f"({manifest['config']['param_count'] / 1e6:.1f}M params, "
        f"{manifest['n_stages']} stages)"
    )


if __name__ == "__main__":
    main()
