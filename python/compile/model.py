"""L2: configurable decoder-only transformer in pure JAX (no flax).

This is the build-time workload of the three-layer stack: it provides

* the full forward/backward training step, lowered once to HLO for the
  Rust runtime's single-device smoke path;
* a *stage decomposition* — per-stage forward / backward / Adam-update
  functions mirroring a pipeline-parallel placement plan, each lowered to
  its own HLO artifact so the Rust trainer can execute true 1F1B pipeline
  training over thread-devices;
* probe computations used by the Rust profiler to calibrate the
  analytical roofline (DESIGN.md §Hardware-Adaptation).

Attention runs through the L1 Pallas flash kernel (``kernels.flash``);
``use_flash=False`` switches to the pure-jnp reference for A/B tests.
"""

import dataclasses
from typing import Any, Dict, List

import jax
import jax.numpy as jnp

from .kernels import flash, ref

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class Config:
    """Model hyperparameters (defaults sized for CPU pipeline training)."""

    n_layers: int = 6
    hidden: int = 256
    heads: int = 4
    intermediate: int = 1024
    vocab: int = 4096
    seq: int = 64
    use_flash: bool = True

    @property
    def head_dim(self) -> int:
        assert self.hidden % self.heads == 0
        return self.hidden // self.heads

    def param_count(self) -> int:
        block = 4 * self.hidden**2 + 2 * self.hidden * self.intermediate
        block += self.intermediate + self.hidden  # MLP biases
        block += 4 * self.hidden  # layernorm gamma/beta ×2
        emb = self.vocab * self.hidden
        head = self.vocab * self.hidden
        return emb + self.n_layers * block + head


# ----- initialization -------------------------------------------------------


def init_block(rng, cfg: Config) -> Params:
    h, i = cfg.hidden, cfg.intermediate
    ks = jax.random.split(rng, 6)
    s = 0.02
    return {
        "wqkv": jax.random.normal(ks[0], (h, 3 * h), jnp.float32) * s,
        "wo": jax.random.normal(ks[1], (h, h), jnp.float32) * s,
        "w_in": jax.random.normal(ks[2], (h, i), jnp.float32) * s,
        "b_in": jnp.zeros((i,), jnp.float32),
        "w_out": jax.random.normal(ks[3], (i, h), jnp.float32) * s,
        "b_out": jnp.zeros((h,), jnp.float32),
        "ln1_g": jnp.ones((h,), jnp.float32),
        "ln1_b": jnp.zeros((h,), jnp.float32),
        "ln2_g": jnp.ones((h,), jnp.float32),
        "ln2_b": jnp.zeros((h,), jnp.float32),
    }


def init_params(rng, cfg: Config) -> Params:
    ks = jax.random.split(rng, cfg.n_layers + 2)
    return {
        "embed": jax.random.normal(ks[0], (cfg.vocab, cfg.hidden), jnp.float32) * 0.02,
        "blocks": [init_block(ks[1 + l], cfg) for l in range(cfg.n_layers)],
        "head": jax.random.normal(ks[-1], (cfg.hidden, cfg.vocab), jnp.float32) * 0.02,
    }


# ----- forward --------------------------------------------------------------


def block_fwd(p: Params, x, cfg: Config):
    """Pre-LN transformer block; attention via the Pallas flash kernel."""
    b, s, h = x.shape
    y = ref.layernorm_ref(x, p["ln1_g"], p["ln1_b"])
    qkv = y @ p["wqkv"]  # [b, s, 3h]
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads(t):
        return t.reshape(b, s, cfg.heads, cfg.head_dim).transpose(0, 2, 1, 3)

    if cfg.use_flash:
        attn = flash.flash_attention(heads(q), heads(k), heads(v), True)
    else:
        attn = ref.attention_ref(heads(q), heads(k), heads(v), True)
    attn = attn.transpose(0, 2, 1, 3).reshape(b, s, h)
    x = x + attn @ p["wo"]
    y = ref.layernorm_ref(x, p["ln2_g"], p["ln2_b"])
    x = x + ref.mlp_ref(y, p["w_in"], p["b_in"], p["w_out"], p["b_out"])
    return x


def forward(params: Params, tokens, cfg: Config):
    """tokens [b, s] int32 → logits [b, s, vocab]."""
    x = params["embed"][tokens]
    for p in params["blocks"]:
        x = block_fwd(p, x, cfg)
    return x @ params["head"]


def loss_fn(params: Params, tokens, targets, cfg: Config):
    """Mean next-token cross-entropy."""
    logits = forward(params, tokens, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return jnp.mean(nll)


# ----- stage decomposition ---------------------------------------------------
#
# A pipeline plan cuts the chain [embed, block0..blockN-1, head] into
# contiguous stages. Stage 0 starts with the embedding; the last stage
# ends with head + loss. Cut indices are in "layer chain" coordinates:
# 0 = embedding, 1..n_layers = blocks, n_layers+1 = head.


def stage_param_slices(cfg: Config, cuts: List[int]) -> List[Params]:
    """Describe each stage's parameter subtree (shapes only via init)."""
    assert cuts[0] == 0 and cuts[-1] == cfg.n_layers + 2
    return cuts


def stage_params(params: Params, cfg: Config, cuts: List[int], k: int) -> Params:
    """Extract stage k's parameters from the full tree."""
    i, j = cuts[k], cuts[k + 1]
    out: Params = {}
    if i == 0:
        out["embed"] = params["embed"]
    lo = max(i - 1, 0)
    hi = min(j - 1, cfg.n_layers)
    out["blocks"] = params["blocks"][lo:hi]
    if j == cfg.n_layers + 2:
        out["head"] = params["head"]
    return out


def stage_fwd(sp: Params, x, cfg: Config, first: bool, last: bool):
    """Forward of one stage. `x` is tokens (int32) for the first stage,
    hidden states otherwise. Returns hidden states (or logits if last —
    but the last stage is driven via `stage_loss` instead)."""
    if first:
        x = sp["embed"][x]
    for p in sp["blocks"]:
        x = block_fwd(p, x, cfg)
    if last:
        x = x @ sp["head"]
    return x


def stage_loss(sp: Params, x, targets, cfg: Config, first: bool):
    """Last-stage forward ending in the mean cross-entropy loss."""
    logits = stage_fwd(sp, x, cfg, first, True)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return jnp.mean(nll)


def make_stage_fns(cfg: Config, cuts: List[int], k: int, n_stages: int):
    """Build (fwd, bwd) closures for stage k, pure in (params, inputs).

    fwd(sp, x)            -> y                      (non-last stages)
    bwd(sp, x, gy)        -> (gsp, gx)              (non-last stages)
    fwd_loss(sp, x, t)    -> loss                   (last stage)
    bwd_loss(sp, x, t)    -> (loss, gsp, gx)        (last stage)

    The backward recomputes the stage forward (activation recomputation at
    stage granularity) so each artifact is a pure function — exactly what
    AOT lowering needs.
    """
    first = k == 0
    last = k == n_stages - 1

    if last:

        def fwd_loss(sp, x, targets):
            return stage_loss(sp, x, targets, cfg, first)

        def bwd_loss(sp, x, targets):
            (loss, (gsp, gx)) = jax.value_and_grad(
                lambda sp, x: stage_loss(sp, x, targets, cfg, first), argnums=(0, 1)
            )(sp, x)
            return loss, gsp, gx

        return fwd_loss, bwd_loss

    def fwd(sp, x):
        return stage_fwd(sp, x, cfg, first, False)

    def bwd(sp, x, gy):
        _, vjp = jax.vjp(lambda sp, x: stage_fwd(sp, x, cfg, first, False), sp, x)
        gsp, gx = vjp(gy)
        return gsp, gx

    return fwd, bwd


# ----- Adam ------------------------------------------------------------------


def adam_init(sp: Params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, sp)
    return zeros, jax.tree_util.tree_map(jnp.zeros_like, sp)


def adam_update(sp, grads, m, v, step, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    """One Adam step over a stage's parameter tree. `step` is 1-based."""
    step = step.astype(jnp.float32)
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, m, grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, v, grads)
    mhat_scale = 1.0 / (1.0 - b1**step)
    vhat_scale = 1.0 / (1.0 - b2**step)
    sp = jax.tree_util.tree_map(
        lambda p, m, v: p - lr * (m * mhat_scale) / (jnp.sqrt(v * vhat_scale) + eps),
        sp,
        m,
        v,
    )
    return sp, m, v


def train_step(params, tokens, targets, m, v, step, cfg: Config):
    """Full single-device train step (for the smoke artifact)."""
    loss, grads = jax.value_and_grad(lambda p: loss_fn(p, tokens, targets, cfg))(params)
    params, m, v = adam_update(params, grads, m, v, step)
    return loss, params, m, v
