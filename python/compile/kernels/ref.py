"""Pure-jnp reference implementations — the correctness oracle (L1).

Every Pallas kernel in this package is checked against these functions by
``python/tests/test_kernel.py`` (hypothesis sweeps shapes/dtypes and
asserts allclose). They are also used as the backward rule for the
flash-attention ``custom_vjp`` so autodiff stays in plain-HLO land (the
interpret-mode Pallas call is forward-only).
"""

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, causal=True):
    """Reference scaled-dot-product attention.

    Args:
      q, k, v: ``[batch, heads, seq, head_dim]``.
      causal: apply a causal mask.

    Returns:
      ``[batch, heads, seq, head_dim]`` attention output.
    """
    head_dim = q.shape[-1]
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(
        jnp.asarray(head_dim, q.dtype)
    )
    if causal:
        seq_q, seq_k = scores.shape[-2], scores.shape[-1]
        mask = jnp.tril(jnp.ones((seq_q, seq_k), dtype=bool), seq_k - seq_q)
        scores = jnp.where(mask, scores, jnp.asarray(-1e30, scores.dtype))
    probs = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", probs.astype(v.dtype), v)


def layernorm_ref(x, gamma, beta, eps=1e-5):
    """Reference LayerNorm over the last axis."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma + beta


def mlp_ref(x, w_in, b_in, w_out, b_out):
    """Reference GELU MLP."""
    h = jax.nn.gelu(x @ w_in + b_in)
    return h @ w_out + b_out
