"""L1: flash-attention Pallas kernel — the L2 model's compute hot-spot.

Blockwise attention with online softmax (Dao et al.), written for the TPU
mental model per the hardware-adaptation rule (DESIGN.md):

* **VMEM tiling** instead of CUDA shared-memory tiles: the grid iterates
  over query blocks; for each, K/V stream through VMEM in ``block_k``
  chunks. Per-(q-block, k-block) VMEM footprint is
  ``(Bq·d + 2·Bk·d + Bq·Bk + 2·Bq) · 4`` bytes — with the default
  Bq=Bk=128, d≤128 that is < 0.26 MiB, comfortably inside a TPU core's
  ~16 MiB VMEM even with double-buffering, leaving headroom for the MXU
  to stay fed.
* **MXU-shaped matmuls**: both the ``q·kᵀ`` and ``p·v`` contractions are
  [128×d]·[d×128] / [128×128]·[128×d] — multiples of the 128×128 systolic
  array, so the estimated MXU utilization of the kernel's matmul phase is
  ≈ d/128 per pass (1.0 at head_dim 128); see DESIGN.md §Perf.
* **interpret=True**: the CPU PJRT plugin cannot execute Mosaic
  custom-calls; interpret mode lowers to plain HLO so the same artifact
  runs under the Rust runtime. Real-TPU performance is *estimated* from
  the footprint/utilization above, never from interpret-mode wallclock.

The public entry point :func:`flash_attention` wraps the kernel in a
``jax.custom_vjp`` whose backward pass uses the pure-jnp reference
(mathematically identical), keeping autodiff in plain-HLO land.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Default block sizes: MXU-aligned, VMEM-friendly (see module docstring).
DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, causal: bool, q_offset_blocks: int):
    """One query-block of flash attention with online softmax.

    Refs arrive blocked by the BlockSpecs in :func:`_flash_call`:
      q_ref: [block_q, d]   — this grid step's query tile
      k_ref: [seq_k, d]     — full K for the (batch·head) row
      v_ref: [seq_k, d]     — full V
      o_ref: [block_q, d]   — output tile
    """
    q = q_ref[...].astype(jnp.float32)
    block_q, head_dim = q.shape
    seq_k = k_ref.shape[0]
    scale = 1.0 / (head_dim**0.5)

    q_block_idx = pl.program_id(1)
    q_start = (q_block_idx + q_offset_blocks) * block_q

    acc = jnp.zeros((block_q, head_dim), jnp.float32)
    m_i = jnp.full((block_q,), _NEG_INF, jnp.float32)  # running max
    l_i = jnp.zeros((block_q,), jnp.float32)  # running denom

    num_k_blocks = seq_k // block_k

    def body(kb, carry):
        acc, m_i, l_i = carry
        k_start = kb * block_k
        k_blk = jax.lax.dynamic_slice_in_dim(k_ref[...], k_start, block_k).astype(
            jnp.float32
        )
        v_blk = jax.lax.dynamic_slice_in_dim(v_ref[...], k_start, block_k).astype(
            jnp.float32
        )
        s = (q @ k_blk.T) * scale  # [block_q, block_k] — MXU matmul 1
        if causal:
            q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(q_pos >= k_pos, s, _NEG_INF)
        # Online softmax update.
        m_new = jnp.maximum(m_i, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_i - m_new)
        l_new = l_i * alpha + jnp.sum(p, axis=1)
        acc_new = acc * alpha[:, None] + p @ v_blk  # MXU matmul 2
        return acc_new, m_new, l_new

    acc, m_i, l_i = jax.lax.fori_loop(0, num_k_blocks, body, (acc, m_i, l_i))
    # Rows that saw no unmasked key keep l_i == 0; guard the divide.
    l_safe = jnp.where(l_i == 0.0, 1.0, l_i)
    o_ref[...] = (acc / l_safe[:, None]).astype(o_ref.dtype)


def _flash_call(q, k, v, block_q: int, block_k: int, causal: bool):
    """pallas_call plumbing over a [bh, seq, d] layout."""
    bh, seq_q, head_dim = q.shape
    seq_k = k.shape[1]
    grid = (bh, seq_q // block_q)
    kernel = functools.partial(
        _flash_kernel,
        block_k=block_k,
        causal=causal,
        # When seq_q < seq_k (not used by the model but supported), align
        # the causal mask to the *end* of the key sequence.
        q_offset_blocks=(seq_k - seq_q) // block_q if causal else 0,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, head_dim), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, seq_k, head_dim), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, seq_k, head_dim), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, head_dim), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, seq_q, head_dim), q.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls.
    )(q, k, v)


def _pick_blocks(seq, block_q, block_k):
    """Shrink blocks to divide short sequences."""
    bq = min(block_q, seq)
    while seq % bq:
        bq //= 2
    bk = min(block_k, seq)
    while seq % bk:
        bk //= 2
    return max(bq, 1), max(bk, 1)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(
    q,
    k,
    v,
    causal=True,
    block_q=DEFAULT_BLOCK_Q,
    block_k=DEFAULT_BLOCK_K,
):
    """Flash attention over ``[batch, heads, seq, head_dim]`` inputs.

    Forward runs the Pallas kernel; backward differentiates the pure-jnp
    reference (identical math) via ``custom_vjp``.
    """
    return _flash_forward(q, k, v, causal, block_q, block_k)


def _flash_forward(q, k, v, causal, block_q, block_k):
    b, h, seq_q, d = q.shape
    seq_k = k.shape[2]
    bq, bk = _pick_blocks(min(seq_q, seq_k), block_q, block_k)
    qf = q.reshape(b * h, seq_q, d)
    kf = k.reshape(b * h, seq_k, d)
    vf = v.reshape(b * h, seq_k, d)
    o = _flash_call(qf, kf, vf, bq, bk, causal)
    return o.reshape(b, h, seq_q, d)


def _flash_fwd(q, k, v, causal, block_q, block_k):
    return _flash_forward(q, k, v, causal, block_q, block_k), (q, k, v)


def _flash_bwd(causal, block_q, block_k, residuals, g):
    q, k, v = residuals
    _, vjp = jax.vjp(lambda q, k, v: ref.attention_ref(q, k, v, causal), q, k, v)
    return vjp(g)


flash_attention.defvjp(_flash_fwd, _flash_bwd)
