//! API-compatible stub of the `xla` (PJRT) crate.
//!
//! The real crate wraps libxla's PJRT C API and is only present on hosts
//! with the XLA toolchain installed. This stub exposes the same surface
//! so the runtime/trainer/profiler modules type-check and the rest of the
//! workspace builds offline; every entry point that would touch PJRT
//! returns [`XlaError`] at runtime. Callers already gate real execution
//! on `artifacts/` being present (see `nest::runtime::artifacts_dir`), so
//! the error paths are never hit in tests — if artifacts ever appear on a
//! PJRT-less host, the error message says exactly what is missing.

use std::fmt;

/// Error type mirroring `xla::Error`: implements `std::error::Error` so
/// `?` converts it into the caller's error type.
#[derive(Debug, Clone)]
pub struct XlaError(String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable(what: &str) -> XlaError {
    XlaError(format!(
        "{what}: PJRT backend not available in this build (the `xla` crate \
         is stubbed for offline environments; install libxla and swap in \
         the real vendored crate to execute artifacts)"
    ))
}

/// Host-side literal (tensor) handle. The stub carries no data; literal
/// construction succeeds (shape validation happens in the caller) and
/// every data-access method reports the backend as unavailable.
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal
    }

    /// Scalar literal.
    pub fn scalar<T>(_value: T) -> Literal {
        Literal
    }

    /// Reshape to `dims` (stub: shape bookkeeping is the caller's).
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    /// Copy the buffer out as a host vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    /// First element of the buffer.
    pub fn get_first_element<T>(&self) -> Result<T> {
        Err(unavailable("Literal::get_first_element"))
    }

    /// Flatten a tuple literal into its elements.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }
}

/// Parsed HLO module.
#[derive(Debug, Clone)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation ready for compilation.
#[derive(Debug, Clone)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device-side buffer returned by execution.
#[derive(Debug, Clone)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A compiled, loaded executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with owned or borrowed literal arguments (the generic
    /// mirrors the real crate's `BufferArgument` flexibility).
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    /// CPU PJRT client — unavailable in the stub.
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let msg = PjRtClient::cpu().unwrap_err().to_string();
        assert!(msg.contains("not available"), "{msg}");
    }

    #[test]
    fn literal_construction_succeeds() {
        let l = Literal::vec1(&[1.0f32, 2.0]).reshape(&[2]).unwrap();
        assert!(l.to_vec::<f32>().is_err());
        let _ = Literal::scalar(3i32);
    }
}
