//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment vendors every dependency (no network, no
//! registry), so this crate re-implements the subset of anyhow's API the
//! project uses: [`Error`], [`Result`], the [`Context`] extension trait,
//! and the `anyhow!` / `ensure!` / `bail!` macros. Error chains are
//! flattened into a single string eagerly — fine for diagnostics, which
//! is all this project uses errors for.

use std::fmt;

/// A string-backed error. Like `anyhow::Error` it deliberately does NOT
/// implement `std::error::Error`, so the blanket
/// `impl<E: std::error::Error> From<E> for Error` stays coherent with the
/// reflexive `From<Error> for Error` the standard library provides.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { msg: m.to_string() }
    }

    /// Prepend context, anyhow-style (`context: cause`).
    pub fn context<C: fmt::Display>(self, c: C) -> Self {
        Error {
            msg: format!("{c}: {}", self.msg),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{:#}` prints the whole (flattened) chain in real anyhow; ours
        // is already flat, so both forms render the same string.
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `anyhow::Result` — defaults the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error if a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($rest:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($rest)*));
        }
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($rest:tt)*) => {
        return Err($crate::anyhow!($($rest)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        Err(anyhow!("base {}", 42))
    }

    #[test]
    fn context_prepends() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: base 42");
        assert_eq!(format!("{e:#}"), "outer: base 42");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert!(v.context("missing").is_err());
        assert_eq!(Some(3u32).context("missing").unwrap(), 3);
    }

    #[test]
    fn ensure_and_question_mark() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x > 0, "x must be positive, got {x}");
            let parsed: u32 = "7".parse()?; // std error converts via From
            Ok(parsed + x)
        }
        assert_eq!(f(1).unwrap(), 8);
        assert!(f(0).is_err());
    }
}
