//! Property-based scenario suite: random clusters (homogeneous *and*
//! heterogeneous, 1–3 tiers, power-of-two arities) × random layer
//! graphs, plus fuzzed edge-list topologies for the flow simulator.
//!
//! For every random solve the suite asserts the cross-engine invariants
//! the shipped configs only spot-check: the returned plan is
//! memory-feasible on every device it uses, the batch time is finite
//! and positive, and a 1-thread solve is field-for-field identical to a
//! 4-thread solve. For every fuzzed topology: routing is deterministic
//! across builds, every lowered flow completes in the fair-share
//! engine, and delivered bytes equal injected bytes.
//!
//! Seeds: pinned in CI; override with `NEST_PROP_SEED=<u64>` (the
//! nightly job passes a date-derived value; `util::prop::forall` prints
//! the failing case's seed for replay).

mod common;

use common::{assert_plans_identical, prop_seed, threaded};
use nest::cost::{CostModel, PricingMode};
use nest::memory::{MemSpec, ZeroStage};
use nest::netsim::{
    flowgen, FlowSpec, LinkGraph, MixSpec, RefillMode, SimMode, Simulation, TaskKind, Workload,
};
use nest::sim::{simulate, Schedule};
use nest::solver::{solve, solve_topk, SolverOpts};
use nest::util::prop::{self, random_cluster, random_tiny_graph};
use nest::util::rng::Rng;

/// Every stage of `plan` fits the HBM of *each* device it uses, replica
/// by replica — checked directly against the per-device pool, not just
/// through `validate`'s min-capacity shortcut.
fn assert_memory_feasible_per_device(
    graph: &nest::graph::LayerGraph,
    cluster: &nest::network::Cluster,
    plan: &nest::solver::plan::PlacementPlan,
) {
    let s_total = plan.n_stages();
    for (k, st) in plan.stages.iter().enumerate() {
        let cm = CostModel::new(graph, cluster, st.sg);
        let stash = s_total - 1 - k;
        let peak = cm.stage_peak_bytes(st.layers.0, st.layers.1, &st.mem, stash);
        for r in 0..plan.dp_width {
            for &dev in &st.devices {
                let id = dev + r * plan.devices_per_replica;
                let cap = cluster.pool.accel_of(id).hbm_capacity;
                assert!(
                    peak <= cap * (1.0 + 1e-9),
                    "stage {k} peak {peak} exceeds device {id} ({}) capacity {cap}",
                    cluster.pool.accel_of(id).name
                );
            }
        }
    }
}

#[test]
fn prop_random_scenarios_valid_and_thread_invariant() {
    let seed = prop_seed(0x5CE9A210);
    prop::forall(24, seed, |rng| {
        let c = random_cluster(rng);
        let g = random_tiny_graph(rng);
        let serial = solve(&g, &c, &threaded(1));
        let parallel = solve(&g, &c, &threaded(4));
        match (serial, parallel) {
            (Some(a), Some(b)) => {
                assert_plans_identical(&a.plan, &b.plan, &c.name);
                a.plan
                    .validate(&g, &c)
                    .unwrap_or_else(|e| panic!("{}: {e}", c.name));
                assert_memory_feasible_per_device(&g, &c, &a.plan);
                assert!(
                    a.plan.batch_time.is_finite() && a.plan.batch_time > 0.0,
                    "{}: batch {}",
                    c.name,
                    a.plan.batch_time
                );
                // The shared DES evaluates the plan without panicking
                // and agrees batch time is positive.
                let rep = simulate(&g, &c, &a.plan, Schedule::OneFOneB);
                assert!(rep.batch_time.is_finite() && rep.batch_time > 0.0);
                for st in &a.plan.stages {
                    assert!(!st.accel_class.is_empty(), "{}", c.name);
                }
            }
            (None, None) => {}
            (a, b) => panic!(
                "{}: feasibility depends on thread count (serial={}, parallel={})",
                c.name,
                a.is_some(),
                b.is_some()
            ),
        }
    });
}

#[test]
fn prop_random_scenarios_topk_deterministic() {
    let seed = prop_seed(0x70D05EED);
    prop::forall(12, seed, |rng| {
        let c = random_cluster(rng);
        let g = random_tiny_graph(rng);
        let k = 1 + rng.gen_range(4);
        let a = solve_topk(&g, &c, &threaded(1), k);
        let b = solve_topk(&g, &c, &threaded(4), k);
        assert_eq!(a.plans, b.plans, "{}: k={k} shortlists diverge", c.name);
        for (x, y) in a.plans.iter().zip(&b.plans) {
            assert_eq!(x.batch_time.to_bits(), y.batch_time.to_bits(), "{}", c.name);
        }
        let direct = solve(&g, &c, &threaded(0));
        assert_eq!(
            a.plans.first(),
            direct.as_ref().map(|s| &s.plan),
            "{}: topk rank-1 disagrees with solve()",
            c.name
        );
        for p in &a.plans {
            p.validate(&g, &c).unwrap_or_else(|e| panic!("{}: {e}", c.name));
        }
    });
}

// ---------------------------------------------------------------------
// Warm-start soundness: a warm-started solve reorders the solver's
// evaluation queue only, so plans must be field-for-field identical to
// cold solves — for genuine hints, adversarial (wrong) hints, and at
// both thread counts. This is the property the placement service's
// cache-key exclusions lean on.
// ---------------------------------------------------------------------

#[test]
fn prop_warm_started_solves_identical_to_cold() {
    use nest::graph::subgraph::SgConfig;
    use nest::solver::WarmStart;

    let seed = prop_seed(0x3A9E_57A7);
    prop::forall(12, seed, |rng| {
        let c = random_cluster(rng);
        let g = random_tiny_graph(rng);
        let k = 1 + rng.gen_range(3);
        let cold = solve_topk(&g, &c, &threaded(1), k);

        // A genuine hint (the winner's own config), and an adversarial
        // one that matches no enumerated configuration.
        let mut hints: Vec<WarmStart> = cold.plans.first().map(WarmStart::from_plan).into_iter().collect();
        hints.push(WarmStart {
            sg: SgConfig {
                tp: 64 + rng.gen_range(64),
                sp: false,
                ep: 1,
                cp: 1,
            },
            recompute: rng.gen_bool(0.5),
        });
        for hint in hints {
            for threads in [1usize, 4] {
                let warm_opts = SolverOpts {
                    warm_start: Some(hint),
                    ..threaded(threads)
                };
                let warm = solve_topk(&g, &c, &warm_opts, k);
                assert_eq!(
                    warm.plans.len(),
                    cold.plans.len(),
                    "{}: warm start changed shortlist size",
                    c.name
                );
                for (w, cold_plan) in warm.plans.iter().zip(&cold.plans) {
                    assert_plans_identical(w, cold_plan, &format!("{} warm vs cold", c.name));
                }
            }
        }
    });
}

#[test]
fn prop_service_cache_hits_and_warm_solves_identical_to_cold() {
    use nest::service::{PlacementService, Query};

    let seed = prop_seed(0xCAC4E5EE);
    prop::forall(8, seed, |rng| {
        let c = random_cluster(rng);
        let g = random_tiny_graph(rng);
        let k = 1 + rng.gen_range(3);
        for threads in [1usize, 4] {
            let mut svc = PlacementService::new(8);
            let q = Query::new(g.clone(), c.clone(), threaded(threads));
            let cold = solve_topk(&g, &c, &threaded(threads), k);

            let first = svc.solve_topk(&q, k);
            assert!(!first.cache_hit, "{}", c.name);
            let hit = svc.solve_topk(&q, k);
            assert!(hit.cache_hit, "{}: identical query must hit", c.name);
            for served in [&first, &hit] {
                assert_eq!(served.plans.len(), cold.plans.len(), "{}", c.name);
                for (s, cp) in served.plans.iter().zip(&cold.plans) {
                    assert_plans_identical(s, cp, &format!("{} served vs cold", c.name));
                }
            }

            // Mutating any fingerprinted cluster field must miss — and
            // the (possibly warm-started) re-solve must still equal its
            // own cold twin.
            let mut c2 = c.clone();
            let t = rng.gen_range(c2.tiers.len());
            c2.tiers[t].link_bw *= 0.5;
            let q2 = Query::new(g.clone(), c2.clone(), threaded(threads));
            let served2 = svc.solve_topk(&q2, k);
            assert!(!served2.cache_hit, "{}: mutated cluster must miss", c.name);
            let cold2 = solve_topk(&g, &c2, &threaded(threads), k);
            assert_eq!(served2.plans.len(), cold2.plans.len(), "{}", c.name);
            for (s, cp) in served2.plans.iter().zip(&cold2.plans) {
                assert_plans_identical(s, cp, &format!("{} mutated-cluster serve", c.name));
            }
        }
    });
}

// ---------------------------------------------------------------------
// Hot-path twins: O(1) range-pricing tables vs the naive reference, and
// incremental fair-share vs the full refill. Both optimizations claim
// bit-identical outputs; these suites are the proof on random inputs.
// ---------------------------------------------------------------------

fn pricing_opts(threads: usize, pricing: PricingMode) -> SolverOpts {
    SolverOpts {
        pricing,
        ..threaded(threads)
    }
}

#[test]
fn prop_prefix_pricing_matches_reference() {
    // Random hom/het clusters × random graphs: every cost-model range
    // query — and therefore every solved plan, at 1 and 4 threads —
    // must be bit-identical between the prefix/sparse-table pricing and
    // the naive layer/tier-walking reference.
    let seed = prop_seed(0x9A1C1E5);
    prop::forall(12, seed, |rng| {
        let c = random_cluster(rng);
        let g = random_tiny_graph(rng);
        let sg = nest::graph::subgraph::SgConfig::serial();
        let opt = CostModel::with_mode(&g, &c, sg, PricingMode::Optimized);
        let refm = CostModel::with_mode(&g, &c, sg, PricingMode::Reference);
        let n = opt.n_layers();
        let cap = c.pool.min_capacity_all();
        for _ in 0..24 {
            let i = rng.gen_range(n - 1);
            let j = i + 1 + rng.gen_range(n - i - 1);
            let rc = rng.gen_bool(0.5);
            let spec = MemSpec {
                zero: if rng.gen_bool(0.3) {
                    ZeroStage::Z3 { degree: 4 }
                } else {
                    ZeroStage::None
                },
                recompute: rc,
            };
            let recv = if rng.gen_bool(0.5) {
                Some(rng.gen_range(c.n_levels()))
            } else {
                None
            };
            let send = if rng.gen_bool(0.5) {
                Some(rng.gen_range(c.n_levels()))
            } else {
                None
            };
            let mask = c.pool.full_mask();
            let a = opt.stage_load_on(mask, i, j, recv, send, &spec, &c);
            let b = refm.stage_load_on(mask, i, j, recv, send, &spec, &c);
            assert_eq!(a.to_bits(), b.to_bits(), "{}: load [{i},{j})", c.name);
            let stash = rng.gen_range(6);
            assert_eq!(
                opt.stage_peak_bytes(i, j, &spec, stash).to_bits(),
                refm.stage_peak_bytes(i, j, &spec, stash).to_bits(),
                "{}: peak [{i},{j})",
                c.name
            );
            assert_eq!(
                opt.stage_choose_spec(i, j, stash, cap, 8, rc),
                refm.stage_choose_spec(i, j, stash, cap, 8, rc),
                "{}: spec [{i},{j})",
                c.name
            );
        }
        // End to end: the full search is plan-identical under both
        // pricing modes at 1 and 4 worker threads.
        for threads in [1usize, 4] {
            let o = solve(&g, &c, &pricing_opts(threads, PricingMode::Optimized));
            let r = solve(&g, &c, &pricing_opts(threads, PricingMode::Reference));
            match (o, r) {
                (Some(a), Some(b)) => {
                    assert_plans_identical(
                        &a.plan,
                        &b.plan,
                        &format!("{} pricing threads={threads}", c.name),
                    );
                }
                (None, None) => {}
                (a, b) => panic!(
                    "{}: feasibility depends on pricing mode (opt={}, ref={})",
                    c.name,
                    a.is_some(),
                    b.is_some()
                ),
            }
        }
    });
}

// ---------------------------------------------------------------------
// Netsim fuzz: random connected edge-lists.
// ---------------------------------------------------------------------

/// Generate a random connected edge-list topology JSON: 4–32 nodes
/// (devices + switches), a random spanning tree over *all* nodes plus
/// extra chords, random bandwidths/latencies. Links are bidirectional
/// (the parser's default), so tree connectivity implies full device
/// reachability.
fn random_edgelist_json(rng: &mut Rng) -> String {
    let n_devices = 4 + rng.gen_range(21); // 4..=24
    let n_switches = rng.gen_range(9).min(32 - n_devices); // 0..=8
    let total = n_devices + n_switches;
    let mut nodes: Vec<String> = Vec::new();
    let mut decls: Vec<String> = Vec::new();
    for i in 0..n_devices {
        nodes.push(format!("d{i}"));
        decls.push(format!("{{\"id\": \"d{i}\", \"kind\": \"device\"}}"));
    }
    for i in 0..n_switches {
        nodes.push(format!("s{i}"));
        decls.push(format!("{{\"id\": \"s{i}\", \"kind\": \"switch\"}}"));
    }
    let mut links: Vec<String> = Vec::new();
    fn link(rng: &mut Rng, a: &str, b: &str) -> String {
        let bw = 1.0 + 99.0 * rng.gen_f64();
        let lat = 0.5 + 4.5 * rng.gen_f64();
        format!(
            "{{\"src\": \"{a}\", \"dst\": \"{b}\", \"bw_gbps\": {bw:.3}, \
             \"latency_us\": {lat:.3}}}"
        )
    }
    // Spanning tree: node i attaches to a random earlier node.
    for i in 1..total {
        let j = rng.gen_range(i);
        links.push(link(rng, &nodes[i], &nodes[j]));
    }
    // Extra chords.
    for _ in 0..rng.gen_range(total) {
        let a = rng.gen_range(total);
        let b = rng.gen_range(total);
        if a != b {
            links.push(link(rng, &nodes[a], &nodes[b]));
        }
    }
    format!(
        "{{\"name\": \"fuzz-{total}\", \"nodes\": [{}], \"links\": [{}]}}",
        decls.join(", "),
        links.join(", ")
    )
}

#[test]
fn prop_netsim_fuzz_routing_deterministic_and_bytes_conserved() {
    let seed = prop_seed(0xF1025EED);
    prop::forall(20, seed, |rng| {
        let json = random_edgelist_json(rng);
        let parsed = nest::util::json::parse(&json).expect("fuzz JSON parses");
        let a = LinkGraph::from_json(&parsed).expect("fuzz topology builds");
        let b = LinkGraph::from_json(&parsed).expect("rebuild");
        let n = a.n_devices();
        assert!(n >= 2);

        // Routing is deterministic across builds: identical link
        // sequences for sampled pairs (and for every pair on small n).
        for _ in 0..32 {
            let x = rng.gen_range(n);
            let mut y = rng.gen_range(n);
            if x == y {
                y = (y + 1) % n;
            }
            let pa = a.path(x, y);
            let pb = b.path(x, y);
            assert_eq!(pa.links, pb.links, "route {x}->{y} differs across builds");
            assert_eq!(pa.latency.to_bits(), pb.latency.to_bits());
        }

        // Random workload: a few chains of compute → concurrent flows.
        let build_wl = |rng: &mut Rng| {
            let mut wl = Workload::new();
            let mut injected = 0.0f64;
            let n_tasks = 1 + rng.gen_range(6);
            let mut prev: Option<u32> = None;
            for _ in 0..n_tasks {
                let deps: Vec<u32> = prev.into_iter().collect();
                let cmp = wl.add(
                    TaskKind::Compute {
                        seconds: rng.gen_f64() * 1e-3,
                    },
                    &deps,
                );
                let mut flows = Vec::new();
                for _ in 0..(1 + rng.gen_range(6)) {
                    let src = rng.gen_range(n);
                    let mut dst = rng.gen_range(n);
                    if src == dst {
                        dst = (dst + 1) % n;
                    }
                    let bytes = 1e6 * (1.0 + rng.gen_f64() * 1e3);
                    injected += bytes;
                    flows.push(FlowSpec { src, dst, bytes });
                }
                prev = Some(wl.add(
                    TaskKind::Transfer {
                        flows,
                        extra_latency: 0.0,
                    },
                    &[cmp],
                ));
            }
            (wl, injected)
        };
        let mut probe = rng.clone();
        let (wl, injected) = build_wl(&mut probe);
        // Every flow completes (the engine asserts all tasks finish)
        // and the report is sane.
        let rep = Simulation::new().run_workload(&a, &wl);
        assert!(rep.batch_time.is_finite() && rep.batch_time > 0.0);
        assert!((rep.total_bytes - injected).abs() < 1.0, "injection accounting");
        // Conservation: delivered bytes equal injected bytes up to the
        // engine's half-byte completion tolerance per flow.
        assert!(
            (rep.delivered_bytes - rep.total_bytes).abs() <= 0.5 * rep.n_flows as f64 + 1e-6,
            "delivered {} vs injected {} over {} flows",
            rep.delivered_bytes,
            rep.total_bytes,
            rep.n_flows
        );
        // Re-running the identical workload is bit-identical.
        let mut probe2 = rng.clone();
        let (wl2, _) = build_wl(&mut probe2);
        let rep2 = Simulation::new().run_workload(&a, &wl2);
        assert_eq!(rep.batch_time.to_bits(), rep2.batch_time.to_bits());
        assert_eq!(rep.events, rep2.events);
        assert_eq!(rep.n_flows, rep2.n_flows);
    });
}

#[test]
fn prop_fairshare_incremental_matches_full_refill() {
    // Random connected edge-lists × random flow DAGs (with parallel
    // chains, so several link-sharing components are alive at once):
    // the incremental dirty-component engine must reproduce the naive
    // every-event full refill field-for-field, at bit precision.
    let seed = prop_seed(0x1FC5_11A7);
    prop::forall(16, seed, |rng| {
        let json = random_edgelist_json(rng);
        let parsed = nest::util::json::parse(&json).expect("fuzz JSON parses");
        let topo = LinkGraph::from_json(&parsed).expect("fuzz topology builds");
        let n = topo.n_devices();
        let build_wl = |rng: &mut Rng| {
            let mut wl = Workload::new();
            // 1–3 independent chains of compute → concurrent flows.
            for _ in 0..(1 + rng.gen_range(3)) {
                let mut prev: Option<u32> = None;
                for _ in 0..(1 + rng.gen_range(5)) {
                    let deps: Vec<u32> = prev.into_iter().collect();
                    let cmp = wl.add(
                        TaskKind::Compute {
                            seconds: rng.gen_f64() * 1e-3,
                        },
                        &deps,
                    );
                    let mut flows = Vec::new();
                    for _ in 0..(1 + rng.gen_range(5)) {
                        let src = rng.gen_range(n);
                        let mut dst = rng.gen_range(n);
                        if src == dst {
                            dst = (dst + 1) % n;
                        }
                        flows.push(FlowSpec {
                            src,
                            dst,
                            bytes: 1e6 * (1.0 + rng.gen_f64() * 1e3),
                        });
                    }
                    prev = Some(wl.add(
                        TaskKind::Transfer {
                            flows,
                            extra_latency: rng.gen_f64() * 1e-6,
                        },
                        &[cmp],
                    ));
                }
            }
            wl
        };
        let mut probe = rng.clone();
        let inc = Simulation::new()
            .refill(RefillMode::Incremental)
            .run_workload(&topo, &build_wl(&mut probe));
        let mut probe = rng.clone();
        let full = Simulation::new()
            .refill(RefillMode::FullRefill)
            .run_workload(&topo, &build_wl(&mut probe));
        inc.assert_bits_eq(&full, "incremental vs full refill");
    });
}

#[test]
fn prop_decomposed_matches_monolithic() {
    // The decomposition theorem, fuzzed: on random connected edge-lists
    // × random multi-chain workloads (several link-sharing components
    // alive at once), the statically partitioned, thread-fanned
    // decomposed engine must reproduce the monolithic event loop
    // *field-for-field at bit precision* — at 1 and 4 worker threads,
    // under both rate-maintenance strategies.
    let seed = prop_seed(0xDEC0_3305);
    prop::forall(14, seed, |rng| {
        let json = random_edgelist_json(rng);
        let parsed = nest::util::json::parse(&json).expect("fuzz JSON parses");
        let topo = LinkGraph::from_json(&parsed).expect("fuzz topology builds");
        let n = topo.n_devices();
        let build_wl = |rng: &mut Rng| {
            let mut wl = Workload::new();
            // 2–5 independent chains → the partition usually has > 1
            // component, so the merge path is genuinely exercised.
            for _ in 0..(2 + rng.gen_range(4)) {
                let mut prev: Option<u32> = None;
                for _ in 0..(1 + rng.gen_range(4)) {
                    let deps: Vec<u32> = prev.into_iter().collect();
                    let cmp = wl.add(
                        TaskKind::Compute {
                            seconds: rng.gen_f64() * 1e-3,
                        },
                        &deps,
                    );
                    let mut flows = Vec::new();
                    for _ in 0..(1 + rng.gen_range(5)) {
                        let src = rng.gen_range(n);
                        let mut dst = rng.gen_range(n);
                        if src == dst {
                            dst = (dst + 1) % n;
                        }
                        flows.push(FlowSpec {
                            src,
                            dst,
                            bytes: 1e6 * (1.0 + rng.gen_f64() * 1e3),
                        });
                    }
                    prev = Some(wl.add(
                        TaskKind::Transfer {
                            flows,
                            extra_latency: rng.gen_f64() * 1e-6,
                        },
                        &[cmp],
                    ));
                }
            }
            wl
        };
        let mut probe = rng.clone();
        let wl = build_wl(&mut probe);
        for refill in [RefillMode::Incremental, RefillMode::FullRefill] {
            let mono = Simulation::new()
                .mode(SimMode::Monolithic)
                .refill(refill)
                .run_workload(&topo, &wl);
            for threads in [1usize, 4] {
                let dec = Simulation::new()
                    .mode(SimMode::Decomposed)
                    .refill(refill)
                    .threads(threads)
                    .run_workload(&topo, &wl);
                dec.assert_bits_eq(
                    &mono,
                    &format!("decomposed({threads}t, {refill:?}) vs monolithic"),
                );
            }
        }
    });
}

#[test]
fn prop_fattree_scale_fuzz_conserves_bytes_and_is_deterministic() {
    // The generated fat-tree + synthetic rack-local workload the
    // `netsim-scale` driver runs, fuzzed over seeds and locality: every
    // injected byte is delivered (up to the engine's half-byte
    // completion tolerance per flow), reports are bit-identical across
    // runs, and decomposed ≡ monolithic on every draw.
    let seed = prop_seed(0xFA77_0EE5);
    let fabric = nest::netsim::topo::fattree(4);
    prop::forall(8, seed, |rng| {
        let wseed = rng.gen_range(1 << 20) as u64;
        let locality = rng.gen_f64();
        let flows = 200 + rng.gen_range(600);
        let wl = nest::harness::scale::scale_workload(
            fabric.n_devices(),
            2,
            4,
            flows,
            locality,
            wseed,
        );
        let mono = Simulation::new()
            .mode(SimMode::Monolithic)
            .run_workload(&fabric, &wl);
        assert_eq!(mono.n_flows, flows, "every synthesized flow crosses the network");
        assert!(
            (mono.delivered_bytes - mono.total_bytes).abs()
                <= 0.5 * mono.n_flows as f64 + 1e-6,
            "delivered {} vs injected {} over {} flows (seed {wseed})",
            mono.delivered_bytes,
            mono.total_bytes,
            mono.n_flows
        );
        // Cross-run determinism, then the decomposition theorem again
        // at fabric scale.
        let rerun = Simulation::new()
            .mode(SimMode::Monolithic)
            .run_workload(&fabric, &wl);
        rerun.assert_bits_eq(&mono, "fat-tree monolithic rerun");
        for threads in [1usize, 4] {
            let dec = Simulation::new()
                .mode(SimMode::Decomposed)
                .threads(threads)
                .run_workload(&fabric, &wl);
            dec.assert_bits_eq(&mono, &format!("fat-tree decomposed {threads}t"));
        }
    });
}

// ---------------------------------------------------------------------
// Background-flow generator (netsim::flowgen): seeded determinism, load
// targeting, and the monotone-degradation property on chain workloads.
// ---------------------------------------------------------------------

/// A serial training chain (compute → concurrent flows → compute → …):
/// exactly one training task is active at a time, which is the regime
/// where background injection provably cannot *speed up* training (see
/// `prop_background_never_speeds_up_training_chains`). Returns the
/// workload and its injected training bytes.
fn random_training_chain(rng: &mut Rng, n: usize) -> (Workload, f64) {
    let mut wl = Workload::new();
    let mut injected = 0.0f64;
    let mut prev: Option<u32> = None;
    for _ in 0..(2 + rng.gen_range(5)) {
        let deps: Vec<u32> = prev.into_iter().collect();
        let cmp = wl.add(
            TaskKind::Compute {
                seconds: rng.gen_f64() * 1e-3,
            },
            &deps,
        );
        let mut flows = Vec::new();
        for _ in 0..(1 + rng.gen_range(5)) {
            let src = rng.gen_range(n);
            let mut dst = rng.gen_range(n);
            if src == dst {
                dst = (dst + 1) % n;
            }
            let bytes = 1e6 * (1.0 + rng.gen_f64() * 1e2);
            injected += bytes;
            flows.push(FlowSpec { src, dst, bytes });
        }
        prev = Some(wl.add(
            TaskKind::Transfer {
                flows,
                extra_latency: 0.0,
            },
            &[cmp],
        ));
    }
    (wl, injected)
}

#[test]
fn prop_flowgen_deterministic_and_hits_target_load() {
    // On random connected edge-lists: the same (topo, spec) yields a
    // bit-identical mix, a different seed yields a different one, the
    // achieved max per-link offered load lands on the target (the spec
    // demands ±10%; the linear rescale hits it to fp precision), and a
    // mixed training+background workload replays bit-identically across
    // simulator modes and thread counts.
    let seed = prop_seed(0xF70_11E2);
    prop::forall(12, seed, |rng| {
        let json = random_edgelist_json(rng);
        let parsed = nest::util::json::parse(&json).expect("fuzz JSON parses");
        let topo = LinkGraph::from_json(&parsed).expect("fuzz topology builds");
        let n = topo.n_devices();
        let target = 0.05 + 0.85 * rng.gen_f64();
        let duration = 1e-3 * (1.0 + rng.gen_f64() * 9.0);
        let mix_seed = rng.next_u64();
        let spec = MixSpec::at_load(target, duration, mix_seed);

        // Same seed ⇒ bit-identical flow set; different seed ⇒ not.
        let a = flowgen::generate(&topo, &spec);
        let b = flowgen::generate(&topo, &spec);
        assert_eq!(a.flows.len(), b.flows.len(), "flow count diverged across draws");
        for (x, y) in a.flows.iter().zip(&b.flows) {
            assert_eq!(x.at.to_bits(), y.at.to_bits());
            assert_eq!(x.flow.src, y.flow.src);
            assert_eq!(x.flow.dst, y.flow.dst);
            assert_eq!(x.flow.bytes.to_bits(), y.flow.bytes.to_bits());
        }
        let other = flowgen::generate(
            &topo,
            &MixSpec {
                seed: mix_seed ^ 0xDEAD_BEEF,
                ..spec.clone()
            },
        );
        let same = a.flows.len() == other.flows.len()
            && a.flows.iter().zip(&other.flows).all(|(x, y)| {
                x.at.to_bits() == y.at.to_bits()
                    && x.flow.bytes.to_bits() == y.flow.bytes.to_bits()
            });
        assert!(!same, "different seeds produced an identical mix");

        // Load targeting: the rescale lands the hottest link on the
        // target exactly (well inside the spec's ±10%).
        if a.flows.is_empty() {
            assert_eq!(a.offered_max_load, 0.0);
        } else {
            let achieved = flowgen::offered_load(&topo, &a.flows, a.duration);
            assert!(
                (achieved - target).abs() <= target * 1e-9,
                "offered load {achieved} missed target {target}"
            );
            assert_eq!(achieved.to_bits(), a.offered_max_load.to_bits());
        }

        // Mixed replay is bit-identical across modes and thread counts,
        // and the report accounts training vs background separately.
        let mut probe = rng.clone();
        let (mut wl, train_bytes) = random_training_chain(&mut probe, n);
        let injected = flowgen::inject(&mut wl, &a);
        let mono = Simulation::new()
            .mode(SimMode::Monolithic)
            .run_workload(&topo, &wl);
        for threads in [1usize, 4] {
            let dec = Simulation::new()
                .mode(SimMode::Decomposed)
                .threads(threads)
                .run_workload(&topo, &wl);
            dec.assert_bits_eq(&mono, &format!("mixed workload decomposed {threads}t"));
        }
        assert_eq!(mono.bg_flows, injected, "every injected flow is accounted");
        assert!(
            ((mono.total_bytes - mono.bg_bytes) - train_bytes).abs() < 1.0,
            "training bytes {} vs injected {train_bytes}",
            mono.total_bytes - mono.bg_bytes
        );
    });
}

#[test]
fn prop_background_never_speeds_up_training_chains() {
    // On random connected edge-lists × serial training chains (one
    // training task active at a time — max-min makespans are NOT
    // monotone under injection when training transfers overlap, so the
    // chain structure is load-bearing): injecting any background mix
    // never decreases the training batch time, and delivered bytes
    // conserve injected bytes with training and background accounted
    // separately.
    let seed = prop_seed(0xB6_10AD);
    prop::forall(12, seed, |rng| {
        let json = random_edgelist_json(rng);
        let parsed = nest::util::json::parse(&json).expect("fuzz JSON parses");
        let topo = LinkGraph::from_json(&parsed).expect("fuzz topology builds");
        let n = topo.n_devices();
        let mut probe = rng.clone();
        let (wl, _) = random_training_chain(&mut probe, n);
        let base = Simulation::new().run_workload(&topo, &wl);
        // A clean run is all training: the training clock IS the batch
        // clock and no background is reported.
        assert_eq!(base.train_batch_time.to_bits(), base.batch_time.to_bits());
        assert_eq!(base.bg_flows, 0);
        assert_eq!(base.bg_bytes, 0.0);

        let load = 0.1 + 0.8 * rng.gen_f64();
        let spec = MixSpec::at_load(load, base.batch_time, rng.next_u64());
        let mix = flowgen::generate(&topo, &spec);
        let mut probe = rng.clone();
        let (mut mixed_wl, train_bytes) = random_training_chain(&mut probe, n);
        let injected = flowgen::inject(&mut mixed_wl, &mix);
        let rep = Simulation::new().run_workload(&topo, &mixed_wl);

        // Monotone degradation (fp-tolerant: with a single active
        // training task, work conservation on each saturated link makes
        // the bound exact).
        assert!(
            rep.train_batch_time >= base.batch_time * (1.0 - 1e-9),
            "background sped training up: {} < {} at load {load}",
            rep.train_batch_time,
            base.batch_time
        );
        assert!(rep.train_batch_time <= rep.batch_time, "training outlived the batch");

        // Conservation, split by class: background bytes match the
        // materialized mix, training bytes match the chain, and each
        // class's delivered bytes equal its injected bytes up to the
        // engine's half-byte completion tolerance per flow.
        let bg_injected: f64 = mix
            .flows
            .iter()
            .filter(|f| f.flow.bytes > 0.5)
            .map(|f| f.flow.bytes)
            .sum();
        assert_eq!(rep.bg_flows, injected);
        assert!(
            (rep.bg_bytes - bg_injected).abs() <= 1e-6 * bg_injected.max(1.0),
            "bg bytes {} vs injected {bg_injected}",
            rep.bg_bytes
        );
        assert!(
            ((rep.total_bytes - rep.bg_bytes) - train_bytes).abs() < 1.0,
            "training bytes {} vs injected {train_bytes}",
            rep.total_bytes - rep.bg_bytes
        );
        assert!(
            (rep.bg_delivered_bytes - rep.bg_bytes).abs()
                <= 0.5 * rep.bg_flows as f64 + 1e-6,
            "bg delivered {} vs offered {}",
            rep.bg_delivered_bytes,
            rep.bg_bytes
        );
        let train_flows = rep.n_flows - rep.bg_flows;
        let train_delivered = rep.delivered_bytes - rep.bg_delivered_bytes;
        assert!(
            (train_delivered - train_bytes).abs() <= 0.5 * train_flows as f64 + 1e-6,
            "training delivered {train_delivered} vs injected {train_bytes}"
        );
    });
}

// ---------------------------------------------------------------------
// Fault injector (netsim::faults): seeded determinism across simulator
// modes and thread counts, and the monotone-degradation property for
// link kills/brownouts on chain workloads.
// ---------------------------------------------------------------------

#[test]
fn prop_faults_deterministic_across_modes_and_threads() {
    // On random connected edge-lists × random multi-chain workloads:
    // the same (topo, spec) draws a bit-identical fault scenario, and a
    // fault-injected replay — timed capacity kills/brownouts/flaps
    // riding the cap-event path — is bit-identical between Monolithic
    // and Decomposed at 1 and 4 worker threads.
    use nest::netsim::faults::{self, FaultSpec};

    let seed = prop_seed(0xFA_D37E);
    prop::forall(12, seed, |rng| {
        let json = random_edgelist_json(rng);
        let parsed = nest::util::json::parse(&json).expect("fuzz JSON parses");
        let topo = LinkGraph::from_json(&parsed).expect("fuzz topology builds");
        let n = topo.n_devices();
        let build_wl = |rng: &mut Rng| {
            let mut wl = Workload::new();
            // 2–4 independent chains, so the decomposed partition has
            // several components sharing the faulted links.
            for _ in 0..(2 + rng.gen_range(3)) {
                let mut prev: Option<u32> = None;
                for _ in 0..(1 + rng.gen_range(4)) {
                    let deps: Vec<u32> = prev.into_iter().collect();
                    let cmp = wl.add(
                        TaskKind::Compute {
                            seconds: rng.gen_f64() * 1e-3,
                        },
                        &deps,
                    );
                    let mut flows = Vec::new();
                    for _ in 0..(1 + rng.gen_range(5)) {
                        let src = rng.gen_range(n);
                        let mut dst = rng.gen_range(n);
                        if src == dst {
                            dst = (dst + 1) % n;
                        }
                        flows.push(FlowSpec {
                            src,
                            dst,
                            bytes: 1e6 * (1.0 + rng.gen_f64() * 1e2),
                        });
                    }
                    prev = Some(wl.add(
                        TaskKind::Transfer {
                            flows,
                            extra_latency: 0.0,
                        },
                        &[cmp],
                    ));
                }
            }
            wl
        };

        let spec = FaultSpec::at_severity(
            0.2 + 0.8 * rng.gen_f64(),
            1e-3 * (1.0 + rng.gen_f64() * 9.0),
            rng.next_u64(),
        );
        // Same (topo, spec) ⇒ bit-identical scenario and cap events.
        let sc = faults::draw(&topo, &spec);
        let sc2 = faults::draw(&topo, &spec);
        let (ev, ev2) = (sc.cap_events(&topo), sc2.cap_events(&topo));
        assert_eq!(ev.len(), ev2.len(), "fault draw diverged across calls");
        for (x, y) in ev.iter().zip(&ev2) {
            assert_eq!(x.at.to_bits(), y.at.to_bits());
            assert_eq!(x.link, y.link);
            assert_eq!(x.capacity.to_bits(), y.capacity.to_bits());
        }

        let mut probe = rng.clone();
        let mut wl = build_wl(&mut probe);
        faults::inject(&mut wl, &topo, &sc);
        let mono = Simulation::new()
            .mode(SimMode::Monolithic)
            .run_workload(&topo, &wl);
        assert!(mono.batch_time.is_finite() && mono.batch_time > 0.0);
        for threads in [1usize, 4] {
            let dec = Simulation::new()
                .mode(SimMode::Decomposed)
                .threads(threads)
                .run_workload(&topo, &wl);
            dec.assert_bits_eq(&mono, &format!("faulted decomposed {threads}t"));
        }
    });
}

#[test]
fn prop_link_kill_never_speeds_up_training() {
    // On random connected edge-lists × serial training chains (one
    // training task active at a time — the regime where capacity loss
    // is provably monotone): killing or degrading a link the chain
    // actually crosses never decreases the training batch time.
    use nest::netsim::faults::{self, FaultScenario, LinkFault};

    let seed = prop_seed(0x1C11_5EED);
    prop::forall(12, seed, |rng| {
        let json = random_edgelist_json(rng);
        let parsed = nest::util::json::parse(&json).expect("fuzz JSON parses");
        let topo = LinkGraph::from_json(&parsed).expect("fuzz topology builds");
        let n = topo.n_devices();

        // A serial chain built inline so the flow endpoints are known:
        // used links come from the same deterministic routes the engine
        // takes.
        let mut endpoints: Vec<(usize, usize)> = Vec::new();
        let build_wl = |rng: &mut Rng, eps: &mut Vec<(usize, usize)>| {
            let mut wl = Workload::new();
            let mut prev: Option<u32> = None;
            for _ in 0..(2 + rng.gen_range(4)) {
                let deps: Vec<u32> = prev.into_iter().collect();
                let cmp = wl.add(
                    TaskKind::Compute {
                        seconds: rng.gen_f64() * 1e-3,
                    },
                    &deps,
                );
                let mut flows = Vec::new();
                for _ in 0..(1 + rng.gen_range(4)) {
                    let src = rng.gen_range(n);
                    let mut dst = rng.gen_range(n);
                    if src == dst {
                        dst = (dst + 1) % n;
                    }
                    eps.push((src, dst));
                    flows.push(FlowSpec {
                        src,
                        dst,
                        bytes: 1e6 * (1.0 + rng.gen_f64() * 1e2),
                    });
                }
                prev = Some(wl.add(
                    TaskKind::Transfer {
                        flows,
                        extra_latency: 0.0,
                    },
                    &[cmp],
                ));
            }
            wl
        };
        let mut probe = rng.clone();
        let wl = build_wl(&mut probe, &mut endpoints);
        let base = Simulation::new().run_workload(&topo, &wl);
        assert_eq!(base.train_batch_time.to_bits(), base.batch_time.to_bits());

        // Pick a link a random training flow crosses and fault it —
        // a hard kill or a brownout, striking inside the clean run.
        let (src, dst) = endpoints[rng.gen_range(endpoints.len())];
        let used = topo.path(src, dst).links;
        let link = used[rng.gen_range(used.len())];
        let at = rng.gen_f64() * 0.9 * base.batch_time;
        let fault = if rng.gen_bool(0.5) {
            LinkFault::Kill { at }
        } else {
            LinkFault::Brownout {
                at,
                fraction: (0.05 + 0.5 * rng.gen_f64()).min(1.0),
            }
        };
        let sc = FaultScenario {
            link_faults: vec![(link, fault)],
            stragglers: Vec::new(),
        };
        let mut endpoints2 = Vec::new();
        let mut probe = rng.clone();
        let mut faulted_wl = build_wl(&mut probe, &mut endpoints2);
        faults::inject(&mut faulted_wl, &topo, &sc);
        let rep = Simulation::new().run_workload(&topo, &faulted_wl);
        assert!(
            rep.train_batch_time.is_finite() && rep.train_batch_time > 0.0,
            "faulted chain never completed"
        );
        assert!(
            rep.train_batch_time >= base.batch_time * (1.0 - 1e-9),
            "fault {fault:?} on link {link} sped training up: {} < {}",
            rep.train_batch_time,
            base.batch_time
        );
    });
}

// ---------------------------------------------------------------------
// Flight recorder: tracing sits *outside* the determinism boundary.
// Enabling the recorder may only observe the pipeline — every solver
// shortlist, service response, and netsim report must be
// field-for-field (bit-for-bit for floats) identical to its untraced
// twin, at 1 and 4 worker threads.
// ---------------------------------------------------------------------

#[test]
fn prop_tracing_is_outside_the_determinism_boundary() {
    use nest::obs;
    use nest::service::{PlacementService, Query};

    // The recorder's enable bit and collector are process-global:
    // serialize against the obs unit tests and drop any stale buffers.
    let _guard = obs::exclusive();
    let _ = obs::drain();

    let seed = prop_seed(0x0B5_7ACE);
    prop::forall(6, seed, |rng| {
        let c = random_cluster(rng);
        let g = random_tiny_graph(rng);
        let k = 1 + rng.gen_range(3);
        let json = random_edgelist_json(rng);
        let parsed = nest::util::json::parse(&json).expect("fuzz JSON parses");
        let topo = LinkGraph::from_json(&parsed).expect("fuzz topology builds");
        let n = topo.n_devices();
        let build_wl = |rng: &mut Rng| {
            let mut wl = Workload::new();
            let mut prev: Option<u32> = None;
            for _ in 0..(1 + rng.gen_range(4)) {
                let deps: Vec<u32> = prev.into_iter().collect();
                let cmp = wl.add(
                    TaskKind::Compute {
                        seconds: rng.gen_f64() * 1e-3,
                    },
                    &deps,
                );
                let mut flows = Vec::new();
                for _ in 0..(1 + rng.gen_range(4)) {
                    let src = rng.gen_range(n);
                    let mut dst = rng.gen_range(n);
                    if src == dst {
                        dst = (dst + 1) % n;
                    }
                    flows.push(FlowSpec {
                        src,
                        dst,
                        bytes: 1e6 * (1.0 + rng.gen_f64() * 1e2),
                    });
                }
                prev = Some(wl.add(
                    TaskKind::Transfer {
                        flows,
                        extra_latency: 0.0,
                    },
                    &[cmp],
                ));
            }
            wl
        };

        for threads in [1usize, 4] {
            // Untraced references.
            assert!(!obs::enabled(), "recorder leaked on from a prior case");
            let cold = solve_topk(&g, &c, &threaded(threads), k);
            let q = Query::new(g.clone(), c.clone(), threaded(threads));
            let mut svc = PlacementService::new(4);
            let served_cold = svc.solve_topk(&q, k);
            let served_hit = svc.solve_topk(&q, k);
            let mut probe = rng.clone();
            let rep = Simulation::new().run_workload(&topo, &build_wl(&mut probe));

            // Traced twins of the exact same calls.
            obs::set_enabled(true);
            let traced = solve_topk(&g, &c, &threaded(threads), k);
            let mut svc2 = PlacementService::new(4);
            let t_cold = svc2.solve_topk(&q, k);
            let t_hit = svc2.solve_topk(&q, k);
            let mut probe = rng.clone();
            let rep2 = Simulation::new().run_workload(&topo, &build_wl(&mut probe));
            obs::set_enabled(false);
            let data = obs::drain();
            assert!(data.n_spans() > 0, "traced pipeline recorded no spans");

            // Solver shortlist: identical plans, bit-identical floats.
            assert_eq!(traced.plans, cold.plans, "{}: traced shortlist diverged", c.name);
            for (x, y) in traced.plans.iter().zip(&cold.plans) {
                assert_eq!(x.batch_time.to_bits(), y.batch_time.to_bits(), "{}", c.name);
            }

            // Service: same hit/miss behaviour, identical served plans.
            for (t, u) in [(&t_cold, &served_cold), (&t_hit, &served_hit)] {
                assert_eq!(t.cache_hit, u.cache_hit, "{}", c.name);
                assert_eq!(t.plans.len(), u.plans.len(), "{}", c.name);
                for (a, b) in t.plans.iter().zip(&u.plans) {
                    assert_plans_identical(a, b, &format!("{} traced serve", c.name));
                }
            }

            // Netsim: the full report at bit precision.
            rep2.assert_bits_eq(&rep, "traced vs untraced fairshare");
        }
    });
}
