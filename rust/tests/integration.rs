//! Cross-module integration tests: solver ⇄ simulator ⇄ baselines ⇄
//! memory model over real model/cluster combinations, plus end-to-end
//! properties the paper's evaluation depends on.

mod common;

use common::{assert_plans_identical, load_cluster, load_edgelist, threaded};
use nest::baselines::{self, build_plan, even_cuts};
use nest::graph::models;
use nest::graph::subgraph::SgConfig;
use nest::harness::{run_method, HarnessOpts, Method};
use nest::memory::ZeroStage;
use nest::netsim::{LinkGraph, SimMode, Simulation};
use nest::network::Cluster;
use nest::sim::{simulate, Schedule};
use nest::solver::refine::{refine, refine_under_load, RefineOpts};
use nest::solver::{exact, solve, solve_topk, SolverOpts};
use nest::util::prop;

/// Every (Table-2 model × paper cluster) cell yields a valid NEST plan.
#[test]
fn nest_solves_every_paper_cell() {
    for model in [
        "bertlarge",
        "llama2-7b",
        "llama3-70b",
        "gpt3-175b",
        "gpt3-35b",
        "mixtral-8x7b",
    ] {
        for cluster in [
            Cluster::fat_tree_tpuv4(64),
            Cluster::fat_tree_tpuv4(512),
            Cluster::spine_leaf_h100(64, 2.0),
            Cluster::spine_leaf_h100(256, 2.0),
        ] {
            let graph = models::by_name(model, 1).unwrap();
            let sol = solve(&graph, &cluster, &SolverOpts::default())
                .unwrap_or_else(|| panic!("{model} on {} infeasible", cluster.name));
            sol.plan
                .validate(&graph, &cluster)
                .unwrap_or_else(|e| panic!("{model} on {}: {e}", cluster.name));
            let rep = simulate(&graph, &cluster, &sol.plan, Schedule::OneFOneB);
            assert!(rep.batch_time.is_finite() && rep.batch_time > 0.0);
        }
    }
}

/// Throughput is monotone in cluster size for NEST (near-linear scaling
/// is the paper's headline; monotonicity is the hard floor).
#[test]
fn nest_scales_monotonically() {
    for model in ["llama2-7b", "gpt3-175b", "mixtral-8x7b"] {
        let graph = models::by_name(model, 1).unwrap();
        let mut last = 0.0;
        for n in [64usize, 128, 256, 512] {
            let cluster = Cluster::fat_tree_tpuv4(n);
            let sol = solve(&graph, &cluster, &SolverOpts::default()).unwrap();
            let t = simulate(&graph, &cluster, &sol.plan, Schedule::OneFOneB).throughput;
            assert!(
                t >= last * 0.98,
                "{model}@{n}: {t} < previous {last}"
            );
            last = t;
        }
    }
}

/// The DP's closed-form batch time tracks the DES within a bounded
/// factor across models and scales (the paper's cost model is trusted
/// for search, the testbed for evaluation — ours must agree).
#[test]
fn dp_estimate_tracks_des() {
    for model in ["bertlarge", "llama2-7b", "gpt3-175b"] {
        let graph = models::by_name(model, 1).unwrap();
        for n in [64usize, 256] {
            let cluster = Cluster::fat_tree_tpuv4(n);
            let sol = solve(&graph, &cluster, &SolverOpts::default()).unwrap();
            let des = simulate(&graph, &cluster, &sol.plan, Schedule::OneFOneB).batch_time;
            let ratio = des / sol.plan.batch_time;
            assert!(
                (0.4..1.3).contains(&ratio),
                "{model}@{n}: DES {des} vs DP {} (ratio {ratio})",
                sol.plan.batch_time
            );
        }
    }
}

/// NEST dominates every baseline under the shared evaluator (modulo the
/// DES-vs-DP selection gap), across a grid of cells.
#[test]
fn nest_dominates_baselines_grid() {
    let opts = HarnessOpts::quick();
    for (model, cluster) in [
        ("llama2-7b", Cluster::fat_tree_tpuv4(128)),
        ("gpt3-175b", Cluster::spine_leaf_h100(128, 2.0)),
        ("mixtral-8x7b", Cluster::fat_tree_tpuv4(128)),
    ] {
        let graph = models::by_name(model, 1).unwrap();
        let nest = run_method(&graph, &cluster, Method::Nest, &opts);
        assert!(nest.throughput() > 0.0, "{model}: nest failed");
        for m in [Method::Manual, Method::Mcmc, Method::Phaze, Method::AlpaE] {
            let r = run_method(&graph, &cluster, m, &opts);
            if r.throughput() > 0.0 {
                assert!(
                    nest.throughput() >= r.throughput() * 0.88,
                    "{model}: nest {} < {} {}",
                    nest.throughput(),
                    m.name(),
                    r.throughput()
                );
            }
        }
    }
}

/// Memory-constrained feasibility (Table 7): ZeRO unlocks placements
/// that are infeasible without it, and the produced plans respect the
/// reduced capacity.
#[test]
fn zero_unlocks_constrained_placements() {
    let graph = models::llama3_70b(1);
    let mut cluster = Cluster::fat_tree_tpuv4(512);
    cluster.shrink_capacity(16.0 * nest::hw::GIB);
    let without = solve(
        &graph,
        &cluster,
        &SolverOpts {
            zero_max_degree: 1,
            try_recompute: false,
            ..Default::default()
        },
    );
    let with = solve(&graph, &cluster, &SolverOpts::default());
    assert!(with.is_some(), "ZeRO+AR should fit 16GB");
    with.as_ref()
        .unwrap()
        .plan
        .validate(&graph, &cluster)
        .unwrap();
    if let Some(w) = &without {
        // If plain fits at all it must not beat the adaptive plan.
        assert!(w.plan.batch_time >= with.unwrap().plan.batch_time * 0.999);
    }
}

/// Property: random valid build_plan inputs always produce plans that
/// validate, and simulating them never panics.
#[test]
fn prop_random_plans_validate_and_simulate() {
    let graph = models::gpt3_35b(1);
    let cluster = Cluster::spine_leaf_h100(128, 2.0);
    prop::forall(60, 0xA11CE, |rng| {
        let n = graph.n_layers();
        let tp = [1usize, 2, 4, 8][rng.gen_range(4)];
        let sg = SgConfig {
            tp,
            sp: tp > 1 && rng.gen_bool(0.5),
            ep: 1,
            cp: 1,
        };
        let g = sg.group_size();
        let p_max = (128 / g).min(n);
        let p = 1 + rng.gen_range(p_max.min(16));
        let d_max = 128 / (p * g);
        if d_max == 0 {
            return;
        }
        let d = 1 + rng.gen_range(d_max);
        let cuts = even_cuts(n, p);
        if let Some(plan) = build_plan(
            &graph,
            &cluster,
            "prop",
            sg,
            &cuts,
            d,
            rng.gen_bool(0.5),
            8,
        ) {
            plan.validate(&graph, &cluster).expect("invalid plan");
            let rep = simulate(&graph, &cluster, &plan, Schedule::OneFOneB);
            assert!(rep.batch_time.is_finite());
            // DES never beats the impossible bound: bottleneck stage's
            // compute work alone.
            let floor = plan
                .stages
                .iter()
                .map(|s| s.load)
                .fold(0.0, f64::max);
            assert!(rep.batch_time >= floor * 0.5);
        }
    });
}

/// Exact solver (small clusters) agrees with the uniform solver when
/// restricted to the uniform space, and both validate.
#[test]
fn exact_and_uniform_agree_on_v100() {
    let graph = models::mixtral_scaled(1);
    for n in [8usize, 16] {
        let cluster = Cluster::v100_cluster(n);
        let uni = solve(&graph, &cluster, &SolverOpts::default()).unwrap();
        uni.plan.validate(&graph, &cluster).unwrap();
        let ex = exact::solve_exact(
            &graph,
            &cluster,
            &exact::ExactOpts {
                max_stages: 8,
                dp_width: uni.plan.dp_width,
                recompute: uni.plan.stages[0].mem.recompute,
                ..Default::default()
            },
        )
        .unwrap();
        ex.plan.validate(&graph, &cluster).unwrap();
        assert!(ex.plan.batch_time <= uni.plan.batch_time * 1.0001);
    }
}

/// Baseline failure modes the paper reports must reproduce:
/// Mist rejects MoE + hidden>8192; Alpa never replicates pipelines.
#[test]
fn baseline_failure_modes() {
    let c = Cluster::spine_leaf_h100(64, 2.0);
    assert!(baselines::mist::solve(&models::mixtral_8x7b(1), &c).is_none());
    assert!(baselines::mist::solve(&models::gpt3_175b(1), &c).is_none());
    let alpa = baselines::alpa::solve(&models::bert_large(1), &c).unwrap();
    assert_eq!(alpa.dp_width, 1);
}

/// Microbatch-size coupling (Figure 6): for Llama2 larger microbatches
/// change the chosen strategy or improve throughput; for all models the
/// solver still validates at every mbs.
#[test]
fn microbatch_sweep_validates() {
    let cluster = Cluster::fat_tree_tpuv4(256);
    for model in ["bertlarge", "llama2-7b"] {
        let mut tputs = Vec::new();
        for mbs in [1usize, 2, 4] {
            let graph = models::by_name(model, mbs).unwrap();
            let sol = solve(&graph, &cluster, &SolverOpts::default()).unwrap();
            sol.plan.validate(&graph, &cluster).unwrap();
            tputs.push(simulate(&graph, &cluster, &sol.plan, Schedule::OneFOneB).throughput);
        }
        // Throughput shouldn't collapse with microbatch growth.
        assert!(tputs.iter().all(|t| *t > 0.0), "{model}: {tputs:?}");
    }
}

/// ZeRO stages in produced plans never exceed the data-parallel width
/// (they shard across replicas), across a random sample of solves.
#[test]
fn prop_zero_degree_bounded_by_dp() {
    prop::forall(10, 0x5A5A_F00Du64, |rng| {
        let model = ["llama3-70b", "gpt3-175b"][rng.gen_range(2)];
        let n = [64usize, 128, 256][rng.gen_range(3)];
        let graph = models::by_name(model, 1).unwrap();
        let mut cluster = Cluster::fat_tree_tpuv4(n);
        if rng.gen_bool(0.5) {
            cluster.shrink_capacity(24.0 * nest::hw::GIB);
        }
        if let Some(sol) = solve(&graph, &cluster, &SolverOpts::default()) {
            for st in &sol.plan.stages {
                assert!(st.mem.zero.degree() <= sol.plan.dp_width.max(1));
                assert!(st.mem.zero == ZeroStage::None || st.mem.zero.degree() >= 2);
            }
        }
    });
}

/// Shipped topology configs load and solve (the App. B.1 network
/// interface; configs/ directory).
#[test]
fn shipped_configs_solve() {
    for (file, expect_devices) in [
        ("configs/dgx_superpod.json", 256usize),
        ("configs/oversubscribed_4to1.json", 128),
        ("configs/hetero_v100_h100.json", 64),
    ] {
        let cluster = load_cluster(file);
        assert_eq!(cluster.n_devices(), expect_devices, "{file}");
        let graph = models::llama2_7b(1);
        let sol = solve(&graph, &cluster, &SolverOpts::default()).unwrap();
        sol.plan.validate(&graph, &cluster).unwrap();
    }
}

/// Satellite invariant for the flow-level simulator: the shipped
/// oversubscribed spine (4:1 agg tier) yields strictly higher flow-sim
/// batch time than its 1:1 twin for the *same* placement plan — the
/// contention netsim exists to expose.
#[test]
fn netsim_oversubscribed_spine_strictly_slower_than_twin() {
    let c_1to1 = load_cluster("configs/oversubscribed_1to1.json");
    let c_4to1 = load_cluster("configs/oversubscribed_4to1.json");
    assert_eq!(c_1to1.n_devices(), c_4to1.n_devices());
    let graph = models::llama2_7b(1);
    // One plan, solved against the clean twin, replayed on both fabrics.
    let plan = solve(&graph, &c_1to1, &SolverOpts::default()).unwrap().plan;
    plan.validate(&graph, &c_1to1).unwrap();
    let clean = Simulation::new().run(
        &graph,
        &c_1to1,
        &LinkGraph::from_cluster(&c_1to1),
        &plan,
        Schedule::OneFOneB,
    );
    let congested = Simulation::new().run(
        &graph,
        &c_1to1, // same analytic cost view: only the fabric differs
        &LinkGraph::from_cluster(&c_4to1),
        &plan,
        Schedule::OneFOneB,
    );
    assert!(
        congested.batch_time > clean.batch_time,
        "4:1 {} must be strictly slower than 1:1 {}",
        congested.batch_time,
        clean.batch_time
    );
    // And the congested run must also never beat the analytic DES.
    let ana = simulate(&graph, &c_1to1, &plan, Schedule::OneFOneB);
    assert!(congested.batch_time >= ana.batch_time * (1.0 - 1e-9));
}

/// Flow-sim determinism across solver thread counts: plans are
/// thread-invariant (PR 1) and the engine is single-threaded, so the
/// reports must be bit-identical.
#[test]
fn netsim_reports_bit_identical_across_threads() {
    let graph = models::bert_large(1);
    let cluster = Cluster::spine_leaf_h100(64, 2.0);
    let topo = LinkGraph::from_cluster(&cluster);
    let mut reports = Vec::new();
    for threads in [1usize, 4] {
        let sol = solve(
            &graph,
            &cluster,
            &SolverOpts {
                threads,
                ..Default::default()
            },
        )
        .unwrap();
        reports.push(Simulation::new().run(
            &graph,
            &cluster,
            &topo,
            &sol.plan,
            Schedule::OneFOneB,
        ));
    }
    assert_eq!(
        reports[0].batch_time.to_bits(),
        reports[1].batch_time.to_bits(),
        "flow-sim result depends on --threads"
    );
    assert_eq!(reports[0].n_flows, reports[1].n_flows);
    assert_eq!(reports[0].events, reports[1].events);
    assert_eq!(
        reports[0].total_bytes.to_bits(),
        reports[1].total_bytes.to_bits()
    );
}

/// The shipped edge-list topologies parse, route, and carry a full
/// netsim run end to end (the `nest netsim --config` path).
#[test]
fn shipped_edge_lists_run_netsim() {
    for (file, expect_devices) in [
        ("configs/edgelist_dumbbell.json", 8usize),
        ("configs/edgelist_spineleaf_4to1.json", 16),
    ] {
        let (cluster, topo) = load_edgelist(file);
        assert_eq!(topo.n_devices(), expect_devices, "{file}");
        let graph = models::bert_large(1);
        let sol = solve(&graph, &cluster, &SolverOpts::default())
            .unwrap_or_else(|| panic!("{file}: infeasible"));
        let rep = Simulation::new().run(&graph, &cluster, &topo, &sol.plan, Schedule::OneFOneB);
        assert!(rep.batch_time.is_finite() && rep.batch_time > 0.0, "{file}");
        assert!(rep.n_flows > 0, "{file}");
        // The flat abstraction is optimistic by construction: the real
        // fabric can only be slower.
        let ana = simulate(&graph, &cluster, &sol.plan, Schedule::OneFOneB);
        assert!(
            rep.batch_time >= ana.batch_time * (1.0 - 1e-9),
            "{file}: flow {} < analytic {}",
            rep.batch_time,
            ana.batch_time
        );
    }
}

/// Decomposed execution is bit-identical to monolithic on every shipped
/// configuration the simulator touches — the edge-list files plus the
/// generated preset fabrics — at 1 and 4 worker threads. This is the
/// in-tree counterpart of the fuzzed decomposition property: real
/// plan-lowered workloads, not synthetic flow chains.
#[test]
fn decomposed_matches_monolithic_on_shipped_configs() {
    let graph = models::bert_large(1);
    let mut scenarios: Vec<(String, Cluster, LinkGraph)> = Vec::new();
    for file in [
        "configs/edgelist_dumbbell.json",
        "configs/edgelist_spineleaf_4to1.json",
    ] {
        let (cluster, topo) = load_edgelist(file);
        scenarios.push((file.to_string(), cluster, topo));
    }
    for (name, cluster) in [
        ("fat-tree-64", Cluster::fat_tree_tpuv4(64)),
        ("spine-leaf-64-4:1", Cluster::spine_leaf_h100(64, 4.0)),
    ] {
        let topo = LinkGraph::from_cluster(&cluster);
        scenarios.push((name.to_string(), cluster, topo));
    }
    for (name, cluster, topo) in &scenarios {
        let sol = solve(&graph, cluster, &SolverOpts::default())
            .unwrap_or_else(|| panic!("{name}: infeasible"));
        let mono = Simulation::new().mode(SimMode::Monolithic).run(
            &graph,
            cluster,
            topo,
            &sol.plan,
            Schedule::OneFOneB,
        );
        for threads in [1usize, 4] {
            let dec = Simulation::new()
                .mode(SimMode::Decomposed)
                .threads(threads)
                .run(&graph, cluster, topo, &sol.plan, Schedule::OneFOneB);
            dec.assert_bits_eq(&mono, &format!("{name}: decomposed {threads}t vs monolithic"));
        }
    }
}

/// The solver is deterministic: identical inputs give identical plans.
#[test]
fn solver_deterministic() {
    let graph = models::gpt3_35b(1);
    let cluster = Cluster::spine_leaf_h100(128, 2.0);
    let a = solve(&graph, &cluster, &SolverOpts::default()).unwrap();
    let b = solve(&graph, &cluster, &SolverOpts::default()).unwrap();
    assert_eq!(a.plan.strategy_string(), b.plan.strategy_string());
    assert_eq!(a.plan.batch_time, b.plan.batch_time);
    let cuts_a: Vec<_> = a.plan.stages.iter().map(|s| s.layers).collect();
    let cuts_b: Vec<_> = b.plan.stages.iter().map(|s| s.layers).collect();
    assert_eq!(cuts_a, cuts_b);
}

/// The parallel outer enumeration is thread-count-invariant: 1-thread
/// and N-thread solves return field-for-field identical plans (the
/// shared incumbent only prunes candidates strictly worse than the
/// optimum, and ties break on a total order — see nest::solver docs).
#[test]
fn solver_thread_count_invariant() {
    for (graph, cluster) in [
        (models::bert_large(1), Cluster::fat_tree_tpuv4(64)),
        (models::gpt3_35b(1), Cluster::spine_leaf_h100(64, 2.0)),
        (models::mixtral_scaled(1), Cluster::v100_cluster(8)),
    ] {
        let serial = solve(
            &graph,
            &cluster,
            &SolverOpts {
                threads: 1,
                ..Default::default()
            },
        );
        let threaded = solve(
            &graph,
            &cluster,
            &SolverOpts {
                threads: 4,
                ..Default::default()
            },
        );
        match (serial, threaded) {
            (Some(a), Some(b)) => assert_plans_identical(
                &a.plan,
                &b.plan,
                &format!("{} on {}", graph.model_name, cluster.name),
            ),
            (None, None) => {}
            (a, b) => panic!(
                "{} on {}: feasibility depends on thread count (serial={}, threaded={})",
                graph.model_name,
                cluster.name,
                a.is_some(),
                b.is_some()
            ),
        }
    }
}

// `threaded` / `load_edgelist` / `load_cluster` live in `common` — they
// load the shipped `configs/` artifacts themselves (not the embedded
// copy `harness::netsim::dumbbell_topology` uses), so the shipped files
// are what these tests pin.

/// The CI smoke's invariant as a test: `refine` with `topk = 1` on the
/// shipped dumbbell edge-list reproduces plain `solve` field-for-field
/// at every thread count.
#[test]
fn refine_topk1_identical_to_solve_on_shipped_edgelist() {
    let (cluster, topo) = load_edgelist("configs/edgelist_dumbbell.json");
    let graph = models::by_name("llama2-7b", 1).unwrap();
    let direct = solve(&graph, &cluster, &threaded(1)).expect("feasible");
    for threads in [1usize, 4] {
        let rep = refine(&graph, &cluster, &topo, &threaded(threads), 1).expect("feasible");
        assert_eq!(rep.ranked.len(), 1, "threads={threads}");
        assert_eq!(
            rep.winner().plan,
            direct.plan,
            "threads={threads}: K=1 shortlist disagrees with solve()"
        );
        assert_eq!(
            rep.winner().analytic_batch.to_bits(),
            direct.plan.batch_time.to_bits(),
            "threads={threads}"
        );
    }
}

/// The K-best shortlist is bit-identical across thread counts on a
/// contended paper topology, every entry is a valid plan, and rank 1 is
/// exactly the single-winner solve.
#[test]
fn topk_shortlist_thread_invariant_on_spine_leaf() {
    let graph = models::gpt3_35b(1);
    let cluster = Cluster::spine_leaf_h100(64, 4.0);
    let a = solve_topk(&graph, &cluster, &threaded(1), 6);
    let b = solve_topk(&graph, &cluster, &threaded(4), 6);
    assert_eq!(a.plans, b.plans, "1-thread vs 4-thread shortlists diverge");
    for (x, y) in a.plans.iter().zip(&b.plans) {
        assert_eq!(x.batch_time.to_bits(), y.batch_time.to_bits());
    }
    assert!(!a.plans.is_empty());
    let direct = solve(&graph, &cluster, &SolverOpts::default()).unwrap();
    assert_eq!(a.plans[0], direct.plan);
    for p in &a.plans {
        p.validate(&graph, &cluster).unwrap();
    }
}

/// End-to-end refinement on the shipped dumbbell: deterministic across
/// runs/threads, ranked by simulated batch time, and the re-ranked
/// winner is never slower than the analytic winner under the flow sim
/// (strictly faster whenever the ranking flips).
#[test]
fn refine_rerank_consistent_on_shipped_dumbbell() {
    let (cluster, topo) = load_edgelist("configs/edgelist_dumbbell.json");
    let graph = models::by_name("llama2-7b", 1).unwrap();
    let a = refine(&graph, &cluster, &topo, &threaded(1), 4).expect("feasible");
    let b = refine(&graph, &cluster, &topo, &threaded(4), 4).expect("feasible");
    assert_eq!(a.ranked.len(), b.ranked.len());
    for (x, y) in a.ranked.iter().zip(&b.ranked) {
        assert_eq!(x.plan, y.plan, "re-rank depends on thread count");
        assert_eq!(x.sim_batch.to_bits(), y.sim_batch.to_bits());
    }
    for w in a.ranked.windows(2) {
        assert!(w[0].sim_batch <= w[1].sim_batch, "not sorted by sim time");
    }
    assert!(a.winner().sim_batch <= a.analytic_winner().sim_batch);
    if a.winner_changed() {
        assert!(a.winner().sim_batch < a.analytic_winner().sim_batch);
    }
    // Every shortlisted plan is valid and the flow sim never undercuts
    // the analytic DES on this contended fabric.
    for r in &a.ranked {
        r.plan.validate(&graph, &cluster).unwrap();
        let ana = simulate(&graph, &cluster, &r.plan, Schedule::OneFOneB);
        assert!(
            r.sim_batch >= ana.batch_time * (1.0 - 1e-9),
            "flow {} < analytic DES {} for dp-rank {}",
            r.sim_batch,
            ana.batch_time,
            r.analytic_rank
        );
    }
}

/// The multi-tenant acceptance gate on the *shipped* 4:1 spine-leaf
/// edge-list: `refine --bg-load` at a high background load keeps (or
/// flips to) a plan whose degradation is no worse than the analytic
/// rank-1 plan's, the ranking is sorted by degradation, and the whole
/// report is bit-identical across thread counts. (The CLI turns the
/// degradation invariant into a nonzero exit — see the `refine` arm.)
#[test]
fn refine_under_load_prefers_robust_plan() {
    let (cluster, topo) = load_edgelist("configs/edgelist_spineleaf_4to1.json");
    let graph = models::by_name("llama2-7b", 1).unwrap();
    let ropts = RefineOpts {
        topk: 4,
        bg_loads: vec![0.3, 0.9],
        ..Default::default()
    };
    let a = refine_under_load(&graph, &cluster, &topo, &threaded(1), &ropts)
        .expect("feasible");
    assert_eq!(a.bg_loads, ropts.bg_loads);
    for r in &a.ranked {
        assert_eq!(r.bg_sim.len(), ropts.bg_loads.len(), "one replay per level");
        for &t in &r.bg_sim {
            assert!(t.is_finite() && t > 0.0, "degenerate replay time {t}");
        }
        assert!(r.degradation.is_finite());
        r.plan.validate(&graph, &cluster).unwrap();
    }
    for w in a.ranked.windows(2) {
        assert!(
            w[0].degradation <= w[1].degradation,
            "shortlist not ranked by degradation"
        );
    }
    // The gate: re-ranking under load never ships a plan that degrades
    // more than the zero-load analytic winner would have.
    assert!(
        a.winner().degradation <= a.analytic_winner().degradation,
        "robust winner degrades {:+.3}% vs analytic rank-1 {:+.3}%",
        a.winner().degradation * 100.0,
        a.analytic_winner().degradation * 100.0
    );
    // Bit-identical across thread counts, replay times included.
    let b = refine_under_load(&graph, &cluster, &topo, &threaded(4), &ropts)
        .expect("feasible");
    assert_eq!(a.ranked.len(), b.ranked.len());
    for (x, y) in a.ranked.iter().zip(&b.ranked) {
        assert_eq!(x.plan, y.plan, "ranking depends on thread count");
        assert_eq!(x.sim_batch.to_bits(), y.sim_batch.to_bits());
        assert_eq!(x.degradation.to_bits(), y.degradation.to_bits());
        assert_eq!(x.bg_sim.len(), y.bg_sim.len());
        for (s, t) in x.bg_sim.iter().zip(&y.bg_sim) {
            assert_eq!(s.to_bits(), t.to_bits(), "replay depends on thread count");
        }
    }
    // The rendered table surfaces the per-level replays.
    let table = a.render_table();
    assert!(table.contains("bg 30%"), "missing level column:\n{table}");
    assert!(table.contains("bg 90%"), "missing level column:\n{table}");
    assert!(table.contains("degradation"), "missing ranking column:\n{table}");
}

/// The heterogeneous-pool acceptance invariant on the *shipped* config:
/// the solver's plan on `configs/hetero_v100_h100.json` is strictly
/// faster (analytic batch time) than the best plan constrained to treat
/// every device as a V100, compute-heavy stages land on the H100 range
/// (low device ids), and the plan is thread-count-invariant.
#[test]
fn hetero_config_strictly_faster_and_migrates_to_h100() {
    let mixed = load_cluster("configs/hetero_v100_h100.json");
    assert_eq!(mixed.pool.n_classes(), 2);
    assert_eq!(mixed.pool.accel_of(0).name, "h100");
    assert_eq!(mixed.pool.accel_of(63).name, "v100");
    let v100 = mixed.with_uniform_accel(nest::hw::Accelerator::v100());
    let graph = models::llama2_7b(1);

    let sol = solve(&graph, &mixed, &threaded(0)).expect("mixed pool feasible");
    sol.plan.validate(&graph, &mixed).unwrap();
    let constrained = solve(&graph, &v100, &threaded(0)).expect("v100 twin feasible");
    constrained.plan.validate(&graph, &v100).unwrap();
    assert!(
        sol.plan.batch_time < constrained.plan.batch_time,
        "mixed pool {} not strictly faster than all-V100 {}",
        sol.plan.batch_time,
        constrained.plan.batch_time
    );

    // Compute-heavy stages migrate to the H100 island: layers hosted on
    // pure-H100 stages must at least match the layers on any stage that
    // touches a V100 (lockstep drags those to V100 speed, so the DP
    // gives them less work — or avoids the slow island entirely).
    let mut layers_h100_only = 0usize;
    let mut layers_touching_v100 = 0usize;
    let mut h100_stage_max = 0usize;
    let mut v100_stage_max = 0usize;
    for st in &sol.plan.stages {
        let layers = st.layers.1 - st.layers.0;
        if st.accel_class == "h100" {
            layers_h100_only += layers;
            h100_stage_max = h100_stage_max.max(layers);
        } else {
            layers_touching_v100 += layers;
            v100_stage_max = v100_stage_max.max(layers);
        }
    }
    assert!(
        layers_h100_only >= layers_touching_v100,
        "H100 range hosts {layers_h100_only} layers < V100-touching {layers_touching_v100}: {}",
        sol.plan.describe()
    );
    if layers_touching_v100 > 0 {
        assert!(
            h100_stage_max >= v100_stage_max,
            "heaviest stage sits on the slow island: {}",
            sol.plan.describe()
        );
    }

    // Determinism holds on the mixed pool too.
    let again = solve(&graph, &mixed, &threaded(1)).expect("serial solve");
    assert_plans_identical(&sol.plan, &again.plan, "hetero config across threads");
}

/// Plan JSON export round-trips through our own parser and carries the
/// full stage structure.
#[test]
fn plan_json_export_complete() {
    let graph = models::mixtral_8x7b(1);
    let cluster = Cluster::fat_tree_tpuv4(128);
    let plan = solve(&graph, &cluster, &SolverOpts::default()).unwrap().plan;
    let j = nest::util::json::parse(&nest::util::json::to_pretty(&plan.to_json())).unwrap();
    assert_eq!(
        j.get("stages").as_arr().unwrap().len(),
        plan.n_stages()
    );
    assert_eq!(
        j.get("data_parallel").as_usize(),
        Some(plan.dp_width)
    );
    // Stage layer ranges tile the model.
    let stages = j.get("stages").as_arr().unwrap();
    let mut expect = 0;
    for st in stages {
        assert_eq!(st.get("layers").idx(0).as_usize(), Some(expect));
        expect = st.get("layers").idx(1).as_usize().unwrap();
    }
    assert_eq!(expect, graph.n_layers());
}

/// Placement-as-a-service elasticity: `reconcile` after a device
/// failure returns a valid plan on the shrunk cluster plus a nonzero
/// priced migration — the ISSUE-6 acceptance gate.
#[test]
fn service_reconcile_prices_device_failure() {
    use nest::service::{ClusterDelta, PlacementService, Query};

    let graph = models::bert_large(1);
    let cluster = Cluster::v100_cluster(16);
    let mut svc = PlacementService::new(8);
    let q = Query::new(graph.clone(), cluster.clone(), threaded(1));

    let outcome = svc
        .reconcile(&q, &ClusterDelta::FailOuterGroups { groups: 1 })
        .expect("bert-large feasible on 14 V100s");
    assert!(!outcome.degraded(), "a clean fit concedes nothing");
    let report = outcome.into_report();
    assert_eq!(report.cluster.n_devices(), 14);
    report
        .plan
        .validate(&graph, &report.cluster)
        .expect("reconciled plan valid on the shrunk cluster");
    assert!(
        report.warm_started,
        "the re-solve should warm-start from the just-cached original"
    );
    assert!(
        report.delta.param_bytes > 0.0,
        "shrinking 16 -> 14 devices must move weights"
    );
    assert!(
        report.delta.migration_seconds > 0.0,
        "a nonzero migration must take nonzero modeled time"
    );
    assert!(!report.delta.is_noop());
    assert!(report.delta.moved.len() + report.delta.unchanged == report.plan.n_stages());

    // The reconciled plan is exactly the cold solve on the shrunk
    // cluster — reconcile is a pure cache/warm-start fast path.
    let shrunk = ClusterDelta::FailOuterGroups { groups: 1 }
        .apply(&cluster)
        .unwrap();
    let cold = solve(&graph, &shrunk, &threaded(1)).expect("feasible");
    assert_plans_identical(&report.plan, &cold.plan, "reconcile vs cold");
}

/// On an oversubscribed 4:1 fabric, expert parallelism must *win*: the
/// best Mixtral plan with EP enabled beats the best `ep_degrees=[1]`
/// twin, and the winner actually uses EP. The scaled Mixtral pins
/// `cp_degrees=[1]`, so EP is the only dimension that shards the
/// dominant expert compute — the twin has no escape hatch.
#[test]
fn expert_parallelism_wins_on_oversubscribed_fabric() {
    let graph = models::mixtral_scaled(1);
    let cluster = Cluster::spine_leaf_h100(64, 4.0);
    let with_ep = solve(&graph, &cluster, &SolverOpts::default())
        .expect("mixtral-790m feasible with EP");

    let mut no_ep_graph = graph.clone();
    no_ep_graph.ep_degrees = vec![1];
    let without_ep = solve(&no_ep_graph, &cluster, &SolverOpts::default())
        .expect("mixtral-790m feasible without EP");

    // The EP search space is a superset, so ≤ holds unconditionally…
    assert!(
        with_ep.plan.batch_time <= without_ep.plan.batch_time,
        "EP superset search lost to its own subset"
    );
    // …and on a 4:1 fabric the win must be strict, through EP.
    assert!(
        with_ep.plan.batch_time < without_ep.plan.batch_time,
        "EP-enabled best ({}) must strictly beat the ep=1 twin ({})",
        with_ep.plan.batch_time,
        without_ep.plan.batch_time
    );
    assert!(
        with_ep.plan.sg.ep > 1,
        "strict win must come from an EP plan, got {:?}",
        with_ep.plan.sg
    );
}
