//! Shared helpers for the integration-level suites (`integration.rs`,
//! `property.rs`, `golden.rs`): config loading, default solver options,
//! and plan-equality assertions — deduplicated so every suite pins the
//! *shipped* artifacts the same way.
//!
//! Compiled once per test target via `mod common;`; not every target
//! uses every helper.
#![allow(dead_code)]

use nest::netsim::LinkGraph;
use nest::network::Cluster;
use nest::solver::plan::PlacementPlan;
use nest::solver::SolverOpts;

/// Absolute path of a repo-relative file (configs live at the root).
pub fn repo_path(file: &str) -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(file)
}

/// Load a shipped tier-stack topology config (`configs/*.json`).
pub fn load_cluster(file: &str) -> Cluster {
    let text = std::fs::read_to_string(repo_path(file))
        .unwrap_or_else(|e| panic!("{file}: {e}"));
    Cluster::from_json(&nest::util::json::parse(&text).unwrap())
        .unwrap_or_else(|e| panic!("{file}: {e}"))
}

/// Load a shipped edge-list topology (`configs/edgelist_*.json`) as the
/// explicit link graph plus the optimistic flat analytic cluster the
/// solver searches on — the `nest netsim --config` construction.
pub fn load_edgelist(file: &str) -> (Cluster, LinkGraph) {
    let text = std::fs::read_to_string(repo_path(file))
        .unwrap_or_else(|e| panic!("{file}: {e}"));
    let topo = LinkGraph::from_json(&nest::util::json::parse(&text).unwrap())
        .unwrap_or_else(|e| panic!("{file}: {e}"));
    let cluster = topo.approx_cluster(nest::hw::Accelerator::h100());
    (cluster, topo)
}

/// Default solver options at an explicit worker-thread count.
pub fn threaded(threads: usize) -> SolverOpts {
    SolverOpts {
        threads,
        ..Default::default()
    }
}

/// Assert two plans are field-for-field identical, with modeled times
/// compared bit-for-bit — the determinism contract (`PartialEq` alone
/// would accept `-0.0 == 0.0`).
pub fn assert_plans_identical(a: &PlacementPlan, b: &PlacementPlan, what: &str) {
    assert_eq!(a, b, "{what}: plans differ field-for-field");
    assert_eq!(
        a.batch_time.to_bits(),
        b.batch_time.to_bits(),
        "{what}: batch times not bit-identical"
    );
    assert_eq!(
        a.bottleneck.to_bits(),
        b.bottleneck.to_bits(),
        "{what}: bottlenecks not bit-identical"
    );
    assert_eq!(
        a.sync_time.to_bits(),
        b.sync_time.to_bits(),
        "{what}: sync times not bit-identical"
    );
}

/// Base seed for a property suite: the pinned default, unless
/// `NEST_PROP_SEED` overrides it (the nightly CI job passes a
/// date-derived value; replays pass the seed printed on failure).
pub fn prop_seed(pinned: u64) -> u64 {
    match std::env::var("NEST_PROP_SEED") {
        Ok(s) => {
            let seed: u64 = s
                .trim()
                .parse()
                .unwrap_or_else(|_| panic!("NEST_PROP_SEED must be a u64, got '{s}'"));
            eprintln!("property suite seeded from NEST_PROP_SEED={seed}");
            seed
        }
        Err(_) => pinned,
    }
}
