//! Golden-file snapshot tests: the rendered `nest refine` shortlist
//! table, the harness netsim cross-validation row, and the `nest mix`
//! and `nest chaos` shortlist tables on the shipped dumbbell edge-list,
//! pinned against checked-in expected output so
//! silent report-field drift (a renamed column, a re-scaled delta, a
//! changed plan) fails loudly.
//!
//! Refresh after an intentional change with:
//!
//! ```text
//! NEST_BLESS=1 cargo test --release --test golden && git add rust/tests/golden/
//! ```
//!
//! A missing golden file is written on first run (bootstrap bless) and
//! the test passes — commit the generated file to arm the guard.

mod common;

use common::{load_edgelist, repo_path, threaded};
use nest::graph::models;
use nest::solver::refine::refine;

/// Compare `actual` against the checked-in snapshot, or (re)write it
/// when blessing / bootstrapping.
fn golden_check(name: &str, actual: &str) {
    let path = repo_path(&format!("rust/tests/golden/{name}"));
    let bless = std::env::var("NEST_BLESS").is_ok();
    if bless || !path.exists() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, actual).unwrap();
        eprintln!(
            "{} golden file {} — commit it to arm the snapshot guard",
            if bless { "blessed" } else { "bootstrapped" },
            path.display()
        );
        return;
    }
    let expected = std::fs::read_to_string(&path).unwrap();
    assert_eq!(
        expected, actual,
        "golden snapshot '{name}' drifted — if the change is intentional, refresh \
         with: NEST_BLESS=1 cargo test --release --test golden"
    );
}

/// `nest refine --config configs/edgelist_dumbbell.json --topk 4`'s
/// rendered shortlist table (serial solver: the report is
/// thread-invariant, this just removes the variable).
#[test]
fn golden_refine_table_on_shipped_dumbbell() {
    let (cluster, topo) = load_edgelist("configs/edgelist_dumbbell.json");
    let graph = models::by_name("llama2-7b", 1).unwrap();
    let rep = refine(&graph, &cluster, &topo, &threaded(1), 4).expect("feasible");
    golden_check("refine_dumbbell.txt", &rep.render_table());
}

/// The harness netsim cross-validation row for the dumbbell family.
#[test]
fn golden_netsim_xval_dumbbell_row() {
    golden_check(
        "netsim_xval_dumbbell.txt",
        &nest::harness::netsim::dumbbell_xval_snapshot(),
    );
}

/// The `nest mix` shortlist-under-load snapshot on the dumbbell
/// (serial solver, fixed seed and load levels): pins the flowgen draw,
/// the injection path, and the degradation ranking in one artifact.
#[test]
fn golden_mix_snapshot_on_dumbbell() {
    golden_check("mix_dumbbell.txt", &nest::harness::mix::mix_snapshot());
}

/// The `nest chaos` shortlist-under-faults snapshot on the dumbbell
/// (serial solver, fixed severities, scenario count, and fault seed):
/// pins the fault draw, the capacity-event injection, the straggler
/// lowering, and the retention ranking in one artifact.
#[test]
fn golden_chaos_snapshot_on_dumbbell() {
    golden_check("chaos_dumbbell.txt", &nest::harness::chaos::chaos_snapshot());
}
