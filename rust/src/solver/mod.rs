//! NEST's network-, compute-, and memory-aware dynamic program (§4,
//! Algorithm 1).
//!
//! Search structure (DESIGN.md §4):
//!
//! * **Outer enumeration** — SUB-GRAPH configuration `sg` (tensor /
//!   sequence / expert / context degrees, Table 2 columns), activation
//!   recomputation on/off, and the ZeRO degree cap. Uniform `sg` across
//!   stages matches the paper's evaluated plans and Megatron practice and
//!   is what keeps the search scalable past 1,000 devices ("template-based
//!   parallelism", §5.2.2).
//! * **DP core** — `dp[i][s]` = minimum bottleneck latency of executing
//!   the layer suffix `[i, L)` as `s` pipeline stages of `g = |sg|`
//!   devices each, *including* the forward edge from the yet-unplaced
//!   producer stage. Because stages are packed compactly tail-first, the
//!   producer boundary of a suffix with `s` stages sits at device offset
//!   `s·g`, so its communication level — the paper's deferred-forward-cost
//!   level `l` — is known exactly (`assign::boundary_level`). Memory
//!   feasibility (Eq. 1) is evaluated *inside* the transition; infeasible
//!   stages escalate ZeRO 1→2→3 (adding the collective overhead to the
//!   load) and are pruned only if nothing fits — never post hoc.
//! * **Final pass** — Algorithm 1 lines 19–31: the first stage (no
//!   forward edge) is evaluated separately per total stage count `p`;
//!   data parallelism replicates the pipeline `d = ⌊K / (p·g)⌋` times
//!   (partial utilization allowed, §5.2.1) and the batch time is
//!   `bottleneck · (m + p − 1) + SyncCost`.
//!
//! # Parallel search and determinism
//!
//! The outer enumeration fans out over worker threads
//! ([`SolverOpts::threads`]; `0` = one per available core): workers pull
//! `(sg, recompute)` configurations from a shared queue, each building its
//! DP tables locally, and share a single atomic **incumbent** — the best
//! batch time found so far. The incumbent prunes in three places, always
//! *strictly* (a candidate tying the incumbent is never discarded):
//!
//! * a `(sg, recompute, p)` combination whose compute-only lower bound
//!   `max(total/p, max-layer) · (m + p − 1)` already exceeds the incumbent
//!   is skipped before its DP table is ever built;
//! * [`run_dp`] drops states whose bottleneck provably exceeds
//!   `incumbent / (m + p − 1)` for every stage count that can reach them;
//! * [`eval_final`] stops scanning first-stage cuts once the compute
//!   lower bound crosses the same threshold.
//!
//! Because the incumbent is always an *achieved* batch time, it can never
//! prune a candidate at least as good as the optimum, so every optimal
//! candidate survives in every worker. The final winner is chosen by a
//! total order on `(batch_time, sg index, recompute, stage count)` —
//! **`solve` returns a field-for-field identical [`PlacementPlan`] for
//! every thread count** (verified by the thread-invariance property
//! tests). Only the [`Solution`] search statistics (`dp_states`,
//! `configs_tried`) vary with pruning luck.
//!
//! # K-best enumeration
//!
//! [`solve_topk`] generalizes the search to the **K best distinct
//! `(sg, recompute, stage count)` solutions** under the same total
//! order, feeding the contention-aware re-ranking loop in [`refine`].
//! The shared incumbent becomes the **K-th smallest achieved batch
//! time** ([`Incumbent`]): a candidate strictly worse than the K-th
//! incumbent cannot appear in the final top-K (K achieved candidates
//! with strictly smaller batch time precede it in the total order), so
//! every prune site — the config-level compute bound, [`run_dp`]'s
//! state bound, and [`eval_final`]'s cut scan — stays exact by reading
//! the K-th value instead of the 1st. Pruning remains strict
//! (bound-tying candidates survive), the enumeration assigns each
//! `(sg, recompute, p)` triple to exactly one worker, and the final
//! merge re-sorts by the total order, so **the K-best set is
//! field-for-field identical for every thread count**. `solve` is the
//! `K = 1` special case and its behavior is unchanged.
//!
//! # Warm starting
//!
//! [`SolverOpts::warm_start`] carries the `(sg, recompute)` hint of a
//! neighboring query's winner (the [`crate::service`] cache layer). The
//! hinted work item is moved to the front of the queue so its achieved
//! batch time is offered to the incumbent first — strictly a search
//! *speed* lever: the item set, every prune bound, and the total order
//! are unchanged, so warm-started solves return bit-identical plans
//! (property-proven at 1 and 4 threads).
//!
//! # Heterogeneous device pools
//!
//! When the cluster's [`crate::hw::DevicePool`] mixes accelerator
//! classes, every DP state is scored with the profile of the devices
//! its block *actually covers* (replicas included): compute runs
//! lockstep at the slowest covered class, memory must fit the smallest
//! covered HBM ([`StageCtx`]). Because a block's replica coverage
//! depends on the stride `p·g` and width `d`, tables are rebuilt per
//! `(p, d)` instead of shared — and `d` itself is enumerated
//! (`⌊K/(p·g)⌋` plus every power of two below it) rather than forced,
//! so the solver can trade replication width for keeping stages off
//! the slow island. All pruning bounds stay exact: config-level lower
//! bounds use the *fastest* class anywhere
//! ([`CostModel::stage_load_lb_best`]), per-state bounds the block's
//! own classes. Homogeneous pools take the original shared-table,
//! forced-width fast path, bit-identically.
//!
//! The full per-stage-device-count generalization (the paper's
//! `dp[l][D][k][s]` with enumerated allocations) is in [`exact`] and is
//! used for small clusters (§5.4) and as the optimality cross-check.
//!
//! # Tuning the prune sites
//!
//! Run any solve with `--trace out.json` (or `NEST_TRACE=out.json`) and
//! the [`crate::obs`] flight recorder counts every hit at the three
//! strict prune sites — `solver.prune.config_bound` (the per-`(p, d)`
//! balanced-compute bound in `eval_config`), `solver.prune.dp_state`
//! (the per-state lower-bound skip and cut-scan break in [`run_dp`]),
//! and `solver.prune.final_cut` (the first-stage cut-scan break in
//! [`eval_final`]) — alongside `solver.dp_states`, per-configuration
//! spans, and `solver.incumbent.improved` events. `nest obs-summary
//! --trace out.json` turns that into a prune-site effectiveness table:
//! the place to look before touching any bound, and the evidence that a
//! new bound actually fires. Tracing is strictly observational — plans
//! are bit-identical with it on or off (property-proven).

pub mod assign;
pub mod exact;
pub mod plan;
pub mod refine;

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::cost::{CostModel, PricingMode};
use crate::graph::subgraph::{enumerate_sg, SgConfig};
use crate::graph::LayerGraph;
use crate::hw::ClassMask;
use crate::memory::MemSpec;
use crate::network::Cluster;
use crate::obs;
use assign::{boundary_level, stage_devices};
use plan::{PlacementPlan, StagePlan};

/// Warm-start hint for the outer enumeration: the `(sg, recompute)`
/// configuration a *neighboring* query's winner used (same graph on a
/// scaled cluster, or vice versa — see `crate::service`).
///
/// The hint seeds the shared incumbent **by evaluation order**, not by
/// value: the matching work item is moved to the front of the queue, so
/// the hinted configuration's *achieved* batch time is offered to the
/// incumbent before the bulk of the enumeration runs. A neighbor's raw
/// batch time is not achievable on this query in general, and
/// [`Incumbent`] only tightens its bound once K achieved values exist —
/// so reordering is the only seeding that is sound for every K. The
/// item set, every prune site, and the total order are untouched:
/// a warm-started solve can only prune *earlier*, never differently,
/// and returns bit-identical plans (the warm-start property tests pin
/// this at 1 and 4 threads).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WarmStart {
    pub sg: SgConfig,
    pub recompute: bool,
}

impl WarmStart {
    /// The hint a cached plan induces: its SUB-GRAPH config plus which
    /// recomputation branch it came from.
    pub fn from_plan(plan: &PlacementPlan) -> Self {
        WarmStart {
            sg: plan.sg,
            recompute: plan.stages.iter().any(|s| s.mem.recompute),
        }
    }
}

/// Solver options.
#[derive(Debug, Clone)]
pub struct SolverOpts {
    /// Cap on pipeline stages (0 = number of layers).
    pub max_stages: usize,
    /// Largest ZeRO sharding degree to consider.
    pub zero_max_degree: usize,
    /// Explore the activation-recomputation branch.
    pub try_recompute: bool,
    /// Explore the stash-everything branch.
    pub try_no_recompute: bool,
    /// Worker threads for the outer (sg, recompute) enumeration
    /// (0 = one per available core). The returned plan is identical for
    /// every thread count — see the module docs.
    pub threads: usize,
    /// Pricing implementation for the cost models the search builds
    /// (`Auto` = `NEST_REFERENCE` env). The optimized tables are
    /// bit-identical to the reference walks, so plans never depend on
    /// this — the property suite proves it.
    pub pricing: PricingMode,
    /// Evaluate this `(sg, recompute)` configuration first so its
    /// achieved batch time seeds the shared incumbent early (see
    /// [`WarmStart`]). `None` = cold start. A hint that matches no
    /// enumerated configuration is ignored. Plans are identical with
    /// and without a hint — only search statistics move.
    pub warm_start: Option<WarmStart>,
}

impl Default for SolverOpts {
    fn default() -> Self {
        SolverOpts {
            max_stages: 0,
            zero_max_degree: 8,
            try_recompute: true,
            try_no_recompute: true,
            threads: 0,
            pricing: PricingMode::Auto,
            warm_start: None,
        }
    }
}

/// Solver outcome: the best plan plus search statistics (Table 4).
#[derive(Debug, Clone)]
pub struct Solution {
    pub plan: PlacementPlan,
    pub solve_seconds: f64,
    /// DP states materialized across all outer configurations. A search
    /// *effort* statistic: incumbent pruning makes it (and
    /// `configs_tried`) vary with thread scheduling; the plan does not.
    pub dp_states: u64,
    /// (sg, recompute, stage-count) combinations evaluated.
    pub configs_tried: u64,
}

pub(crate) use crate::util::resolve_threads;

/// Shared K-best incumbent: the pruning bound is the K-th smallest
/// *achieved* batch time offered so far (`f64::INFINITY` until K
/// candidates exist). For `k == 1` this degenerates to the original
/// single-incumbent behavior. The K-th value is cached in an atomic so
/// the hot pruning paths never take the lock; `offer` is called once
/// per evaluated `(sg, recompute, p)` combination, which is cold.
struct Incumbent {
    k: usize,
    /// Cached K-th best value (bits), monotonically nonincreasing.
    kth: AtomicU64,
    /// The up-to-K smallest achieved batch times, sorted ascending.
    times: Mutex<Vec<f64>>,
}

impl Incumbent {
    fn new(k: usize) -> Self {
        Incumbent {
            k,
            kth: AtomicU64::new(f64::INFINITY.to_bits()),
            times: Mutex::new(Vec::with_capacity(k)),
        }
    }

    /// Current pruning bound: the K-th smallest achieved batch time.
    fn bound(&self) -> f64 {
        f64::from_bits(self.kth.load(Ordering::Relaxed))
    }

    /// Record an achieved batch time. Values that cannot enter the
    /// current top-K (≥ the K-th with the list full) are rejected
    /// without locking; ties at the K-th value leave the bound
    /// unchanged, so pruning against `bound()` stays strict.
    fn offer(&self, v: f64) {
        if v >= self.bound() {
            return;
        }
        let mut ts = self.times.lock().expect("incumbent poisoned");
        let pos = ts.partition_point(|&t| t <= v);
        ts.insert(pos, v);
        ts.truncate(self.k);
        // Flight recorder (cold path: only reached on a genuine top-K
        // entry). Strictly observational — never steers the search.
        obs::count("solver.incumbent.improved", 1);
        obs::instant("solver.incumbent.improved", "solver", || {
            vec![("batch_time", format!("{v:.6e}"))]
        });
        if ts.len() == self.k {
            self.kth
                .fetch_min(ts[self.k - 1].to_bits(), Ordering::Relaxed);
        }
    }
}

/// Pool context of one DP state's stage block: which accelerator
/// classes the block (and its data-parallel replicas) covers, and the
/// smallest HBM capacity among them. Index `s` (stages remaining,
/// 1-based) corresponds to the block `s − 1` blocks from the pipeline
/// end — devices `[(s−1)·g, s·g)` under compact tail-first packing —
/// so the DP prices every candidate stage with the accelerator profile
/// of the devices it actually covers (TP/DP lockstep semantics).
#[derive(Debug, Clone, Copy)]
struct StageCtx {
    mask: ClassMask,
    cap: f64,
}

/// Contexts for stage blocks `0..count` of `g` devices, replicated `d`
/// times at `stride`. `out[s]` is the context of the state with `s`
/// stages remaining (`out[0]` is a pool-wide placeholder). On a
/// homogeneous pool every context is identical, which is what lets DP
/// tables be shared across stage counts there.
fn stage_ctxs(cluster: &Cluster, g: usize, count: usize, d: usize, stride: usize) -> Vec<StageCtx> {
    let pool = &cluster.pool;
    let mut out = Vec::with_capacity(count + 1);
    out.push(StageCtx {
        mask: pool.full_mask(),
        cap: pool.min_capacity_all(),
    });
    for s in 1..=count {
        let mask = pool.replicated_mask((s - 1) * g, s * g, d, stride);
        out.push(StageCtx {
            mask,
            cap: pool.min_capacity(mask),
        });
    }
    out
}

/// One DP table for a fixed (sg, recompute, zero-cap).
struct DpTable {
    n: usize,
    g: usize,
    /// cost[s][i] flattened; `f64::INFINITY` = infeasible (or provably
    /// worse than the incumbent bound the table was built under).
    cost: Vec<f64>,
    /// Backpointer: cut `j` for state (i, s).
    cut: Vec<u32>,
    /// Memory spec chosen for stage `[i, cut)` at state (i, s).
    spec: Vec<MemSpec>,
}

impl DpTable {
    fn idx(&self, i: usize, s: usize) -> usize {
        s * (self.n + 1) + i
    }
    fn cost_at(&self, i: usize, s: usize) -> f64 {
        self.cost[self.idx(i, s)]
    }
}

/// Run the suffix DP for one (cost model, recompute, zero cap).
///
/// `bound` is the bottleneck-level incumbent bound (`incumbent / (m+p−1)`
/// for the smallest stage count that will read this table): states whose
/// cost provably exceeds it are stored as infeasible. Pruning is strict —
/// states with cost equal to the bound survive — so the optimal plan's
/// backpointer chain is never cut (module docs).
///
/// `ctxs[s]` carries the accelerator-class coverage and memory capacity
/// of the device block the state with `s` stages remaining occupies
/// (see [`stage_ctxs`]); compute prices lockstep on the slowest covered
/// class and memory checks against the smallest covered HBM.
#[allow(clippy::too_many_arguments)]
fn run_dp(
    cm: &CostModel,
    cluster: &Cluster,
    recompute: bool,
    zero_cap: usize,
    s_max: usize,
    states: &mut u64,
    bound: f64,
    ctxs: &[StageCtx],
) -> DpTable {
    let n = cm.n_layers();
    let g = cm.group;
    let mut t = DpTable {
        n,
        g,
        cost: vec![f64::INFINITY; (s_max + 1) * (n + 1)],
        cut: vec![0; (s_max + 1) * (n + 1)],
        spec: vec![MemSpec::plain(); (s_max + 1) * (n + 1)],
    };

    // Boundary levels memoized per block index: the recv level of the
    // state with `s` stages remaining is `blev[s]`, its send level
    // `blev[s − 1]` — computed once instead of per (s) pair.
    let blev: Vec<usize> = (0..=s_max)
        .map(|s| if s == 0 { 0 } else { boundary_level(cluster, s * g) })
        .collect();
    // Prune hits accumulate in a plain local (same pattern as `states`)
    // and flush to the flight recorder once per table build — the
    // transition scans never pay a per-iteration recorder call.
    let mut pruned: u64 = 0;
    for s in 1..=s_max {
        let StageCtx { mask, cap } = ctxs[s];
        // Per-s invariants hoisted out of the cut scan: the resolved
        // class pricer and the boundary levels.
        let pricer = cm.pricer(mask);
        let l_recv = blev[s];
        let l_send = if s > 1 { Some(blev[s - 1]) } else { None };
        let stash = s - 1;
        // Suffix [i, n) needs at least s layers.
        for i in 0..=(n - s) {
            if s == 1 {
                // Single stage covering the whole suffix. `stage_load`
                // strictly exceeds the compute lower bound here (the
                // producer edge pays latency), so `lb >= bound` implies
                // the state is strictly worse than the incumbent.
                if cm.stage_load_lb_priced(&pricer, i, n) >= bound {
                    pruned += 1;
                    continue;
                }
                if let Some(spec) = cm.stage_choose_spec(i, n, stash, cap, zero_cap, recompute)
                {
                    let load =
                        cm.stage_load_priced(&pricer, i, n, Some(l_recv), None, &spec, cluster);
                    *states += 1;
                    if load <= bound {
                        let ix = t.idx(i, 1);
                        t.cost[ix] = load;
                        t.cut[ix] = n as u32;
                        t.spec[ix] = spec;
                    }
                }
                continue;
            }
            let mut best = f64::INFINITY;
            let mut best_cut = 0u32;
            let mut best_spec = MemSpec::plain();
            // Cut j: this stage is [i, j), the rest [j, n) has s−1 stages.
            for j in (i + 1)..=(n - (s - 1)) {
                // Lower bound on load: pure compute, strictly increasing
                // in j — exact pruning once it exceeds the incumbent or
                // the local best (stage_load > lb strictly, so no
                // bound-tying candidate is ever lost to this break).
                let lb = cm.stage_load_lb_priced(&pricer, i, j);
                if lb >= best.min(bound) {
                    pruned += 1;
                    break;
                }
                let rest = t.cost_at(j, s - 1);
                if rest.is_infinite() {
                    // Infeasible suffix: a *larger* j leaves a smaller,
                    // memory-lighter suffix that may still fit — skip
                    // this cut without pricing it, don't abandon the
                    // whole scan.
                    continue;
                }
                let Some(spec) = cm.stage_choose_spec(i, j, stash, cap, zero_cap, recompute)
                else {
                    // Memory grows with j: no larger stage fits either.
                    break;
                };
                let load =
                    cm.stage_load_priced(&pricer, i, j, Some(l_recv), l_send, &spec, cluster);
                *states += 1;
                let cand = load.max(rest);
                if cand < best {
                    best = cand;
                    best_cut = j as u32;
                    best_spec = spec;
                }
            }
            if best <= bound {
                let ix = t.idx(i, s);
                t.cost[ix] = best;
                t.cut[ix] = best_cut;
                t.spec[ix] = best_spec;
            }
        }
    }
    if obs::enabled() {
        obs::count("solver.prune.dp_state", pruned);
    }
    t
}

/// Evaluate the first stage + suffix for a total stage count `p`
/// (Algorithm 1 lines 19–31). Returns (bottleneck, first cut, first spec).
///
/// `bound` is the bottleneck-level incumbent bound for this `p`; the cut
/// scan stops once the compute lower bound crosses it (strictly safe for
/// the same reason as in [`run_dp`]).
#[allow(clippy::too_many_arguments)]
fn eval_final(
    cm: &CostModel,
    cluster: &Cluster,
    dp: &DpTable,
    p: usize,
    recompute: bool,
    zero_cap: usize,
    bound: f64,
    first: StageCtx,
) -> Option<(f64, usize, MemSpec)> {
    let n = cm.n_layers();
    let StageCtx { mask, cap } = first;
    let pricer = cm.pricer(mask);
    let stash = p - 1;
    if p == 1 {
        let spec = cm.stage_choose_spec(0, n, 0, cap, zero_cap, recompute)?;
        let load = cm.stage_load_priced(&pricer, 0, n, None, None, &spec, cluster);
        if load > bound {
            return None;
        }
        return Some((load, n, spec));
    }
    let l_send = boundary_level(cluster, (p - 1) * dp.g);
    let mut best: Option<(f64, usize, MemSpec)> = None;
    let mut pruned: u64 = 0;
    for j in 1..=(n - (p - 1)) {
        let lb = cm.stage_load_lb_priced(&pricer, 0, j);
        let mut cutoff = bound;
        if let Some((b, _, _)) = best {
            cutoff = cutoff.min(b);
        }
        if lb >= cutoff {
            pruned += 1;
            break;
        }
        let Some(spec) = cm.stage_choose_spec(0, j, stash, cap, zero_cap, recompute) else {
            break;
        };
        let load = cm.stage_load_priced(&pricer, 0, j, None, Some(l_send), &spec, cluster);
        let rest = dp.cost_at(j, p - 1);
        let cand = load.max(rest);
        if cand.is_finite() && best.map(|(b, _, _)| cand < b).unwrap_or(true) {
            best = Some((cand, j, spec));
        }
    }
    if obs::enabled() {
        obs::count("solver.prune.final_cut", pruned);
    }
    best
}

/// Reconstruct the stage list for total stage count `p`. `ctxs[s]` must
/// cover states `s ∈ 1..=p` (the same contexts the table was built
/// with), so the recorded loads and device classes match what the DP
/// scored.
fn reconstruct(
    cm: &CostModel,
    cluster: &Cluster,
    dp: &DpTable,
    p: usize,
    first_cut: usize,
    first_spec: MemSpec,
    ctxs: &[StageCtx],
) -> Vec<StagePlan> {
    let g = dp.g;
    let mut stages = Vec::with_capacity(p);
    let mut push_stage = |i: usize, j: usize, spec: MemSpec, k: usize| {
        let blocks_from_end = p - 1 - k;
        let ctx = ctxs[p - k];
        let send_level = if k + 1 < p {
            Some(boundary_level(cluster, (p - 1 - k) * g))
        } else {
            None
        };
        let recv_level = if k > 0 {
            Some(boundary_level(cluster, (p - k) * g))
        } else {
            None
        };
        let load = cm.stage_load_on(ctx.mask, i, j, recv_level, send_level, &spec, cluster);
        stages.push(StagePlan {
            layers: (i, j),
            devices: stage_devices(blocks_from_end, g),
            sg: cm.sg,
            mem: spec,
            send_level,
            load,
            accel_class: cluster.pool.class_names(ctx.mask),
        });
    };

    push_stage(0, first_cut, first_spec, 0);
    let mut i = first_cut;
    for k in 1..p {
        let s = p - k; // stages remaining including this one
        let ix = dp.idx(i, s);
        let j = dp.cut[ix] as usize;
        debug_assert!(j > i, "broken backpointer at ({i},{s})");
        push_stage(i, j, dp.spec[ix], k);
        i = j;
    }
    debug_assert_eq!(i, cm.n_layers());
    stages
}

/// Largest power of two ≤ x (≥ 1).
pub fn pow2_floor(x: usize) -> usize {
    if x <= 1 {
        1
    } else {
        1 << (usize::BITS - 1 - x.leading_zeros())
    }
}

/// A scored plan plus its position in the deterministic enumeration
/// order, for total-order tie-breaking across workers.
struct Candidate {
    batch_time: f64,
    sg_idx: usize,
    p: usize,
    /// Data-parallel width. Forced to `⌊K/(p·g)⌋` on homogeneous pools;
    /// enumerated on heterogeneous ones (a narrower replication can
    /// keep a stage off the slow island).
    d: usize,
    rc: bool,
    plan: PlacementPlan,
}

/// Strict total order on candidates: batch time, then SUB-GRAPH config
/// index, then the stash-everything branch, then stage count, then the
/// wider data-parallel width — the pre-parallel serial enumeration
/// order (sg outer, recompute middle, p inner, first strict improvement
/// kept), so results are identical for every thread count. On
/// homogeneous pools `d` is a function of `p` and the last key is inert.
fn candidate_before(a: &Candidate, b: &Candidate) -> bool {
    if a.batch_time != b.batch_time {
        return a.batch_time < b.batch_time;
    }
    if a.sg_idx != b.sg_idx {
        return a.sg_idx < b.sg_idx;
    }
    if a.rc != b.rc {
        return !a.rc;
    }
    if a.p != b.p {
        return a.p < b.p;
    }
    a.d > b.d
}

/// Insert `cand` into a list kept sorted by [`candidate_before`],
/// bounded to the `k` best. The order is strict and total over distinct
/// `(sg, recompute, p)` triples, so the resulting list is independent of
/// insertion order.
fn kbest_insert(list: &mut Vec<Candidate>, cand: Candidate, k: usize) {
    let pos = list.partition_point(|c| candidate_before(c, &cand));
    if pos >= k {
        return;
    }
    list.insert(pos, cand);
    list.truncate(k);
}

/// Per-(sg, recompute) work-item outcome.
struct ConfigOutcome {
    /// The item's up-to-K best candidates in total order.
    kbest: Vec<Candidate>,
    dp_states: u64,
    configs: u64,
}

/// Evaluate every stage count for one (sg, recompute) configuration,
/// pruning against (and offering improvements to) the shared K-th
/// incumbent.
#[allow(clippy::too_many_arguments)]
fn eval_config(
    graph: &LayerGraph,
    cluster: &Cluster,
    opts: &SolverOpts,
    sg_idx: usize,
    sg: SgConfig,
    rc: bool,
    s_cap: usize,
    k: usize,
    incumbent: &Incumbent,
) -> ConfigOutcome {
    // Per-configuration span: one per (sg, recompute) work item, with
    // the configuration in the args (the trace's unit of solver work).
    let _span = obs::span_with("solver.config", "solver", || {
        vec![
            ("sg", format!("{sg:?}")),
            ("recompute", rc.to_string()),
            ("sg_idx", sg_idx.to_string()),
        ]
    });
    let mut out = ConfigOutcome {
        kbest: Vec::new(),
        dp_states: 0,
        configs: 0,
    };
    let k_total = cluster.n_devices();
    let n = graph.n_layers();
    let g = sg.group_size();
    if g > k_total {
        return out;
    }
    let cm = CostModel::with_mode(graph, cluster, sg, opts.pricing);
    let s_max = s_cap.min(k_total / g).min(n);
    let global_batch = graph.global_batch;
    let hetero = !cluster.pool.is_homogeneous();

    // Compute-only bounds for config-level pruning: any p-stage pipeline's
    // bottleneck is at least the balanced share of the total compute and
    // at least the heaviest single layer — on the pool's *fastest* class,
    // so the bound holds wherever the stages land. The single-layer max
    // is precomputed by the cost model (same fold, same bits).
    let total_lb = cm.stage_load_lb_best(0, n);
    let max_layer_lb = cm.max_single_layer_lb_best();

    // Homogeneous pools: every stage block has the same (single-class)
    // context, so DP tables are cached per ZeRO-degree cap (the cap
    // depends on the data-parallel width, which varies with the stage
    // count). Heterogeneous pools rebuild per (p, d) below — a block's
    // replica coverage depends on the stride p·g and width d.
    let uniform_ctxs = stage_ctxs(cluster, g, s_max, 1, 0);
    let mut tables: HashMap<usize, DpTable> = HashMap::new();
    let mut prune_cfg: u64 = 0;
    for p in 1..=s_max {
        let d_max = k_total / (g * p);
        if d_max == 0 {
            break;
        }
        // Homogeneous pools replicate as widely as possible (the paper's
        // d = ⌊K/(p·g)⌋). On a mixed pool the forced width can drag every
        // replica group across the slow island, so the data-parallel
        // width is enumerated: d_max plus every power of two below it
        // (descending — full utilization first).
        let mut d_options: Vec<usize> = vec![d_max];
        if hetero {
            let mut dd = pow2_floor(d_max);
            while dd >= 1 {
                if dd != d_max {
                    d_options.push(dd);
                }
                dd /= 2;
            }
        }
        for d in d_options {
            out.configs += 1;
            let m = global_batch.div_ceil(d * graph.mbs);
            let mult = m as f64 + p as f64 - 1.0;
            // Config-level prune (strict): even a perfectly balanced,
            // communication-free pipeline on the fastest class cannot
            // enter the top-K here.
            if (total_lb / p as f64).max(max_layer_lb) * mult > incumbent.bound() {
                prune_cfg += 1;
                continue;
            }
            let zero_cap = pow2_floor(d).min(opts.zero_max_degree);
            let bound = incumbent.bound() / mult;
            let stride = p * g;
            let hetero_state; // per-(p, d) contexts + table (mixed pools)
            let (dp, ctxs): (&DpTable, &[StageCtx]) = if hetero {
                let ctxs = stage_ctxs(cluster, g, p, d, stride);
                let table = run_dp(
                    &cm,
                    cluster,
                    rc,
                    zero_cap,
                    p - 1,
                    &mut out.dp_states,
                    bound,
                    &ctxs,
                );
                hetero_state = (table, ctxs);
                (&hetero_state.0, hetero_state.1.as_slice())
            } else {
                let dp = tables.entry(zero_cap).or_insert_with(|| {
                    // The table is shared by all stage counts p' ≥ p
                    // mapping to this zero cap; their multipliers only
                    // grow, so this p's bound is the loosest — safe for
                    // every later reader.
                    run_dp(
                        &cm,
                        cluster,
                        rc,
                        zero_cap,
                        s_max,
                        &mut out.dp_states,
                        bound,
                        &uniform_ctxs,
                    )
                });
                (&*dp, &uniform_ctxs[..])
            };
            let first_ctx = ctxs[p];
            let Some((bottleneck, first_cut, first_spec)) =
                eval_final(&cm, cluster, dp, p, rc, zero_cap, bound, first_ctx)
            else {
                continue;
            };
            if !bottleneck.is_finite() {
                continue;
            }
            // Gradient sync (Algorithm 1 line 25): priced on the
            // reconstructed stages' parameter volumes.
            let stages = reconstruct(&cm, cluster, dp, p, first_cut, first_spec, ctxs);
            let sync = stages
                .iter()
                .map(|st| {
                    cluster.dp_allreduce(
                        cm.stage_grad_bytes(st.layers.0, st.layers.1),
                        d,
                        stride,
                    )
                })
                .fold(0.0, f64::max);
            let batch_time = bottleneck * mult + sync;
            incumbent.offer(batch_time);
            let cand = Candidate {
                batch_time,
                sg_idx,
                p,
                d,
                rc,
                plan: PlacementPlan {
                    model_name: graph.model_name.clone(),
                    method: "nest".into(),
                    sg,
                    stages,
                    dp_width: d,
                    mbs: graph.mbs,
                    n_microbatches: m,
                    devices_per_replica: stride,
                    bottleneck,
                    sync_time: sync,
                    batch_time,
                },
            };
            kbest_insert(&mut out.kbest, cand, k);
        }
    }
    if obs::enabled() {
        obs::count("solver.prune.config_bound", prune_cfg);
    }
    out
}

/// K-best solver outcome: the analytic shortlist plus search statistics.
#[derive(Debug, Clone)]
pub struct TopKSolution {
    /// The K best distinct `(sg, recompute, stage count)` plans in the
    /// solver's total order (index 0 = the plan [`solve`] returns).
    /// Fewer than K entries when the search space is smaller; empty when
    /// no feasible placement exists.
    pub plans: Vec<PlacementPlan>,
    pub solve_seconds: f64,
    /// See [`Solution::dp_states`].
    pub dp_states: u64,
    /// See [`Solution::configs_tried`].
    pub configs_tried: u64,
}

/// Solve placement for `graph` on `cluster` with NEST's DP.
///
/// Deterministic: the returned plan is field-for-field identical for
/// every `opts.threads` value (see the module docs); only the search
/// statistics in [`Solution`] depend on scheduling.
pub fn solve(graph: &LayerGraph, cluster: &Cluster, opts: &SolverOpts) -> Option<Solution> {
    let top = solve_topk(graph, cluster, opts, 1);
    let plan = top.plans.into_iter().next()?;
    Some(Solution {
        plan,
        solve_seconds: top.solve_seconds,
        dp_states: top.dp_states,
        configs_tried: top.configs_tried,
    })
}

/// Solve placement, retaining the `k` best distinct
/// `(sg, recompute, stage count)` solutions under the solver's total
/// order (module docs, "K-best enumeration"). `k` is clamped to ≥ 1.
///
/// Deterministic: the returned shortlist is field-for-field identical
/// for every `opts.threads` value. `solve_topk(…, 1)` selects exactly
/// the plan [`solve`] returns.
pub fn solve_topk(
    graph: &LayerGraph,
    cluster: &Cluster,
    opts: &SolverOpts,
    k: usize,
) -> TopKSolution {
    let k = k.max(1);
    let _span = obs::span_with("solver.solve_topk", "solver", || {
        vec![
            ("model", graph.model_name.clone()),
            ("cluster", cluster.name.clone()),
            ("k", k.to_string()),
        ]
    });
    let t0 = Instant::now();
    let k_total = cluster.n_devices();
    let n = graph.n_layers();
    let s_cap = if opts.max_stages == 0 {
        n
    } else {
        opts.max_stages.min(n)
    };

    let sgs = enumerate_sg(
        &graph.tp_widths,
        &graph.ep_degrees,
        &graph.cp_degrees,
        k_total,
    );
    let mut rcs = Vec::new();
    if opts.try_no_recompute {
        rcs.push(false);
    }
    if opts.try_recompute {
        rcs.push(true);
    }

    // Work queue: one item per (sg, recompute) pair.
    let mut items: Vec<(usize, SgConfig, bool)> = Vec::with_capacity(sgs.len() * rcs.len());
    for (sg_idx, sg) in sgs.iter().enumerate() {
        for &rc in &rcs {
            items.push((sg_idx, *sg, rc));
        }
    }

    // Warm start: front-load the hinted configuration so the first
    // worker evaluates it before anything else and its achieved batch
    // time seeds the shared incumbent. `sg_idx` values travel with the
    // items, the (sg, recompute, p) space is partitioned exactly as
    // before, and the K-best merge is insertion-order-independent, so
    // the result is bit-identical to a cold start (see [`WarmStart`]).
    if let Some(ws) = &opts.warm_start {
        if let Some(pos) = items
            .iter()
            .position(|&(_, sg, rc)| sg == ws.sg && rc == ws.recompute)
        {
            let hinted = items.remove(pos);
            items.insert(0, hinted);
        }
    }

    let incumbent = Incumbent::new(k);
    let next = AtomicUsize::new(0);
    let dp_states = AtomicU64::new(0);
    let configs = AtomicU64::new(0);

    let worker = |local_kbest: &mut Vec<Candidate>| {
        loop {
            let idx = next.fetch_add(1, Ordering::Relaxed);
            if idx >= items.len() {
                break;
            }
            let (sg_idx, sg, rc) = items[idx];
            let out = eval_config(graph, cluster, opts, sg_idx, sg, rc, s_cap, k, &incumbent);
            dp_states.fetch_add(out.dp_states, Ordering::Relaxed);
            configs.fetch_add(out.configs, Ordering::Relaxed);
            for cand in out.kbest {
                kbest_insert(local_kbest, cand, k);
            }
        }
    };

    let n_threads = resolve_threads(opts.threads).min(items.len().max(1));
    let mut per_worker: Vec<Vec<Candidate>> = Vec::with_capacity(n_threads);
    if n_threads <= 1 {
        let mut best = Vec::new();
        worker(&mut best);
        per_worker.push(best);
    } else {
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..n_threads)
                .map(|_| {
                    scope.spawn(|| {
                        let mut best = Vec::new();
                        worker(&mut best);
                        best
                    })
                })
                .collect();
            for h in handles {
                per_worker.push(h.join().expect("solver worker panicked"));
            }
        });
    }

    // Deterministic reduce: merge every worker's K-best under the total
    // order. Work items partition the (sg, recompute, p) space, so the
    // merged candidates are distinct and the result is the global top-K
    // regardless of how items were scheduled.
    let mut best: Vec<Candidate> = Vec::new();
    for cand in per_worker.into_iter().flatten() {
        kbest_insert(&mut best, cand, k);
    }

    if obs::enabled() {
        obs::count("solver.dp_states", dp_states.load(Ordering::Relaxed));
        obs::count("solver.configs", configs.load(Ordering::Relaxed));
    }

    TopKSolution {
        plans: best.into_iter().map(|c| c.plan).collect(),
        solve_seconds: t0.elapsed().as_secs_f64(),
        dp_states: dp_states.load(Ordering::Relaxed),
        configs_tried: configs.load(Ordering::Relaxed),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::util::prop;

    #[test]
    fn solves_tiny_model() {
        let g = models::tiny_transformer(6, 256, 128, 1);
        let c = Cluster::v100_cluster(8);
        let sol = solve(&g, &c, &SolverOpts::default()).expect("solution");
        sol.plan.validate(&g, &c).unwrap();
        assert!(sol.plan.batch_time > 0.0);
        assert!(sol.plan.used_devices() <= 8);
    }

    #[test]
    fn solves_bertlarge_fat_tree() {
        let g = models::bert_large(1);
        let c = Cluster::fat_tree_tpuv4(64);
        let sol = solve(&g, &c, &SolverOpts::default()).expect("solution");
        sol.plan.validate(&g, &c).unwrap();
        // BertLarge at small scale should prefer heavy data parallelism
        // (§5.2: NEST picks {1, 512, 1, 1} at 512 devices).
        assert!(sol.plan.dp_width >= sol.plan.n_stages());
    }

    #[test]
    fn llama3_on_64_needs_memory_tricks() {
        // 70B params × 16 bytes ≈ 1.1 TB of static state on 64×64 GB
        // devices → must pipeline deeply, recompute, or ZeRO.
        let g = models::llama3_70b(1);
        let c = Cluster::fat_tree_tpuv4(64);
        let sol = solve(&g, &c, &SolverOpts::default()).expect("solution");
        sol.plan.validate(&g, &c).unwrap();
        let uses_zero = sol
            .plan
            .stages
            .iter()
            .any(|s| s.mem.zero != crate::memory::ZeroStage::None);
        let uses_rc = sol.plan.stages.iter().any(|s| s.mem.recompute);
        assert!(
            sol.plan.n_stages() >= 4 || uses_zero || uses_rc,
            "plan: {}",
            sol.plan.describe()
        );
    }

    #[test]
    fn bigger_cluster_not_slower() {
        let g = models::llama2_7b(1);
        let t64 = solve(&g, &Cluster::fat_tree_tpuv4(64), &SolverOpts::default())
            .unwrap()
            .plan
            .batch_time;
        let t256 = solve(&g, &Cluster::fat_tree_tpuv4(256), &SolverOpts::default())
            .unwrap()
            .plan
            .batch_time;
        assert!(
            t256 < t64,
            "256 devices ({t256}s) should beat 64 ({t64}s)"
        );
    }

    #[test]
    fn gpt3_uses_tensor_parallelism() {
        let g = models::gpt3_175b(1);
        let c = Cluster::fat_tree_tpuv4(256);
        let sol = solve(&g, &c, &SolverOpts::default()).expect("solution");
        sol.plan.validate(&g, &c).unwrap();
        // Table 2: GPT-3 175B runs with TP 4 or 8.
        assert!(sol.plan.sg.tp >= 4, "plan: {}", sol.plan.strategy_string());
    }

    #[test]
    fn pow2_floor_values() {
        assert_eq!(pow2_floor(1), 1);
        assert_eq!(pow2_floor(2), 2);
        assert_eq!(pow2_floor(3), 2);
        assert_eq!(pow2_floor(8), 8);
        assert_eq!(pow2_floor(1000), 512);
    }

    #[test]
    fn respects_max_stages() {
        let g = models::llama2_7b(1);
        let c = Cluster::fat_tree_tpuv4(64);
        let opts = SolverOpts {
            max_stages: 2,
            ..Default::default()
        };
        let sol = solve(&g, &c, &opts).unwrap();
        assert!(sol.plan.n_stages() <= 2);
    }

    #[test]
    fn mixtral_uses_expert_parallelism() {
        let g = models::mixtral_8x7b(1);
        let c = Cluster::fat_tree_tpuv4(256);
        let sol = solve(&g, &c, &SolverOpts::default()).expect("solution");
        sol.plan.validate(&g, &c).unwrap();
        assert!(
            sol.plan.sg.ep > 1 || sol.plan.sg.cp > 1,
            "MoE plan should use EP/CP: {}",
            sol.plan.strategy_string()
        );
    }

    fn solve_with_threads(g: &LayerGraph, c: &Cluster, threads: usize) -> Option<Solution> {
        solve(
            g,
            c,
            &SolverOpts {
                threads,
                ..Default::default()
            },
        )
    }

    #[test]
    fn thread_count_invariant_on_moe() {
        // Many (sg, recompute) work items → real contention on the queue
        // and incumbent; plans must still match field-for-field.
        let g = models::mixtral_scaled(1);
        let c = Cluster::v100_cluster(16);
        let a = solve_with_threads(&g, &c, 1).expect("serial solution");
        let b = solve_with_threads(&g, &c, 4).expect("threaded solution");
        assert_eq!(a.plan, b.plan, "1-thread vs 4-thread plans diverge");
    }

    #[test]
    fn prop_thread_count_invariant() {
        // The determinism guarantee as a property: across random tiny
        // models and clusters, 1-thread and 4-thread solves produce
        // field-for-field identical plans (same sg, stages, dp_width,
        // batch_time — PlacementPlan derives PartialEq).
        prop::forall(8, 0x7EAD5AFE, |rng| {
            let n_blocks = 2 + rng.gen_range(5); // 2..6 blocks (+emb+head)
            let hidden = 128 * (1 + rng.gen_range(3));
            let seq = 64 * (1 + rng.gen_range(2));
            let g = models::tiny_transformer(n_blocks, hidden, seq, 1);
            let devices = [4usize, 8, 16][rng.gen_range(3)];
            let c = Cluster::v100_cluster(devices);
            let serial = solve_with_threads(&g, &c, 1);
            let threaded = solve_with_threads(&g, &c, 4);
            match (serial, threaded) {
                (Some(a), Some(b)) => {
                    assert_eq!(
                        a.plan, b.plan,
                        "plans diverge on {} blocks / h={hidden} / {devices} devices",
                        n_blocks
                    );
                }
                (None, None) => {}
                (a, b) => panic!(
                    "feasibility depends on thread count: serial={} threaded={}",
                    a.is_some(),
                    b.is_some()
                ),
            }
        });
    }

    #[test]
    fn hetero_pool_strictly_beats_v100_constrained_twin() {
        // A mixed H100+V100 pool must solve, validate, and strictly
        // beat the same fabric with every device forced to V100: the
        // search space weakly dominates (lockstep pricing of any plan
        // is ≤ its all-V100 price), and the H100 island buys a strict
        // win via narrower replication / migrated stages.
        let g = models::tiny_transformer(6, 256, 128, 1);
        let mixed = Cluster::hetero_pool(32);
        let v100 = mixed.with_uniform_accel(crate::hw::Accelerator::v100());
        let a = solve(&g, &mixed, &SolverOpts::default()).expect("mixed feasible");
        a.plan.validate(&g, &mixed).unwrap();
        let b = solve(&g, &v100, &SolverOpts::default()).expect("twin feasible");
        assert!(
            a.plan.batch_time < b.plan.batch_time,
            "mixed {} not strictly faster than all-V100 {}",
            a.plan.batch_time,
            b.plan.batch_time
        );
        // Every stage records the classes it covers.
        for st in &a.plan.stages {
            assert!(!st.accel_class.is_empty());
            assert!(
                ["h100", "v100", "h100+v100"].contains(&st.accel_class.as_str()),
                "unexpected class record '{}'",
                st.accel_class
            );
        }
    }

    #[test]
    fn hetero_thread_count_invariant() {
        // The per-(p, d) table rebuilds and the d enumeration must not
        // disturb the determinism guarantee.
        let g = models::tiny_transformer(6, 256, 128, 1);
        let c = Cluster::hetero_pool(32);
        let a = solve_with_threads(&g, &c, 1).expect("serial");
        let b = solve_with_threads(&g, &c, 4).expect("threaded");
        assert_eq!(a.plan, b.plan, "hetero plans diverge across threads");
        for k in [2usize, 4] {
            let s = solve_topk(
                &g,
                &c,
                &SolverOpts {
                    threads: 1,
                    ..Default::default()
                },
                k,
            );
            let t = solve_topk(
                &g,
                &c,
                &SolverOpts {
                    threads: 4,
                    ..Default::default()
                },
                k,
            );
            assert_eq!(s.plans, t.plans, "hetero k={k} shortlists diverge");
        }
    }

    #[test]
    fn reference_pricing_reproduces_optimized_plans() {
        // The O(1) range tables must not move a single bit of any plan:
        // solve under both pricing modes and compare field-for-field.
        let g = models::llama2_7b(1);
        for c in [Cluster::fat_tree_tpuv4(64), Cluster::hetero_pool(32)] {
            for threads in [1usize, 4] {
                let opt = solve(
                    &g,
                    &c,
                    &SolverOpts {
                        threads,
                        pricing: PricingMode::Optimized,
                        ..Default::default()
                    },
                )
                .expect("optimized feasible");
                let refp = solve(
                    &g,
                    &c,
                    &SolverOpts {
                        threads,
                        pricing: PricingMode::Reference,
                        ..Default::default()
                    },
                )
                .expect("reference feasible");
                assert_eq!(opt.plan, refp.plan, "{} threads={threads}", c.name);
                assert_eq!(
                    opt.plan.batch_time.to_bits(),
                    refp.plan.batch_time.to_bits(),
                    "{} threads={threads}: batch times not bit-identical",
                    c.name
                );
            }
        }
    }

    #[test]
    fn topk1_matches_solve_field_for_field() {
        let g = models::bert_large(1);
        let c = Cluster::fat_tree_tpuv4(64);
        let sol = solve(&g, &c, &SolverOpts::default()).expect("solution");
        let top = solve_topk(&g, &c, &SolverOpts::default(), 1);
        assert_eq!(top.plans.len(), 1);
        assert_eq!(top.plans[0], sol.plan);
    }

    #[test]
    fn topk_zero_clamps_to_one() {
        let g = models::tiny_transformer(6, 256, 128, 1);
        let c = Cluster::v100_cluster(8);
        let top = solve_topk(&g, &c, &SolverOpts::default(), 0);
        assert_eq!(top.plans.len(), 1);
    }

    #[test]
    fn topk_sorted_distinct_and_headed_by_winner() {
        let g = models::mixtral_scaled(1);
        let c = Cluster::v100_cluster(16);
        let sol = solve(&g, &c, &SolverOpts::default()).expect("solution");
        let top = solve_topk(&g, &c, &SolverOpts::default(), 5);
        assert!(!top.plans.is_empty() && top.plans.len() <= 5);
        assert_eq!(top.plans[0], sol.plan, "rank 1 must be solve()'s plan");
        for w in top.plans.windows(2) {
            assert!(
                w[0].batch_time <= w[1].batch_time,
                "shortlist out of order: {} then {}",
                w[0].batch_time,
                w[1].batch_time
            );
            assert_ne!(w[0], w[1], "duplicate plan in shortlist");
        }
        // Distinct (sg, recompute, stage count) triples by construction.
        let keys: Vec<_> = top
            .plans
            .iter()
            .map(|p| {
                (
                    p.sg,
                    p.stages.iter().any(|s| s.mem.recompute),
                    p.n_stages(),
                )
            })
            .collect();
        for a in 0..keys.len() {
            for b in (a + 1)..keys.len() {
                assert!(keys[a] != keys[b], "shortlist triples not distinct");
            }
        }
        for p in &top.plans {
            p.validate(&g, &c).unwrap();
        }
    }

    #[test]
    fn topk_set_bit_identical_across_threads() {
        // The K-th-incumbent pruning must never change which K plans
        // survive, no matter how workers race.
        let g = models::mixtral_scaled(1);
        let c = Cluster::v100_cluster(16);
        for k in [2usize, 4, 8] {
            let a = solve_topk(
                &g,
                &c,
                &SolverOpts {
                    threads: 1,
                    ..Default::default()
                },
                k,
            );
            let b = solve_topk(
                &g,
                &c,
                &SolverOpts {
                    threads: 4,
                    ..Default::default()
                },
                k,
            );
            assert_eq!(a.plans, b.plans, "k={k}: 1-thread vs 4-thread shortlists diverge");
            for (x, y) in a.plans.iter().zip(&b.plans) {
                assert_eq!(
                    x.batch_time.to_bits(),
                    y.batch_time.to_bits(),
                    "k={k}: batch times not bit-identical"
                );
            }
        }
    }

    #[test]
    fn prop_topk_thread_count_invariant() {
        // K-best determinism as a property across random tiny models:
        // topk(1) ≡ solve, and the K-best set matches across thread
        // counts, ties resolved by (batch_time, sg, recompute, stages).
        prop::forall(6, 0x70D07EA5, |rng| {
            let n_blocks = 2 + rng.gen_range(5);
            let hidden = 128 * (1 + rng.gen_range(3));
            let seq = 64 * (1 + rng.gen_range(2));
            let g = models::tiny_transformer(n_blocks, hidden, seq, 1);
            let devices = [4usize, 8, 16][rng.gen_range(3)];
            let c = Cluster::v100_cluster(devices);
            let k = 1 + rng.gen_range(4);
            let serial = solve_topk(
                &g,
                &c,
                &SolverOpts {
                    threads: 1,
                    ..Default::default()
                },
                k,
            );
            let threaded = solve_topk(
                &g,
                &c,
                &SolverOpts {
                    threads: 4,
                    ..Default::default()
                },
                k,
            );
            assert_eq!(
                serial.plans, threaded.plans,
                "k={k} shortlists diverge on {n_blocks} blocks / h={hidden} / {devices} devices"
            );
            let direct = solve(&g, &c, &SolverOpts::default());
            assert_eq!(
                serial.plans.first(),
                direct.as_ref().map(|s| &s.plan),
                "topk rank-1 disagrees with solve()"
            );
        });
    }

    #[test]
    fn warm_start_hint_does_not_move_any_plan() {
        // A correct hint, a deliberately wrong hint, and a hint that
        // matches nothing must all reproduce the cold shortlist
        // bit-for-bit — the hint is an evaluation-order lever only.
        let g = models::mixtral_scaled(1);
        let c = Cluster::v100_cluster(16);
        for k in [1usize, 4] {
            let cold = solve_topk(
                &g,
                &c,
                &SolverOpts {
                    threads: 1,
                    ..Default::default()
                },
                k,
            );
            let winner = cold.plans.first().expect("feasible");
            let hints = [
                WarmStart::from_plan(winner),
                WarmStart {
                    sg: winner.sg,
                    recompute: !winner.stages.iter().any(|s| s.mem.recompute),
                },
                WarmStart {
                    sg: SgConfig {
                        tp: 64, // no such configuration is enumerated
                        sp: false,
                        ep: 1,
                        cp: 1,
                    },
                    recompute: false,
                },
            ];
            for hint in hints {
                for threads in [1usize, 4] {
                    let warm = solve_topk(
                        &g,
                        &c,
                        &SolverOpts {
                            threads,
                            warm_start: Some(hint),
                            ..Default::default()
                        },
                        k,
                    );
                    assert_eq!(
                        cold.plans, warm.plans,
                        "k={k} threads={threads} hint={hint:?}: warm shortlist diverged"
                    );
                    for (a, b) in cold.plans.iter().zip(&warm.plans) {
                        assert_eq!(
                            a.batch_time.to_bits(),
                            b.batch_time.to_bits(),
                            "k={k} threads={threads}: batch times not bit-identical"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn warm_start_from_plan_captures_recompute_branch() {
        let g = models::bert_large(1);
        let c = Cluster::fat_tree_tpuv4(64);
        let sol = solve(&g, &c, &SolverOpts::default()).expect("solution");
        let ws = WarmStart::from_plan(&sol.plan);
        assert_eq!(ws.sg, sol.plan.sg);
        assert_eq!(
            ws.recompute,
            sol.plan.stages.iter().any(|s| s.mem.recompute)
        );
    }

    #[test]
    fn repeated_and_threaded_solves_identical() {
        // How hard the incumbent prunes depends on how fast it drops,
        // which depends on worker scheduling — so sweeping thread counts
        // (and re-running) exercises materially different pruning paths.
        // The plan must never move.
        let g = models::bert_large(1);
        let c = Cluster::fat_tree_tpuv4(64);
        let base = solve_with_threads(&g, &c, 1).unwrap();
        for threads in [2usize, 8] {
            let other = solve_with_threads(&g, &c, threads).unwrap();
            assert_eq!(base.plan, other.plan, "threads={threads}");
        }
        let again = solve_with_threads(&g, &c, 1).unwrap();
        assert_eq!(base.plan, again.plan);
    }
}
