//! NEST's network-, compute-, and memory-aware dynamic program (§4,
//! Algorithm 1).
//!
//! Search structure (DESIGN.md §4):
//!
//! * **Outer enumeration** — SUB-GRAPH configuration `sg` (tensor /
//!   sequence / expert / context degrees, Table 2 columns), activation
//!   recomputation on/off, and the ZeRO degree cap. Uniform `sg` across
//!   stages matches the paper's evaluated plans and Megatron practice and
//!   is what keeps the search scalable past 1,000 devices ("template-based
//!   parallelism", §5.2.2).
//! * **DP core** — `dp[i][s]` = minimum bottleneck latency of executing
//!   the layer suffix `[i, L)` as `s` pipeline stages of `g = |sg|`
//!   devices each, *including* the forward edge from the yet-unplaced
//!   producer stage. Because stages are packed compactly tail-first, the
//!   producer boundary of a suffix with `s` stages sits at device offset
//!   `s·g`, so its communication level — the paper's deferred-forward-cost
//!   level `l` — is known exactly (`assign::boundary_level`). Memory
//!   feasibility (Eq. 1) is evaluated *inside* the transition; infeasible
//!   stages escalate ZeRO 1→2→3 (adding the collective overhead to the
//!   load) and are pruned only if nothing fits — never post hoc.
//! * **Final pass** — Algorithm 1 lines 19–31: the first stage (no
//!   forward edge) is evaluated separately per total stage count `p`;
//!   data parallelism replicates the pipeline `d = ⌊K / (p·g)⌋` times
//!   (partial utilization allowed, §5.2.1) and the batch time is
//!   `bottleneck · (m + p − 1) + SyncCost`.
//!
//! The full per-stage-device-count generalization (the paper's
//! `dp[l][D][k][s]` with enumerated allocations) is in [`exact`] and is
//! used for small clusters (§5.4) and as the optimality cross-check.

pub mod assign;
pub mod exact;
pub mod plan;

use std::collections::HashMap;
use std::time::Instant;

use crate::cost::CostModel;
use crate::graph::subgraph::enumerate_sg;
use crate::graph::LayerGraph;
use crate::memory::MemSpec;
use crate::network::Cluster;
use assign::{boundary_level, stage_devices};
use plan::{PlacementPlan, StagePlan};

/// Solver options.
#[derive(Debug, Clone)]
pub struct SolverOpts {
    /// Cap on pipeline stages (0 = number of layers).
    pub max_stages: usize,
    /// Largest ZeRO sharding degree to consider.
    pub zero_max_degree: usize,
    /// Explore the activation-recomputation branch.
    pub try_recompute: bool,
    /// Explore the stash-everything branch.
    pub try_no_recompute: bool,
}

impl Default for SolverOpts {
    fn default() -> Self {
        SolverOpts {
            max_stages: 0,
            zero_max_degree: 8,
            try_recompute: true,
            try_no_recompute: true,
        }
    }
}

/// Solver outcome: the best plan plus search statistics (Table 4).
#[derive(Debug, Clone)]
pub struct Solution {
    pub plan: PlacementPlan,
    pub solve_seconds: f64,
    /// DP states materialized across all outer configurations.
    pub dp_states: u64,
    /// (sg, recompute, stage-count) combinations evaluated.
    pub configs_tried: u64,
}

/// One DP table for a fixed (sg, recompute, zero-cap).
struct DpTable {
    n: usize,
    #[allow(dead_code)]
    s_max: usize,
    g: usize,
    /// cost[s][i] flattened; `f64::INFINITY` = infeasible.
    cost: Vec<f64>,
    /// Backpointer: cut `j` for state (i, s).
    cut: Vec<u32>,
    /// Memory spec chosen for stage `[i, cut)` at state (i, s).
    spec: Vec<MemSpec>,
}

impl DpTable {
    fn idx(&self, i: usize, s: usize) -> usize {
        s * (self.n + 1) + i
    }
    fn cost_at(&self, i: usize, s: usize) -> f64 {
        self.cost[self.idx(i, s)]
    }
}

/// Run the suffix DP for one (cost model, recompute, zero cap).
fn run_dp(
    cm: &CostModel,
    cluster: &Cluster,
    recompute: bool,
    zero_cap: usize,
    #[allow(dead_code)]
    s_max: usize,
    states: &mut u64,
) -> DpTable {
    let n = cm.n_layers();
    let g = cm.group;
    let cap = cluster.accel.hbm_capacity;
    let mut t = DpTable {
        n,
        s_max,
        g,
        cost: vec![f64::INFINITY; (s_max + 1) * (n + 1)],
        cut: vec![0; (s_max + 1) * (n + 1)],
        spec: vec![MemSpec::plain(); (s_max + 1) * (n + 1)],
    };

    for s in 1..=s_max {
        let l_recv = boundary_level(cluster, s * g);
        let l_send = if s > 1 {
            Some(boundary_level(cluster, (s - 1) * g))
        } else {
            None
        };
        let stash = s - 1;
        // Suffix [i, n) needs at least s layers.
        for i in 0..=(n - s) {
            if s == 1 {
                // Single stage covering the whole suffix.
                if let Some(spec) = cm.stage_choose_spec(i, n, stash, cap, zero_cap, recompute)
                {
                    let load = cm.stage_load(i, n, Some(l_recv), None, &spec, cluster);
                    let ix = t.idx(i, 1);
                    t.cost[ix] = load;
                    t.cut[ix] = n as u32;
                    t.spec[ix] = spec;
                    *states += 1;
                }
                continue;
            }
            let mut best = f64::INFINITY;
            let mut best_cut = 0u32;
            let mut best_spec = MemSpec::plain();
            // Cut j: this stage is [i, j), the rest [j, n) has s−1 stages.
            for j in (i + 1)..=(n - (s - 1)) {
                // Lower bound on load: pure compute, strictly increasing
                // in j — exact pruning once it exceeds the incumbent.
                let lb = cm.stage_load_lb(i, j);
                if lb >= best {
                    break;
                }
                let rest = t.cost_at(j, s - 1);
                if rest.is_infinite() && lb >= best {
                    break;
                }
                let Some(spec) = cm.stage_choose_spec(i, j, stash, cap, zero_cap, recompute)
                else {
                    // Memory grows with j: no larger stage fits either.
                    break;
                };
                let load = cm.stage_load(i, j, Some(l_recv), l_send, &spec, cluster);
                *states += 1;
                let cand = load.max(rest);
                if cand < best {
                    best = cand;
                    best_cut = j as u32;
                    best_spec = spec;
                }
            }
            let ix = t.idx(i, s);
            t.cost[ix] = best;
            t.cut[ix] = best_cut;
            t.spec[ix] = best_spec;
        }
    }
    t
}

/// Evaluate the first stage + suffix for a total stage count `p`
/// (Algorithm 1 lines 19–31). Returns (bottleneck, first cut, first spec).
fn eval_final(
    cm: &CostModel,
    cluster: &Cluster,
    dp: &DpTable,
    p: usize,
    recompute: bool,
    zero_cap: usize,
) -> Option<(f64, usize, MemSpec)> {
    let n = cm.n_layers();
    let cap = cluster.accel.hbm_capacity;
    let stash = p - 1;
    if p == 1 {
        let spec = cm.stage_choose_spec(0, n, 0, cap, zero_cap, recompute)?;
        let load = cm.stage_load(0, n, None, None, &spec, cluster);
        return Some((load, n, spec));
    }
    let l_send = boundary_level(cluster, (p - 1) * dp.g);
    let mut best: Option<(f64, usize, MemSpec)> = None;
    for j in 1..=(n - (p - 1)) {
        let lb = cm.stage_load_lb(0, j);
        if let Some((b, _, _)) = best {
            if lb >= b {
                break;
            }
        }
        let Some(spec) = cm.stage_choose_spec(0, j, stash, cap, zero_cap, recompute) else {
            break;
        };
        let load = cm.stage_load(0, j, None, Some(l_send), &spec, cluster);
        let rest = dp.cost_at(j, p - 1);
        let cand = load.max(rest);
        if cand.is_finite() && best.map(|(b, _, _)| cand < b).unwrap_or(true) {
            best = Some((cand, j, spec));
        }
    }
    best
}

/// Reconstruct the stage list for total stage count `p`.
fn reconstruct(
    cm: &CostModel,
    cluster: &Cluster,
    dp: &DpTable,
    p: usize,
    first_cut: usize,
    first_spec: MemSpec,
) -> Vec<StagePlan> {
    let g = dp.g;
    let mut stages = Vec::with_capacity(p);
    let mut push_stage = |i: usize, j: usize, spec: MemSpec, k: usize| {
        let blocks_from_end = p - 1 - k;
        let send_level = if k + 1 < p {
            Some(boundary_level(cluster, (p - 1 - k) * g))
        } else {
            None
        };
        let recv_level = if k > 0 {
            Some(boundary_level(cluster, (p - k) * g))
        } else {
            None
        };
        let load = cm.stage_load(i, j, recv_level, send_level, &spec, cluster);
        stages.push(StagePlan {
            layers: (i, j),
            devices: stage_devices(blocks_from_end, g),
            sg: cm.sg,
            mem: spec,
            send_level,
            load,
        });
    };

    push_stage(0, first_cut, first_spec, 0);
    let mut i = first_cut;
    for k in 1..p {
        let s = p - k; // stages remaining including this one
        let ix = dp.idx(i, s);
        let j = dp.cut[ix] as usize;
        debug_assert!(j > i, "broken backpointer at ({i},{s})");
        push_stage(i, j, dp.spec[ix], k);
        i = j;
    }
    debug_assert_eq!(i, cm.n_layers());
    stages
}

/// Largest power of two ≤ x (≥ 1).
pub fn pow2_floor(x: usize) -> usize {
    if x <= 1 {
        1
    } else {
        1 << (usize::BITS - 1 - x.leading_zeros())
    }
}

/// Solve placement for `graph` on `cluster` with NEST's DP.
pub fn solve(graph: &LayerGraph, cluster: &Cluster, opts: &SolverOpts) -> Option<Solution> {
    let t0 = Instant::now();
    let k_total = cluster.n_devices();
    let n = graph.n_layers();
    let s_cap = if opts.max_stages == 0 {
        n
    } else {
        opts.max_stages.min(n)
    };
    let global_batch = graph.global_batch;

    let mut best: Option<(f64, PlacementPlan)> = None;
    let mut dp_states: u64 = 0;
    let mut configs: u64 = 0;

    let sgs = enumerate_sg(
        &graph.tp_widths,
        &graph.ep_degrees,
        &graph.cp_degrees,
        k_total,
    );
    let mut rcs = Vec::new();
    if opts.try_no_recompute {
        rcs.push(false);
    }
    if opts.try_recompute {
        rcs.push(true);
    }

    for sg in &sgs {
        let g = sg.group_size();
        if g > k_total {
            continue;
        }
        let cm = CostModel::new(graph, cluster, *sg);
        let s_max = s_cap.min(k_total / g).min(n);
        for &rc in &rcs {
            // DP tables cached per ZeRO-degree cap (the cap depends on the
            // data-parallel width, which varies with the stage count).
            let mut tables: HashMap<usize, DpTable> = HashMap::new();
            for p in 1..=s_max {
                configs += 1;
                let d = k_total / (g * p);
                if d == 0 {
                    break;
                }
                let zero_cap = pow2_floor(d).min(opts.zero_max_degree);
                let dp = tables.entry(zero_cap).or_insert_with(|| {
                    run_dp(&cm, cluster, rc, zero_cap, s_max, &mut dp_states)
                });
                let Some((bottleneck, first_cut, first_spec)) =
                    eval_final(&cm, cluster, dp, p, rc, zero_cap)
                else {
                    continue;
                };
                if !bottleneck.is_finite() {
                    continue;
                }
                let m = global_batch.div_ceil(d * graph.mbs);
                // Gradient sync (Algorithm 1 line 25): priced on the
                // reconstructed stages' parameter volumes.
                let stages = reconstruct(&cm, cluster, dp, p, first_cut, first_spec);
                let stride = p * g;
                let sync = stages
                    .iter()
                    .map(|st| {
                        cluster.dp_allreduce(
                            cm.stage_grad_bytes(st.layers.0, st.layers.1),
                            d,
                            stride,
                        )
                    })
                    .fold(0.0, f64::max);
                let batch_time = bottleneck * (m as f64 + p as f64 - 1.0) + sync;
                if best
                    .as_ref()
                    .map(|(bt, _)| batch_time < *bt)
                    .unwrap_or(true)
                {
                    let plan = PlacementPlan {
                        model_name: graph.model_name.clone(),
                        method: "nest".into(),
                        sg: *sg,
                        stages,
                        dp_width: d,
                        mbs: graph.mbs,
                        n_microbatches: m,
                        devices_per_replica: stride,
                        bottleneck,
                        sync_time: sync,
                        batch_time,
                    };
                    best = Some((batch_time, plan));
                }
            }
        }
    }

    best.map(|(_, plan)| Solution {
        plan,
        solve_seconds: t0.elapsed().as_secs_f64(),
        dp_states,
        configs_tried: configs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;

    #[test]
    fn solves_tiny_model() {
        let g = models::tiny_transformer(6, 256, 128, 1);
        let c = Cluster::v100_cluster(8);
        let sol = solve(&g, &c, &SolverOpts::default()).expect("solution");
        sol.plan.validate(&g, &c).unwrap();
        assert!(sol.plan.batch_time > 0.0);
        assert!(sol.plan.used_devices() <= 8);
    }

    #[test]
    fn solves_bertlarge_fat_tree() {
        let g = models::bert_large(1);
        let c = Cluster::fat_tree_tpuv4(64);
        let sol = solve(&g, &c, &SolverOpts::default()).expect("solution");
        sol.plan.validate(&g, &c).unwrap();
        // BertLarge at small scale should prefer heavy data parallelism
        // (§5.2: NEST picks {1, 512, 1, 1} at 512 devices).
        assert!(sol.plan.dp_width >= sol.plan.n_stages());
    }

    #[test]
    fn llama3_on_64_needs_memory_tricks() {
        // 70B params × 16 bytes ≈ 1.1 TB of static state on 64×64 GB
        // devices → must pipeline deeply, recompute, or ZeRO.
        let g = models::llama3_70b(1);
        let c = Cluster::fat_tree_tpuv4(64);
        let sol = solve(&g, &c, &SolverOpts::default()).expect("solution");
        sol.plan.validate(&g, &c).unwrap();
        let uses_zero = sol
            .plan
            .stages
            .iter()
            .any(|s| s.mem.zero != crate::memory::ZeroStage::None);
        let uses_rc = sol.plan.stages.iter().any(|s| s.mem.recompute);
        assert!(
            sol.plan.n_stages() >= 4 || uses_zero || uses_rc,
            "plan: {}",
            sol.plan.describe()
        );
    }

    #[test]
    fn bigger_cluster_not_slower() {
        let g = models::llama2_7b(1);
        let t64 = solve(&g, &Cluster::fat_tree_tpuv4(64), &SolverOpts::default())
            .unwrap()
            .plan
            .batch_time;
        let t256 = solve(&g, &Cluster::fat_tree_tpuv4(256), &SolverOpts::default())
            .unwrap()
            .plan
            .batch_time;
        assert!(
            t256 < t64,
            "256 devices ({t256}s) should beat 64 ({t64}s)"
        );
    }

    #[test]
    fn gpt3_uses_tensor_parallelism() {
        let g = models::gpt3_175b(1);
        let c = Cluster::fat_tree_tpuv4(256);
        let sol = solve(&g, &c, &SolverOpts::default()).expect("solution");
        sol.plan.validate(&g, &c).unwrap();
        // Table 2: GPT-3 175B runs with TP 4 or 8.
        assert!(sol.plan.sg.tp >= 4, "plan: {}", sol.plan.strategy_string());
    }

    #[test]
    fn pow2_floor_values() {
        assert_eq!(pow2_floor(1), 1);
        assert_eq!(pow2_floor(2), 2);
        assert_eq!(pow2_floor(3), 2);
        assert_eq!(pow2_floor(8), 8);
        assert_eq!(pow2_floor(1000), 512);
    }

    #[test]
    fn respects_max_stages() {
        let g = models::llama2_7b(1);
        let c = Cluster::fat_tree_tpuv4(64);
        let opts = SolverOpts {
            max_stages: 2,
            ..Default::default()
        };
        let sol = solve(&g, &c, &opts).unwrap();
        assert!(sol.plan.n_stages() <= 2);
    }

    #[test]
    fn mixtral_uses_expert_parallelism() {
        let g = models::mixtral_8x7b(1);
        let c = Cluster::fat_tree_tpuv4(256);
        let sol = solve(&g, &c, &SolverOpts::default()).expect("solution");
        sol.plan.validate(&g, &c).unwrap();
        assert!(
            sol.plan.sg.ep > 1 || sol.plan.sg.cp > 1,
            "MoE plan should use EP/CP: {}",
            sol.plan.strategy_string()
        );
    }
}
