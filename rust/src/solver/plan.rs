//! Placement plans: the solver's output (§3.2 "The final output is a
//! parallelism configuration and placement plan").

use crate::cost::{CostArena, CostModel};
use crate::graph::subgraph::SgConfig;
use crate::graph::LayerGraph;
use crate::memory::MemSpec;
use crate::network::Cluster;

/// One pipeline stage of a plan.
///
/// `PartialEq` is field-for-field (exact float equality) — used by the
/// solver's thread-count-invariance tests.
#[derive(Debug, Clone, PartialEq)]
pub struct StagePlan {
    /// Layer range `[start, end)` into the model's layer chain.
    pub layers: (usize, usize),
    /// Devices of replica 0 (replica `r` adds `r · stride` to each id).
    pub devices: Vec<usize>,
    /// SUB-GRAPH config of this stage. Uniform across stages for the
    /// scalable solver; the exact solver and the Alpa baseline may vary
    /// it per stage.
    pub sg: SgConfig,
    /// Memory spec chosen for this stage (ZeRO stage + recompute).
    pub mem: MemSpec,
    /// Communication level to the *next* stage (None for the last).
    pub send_level: Option<usize>,
    /// Modeled per-microbatch latency (compute + collectives + p2p).
    pub load: f64,
    /// Accelerator classes the stage's devices (all replicas) cover,
    /// "+"-joined (e.g. `"h100"` or `"h100+v100"`): the device-class
    /// record of the heterogeneous-pool solver. Lockstep semantics mean
    /// a multi-class stage runs at its slowest listed class.
    pub accel_class: String,
}

/// A complete placement plan: SUB-GRAPH config, pipeline stages, and
/// data-parallel replication.
///
/// `PartialEq` compares every field exactly (floats included): two plans
/// are equal only if they encode the same decisions *and* the same
/// modeled costs. The solver guarantees this equality across thread
/// counts (see `solver` module docs).
#[derive(Debug, Clone, PartialEq)]
pub struct PlacementPlan {
    pub model_name: String,
    /// Which method produced it ("nest", "manual", "mcmc", ...).
    pub method: String,
    pub sg: SgConfig,
    pub stages: Vec<StagePlan>,
    /// Data-parallel width d (pipeline replicas).
    pub dp_width: usize,
    /// Microbatch size (sequences).
    pub mbs: usize,
    /// Microbatches per replica per batch: ⌈B / (d · mbs)⌉.
    pub n_microbatches: usize,
    /// Devices per pipeline replica.
    pub devices_per_replica: usize,
    /// Modeled bottleneck stage latency.
    pub bottleneck: f64,
    /// Modeled gradient-sync time (Algorithm 1 line 25).
    pub sync_time: f64,
    /// Modeled batch time: bottleneck · (m + s − 1) + sync.
    pub batch_time: f64,
}

impl PlacementPlan {
    pub fn n_stages(&self) -> usize {
        self.stages.len()
    }

    pub fn used_devices(&self) -> usize {
        self.dp_width * self.devices_per_replica
    }

    /// Samples per second at the plan's global batch size.
    pub fn throughput(&self, global_batch: usize) -> f64 {
        global_batch as f64 / self.batch_time
    }

    /// Table-2-style strategy string `{p, d, t, s, (e, c)}`.
    pub fn strategy_string(&self) -> String {
        let t = self.sg.tp;
        let s = if self.sg.sp { self.sg.tp } else { 1 };
        if self.sg.ep > 1 || self.sg.cp > 1 {
            format!(
                "{{{}, {}, {}, {}, ({}, {})}}",
                self.n_stages(),
                self.dp_width,
                t,
                s,
                self.sg.ep,
                self.sg.cp
            )
        } else {
            format!("{{{}, {}, {}, {}}}", self.n_stages(), self.dp_width, t, s)
        }
    }

    /// Validate plan invariants against the graph and cluster:
    /// full layer coverage in order, stage/replica device-disjointness,
    /// device ids in range, per-stage memory within capacity, and batch
    /// accounting. Every method's output goes through this in tests.
    pub fn validate(&self, graph: &LayerGraph, cluster: &Cluster) -> Result<(), String> {
        if self.stages.is_empty() {
            return Err("plan has no stages".into());
        }
        // Layer coverage.
        let mut expect = 0usize;
        for (k, st) in self.stages.iter().enumerate() {
            if st.layers.0 != expect {
                return Err(format!(
                    "stage {k} starts at layer {} expected {expect}",
                    st.layers.0
                ));
            }
            if st.layers.1 <= st.layers.0 {
                return Err(format!("stage {k} empty range {:?}", st.layers));
            }
            expect = st.layers.1;
        }
        if expect != graph.n_layers() {
            return Err(format!(
                "layers covered {expect} != model layers {}",
                graph.n_layers()
            ));
        }
        // Device disjointness across stages and replicas.
        if self.dp_width == 0 {
            return Err("zero data-parallel width".into());
        }
        let mut seen = std::collections::HashSet::new();
        let stride = self.devices_per_replica;
        for r in 0..self.dp_width {
            for (k, st) in self.stages.iter().enumerate() {
                if st.devices.len() != st.sg.group_size() {
                    return Err(format!(
                        "stage {k} has {} devices, sg group is {}",
                        st.devices.len(),
                        st.sg.group_size()
                    ));
                }
                for &d in &st.devices {
                    let id = d + r * stride;
                    if id >= cluster.n_devices() {
                        return Err(format!("device {id} out of range (replica {r})"));
                    }
                    if !seen.insert(id) {
                        return Err(format!("device {id} assigned twice"));
                    }
                }
            }
        }
        // Memory feasibility (Eq. 1 with each stage's own sg and spec).
        let mut cms: Vec<(SgConfig, CostModel)> = Vec::new();
        let s_total = self.n_stages();
        for (k, st) in self.stages.iter().enumerate() {
            let pos = match cms.iter().position(|(sg, _)| *sg == st.sg) {
                Some(p) => p,
                None => {
                    cms.push((st.sg, CostModel::new(graph, cluster, st.sg)));
                    cms.len() - 1
                }
            };
            let cm = &cms[pos].1;
            let stash = s_total - 1 - k; // position from pipeline end
            let peak = cm.stage_peak_bytes(st.layers.0, st.layers.1, &st.mem, stash);
            // Memory-feasible on *every* device the stage uses, replicas
            // included: heterogeneous pools bound each stage by its
            // smallest covered HBM.
            let mask =
                super::assign::stage_class_mask(cluster, &st.devices, self.dp_width, stride);
            let capacity = cluster.pool.min_capacity(mask);
            if peak > capacity * (1.0 + 1e-9) {
                return Err(format!(
                    "stage {k} peak {} exceeds capacity {} of its weakest device \
                     (classes {})",
                    crate::util::table::fmt_bytes(peak),
                    crate::util::table::fmt_bytes(capacity),
                    cluster.pool.class_names(mask)
                ));
            }
            if st.mem.zero.degree() > self.dp_width {
                return Err(format!(
                    "stage {k} ZeRO degree {} exceeds dp width {}",
                    st.mem.zero.degree(),
                    self.dp_width
                ));
            }
        }
        // Batch accounting.
        if self.used_devices() > cluster.n_devices() {
            return Err("plan uses more devices than the cluster has".into());
        }
        if self.n_microbatches == 0 {
            return Err("zero microbatches".into());
        }
        Ok(())
    }

    /// Machine-readable plan export (the artifact's "final output is a
    /// parallelism configuration and placement plan", §3.2) — consumable
    /// by downstream launchers (Megatron/NeMo-style configs).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let stage_json = |st: &StagePlan| {
            Json::obj(vec![
                ("layers", Json::arr(vec![
                    Json::num(st.layers.0 as f64),
                    Json::num(st.layers.1 as f64),
                ])),
                ("devices", Json::arr(
                    st.devices.iter().map(|&d| Json::num(d as f64)).collect(),
                )),
                ("tp", Json::num(st.sg.tp as f64)),
                ("sp", Json::Bool(st.sg.sp)),
                ("ep", Json::num(st.sg.ep as f64)),
                ("cp", Json::num(st.sg.cp as f64)),
                ("zero", Json::str(st.mem.zero.describe())),
                ("zero_degree", Json::num(st.mem.zero.degree() as f64)),
                ("recompute", Json::Bool(st.mem.recompute)),
                ("accel_class", Json::str(st.accel_class.clone())),
                (
                    "send_level",
                    st.send_level
                        .map(|l| Json::num(l as f64))
                        .unwrap_or(Json::Null),
                ),
                ("load_seconds", Json::num(st.load)),
            ])
        };
        Json::obj(vec![
            ("model", Json::str(self.model_name.clone())),
            ("method", Json::str(self.method.clone())),
            ("strategy", Json::str(self.strategy_string())),
            ("pipeline_stages", Json::num(self.n_stages() as f64)),
            ("data_parallel", Json::num(self.dp_width as f64)),
            ("microbatch_size", Json::num(self.mbs as f64)),
            ("n_microbatches", Json::num(self.n_microbatches as f64)),
            ("devices_per_replica", Json::num(self.devices_per_replica as f64)),
            ("bottleneck_seconds", Json::num(self.bottleneck)),
            ("sync_seconds", Json::num(self.sync_time)),
            ("batch_seconds", Json::num(self.batch_time)),
            ("stages", Json::arr(self.stages.iter().map(stage_json).collect())),
        ])
    }

    /// Long-form human-readable description.
    pub fn describe(&self) -> String {
        let mut out = format!(
            "{} on {} [{}]: {} — {} stages × {} devices × d={} ({} of cluster devices used)\n",
            self.model_name,
            self.method,
            self.sg.describe(),
            self.strategy_string(),
            self.n_stages(),
            self.sg.group_size(),
            self.dp_width,
            self.used_devices(),
        );
        for (k, st) in self.stages.iter().enumerate() {
            out.push_str(&format!(
                "  stage {k:3}: layers [{:3}, {:3}) load={} mem={}{} dev[0]={} [{}]\n",
                st.layers.0,
                st.layers.1,
                crate::util::table::fmt_time(st.load),
                st.mem.zero.describe(),
                if st.mem.recompute { "+AR" } else { "" },
                st.devices.first().copied().unwrap_or(0),
                st.accel_class,
            ));
        }
        out.push_str(&format!(
            "  bottleneck={} sync={} batch={}",
            crate::util::table::fmt_time(self.bottleneck),
            crate::util::table::fmt_time(self.sync_time),
            crate::util::table::fmt_time(self.batch_time)
        ));
        out
    }
}

/// One stage of a re-solved plan whose physical placement changed
/// relative to the previous plan (an elasticity event re-homed it).
#[derive(Debug, Clone, PartialEq)]
pub struct StageMove {
    /// Stage index in the *new* plan.
    pub stage: usize,
    pub layers: (usize, usize),
    /// First device (replica 0) of the old stage that held this stage's
    /// leading layer; `None` when the old plan had no stage starting a
    /// comparable range (the whole pipeline was recut).
    pub from_device: Option<usize>,
    /// First device (replica 0) of the stage in the new plan.
    pub to_device: usize,
    /// Weight bytes that must land on the stage's devices, replicas
    /// included (`per-device shard × group × dp width`).
    pub param_bytes: f64,
    /// Slowest single shard pull for this stage, priced through the
    /// cluster's α–β levels.
    pub seconds: f64,
}

/// What changed between two plans for the same graph: the stages whose
/// device ranges moved, the parameter bytes that must migrate, and the
/// migration time priced through [`Cluster`].
///
/// The migration model is deliberately simple: every device of a moved
/// stage pulls its weight shard point-to-point from the shard's old
/// home, all pulls proceed in parallel, so the migration time is the
/// slowest single pull (`max` over moved stages). Levels come from the
/// lowest common tier of the old and new leading devices.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanDelta {
    /// Moves in new-plan stage order.
    pub moved: Vec<StageMove>,
    /// Stages of the new plan that kept layers, devices, sub-graph
    /// config, memory spec, and replication intact.
    pub unchanged: usize,
    /// Total weight bytes across all moved stages (all replicas).
    pub param_bytes: f64,
    /// Modeled migration time (seconds); 0.0 when nothing moved.
    pub migration_seconds: f64,
}

impl PlanDelta {
    pub fn is_noop(&self) -> bool {
        self.moved.is_empty()
    }

    /// One-line summary for tables and logs.
    pub fn describe(&self) -> String {
        format!(
            "{} moved / {} unchanged stages, {} to migrate, {}",
            self.moved.len(),
            self.unchanged,
            crate::util::table::fmt_bytes(self.param_bytes),
            crate::util::table::fmt_time(self.migration_seconds),
        )
    }
}

/// Lowest common tier of devices `a` and `b` under compact packing: the
/// innermost level whose subtree contains both. Device ids past the
/// cluster's edge (a source that *failed* out of the pool) resolve to
/// the subtree they would occupy, which lands the transfer on the
/// outermost shared tier — the conservative choice.
fn lca_level(cluster: &Cluster, a: usize, b: usize) -> usize {
    if a == b {
        return 0;
    }
    for l in 0..cluster.n_levels() {
        if a / cluster.capacity(l) == b / cluster.capacity(l) {
            return l;
        }
    }
    cluster.n_levels() - 1
}

/// Diff `new` against `old` for the same `graph`, pricing the migration
/// on `cluster` (the cluster the *new* plan runs on). See [`PlanDelta`]
/// for the migration model. Any change to the replication layout
/// (`dp_width` / `devices_per_replica`) moves every stage: replica
/// weights live at `devices + r·stride`, so a stride change re-homes
/// every copy even when replica 0 stands still.
pub fn diff_plans(
    old: &PlacementPlan,
    new: &PlacementPlan,
    graph: &LayerGraph,
    cluster: &Cluster,
) -> PlanDelta {
    diff_plans_in(&mut CostArena::new(), 0, old, new, graph, cluster)
}

/// [`diff_plans`] pricing through a caller-held [`CostArena`], so
/// repeated reconciles of the same (graph, cluster) context (keyed by
/// `key`, the caller's content fingerprint) reuse per-strategy cost
/// tables instead of rebuilding them per diff.
pub fn diff_plans_in(
    arena: &mut CostArena,
    key: u64,
    old: &PlacementPlan,
    new: &PlacementPlan,
    graph: &LayerGraph,
    cluster: &Cluster,
) -> PlanDelta {
    let replication_changed =
        old.dp_width != new.dp_width || old.devices_per_replica != new.devices_per_replica;
    let mut moved = Vec::new();
    let mut total_bytes = 0.0;
    let mut migration = 0.0f64;
    for (k, st) in new.stages.iter().enumerate() {
        let unchanged = !replication_changed
            && old.stages.iter().any(|o| {
                o.layers == st.layers
                    && o.devices == st.devices
                    && o.sg == st.sg
                    && o.mem == st.mem
            });
        if unchanged {
            continue;
        }
        let cm = arena.get(key, graph, cluster, st.sg);
        // Per-device weight shard of the stage's layer range, and the
        // full footprint across the group and every replica.
        let shard_bytes = cm.stage_params(st.layers.0, st.layers.1) * crate::memory::WEIGHT_BYTES;
        let stage_bytes = shard_bytes * st.sg.group_size() as f64 * new.dp_width as f64;
        let to_device = st.devices.first().copied().unwrap_or(0);
        // The shard's old home: the old stage that held this range's
        // leading layer.
        let from_device = old
            .stages
            .iter()
            .find(|o| o.layers.0 <= st.layers.0 && st.layers.0 < o.layers.1)
            .and_then(|o| o.devices.first().copied());
        let level = lca_level(cluster, from_device.unwrap_or(to_device), to_device);
        let seconds = cluster.p2p_time(level, shard_bytes);
        total_bytes += stage_bytes;
        migration = migration.max(seconds);
        moved.push(StageMove {
            stage: k,
            layers: st.layers,
            from_device,
            to_device,
            param_bytes: stage_bytes,
            seconds,
        });
    }
    PlanDelta {
        unchanged: new.stages.len() - moved.len(),
        moved,
        param_bytes: total_bytes,
        migration_seconds: migration,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::memory::MemSpec;

    fn mini_plan() -> (LayerGraph, Cluster, PlacementPlan) {
        let g = models::tiny_transformer(6, 256, 128, 1);
        let c = Cluster::v100_cluster(8);
        let plan = PlacementPlan {
            model_name: g.model_name.clone(),
            method: "test".into(),
            sg: SgConfig::serial(),
            stages: vec![
                StagePlan {
                    layers: (0, 4),
                    devices: vec![1],
                    sg: SgConfig::serial(),
                    mem: MemSpec::plain(),
                    send_level: Some(0),
                    load: 1.0,
                    accel_class: "v100".into(),
                },
                StagePlan {
                    layers: (4, 8),
                    devices: vec![0],
                    sg: SgConfig::serial(),
                    mem: MemSpec::plain(),
                    send_level: None,
                    load: 1.0,
                    accel_class: "v100".into(),
                },
            ],
            dp_width: 2,
            mbs: 1,
            n_microbatches: 4,
            devices_per_replica: 2,
            bottleneck: 1.0,
            sync_time: 0.1,
            batch_time: 1.0 * (4.0 + 1.0) + 0.1,
        };
        (g, c, plan)
    }

    #[test]
    fn valid_plan_passes() {
        let (g, c, plan) = mini_plan();
        plan.validate(&g, &c).unwrap();
    }

    #[test]
    fn detects_gap_in_layers() {
        let (g, c, mut plan) = mini_plan();
        plan.stages[1].layers = (5, 8);
        assert!(plan.validate(&g, &c).is_err());
    }

    #[test]
    fn detects_device_reuse() {
        let (g, c, mut plan) = mini_plan();
        plan.stages[1].devices = vec![1];
        assert!(plan.validate(&g, &c).is_err());
    }

    #[test]
    fn detects_overflow_dp() {
        let (g, c, mut plan) = mini_plan();
        plan.dp_width = 8; // 8 replicas × 2 devices > 8 devices
        assert!(plan.validate(&g, &c).is_err());
    }

    #[test]
    fn strategy_string_formats() {
        let (_, _, mut plan) = mini_plan();
        assert_eq!(plan.strategy_string(), "{2, 2, 1, 1}");
        plan.sg.ep = 4;
        assert_eq!(plan.strategy_string(), "{2, 2, 1, 1, (4, 1)}");
    }

    #[test]
    fn throughput_is_batch_over_time() {
        let (_, _, plan) = mini_plan();
        let t = plan.throughput(4096);
        assert!((t - 4096.0 / plan.batch_time).abs() < 1e-9);
    }

    #[test]
    fn json_export_roundtrips() {
        let (_, _, plan) = mini_plan();
        let j = plan.to_json();
        let text = crate::util::json::to_pretty(&j);
        let parsed = crate::util::json::parse(&text).unwrap();
        assert_eq!(parsed.get("strategy").as_str().unwrap(), "{2, 2, 1, 1}");
        assert_eq!(parsed.get("pipeline_stages").as_usize(), Some(2));
        assert_eq!(parsed.get("stages").as_arr().unwrap().len(), 2);
        assert_eq!(
            parsed.get("stages").idx(0).get("layers").idx(1).as_usize(),
            Some(4)
        );
        assert_eq!(
            parsed.get("stages").idx(1).get("send_level"),
            &crate::util::json::Json::Null
        );
    }

    #[test]
    fn diff_of_identical_plans_is_noop() {
        let (g, c, plan) = mini_plan();
        let delta = diff_plans(&plan, &plan, &g, &c);
        assert!(delta.is_noop());
        assert_eq!(delta.unchanged, plan.n_stages());
        assert_eq!(delta.param_bytes, 0.0);
        assert_eq!(delta.migration_seconds, 0.0);
    }

    #[test]
    fn diff_prices_a_moved_stage() {
        let (g, c, plan) = mini_plan();
        let mut moved = plan.clone();
        moved.stages[0].devices = vec![3];
        let delta = diff_plans(&plan, &moved, &g, &c);
        assert_eq!(delta.moved.len(), 1);
        assert_eq!(delta.unchanged, 1);
        let mv = &delta.moved[0];
        assert_eq!(mv.stage, 0);
        assert_eq!(mv.from_device, Some(1));
        assert_eq!(mv.to_device, 3);
        assert!(mv.param_bytes > 0.0, "weights must migrate");
        assert!(delta.migration_seconds > 0.0, "migration is never free");
        assert!(delta.describe().contains("1 moved"));
    }

    #[test]
    fn replication_change_moves_every_stage() {
        // A narrower dp width keeps replica 0 in place but re-homes
        // every other replica's weights — all stages count as moved.
        let (g, c, plan) = mini_plan();
        let mut resized = plan.clone();
        resized.dp_width = 1;
        let delta = diff_plans(&plan, &resized, &g, &c);
        assert_eq!(delta.moved.len(), plan.n_stages());
        assert_eq!(delta.unchanged, 0);
        assert!(delta.migration_seconds > 0.0);
    }

    #[test]
    fn lca_level_shared_and_disjoint_subtrees() {
        let c = Cluster::v100_cluster(16); // capacities [2, 16]
        assert_eq!(lca_level(&c, 3, 3), 0);
        assert_eq!(lca_level(&c, 0, 1), 0); // same 2-wide node
        assert_eq!(lca_level(&c, 0, 2), 1); // across nodes
        // A failed source past the cluster edge resolves conservatively
        // to the outermost tier.
        assert_eq!(lca_level(&c, 17, 0), 1);
    }
}
