//! Exact small-cluster solver: the full Algorithm 1 state space
//! `dp[l][D][k][s]` with per-stage device allocations and per-stage
//! SUB-GRAPH configurations.
//!
//! The scalable solver in [`super`] fixes a uniform SUB-GRAPH config per
//! plan (matching the paper's evaluated strategies); this module keeps
//! the paper's full generality — each stage independently picks its
//! allocation `a` from the valid SUB-GRAPH group sizes — which matters on
//! the small §5.4 validation clusters (8/16 V100s) where e.g. the
//! embedding stage wants 1 device while block stages want 2. Under
//! compact tail-first packing the producer-boundary level of a suffix
//! that occupies `k` devices is `boundary_level(k)` — the level-wise
//! state `l` of Eq. 3 realized exactly (see `assign.rs`).
//!
//! Complexity is `O(L² · K² · S · |sg|)`; guarded to K ≤ 64. Tests
//! cross-check against brute-force enumeration on tiny instances,
//! providing the paper's "provable optimality" evidence for our
//! implementation.
//!
//! Like the scalable solver, the DP is multi-threaded
//! ([`ExactOpts::threads`], 0 = one per core): within a stage-count
//! layer `s`, states `(i, k, s)` only read layer `s−1`, so the device
//! counts `k` fan out over scoped workers whose results merge before the
//! next layer. States are computed identically regardless of scheduling,
//! so the result is deterministic and thread-count-invariant.
//!
//! Unlike the scalable solver, this DP takes no
//! [`super::SolverOpts::warm_start`] hint: it has no incumbent to
//! tighten — every state is materialized unconditionally (no pruning),
//! so evaluation order cannot change the work done, and a warm start
//! would be a no-op by construction. The service layer therefore only
//! warm-starts the scalable path.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Instant;

use crate::cost::{CostModel, PricingMode, RangePricer};
use crate::graph::subgraph::{enumerate_sg, SgConfig};
use crate::graph::LayerGraph;
use crate::memory::MemSpec;
use crate::network::Cluster;

use super::assign::boundary_level;
use super::plan::{PlacementPlan, StagePlan};
use super::{resolve_threads, Solution};

/// Options for the exact solver.
#[derive(Debug, Clone)]
pub struct ExactOpts {
    pub max_stages: usize,
    pub zero_max_degree: usize,
    pub recompute: bool,
    /// Data-parallel replication of the resulting pipeline (1 = use the
    /// whole cluster for one pipeline).
    pub dp_width: usize,
    /// Worker threads for the per-layer DP fan-out (0 = one per core).
    /// Deterministic: the plan is identical for every thread count.
    pub threads: usize,
    /// Pricing implementation for the per-config cost models (`Auto` =
    /// `NEST_REFERENCE` env); bit-identical either way.
    pub pricing: PricingMode,
}

impl Default for ExactOpts {
    fn default() -> Self {
        ExactOpts {
            max_stages: 8,
            zero_max_degree: 8,
            recompute: false,
            dp_width: 1,
            threads: 0,
            pricing: PricingMode::Auto,
        }
    }
}

#[derive(Clone, Copy)]
struct Back {
    cut: u32,
    alloc: u16,
    sg_idx: u16,
    spec: MemSpec,
}

type DpMap = HashMap<(usize, usize, usize), (f64, Back)>;
type DpEntry = ((usize, usize, usize), (f64, Back));

/// Compute every layer-`s` state for one device count `k`. Reads only
/// layer `s−1` of `dp`, so calls for different `k` are independent — the
/// parallel fan-out below relies on exactly this.
///
/// `d`/`stride` describe the data-parallel replication of the replica
/// being solved (replica `r` shifts every device by `r·stride`): the
/// stage occupying `[k−a, k)` prices compute on the slowest accelerator
/// class its replicated coverage touches and checks memory against the
/// smallest covered HBM (heterogeneous pools; single-class pools see
/// the old behavior).
#[allow(clippy::too_many_arguments)]
fn layer_states_for_k(
    n: usize,
    cluster: &Cluster,
    cms: &[CostModel],
    dp: &DpMap,
    d: usize,
    stride: usize,
    zero_cap: usize,
    recompute: bool,
    s: usize,
    k: usize,
    states: &mut u64,
    out: &mut Vec<DpEntry>,
) {
    let l_recv = boundary_level(cluster, k);
    // Per SUB-GRAPH config: the block [k−a, k)'s class coverage, memory
    // bound, resolved pricer, and send boundary level (all invariant
    // over the layer loop — hoisted out of the O(n²) scans).
    let ctxs: Vec<Option<(RangePricer, f64, Option<usize>)>> = cms
        .iter()
        .map(|cm| {
            let a = cm.group;
            if a > k {
                return None;
            }
            let mask = cluster.pool.replicated_mask(k - a, k, d, stride);
            let l_send = if s > 1 {
                Some(boundary_level(cluster, k - a))
            } else {
                None
            };
            Some((cm.pricer(mask), cluster.pool.min_capacity(mask), l_send))
        })
        .collect();
    for i in (0..n).rev() {
        if n - i < s {
            continue;
        }
        let mut best: Option<(f64, Back)> = None;
        for (ci, cm) in cms.iter().enumerate() {
            let a = cm.group;
            // The last stage may leave an idle tail (a < k); middle
            // stages must leave at least one device per remaining stage.
            if a > k || (s > 1 && k - a < s - 1) {
                continue;
            }
            let (pricer, cap, l_send) = ctxs[ci].expect("ctx exists when a <= k");
            let stash = s - 1;
            if s == 1 {
                let Some(spec) = cm.stage_choose_spec(i, n, stash, cap, zero_cap, recompute)
                else {
                    continue;
                };
                let load =
                    cm.stage_load_priced(&pricer, i, n, Some(l_recv), None, &spec, cluster);
                *states += 1;
                if best.map(|(b, _)| load < b).unwrap_or(true) {
                    best = Some((
                        load,
                        Back {
                            cut: n as u32,
                            alloc: a as u16,
                            sg_idx: ci as u16,
                            spec,
                        },
                    ));
                }
                continue;
            }
            for j in (i + 1)..=(n - (s - 1)) {
                let Some(&(rest, _)) = dp.get(&(j, k - a, s - 1)) else {
                    continue;
                };
                let Some(spec) = cm.stage_choose_spec(i, j, stash, cap, zero_cap, recompute)
                else {
                    break; // memory monotone in j
                };
                let load =
                    cm.stage_load_priced(&pricer, i, j, Some(l_recv), l_send, &spec, cluster);
                *states += 1;
                let cand = load.max(rest);
                if best.map(|(b, _)| cand < b).unwrap_or(true) {
                    best = Some((
                        cand,
                        Back {
                            cut: j as u32,
                            alloc: a as u16,
                            sg_idx: ci as u16,
                            spec,
                        },
                    ));
                }
            }
        }
        if let Some(b) = best {
            out.push(((i, k, s), b));
        }
    }
}

/// Solve with the exact per-stage-allocation DP. `cluster` devices are
/// split into `dp_width` replicas of `K/dp_width` devices each.
pub fn solve_exact(graph: &LayerGraph, cluster: &Cluster, opts: &ExactOpts) -> Option<Solution> {
    let t0 = Instant::now();
    let k_rep = cluster.n_devices() / opts.dp_width.max(1);
    assert!(
        k_rep <= 64,
        "exact solver is O(L²K²S); use solver::solve beyond 64 devices/replica"
    );
    let n = graph.n_layers();
    let s_max = opts.max_stages.min(n).min(k_rep);
    let d = opts.dp_width.max(1);
    let zero_cap = super::pow2_floor(opts.dp_width).min(opts.zero_max_degree);

    // Candidate SUB-GRAPH configs and their cost models.
    let sgs: Vec<SgConfig> = enumerate_sg(
        &graph.tp_widths,
        &graph.ep_degrees,
        &graph.cp_degrees,
        k_rep,
    );
    let cms: Vec<CostModel> = sgs
        .iter()
        .map(|sg| CostModel::with_mode(graph, cluster, *sg, opts.pricing))
        .collect();

    // dp[(i, k, s)] = min bottleneck for suffix [i, n) on k tail devices
    // in s stages, including the producer edge at boundary_level(k).
    // Layer s reads only layer s−1, so each layer's device counts fan out
    // over scoped workers; entries merge before the next layer starts.
    let mut dp: DpMap = HashMap::new();
    let mut states: u64 = 0;
    let recompute = opts.recompute;

    for s in 1..=s_max {
        let ks: Vec<usize> = (s..=k_rep).collect();
        let n_threads = if ks.len() >= 4 {
            resolve_threads(opts.threads).min(ks.len())
        } else {
            1
        };
        if n_threads <= 1 {
            let mut entries: Vec<DpEntry> = Vec::new();
            for &k in &ks {
                layer_states_for_k(
                    n, cluster, &cms, &dp, d, k_rep, zero_cap, recompute, s, k, &mut states,
                    &mut entries,
                );
            }
            dp.extend(entries);
        } else {
            let next = AtomicUsize::new(0);
            let mut merged: Vec<(Vec<DpEntry>, u64)> = Vec::with_capacity(n_threads);
            std::thread::scope(|scope| {
                let dp_ref = &dp;
                let cms_ref = &cms;
                let ks_ref = &ks;
                let next_ref = &next;
                let handles: Vec<_> = (0..n_threads)
                    .map(|_| {
                        scope.spawn(move || {
                            let mut local: Vec<DpEntry> = Vec::new();
                            let mut st = 0u64;
                            loop {
                                let idx = next_ref.fetch_add(1, Ordering::Relaxed);
                                if idx >= ks_ref.len() {
                                    break;
                                }
                                layer_states_for_k(
                                    n,
                                    cluster,
                                    cms_ref,
                                    dp_ref,
                                    d,
                                    k_rep,
                                    zero_cap,
                                    recompute,
                                    s,
                                    ks_ref[idx],
                                    &mut st,
                                    &mut local,
                                );
                            }
                            (local, st)
                        })
                    })
                    .collect();
                for h in handles {
                    merged.push(h.join().expect("exact solver worker panicked"));
                }
            });
            for (entries, st) in merged {
                states += st;
                dp.extend(entries);
            }
        }
    }

    // Final pass: first stage has no producer edge (Algorithm 1 l.19–31).
    let mut best_final: Option<(f64, usize, usize, Back)> = None; // (batch, p, k, first)
    for p in 1..=s_max {
        for k in p..=k_rep {
            for (ci, cm) in cms.iter().enumerate() {
                let a = cm.group;
                if a > k || (p > 1 && k - a < p - 1) {
                    continue;
                }
                let stash = p - 1;
                let l_send = if p > 1 {
                    Some(boundary_level(cluster, k - a))
                } else {
                    None
                };
                // The first stage occupies the top block [k−a, k).
                let mask = cluster.pool.replicated_mask(k - a, k, d, k_rep);
                let fcap = cluster.pool.min_capacity(mask);
                let pricer = cm.pricer(mask);
                let eval = |j: usize, rest: f64| -> Option<(f64, Back)> {
                    let spec =
                        cm.stage_choose_spec(0, j, stash, fcap, zero_cap, opts.recompute)?;
                    let load = cm.stage_load_priced(&pricer, 0, j, None, l_send, &spec, cluster);
                    Some((
                        load.max(rest),
                        Back {
                            cut: j as u32,
                            alloc: a as u16,
                            sg_idx: ci as u16,
                            spec,
                        },
                    ))
                };
                let candidates: Vec<(f64, Back)> = if p == 1 {
                    eval(n, 0.0).into_iter().collect()
                } else {
                    (1..=(n - (p - 1)))
                        .filter_map(|j| {
                            dp.get(&(j, k - a, p - 1))
                                .and_then(|&(rest, _)| eval(j, rest))
                        })
                        .collect()
                };
                for (bottleneck, back) in candidates {
                    let m = graph.global_batch.div_ceil(d * graph.mbs);
                    let sync_stride = k_rep;
                    let sync = cluster.dp_allreduce(
                        cms[back.sg_idx as usize]
                            .stage_grad_bytes(0, back.cut as usize),
                        d,
                        sync_stride,
                    );
                    let batch = bottleneck * (m as f64 + p as f64 - 1.0) + sync;
                    if best_final
                        .map(|(b, _, _, _)| batch < b)
                        .unwrap_or(true)
                    {
                        best_final = Some((batch, p, k, back));
                    }
                }
            }
        }
    }

    let (batch_time, p, k_used, first) = best_final?;

    // Reconstruct stages front-to-back.
    let mut stages: Vec<StagePlan> = Vec::with_capacity(p);
    let mut i = 0usize;
    let mut k = k_used;
    let mut back = first;
    for stage_idx in 0..p {
        let cm = &cms[back.sg_idx as usize];
        let a = back.alloc as usize;
        let j = back.cut as usize;
        // Tail-first compact packing: this stage occupies [k-a, k).
        let devices: Vec<usize> = ((k - a)..k).collect();
        let send_level = if stage_idx + 1 < p {
            Some(boundary_level(cluster, k - a))
        } else {
            None
        };
        let recv_level = if stage_idx > 0 {
            Some(boundary_level(cluster, k))
        } else {
            None
        };
        let mask = cluster.pool.replicated_mask(k - a, k, d, k_rep);
        let load = cm.stage_load_on(mask, i, j, recv_level, send_level, &back.spec, cluster);
        stages.push(StagePlan {
            layers: (i, j),
            devices,
            sg: cm.sg,
            mem: back.spec,
            send_level,
            load,
            accel_class: cluster.pool.class_names(mask),
        });
        k -= a;
        i = j;
        if stage_idx + 1 < p {
            back = dp
                .get(&(i, k, p - 1 - stage_idx))
                .expect("backpointer chain broken")
                .1;
        }
    }

    let bottleneck = stages.iter().map(|s| s.load).fold(0.0, f64::max);
    let m = graph.global_batch.div_ceil(d * graph.mbs);
    let sync = batch_time - bottleneck * (m as f64 + p as f64 - 1.0);
    let plan = PlacementPlan {
        model_name: graph.model_name.clone(),
        method: "nest-exact".into(),
        sg: stages
            .iter()
            .map(|s| s.sg)
            .max_by_key(|sg| sg.group_size())
            .unwrap(),
        stages,
        dp_width: d,
        mbs: graph.mbs,
        n_microbatches: m,
        devices_per_replica: k_rep,
        bottleneck,
        sync_time: sync.max(0.0),
        batch_time,
    };
    Some(Solution {
        plan,
        solve_seconds: t0.elapsed().as_secs_f64(),
        dp_states: states,
        configs_tried: sgs.len() as u64,
    })
}

/// Brute-force reference: enumerate every (stage count, cut combination,
/// per-stage sg) under compact packing and return the best batch time.
/// Exponential — only for tiny test instances.
pub fn brute_force_batch_time(
    graph: &LayerGraph,
    cluster: &Cluster,
    opts: &ExactOpts,
) -> Option<f64> {
    let k_rep = cluster.n_devices() / opts.dp_width.max(1);
    let n = graph.n_layers();
    assert!(n <= 10 && k_rep <= 8, "brute force is exponential");
    let d = opts.dp_width.max(1);
    let zero_cap = super::pow2_floor(opts.dp_width).min(opts.zero_max_degree);
    let sgs = enumerate_sg(
        &graph.tp_widths,
        &graph.ep_degrees,
        &graph.cp_degrees,
        k_rep,
    );
    let cms: Vec<CostModel> = sgs
        .iter()
        .map(|sg| CostModel::with_mode(graph, cluster, *sg, opts.pricing))
        .collect();

    let mut best: Option<f64> = None;
    let s_max = opts.max_stages.min(n).min(k_rep);
    // Enumerate cut vectors via bitmasks over n-1 cut positions.
    for mask in 0u32..(1 << (n - 1)) {
        let p = mask.count_ones() as usize + 1;
        if p > s_max {
            continue;
        }
        let mut cuts = vec![0usize];
        for b in 0..(n - 1) {
            if mask & (1 << b) != 0 {
                cuts.push(b + 1);
            }
        }
        cuts.push(n);
        // Enumerate per-stage sg assignment.
        let mut sg_choice = vec![0usize; p];
        loop {
            let total_devices: usize = sg_choice.iter().map(|&c| cms[c].group).sum();
            if total_devices <= k_rep {
                // Evaluate under tail-first packing.
                let mut offsets = vec![0usize; p + 1];
                for idx in (0..p).rev() {
                    offsets[idx] = offsets[idx + 1] + cms[sg_choice[idx]].group;
                }
                let mut bottleneck: f64 = 0.0;
                let mut feasible = true;
                let mut sync: f64 = 0.0;
                for idx in 0..p {
                    let cm = &cms[sg_choice[idx]];
                    let (i, j) = (cuts[idx], cuts[idx + 1]);
                    let stash = p - 1 - idx;
                    // Stage idx occupies [offsets[idx+1], offsets[idx]).
                    let mask = cluster.pool.replicated_mask(
                        offsets[idx + 1],
                        offsets[idx],
                        d,
                        k_rep,
                    );
                    let cap = cluster.pool.min_capacity(mask);
                    let Some(spec) =
                        cm.stage_choose_spec(i, j, stash, cap, zero_cap, opts.recompute)
                    else {
                        feasible = false;
                        break;
                    };
                    let recv = if idx > 0 {
                        Some(boundary_level(cluster, offsets[idx]))
                    } else {
                        None
                    };
                    let send = if idx + 1 < p {
                        Some(boundary_level(cluster, offsets[idx + 1]))
                    } else {
                        None
                    };
                    bottleneck = bottleneck
                        .max(cm.stage_load_on(mask, i, j, recv, send, &spec, cluster));
                    if idx == 0 {
                        sync = cluster.dp_allreduce(
                            cm.stage_grad_bytes(i, j),
                            opts.dp_width,
                            k_rep,
                        );
                    }
                }
                if feasible {
                    let m = graph.global_batch.div_ceil(opts.dp_width * graph.mbs);
                    let batch = bottleneck * (m as f64 + p as f64 - 1.0) + sync;
                    if best.map(|b| batch < b).unwrap_or(true) {
                        best = Some(batch);
                    }
                }
            }
            // Next sg assignment.
            let mut carry = true;
            for slot in sg_choice.iter_mut() {
                if carry {
                    *slot += 1;
                    if *slot == cms.len() {
                        *slot = 0;
                    } else {
                        carry = false;
                    }
                }
            }
            if carry {
                break;
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::util::prop;

    #[test]
    fn exact_solves_and_validates() {
        let g = models::tiny_transformer(6, 256, 128, 1);
        let c = Cluster::v100_cluster(8);
        let sol = solve_exact(&g, &c, &ExactOpts::default()).expect("solution");
        sol.plan.validate(&g, &c).unwrap();
        assert!(sol.plan.batch_time.is_finite());
    }

    #[test]
    fn exact_matches_brute_force() {
        // The optimality cross-check: on tiny instances the DP must equal
        // exhaustive enumeration.
        let g = models::tiny_transformer(4, 128, 64, 1);
        let c = Cluster::v100_cluster(4);
        let opts = ExactOpts {
            max_stages: 4,
            ..Default::default()
        };
        let dp = solve_exact(&g, &c, &opts).unwrap().plan.batch_time;
        let bf = brute_force_batch_time(&g, &c, &opts).unwrap();
        assert!(
            (dp - bf).abs() / bf < 1e-9,
            "dp {dp} != brute force {bf}"
        );
    }

    #[test]
    fn prop_exact_matches_brute_force_random() {
        prop::forall(8, 0xDEC0DE, |rng| {
            let n_blocks = 2 + rng.gen_range(4); // 2..5 blocks (+emb+head)
            let hidden = 128 * (1 + rng.gen_range(2));
            let g = models::tiny_transformer(n_blocks, hidden, 64, 1);
            let devices = [2usize, 4, 8][rng.gen_range(3)];
            let c = Cluster::v100_cluster(devices);
            let opts = ExactOpts {
                max_stages: 4,
                recompute: rng.gen_bool(0.5),
                ..Default::default()
            };
            let dp = solve_exact(&g, &c, &opts).map(|s| s.plan.batch_time);
            let bf = brute_force_batch_time(&g, &c, &opts);
            match (dp, bf) {
                (Some(a), Some(b)) => {
                    assert!((a - b).abs() / b < 1e-9, "dp {a} bf {b}");
                }
                (None, None) => {}
                (a, b) => panic!("feasibility mismatch: dp={a:?} bf={b:?}"),
            }
        });
    }

    #[test]
    fn exact_beats_or_ties_uniform() {
        // The exact solver explores a superset of the uniform solver's
        // space at equal dp_width, so it can only be ≤.
        let g = models::mixtral_scaled(1);
        let c = Cluster::v100_cluster(8);
        let uni = super::super::solve(&g, &c, &super::super::SolverOpts::default()).unwrap();
        let opts = ExactOpts {
            max_stages: 8,
            dp_width: uni.plan.dp_width,
            recompute: uni.plan.stages[0].mem.recompute,
            ..Default::default()
        };
        let ex = solve_exact(&g, &c, &opts).unwrap();
        ex.plan.validate(&g, &c).unwrap();
        assert!(
            ex.plan.batch_time <= uni.plan.batch_time * (1.0 + 1e-9),
            "exact {} > uniform {}",
            ex.plan.batch_time,
            uni.plan.batch_time
        );
    }
}
