//! Concrete device assignment: realizing DP placements on the cluster.
//!
//! The uniform-group solver lays each pipeline replica out *compactly and
//! tail-first*: the last pipeline stage occupies device block `[0, g)`,
//! the stage `b` blocks from the end occupies `[b·g, (b+1)·g)`, and
//! replica `r` shifts everything by `r · s_total · g`. Under this layout
//! the communication level of every stage boundary is a pure function of
//! its device offset — `boundary_level` — which is what lets the DP know
//! forward-edge costs exactly while it recurses backward from the last
//! stage (the paper's "deferred forward cost", §4).

use crate::hw::ClassMask;
use crate::network::Cluster;

/// Accelerator classes covered by a realized stage: its device list
/// plus every data-parallel replica (`replica r` adds `r·stride`).
/// This is the lockstep group the cost model prices — the simulators
/// and plan validation all derive per-stage classes through here.
pub fn stage_class_mask(
    cluster: &Cluster,
    devices: &[usize],
    d: usize,
    stride: usize,
) -> ClassMask {
    cluster.pool.devices_mask(devices, d.max(1), stride)
}

/// Communication level crossed by the boundary between device `offset−1`
/// and device `offset` under compact packing: the innermost tier whose
/// group size does *not* divide the offset. Example for tier capacities
/// `[8, 32, 1024]`: offset 4 → level 0 (intra-node), offset 8 → level 1
/// (node boundary), offset 32 → level 2 (rack boundary).
pub fn boundary_level(cluster: &Cluster, offset: usize) -> usize {
    cluster.boundary_level(offset)
}

/// Device ids of the stage `blocks_from_end` blocks from the pipeline
/// end, for a group of `g` devices (replica 0).
pub fn stage_devices(blocks_from_end: usize, g: usize) -> Vec<usize> {
    let base = blocks_from_end * g;
    (base..base + g).collect()
}

/// Minimum realizable send level between a stage and a suffix of
/// `suffix_stages` stages of `g` devices each: the boundary sits at
/// offset `suffix_stages · g`.
pub fn min_send_level(cluster: &Cluster, suffix_stages: usize, g: usize) -> usize {
    boundary_level(cluster, suffix_stages * g)
}

/// Communication level between two *arbitrary* device blocks of `g`
/// devices (block `b` spans `[b·g, (b+1)·g)`): the innermost tier whose
/// subtree contains both blocks. Used by searches that permute stage
/// placement (the MCMC baseline explores non-compact layouts).
pub fn block_pair_level(cluster: &Cluster, b1: usize, b2: usize, g: usize) -> usize {
    if b1 == b2 {
        return 0;
    }
    let (lo1, hi1) = (b1 * g, (b1 + 1) * g - 1);
    let (lo2, hi2) = (b2 * g, (b2 + 1) * g - 1);
    for l in 0..cluster.n_levels() {
        let cap = cluster.capacity(l);
        if lo1 / cap == lo2 / cap && hi1 / cap == lo1 / cap && hi2 / cap == lo2 / cap {
            return l;
        }
    }
    cluster.n_levels() - 1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn boundary_levels_fat_tree() {
        let c = Cluster::fat_tree_tpuv4(1024); // caps [8, 32, 1024]
        assert_eq!(boundary_level(&c, 1), 0);
        assert_eq!(boundary_level(&c, 4), 0);
        assert_eq!(boundary_level(&c, 8), 1);
        assert_eq!(boundary_level(&c, 16), 1);
        assert_eq!(boundary_level(&c, 32), 2);
        assert_eq!(boundary_level(&c, 64), 2);
        assert_eq!(boundary_level(&c, 40), 1);
        assert_eq!(boundary_level(&c, 33), 0);
    }

    #[test]
    fn node_sized_stages_cross_nodes() {
        let c = Cluster::fat_tree_tpuv4(64);
        // Stages of 8 devices: every boundary is at a node edge (level 1)
        // except rack edges (level 2 at offsets divisible by 32).
        assert_eq!(min_send_level(&c, 1, 8), 1);
        assert_eq!(min_send_level(&c, 2, 8), 1);
        assert_eq!(min_send_level(&c, 4, 8), 2);
    }

    #[test]
    fn sub_node_stages_stay_local() {
        let c = Cluster::fat_tree_tpuv4(64);
        // Stages of 2 devices: 3 of 4 boundaries are intra-node.
        assert_eq!(min_send_level(&c, 1, 2), 0);
        assert_eq!(min_send_level(&c, 2, 2), 0);
        assert_eq!(min_send_level(&c, 3, 2), 0);
        assert_eq!(min_send_level(&c, 4, 2), 1);
    }

    #[test]
    fn stage_class_masks_cover_replicas() {
        let c = Cluster::hetero_pool(64); // h100 on [0,32), v100 on [32,64)
        assert_eq!(stage_class_mask(&c, &[0, 1], 1, 0), 0b01);
        assert_eq!(stage_class_mask(&c, &[40], 1, 0), 0b10);
        // Replica 1 at stride 32 drags the lockstep group onto the
        // V100 island.
        assert_eq!(stage_class_mask(&c, &[0, 1], 2, 32), 0b11);
        // Homogeneous clusters collapse to the single class.
        let v = Cluster::v100_cluster(8);
        assert_eq!(stage_class_mask(&v, &[0, 5], 2, 2), 0b01);
    }

    #[test]
    fn stage_devices_contiguous_disjoint() {
        let a = stage_devices(0, 4);
        let b = stage_devices(1, 4);
        assert_eq!(a, vec![0, 1, 2, 3]);
        assert_eq!(b, vec![4, 5, 6, 7]);
    }

    #[test]
    fn block_pair_levels() {
        let c = Cluster::fat_tree_tpuv4(1024); // caps [8, 32, 1024]
        // Two 4-device blocks in the same node.
        assert_eq!(block_pair_level(&c, 0, 1, 4), 0);
        // Adjacent nodes in a rack (blocks of 8).
        assert_eq!(block_pair_level(&c, 0, 1, 8), 1);
        assert_eq!(block_pair_level(&c, 0, 3, 8), 1);
        // Across racks.
        assert_eq!(block_pair_level(&c, 0, 4, 8), 2);
        assert_eq!(block_pair_level(&c, 1, 17, 8), 2);
        // Same block.
        assert_eq!(block_pair_level(&c, 5, 5, 8), 0);
        // Symmetric.
        assert_eq!(
            block_pair_level(&c, 2, 9, 8),
            block_pair_level(&c, 9, 2, 8)
        );
    }
}
