//! Contention-aware plan refinement: the DP's analytic shortlist,
//! re-ranked by the flow-level network simulator.
//!
//! NEST's DP prices communication with closed-form per-level costs, so
//! on oversubscribed fabrics the analytically-best plan is not always
//! the best plan on the real network (the blind spot
//! [`crate::harness::netsim`] measures). The refinement loop closes
//! that gap without PHAZE-style joint search or learned-placement
//! rollouts:
//!
//! 1. [`crate::solver::solve_topk`] enumerates the K best distinct
//!    `(sg, recompute, stage count)` plans under the analytic total
//!    order — a shortlist the DP produces nearly for free;
//! 2. every shortlisted plan is lowered through [`crate::netsim::flows`]
//!    onto the explicit link graph and re-scored by the max-min
//!    fair-share engine ([`crate::netsim::fairshare`]);
//! 3. the shortlist is re-ranked by simulated batch time, ties broken
//!    by analytic rank.
//!
//! Because the analytic winner is always in the shortlist, the
//! re-ranked winner's simulated batch time is never worse than the
//! analytic winner's — when the ranking flips, it flips to a strictly
//! faster plan under contention. Everything downstream of the solver is
//! single-threaded and bit-deterministic, so the report is
//! field-for-field identical for every `threads` setting.
//!
//! Entry points: [`refine`], the `nest refine` CLI subcommand, and the
//! cross-topology table in [`crate::harness::refine`].
//!
//! [`refine_under_load`] extends the loop to *shared* fabrics: every
//! shortlisted plan is additionally replayed against seeded background
//! mixes ([`crate::netsim::flowgen`]) at each requested per-link load
//! level, and the ranking key becomes the worst-case (or mean) relative
//! degradation of the plan's *training* batch time — `nest refine
//! --bg-load 0.3,0.6` picks the plan that degrades least, and the
//! `nest mix` harness tables the flips across load levels.

use crate::graph::LayerGraph;
use crate::netsim::{
    faults, flowgen, flows, FaultSpec, LinkGraph, MixSpec, NetsimOpts, Simulation,
};
use crate::network::Cluster;
use crate::sim::Schedule;
use crate::util::table::{fmt_time, Table};

use super::plan::PlacementPlan;
use super::{solve_topk, SolverOpts};

/// One shortlisted plan scored both ways.
#[derive(Debug, Clone)]
pub struct RefinedPlan {
    /// Position in the analytic shortlist (0 = the plan [`super::solve`]
    /// returns).
    pub analytic_rank: usize,
    /// The DP's analytic batch time the shortlist was ranked by
    /// (`plan.batch_time`).
    pub analytic_batch: f64,
    /// Contention-aware flow-simulated batch time.
    pub sim_batch: f64,
    /// Relative analytic→simulated delta:
    /// `(sim_batch − analytic_batch) / analytic_batch`.
    pub delta: f64,
    /// Hottest link's mean utilization under the flow simulation.
    pub max_link_util: f64,
    /// Flows the plan's training batch lowered into.
    pub n_flows: usize,
    /// Flow-simulated *training* batch time under each requested
    /// background-load level, parallel to [`RefineOpts::bg_loads`]
    /// (empty when no background replays were requested).
    pub bg_sim: Vec<f64>,
    /// Contention-robustness key: worst-case (or mean — see
    /// [`RefineOpts::worst_case`]) relative degradation of the training
    /// batch time across the background levels,
    /// `(bg_sim[i] − sim_batch) / sim_batch`. 0.0 without levels.
    pub degradation: f64,
    /// Worst-case flow-simulated training batch time per fault severity
    /// level, parallel to [`RefineOpts::fault_severities`] (the max over
    /// that level's seeded scenarios; empty without fault replays).
    pub fault_sim: Vec<f64>,
    /// Failure-robustness key: throughput retention under faults,
    /// `sim_batch / fault_sim[i]` per level, folded to the worst level
    /// (or the mean of per-level worst cases — CVaR-style — when
    /// [`RefineOpts::worst_case`] is false). In `(0, 1]`; 1.0 without
    /// fault replays. Higher is better.
    pub retention: f64,
    pub plan: PlacementPlan,
}

/// Refinement outcome: the shortlist in *simulated* order.
#[derive(Debug, Clone)]
pub struct RefineReport {
    /// Shortlisted plans sorted by `(sim_batch, analytic_rank)` — or,
    /// when background levels / fault severities were replayed
    /// ([`refine_under_load`]), by `(retention desc, degradation,
    /// sim_batch, analytic_rank)` — index 0 is the re-ranked winner.
    pub ranked: Vec<RefinedPlan>,
    /// Background-load levels the shortlist was replayed under (empty
    /// for plain refinement); `ranked[..].bg_sim` is parallel to this.
    pub bg_loads: Vec<f64>,
    /// Fault severity levels the shortlist was replayed under (empty
    /// when no fault replays were requested); `ranked[..].fault_sim` is
    /// parallel to this.
    pub fault_severities: Vec<f64>,
    pub solve_seconds: f64,
    pub dp_states: u64,
    pub configs_tried: u64,
}

impl RefineReport {
    /// The re-ranked (contention-aware) winner.
    pub fn winner(&self) -> &RefinedPlan {
        &self.ranked[0]
    }

    /// The analytic winner (the plan plain `solve` returns), wherever
    /// the re-ranking left it.
    pub fn analytic_winner(&self) -> &RefinedPlan {
        self.ranked
            .iter()
            .find(|r| r.analytic_rank == 0)
            .expect("shortlist always contains the analytic winner")
    }

    /// Did the flow-level re-ranking pick a different plan than the DP?
    pub fn winner_changed(&self) -> bool {
        self.winner().analytic_rank != 0
    }

    /// Fraction of simulated batch time the re-ranked winner saves over
    /// the analytic winner (0.0 when the ranking did not change;
    /// strictly positive when it did — ties re-rank by analytic order).
    pub fn sim_improvement(&self) -> f64 {
        let ana = self.analytic_winner().sim_batch;
        (ana - self.winner().sim_batch) / ana
    }

    /// Render the shortlist as a per-plan table (sim order). When
    /// background levels were replayed, one `bg N%` column per level
    /// (training batch time under that load) and the degradation key
    /// are appended.
    pub fn render_table(&self) -> String {
        let mut headers: Vec<String> = [
            "sim rank",
            "dp rank",
            "strategy",
            "stages",
            "analytic",
            "flow-sim",
            "delta",
            "max link util",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        for load in &self.bg_loads {
            headers.push(format!("bg {:.0}%", load * 100.0));
        }
        if !self.bg_loads.is_empty() {
            headers.push("degradation".into());
        }
        for sev in &self.fault_severities {
            headers.push(format!("faults {:.0}%", sev * 100.0));
        }
        if !self.fault_severities.is_empty() {
            headers.push("retention".into());
        }
        let header_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let mut tbl = Table::new(&header_refs);
        for (i, r) in self.ranked.iter().enumerate() {
            let mut row = vec![
                (i + 1).to_string(),
                (r.analytic_rank + 1).to_string(),
                r.plan.strategy_string(),
                r.plan.n_stages().to_string(),
                fmt_time(r.analytic_batch),
                fmt_time(r.sim_batch),
                format!("{:+.1}%", r.delta * 100.0),
                format!("{:.0}%", r.max_link_util * 100.0),
            ];
            for bg in &r.bg_sim {
                row.push(fmt_time(*bg));
            }
            if !self.bg_loads.is_empty() {
                row.push(format!("{:+.1}%", r.degradation * 100.0));
            }
            // Per-level retention: the clean simulated time over that
            // level's worst-case faulted time.
            for ft in &r.fault_sim {
                row.push(format!("{:.0}%", r.sim_batch / ft * 100.0));
            }
            if !self.fault_severities.is_empty() {
                row.push(format!("{:.0}%", r.retention * 100.0));
            }
            tbl.row(row);
        }
        tbl.render()
    }
}

/// Solve the analytic top-K shortlist for `graph` on `cluster`, replay
/// every shortlisted plan's training batch on the explicit `topo` link
/// graph, and re-rank by contention-aware batch time. Returns `None`
/// when no feasible placement exists.
///
/// Deterministic: the report is field-for-field identical for every
/// `opts.threads` value, and `topk = 1` reproduces plain
/// [`super::solve`] (the single-entry shortlist *is* its plan).
pub fn refine(
    graph: &LayerGraph,
    cluster: &Cluster,
    topo: &LinkGraph,
    opts: &SolverOpts,
    topk: usize,
) -> Option<RefineReport> {
    refine_opts(graph, cluster, topo, opts, topk, NetsimOpts::default())
}

/// [`refine`] with explicit flow-simulator options (`nest refine
/// --mode …` lands here). Reports are bit-identical across simulation
/// modes and thread counts — the options trade wall-clock, not bits.
pub fn refine_opts(
    graph: &LayerGraph,
    cluster: &Cluster,
    topo: &LinkGraph,
    opts: &SolverOpts,
    topk: usize,
    netsim: NetsimOpts,
) -> Option<RefineReport> {
    let _span = crate::obs::span_with("refine.refine", "refine", || {
        vec![("topk", topk.to_string())]
    });
    let top = solve_topk(graph, cluster, opts, topk);
    if top.plans.is_empty() {
        return None;
    }
    // One Simulation for all K replays: its retained engine's per-link
    // buffers are sized once and reused (bit-identical to fresh engines).
    let mut sim = Simulation::with_opts(netsim);
    let ranked = rerank(&mut sim, graph, cluster, topo, top.plans);
    Some(RefineReport {
        ranked,
        bg_loads: Vec::new(),
        fault_severities: Vec::new(),
        solve_seconds: top.solve_seconds,
        dp_states: top.dp_states,
        configs_tried: top.configs_tried,
    })
}

/// Knobs of a background-load-aware refinement ([`refine_under_load`]).
#[derive(Debug, Clone)]
pub struct RefineOpts {
    /// Analytic shortlist size.
    pub topk: usize,
    /// Flow-simulator options for every replay.
    pub netsim: NetsimOpts,
    /// Target max per-link background loads to replay the shortlist
    /// under (`nest refine --bg-load 0.3,0.6`). Empty = plain
    /// [`refine_opts`] behavior.
    pub bg_loads: Vec<f64>,
    /// Seed of the background mixes; level `i` draws with
    /// `bg_seed + i`, and every plan at one level replays the *same*
    /// mix (robustness must compare like against like).
    pub bg_seed: u64,
    /// Rank by worst-case degradation/retention across the levels
    /// (default); `false` ranks by the mean instead (for retention this
    /// is the CVaR-style mean of per-level worst cases).
    pub worst_case: bool,
    /// Fault severity levels to replay the shortlist under (`nest
    /// refine --fault-severity 0.3,0.7`). Empty = no fault replays.
    pub fault_severities: Vec<f64>,
    /// Seeded scenarios replayed per severity level (each plan replays
    /// every scenario; a level's score is its worst scenario).
    pub fault_scenarios: usize,
    /// Seed of the fault scenarios; level `i` scenario `j` draws with
    /// `fault_seed + i·fault_scenarios + j`, and every plan replays the
    /// *same* scenarios (robustness must compare like against like).
    pub fault_seed: u64,
}

impl Default for RefineOpts {
    fn default() -> Self {
        RefineOpts {
            topk: 4,
            netsim: NetsimOpts::default(),
            bg_loads: Vec::new(),
            bg_seed: 0xB6,
            worst_case: true,
            fault_severities: Vec::new(),
            fault_scenarios: 2,
            fault_seed: 0xFA17,
        }
    }
}

/// Refinement under multi-tenant fabric load: solve the analytic top-K
/// shortlist, re-rank it by contention-aware batch time as
/// [`refine_opts`] does, then replay every shortlisted plan under each
/// requested background-load level (one seeded [`crate::netsim::flowgen`]
/// mix per level, shared by all plans) and re-rank by worst-case (or
/// mean) *training* batch-time degradation. The plan that degrades
/// least on a shared fabric wins; zero-load simulated time and analytic
/// rank break ties. With empty `ropts.bg_loads` this is exactly
/// [`refine_opts`].
///
/// Deterministic: mixes are pure functions of `(topo, level, bg_seed)`
/// and the replays are bit-deterministic, so the report is
/// field-for-field identical across solver threads and simulator modes.
pub fn refine_under_load(
    graph: &LayerGraph,
    cluster: &Cluster,
    topo: &LinkGraph,
    opts: &SolverOpts,
    ropts: &RefineOpts,
) -> Option<RefineReport> {
    let mut report = refine_opts(graph, cluster, topo, opts, ropts.topk, ropts.netsim)?;
    if ropts.bg_loads.is_empty() && ropts.fault_severities.is_empty() {
        return Some(report);
    }
    let _span = crate::obs::span_with("refine.under_load", "refine", || {
        vec![
            ("levels", ropts.bg_loads.len().to_string()),
            ("fault_levels", ropts.fault_severities.len().to_string()),
            ("plans", report.ranked.len().to_string()),
        ]
    });
    // The mixes' arrival window (and the faults' strike window) covers
    // the slowest shortlisted plan, so every candidate sees the whole
    // background churn / every fault.
    let duration = report
        .ranked
        .iter()
        .map(|r| r.sim_batch)
        .fold(0.0f64, f64::max)
        .max(1e-9);
    let mut sim = Simulation::with_opts(ropts.netsim);
    for (li, &load) in ropts.bg_loads.iter().enumerate() {
        let mix = flowgen::generate(
            topo,
            &MixSpec::at_load(load, duration, ropts.bg_seed.wrapping_add(li as u64)),
        );
        for r in report.ranked.iter_mut() {
            let mut wl = flows::lower(graph, cluster, topo, &r.plan, Schedule::OneFOneB);
            flowgen::inject(&mut wl, &mix);
            let rep = sim.run_workload(topo, &wl);
            r.bg_sim.push(rep.train_batch_time);
        }
    }
    if !ropts.bg_loads.is_empty() {
        for r in report.ranked.iter_mut() {
            let sim_batch = r.sim_batch;
            let d = if ropts.worst_case {
                r.bg_sim
                    .iter()
                    .map(|&bg| (bg - sim_batch) / sim_batch)
                    .fold(f64::NEG_INFINITY, f64::max)
            } else {
                r.bg_sim
                    .iter()
                    .map(|&bg| (bg - sim_batch) / sim_batch)
                    .sum::<f64>()
                    / r.bg_sim.len() as f64
            };
            r.degradation = d;
        }
    }
    // Fault axis: N seeded scenarios per severity level, shared across
    // plans. A level scores a plan by its *worst* scenario (stragglers
    // stretch the stage compute during lowering, link faults become
    // timed capacity events), and the ranking key is throughput
    // retention — worst level, or the CVaR-style mean of per-level
    // worsts when `worst_case` is off.
    let n_sc = ropts.fault_scenarios.max(1);
    for (li, &sev) in ropts.fault_severities.iter().enumerate() {
        for j in 0..n_sc {
            let seed = ropts
                .fault_seed
                .wrapping_add((li * n_sc + j) as u64);
            let sc = faults::draw(topo, &FaultSpec::at_severity(sev, duration, seed));
            for r in report.ranked.iter_mut() {
                let mut wl = flows::lower_faulted(
                    graph,
                    cluster,
                    topo,
                    &r.plan,
                    Schedule::OneFOneB,
                    Some(&sc),
                );
                faults::inject(&mut wl, topo, &sc);
                let rep = sim.run_workload(topo, &wl);
                if j == 0 {
                    r.fault_sim.push(rep.train_batch_time);
                } else {
                    r.fault_sim[li] = r.fault_sim[li].max(rep.train_batch_time);
                }
            }
        }
    }
    if !ropts.fault_severities.is_empty() {
        for r in report.ranked.iter_mut() {
            let rets = r.fault_sim.iter().map(|&ft| r.sim_batch / ft);
            r.retention = if ropts.worst_case {
                rets.fold(f64::INFINITY, f64::min)
            } else {
                rets.sum::<f64>() / r.fault_sim.len() as f64
            };
        }
    }
    report.ranked.sort_by(|a, b| {
        b.retention
            .total_cmp(&a.retention)
            .then(a.degradation.total_cmp(&b.degradation))
            .then(a.sim_batch.total_cmp(&b.sim_batch))
            .then(a.analytic_rank.cmp(&b.analytic_rank))
    });
    report.bg_loads = ropts.bg_loads.clone();
    report.fault_severities = ropts.fault_severities.clone();
    Some(report)
}

/// Re-rank an analytic shortlist (plans in DP order, index = analytic
/// rank) by flow-simulated batch time on `topo`, reusing the caller's
/// `sim`. This is the simulation half of [`refine`], split out so
/// [`crate::service::PlacementService`] can re-rank a *cached*
/// shortlist against a new topology without re-solving.
/// Bit-deterministic: the result depends only on the inputs and never
/// on simulation history, mode, or thread count.
pub fn rerank(
    sim: &mut Simulation,
    graph: &LayerGraph,
    cluster: &Cluster,
    topo: &LinkGraph,
    plans: Vec<PlacementPlan>,
) -> Vec<RefinedPlan> {
    let mut ranked: Vec<RefinedPlan> = plans
        .into_iter()
        .enumerate()
        .map(|(rank, plan)| {
            let _span = crate::obs::span_with("refine.replay", "refine", || {
                vec![("analytic_rank", rank.to_string())]
            });
            let rep = sim.run(graph, cluster, topo, &plan, Schedule::OneFOneB);
            let delta = (rep.batch_time - plan.batch_time) / plan.batch_time;
            RefinedPlan {
                analytic_rank: rank,
                analytic_batch: plan.batch_time,
                sim_batch: rep.batch_time,
                delta,
                max_link_util: rep.max_link_util,
                n_flows: rep.n_flows,
                bg_sim: Vec::new(),
                degradation: 0.0,
                fault_sim: Vec::new(),
                retention: 1.0,
                plan,
            }
        })
        .collect();
    ranked.sort_by(|a, b| {
        a.sim_batch
            .total_cmp(&b.sim_batch)
            .then(a.analytic_rank.cmp(&b.analytic_rank))
    });
    ranked
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::harness::netsim::dumbbell_topology as dumbbell;
    use crate::solver::solve;

    fn opts(threads: usize) -> SolverOpts {
        SolverOpts {
            threads,
            ..Default::default()
        }
    }

    #[test]
    fn topk1_reproduces_solve_exactly() {
        let g = models::llama2_7b(1);
        let (c, topo) = dumbbell();
        let direct = solve(&g, &c, &opts(1)).expect("feasible");
        for threads in [1usize, 4] {
            let rep = refine(&g, &c, &topo, &opts(threads), 1).expect("feasible");
            assert_eq!(rep.ranked.len(), 1);
            assert_eq!(
                rep.winner().plan,
                direct.plan,
                "threads={threads}: K=1 refinement diverged from solve()"
            );
            assert!(!rep.winner_changed());
            assert_eq!(rep.sim_improvement(), 0.0);
        }
    }

    #[test]
    fn report_deterministic_across_threads_and_runs() {
        let g = models::llama2_7b(1);
        let (c, topo) = dumbbell();
        let a = refine(&g, &c, &topo, &opts(1), 4).expect("feasible");
        let b = refine(&g, &c, &topo, &opts(4), 4).expect("feasible");
        assert_eq!(a.ranked.len(), b.ranked.len());
        for (x, y) in a.ranked.iter().zip(&b.ranked) {
            assert_eq!(x.plan, y.plan);
            assert_eq!(x.analytic_rank, y.analytic_rank);
            assert_eq!(x.sim_batch.to_bits(), y.sim_batch.to_bits());
        }
        let c2 = refine(&g, &c, &topo, &opts(4), 4).expect("feasible");
        for (x, y) in b.ranked.iter().zip(&c2.ranked) {
            assert_eq!(x.sim_batch.to_bits(), y.sim_batch.to_bits());
        }
    }

    #[test]
    fn rerank_winner_never_worse_in_sim() {
        let g = models::llama2_7b(1);
        let (c, topo) = dumbbell();
        let rep = refine(&g, &c, &topo, &opts(0), 4).expect("feasible");
        assert!(
            rep.winner().sim_batch <= rep.analytic_winner().sim_batch,
            "re-ranked winner slower than the analytic winner under the flow sim"
        );
        if rep.winner_changed() {
            // Ties break toward the analytic order, so a flip is always
            // a strict simulated improvement.
            assert!(rep.winner().sim_batch < rep.analytic_winner().sim_batch);
            assert!(rep.sim_improvement() > 0.0);
        }
        // Ranked order is by simulated batch time.
        for w in rep.ranked.windows(2) {
            assert!(w[0].sim_batch <= w[1].sim_batch);
        }
    }

    #[test]
    fn render_table_lists_every_plan() {
        let g = models::llama2_7b(1);
        let (c, topo) = dumbbell();
        let rep = refine(&g, &c, &topo, &opts(0), 3).expect("feasible");
        let table = rep.render_table();
        for r in &rep.ranked {
            assert!(table.contains(&r.plan.strategy_string()));
        }
    }

    #[test]
    fn under_load_with_no_levels_is_plain_refine() {
        let g = models::llama2_7b(1);
        let (c, topo) = dumbbell();
        let plain = refine(&g, &c, &topo, &opts(1), 3).expect("feasible");
        let ropts = RefineOpts {
            topk: 3,
            ..Default::default()
        };
        let under = refine_under_load(&g, &c, &topo, &opts(1), &ropts).expect("feasible");
        assert!(under.bg_loads.is_empty());
        assert_eq!(plain.ranked.len(), under.ranked.len());
        for (x, y) in plain.ranked.iter().zip(&under.ranked) {
            assert_eq!(x.plan, y.plan);
            assert_eq!(x.sim_batch.to_bits(), y.sim_batch.to_bits());
            assert!(y.bg_sim.is_empty());
            assert_eq!(y.degradation, 0.0);
        }
    }

    #[test]
    fn under_load_ranks_by_degradation_and_is_thread_invariant() {
        let g = models::llama2_7b(1);
        let (c, topo) = dumbbell();
        let ropts = RefineOpts {
            topk: 3,
            bg_loads: vec![0.3, 0.6],
            ..Default::default()
        };
        let a = refine_under_load(&g, &c, &topo, &opts(1), &ropts).expect("feasible");
        let b = refine_under_load(&g, &c, &topo, &opts(4), &ropts).expect("feasible");
        assert_eq!(a.bg_loads, vec![0.3, 0.6]);
        for r in &a.ranked {
            assert_eq!(r.bg_sim.len(), 2, "one replay per load level");
            // Worst-case key: the max per-level degradation.
            let worst = r
                .bg_sim
                .iter()
                .map(|&bg| (bg - r.sim_batch) / r.sim_batch)
                .fold(f64::NEG_INFINITY, f64::max);
            assert_eq!(r.degradation.to_bits(), worst.to_bits());
        }
        for w in a.ranked.windows(2) {
            assert!(w[0].degradation <= w[1].degradation, "ranked by degradation");
        }
        // The robust winner never degrades more than the analytic pick.
        assert!(a.winner().degradation <= a.analytic_winner().degradation);
        // Field-for-field thread invariance.
        assert_eq!(a.ranked.len(), b.ranked.len());
        for (x, y) in a.ranked.iter().zip(&b.ranked) {
            assert_eq!(x.plan, y.plan);
            assert_eq!(x.analytic_rank, y.analytic_rank);
            assert_eq!(x.sim_batch.to_bits(), y.sim_batch.to_bits());
            assert_eq!(x.degradation.to_bits(), y.degradation.to_bits());
            for (p, q) in x.bg_sim.iter().zip(&y.bg_sim) {
                assert_eq!(p.to_bits(), q.to_bits());
            }
        }
        // The rendered table grows one column per level plus the key.
        let table = a.render_table();
        assert!(table.contains("bg 30%"));
        assert!(table.contains("bg 60%"));
        assert!(table.contains("degradation"));
    }

    #[test]
    fn under_faults_ranks_by_retention_and_is_thread_invariant() {
        let g = models::llama2_7b(1);
        let (c, topo) = dumbbell();
        let ropts = RefineOpts {
            topk: 3,
            fault_severities: vec![0.4, 0.8],
            fault_scenarios: 2,
            ..Default::default()
        };
        let a = refine_under_load(&g, &c, &topo, &opts(1), &ropts).expect("feasible");
        let b = refine_under_load(&g, &c, &topo, &opts(4), &ropts).expect("feasible");
        assert_eq!(a.fault_severities, vec![0.4, 0.8]);
        assert!(a.bg_loads.is_empty());
        for r in &a.ranked {
            assert_eq!(r.fault_sim.len(), 2, "one worst-case per severity level");
            // Faults only slow: retention stays in (0, 1] up to dust.
            assert!(r.retention > 0.0 && r.retention <= 1.0 + 1e-9, "{}", r.retention);
            for &ft in &r.fault_sim {
                assert!(ft >= r.sim_batch * (1.0 - 1e-9));
            }
            // Worst-case key: the minimum per-level retention.
            let worst = r
                .fault_sim
                .iter()
                .map(|&ft| r.sim_batch / ft)
                .fold(f64::INFINITY, f64::min);
            assert_eq!(r.retention.to_bits(), worst.to_bits());
        }
        for w in a.ranked.windows(2) {
            assert!(w[0].retention >= w[1].retention, "ranked by retention desc");
        }
        // The fault-aware winner never retains less than the analytic pick.
        assert!(a.winner().retention >= a.analytic_winner().retention);
        // Field-for-field thread invariance.
        assert_eq!(a.ranked.len(), b.ranked.len());
        for (x, y) in a.ranked.iter().zip(&b.ranked) {
            assert_eq!(x.plan, y.plan);
            assert_eq!(x.retention.to_bits(), y.retention.to_bits());
            for (p, q) in x.fault_sim.iter().zip(&y.fault_sim) {
                assert_eq!(p.to_bits(), q.to_bits());
            }
        }
        // The rendered table grows one column per level plus the key.
        let table = a.render_table();
        assert!(table.contains("faults 40%"));
        assert!(table.contains("faults 80%"));
        assert!(table.contains("retention"));
    }

    #[test]
    fn faults_and_bg_axes_compose() {
        let g = models::llama2_7b(1);
        let (c, topo) = dumbbell();
        let ropts = RefineOpts {
            topk: 2,
            bg_loads: vec![0.4],
            fault_severities: vec![0.6],
            fault_scenarios: 1,
            ..Default::default()
        };
        let rep = refine_under_load(&g, &c, &topo, &opts(0), &ropts).expect("feasible");
        for r in &rep.ranked {
            assert_eq!(r.bg_sim.len(), 1);
            assert_eq!(r.fault_sim.len(), 1);
            assert!(r.degradation >= -1e-9);
            assert!(r.retention > 0.0 && r.retention <= 1.0 + 1e-9);
        }
        // Retention is the primary key.
        for w in rep.ranked.windows(2) {
            assert!(w[0].retention >= w[1].retention);
        }
    }

    #[test]
    fn under_load_mean_ranking_uses_the_mean() {
        let g = models::llama2_7b(1);
        let (c, topo) = dumbbell();
        let ropts = RefineOpts {
            topk: 2,
            bg_loads: vec![0.2, 0.5],
            worst_case: false,
            ..Default::default()
        };
        let rep = refine_under_load(&g, &c, &topo, &opts(0), &ropts).expect("feasible");
        for r in &rep.ranked {
            let mean = r
                .bg_sim
                .iter()
                .map(|&bg| (bg - r.sim_batch) / r.sim_batch)
                .sum::<f64>()
                / r.bg_sim.len() as f64;
            assert_eq!(r.degradation.to_bits(), mean.to_bits());
        }
    }
}
