//! Deterministic pseudo-random number generation.
//!
//! SplitMix64 for seeding + xoshiro256** for the stream: fast, tiny, and
//! reproducible across platforms. Used by the MCMC baseline (which the
//! paper runs with 10 seeds, reporting the best) and by the in-repo
//! property-testing driver.

/// xoshiro256** PRNG seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the full state.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift rejection method.
    pub fn gen_range(&mut self, n: usize) -> usize {
        assert!(n > 0, "gen_range(0)");
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= lo.wrapping_neg() % n {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform float in `[0, 1)`.
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli draw.
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Pick a random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.gen_range(xs.len())]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.gen_range(i + 1));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(8);
        assert_ne!(Rng::new(7).next_u64(), c.next_u64());
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Rng::new(42);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.gen_range(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit in 1000 draws");
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(1);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.gen_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean} too far from 0.5");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
