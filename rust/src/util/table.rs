//! Fixed-width table pretty-printer used by the figure/table harnesses to
//! print the paper's rows to the terminal.

/// A simple text table builder.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row arity must match header"
        );
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for i in 0..ncol {
                let pad = widths[i] - cells[i].chars().count();
                s.push(' ');
                s.push_str(&cells[i]);
                s.push_str(&" ".repeat(pad + 1));
                s.push('|');
            }
            s
        };
        let mut out = String::new();
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out
    }
}

/// Format seconds with an adaptive unit (ns/µs/ms/s).
pub fn fmt_time(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.1}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else if secs < 120.0 {
        format!("{:.2}s", secs)
    } else {
        format!("{:.1}min", secs / 60.0)
    }
}

/// Format a byte count with binary units.
pub fn fmt_bytes(bytes: f64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = bytes;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{:.0}{}", v, UNITS[u])
    } else {
        format!("{:.2}{}", v, UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["model", "tput"]);
        t.row(vec!["gpt3".into(), "1.59x".into()]);
        t.row(vec!["llama2-7b".into(), "2x".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
        assert!(s.contains("llama2-7b"));
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        Table::new(&["a", "b"]).row(vec!["x".into()]);
    }

    #[test]
    fn time_units() {
        assert_eq!(fmt_time(0.5e-9 * 3.0), "1.5ns");
        assert_eq!(fmt_time(2.5e-5), "25.0µs");
        assert_eq!(fmt_time(0.0035), "3.50ms");
        assert_eq!(fmt_time(3.0), "3.00s");
        assert_eq!(fmt_time(600.0), "10.0min");
    }

    #[test]
    fn byte_units() {
        assert_eq!(fmt_bytes(512.0), "512B");
        assert_eq!(fmt_bytes(2048.0), "2.00KiB");
        assert_eq!(fmt_bytes(80.0 * 1024.0 * 1024.0 * 1024.0), "80.00GiB");
    }
}
