//! Small statistics helpers shared by the profiler, bench harness, and
//! experiment reports.

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean; 0.0 for empty input. Used for the paper's "on average
/// N.NN× higher throughput" aggregates.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Sample standard deviation; 0.0 for fewer than two samples.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Median (averages the middle pair for even lengths).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        (v[n / 2 - 1] + v[n / 2]) / 2.0
    }
}

/// p-th percentile (nearest-rank), p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn geomean_of_ratios() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
    }

    #[test]
    fn stddev_known() {
        let s = stddev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn percentile_ranks() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert!((percentile(&xs, 50.0) - 50.0).abs() <= 1.0);
    }
}
