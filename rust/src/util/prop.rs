//! Tiny property-testing driver (proptest is unavailable offline).
//!
//! `forall(cases, seed, |rng| { ... })` runs a closure over `cases`
//! independently seeded RNGs; on panic the failing seed is printed so the
//! case can be replayed with `forall(1, <seed>, ..)`.

use super::rng::Rng;

/// Run `body` for `cases` random cases. Each case gets an `Rng` derived
/// from `base_seed` and the case index; the failing case's seed is
/// reported via a wrapping panic message.
pub fn forall(cases: usize, base_seed: u64, mut body: impl FnMut(&mut Rng)) {
    for case in 0..cases {
        let seed = base_seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(case as u64);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            body(&mut rng);
        }));
        if let Err(e) = result {
            eprintln!(
                "property failed at case {case} (replay: forall(1, {seed}, ..))"
            );
            std::panic::resume_unwind(e);
        }
    }
}

/// Draw a random subset of `[0, n)` with inclusion probability `p`.
pub fn random_subset(rng: &mut Rng, n: usize, p: f64) -> Vec<usize> {
    (0..n).filter(|_| rng.gen_bool(p)).collect()
}

/// Draw a random power of two in `[lo, hi]` (both inclusive, rounded to
/// powers of two).
pub fn random_pow2(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    let lo_exp = (lo.max(1)).next_power_of_two().trailing_zeros();
    let hi_exp = hi.next_power_of_two().trailing_zeros();
    let exp = lo_exp + rng.gen_range((hi_exp - lo_exp + 1) as usize) as u32;
    1usize << exp
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_runs_all_cases() {
        let mut count = 0;
        forall(25, 1, |_rng| {
            count += 1;
        });
        assert_eq!(count, 25);
    }

    #[test]
    fn pow2_in_range() {
        forall(100, 2, |rng| {
            let v = random_pow2(rng, 1, 64);
            assert!(v.is_power_of_two());
            assert!((1..=64).contains(&v));
        });
    }

    #[test]
    fn subset_bounds() {
        forall(50, 3, |rng| {
            let s = random_subset(rng, 20, 0.5);
            assert!(s.iter().all(|&i| i < 20));
            // strictly increasing => unique
            assert!(s.windows(2).all(|w| w[0] < w[1]));
        });
    }
}
