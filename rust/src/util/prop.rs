//! Tiny property-testing driver (proptest is unavailable offline).
//!
//! `forall(cases, seed, |rng| { ... })` runs a closure over `cases`
//! independently seeded RNGs; on panic the failing seed is printed so the
//! case can be replayed with `forall(1, <seed>, ..)`.

use super::rng::Rng;

/// Run `body` for `cases` random cases. Each case gets an `Rng` derived
/// from `base_seed` and the case index; the failing case's seed is
/// reported via a wrapping panic message.
pub fn forall(cases: usize, base_seed: u64, mut body: impl FnMut(&mut Rng)) {
    for case in 0..cases {
        let seed = base_seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(case as u64);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            body(&mut rng);
        }));
        if let Err(e) = result {
            eprintln!(
                "property failed at case {case} (replay: forall(1, {seed}, ..))"
            );
            std::panic::resume_unwind(e);
        }
    }
}

/// Draw a random subset of `[0, n)` with inclusion probability `p`.
pub fn random_subset(rng: &mut Rng, n: usize, p: f64) -> Vec<usize> {
    (0..n).filter(|_| rng.gen_bool(p)).collect()
}

/// Draw a random power of two in `[lo, hi]` (both inclusive, rounded to
/// powers of two).
pub fn random_pow2(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    let lo_exp = (lo.max(1)).next_power_of_two().trailing_zeros();
    let hi_exp = hi.next_power_of_two().trailing_zeros();
    let exp = lo_exp + rng.gen_range((hi_exp - lo_exp + 1) as usize) as u32;
    1usize << exp
}

/// Draw a random hierarchical cluster for the scenario suite: 1–3 tiers
/// with power-of-two arities (8-wide max innermost, 4-wide outer),
/// bandwidth shrinking and latency growing outward, occasional outer
/// oversubscription — and, half the time, a *heterogeneous* two-run
/// device pool (two distinct accelerator classes split at a random
/// power-of-two boundary), exercising the mixed-pool solver paths.
pub fn random_cluster(rng: &mut Rng) -> crate::network::Cluster {
    use crate::hw::{Accelerator, DevicePool, DeviceRun, GB};
    use crate::network::{Cluster, Tier};
    let n_tiers = 1 + rng.gen_range(3);
    let mut tiers = Vec::new();
    let mut bw = (100.0 + 800.0 * rng.gen_f64()) * GB;
    let mut lat = 1e-6;
    for t in 0..n_tiers {
        let arity = if t == 0 {
            random_pow2(rng, 2, 8)
        } else {
            random_pow2(rng, 2, 4)
        };
        let outermost = t + 1 == n_tiers;
        tiers.push(Tier {
            name: format!("t{t}"),
            arity,
            link_bw: bw,
            latency: lat,
            oversub: if outermost && t > 0 && rng.gen_bool(0.5) {
                2.0
            } else {
                1.0
            },
        });
        bw /= 2.0 + 6.0 * rng.gen_f64();
        lat *= 2.0;
    }
    let n: usize = tiers.iter().map(|t| t.arity).product();
    let accels = [Accelerator::v100(), Accelerator::tpu_v4(), Accelerator::h100()];
    let pool = if n >= 4 && rng.gen_bool(0.5) {
        let a = rng.gen_range(3);
        let mut b = rng.gen_range(3);
        if b == a {
            b = (b + 1) % 3;
        }
        let split = random_pow2(rng, 1, n / 2).min(n - 1);
        DevicePool::from_runs(vec![
            DeviceRun {
                accel: accels[a].clone(),
                count: split,
                access_bw: None,
            },
            DeviceRun {
                accel: accels[b].clone(),
                count: n - split,
                access_bw: None,
            },
        ])
    } else {
        DevicePool::uniform(accels[rng.gen_range(3)].clone(), n)
    };
    Cluster {
        name: format!("rand-{n_tiers}t-{n}d"),
        pool,
        tiers,
    }
}

/// Draw a random tiny transformer for the scenario suite: 2–6 blocks
/// (plus embedding/head), small hidden/seq so a solve stays in the
/// microsecond-to-millisecond range.
pub fn random_tiny_graph(rng: &mut Rng) -> crate::graph::LayerGraph {
    let n_blocks = 2 + rng.gen_range(5);
    let hidden = 128 * (1 + rng.gen_range(3));
    let seq = 64 * (1 + rng.gen_range(2));
    crate::graph::models::tiny_transformer(n_blocks, hidden, seq, 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_runs_all_cases() {
        let mut count = 0;
        forall(25, 1, |_rng| {
            count += 1;
        });
        assert_eq!(count, 25);
    }

    #[test]
    fn pow2_in_range() {
        forall(100, 2, |rng| {
            let v = random_pow2(rng, 1, 64);
            assert!(v.is_power_of_two());
            assert!((1..=64).contains(&v));
        });
    }

    #[test]
    fn random_clusters_well_formed() {
        forall(60, 4, |rng| {
            let c = random_cluster(rng);
            let n = c.n_devices();
            assert!(n >= 2, "{}", c.name);
            assert_eq!(c.pool.n_devices(), n);
            assert!((1..=3).contains(&c.n_levels()));
            assert!(c.pool.n_classes() <= 2);
            for t in &c.tiers {
                assert!(t.arity.is_power_of_two());
                assert!(t.link_bw > 0.0 && t.latency > 0.0);
            }
            // Level-wise queries hold together on the random stack.
            assert!(c.bw_eff(c.n_levels() - 1) <= c.bw_eff(0));
            assert!(c.p2p_time(c.n_levels() - 1, 1e6).is_finite());
        });
    }

    #[test]
    fn random_graphs_well_formed() {
        forall(20, 5, |rng| {
            let g = random_tiny_graph(rng);
            assert!(g.n_layers() >= 4); // 2 blocks + emb + head
            assert!(g.tokens > 0.0);
        });
    }

    #[test]
    fn subset_bounds() {
        forall(50, 3, |rng| {
            let s = random_subset(rng, 20, 0.5);
            assert!(s.iter().all(|&i| i < 20));
            // strictly increasing => unique
            assert!(s.windows(2).all(|w| w[0] < w[1]));
        });
    }
}
