//! Minimal JSON value model, parser, and serializer.
//!
//! Used for (a) network/model/solver config files, (b) the
//! `artifacts/manifest.json` handed over from the python AOT pipeline, and
//! (c) machine-readable experiment outputs. Supports the full JSON grammar
//! except `\u` surrogate pairs are passed through unvalidated.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Numbers are kept as `f64` (sufficient for this project:
/// byte counts stay below 2^53).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access; returns `Json::Null` for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
    /// Array index access; returns `Json::Null` out of range.
    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write_json(self, f, 0, false)
    }
}

/// Pretty serialization with 2-space indentation.
pub fn to_pretty(v: &Json) -> String {
    struct P<'a>(&'a Json);
    impl fmt::Display for P<'_> {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            write_json(self.0, f, 0, true)
        }
    }
    format!("{}", P(v))
}

fn write_json(v: &Json, f: &mut fmt::Formatter<'_>, depth: usize, pretty: bool) -> fmt::Result {
    let pad = |f: &mut fmt::Formatter<'_>, d: usize| -> fmt::Result {
        if pretty {
            write!(f, "\n{}", "  ".repeat(d))?;
        }
        Ok(())
    };
    match v {
        Json::Null => write!(f, "null"),
        Json::Bool(b) => write!(f, "{b}"),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9e15 {
                write!(f, "{}", *n as i64)
            } else {
                write!(f, "{n}")
            }
        }
        Json::Str(s) => write_escaped(s, f),
        Json::Arr(a) => {
            write!(f, "[")?;
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    write!(f, ",")?;
                    if !pretty {
                        write!(f, " ")?;
                    }
                }
                pad(f, depth + 1)?;
                write_json(item, f, depth + 1, pretty)?;
            }
            if !a.is_empty() {
                pad(f, depth)?;
            }
            write!(f, "]")
        }
        Json::Obj(o) => {
            write!(f, "{{")?;
            for (i, (k, item)) in o.iter().enumerate() {
                if i > 0 {
                    write!(f, ",")?;
                    if !pretty {
                        write!(f, " ")?;
                    }
                }
                pad(f, depth + 1)?;
                write_escaped(k, f)?;
                write!(f, ": ")?;
                write_json(item, f, depth + 1, pretty)?;
            }
            if !o.is_empty() {
                pad(f, depth)?;
            }
            write!(f, "}}")
        }
    }
}

fn write_escaped(s: &str, f: &mut fmt::Formatter<'_>) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Parse a JSON document. Errors carry byte offsets for debugging configs.
pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser {
        b: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return Err(format!("trailing content at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }
    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }
    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }
    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.pos))
        }
    }
    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected character at byte {}", self.pos)),
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err("unterminated string".into()),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or("bad \\u escape")?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or("bad \\u escape")?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(format!("bad escape at byte {}", self.pos)),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Multi-byte UTF-8: copy the raw bytes.
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let start = self.pos - 1;
                    let end = (start + len).min(self.b.len());
                    self.pos = end;
                    out.push_str(
                        std::str::from_utf8(&self.b[start..end]).map_err(|e| e.to_string())?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("invalid number at byte {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalar() {
        for src in ["null", "true", "false", "42", "-3.5", "\"hi\""] {
            let v = parse(src).unwrap();
            assert_eq!(parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").idx(2).get("b").as_str(), Some("x\ny"));
        assert_eq!(v.get("c"), &Json::Null);
        assert_eq!(v.get("a").idx(0).as_u64(), Some(1));
    }

    #[test]
    fn parse_scientific_and_unicode() {
        let v = parse(r#"{"n": 1.5e3, "s": "é", "u": "héllo"}"#).unwrap();
        assert_eq!(v.get("n").as_f64(), Some(1500.0));
        assert_eq!(v.get("s").as_str(), Some("é"));
        assert_eq!(v.get("u").as_str(), Some("héllo"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip_pretty() {
        let v = Json::obj(vec![
            ("model", Json::str("gpt3-175b")),
            ("devices", Json::num(1024.0)),
            ("tiers", Json::arr(vec![Json::num(8.0), Json::num(4.0)])),
        ]);
        let s = to_pretty(&v);
        assert_eq!(parse(&s).unwrap(), v);
        assert!(s.contains('\n'));
    }

    #[test]
    fn display_compact_roundtrip() {
        let v = parse(r#"[{"k":[true,false,null]},1e-3]"#).unwrap();
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }
}
