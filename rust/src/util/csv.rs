//! CSV writer for experiment outputs (`results/*.csv`).
//!
//! Quoting follows RFC 4180: fields containing commas, quotes, or newlines
//! are quoted with embedded quotes doubled.

use std::fs;
use std::io::Write as _;
use std::path::Path;

#[derive(Debug)]
pub struct Csv {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Csv {
    pub fn new(header: &[&str]) -> Self {
        Csv {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "CSV row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&join(&self.header));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&join(r));
            out.push('\n');
        }
        out
    }

    /// Write to `path`, creating parent directories.
    pub fn write(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        let path = path.as_ref();
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent)?;
        }
        let mut f = fs::File::create(path)?;
        f.write_all(self.render().as_bytes())
    }
}

fn join(cells: &[String]) -> String {
    cells
        .iter()
        .map(|c| escape(c))
        .collect::<Vec<_>>()
        .join(",")
}

fn escape(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_and_escapes() {
        let mut c = Csv::new(&["a", "b"]);
        c.row(vec!["1".into(), "x,y".into()]);
        c.row(vec!["he said \"hi\"".into(), "z".into()]);
        let s = c.render();
        assert_eq!(
            s,
            "a,b\n1,\"x,y\"\n\"he said \"\"hi\"\"\",z\n"
        );
    }

    #[test]
    fn writes_file() {
        let dir = std::env::temp_dir().join("nest_csv_test");
        let path = dir.join("out.csv");
        let mut c = Csv::new(&["k"]);
        c.row(vec!["v".into()]);
        c.write(&path).unwrap();
        assert_eq!(fs::read_to_string(&path).unwrap(), "k\nv\n");
        let _ = fs::remove_dir_all(dir);
    }
}
