//! Self-contained utility substrates.
//!
//! The build environment is fully offline with only the `xla` crate
//! vendored, so the usual ecosystem crates (serde, rand, clap, criterion,
//! proptest) are re-implemented here at the scale this project needs:
//!
//! * [`json`]   — JSON parser/serializer for configs and artifacts metadata.
//! * [`rng`]    — deterministic SplitMix64/PCG RNG (MCMC baseline, tests).
//! * [`cli`]    — flag/option parsing for the `nest` binary and examples.
//! * [`table`]  — fixed-width table pretty-printer for paper tables.
//! * [`csv`]    — CSV writer for `results/*.csv`.
//! * [`stats`]  — mean/median/stddev helpers.
//! * [`bench`]  — mini-criterion: warmup + timed iterations + report.
//! * [`prop`]   — tiny property-testing loop driver over seeded RNGs.

pub mod bench;
pub mod cli;
pub mod csv;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;

/// `NEST_REFERENCE=1` switches every hot path that keeps a naive twin
/// (prefix-table pricing in [`crate::cost`], the incremental fair-share
/// engine in [`crate::netsim::fairshare`]) to its reference
/// implementation. Read once per process — the property suites pass the
/// mode explicitly instead of mutating the environment.
pub fn reference_mode() -> bool {
    static REFERENCE: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *REFERENCE.get_or_init(|| {
        std::env::var("NEST_REFERENCE").map(|v| v == "1").unwrap_or(false)
    })
}

/// Resolve a thread-count option (0 = available parallelism). Shared by
/// every fan-out site (solver workers, netsim component workers) so
/// `--threads` means the same thing everywhere.
pub fn resolve_threads(requested: usize) -> usize {
    if requested > 0 {
        requested
    } else {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    }
}
