//! Self-contained utility substrates.
//!
//! The build environment is fully offline with only the `xla` crate
//! vendored, so the usual ecosystem crates (serde, rand, clap, criterion,
//! proptest) are re-implemented here at the scale this project needs:
//!
//! * [`json`]   — JSON parser/serializer for configs and artifacts metadata.
//! * [`rng`]    — deterministic SplitMix64/PCG RNG (MCMC baseline, tests).
//! * [`cli`]    — flag/option parsing for the `nest` binary and examples.
//! * [`table`]  — fixed-width table pretty-printer for paper tables.
//! * [`csv`]    — CSV writer for `results/*.csv`.
//! * [`stats`]  — mean/median/stddev helpers.
//! * [`bench`]  — mini-criterion: warmup + timed iterations + report.
//! * [`prop`]   — tiny property-testing loop driver over seeded RNGs.

pub mod bench;
pub mod cli;
pub mod csv;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
