//! Mini-criterion: a self-contained micro-benchmark harness.
//!
//! `cargo bench` targets use `harness = false` and drive this module:
//! warmup, calibrated iteration counts, mean/stddev/min reporting, and a
//! machine-readable line (`BENCH <name> mean_ns=<..>`) that the perf pass
//! in EXPERIMENTS.md greps for.

use std::hint::black_box;
use std::time::{Duration, Instant};

use super::stats;

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub stddev: Duration,
    pub min: Duration,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "BENCH {:40} iters={:<8} mean_ns={:<14.0} stddev_ns={:<12.0} min_ns={:.0}",
            self.name,
            self.iters,
            self.mean.as_nanos() as f64,
            self.stddev.as_nanos() as f64,
            self.min.as_nanos() as f64,
        );
    }
}

/// Benchmark `f`, returning timing statistics.
///
/// Runs a short warmup, then picks an iteration count targeting ~1s of
/// total measurement split into 10 samples.
pub fn bench<T>(name: &str, mut f: impl FnMut() -> T) -> BenchResult {
    // Warmup + calibration: run until 50ms elapsed, counting iterations.
    let warm_start = Instant::now();
    let mut warm_iters: u64 = 0;
    while warm_start.elapsed() < Duration::from_millis(50) {
        black_box(f());
        warm_iters += 1;
    }
    let per_iter = warm_start.elapsed().as_secs_f64() / warm_iters as f64;

    // Target ~1s of measurement across 10 samples, ≥1 iter per sample.
    let samples = 10usize;
    let iters_per_sample = ((1.0 / samples as f64) / per_iter).max(1.0) as u64;

    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        for _ in 0..iters_per_sample {
            black_box(f());
        }
        times.push(t0.elapsed().as_secs_f64() / iters_per_sample as f64);
    }

    let res = BenchResult {
        name: name.to_string(),
        iters: iters_per_sample * samples as u64,
        mean: Duration::from_secs_f64(stats::mean(&times)),
        stddev: Duration::from_secs_f64(stats::stddev(&times)),
        min: Duration::from_secs_f64(
            times.iter().cloned().fold(f64::INFINITY, f64::min),
        ),
    };
    res.report();
    res
}

/// Benchmark a function that is too slow for the 1s-budget loop: runs it
/// exactly `n` times and reports.
pub fn bench_n<T>(name: &str, n: usize, mut f: impl FnMut() -> T) -> BenchResult {
    let mut times = Vec::with_capacity(n);
    for _ in 0..n {
        let t0 = Instant::now();
        black_box(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    let res = BenchResult {
        name: name.to_string(),
        iters: n as u64,
        mean: Duration::from_secs_f64(stats::mean(&times)),
        stddev: Duration::from_secs_f64(stats::stddev(&times)),
        min: Duration::from_secs_f64(
            times.iter().cloned().fold(f64::INFINITY, f64::min),
        ),
    };
    res.report();
    res
}

/// Report the speedup of `fast` over `base` (ratio of mean times) in the
/// machine-readable BENCH format the perf pass greps for. Returns the
/// speedup factor.
pub fn report_speedup(name: &str, base: &BenchResult, fast: &BenchResult) -> f64 {
    let base_s = base.mean.as_secs_f64();
    let fast_s = fast.mean.as_secs_f64().max(1e-12);
    let speedup = base_s / fast_s;
    println!(
        "BENCH {:40} speedup={:<8.2} base_ns={:<14.0} fast_ns={:.0}",
        name,
        speedup,
        base.mean.as_nanos() as f64,
        fast.mean.as_nanos() as f64,
    );
    speedup
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let r = bench_n("noop", 5, || 1 + 1);
        assert_eq!(r.iters, 5);
        assert!(r.min <= r.mean || r.mean.as_nanos() == 0);
    }

    #[test]
    fn bench_fast_fn() {
        let r = bench("add", || black_box(3u64) + black_box(4u64));
        assert!(r.iters >= 10);
        assert!(r.mean.as_secs_f64() < 0.01);
    }

    #[test]
    fn speedup_is_base_over_fast() {
        let mk = |ns: u64| BenchResult {
            name: "x".into(),
            iters: 1,
            mean: Duration::from_nanos(ns),
            stddev: Duration::ZERO,
            min: Duration::from_nanos(ns),
        };
        let s = report_speedup("pair", &mk(4000), &mk(1000));
        assert!((s - 4.0).abs() < 1e-9);
    }
}
