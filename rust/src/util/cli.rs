//! Minimal command-line flag parsing for the `nest` binary and examples.
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments. Unknown flags are an error so typos surface immediately.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
    /// Flags/options the caller has declared, for unknown-flag detection.
    known: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping the binary name).
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1).collect())
    }

    pub fn parse(raw: Vec<String>) -> Self {
        let mut a = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    a.opts.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    a.opts.insert(body.to_string(), v);
                } else {
                    a.flags.push(body.to_string());
                }
            } else {
                a.positional.push(tok);
            }
        }
        a
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// String option with a default.
    pub fn get(&mut self, key: &str, default: &str) -> String {
        self.known.push(key.to_string());
        self.opts.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Optional string option.
    pub fn get_opt(&mut self, key: &str) -> Option<String> {
        self.known.push(key.to_string());
        self.opts.get(key).cloned()
    }

    /// usize option with a default; panics with a clear message on garbage.
    pub fn get_usize(&mut self, key: &str, default: usize) -> usize {
        self.known.push(key.to_string());
        match self.opts.get(key) {
            None => default,
            Some(v) => v
                .parse()
                .unwrap_or_else(|_| panic!("--{key} expects an integer, got '{v}'")),
        }
    }

    /// f64 option with a default.
    pub fn get_f64(&mut self, key: &str, default: f64) -> f64 {
        self.known.push(key.to_string());
        match self.opts.get(key) {
            None => default,
            Some(v) => v
                .parse()
                .unwrap_or_else(|_| panic!("--{key} expects a number, got '{v}'")),
        }
    }

    /// Boolean flag (present or absent).
    pub fn has_flag(&mut self, key: &str) -> bool {
        self.known.push(key.to_string());
        self.flags.iter().any(|f| f == key)
    }

    /// Call after all get_* calls: errors on unrecognized flags/options.
    pub fn finish(&self) -> Result<(), String> {
        for k in self.opts.keys() {
            if !self.known.contains(k) {
                return Err(format!("unknown option --{k}"));
            }
        }
        for f in &self.flags {
            if !self.known.contains(f) {
                return Err(format!("unknown flag --{f}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_forms() {
        let mut a = Args::parse(v(&["solve", "--model=gpt3-175b", "--devices", "512", "--verbose"]));
        assert_eq!(a.positional(), &["solve".to_string()]);
        assert_eq!(a.get("model", "x"), "gpt3-175b");
        assert_eq!(a.get_usize("devices", 64), 512);
        assert!(a.has_flag("verbose"));
        assert!(a.finish().is_ok());
    }

    #[test]
    fn defaults_apply() {
        let mut a = Args::parse(v(&[]));
        assert_eq!(a.get_usize("devices", 64), 64);
        assert_eq!(a.get_f64("oversub", 2.0), 2.0);
        assert!(!a.has_flag("verbose"));
    }

    #[test]
    fn unknown_flag_detected() {
        let mut a = Args::parse(v(&["--bogus", "1"]));
        let _ = a.get("model", "x");
        assert!(a.finish().is_err());
    }

    #[test]
    #[should_panic]
    fn bad_int_panics() {
        let mut a = Args::parse(v(&["--devices", "many"]));
        a.get_usize("devices", 1);
    }
}
