//! Minimal command-line flag parsing for the `nest` binary and examples.
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments. Unknown flags are an error so typos surface immediately.
//! Malformed numeric values are *clean errors*, not panics: `get_usize` /
//! `get_f64` record the problem and return the default, and the first
//! recorded error surfaces through [`Args::check`] / [`Args::finish`].

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
    /// Flags/options the caller has declared, for unknown-flag detection.
    known: Vec<String>,
    /// Validation problems recorded by the get_* accessors.
    errors: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping the binary name).
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1).collect())
    }

    pub fn parse(raw: Vec<String>) -> Self {
        let mut a = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    a.opts.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    a.opts.insert(body.to_string(), v);
                } else {
                    a.flags.push(body.to_string());
                }
            } else {
                a.positional.push(tok);
            }
        }
        a
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// String option with a default.
    pub fn get(&mut self, key: &str, default: &str) -> String {
        self.known.push(key.to_string());
        self.opts.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Optional string option.
    pub fn get_opt(&mut self, key: &str) -> Option<String> {
        self.known.push(key.to_string());
        self.opts.get(key).cloned()
    }

    /// usize option with a default; garbage records a clean error (see
    /// [`Args::check`]) and returns the default.
    pub fn get_usize(&mut self, key: &str, default: usize) -> usize {
        self.known.push(key.to_string());
        match self.opts.get(key) {
            None => default,
            Some(v) => match v.parse() {
                Ok(n) => n,
                Err(_) => {
                    self.errors
                        .push(format!("--{key} expects an integer, got '{v}'"));
                    default
                }
            },
        }
    }

    /// Like [`Args::get_usize`], but an *explicitly supplied* 0 is a
    /// clean error (the default itself may be 0, e.g. `--threads`'s
    /// "one worker per core" sentinel). Returns `max(default, 1)` on
    /// rejection so callers stay well-defined until the error surfaces.
    pub fn get_usize_nonzero(&mut self, key: &str, default: usize) -> usize {
        let v = self.get_usize(key, default);
        if v == 0 && self.opts.contains_key(key) {
            self.errors.push(format!(
                "--{key} must be ≥ 1 (omit the flag for the default)"
            ));
            return default.max(1);
        }
        v
    }

    /// Optional *output-file* path option (e.g. `--trace out.json`):
    /// validates that the value is plausibly writable *before* the
    /// expensive run, mirroring [`Args::get_usize_nonzero`]'s
    /// record-and-continue error style. Rejected with a clean error (and
    /// `None` returned): an empty value, a path that names an existing
    /// directory, or a path whose parent directory does not exist (or is
    /// not a directory). An existing *file* is accepted — output paths
    /// overwrite.
    pub fn get_out_path(&mut self, key: &str) -> Option<String> {
        self.known.push(key.to_string());
        let v = self.opts.get(key).cloned()?;
        if v.is_empty() {
            self.errors
                .push(format!("--{key} expects a file path, got an empty string"));
            return None;
        }
        let path = std::path::Path::new(&v);
        if path.is_dir() {
            self.errors
                .push(format!("--{key} path '{v}' is an existing directory"));
            return None;
        }
        // Parent "" means the current directory (plain file name) —
        // always fine. Anything else must already exist as a directory.
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() && !parent.is_dir() {
                self.errors.push(format!(
                    "--{key} path '{v}': parent directory '{}' does not exist",
                    parent.display()
                ));
                return None;
            }
        }
        Some(v)
    }

    /// Enumerated string option: the value must be one of `choices`
    /// (e.g. `--mode auto|monolithic|decomposed`). A value outside the
    /// set records a clean error listing the alternatives and returns
    /// the default — the record-and-continue style of the numeric
    /// accessors, so every sim-touching subcommand rejects the same
    /// inputs with the same message.
    pub fn get_choice(&mut self, key: &str, choices: &[&str], default: &str) -> String {
        debug_assert!(choices.contains(&default), "default must be a choice");
        self.known.push(key.to_string());
        match self.opts.get(key) {
            None => default.to_string(),
            Some(v) if choices.iter().any(|c| c == v) => v.clone(),
            Some(v) => {
                self.errors.push(format!(
                    "--{key} expects one of {}, got '{v}'",
                    choices.join("|")
                ));
                default.to_string()
            }
        }
    }

    /// f64 option with a default; garbage records a clean error (see
    /// [`Args::check`]) and returns the default.
    pub fn get_f64(&mut self, key: &str, default: f64) -> f64 {
        self.known.push(key.to_string());
        match self.opts.get(key) {
            None => default,
            Some(v) => match v.parse() {
                Ok(n) => n,
                Err(_) => {
                    self.errors
                        .push(format!("--{key} expects a number, got '{v}'"));
                    default
                }
            },
        }
    }

    /// Like [`Args::get_f64`], but additionally requires the value to
    /// lie in `[min, max]` (inclusive) and be finite. Out-of-range or
    /// non-finite values record a clean error naming the accepted range
    /// and return the default — the record-and-continue style of the
    /// other accessors, so `--bg-load 1.5` and `--fault-severity 2`
    /// reject with the same message shape everywhere.
    pub fn get_f64_in_range(&mut self, key: &str, default: f64, min: f64, max: f64) -> f64 {
        debug_assert!(
            min <= max && (min..=max).contains(&default),
            "default must lie in [min, max]"
        );
        let v = self.get_f64(key, default);
        if !v.is_finite() || v < min || v > max {
            self.errors.push(format!(
                "--{key} expects a number in [{min}, {max}], got '{v}'"
            ));
            return default;
        }
        v
    }

    /// Boolean flag (present or absent).
    pub fn has_flag(&mut self, key: &str) -> bool {
        self.known.push(key.to_string());
        self.flags.iter().any(|f| f == key)
    }

    /// First validation error recorded so far by the get_* accessors.
    /// Call right after reading a command's numeric options to fail
    /// *before* doing any expensive work ([`Args::finish`] would only
    /// surface it afterwards).
    pub fn check(&self) -> Result<(), String> {
        match self.errors.first() {
            Some(e) => Err(e.clone()),
            None => Ok(()),
        }
    }

    /// Call after all get_* calls: surfaces recorded validation errors,
    /// then errors on unrecognized flags/options.
    pub fn finish(&self) -> Result<(), String> {
        self.check()?;
        for k in self.opts.keys() {
            if !self.known.contains(k) {
                return Err(format!("unknown option --{k}"));
            }
        }
        for f in &self.flags {
            if !self.known.contains(f) {
                return Err(format!("unknown flag --{f}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_forms() {
        let mut a = Args::parse(v(&["solve", "--model=gpt3-175b", "--devices", "512", "--verbose"]));
        assert_eq!(a.positional(), &["solve".to_string()]);
        assert_eq!(a.get("model", "x"), "gpt3-175b");
        assert_eq!(a.get_usize("devices", 64), 512);
        assert!(a.has_flag("verbose"));
        assert!(a.finish().is_ok());
    }

    #[test]
    fn defaults_apply() {
        let mut a = Args::parse(v(&[]));
        assert_eq!(a.get_usize("devices", 64), 64);
        assert_eq!(a.get_f64("oversub", 2.0), 2.0);
        assert!(!a.has_flag("verbose"));
    }

    #[test]
    fn unknown_flag_detected() {
        let mut a = Args::parse(v(&["--bogus", "1"]));
        let _ = a.get("model", "x");
        assert!(a.finish().is_err());
    }

    #[test]
    fn bad_int_is_clean_error_not_panic() {
        let mut a = Args::parse(v(&["--devices", "many"]));
        assert_eq!(a.get_usize("devices", 1), 1);
        let err = a.check().unwrap_err();
        assert!(err.contains("--devices"), "unexpected message: {err}");
        assert!(a.finish().is_err());
    }

    #[test]
    fn bad_float_is_clean_error_not_panic() {
        let mut a = Args::parse(v(&["--oversub", "wide"]));
        assert_eq!(a.get_f64("oversub", 2.0), 2.0);
        assert!(a.finish().is_err());
    }

    #[test]
    fn explicit_zero_rejected_by_nonzero() {
        // --threads 0 / --topk 0 must be clean errors, not silent hangs.
        for key in ["threads", "topk"] {
            let mut a = Args::parse(v(&[&format!("--{key}"), "0"]));
            let got = a.get_usize_nonzero(key, 0);
            assert!(got >= 1, "--{key} 0 returned {got}");
            let err = a.check().unwrap_err();
            assert!(err.contains("≥ 1"), "unexpected message: {err}");
        }
    }

    #[test]
    fn nonzero_allows_zero_default_and_positive_values() {
        // Absent flag: a 0 default (threads' "all cores" sentinel) is fine.
        let mut a = Args::parse(v(&[]));
        assert_eq!(a.get_usize_nonzero("threads", 0), 0);
        assert!(a.check().is_ok());
        // Explicit positive value passes through.
        let mut a = Args::parse(v(&["--topk", "4"]));
        assert_eq!(a.get_usize_nonzero("topk", 1), 4);
        assert!(a.finish().is_ok());
    }

    #[test]
    fn out_path_accepts_plain_and_nested_writable_paths() {
        // Plain file name in the current directory.
        let mut a = Args::parse(v(&["--trace", "out.json"]));
        assert_eq!(a.get_out_path("trace"), Some("out.json".to_string()));
        assert!(a.finish().is_ok());
        // Existing parent directory.
        let dir = std::env::temp_dir().join("nest_cli_out_path_ok");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.json");
        let mut a = Args::parse(v(&["--trace", p.to_str().unwrap()]));
        assert_eq!(a.get_out_path("trace"), Some(p.to_str().unwrap().to_string()));
        assert!(a.check().is_ok());
        // Absent flag: None, no error.
        let mut a = Args::parse(v(&[]));
        assert_eq!(a.get_out_path("trace"), None);
        assert!(a.finish().is_ok());
    }

    #[test]
    fn out_path_rejects_empty_dir_and_missing_parent() {
        // Empty string (`--trace=`).
        let mut a = Args::parse(v(&["--trace="]));
        assert_eq!(a.get_out_path("trace"), None);
        assert!(a.check().unwrap_err().contains("empty"), "{:?}", a.check());
        // Existing directory.
        let dir = std::env::temp_dir().join("nest_cli_out_path_dir");
        std::fs::create_dir_all(&dir).unwrap();
        let mut a = Args::parse(v(&["--trace", dir.to_str().unwrap()]));
        assert_eq!(a.get_out_path("trace"), None);
        let err = a.check().unwrap_err();
        assert!(err.contains("existing directory"), "unexpected: {err}");
        // Nonexistent parent directory.
        let mut a = Args::parse(v(&["--trace", "no/such/dir/t.json"]));
        assert_eq!(a.get_out_path("trace"), None);
        let err = a.check().unwrap_err();
        assert!(err.contains("parent directory"), "unexpected: {err}");
        assert!(a.finish().is_err());
    }

    #[test]
    fn choice_accepts_listed_values_and_defaults() {
        const MODES: &[&str] = &["auto", "monolithic", "decomposed"];
        let mut a = Args::parse(v(&["--mode", "decomposed"]));
        assert_eq!(a.get_choice("mode", MODES, "auto"), "decomposed");
        assert!(a.finish().is_ok());
        // Absent flag: the default, no error.
        let mut a = Args::parse(v(&[]));
        assert_eq!(a.get_choice("mode", MODES, "auto"), "auto");
        assert!(a.finish().is_ok());
    }

    #[test]
    fn choice_rejects_unlisted_value_with_alternatives() {
        const MODES: &[&str] = &["auto", "monolithic", "decomposed"];
        let mut a = Args::parse(v(&["--mode", "turbo"]));
        assert_eq!(a.get_choice("mode", MODES, "auto"), "auto");
        let err = a.check().unwrap_err();
        assert!(
            err.contains("auto|monolithic|decomposed") && err.contains("turbo"),
            "unexpected message: {err}"
        );
        assert!(a.finish().is_err());
    }

    #[test]
    fn in_range_accepts_bounds_and_interior() {
        for val in ["0", "0.5", "1"] {
            let mut a = Args::parse(v(&["--fault-severity", val]));
            let got = a.get_f64_in_range("fault-severity", 0.6, 0.0, 1.0);
            assert_eq!(got, val.parse::<f64>().unwrap());
            assert!(a.finish().is_ok(), "--fault-severity {val} rejected");
        }
        // Absent flag: the default, no error.
        let mut a = Args::parse(v(&[]));
        assert_eq!(a.get_f64_in_range("bg-load", 0.4, 0.0, 1.0), 0.4);
        assert!(a.check().is_ok());
    }

    #[test]
    fn in_range_rejects_outside_and_non_finite_with_the_range() {
        for val in ["1.5", "-0.1", "inf", "NaN"] {
            let mut a = Args::parse(v(&["--fault-severity", val]));
            let got = a.get_f64_in_range("fault-severity", 0.6, 0.0, 1.0);
            assert_eq!(got, 0.6, "--fault-severity {val} did not fall back");
            let err = a.check().unwrap_err();
            assert!(
                err.contains("--fault-severity") && err.contains("[0, 1]"),
                "unexpected message for {val}: {err}"
            );
            assert!(a.finish().is_err());
        }
        // Garbage still surfaces through the underlying get_f64 message.
        let mut a = Args::parse(v(&["--bg-load", "heavy"]));
        assert_eq!(a.get_f64_in_range("bg-load", 0.4, 0.0, 1.0), 0.4);
        let err = a.check().unwrap_err();
        assert!(err.contains("expects a number"), "unexpected: {err}");
    }

    #[test]
    fn check_fails_before_finish_on_garbage() {
        let mut a = Args::parse(v(&["--topk", "four"]));
        let _ = a.get_usize_nonzero("topk", 4);
        assert!(a.check().is_err());
    }
}
