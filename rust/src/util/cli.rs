//! Minimal command-line flag parsing for the `nest` binary and examples.
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments. Unknown flags are an error so typos surface immediately.
//! Malformed numeric values are *clean errors*, not panics: `get_usize` /
//! `get_f64` record the problem and return the default, and the first
//! recorded error surfaces through [`Args::check`] / [`Args::finish`].

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
    /// Flags/options the caller has declared, for unknown-flag detection.
    known: Vec<String>,
    /// Validation problems recorded by the get_* accessors.
    errors: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping the binary name).
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1).collect())
    }

    pub fn parse(raw: Vec<String>) -> Self {
        let mut a = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    a.opts.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    a.opts.insert(body.to_string(), v);
                } else {
                    a.flags.push(body.to_string());
                }
            } else {
                a.positional.push(tok);
            }
        }
        a
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// String option with a default.
    pub fn get(&mut self, key: &str, default: &str) -> String {
        self.known.push(key.to_string());
        self.opts.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Optional string option.
    pub fn get_opt(&mut self, key: &str) -> Option<String> {
        self.known.push(key.to_string());
        self.opts.get(key).cloned()
    }

    /// usize option with a default; garbage records a clean error (see
    /// [`Args::check`]) and returns the default.
    pub fn get_usize(&mut self, key: &str, default: usize) -> usize {
        self.known.push(key.to_string());
        match self.opts.get(key) {
            None => default,
            Some(v) => match v.parse() {
                Ok(n) => n,
                Err(_) => {
                    self.errors
                        .push(format!("--{key} expects an integer, got '{v}'"));
                    default
                }
            },
        }
    }

    /// Like [`Args::get_usize`], but an *explicitly supplied* 0 is a
    /// clean error (the default itself may be 0, e.g. `--threads`'s
    /// "one worker per core" sentinel). Returns `max(default, 1)` on
    /// rejection so callers stay well-defined until the error surfaces.
    pub fn get_usize_nonzero(&mut self, key: &str, default: usize) -> usize {
        let v = self.get_usize(key, default);
        if v == 0 && self.opts.contains_key(key) {
            self.errors.push(format!(
                "--{key} must be ≥ 1 (omit the flag for the default)"
            ));
            return default.max(1);
        }
        v
    }

    /// f64 option with a default; garbage records a clean error (see
    /// [`Args::check`]) and returns the default.
    pub fn get_f64(&mut self, key: &str, default: f64) -> f64 {
        self.known.push(key.to_string());
        match self.opts.get(key) {
            None => default,
            Some(v) => match v.parse() {
                Ok(n) => n,
                Err(_) => {
                    self.errors
                        .push(format!("--{key} expects a number, got '{v}'"));
                    default
                }
            },
        }
    }

    /// Boolean flag (present or absent).
    pub fn has_flag(&mut self, key: &str) -> bool {
        self.known.push(key.to_string());
        self.flags.iter().any(|f| f == key)
    }

    /// First validation error recorded so far by the get_* accessors.
    /// Call right after reading a command's numeric options to fail
    /// *before* doing any expensive work ([`Args::finish`] would only
    /// surface it afterwards).
    pub fn check(&self) -> Result<(), String> {
        match self.errors.first() {
            Some(e) => Err(e.clone()),
            None => Ok(()),
        }
    }

    /// Call after all get_* calls: surfaces recorded validation errors,
    /// then errors on unrecognized flags/options.
    pub fn finish(&self) -> Result<(), String> {
        self.check()?;
        for k in self.opts.keys() {
            if !self.known.contains(k) {
                return Err(format!("unknown option --{k}"));
            }
        }
        for f in &self.flags {
            if !self.known.contains(f) {
                return Err(format!("unknown flag --{f}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_forms() {
        let mut a = Args::parse(v(&["solve", "--model=gpt3-175b", "--devices", "512", "--verbose"]));
        assert_eq!(a.positional(), &["solve".to_string()]);
        assert_eq!(a.get("model", "x"), "gpt3-175b");
        assert_eq!(a.get_usize("devices", 64), 512);
        assert!(a.has_flag("verbose"));
        assert!(a.finish().is_ok());
    }

    #[test]
    fn defaults_apply() {
        let mut a = Args::parse(v(&[]));
        assert_eq!(a.get_usize("devices", 64), 64);
        assert_eq!(a.get_f64("oversub", 2.0), 2.0);
        assert!(!a.has_flag("verbose"));
    }

    #[test]
    fn unknown_flag_detected() {
        let mut a = Args::parse(v(&["--bogus", "1"]));
        let _ = a.get("model", "x");
        assert!(a.finish().is_err());
    }

    #[test]
    fn bad_int_is_clean_error_not_panic() {
        let mut a = Args::parse(v(&["--devices", "many"]));
        assert_eq!(a.get_usize("devices", 1), 1);
        let err = a.check().unwrap_err();
        assert!(err.contains("--devices"), "unexpected message: {err}");
        assert!(a.finish().is_err());
    }

    #[test]
    fn bad_float_is_clean_error_not_panic() {
        let mut a = Args::parse(v(&["--oversub", "wide"]));
        assert_eq!(a.get_f64("oversub", 2.0), 2.0);
        assert!(a.finish().is_err());
    }

    #[test]
    fn explicit_zero_rejected_by_nonzero() {
        // --threads 0 / --topk 0 must be clean errors, not silent hangs.
        for key in ["threads", "topk"] {
            let mut a = Args::parse(v(&[&format!("--{key}"), "0"]));
            let got = a.get_usize_nonzero(key, 0);
            assert!(got >= 1, "--{key} 0 returned {got}");
            let err = a.check().unwrap_err();
            assert!(err.contains("≥ 1"), "unexpected message: {err}");
        }
    }

    #[test]
    fn nonzero_allows_zero_default_and_positive_values() {
        // Absent flag: a 0 default (threads' "all cores" sentinel) is fine.
        let mut a = Args::parse(v(&[]));
        assert_eq!(a.get_usize_nonzero("threads", 0), 0);
        assert!(a.check().is_ok());
        // Explicit positive value passes through.
        let mut a = Args::parse(v(&["--topk", "4"]));
        assert_eq!(a.get_usize_nonzero("topk", 1), 4);
        assert!(a.finish().is_ok());
    }

    #[test]
    fn check_fails_before_finish_on_garbage() {
        let mut a = Args::parse(v(&["--topk", "four"]));
        let _ = a.get_usize_nonzero("topk", 4);
        assert!(a.check().is_err());
    }
}
