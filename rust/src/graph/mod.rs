//! Operator/layer graph representation (§3.2 "Graph Extraction").
//!
//! The paper extracts operator graphs from training scripts with torch.fx
//! and groups them into layers; all evaluated workloads (Table 2) are
//! transformer *chains* — embedding → N blocks → LM head — so a *downset*
//! of the graph is a suffix and the DP's downset index is a suffix start
//! (DESIGN.md §1). Each layer carries the structural dimensions needed to
//! derive FLOPs, parameter counts, activation footprints, and collective
//! traffic under any SUB-GRAPH parallelism configuration; the actual
//! sharded quantities are computed in [`subgraph`].
//!
//! Ground truth for these analytical annotations is validated against the
//! L2 JAX model's real HLO artifacts by the Table 6 harness.

pub mod models;
pub mod subgraph;

use subgraph::SgConfig;

/// Mixture-of-Experts configuration for a block.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MoeCfg {
    pub experts: usize,
    pub top_k: usize,
}

/// What a layer is. `Block` covers one full transformer layer
/// (attention + MLP); `MoeBlock` replaces the MLP with routed experts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerKind {
    Embedding,
    Block,
    MoeBlock(MoeCfg),
    /// LM head / classifier projection.
    Head,
}

/// Structural dimensions of the model a layer belongs to.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Dims {
    pub hidden: usize,
    pub heads: usize,
    /// Key/value heads (GQA); equals `heads` for MHA models.
    pub kv_heads: usize,
    pub intermediate: usize,
    pub seq: usize,
    pub vocab: usize,
    /// Gated (SwiGLU, 3 projections) vs plain (GELU, 2 projections) MLP.
    pub gated_mlp: bool,
}

impl Dims {
    pub fn head_dim(&self) -> usize {
        self.hidden / self.heads
    }
    pub fn kv_dim(&self) -> usize {
        self.kv_heads * self.head_dim()
    }
    /// Number of MLP weight matrices (2 plain, 3 gated).
    pub fn mlp_mats(&self) -> usize {
        if self.gated_mlp {
            3
        } else {
            2
        }
    }
}

/// One layer of the chain graph.
#[derive(Debug, Clone, PartialEq)]
pub struct Layer {
    pub name: String,
    pub kind: LayerKind,
    pub dims: Dims,
}

/// Bytes per element of the training dtype (bf16).
pub const DTYPE_BYTES: f64 = 2.0;

impl Layer {
    // ----- parameters ---------------------------------------------------

    /// Total parameter count of the *unsharded* layer.
    pub fn param_count(&self) -> f64 {
        let d = &self.dims;
        let h = d.hidden as f64;
        match self.kind {
            LayerKind::Embedding | LayerKind::Head => d.vocab as f64 * h,
            LayerKind::Block => attn_params(d) + mlp_params(d),
            LayerKind::MoeBlock(moe) => {
                attn_params(d) + moe.experts as f64 * mlp_params(d) + router_params(d, moe)
            }
        }
    }

    /// Parameter count resident on one device under `sg` (tensor/expert
    /// sharding divides the respective components).
    pub fn param_count_sharded(&self, sg: &SgConfig) -> f64 {
        let d = &self.dims;
        let t = sg.tp as f64;
        match self.kind {
            // Embedding/head shard their vocab dimension across TP ranks.
            LayerKind::Embedding | LayerKind::Head => self.param_count() / t,
            LayerKind::Block => (attn_params(d) + mlp_params(d)) / t,
            LayerKind::MoeBlock(moe) => {
                let e = sg.ep.min(moe.experts) as f64;
                attn_params(d) / t
                    + moe.experts as f64 * mlp_params(d) / (e * t)
                    + router_params(d, moe)
            }
        }
    }

    // ----- compute ------------------------------------------------------

    /// Dense matmul FLOPs for the forward pass of one microbatch of
    /// `tokens` tokens, per device, under `sg`. Backward is 2× this.
    pub fn matmul_flops_fwd(&self, tokens: f64, sg: &SgConfig) -> f64 {
        let d = &self.dims;
        let t = sg.tp as f64;
        let c = sg.cp as f64;
        let local_tokens = tokens / c; // CP splits the sequence
        match self.kind {
            LayerKind::Embedding => 0.0, // gather, no matmul
            LayerKind::Head => 2.0 * local_tokens * d.vocab as f64 * d.hidden as f64 / t,
            LayerKind::Block => {
                let proj = 2.0 * local_tokens * (attn_params(d) + mlp_params(d)) / t;
                proj + attn_score_flops(d, local_tokens) / t
            }
            LayerKind::MoeBlock(moe) => {
                let e = sg.ep.min(moe.experts) as f64;
                let attn = 2.0 * local_tokens * attn_params(d) / t + attn_score_flops(d, local_tokens) / t;
                // Each token activates top_k experts; expert parallelism
                // spreads the expert-token pairs over e groups.
                let moe_flops =
                    2.0 * local_tokens * moe.top_k as f64 * mlp_params(d) / (e * t);
                attn + moe_flops
            }
        }
    }

    /// Vector-unit FLOPs (norms, softmax, activation functions) forward.
    pub fn vector_flops_fwd(&self, tokens: f64, sg: &SgConfig) -> f64 {
        let d = &self.dims;
        let local_tokens = tokens / sg.cp as f64;
        let h = d.hidden as f64;
        match self.kind {
            LayerKind::Embedding => 2.0 * local_tokens * h,
            LayerKind::Head => 5.0 * local_tokens * d.vocab as f64, // softmax+xent
            LayerKind::Block | LayerKind::MoeBlock(_) => {
                let t = sg.tp as f64;
                // 2 norms (~8h), softmax over seq (~5·seq per head),
                // activation fn (~8·intermediate).
                let softmax = 5.0 * d.seq as f64 * d.heads as f64 / (t * sg.cp as f64);
                local_tokens * (16.0 * h + softmax + 8.0 * d.intermediate as f64 / t)
            }
        }
    }

    /// HBM bytes moved in the forward pass (weights + activations read and
    /// written once), per device — the memory-bound roofline term.
    pub fn hbm_bytes_fwd(&self, tokens: f64, sg: &SgConfig) -> f64 {
        let d = &self.dims;
        let local_tokens = tokens / sg.cp as f64;
        let weight_bytes = self.param_count_sharded(sg) * DTYPE_BYTES;
        let act_bytes = 6.0 * local_tokens * d.hidden as f64 * DTYPE_BYTES;
        weight_bytes + act_bytes
    }

    // ----- memory -------------------------------------------------------

    /// Activation bytes stashed for the backward pass of one microbatch
    /// (per device). Follows the Megatron selective-recompute accounting:
    /// without recompute a transformer block stashes
    /// `seq·b·h·(34 + 5·a·seq/h)` bytes; with recompute only the
    /// stage-boundary input (`2·tokens·h`) survives (§3.3).
    pub fn act_stash_bytes(&self, tokens: f64, sg: &SgConfig, recompute: bool) -> f64 {
        let d = &self.dims;
        let t = sg.tp as f64;
        let c = sg.cp as f64;
        let local_tokens = tokens / c;
        let h = d.hidden as f64;
        if recompute {
            return DTYPE_BYTES * local_tokens * h;
        }
        match self.kind {
            LayerKind::Embedding => DTYPE_BYTES * local_tokens * h,
            LayerKind::Head => DTYPE_BYTES * local_tokens * h,
            LayerKind::Block | LayerKind::MoeBlock(_) => {
                let attn_quad = 5.0 * d.heads as f64 * (d.seq as f64 / c) / h;
                let per_token_h = 34.0 / t + attn_quad / t;
                let mut bytes = local_tokens * h * per_token_h;
                if let LayerKind::MoeBlock(moe) = self.kind {
                    // Routed activations scale with top_k.
                    bytes *= moe.top_k as f64;
                }
                bytes
            }
        }
    }

    /// Bytes of the activation tensor crossing to the *next* layer for one
    /// microbatch (the pipeline p2p volume).
    pub fn boundary_bytes(&self, tokens: f64, sg: &SgConfig) -> f64 {
        let local_tokens = tokens / sg.cp as f64;
        // With sequence parallelism the boundary tensor is sharded over t.
        let shard = if sg.sp { sg.tp as f64 } else { 1.0 };
        DTYPE_BYTES * local_tokens * self.dims.hidden as f64 / shard
    }
}

fn attn_params(d: &Dims) -> f64 {
    let h = d.hidden as f64;
    // Q and O are h×h; K and V are h×kv_dim (GQA).
    2.0 * h * h + 2.0 * h * d.kv_dim() as f64
}

fn mlp_params(d: &Dims) -> f64 {
    d.mlp_mats() as f64 * d.hidden as f64 * d.intermediate as f64
}

fn router_params(d: &Dims, moe: MoeCfg) -> f64 {
    d.hidden as f64 * moe.experts as f64
}

/// Attention score FLOPs (QKᵀ and PV) for `tokens` query tokens against
/// the full sequence: `4 · tokens · seq · hidden`.
fn attn_score_flops(d: &Dims, tokens: f64) -> f64 {
    4.0 * tokens * d.seq as f64 * d.hidden as f64
}

/// A chain-structured layer graph for one (model, microbatch) pair.
#[derive(Debug, Clone)]
pub struct LayerGraph {
    pub model_name: String,
    pub layers: Vec<Layer>,
    /// Microbatch size (sequences per microbatch).
    pub mbs: usize,
    /// Tokens per microbatch = mbs · seq.
    pub tokens: f64,
    /// Global batch size (sequences) — 4096 in the paper unless stated.
    pub global_batch: usize,
    /// Allowed SUB-GRAPH degrees for this model (Table 2 columns).
    pub tp_widths: Vec<usize>,
    pub ep_degrees: Vec<usize>,
    pub cp_degrees: Vec<usize>,
}

impl LayerGraph {
    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Total parameter count (unsharded) — sanity metric vs. the paper.
    pub fn total_params(&self) -> f64 {
        self.layers.iter().map(|l| l.param_count()).sum()
    }

    /// Sum of dense forward matmul FLOPs per microbatch (unsharded).
    pub fn total_fwd_flops(&self) -> f64 {
        let sg = SgConfig::serial();
        self.layers
            .iter()
            .map(|l| l.matmul_flops_fwd(self.tokens, &sg))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::models::*;
    use super::subgraph::SgConfig;
    use super::*;

    #[test]
    fn param_counts_match_published_sizes() {
        // (model, published params, tolerance)
        let cases: Vec<(LayerGraph, f64, f64)> = vec![
            (gpt3_175b(1), 175e9, 0.05),
            (llama2_7b(1), 6.7e9, 0.08),
            (llama3_70b(1), 70e9, 0.05),
            (bert_large(1), 0.35e9, 0.10),
            (mixtral_8x7b(1), 46.7e9, 0.05),
        ];
        for (g, expect, tol) in cases {
            let p = g.total_params();
            let rel = (p - expect).abs() / expect;
            assert!(
                rel < tol,
                "{}: {:.2}B vs published {:.2}B (rel {:.3})",
                g.model_name,
                p / 1e9,
                expect / 1e9,
                rel
            );
        }
    }

    #[test]
    fn tp_shards_params() {
        let g = gpt3_175b(1);
        let block = &g.layers[1];
        let s1 = block.param_count_sharded(&SgConfig::serial());
        let s4 = block.param_count_sharded(&SgConfig::tp(4));
        assert!((s1 / s4 - 4.0).abs() < 1e-9);
        assert!((s1 - block.param_count()).abs() < 1e-9);
    }

    #[test]
    fn ep_shards_only_experts() {
        let g = mixtral_8x7b(1);
        let block = g
            .layers
            .iter()
            .find(|l| matches!(l.kind, LayerKind::MoeBlock(_)))
            .unwrap();
        let dense = block.param_count_sharded(&SgConfig::serial());
        let mut sg = SgConfig::serial();
        sg.ep = 8;
        let sharded = block.param_count_sharded(&sg);
        // Experts are 8/8 sharded but attention stays: ratio < 8.
        assert!(sharded < dense);
        assert!(dense / sharded < 8.0);
        assert!(dense / sharded > 4.0);
    }

    #[test]
    fn cp_divides_compute_tokens() {
        let g = llama2_7b(1);
        let block = &g.layers[1];
        let f1 = block.matmul_flops_fwd(g.tokens, &SgConfig::serial());
        let mut sg = SgConfig::serial();
        sg.cp = 4;
        let f4 = block.matmul_flops_fwd(g.tokens, &sg);
        assert!((f1 / f4 - 4.0).abs() < 1e-9);
    }

    #[test]
    fn recompute_shrinks_stash() {
        let g = gpt3_175b(1);
        let block = &g.layers[1];
        let sg = SgConfig::serial();
        let full = block.act_stash_bytes(g.tokens, &sg, false);
        let rc = block.act_stash_bytes(g.tokens, &sg, true);
        assert!(full / rc > 10.0, "full {full} vs recompute {rc}");
    }

    #[test]
    fn fwd_flops_approx_6nd_rule() {
        // For dense decoder models fwd flops per token ≈ 2·params
        // (+ attention quadratic term).
        let g = llama2_7b(1);
        let per_token = g.total_fwd_flops() / g.tokens;
        let two_n = 2.0 * g.total_params();
        assert!(per_token > two_n * 0.9 && per_token < two_n * 1.6);
    }

    #[test]
    fn boundary_bytes_sharded_by_sp() {
        let g = gpt3_175b(1);
        let block = &g.layers[1];
        let nosp = block.boundary_bytes(g.tokens, &SgConfig::tp(4));
        let mut sg = SgConfig::tp(4);
        sg.sp = true;
        let sp = block.boundary_bytes(g.tokens, &sg);
        assert!((nosp / sp - 4.0).abs() < 1e-9);
    }
}
