//! SUB-GRAPH parallelism configurations (§3.1).
//!
//! SUB-GRAPH strategies (tensor, sequence, expert, context parallelism)
//! transform a layer's internal execution while preserving the chain
//! dataflow. NEST pre-characterizes their compute/memory/communication
//! effects offline and composes them analytically inside the DP's
//! `load(·)` term — this module enumerates the configurations allowed for
//! a model (Table 2 columns) and derives the collective calls each one
//! issues per microbatch.

use super::{Layer, LayerKind, DTYPE_BYTES};

/// A SUB-GRAPH parallelism configuration. The per-stage device group size
/// is `tp · ep · cp`; sequence parallelism reuses the TP group (Table 2:
/// "sequence-parallel width, if applied, equals tensor model-parallel
/// width").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SgConfig {
    /// Tensor model parallel degree.
    pub tp: usize,
    /// Sequence parallelism on the TP group (Megatron-SP).
    pub sp: bool,
    /// Expert parallel degree (MoE layers only; 1 elsewhere).
    pub ep: usize,
    /// Context parallel degree.
    pub cp: usize,
}

impl SgConfig {
    /// The trivial configuration: no intra-layer parallelism.
    pub fn serial() -> Self {
        SgConfig {
            tp: 1,
            sp: false,
            ep: 1,
            cp: 1,
        }
    }

    pub fn tp(t: usize) -> Self {
        SgConfig {
            tp: t,
            sp: false,
            ep: 1,
            cp: 1,
        }
    }

    /// Devices each stage replica occupies.
    pub fn group_size(&self) -> usize {
        self.tp * self.ep * self.cp
    }

    /// Table-2-style rendering `{t, s, (e, c)}` fragments.
    pub fn describe(&self) -> String {
        format!(
            "t={} s={} e={} c={}",
            self.tp,
            if self.sp { self.tp } else { 1 },
            self.ep,
            self.cp
        )
    }
}

/// The collective operations NEST models (§2, §3.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CollectiveKind {
    AllReduce,
    AllGather,
    ReduceScatter,
    AllToAll,
    /// Point-to-point exchange between two *adjacent* compact blocks of
    /// `group` devices each (pipeline-style boundaries, CP ring steps
    /// between neighboring TP blocks). Priced at the level the block
    /// boundary actually crosses — `Cluster::boundary_level(group)`.
    SendRecv,
}

/// One collective issued inside a stage, over a sub-group of the stage's
/// devices.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CollectiveCall {
    pub kind: CollectiveKind,
    /// Payload bytes per participant.
    pub bytes: f64,
    /// Number of participants.
    pub group: usize,
}

/// Collective calls a layer issues during forward+backward of one
/// microbatch under `sg` (per pipeline replica):
///
/// * TP (no SP): 2 all-reduces fwd + 2 bwd per block, each of the full
///   activation tensor.
/// * TP + SP: the all-reduces become all-gather + reduce-scatter pairs of
///   the same total volume (4 fwd + 4 bwd), halving redundant activation
///   memory instead of latency.
/// * EP: dispatch + combine all-to-alls (2 fwd + 2 bwd), top_k-scaled.
/// * CP: ring exchange of K/V shards — (cp−1) send/recvs each direction.
/// * Embedding/head with TP shard the vocab dim: 1 all-reduce of logits /
///   embedding grads each direction.
pub fn layer_collectives(layer: &Layer, tokens: f64, sg: &SgConfig) -> Vec<CollectiveCall> {
    let mut out = Vec::new();
    let d = &layer.dims;
    let local_tokens = tokens / sg.cp as f64;
    let act = DTYPE_BYTES * local_tokens * d.hidden as f64;

    match layer.kind {
        LayerKind::Embedding | LayerKind::Head => {
            if sg.tp > 1 {
                // Vocab-parallel embedding/head: one all-reduce fwd + bwd.
                for _ in 0..2 {
                    out.push(CollectiveCall {
                        kind: CollectiveKind::AllReduce,
                        bytes: act,
                        group: sg.tp,
                    });
                }
            }
        }
        LayerKind::Block | LayerKind::MoeBlock(_) => {
            if sg.tp > 1 {
                if sg.sp {
                    // 4 (AG+RS) pairs fwd + 4 bwd, sharded volume.
                    for _ in 0..4 {
                        out.push(CollectiveCall {
                            kind: CollectiveKind::AllGather,
                            bytes: act / sg.tp as f64,
                            group: sg.tp,
                        });
                        out.push(CollectiveCall {
                            kind: CollectiveKind::ReduceScatter,
                            bytes: act / sg.tp as f64,
                            group: sg.tp,
                        });
                    }
                } else {
                    // 2 all-reduces fwd + 2 bwd.
                    for _ in 0..4 {
                        out.push(CollectiveCall {
                            kind: CollectiveKind::AllReduce,
                            bytes: act,
                            group: sg.tp,
                        });
                    }
                }
            }
            if let LayerKind::MoeBlock(moe) = layer.kind {
                let e = sg.ep.min(moe.experts);
                if e > 1 {
                    let routed = act * moe.top_k as f64;
                    // dispatch + combine, forward and backward.
                    for _ in 0..4 {
                        out.push(CollectiveCall {
                            kind: CollectiveKind::AllToAll,
                            bytes: routed,
                            group: e,
                        });
                    }
                }
            }
            if sg.cp > 1 {
                // Ring exchange of K/V shards: each CP step moves the
                // local K/V block to the neighbor, (cp−1) steps, fwd+bwd.
                // CP ring neighbors sit one TP block apart inside the
                // stage group, so the exchange is between two *adjacent*
                // blocks of `tp` devices — the SendRecv `group`
                // convention (priced at `boundary_level(tp)`: intra-node
                // for small TP, across the tier a TP block exactly
                // fills).
                let kv = DTYPE_BYTES * local_tokens * d.kv_dim() as f64 * 2.0;
                for _ in 0..(2 * (sg.cp - 1)) {
                    out.push(CollectiveCall {
                        kind: CollectiveKind::SendRecv,
                        bytes: kv,
                        group: sg.tp,
                    });
                }
            }
        }
    }
    out
}

/// Enumerate the SUB-GRAPH configurations allowed for a model
/// (cross-product of the Table 2 degree columns, with SP tied to TP),
/// filtered to groups that fit within `max_group` devices.
pub fn enumerate_sg(
    tp_widths: &[usize],
    ep_degrees: &[usize],
    cp_degrees: &[usize],
    max_group: usize,
) -> Vec<SgConfig> {
    let mut out = Vec::new();
    for &tp in tp_widths {
        for &ep in ep_degrees {
            for &cp in cp_degrees {
                if tp * ep * cp > max_group {
                    continue;
                }
                // Plain TP and TP+SP are distinct points when tp > 1.
                out.push(SgConfig {
                    tp,
                    sp: false,
                    ep,
                    cp,
                });
                if tp > 1 {
                    out.push(SgConfig {
                        tp,
                        sp: true,
                        ep,
                        cp,
                    });
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::models;
    use super::*;

    #[test]
    fn serial_has_no_collectives() {
        let g = models::gpt3_175b(1);
        for l in &g.layers {
            assert!(layer_collectives(l, g.tokens, &SgConfig::serial()).is_empty());
        }
    }

    #[test]
    fn tp_block_has_four_allreduces() {
        let g = models::gpt3_175b(1);
        let calls = layer_collectives(&g.layers[1], g.tokens, &SgConfig::tp(4));
        assert_eq!(calls.len(), 4);
        assert!(calls
            .iter()
            .all(|c| c.kind == CollectiveKind::AllReduce && c.group == 4));
    }

    #[test]
    fn sp_preserves_total_volume() {
        let g = models::gpt3_175b(1);
        let tp = layer_collectives(&g.layers[1], g.tokens, &SgConfig::tp(4));
        let mut sg = SgConfig::tp(4);
        sg.sp = true;
        let sp = layer_collectives(&g.layers[1], g.tokens, &sg);
        // Ring AR of V bytes moves 2·V·(g−1)/g per rank; AG+RS of V/g each
        // moves the same total. Compare summed payloads: 4·V vs 8·(V/4)=2V
        // — SP halves the on-wire payload bookkeeping but the *cost model*
        // (network::collectives) makes AR(V) == AG(V/g)+RS(V/g) in time.
        let tp_bytes: f64 = tp.iter().map(|c| c.bytes).sum();
        let sp_bytes: f64 = sp.iter().map(|c| c.bytes).sum();
        assert!(sp_bytes < tp_bytes);
        assert_eq!(sp.len(), 8);
    }

    #[test]
    fn moe_all_to_all_present() {
        let g = models::mixtral_8x7b(1);
        let mut sg = SgConfig::serial();
        sg.ep = 4;
        let calls = layer_collectives(&g.layers[1], g.tokens, &sg);
        let a2a: Vec<_> = calls
            .iter()
            .filter(|c| c.kind == CollectiveKind::AllToAll)
            .collect();
        assert_eq!(a2a.len(), 4);
        assert!(a2a.iter().all(|c| c.group == 4));
    }

    #[test]
    fn cp_ring_steps_scale() {
        let g = models::mixtral_8x7b(1);
        let mut sg = SgConfig::serial();
        sg.cp = 4;
        let calls = layer_collectives(&g.layers[1], g.tokens, &sg);
        let sends = calls
            .iter()
            .filter(|c| c.kind == CollectiveKind::SendRecv)
            .count();
        assert_eq!(sends, 2 * 3);
    }

    #[test]
    fn enumerate_respects_max_group() {
        let cfgs = enumerate_sg(&[1, 2, 4, 8], &[1, 2], &[1, 2], 8);
        assert!(cfgs.iter().all(|c| c.group_size() <= 8));
        assert!(cfgs.contains(&SgConfig::serial()));
        // SP variants only for tp > 1.
        assert!(cfgs.iter().filter(|c| c.sp).all(|c| c.tp > 1));
        // No duplicates.
        let mut seen = std::collections::HashSet::new();
        for c in &cfgs {
            assert!(seen.insert(*c), "dup {c:?}");
        }
    }
}
