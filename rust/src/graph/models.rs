//! Model zoo: the workloads of Table 2 (plus the scaled-down variants of
//! Tables 3 and 5), built from published hyperparameters.
//!
//! Each builder takes the microbatch size `mbs` (sequences per
//! microbatch; the paper sweeps 1–8 in Figures 6/11) and returns a
//! [`LayerGraph`] with the allowed SUB-GRAPH degrees from Table 2's
//! "TMP Widths" / "Expert Degree" / "Context Degree" columns.

use super::{Dims, Layer, LayerGraph, LayerKind, MoeCfg};

/// Paper-wide default global batch size (§5.1).
pub const GLOBAL_BATCH: usize = 4096;

#[allow(clippy::too_many_arguments)]
fn build(
    name: &str,
    n_blocks: usize,
    hidden: usize,
    heads: usize,
    kv_heads: usize,
    intermediate: usize,
    seq: usize,
    vocab: usize,
    gated_mlp: bool,
    moe: Option<MoeCfg>,
    mbs: usize,
    tp_widths: Vec<usize>,
    ep_degrees: Vec<usize>,
    cp_degrees: Vec<usize>,
) -> LayerGraph {
    assert!(mbs >= 1, "microbatch size must be >= 1");
    let dims = Dims {
        hidden,
        heads,
        kv_heads,
        intermediate,
        seq,
        vocab,
        gated_mlp,
    };
    let mut layers = Vec::with_capacity(n_blocks + 2);
    layers.push(Layer {
        name: "embedding".into(),
        kind: LayerKind::Embedding,
        dims,
    });
    for i in 0..n_blocks {
        layers.push(Layer {
            name: format!("block{i}"),
            kind: match moe {
                Some(m) => LayerKind::MoeBlock(m),
                None => LayerKind::Block,
            },
            dims,
        });
    }
    layers.push(Layer {
        name: "head".into(),
        kind: LayerKind::Head,
        dims,
    });
    LayerGraph {
        model_name: name.into(),
        layers,
        mbs,
        tokens: (mbs * seq) as f64,
        global_batch: GLOBAL_BATCH,
        tp_widths,
        ep_degrees,
        cp_degrees,
    }
}

/// Llama2-7B: 32 layers, 32 heads, h=4096 (Table 2; no TMP evaluated).
pub fn llama2_7b(mbs: usize) -> LayerGraph {
    build(
        "llama2-7b",
        32,
        4096,
        32,
        32,
        11008,
        4096,
        32000,
        true,
        None,
        mbs,
        vec![1],
        vec![1],
        vec![1],
    )
}

/// Llama3-70B: 80 layers, 64 heads (8 KV heads, GQA), h=8192.
pub fn llama3_70b(mbs: usize) -> LayerGraph {
    build(
        "llama3-70b",
        80,
        8192,
        64,
        8,
        28672,
        4096,
        128256,
        true,
        None,
        mbs,
        vec![1],
        vec![1],
        vec![1],
    )
}

/// BertLarge: 24 layers, 16 heads, h=1024, seq 512; TMP widths 1,2,4,8.
pub fn bert_large(mbs: usize) -> LayerGraph {
    build(
        "bertlarge",
        24,
        1024,
        16,
        16,
        4096,
        512,
        30522,
        false,
        None,
        mbs,
        vec![1, 2, 4, 8],
        vec![1],
        vec![1],
    )
}

/// Megatron GPT3-175B: 96 layers, 96 heads, h=12288, seq 2048; TMP 4,8.
pub fn gpt3_175b(mbs: usize) -> LayerGraph {
    build(
        "gpt3-175b",
        96,
        12288,
        96,
        96,
        4 * 12288,
        2048,
        50257,
        false,
        None,
        mbs,
        vec![4, 8],
        vec![1],
        vec![1, 2, 4],
    )
}

/// GPT3-35B (Table 3): the scaled-down variant used for the Mist
/// comparison in §5.3 (64 layers, h=8192, 64 heads, I=16384, seq 2048).
pub fn gpt3_35b(mbs: usize) -> LayerGraph {
    build(
        "gpt3-35b",
        64,
        8192,
        64,
        64,
        16384,
        2048,
        50257,
        false,
        None,
        mbs,
        vec![1, 2, 4, 8],
        vec![1],
        vec![1, 2],
    )
}

/// Mixtral-8x7B: 32 layers, 32 heads (8 KV), h=4096, I=14336, 8 experts
/// top-2; expert degrees 1,2,4,8 and context degrees 1,2,4,8 (Table 2).
pub fn mixtral_8x7b(mbs: usize) -> LayerGraph {
    build(
        "mixtral-8x7b",
        32,
        4096,
        32,
        8,
        14336,
        4096,
        32000,
        true,
        Some(MoeCfg {
            experts: 8,
            top_k: 2,
        }),
        mbs,
        vec![1],
        vec![1, 2, 4, 8],
        vec![1, 2, 4, 8],
    )
}

/// Scaled-down Mixtral (Table 5, §5.4): 8 layers, 8 experts, h=1024,
/// 16 heads, I=3584, seq 1024 — ~790M params, used on the 8/16-device
/// validation clusters.
pub fn mixtral_scaled(mbs: usize) -> LayerGraph {
    build(
        "mixtral-790m",
        8,
        1024,
        16,
        16,
        3584,
        1024,
        32000,
        true,
        Some(MoeCfg {
            experts: 8,
            top_k: 2,
        }),
        mbs,
        vec![1, 2],
        vec![1, 2, 4, 8],
        vec![1],
    )
}

/// Tiny synthetic transformer used by unit/property tests and the real
/// pipeline trainer (matches the L2 JAX model's default config).
pub fn tiny_transformer(n_blocks: usize, hidden: usize, seq: usize, mbs: usize) -> LayerGraph {
    build(
        "tiny",
        n_blocks,
        hidden,
        (hidden / 64).max(1),
        (hidden / 64).max(1),
        4 * hidden,
        seq,
        8192,
        false,
        None,
        mbs,
        vec![1, 2],
        vec![1],
        vec![1],
    )
}

/// Look a model up by CLI name.
pub fn by_name(name: &str, mbs: usize) -> Option<LayerGraph> {
    match name {
        "llama2-7b" => Some(llama2_7b(mbs)),
        "llama3-70b" => Some(llama3_70b(mbs)),
        "bertlarge" => Some(bert_large(mbs)),
        "gpt3-175b" => Some(gpt3_175b(mbs)),
        "gpt3-35b" => Some(gpt3_35b(mbs)),
        "mixtral-8x7b" => Some(mixtral_8x7b(mbs)),
        "mixtral-790m" => Some(mixtral_scaled(mbs)),
        _ => None,
    }
}

/// All Table 2 models at a given microbatch size.
pub fn table2_models(mbs: usize) -> Vec<LayerGraph> {
    vec![
        bert_large(mbs),
        llama2_7b(mbs),
        llama3_70b(mbs),
        gpt3_175b(mbs),
        mixtral_8x7b(mbs),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_names_resolve() {
        for n in [
            "llama2-7b",
            "llama3-70b",
            "bertlarge",
            "gpt3-175b",
            "gpt3-35b",
            "mixtral-8x7b",
            "mixtral-790m",
        ] {
            let g = by_name(n, 1).unwrap_or_else(|| panic!("{n} missing"));
            assert_eq!(g.model_name, n);
            assert!(g.n_layers() >= 3);
        }
        assert!(by_name("nope", 1).is_none());
    }

    #[test]
    fn layer_counts_match_table2() {
        assert_eq!(llama2_7b(1).n_layers(), 32 + 2);
        assert_eq!(llama3_70b(1).n_layers(), 80 + 2);
        assert_eq!(bert_large(1).n_layers(), 24 + 2);
        assert_eq!(gpt3_175b(1).n_layers(), 96 + 2);
        assert_eq!(mixtral_8x7b(1).n_layers(), 32 + 2);
    }

    #[test]
    fn mixtral_scaled_is_790m() {
        let g = mixtral_scaled(1);
        let p = g.total_params();
        assert!(
            (p - 790e6).abs() / 790e6 < 0.20,
            "scaled mixtral {:.0}M params",
            p / 1e6
        );
    }

    #[test]
    fn mbs_scales_tokens() {
        let g1 = gpt3_175b(1);
        let g4 = gpt3_175b(4);
        assert!((g4.tokens / g1.tokens - 4.0).abs() < 1e-12);
    }

    #[test]
    fn gpt35b_matches_table3() {
        let g = gpt3_35b(1);
        assert_eq!(g.layers[1].dims.hidden, 8192);
        assert_eq!(g.layers[1].dims.heads, 64);
        assert_eq!(g.layers[1].dims.intermediate, 16384);
        assert_eq!(g.layers[1].dims.seq, 2048);
        let p = g.total_params();
        assert!((p - 35e9).abs() / 35e9 < 0.25, "{:.1}B", p / 1e9);
    }
}
