//! Analytical collective-communication cost models.
//!
//! The paper estimates collective latencies with AstraSim's analytical
//! backend (validated within 2% of H100 measurements, Fig. 10). We
//! implement the same α–β hierarchical decomposition: a collective over a
//! group shaped `[g0, g1, ...]` across tiers executes phase-wise — ring
//! reduce-scatter ascending the hierarchy, then ring all-gather
//! descending — with each tier moving its shard at that tier's effective
//! bandwidth. This is the standard hierarchical ring schedule used by
//! NCCL trees/rings and AstraSim's `Ring_AllReduce` per dimension.
//!
//! All functions take the *full* payload `bytes` (the tensor size being
//! reduced/gathered) and return seconds.

use super::Cluster;
use crate::graph::subgraph::{CollectiveCall, CollectiveKind};

impl Cluster {
    /// Ring all-reduce of `bytes` over a group shaped `shape` (participants
    /// per tier, innermost first; product = group size).
    ///
    /// Per tier `i` with `gᵢ` participants and per-participant shard
    /// `Vᵢ = bytes / Π_{j<i} gⱼ`, a ring all-reduce costs
    /// `2·(gᵢ−1)/gᵢ · Vᵢ / bwᵢ + 2·(gᵢ−1)·αᵢ`.
    pub fn allreduce(&self, bytes: f64, shape: &[usize]) -> f64 {
        let mut t = 0.0;
        let mut shard = bytes;
        for (i, &gi) in shape.iter().enumerate() {
            if gi <= 1 {
                continue;
            }
            let g = gi as f64;
            let tier = self.tier_for(i, shape);
            t += 2.0 * (g - 1.0) / g * shard / tier_bw(self, tier)
                + 2.0 * (g - 1.0) * self.tiers[tier].latency;
            shard /= g;
        }
        t
    }

    /// Ring all-gather: each participant starts with `bytes / g` and ends
    /// with `bytes`. Cost per tier: `(gᵢ−1)/gᵢ · Bᵢ / bwᵢ` on the gathered
    /// volume at that tier.
    pub fn allgather(&self, bytes: f64, shape: &[usize]) -> f64 {
        let mut t = 0.0;
        let mut vol = bytes;
        for (i, &gi) in shape.iter().enumerate() {
            if gi <= 1 {
                continue;
            }
            let g = gi as f64;
            let tier = self.tier_for(i, shape);
            t += (g - 1.0) / g * vol / tier_bw(self, tier)
                + (g - 1.0) * self.tiers[tier].latency;
            vol /= g;
        }
        t
    }

    /// Ring reduce-scatter: mirror of all-gather.
    pub fn reduce_scatter(&self, bytes: f64, shape: &[usize]) -> f64 {
        self.allgather(bytes, shape)
    }

    /// All-to-all of `bytes` per participant (each sends `bytes/g` to every
    /// peer). The bottleneck is the outermost tier each message crosses:
    /// traffic crossing tier `i` per device is `bytes · fᵢ` where `fᵢ` is
    /// the fraction of peers outside the tier-`i` subtree. Phases overlap,
    /// so the cost is the max per-tier term plus latency of the deepest
    /// tier (matches AstraSim's analytical All2All).
    pub fn alltoall(&self, bytes: f64, shape: &[usize]) -> f64 {
        let g_total: usize = shape.iter().product();
        if g_total <= 1 {
            return 0.0;
        }
        let mut worst: f64 = 0.0;
        let mut inner: usize = 1;
        let mut deepest_tier = 0;
        let mut active_phases = 0usize;
        for (i, &gi) in shape.iter().enumerate() {
            if gi <= 1 {
                continue;
            }
            active_phases += 1;
            let tier = self.tier_for(i, shape);
            deepest_tier = deepest_tier.max(tier);
            let below = inner * gi;
            // Fraction of peers outside the subtree of size `inner` but
            // inside `below`, crossing tier `tier`:
            let f = (below - inner) as f64 / g_total as f64;
            worst = worst.max(bytes * f / tier_bw(self, tier));
            inner = below;
        }
        // Latency is paid once per phase that actually exchanges data:
        // 1-entries in the shape (tiers no ring runs over) cost nothing.
        worst + self.tiers[deepest_tier].latency * (active_phases as f64)
    }

    /// Point-to-point send/recv between two compact sub-groups at `level`.
    pub fn sendrecv(&self, bytes: f64, level: usize) -> f64 {
        self.p2p_time(level.min(self.n_levels() - 1), bytes)
    }

    /// Cost of one [`CollectiveCall`] issued by a stage whose `group`
    /// participants are placed compactly (SUB-GRAPH collectives run within
    /// a stage's device group, §3.1).
    pub fn collective_time(&self, call: &CollectiveCall) -> f64 {
        let shape = self.compact_shape(call.group);
        match call.kind {
            CollectiveKind::AllReduce => self.allreduce(call.bytes, &shape),
            CollectiveKind::AllGather => self.allgather(call.bytes * call.group as f64, &shape),
            CollectiveKind::ReduceScatter => {
                self.reduce_scatter(call.bytes * call.group as f64, &shape)
            }
            CollectiveKind::AllToAll => self.alltoall(call.bytes, &shape),
            CollectiveKind::SendRecv => {
                // A SendRecv call is the exchange between two *adjacent*
                // compact blocks of `group` devices (pipeline-style
                // neighbors). Two blocks that each exactly fill a
                // level-`l` subtree talk across the tier above —
                // `boundary_level`, not `level_of_group`, which answers
                // the different question of where one block *lives* (and
                // under-priced the exactly-filling case at level `l`).
                self.sendrecv(call.bytes, self.boundary_level(call.group.max(1)))
            }
        }
    }

    /// Gradient all-reduce across `d` data-parallel replicas whose members
    /// are `stride` devices apart (Algorithm 1 line 25 SyncCost).
    pub fn dp_allreduce(&self, bytes: f64, d: usize, stride: usize) -> f64 {
        if d <= 1 {
            return 0.0;
        }
        let shape = self.spread_shape(d, stride);
        self.allreduce(bytes, &shape)
    }

    /// Map a shape index to the tier the ring at that index runs over.
    /// Shapes are tier-aligned: `compact_shape` / `spread_shape` emit
    /// exactly one entry per tier, innermost first, with 1-entries
    /// holding the slots of tiers no ring runs over (the inner tiers a
    /// spread group's stride fully covers — `[1, 1, 4]` is a DP group
    /// whose members sit one leaf apart, ringing at the aggregation
    /// tier — or a degenerate arity-1 tier). So entry `i` rings over
    /// tier `i`, clamped for hand-built shapes deeper than the
    /// hierarchy.
    fn tier_for(&self, shape_idx: usize, shape: &[usize]) -> usize {
        debug_assert!(
            shape_idx < shape.len() && shape[shape_idx] > 1,
            "tier_for queried for a non-ringing shape entry"
        );
        shape_idx.min(self.n_levels() - 1)
    }
}

fn tier_bw(c: &Cluster, tier: usize) -> f64 {
    // The ring at tier `tier` is bounded by the slowest link on its path,
    // i.e. the effective p2p bandwidth at that level.
    c.bw_eff(tier)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::subgraph::{CollectiveCall, CollectiveKind};
    use crate::hw::{Accelerator, GB};
    use crate::util::prop;

    fn cluster() -> Cluster {
        Cluster::fat_tree_tpuv4(1024)
    }

    #[test]
    fn allreduce_flat_matches_ring_formula() {
        let c = Cluster::flat(Accelerator::h100(), 8, 100.0 * GB, 1e-6);
        let bytes = 1e9;
        let t = c.allreduce(bytes, &[8]);
        let expect = 2.0 * 7.0 / 8.0 * bytes / (100.0 * GB) + 2.0 * 7.0 * 1e-6;
        assert!((t - expect).abs() / expect < 1e-9);
    }

    #[test]
    fn allreduce_intra_node_faster_than_cross_rack() {
        let c = cluster();
        let bytes = 1e9;
        let intra = c.allreduce(bytes, &[8]);
        let cross = c.allreduce(bytes, &[8, 4]);
        let far = c.allreduce(bytes, &[8, 4, 4]);
        assert!(intra < cross);
        assert!(cross < far);
    }

    #[test]
    fn hierarchical_beats_flat_ring_over_slow_tier() {
        // An 8×4 hierarchical all-reduce should beat a flat 32-ring that
        // crosses the slow tier every hop.
        let c = cluster();
        let bytes = 1e9;
        let hier = c.allreduce(bytes, &[8, 4]);
        // Flat ring over 32 where every link is leaf-speed:
        let flat = 2.0 * 31.0 / 32.0 * bytes / c.bw_eff(1) + 2.0 * 31.0 * c.tiers[1].latency;
        assert!(hier < flat, "hier {hier} flat {flat}");
    }

    #[test]
    fn allgather_half_of_allreduce() {
        let c = cluster();
        let b = 1e9;
        let ar = c.allreduce(b, &[8, 4]);
        let ag = c.allgather(b, &[8, 4]);
        let rs = c.reduce_scatter(b, &[8, 4]);
        assert!((ar - (ag + rs)).abs() / ar < 1e-9, "AR = AG + RS");
    }

    #[test]
    fn alltoall_grows_with_group_and_crossing() {
        let c = cluster();
        let b = 1e8;
        let small = c.alltoall(b, &[4]);
        let node = c.alltoall(b, &[8]);
        let cross = c.alltoall(b, &[8, 4]);
        assert!(small <= node);
        assert!(node < cross);
    }

    #[test]
    fn dp_allreduce_zero_for_single_replica() {
        let c = cluster();
        assert_eq!(c.dp_allreduce(1e9, 1, 32), 0.0);
        assert!(c.dp_allreduce(1e9, 8, 32) > 0.0);
    }

    #[test]
    fn dp_allreduce_spread_uses_slow_tiers() {
        let c = cluster();
        let b = 1e9;
        // 4 replicas inside one rack (stride 8 devices) vs spread across
        // racks (stride 32): the rack-internal one is cheaper.
        let near = c.dp_allreduce(b, 4, 8);
        let far = c.dp_allreduce(b, 4, 32);
        assert!(near < far, "near {near} far {far}");
    }

    #[test]
    fn spread_shape_allreduce_priced_at_outer_tier() {
        // Regression for tier_for ignoring its shape argument: a spread
        // DP-allreduce shape like [1, 1, 4] must ring at the tier past
        // its leading 1-entries (the aggregation tier here), not at an
        // inner tier.
        let c = cluster(); // fat-tree, caps [8, 32, 1024]
        let b = 1e9;
        let t = c.allreduce(b, &[1, 1, 4]);
        let expect = 2.0 * 3.0 / 4.0 * b / c.bw_eff(2) + 2.0 * 3.0 * c.tiers[2].latency;
        assert!(
            (t - expect).abs() / expect < 1e-9,
            "[1,1,4] should price at the agg tier: {t} vs {expect}"
        );
        // And dp_allreduce at a one-leaf stride produces exactly that.
        assert_eq!(c.spread_shape(4, 32), vec![1, 1, 4]);
        let dp = c.dp_allreduce(b, 4, 32);
        assert!((dp - expect).abs() / expect < 1e-9, "dp {dp} vs {expect}");
    }

    #[test]
    fn collective_call_dispatch() {
        let c = cluster();
        for kind in [
            CollectiveKind::AllReduce,
            CollectiveKind::AllGather,
            CollectiveKind::ReduceScatter,
            CollectiveKind::AllToAll,
            CollectiveKind::SendRecv,
        ] {
            let t = c.collective_time(&CollectiveCall {
                kind,
                bytes: 1e8,
                group: 8,
            });
            assert!(t > 0.0 && t.is_finite(), "{kind:?}");
        }
    }

    #[test]
    fn prop_costs_monotone_in_bytes_and_group() {
        prop::forall(200, 0xC0FFEE, |rng| {
            let c = cluster();
            let b1 = 1e6 * (1.0 + rng.gen_f64() * 1e3);
            let b2 = b1 * (1.0 + rng.gen_f64());
            let g = [2usize, 4, 8, 16, 32][rng.gen_range(5)];
            let shape = c.compact_shape(g);
            assert!(c.allreduce(b2, &shape) >= c.allreduce(b1, &shape));
            assert!(c.allgather(b2, &shape) >= c.allgather(b1, &shape));
            assert!(c.alltoall(b2, &shape) >= c.alltoall(b1, &shape));
            // Larger groups at the same volume never get cheaper for AR.
            let shape_big = c.compact_shape(g * 2);
            assert!(c.allreduce(b1, &shape_big) >= c.allreduce(b1, &shape) * 0.99);
        });
    }

    #[test]
    fn sendrecv_adjacent_full_subtree_crosses_next_tier() {
        // Mirror of the PR-1 spread_shape stride bug, on the p2p path:
        // two adjacent stage groups of 8 devices each exactly fill a
        // fat-tree node (capacities [8, 32, 1024]), so their boundary
        // transfer must be priced at the leaf tier, never over NVLink.
        let c = cluster();
        let b = 1e8;
        let t8 = c.collective_time(&CollectiveCall {
            kind: CollectiveKind::SendRecv,
            bytes: b,
            group: 8,
        });
        let expect = c.p2p_time(1, b);
        assert!(
            (t8 - expect).abs() / expect < 1e-12,
            "node-filling groups must talk at level 1: {t8} vs {expect}"
        );
        // Rack-filling groups (32 = leaf capacity) cross the agg tier.
        let t32 = c.collective_time(&CollectiveCall {
            kind: CollectiveKind::SendRecv,
            bytes: b,
            group: 32,
        });
        let expect32 = c.p2p_time(2, b);
        assert!((t32 - expect32).abs() / expect32 < 1e-12);
        // Non-filling groups still talk inside the shared subtree.
        let t4 = c.collective_time(&CollectiveCall {
            kind: CollectiveKind::SendRecv,
            bytes: b,
            group: 4,
        });
        let expect4 = c.p2p_time(0, b);
        assert!((t4 - expect4).abs() / expect4 < 1e-12);
        // The underlying level queries.
        assert_eq!(c.boundary_level(4), 0);
        assert_eq!(c.boundary_level(8), 1);
        assert_eq!(c.boundary_level(12), 0);
        assert_eq!(c.boundary_level(32), 2);
        assert_eq!(c.boundary_level(40), 1);
    }

    #[test]
    fn cp_pair_exchange_stays_intra_node_on_arity2_nodes() {
        // CP with tp=1 emits SendRecv group=1 (two adjacent 1-blocks):
        // on a V100 cluster (2-wide NVLink nodes) the pair {0,1} is
        // genuinely intra-node and must price at NVLink, not the
        // switch tier — `boundary_level(1)` is 0 on every topology.
        let c = Cluster::v100_cluster(8);
        let b = 1e8;
        let t = c.collective_time(&CollectiveCall {
            kind: CollectiveKind::SendRecv,
            bytes: b,
            group: 1,
        });
        let expect = c.p2p_time(0, b);
        assert!(
            (t - expect).abs() / expect < 1e-12,
            "tp=1 CP pair must stay intra-node: {t} vs {expect}"
        );
    }

    #[test]
    fn alltoall_latency_counts_only_active_phases() {
        // A shape with a 1-entry ([8, 1, 4]: node rings + agg rings, no
        // leaf phase) pays latency for 2 phases, not shape.len() = 3.
        let c = cluster(); // fat-tree, caps [8, 32, 1024]
        let b = 1e8;
        let t = c.alltoall(b, &[8, 1, 4]);
        let g_total = 32.0;
        let worst = (b * (7.0 / g_total) / c.bw_eff(0))
            .max(b * (24.0 / g_total) / c.bw_eff(2));
        let expect = worst + c.tiers[2].latency * 2.0;
        assert!(
            (t - expect).abs() / expect < 1e-12,
            "[8,1,4] latency must count 2 active phases: {t} vs {expect}"
        );
        // Degenerate all-ones shape moves nothing and costs nothing.
        assert_eq!(c.alltoall(b, &[1, 1, 1]), 0.0);
    }

    #[test]
    fn sp_equivalence_in_time() {
        // AG(V/g·g) + RS(V/g·g) over the same group == AR(V): the SP
        // rewrite must not change modeled time (only memory).
        let c = cluster();
        let v = 1e9;
        let g = 8usize;
        let shape = c.compact_shape(g);
        let ar = c.allreduce(v, &shape);
        let agrs = c.allgather(v, &shape) + c.reduce_scatter(v, &shape);
        assert!((ar - agrs).abs() / ar < 1e-9);
    }
}
