//! Network topology modeling and the level-wise abstraction (§4, App. B).
//!
//! A [`Cluster`] is an accelerator type plus a stack of [`Tier`]s,
//! innermost first (devices-per-node, nodes-per-leaf, leaves-per-spine,
//! ...). *Communication level* `l` means traffic whose lowest common
//! ancestor is tier `l`: level 0 is intra-node (NVLink/ICI), level 1
//! crosses the first switch, and so on. This is exactly the paper's
//! level-wise abstraction: the DP reasons over a handful of levels
//! instead of all device pairs while the per-level costs retain hierarchy,
//! asymmetry, and oversubscription.
//!
//! Non-hierarchical topologies (torus/mesh, App. B.2) are mapped onto the
//! same abstraction via hop-distance affinity classes — see
//! [`Cluster::torus2d`] / [`Cluster::torus3d`].

pub mod collectives;

use crate::hw::{Accelerator, DevicePool, DeviceRun, GB};
use crate::util::json::Json;

/// One tier of the hierarchy.
#[derive(Debug, Clone, PartialEq)]
pub struct Tier {
    pub name: String,
    /// Children per group at this tier (8 devices/node, 4 nodes/leaf, ...).
    pub arity: usize,
    /// Per-device link bandwidth through this tier (bytes/s).
    pub link_bw: f64,
    /// Per-message latency across this tier (seconds).
    pub latency: f64,
    /// Oversubscription factor ≥ 1 (2.0 = "2:1"): effective bandwidth
    /// under load is `link_bw / oversub`.
    pub oversub: f64,
}

impl Tier {
    pub fn effective_bw(&self) -> f64 {
        self.link_bw / self.oversub
    }
}

/// A cluster: accelerators wired into a hierarchical (or hierarchically
/// abstracted) network.
///
/// Devices need not be identical: `pool` maps runs of
/// `(Accelerator, count)` onto contiguous device-id ranges (a V100
/// island next to an H100 island). Homogeneous clusters are the
/// single-run special case, and every constructor below builds one;
/// [`Cluster::hetero_pool`] and the JSON `"pool"` extension build mixed
/// pools.
#[derive(Debug, Clone)]
pub struct Cluster {
    pub name: String,
    /// Per-device accelerator profiles (replaces the former single
    /// `accel` field — use [`Cluster::accel`] for the reference class).
    pub pool: DevicePool,
    /// Innermost tier first. The product of arities is the device count.
    pub tiers: Vec<Tier>,
}

impl Cluster {
    // ----- constructors (paper setups) -----------------------------------

    /// Fat-tree of TPUv4-like accelerators (§5.2, Fig. 8a): 8 accelerators
    /// per node on an HGX-style 900 GB/s link, 4 nodes per first-level
    /// switch at 100 GB/s, second-level aggregation at 400 GB/s (4 nodes'
    /// uplinks → no oversubscription, but lower per-device bandwidth and
    /// higher latency).
    pub fn fat_tree_tpuv4(n_devices: usize) -> Self {
        assert!(n_devices % 32 == 0, "fat-tree built from 32-device pods");
        let racks = n_devices / 32;
        Cluster {
            name: format!("tpuv4-fattree-{n_devices}"),
            pool: DevicePool::uniform(Accelerator::tpu_v4(), n_devices),
            tiers: vec![
                Tier {
                    name: "node(HGX)".into(),
                    arity: 8,
                    link_bw: 900.0 * GB,
                    latency: 1.0e-6,
                    oversub: 1.0,
                },
                Tier {
                    name: "leaf".into(),
                    arity: 4,
                    link_bw: 100.0 * GB,
                    latency: 5.0e-6,
                    oversub: 1.0,
                },
                Tier {
                    name: "agg".into(),
                    arity: racks,
                    link_bw: 100.0 * GB,
                    latency: 10.0e-6,
                    oversub: 1.0,
                },
            ],
        }
    }

    /// Spine-leaf H100 cluster (§5.3, Fig. 2 topology): 8×H100 per node
    /// (NVLink 900 GB/s), 4 nodes per leaf at 12.5 GB/s, two spines with
    /// 2:2 oversubscription across leaves.
    pub fn spine_leaf_h100(n_devices: usize, oversub: f64) -> Self {
        assert!(n_devices % 32 == 0, "spine-leaf built from 32-GPU leaves");
        let leaves = n_devices / 32;
        Cluster {
            name: format!("h100-spineleaf-{n_devices}"),
            pool: DevicePool::uniform(Accelerator::h100(), n_devices),
            tiers: vec![
                Tier {
                    name: "node(NVLink)".into(),
                    arity: 8,
                    link_bw: 900.0 * GB,
                    latency: 1.0e-6,
                    oversub: 1.0,
                },
                Tier {
                    name: "leaf".into(),
                    arity: 4,
                    link_bw: 12.5 * GB,
                    latency: 5.0e-6,
                    oversub: 1.0,
                },
                Tier {
                    name: "spine".into(),
                    arity: leaves,
                    link_bw: 12.5 * GB,
                    latency: 10.0e-6,
                    oversub,
                },
            ],
        }
    }

    /// V100 validation cluster (§5.4): 2×V100 per node (NVLink 300 GB/s),
    /// nodes joined by 12.5 GB/s switches.
    pub fn v100_cluster(n_devices: usize) -> Self {
        assert!(n_devices % 2 == 0);
        Cluster {
            name: format!("v100-{n_devices}"),
            pool: DevicePool::uniform(Accelerator::v100(), n_devices),
            tiers: vec![
                Tier {
                    name: "node(NVLink)".into(),
                    arity: 2,
                    link_bw: 300.0 * GB,
                    latency: 1.5e-6,
                    oversub: 1.0,
                },
                Tier {
                    name: "switch".into(),
                    arity: n_devices / 2,
                    link_bw: 12.5 * GB,
                    latency: 8.0e-6,
                    oversub: 1.0,
                },
            ],
        }
    }

    /// Mixed-generation pool: the first half of the devices are
    /// H100-SXM nodes (NVLink 900 GB/s), the second half V100 nodes
    /// whose intra-node fabric tops out at 300 GB/s — the
    /// heterogeneous-datacenter setting hardware/placement co-search
    /// works optimize over. Uniform 8-wide nodes behind a 25 GB/s leaf
    /// and a 2:1-oversubscribed spine; the analytic tier keeps the
    /// fastest (H100) intra-node bandwidth, so the level-wise model
    /// stays optimistic and the flow simulator exposes the V100 nodes'
    /// slower access links. The H100 island occupies the *low* device
    /// ids: the solver packs pipelines tail-first from device 0, so
    /// partially-utilizing plans concentrate on the fast island.
    pub fn hetero_pool(n_devices: usize) -> Self {
        assert!(
            n_devices >= 32 && n_devices % 32 == 0,
            "hetero pool needs whole 32-device leaf groups (n ≥ 32, n % 32 == 0)"
        );
        let half = n_devices / 2;
        Cluster {
            name: format!("hetero-h100-v100-{n_devices}"),
            pool: DevicePool::from_runs(vec![
                DeviceRun {
                    accel: Accelerator::h100(),
                    count: half,
                    access_bw: None,
                },
                DeviceRun {
                    accel: Accelerator::v100(),
                    count: half,
                    access_bw: Some(300.0 * GB),
                },
            ]),
            tiers: vec![
                Tier {
                    name: "node(NVLink)".into(),
                    arity: 8,
                    link_bw: 900.0 * GB,
                    latency: 1.0e-6,
                    oversub: 1.0,
                },
                Tier {
                    name: "leaf".into(),
                    arity: 4,
                    link_bw: 25.0 * GB,
                    latency: 5.0e-6,
                    oversub: 1.0,
                },
                Tier {
                    name: "spine".into(),
                    arity: n_devices / 32,
                    link_bw: 25.0 * GB,
                    latency: 10.0e-6,
                    oversub: 2.0,
                },
            ],
        }
    }

    /// 2D torus mapped to levels by hop distance (App. B.2 / Fig. 9):
    /// level 0 ≈ same tile (4-device tile on full-bandwidth links),
    /// level 1 ≈ near neighbors, level 2 ≈ remote. Effective bandwidth
    /// decays with hop-class because paths share links (modeled as the
    /// per-hop serialization of the ICI link).
    pub fn torus2d(x: usize, y: usize, link_bw: f64, hop_latency: f64) -> Self {
        let n = x * y;
        assert!(n >= 16 && n % 16 == 0, "torus modeled in 16-device tiles");
        Cluster {
            name: format!("torus2d-{x}x{y}"),
            pool: DevicePool::uniform(Accelerator::tpu_v4(), n),
            tiers: vec![
                Tier {
                    name: "tile(1-hop)".into(),
                    arity: 4,
                    link_bw,
                    latency: hop_latency,
                    oversub: 1.0,
                },
                Tier {
                    name: "near(2-hop)".into(),
                    arity: 4,
                    link_bw: link_bw / 2.0,
                    latency: 2.0 * hop_latency,
                    oversub: 1.0,
                },
                Tier {
                    name: "remote".into(),
                    arity: n / 16,
                    // Remote traffic shares the torus bisection:
                    // bisection bw per device ≈ 2·link_bw/√n side links.
                    link_bw: (link_bw * 2.0 * (x.min(y) as f64)) / n as f64,
                    latency: hop_latency * (x + y) as f64 / 2.0,
                    oversub: 1.0,
                },
            ],
        }
    }

    /// 3D torus (TPUv4 pods are 4×4×4-based): same hop-class mapping with
    /// a larger 1-hop neighborhood and better bisection.
    pub fn torus3d(x: usize, y: usize, z: usize, link_bw: f64, hop_latency: f64) -> Self {
        let n = x * y * z;
        assert!(n >= 64 && n % 64 == 0, "3d torus modeled in 64-device cubes");
        Cluster {
            name: format!("torus3d-{x}x{y}x{z}"),
            pool: DevicePool::uniform(Accelerator::tpu_v4(), n),
            tiers: vec![
                Tier {
                    name: "cube(1-hop)".into(),
                    arity: 8,
                    link_bw,
                    latency: hop_latency,
                    oversub: 1.0,
                },
                Tier {
                    name: "near".into(),
                    arity: 8,
                    link_bw: link_bw / 2.0,
                    latency: 2.0 * hop_latency,
                    oversub: 1.0,
                },
                Tier {
                    name: "remote".into(),
                    arity: n / 64,
                    link_bw: link_bw * 2.0 * (x * y).min(y * z).min(x * z) as f64 / n as f64,
                    latency: hop_latency * (x + y + z) as f64 / 2.0,
                    oversub: 1.0,
                },
            ],
        }
    }

    /// Flat uniform network (what topology-agnostic baselines assume):
    /// every pair communicates at `bw`/`lat`.
    pub fn flat(accel: Accelerator, n_devices: usize, bw: f64, lat: f64) -> Self {
        Cluster {
            name: format!("flat-{n_devices}"),
            pool: DevicePool::uniform(accel, n_devices),
            tiers: vec![Tier {
                name: "flat".into(),
                arity: n_devices,
                link_bw: bw,
                latency: lat,
                oversub: 1.0,
            }],
        }
    }

    /// Parse a cluster from the JSON network-description interface
    /// (App. B.1: device identifiers, connectivity, per-link bandwidth and
    /// latency):
    ///
    /// ```json
    /// {"name": "...", "accelerator": "h100",
    ///  "tiers": [{"name": "node", "arity": 8, "bw_gbps": 900,
    ///             "latency_us": 1.0, "oversub": 1.0}, ...]}
    /// ```
    ///
    /// Heterogeneous pools extend the schema with a `"pool"` array of
    /// `(accelerator, count)` runs mapped to contiguous device ranges
    /// (fully backward compatible — without `"pool"` the single
    /// `"accelerator"` covers every device):
    ///
    /// ```json
    /// {"name": "...",
    ///  "pool": [{"accelerator": "h100", "count": 32},
    ///           {"accelerator": "v100", "count": 32, "access_bw_gbps": 300}],
    ///  "tiers": [...]}
    /// ```
    ///
    /// Run counts must sum to the tier product; a run's optional
    /// `access_bw_gbps` (its devices' innermost-tier link speed, seen
    /// by the flow-level simulator) must not exceed the innermost
    /// tier's bandwidth, so the level-wise analytic model stays
    /// optimistic.
    pub fn from_json(v: &Json) -> Result<Self, String> {
        let tiers_json = v
            .get("tiers")
            .as_arr()
            .ok_or("missing 'tiers' array")?;
        if tiers_json.is_empty() {
            return Err("empty 'tiers'".into());
        }
        let mut tiers = Vec::new();
        for t in tiers_json {
            tiers.push(Tier {
                name: t.get("name").as_str().unwrap_or("tier").to_string(),
                arity: t
                    .get("arity")
                    .as_usize()
                    .ok_or("tier missing 'arity'")?,
                link_bw: t.get("bw_gbps").as_f64().ok_or("tier missing 'bw_gbps'")?
                    * GB,
                latency: t.get("latency_us").as_f64().unwrap_or(1.0) * 1e-6,
                oversub: t.get("oversub").as_f64().unwrap_or(1.0),
            });
        }
        let n_devices: usize = tiers.iter().map(|t| t.arity).product();
        let pool = match v.get("pool").as_arr() {
            None => {
                let accel_name = v.get("accelerator").as_str().unwrap_or("h100");
                let accel = Accelerator::by_name(accel_name)
                    .ok_or_else(|| format!("unknown accelerator '{accel_name}'"))?;
                DevicePool::uniform(accel, n_devices)
            }
            Some(runs_json) => {
                if runs_json.is_empty() {
                    return Err("empty 'pool'".into());
                }
                let mut runs = Vec::with_capacity(runs_json.len());
                for r in runs_json {
                    let accel_name = r
                        .get("accelerator")
                        .as_str()
                        .ok_or("pool run missing 'accelerator'")?;
                    let accel = Accelerator::by_name(accel_name)
                        .ok_or_else(|| format!("unknown accelerator '{accel_name}'"))?;
                    let count = r
                        .get("count")
                        .as_usize()
                        .ok_or("pool run missing 'count'")?;
                    let access_bw = r.get("access_bw_gbps").as_f64().map(|b| b * GB);
                    if let Some(bw) = access_bw {
                        if bw <= 0.0 {
                            return Err(format!(
                                "pool run '{accel_name}': non-positive access_bw_gbps"
                            ));
                        }
                        if bw > tiers[0].link_bw * (1.0 + 1e-9) {
                            return Err(format!(
                                "pool run '{accel_name}': access_bw_gbps exceeds the \
                                 innermost tier's bw_gbps (the analytic tier must stay \
                                 the optimistic upper bound)"
                            ));
                        }
                    }
                    runs.push(DeviceRun {
                        accel,
                        count,
                        access_bw,
                    });
                }
                let total: usize = runs.iter().map(|r| r.count).sum();
                if total != n_devices {
                    return Err(format!(
                        "pool covers {total} devices but the tiers define {n_devices}"
                    ));
                }
                DevicePool::from_runs(runs)
            }
        };
        Ok(Cluster {
            name: v.get("name").as_str().unwrap_or("custom").to_string(),
            pool,
            tiers,
        })
    }

    // ----- pool queries --------------------------------------------------

    /// The pool's reference accelerator (first run) — the one
    /// homogeneous call sites mean by "the cluster's accelerator".
    pub fn accel(&self) -> &Accelerator {
        self.pool.accel_of(0)
    }

    /// Clone with every device replaced by `accel` (uniform twin; e.g.
    /// the "treat everything as a V100" constrained baseline).
    pub fn with_uniform_accel(&self, accel: Accelerator) -> Cluster {
        let mut c = self.clone();
        c.name = format!("{}-as-{}", self.name, accel.name);
        c.pool = DevicePool::uniform(accel, self.n_devices());
        c
    }

    /// Shrink every device's HBM capacity (Table 7 memory-constrained
    /// ablations).
    pub fn shrink_capacity(&mut self, bytes: f64) {
        self.pool = self.pool.map_accels(|a| a.with_capacity(bytes));
    }

    // ----- level-wise queries --------------------------------------------

    pub fn n_devices(&self) -> usize {
        self.tiers.iter().map(|t| t.arity).product()
    }

    /// Number of communication levels (= number of tiers).
    pub fn n_levels(&self) -> usize {
        self.tiers.len()
    }

    /// Devices reachable within level `l` (subtree capacity).
    pub fn capacity(&self, l: usize) -> usize {
        self.tiers[..=l].iter().map(|t| t.arity).product()
    }

    /// Effective point-to-point bandwidth for traffic whose lowest common
    /// tier is `l`: the min effective bandwidth along the path.
    pub fn bw_eff(&self, l: usize) -> f64 {
        self.tiers[..=l]
            .iter()
            .map(|t| t.effective_bw())
            .fold(f64::INFINITY, f64::min)
    }

    /// Cumulative latency to cross up to tier `l`.
    pub fn lat(&self, l: usize) -> f64 {
        self.tiers[..=l].iter().map(|t| t.latency).sum()
    }

    /// Point-to-point transfer time of `bytes` at level `l` (α–β model).
    pub fn p2p_time(&self, l: usize, bytes: f64) -> f64 {
        debug_assert!(l < self.n_levels());
        self.lat(l) + bytes / self.bw_eff(l)
    }

    /// Communication level crossed by the boundary between device
    /// `offset−1` and device `offset` under compact packing: the innermost
    /// tier whose subtree capacity does *not* divide the offset. This is
    /// the level at which two *adjacent* compact blocks of `offset`
    /// devices talk: a block that exactly fills a level-`l` subtree must
    /// reach its neighbor through the tier above (`capacity(l) | offset`
    /// pushes the answer past `l`), while a non-filling block shares a
    /// subtree with its neighbor. Example for capacities `[8, 32, 1024]`:
    /// offset 4 → level 0 (intra-node), offset 8 → level 1 (node edge),
    /// offset 32 → level 2 (rack edge), offset 12 → level 0.
    pub fn boundary_level(&self, offset: usize) -> usize {
        debug_assert!(offset > 0, "offset 0 is not a boundary");
        for l in 0..self.n_levels() {
            if offset % self.capacity(l) != 0 {
                return l;
            }
        }
        self.n_levels() - 1
    }

    /// Smallest level whose subtree holds `g` devices — where a compactly
    /// placed group of size `g` lives.
    pub fn level_of_group(&self, g: usize) -> usize {
        for l in 0..self.n_levels() {
            if self.capacity(l) >= g {
                return l;
            }
        }
        self.n_levels() - 1
    }

    /// Shape of a compactly placed group of `g` devices: participants per
    /// tier, innermost first (e.g. g=32 on an 8-wide node, 4-wide leaf →
    /// `[8, 4]`). Product of entries ≥ g (ceil division upward).
    pub fn compact_shape(&self, g: usize) -> Vec<usize> {
        let mut shape = Vec::new();
        let mut rem = g;
        for t in &self.tiers {
            if rem == 1 {
                break;
            }
            let here = rem.min(t.arity);
            shape.push(here);
            rem = rem.div_ceil(here);
        }
        if shape.is_empty() {
            shape.push(1);
        }
        shape
    }

    /// Shape of a data-parallel group of `d` replicas whose members are
    /// spaced `stride` devices apart (one per pipeline replica). Tiers the
    /// stride fully spans contribute a 1-entry (no ring runs there — at
    /// most one member lives in each such subtree); each outer tier's
    /// entry is the number of members inside its subtree divided by the
    /// members of the subtree below. Example on capacities `[8, 32, 1024]`:
    /// `spread_shape(32, 8) = [1, 4, 8]` — a stride-8 group has one member
    /// per node, rings over 4 members inside each leaf and over 8 leaf
    /// groups at the aggregation tier. The stride == capacity boundary
    /// matters: members exactly one node apart ring at the *leaf* tier,
    /// never over NVLink.
    pub fn spread_shape(&self, d: usize, stride: usize) -> Vec<usize> {
        let d = d.max(1);
        let stride = stride.max(1);
        let mut shape = Vec::new();
        let mut cap = 1usize; // cumulative subtree capacity
        let mut below = 1usize; // members per subtree at the previous tier
        for t in &self.tiers {
            cap *= t.arity;
            // Members land every `stride` devices from offset 0, so a
            // subtree of `cap` devices holds ⌈cap / stride⌉ of them;
            // ceil on both divisions (like `compact_shape`) keeps the
            // shape's product ≥ d for non-divisible strides.
            let members = cap.div_ceil(stride).clamp(1, d);
            shape.push(members.div_ceil(below));
            below = members;
            if members >= d {
                break;
            }
        }
        if shape.iter().all(|&x| x == 1) {
            shape = vec![d.max(1)];
        }
        shape
    }

    /// Human-readable summary for logs/README.
    pub fn describe(&self) -> String {
        let tiers: Vec<String> = self
            .tiers
            .iter()
            .map(|t| {
                format!(
                    "{}×{} @{:.1}GB/s{}",
                    t.arity,
                    t.name,
                    t.link_bw / GB,
                    if t.oversub > 1.0 {
                        format!(" ({}:1 oversub)", t.oversub)
                    } else {
                        String::new()
                    }
                )
            })
            .collect();
        format!(
            "{} [{} devices, {}]: {}",
            self.name,
            self.n_devices(),
            self.pool.describe(),
            tiers.join(" → ")
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn fat_tree_device_count() {
        for n in [64, 128, 256, 512, 1024] {
            let c = Cluster::fat_tree_tpuv4(n);
            assert_eq!(c.n_devices(), n);
            assert_eq!(c.n_levels(), 3);
        }
    }

    #[test]
    fn bandwidth_decreases_with_level() {
        let c = Cluster::spine_leaf_h100(1024, 2.0);
        for l in 1..c.n_levels() {
            assert!(c.bw_eff(l) <= c.bw_eff(l - 1), "level {l}");
            assert!(c.lat(l) > c.lat(l - 1));
        }
        // 2:2 oversubscription halves spine bandwidth.
        assert!((c.bw_eff(2) - 12.5 * GB / 2.0).abs() / c.bw_eff(2) < 1e-9);
    }

    #[test]
    fn p2p_time_monotone_in_level_and_bytes() {
        let c = Cluster::fat_tree_tpuv4(64);
        let b = 1e9;
        assert!(c.p2p_time(0, b) < c.p2p_time(1, b));
        assert!(c.p2p_time(1, b) < c.p2p_time(2, b));
        assert!(c.p2p_time(1, 2.0 * b) > c.p2p_time(1, b));
    }

    #[test]
    fn level_of_group_matches_capacities() {
        let c = Cluster::fat_tree_tpuv4(128);
        assert_eq!(c.level_of_group(1), 0);
        assert_eq!(c.level_of_group(8), 0);
        assert_eq!(c.level_of_group(9), 1);
        assert_eq!(c.level_of_group(32), 1);
        assert_eq!(c.level_of_group(33), 2);
    }

    #[test]
    fn compact_shape_products_cover_group() {
        let c = Cluster::fat_tree_tpuv4(1024);
        for g in [1, 2, 8, 16, 32, 64, 256, 1024] {
            let s = c.compact_shape(g);
            let prod: usize = s.iter().product();
            assert!(prod >= g, "g={g} shape={s:?}");
            assert!(prod <= g * 2, "shape not overly loose: g={g} {s:?}");
        }
        assert_eq!(c.compact_shape(32), vec![8, 4]);
    }

    #[test]
    fn spread_shape_skips_inner_tiers() {
        let c = Cluster::fat_tree_tpuv4(1024);
        // 8 replicas of 32-device pipelines: the DP group lives at the
        // agg tier.
        let s = c.spread_shape(8, 32);
        assert_eq!(s.len(), 3);
        assert_eq!(s[0], 1);
        assert!(s[2] >= 1);
        let prod: usize = s.iter().product();
        assert!(prod >= 8);
    }

    #[test]
    fn spread_shape_strides_past_covered_tiers() {
        let c = Cluster::fat_tree_tpuv4(1024); // caps [8, 32, 1024]
        // Members one node apart: the ring runs at the leaf tier, never
        // over NVLink (regression: the old impl returned [4] here).
        assert_eq!(c.spread_shape(4, 8), vec![1, 4]);
        // Members one leaf apart: ring at the aggregation tier.
        assert_eq!(c.spread_shape(4, 32), vec![1, 1, 4]);
        // Stride-8 members fill the leaf (4 per leaf) then spill upward.
        assert_eq!(c.spread_shape(32, 8), vec![1, 4, 8]);
        // Stride 1 degenerates to compact packing.
        assert_eq!(c.spread_shape(256, 1), vec![8, 4, 8]);
    }

    #[test]
    fn torus_levels_ordered() {
        let c = Cluster::torus2d(8, 8, 50.0 * GB, 1e-6);
        assert_eq!(c.n_devices(), 64);
        assert!(c.bw_eff(0) > c.bw_eff(1));
        assert!(c.bw_eff(1) > c.bw_eff(2));
    }

    #[test]
    fn json_roundtrip() {
        let src = r#"{
            "name": "custom", "accelerator": "v100",
            "tiers": [
                {"name": "node", "arity": 2, "bw_gbps": 300, "latency_us": 1.5},
                {"name": "sw", "arity": 4, "bw_gbps": 12.5, "latency_us": 8, "oversub": 2.0}
            ]}"#;
        let c = Cluster::from_json(&json::parse(src).unwrap()).unwrap();
        assert_eq!(c.n_devices(), 8);
        assert_eq!(c.accel().name, "v100");
        assert!(c.pool.is_homogeneous());
        assert!((c.tiers[1].oversub - 2.0).abs() < 1e-12);
    }

    #[test]
    fn json_pool_extension_parses() {
        let src = r#"{
            "name": "mixed", "tiers": [
                {"name": "node", "arity": 8, "bw_gbps": 900, "latency_us": 1},
                {"name": "sw", "arity": 8, "bw_gbps": 25, "latency_us": 8}
            ],
            "pool": [
                {"accelerator": "h100", "count": 32},
                {"accelerator": "v100", "count": 32, "access_bw_gbps": 300}
            ]}"#;
        let c = Cluster::from_json(&json::parse(src).unwrap()).unwrap();
        assert_eq!(c.n_devices(), 64);
        assert_eq!(c.pool.n_classes(), 2);
        assert_eq!(c.pool.accel_of(0).name, "h100");
        assert_eq!(c.pool.accel_of(63).name, "v100");
        assert_eq!(c.pool.access_bw_of(40), Some(300.0 * GB));
        assert_eq!(c.accel().name, "h100");
    }

    #[test]
    fn json_pool_rejects_bad_runs() {
        for (bad, why) in [
            (
                r#"{"tiers": [{"arity": 8, "bw_gbps": 900}],
                    "pool": [{"accelerator": "h100", "count": 4}]}"#,
                "count mismatch",
            ),
            (
                r#"{"tiers": [{"arity": 8, "bw_gbps": 900}],
                    "pool": [{"accelerator": "quantum", "count": 8}]}"#,
                "unknown accelerator",
            ),
            (
                r#"{"tiers": [{"arity": 8, "bw_gbps": 300}],
                    "pool": [{"accelerator": "h100", "count": 8,
                              "access_bw_gbps": 900}]}"#,
                "access bw above tier bw",
            ),
            (
                r#"{"tiers": [{"arity": 8, "bw_gbps": 900}], "pool": []}"#,
                "empty pool",
            ),
        ] {
            assert!(
                Cluster::from_json(&json::parse(bad).unwrap()).is_err(),
                "{why}"
            );
        }
    }

    #[test]
    fn hetero_pool_constructor_layout() {
        let c = Cluster::hetero_pool(64);
        assert_eq!(c.n_devices(), 64);
        assert_eq!(c.pool.n_classes(), 2);
        // H100 island on the low ids (tail-first packing lands there).
        assert_eq!(c.pool.accel_of(0).name, "h100");
        assert_eq!(c.pool.accel_of(32).name, "v100");
        assert_eq!(c.pool.access_bw_of(32), Some(300.0 * GB));
        assert!(c.pool.access_bw_of(0).is_none());
        // The v100 twin treats every device as the slow class.
        let twin = c.with_uniform_accel(crate::hw::Accelerator::v100());
        assert!(twin.pool.is_homogeneous());
        assert_eq!(twin.n_devices(), 64);
        assert_eq!(twin.tiers, c.tiers);
    }

    #[test]
    fn json_rejects_bad_configs() {
        for bad in [
            r#"{"accelerator": "quantum", "tiers": [{"arity": 2, "bw_gbps": 1}]}"#,
            r#"{"accelerator": "h100", "tiers": []}"#,
            r#"{"accelerator": "h100"}"#,
            r#"{"accelerator": "h100", "tiers": [{"bw_gbps": 1}]}"#,
        ] {
            assert!(Cluster::from_json(&json::parse(bad).unwrap()).is_err());
        }
    }

    #[test]
    fn flat_network_single_level() {
        let c = Cluster::flat(Accelerator::h100(), 64, 100.0 * GB, 1e-6);
        assert_eq!(c.n_levels(), 1);
        assert_eq!(c.level_of_group(64), 0);
    }
}
