//! Explicit link-graph topologies for the flow-level simulator.
//!
//! Where [`crate::network::Cluster`] abstracts a network into per-level
//! effective bandwidths (the representation the DP searches over), a
//! [`LinkGraph`] keeps every node, switch, and directed link explicit so
//! concurrent flows can *share* links. Graphs come from two sources:
//!
//! * [`LinkGraph::from_cluster`] expands any tier stack into its physical
//!   tree — one switch per subtree per tier, per-device access links at
//!   the innermost tier, aggregate trunks above (an oversubscription
//!   factor shrinks the trunk, which is exactly where contention lives).
//! * [`LinkGraph::from_json`] parses the arbitrary edge-list interface
//!   (App. B.1's "device identifiers, connectivity, per-link bandwidth
//!   and latency"):
//!
//! ```json
//! {"name": "dumbbell", "accelerator": "h100",
//!  "nodes": [{"id": "d0", "kind": "device"}, {"id": "s0", "kind": "switch"}],
//!  "links": [{"src": "d0", "dst": "s0", "bw_gbps": 100, "latency_us": 1.0}]}
//! ```
//!
//! Routing is deterministic shortest-path (hop count, then latency, with
//! a fixed tie-break), which degenerates to classic up-down routing on
//! the tree expansions. Every run routes identically — the flow
//! simulator's reports are bit-reproducible.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, BTreeMap};

use crate::hw::GB;
use crate::network::Cluster;
use crate::util::json::Json;

/// What a graph node is.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeKind {
    /// An accelerator endpoint (flows start and end here).
    Device,
    /// A switch/router (forwards only).
    Switch,
}

/// One node of the graph.
#[derive(Debug, Clone)]
pub struct Node {
    pub name: String,
    pub kind: NodeKind,
}

/// One directed link.
#[derive(Debug, Clone)]
pub struct Link {
    pub src: usize,
    pub dst: usize,
    /// Aggregate capacity shared by all flows on the link (bytes/s).
    pub capacity: f64,
    /// One-way traversal latency (seconds).
    pub latency: f64,
    /// Ceiling on any single flow's rate through this link (bytes/s):
    /// the per-device lane speed of the tier a trunk aggregates. A lone
    /// flow on an idle 32-lane trunk still moves at one lane's rate.
    /// `f64::INFINITY` when one flow can fill the link (edge-lists).
    pub flow_cap: f64,
}

/// A directed link-graph topology with deterministic routing tables.
#[derive(Debug, Clone)]
pub struct LinkGraph {
    pub name: String,
    pub nodes: Vec<Node>,
    pub links: Vec<Link>,
    /// Device index (the id space plans use) → node id.
    devices: Vec<usize>,
    /// `next_hop[d][n]` = link id of the first hop from node `n` toward
    /// device `d` (`u32::MAX` = unreachable / arrived).
    next_hop: Vec<Vec<u32>>,
    /// Cumulative subtree capacities for cluster-expanded graphs
    /// (e.g. `[8, 32, 1024]`); empty for edge-lists, which ring flat.
    caps: Vec<usize>,
}

/// A resolved route between two devices.
#[derive(Debug, Clone)]
pub struct PathInfo {
    /// Link ids in traversal order (empty when src == dst).
    pub links: Vec<usize>,
    /// Total one-way latency along the path.
    pub latency: f64,
    /// Min per-flow ceiling along the path (the rate a lone flow gets).
    pub flow_cap: f64,
}

impl LinkGraph {
    // ----- constructors --------------------------------------------------

    /// Expand a tier stack into its explicit tree: devices at the leaves,
    /// one switch per subtree per tier. The innermost tier contributes
    /// per-device access links at that tier's effective bandwidth; tier
    /// `t > 0` contributes one trunk per child subtree with aggregate
    /// capacity `(devices below) · link_bw / oversub` but a per-flow
    /// ceiling of one lane (`link_bw / oversub`), so a single flow
    /// reproduces `Cluster::p2p_time` exactly while concurrent flows
    /// share the trunk. Each tier's latency splits evenly over its up
    /// and down hop.
    ///
    /// Heterogeneous pools: a device whose [`crate::hw::DeviceRun`]
    /// carries an `access_bw` override gets *its own* (slower) access
    /// link at the innermost tier — e.g. V100 nodes at 300 GB/s inside
    /// an H100 fabric. The analytic tier keeps the fast bandwidth (it
    /// is validated as an upper bound at parse time), so the flow
    /// simulator is where the slow island's links become visible.
    pub fn from_cluster(cluster: &Cluster) -> Self {
        let n = cluster.n_devices();
        let mut nodes: Vec<Node> = (0..n)
            .map(|d| Node {
                name: format!("dev{d}"),
                kind: NodeKind::Device,
            })
            .collect();
        let devices: Vec<usize> = (0..n).collect();
        let mut links: Vec<Link> = Vec::new();
        let mut caps: Vec<usize> = Vec::new();

        // Entities of the level below, innermost first (devices at t=0).
        let mut prev_ids: Vec<usize> = (0..n).collect();
        let mut cap = 1usize;
        for (t, tier) in cluster.tiers.iter().enumerate() {
            let sub = cap; // devices per child entity
            cap *= tier.arity;
            caps.push(cap);
            let n_sw = n.div_ceil(cap);
            let sw_base = nodes.len();
            for s in 0..n_sw {
                nodes.push(Node {
                    name: format!("{}[{s}]", tier.name),
                    kind: NodeKind::Switch,
                });
            }
            let tier_lane = tier.effective_bw();
            for (i, &child) in prev_ids.iter().enumerate() {
                // Innermost tier: the child IS a device — honor its
                // pool run's access-bandwidth override.
                let lane = if t == 0 {
                    cluster
                        .pool
                        .access_bw_of(child)
                        .map(|bw| bw / tier.oversub)
                        .unwrap_or(tier_lane)
                } else {
                    tier_lane
                };
                let trunk = sub as f64 * lane;
                let sw = sw_base + (i / tier.arity).min(n_sw - 1);
                for (a, b) in [(child, sw), (sw, child)] {
                    links.push(Link {
                        src: a,
                        dst: b,
                        capacity: trunk,
                        latency: tier.latency / 2.0,
                        flow_cap: lane,
                    });
                }
            }
            prev_ids = (sw_base..sw_base + n_sw).collect();
        }
        Self::build(cluster.name.clone(), nodes, links, devices, caps)
            .expect("cluster expansion is always connected")
    }

    /// Parse the arbitrary edge-list JSON format. Node entries are
    /// objects `{"id": ..., "kind": "device"|"switch"}` (kind defaults
    /// to `"device"`) or bare strings (devices). Device indices follow
    /// listing order. Links default to full-duplex (`"bidir": false`
    /// for a one-way link); a lone flow may fill a link (`flow_cap` =
    /// capacity) unless `"flow_cap_gbps"` says otherwise.
    pub fn from_json(v: &Json) -> Result<Self, String> {
        let name = v.get("name").as_str().unwrap_or("edgelist").to_string();
        let nodes_json = v.get("nodes").as_arr().ok_or("missing 'nodes' array")?;
        let mut nodes: Vec<Node> = Vec::new();
        let mut ids: BTreeMap<String, usize> = BTreeMap::new();
        let mut devices: Vec<usize> = Vec::new();
        for nj in nodes_json {
            let (id, kind) = match nj {
                Json::Str(s) => (s.clone(), NodeKind::Device),
                _ => {
                    let id = nj
                        .get("id")
                        .as_str()
                        .ok_or("node entry missing 'id'")?
                        .to_string();
                    let kind = match nj.get("kind").as_str().unwrap_or("device") {
                        "device" | "host" | "gpu" => NodeKind::Device,
                        "switch" | "router" => NodeKind::Switch,
                        other => return Err(format!("unknown node kind '{other}'")),
                    };
                    (id, kind)
                }
            };
            if ids.insert(id.clone(), nodes.len()).is_some() {
                return Err(format!("duplicate node id '{id}'"));
            }
            if kind == NodeKind::Device {
                devices.push(nodes.len());
            }
            nodes.push(Node { name: id, kind });
        }
        if devices.is_empty() {
            return Err("edge-list has no device nodes".into());
        }
        let links_json = v.get("links").as_arr().ok_or("missing 'links' array")?;
        if links_json.is_empty() {
            return Err("empty 'links'".into());
        }
        let mut links: Vec<Link> = Vec::new();
        for lj in links_json {
            let src_id = lj.get("src").as_str().ok_or("link missing 'src'")?;
            let dst_id = lj.get("dst").as_str().ok_or("link missing 'dst'")?;
            let src = *ids
                .get(src_id)
                .ok_or_else(|| format!("link src '{src_id}' is not a node"))?;
            let dst = *ids
                .get(dst_id)
                .ok_or_else(|| format!("link dst '{dst_id}' is not a node"))?;
            if src == dst {
                return Err(format!("self-link on '{src_id}'"));
            }
            let bw = lj
                .get("bw_gbps")
                .as_f64()
                .ok_or("link missing 'bw_gbps'")?
                * GB;
            if bw.is_nan() || bw <= 0.0 {
                return Err(format!("link {src_id}→{dst_id} has non-positive bandwidth"));
            }
            let latency = lj.get("latency_us").as_f64().unwrap_or(1.0) * 1e-6;
            let flow_cap = match lj.get("flow_cap_gbps").as_f64() {
                Some(fc) => fc * GB,
                None => bw,
            };
            let bidir = lj.get("bidir").as_bool().unwrap_or(true);
            links.push(Link {
                src,
                dst,
                capacity: bw,
                latency,
                flow_cap,
            });
            if bidir {
                links.push(Link {
                    src: dst,
                    dst: src,
                    capacity: bw,
                    latency,
                    flow_cap,
                });
            }
        }
        Self::build(name, nodes, links, devices, Vec::new())
    }

    /// Shared constructor: computes routing tables and checks that every
    /// device can reach every other.
    fn build(
        name: String,
        nodes: Vec<Node>,
        links: Vec<Link>,
        devices: Vec<usize>,
        caps: Vec<usize>,
    ) -> Result<Self, String> {
        let nn = nodes.len();
        // Links INTO each node, for the reverse Dijkstra.
        let mut in_links: Vec<Vec<usize>> = vec![Vec::new(); nn];
        for (ei, e) in links.iter().enumerate() {
            in_links[e.dst].push(ei);
        }
        let mut next_hop: Vec<Vec<u32>> = Vec::with_capacity(devices.len());
        for &dn in &devices {
            next_hop.push(route_toward(nn, &links, &in_links, dn));
        }
        // Reachability: every device pair must route.
        for (di, nh) in next_hop.iter().enumerate() {
            for (dj, &nj) in devices.iter().enumerate() {
                if di != dj && nh[nj] == u32::MAX {
                    return Err(format!(
                        "graph '{name}': device {dj} cannot reach device {di}"
                    ));
                }
            }
        }
        Ok(LinkGraph {
            name,
            nodes,
            links,
            devices,
            next_hop,
            caps,
        })
    }

    // ----- queries -------------------------------------------------------

    pub fn n_devices(&self) -> usize {
        self.devices.len()
    }

    /// Node id of device `dev`.
    pub fn device_node(&self, dev: usize) -> usize {
        self.devices[dev]
    }

    /// Resolve the deterministic route between two devices.
    pub fn path(&self, src_dev: usize, dst_dev: usize) -> PathInfo {
        let dn = self.devices[dst_dev];
        let mut cur = self.devices[src_dev];
        let mut out = PathInfo {
            links: Vec::new(),
            latency: 0.0,
            flow_cap: f64::INFINITY,
        };
        let mut guard = 0usize;
        while cur != dn {
            let e = self.next_hop[dst_dev][cur];
            assert!(
                e != u32::MAX,
                "no route from device {src_dev} to {dst_dev}"
            );
            let link = &self.links[e as usize];
            out.links.push(e as usize);
            out.latency += link.latency;
            out.flow_cap = out.flow_cap.min(link.flow_cap);
            cur = link.dst;
            guard += 1;
            assert!(guard <= self.nodes.len(), "routing loop");
        }
        out
    }

    /// Number of hierarchical ring levels collective lowering should
    /// use: the tier count for cluster expansions, 1 (one flat ring)
    /// for arbitrary edge-lists.
    pub fn n_ring_levels(&self) -> usize {
        self.caps.len().max(1)
    }

    /// Grouping key of device `dev` at ring level `level`: devices with
    /// equal keys share a subtree there (everything shares the single
    /// level on edge-lists).
    pub fn ring_group(&self, dev: usize, level: usize) -> usize {
        match self.caps.get(level) {
            Some(&c) => dev / c,
            None => 0,
        }
    }

    /// The optimistic flat abstraction of this graph — what a
    /// topology-agnostic analytic model assumes: every pair talks at
    /// the best pairwise bottleneck bandwidth with the smallest
    /// pairwise latency. It gives the level-wise DP *something* to
    /// search on for arbitrary edge-lists; the flow simulator then
    /// reveals what the abstraction hid (and is therefore never faster
    /// than it).
    pub fn approx_cluster(&self, accel: crate::hw::Accelerator) -> Cluster {
        let n = self.n_devices();
        let mut best_bw: f64 = 0.0;
        let mut best_lat = f64::INFINITY;
        for a in 0..n {
            for b in 0..n {
                if a == b {
                    continue;
                }
                let p = self.path(a, b);
                let mut bottleneck = p.flow_cap;
                for &l in &p.links {
                    bottleneck = bottleneck.min(self.links[l].capacity);
                }
                best_bw = best_bw.max(bottleneck);
                best_lat = best_lat.min(p.latency);
            }
        }
        let mut c = Cluster::flat(accel, n, best_bw, best_lat);
        c.name = format!("{}-flat-abstraction", self.name);
        c
    }

    /// Human-readable summary for logs.
    pub fn describe(&self) -> String {
        let switches = self
            .nodes
            .iter()
            .filter(|n| n.kind == NodeKind::Switch)
            .count();
        let (mut bw_lo, mut bw_hi) = (f64::INFINITY, 0.0f64);
        for l in &self.links {
            bw_lo = bw_lo.min(l.capacity);
            bw_hi = bw_hi.max(l.capacity);
        }
        format!(
            "{} [graph: {} devices, {} switches, {} directed links, {:.1}–{:.1} GB/s]",
            self.name,
            self.n_devices(),
            switches,
            self.links.len(),
            bw_lo / GB,
            bw_hi / GB,
        )
    }

    /// Display name of link `l` ("src→dst").
    pub fn link_name(&self, l: usize) -> String {
        let e = &self.links[l];
        format!("{}→{}", self.nodes[e.src].name, self.nodes[e.dst].name)
    }
}

/// Latency key with a total order (latencies are finite, never NaN).
#[derive(Debug, Clone, Copy)]
struct LatKey(f64);
impl PartialEq for LatKey {
    fn eq(&self, other: &Self) -> bool {
        self.0.total_cmp(&other.0).is_eq()
    }
}
impl Eq for LatKey {}
impl PartialOrd for LatKey {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for LatKey {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// Reverse Dijkstra toward destination node `dn`: returns, per node, the
/// id of the first link on the best path to `dn` (`u32::MAX` when
/// unreachable or already there). Paths minimize (hop count, latency)
/// lexicographically; exact ties resolve by deterministic heap order
/// (smaller node id settles first), so routing is identical on every
/// run — no ECMP randomness.
fn route_toward(nn: usize, links: &[Link], in_links: &[Vec<usize>], dn: usize) -> Vec<u32> {
    let mut hops: Vec<u32> = vec![u32::MAX; nn];
    let mut lat: Vec<f64> = vec![f64::INFINITY; nn];
    let mut hop_link: Vec<u32> = vec![u32::MAX; nn];
    let mut heap: BinaryHeap<Reverse<(u32, LatKey, usize)>> = BinaryHeap::new();
    hops[dn] = 0;
    lat[dn] = 0.0;
    heap.push(Reverse((0, LatKey(0.0), dn)));
    while let Some(Reverse((h, LatKey(l), u))) = heap.pop() {
        if h != hops[u] || l != lat[u] {
            continue; // stale entry
        }
        for &ei in &in_links[u] {
            let e = &links[ei];
            let v = e.src;
            let nh = h + 1;
            let nl = l + e.latency;
            let better = nh < hops[v] || (nh == hops[v] && nl < lat[v]);
            if better {
                hops[v] = nh;
                lat[v] = nl;
                hop_link[v] = ei as u32;
                heap.push(Reverse((nh, LatKey(nl), v)));
            }
        }
    }
    hop_link
}

// ----- generated fabrics --------------------------------------------------

/// NIC lane of the generated fabrics: 200 Gbit/s ≈ 25 GB/s.
const GEN_LANE: f64 = 25.0 * GB;

fn bidir_link(links: &mut Vec<Link>, a: usize, b: usize, capacity: f64, latency: f64, flow_cap: f64) {
    for (src, dst) in [(a, b), (b, a)] {
        links.push(Link {
            src,
            dst,
            capacity,
            latency,
            flow_cap,
        });
    }
}

/// Generate a classic k-ary fat-tree (Al-Fares et al.): `k` pods of
/// `k/2` edge + `k/2` aggregation switches, `(k/2)²` cores, `k³/4`
/// hosts, uniform 25 GB/s links (rearrangeably non-blocking). `k` must
/// be even and ≥ 2; `fattree(16)` is the 1024-host / 1344-node fabric
/// `nest netsim-scale` sweeps. Hosts under one edge switch are
/// consecutive device ids (rack-locality is id-locality), and routing
/// is the deterministic shortest-path tables every `LinkGraph` gets.
pub fn fattree(k: usize) -> LinkGraph {
    assert!(k >= 2 && k % 2 == 0, "fat-tree arity must be even, got {k}");
    let h = k / 2;
    let hosts = k * h * h;
    let lat = 1e-6;
    let mut nodes: Vec<Node> = (0..hosts)
        .map(|d| Node {
            name: format!("h{d}"),
            kind: NodeKind::Device,
        })
        .collect();
    let edge_base = nodes.len();
    for p in 0..k {
        for e in 0..h {
            nodes.push(Node {
                name: format!("edge{p}.{e}"),
                kind: NodeKind::Switch,
            });
        }
    }
    let agg_base = nodes.len();
    for p in 0..k {
        for a in 0..h {
            nodes.push(Node {
                name: format!("agg{p}.{a}"),
                kind: NodeKind::Switch,
            });
        }
    }
    let core_base = nodes.len();
    for c in 0..h * h {
        nodes.push(Node {
            name: format!("core{c}"),
            kind: NodeKind::Switch,
        });
    }

    let mut links: Vec<Link> = Vec::new();
    for p in 0..k {
        for e in 0..h {
            let edge = edge_base + p * h + e;
            for i in 0..h {
                bidir_link(&mut links, p * h * h + e * h + i, edge, GEN_LANE, lat, GEN_LANE);
            }
            for a in 0..h {
                bidir_link(&mut links, edge, agg_base + p * h + a, GEN_LANE, lat, GEN_LANE);
            }
        }
        for a in 0..h {
            for j in 0..h {
                bidir_link(
                    &mut links,
                    agg_base + p * h + a,
                    core_base + a * h + j,
                    GEN_LANE,
                    lat,
                    GEN_LANE,
                );
            }
        }
    }
    LinkGraph::build(
        format!("fattree-k{k}"),
        nodes,
        links,
        (0..hosts).collect(),
        Vec::new(),
    )
    .expect("generated fat-tree is connected")
}

/// Generate a two-tier spine-leaf fabric: `racks` leaves of
/// `hosts_per_rack` hosts each, `max(1, racks/4)` spines, host lanes at
/// 25 GB/s, and each leaf's spine uplinks sized so aggregate uplink =
/// downlink / `oversub` (per-flow ceiling one lane). Hosts in one rack
/// are consecutive device ids.
pub fn spineleaf(racks: usize, hosts_per_rack: usize, oversub: f64) -> LinkGraph {
    assert!(racks >= 1 && hosts_per_rack >= 1, "empty spine-leaf");
    assert!(
        oversub.is_finite() && oversub >= 1.0,
        "oversubscription must be ≥ 1, got {oversub}"
    );
    let hosts = racks * hosts_per_rack;
    let spines = (racks / 4).max(1);
    let mut nodes: Vec<Node> = (0..hosts)
        .map(|d| Node {
            name: format!("h{d}"),
            kind: NodeKind::Device,
        })
        .collect();
    let leaf_base = nodes.len();
    for r in 0..racks {
        nodes.push(Node {
            name: format!("leaf{r}"),
            kind: NodeKind::Switch,
        });
    }
    let spine_base = nodes.len();
    for s in 0..spines {
        nodes.push(Node {
            name: format!("spine{s}"),
            kind: NodeKind::Switch,
        });
    }

    let mut links: Vec<Link> = Vec::new();
    let uplink = hosts_per_rack as f64 * GEN_LANE / oversub / spines as f64;
    for r in 0..racks {
        let leaf = leaf_base + r;
        for i in 0..hosts_per_rack {
            bidir_link(&mut links, r * hosts_per_rack + i, leaf, GEN_LANE, 1e-6, GEN_LANE);
        }
        for s in 0..spines {
            bidir_link(
                &mut links,
                leaf,
                spine_base + s,
                uplink,
                2e-6,
                GEN_LANE.min(uplink),
            );
        }
    }
    LinkGraph::build(
        format!("spineleaf-{racks}x{hosts_per_rack}-o{oversub}"),
        nodes,
        links,
        (0..hosts).collect(),
        Vec::new(),
    )
    .expect("generated spine-leaf is connected")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{json, prop};

    #[test]
    fn fat_tree_expansion_counts() {
        let c = Cluster::fat_tree_tpuv4(64); // tiers 8 × 4 × 2
        let g = LinkGraph::from_cluster(&c);
        // 64 devices + 8 node switches + 2 leaf switches + 1 agg switch.
        assert_eq!(g.n_devices(), 64);
        assert_eq!(g.nodes.len(), 64 + 8 + 2 + 1);
        // Every child entity gets an up and a down link per tier.
        assert_eq!(g.links.len(), (64 + 8 + 2) * 2);
    }

    #[test]
    fn paths_match_levelwise_abstraction() {
        // Single-path properties: latency = Cluster::lat(lca) and lone
        // flow rate = Cluster::bw_eff(lca), for every preset and many
        // random pairs.
        for c in [
            Cluster::fat_tree_tpuv4(64),
            Cluster::spine_leaf_h100(128, 2.0),
            Cluster::v100_cluster(8),
            Cluster::torus2d(8, 8, 50.0 * GB, 1e-6),
        ] {
            let g = LinkGraph::from_cluster(&c);
            prop::forall(50, 0xD1CE, |rng| {
                let a = rng.gen_range(c.n_devices());
                let mut b = rng.gen_range(c.n_devices());
                if a == b {
                    b = (b + 1) % c.n_devices();
                }
                // LCA level: innermost tier whose subtree holds both.
                let mut lca = c.n_levels() - 1;
                for l in 0..c.n_levels() {
                    if a / c.capacity(l) == b / c.capacity(l) {
                        lca = l;
                        break;
                    }
                }
                let p = g.path(a, b);
                assert_eq!(p.links.len(), 2 * (lca + 1), "{a}->{b}");
                let lat = c.lat(lca);
                assert!(
                    (p.latency - lat).abs() <= 1e-12 + 1e-9 * lat,
                    "{a}->{b}: {} vs {}",
                    p.latency,
                    lat
                );
                assert_eq!(p.flow_cap, c.bw_eff(lca), "{a}->{b}");
            });
        }
    }

    #[test]
    fn trunk_capacity_aggregates_and_oversubscribes() {
        let c = Cluster::spine_leaf_h100(64, 2.0);
        let g = LinkGraph::from_cluster(&c);
        // A leaf→spine trunk aggregates 32 devices at 12.5/2 GB/s lanes.
        let trunk = g
            .links
            .iter()
            .find(|l| {
                g.nodes[l.src].name.starts_with("leaf")
                    && g.nodes[l.dst].name.starts_with("spine")
            })
            .expect("leaf→spine trunk exists");
        assert!((trunk.capacity - 32.0 * 12.5 * GB / 2.0).abs() < 1.0);
        assert!((trunk.flow_cap - 12.5 * GB / 2.0).abs() < 1.0);
    }

    #[test]
    fn hetero_pool_access_links_use_run_overrides() {
        let c = Cluster::hetero_pool(64); // H100 on [0,32), V100 on [32,64)
        let g = LinkGraph::from_cluster(&c);
        let fast = g.links.iter().find(|l| l.src == 0).expect("access link");
        let slow = g.links.iter().find(|l| l.src == 40).expect("access link");
        assert!((fast.flow_cap - 900.0 * GB).abs() < 1.0, "{}", fast.flow_cap);
        assert!((slow.flow_cap - 300.0 * GB).abs() < 1.0, "{}", slow.flow_cap);
        // A lone V100-island intra-node flow moves at the slow lane —
        // strictly below the analytic tier's (optimistic) estimate.
        let p = g.path(40, 41);
        assert_eq!(p.flow_cap, 300.0 * GB);
        assert!(p.flow_cap < c.bw_eff(0));
        // H100-island flows still reproduce the analytic tier exactly.
        let p = g.path(0, 1);
        assert_eq!(p.flow_cap, c.bw_eff(0));
    }

    #[test]
    fn routing_is_deterministic() {
        let c = Cluster::spine_leaf_h100(64, 2.0);
        let a = LinkGraph::from_cluster(&c);
        let b = LinkGraph::from_cluster(&c);
        for d in 0..a.n_devices() {
            assert_eq!(a.next_hop[d], b.next_hop[d]);
        }
    }

    fn dumbbell_json() -> String {
        let mut nodes = String::new();
        for d in 0..8 {
            nodes.push_str(&format!("{{\"id\": \"d{d}\", \"kind\": \"device\"}},"));
        }
        format!(
            r#"{{"name": "dumbbell-8", "accelerator": "h100",
                "nodes": [{nodes}
                          {{"id": "s0", "kind": "switch"}},
                          {{"id": "s1", "kind": "switch"}}],
                "links": [
                  {{"src": "d0", "dst": "s0", "bw_gbps": 100, "latency_us": 1}},
                  {{"src": "d1", "dst": "s0", "bw_gbps": 100, "latency_us": 1}},
                  {{"src": "d2", "dst": "s0", "bw_gbps": 100, "latency_us": 1}},
                  {{"src": "d3", "dst": "s0", "bw_gbps": 100, "latency_us": 1}},
                  {{"src": "d4", "dst": "s1", "bw_gbps": 100, "latency_us": 1}},
                  {{"src": "d5", "dst": "s1", "bw_gbps": 100, "latency_us": 1}},
                  {{"src": "d6", "dst": "s1", "bw_gbps": 100, "latency_us": 1}},
                  {{"src": "d7", "dst": "s1", "bw_gbps": 100, "latency_us": 1}},
                  {{"src": "s0", "dst": "s1", "bw_gbps": 25, "latency_us": 5}}
                ]}}"#
        )
    }

    #[test]
    fn edge_list_parses_and_routes() {
        let g = LinkGraph::from_json(&json::parse(&dumbbell_json()).unwrap()).unwrap();
        assert_eq!(g.n_devices(), 8);
        // Same-side pair: 2 hops through s0.
        let p = g.path(0, 1);
        assert_eq!(p.links.len(), 2);
        assert!((p.flow_cap - 100.0 * GB).abs() < 1.0);
        // Cross pair: 3 hops through the 25 GB/s waist.
        let p = g.path(0, 4);
        assert_eq!(p.links.len(), 3);
        assert!((p.flow_cap - 25.0 * GB).abs() < 1.0);
        assert!((p.latency - 7e-6).abs() < 1e-12);
    }

    #[test]
    fn edge_list_rejects_bad_inputs() {
        for bad in [
            // No devices.
            r#"{"nodes": [{"id": "s", "kind": "switch"}],
                "links": [{"src": "s", "dst": "s", "bw_gbps": 1}]}"#,
            // Unknown endpoint.
            r#"{"nodes": ["a", "b"],
                "links": [{"src": "a", "dst": "zzz", "bw_gbps": 1}]}"#,
            // Duplicate id.
            r#"{"nodes": ["a", "a"], "links": [{"src": "a", "dst": "a", "bw_gbps": 1}]}"#,
            // Disconnected devices.
            r#"{"nodes": ["a", "b", "c"],
                "links": [{"src": "a", "dst": "b", "bw_gbps": 1}]}"#,
            // Missing bandwidth.
            r#"{"nodes": ["a", "b"], "links": [{"src": "a", "dst": "b"}]}"#,
        ] {
            assert!(
                LinkGraph::from_json(&json::parse(bad).unwrap()).is_err(),
                "{bad}"
            );
        }
    }

    #[test]
    fn approx_cluster_is_optimistic() {
        let g = LinkGraph::from_json(&json::parse(&dumbbell_json()).unwrap()).unwrap();
        let c = g.approx_cluster(crate::hw::Accelerator::h100());
        assert_eq!(c.n_devices(), 8);
        // Best pairwise bottleneck is a same-side pair at 100 GB/s with
        // 2 µs of latency — faster than anything crossing the waist.
        assert!((c.bw_eff(0) - 100.0 * GB).abs() < 1.0);
        assert!((c.lat(0) - 2e-6).abs() < 1e-12);
    }

    #[test]
    fn ring_levels_flat_for_edge_lists() {
        let g = LinkGraph::from_json(&json::parse(&dumbbell_json()).unwrap()).unwrap();
        assert_eq!(g.n_ring_levels(), 1);
        assert_eq!(g.ring_group(0, 0), g.ring_group(7, 0));
        let c = Cluster::fat_tree_tpuv4(64);
        let t = LinkGraph::from_cluster(&c);
        assert_eq!(t.n_ring_levels(), 3);
        assert_eq!(t.ring_group(0, 0), 0);
        assert_eq!(t.ring_group(9, 0), 1);
        assert_eq!(t.ring_group(9, 1), 0);
    }

    #[test]
    fn fattree_generator_shape_and_routing() {
        let g = fattree(4);
        assert_eq!(g.n_devices(), 16);
        assert_eq!(g.nodes.len(), 16 + 8 + 8 + 4);
        // host-edge + edge-agg + agg-core, bidirectional.
        assert_eq!(g.links.len(), 2 * (16 + 16 + 16));
        // Rack-local: two hops under the shared edge switch.
        assert_eq!(g.path(0, 1).links.len(), 2);
        // Cross-pod: host→edge→agg→core→agg→edge→host.
        assert_eq!(g.path(0, 15).links.len(), 6);
        // Deterministic: regenerating gives identical routes.
        let g2 = fattree(4);
        assert_eq!(g.path(3, 12).links, g2.path(3, 12).links);
    }

    #[test]
    fn fattree_reaches_netsim_scale_size() {
        let g = fattree(16);
        assert_eq!(g.n_devices(), 1024);
        assert_eq!(g.nodes.len(), 1024 + 128 + 128 + 64);
    }

    #[test]
    fn spineleaf_generator_shape_and_oversub() {
        let g = spineleaf(8, 4, 4.0);
        assert_eq!(g.n_devices(), 32);
        assert_eq!(g.nodes.len(), 32 + 8 + 2);
        assert_eq!(g.path(0, 1).links.len(), 2);
        // Cross-rack: host→leaf→spine→leaf→host.
        assert_eq!(g.path(0, 31).links.len(), 4);
        // Aggregate uplink per leaf = downlink / oversub.
        let leaf = 32; // first leaf node id
        let up: f64 = g
            .links
            .iter()
            .filter(|l| l.src == leaf && l.dst >= 40)
            .map(|l| l.capacity)
            .sum();
        let down: f64 = g
            .links
            .iter()
            .filter(|l| l.src == leaf && l.dst < 32)
            .map(|l| l.capacity)
            .sum();
        assert!((up - down / 4.0).abs() < 1.0, "up {up} vs down/4 {}", down / 4.0);
    }
}
