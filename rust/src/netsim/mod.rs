//! Flow-level contention-aware network simulation (netsim).
//!
//! NEST's DP searches over the level-wise analytic abstraction
//! ([`crate::network`]) and is *evaluated* by the analytic DES
//! ([`crate::sim`]) — but both price communication with closed-form α–β
//! terms that assume every transfer gets its level's effective
//! bandwidth. This subsystem closes the loop the way Parsimon/flowSim
//! validate datacenter designs: it expands the topology into an explicit
//! link graph ([`topo`]), lowers a placement plan's entire training
//! batch into timestamped flows ([`flows`]), and replays them through a
//! max-min fair-share engine ([`fairshare`]) that re-solves bottleneck
//! rates at every flow arrival/completion — incrementally, for just the
//! link-sharing component the event touched. The result is a
//! contention-aware batch time plus per-link utilization — an
//! independent check of the analytic cost model's *congestion* blind
//! spot, and the first place oversubscribed trunks, cross-replica
//! interference, and arbitrary (non-tree) fabrics become visible.
//!
//! One deliberate asymmetry: netsim only ever reports congestion *on
//! top of* the analytic estimate. The data-parallel sync keeps the
//! DES's `dp_allreduce` term as a parallel lower bound (see
//! `flows::lower`), because the physical rings can legitimately beat
//! the `spread_shape` ceiling on ragged strides — netsim answers "how
//! much worse under contention", not "was the analytic model too
//! pessimistic".
//!
//! Entry points: [`simulate_flows`] for one plan on one topology, the
//! `nest netsim` / `nest netsim-xval` CLI subcommands, and
//! [`crate::harness::netsim::netsim_xval`] for the cross-validation
//! table over topology families. Since the refinement loop
//! ([`crate::solver::refine`], `nest refine`) landed, the simulator is
//! also a *decision-maker*: it re-ranks the DP's analytic top-K
//! shortlist under contention.

pub mod fairshare;
pub mod flows;
pub mod topo;

pub use fairshare::{
    FairshareEngine, FlowSpec, LinkUtil, NetsimReport, RefillMode, TaskKind, Workload,
};
pub use topo::{Link, LinkGraph, Node, NodeKind, PathInfo};

use crate::graph::LayerGraph;
use crate::network::Cluster;
use crate::sim::Schedule;
use crate::solver::plan::PlacementPlan;

/// Lower one training batch of `plan` onto `topo` and run the
/// fair-share engine. `cluster` is the analytic view the plan was
/// solved against (compute costs + α accounting). Deterministic:
/// identical inputs produce bit-identical reports.
pub fn simulate_flows(
    graph: &LayerGraph,
    cluster: &Cluster,
    topo: &LinkGraph,
    plan: &PlacementPlan,
    schedule: Schedule,
) -> NetsimReport {
    let mut engine = FairshareEngine::new(topo);
    simulate_flows_with(&mut engine, graph, cluster, topo, plan, schedule)
}

/// [`simulate_flows`] on a caller-held [`FairshareEngine`], so loops
/// that replay many plans on one topology (the refinement re-ranking,
/// the benches) reuse the engine's per-link buffers instead of
/// reallocating them per plan. Bit-identical to a fresh engine.
pub fn simulate_flows_with(
    engine: &mut FairshareEngine,
    graph: &LayerGraph,
    cluster: &Cluster,
    topo: &LinkGraph,
    plan: &PlacementPlan,
    schedule: Schedule,
) -> NetsimReport {
    let wl = flows::lower(graph, cluster, topo, plan, schedule);
    engine.run(topo, &wl)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::solver::{solve, SolverOpts};

    #[test]
    fn end_to_end_on_solver_plan() {
        // Full pipeline: solve → expand → lower → flow-sim, on a small
        // fat-tree. The flow-level batch time tracks the analytic DES
        // from above (never below, up to float dust).
        let g = models::bert_large(1);
        let c = Cluster::fat_tree_tpuv4(64);
        let sol = solve(&g, &c, &SolverOpts::default()).expect("feasible");
        let topo = LinkGraph::from_cluster(&c);
        let ana = crate::sim::simulate(&g, &c, &sol.plan, Schedule::OneFOneB);
        let flow = simulate_flows(&g, &c, &topo, &sol.plan, Schedule::OneFOneB);
        assert!(flow.batch_time.is_finite() && flow.batch_time > 0.0);
        assert!(
            flow.batch_time >= ana.batch_time * (1.0 - 1e-9),
            "flow {} < analytic {}",
            flow.batch_time,
            ana.batch_time
        );
        assert!(
            flow.batch_time <= ana.batch_time * 2.0,
            "flow-sim drifted from analytic on an uncontended fat-tree: {} vs {}",
            flow.batch_time,
            ana.batch_time
        );
    }

    #[test]
    fn reports_bit_identical_across_runs() {
        let g = models::bert_large(1);
        let c = Cluster::spine_leaf_h100(64, 2.0);
        let sol = solve(&g, &c, &SolverOpts::default()).expect("feasible");
        let topo = LinkGraph::from_cluster(&c);
        let a = simulate_flows(&g, &c, &topo, &sol.plan, Schedule::OneFOneB);
        let b = simulate_flows(&g, &c, &topo, &sol.plan, Schedule::OneFOneB);
        a.assert_bits_eq(&b, "repeated simulate_flows");
    }
}
