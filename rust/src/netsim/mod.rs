//! Flow-level contention-aware network simulation (netsim).
//!
//! NEST's DP searches over the level-wise analytic abstraction
//! ([`crate::network`]) and is *evaluated* by the analytic DES
//! ([`crate::sim`]) — but both price communication with closed-form α–β
//! terms that assume every transfer gets its level's effective
//! bandwidth. This subsystem closes the loop the way Parsimon/flowSim
//! validate datacenter designs: it expands the topology into an explicit
//! link graph ([`topo`]), lowers a placement plan's entire training
//! batch into timestamped flows ([`flows`]), and replays them through a
//! max-min fair-share engine ([`fairshare`]) that re-solves bottleneck
//! rates at every flow arrival/completion — incrementally, for just the
//! link-sharing component the event touched. The result is a
//! contention-aware batch time plus per-link utilization — an
//! independent check of the analytic cost model's *congestion* blind
//! spot, and the first place oversubscribed trunks, cross-replica
//! interference, and arbitrary (non-tree) fabrics become visible.
//!
//! One deliberate asymmetry: netsim only ever reports congestion *on
//! top of* the analytic estimate. The data-parallel sync keeps the
//! DES's `dp_allreduce` term as a parallel lower bound (see
//! `flows::lower`), because the physical rings can legitimately beat
//! the `spread_shape` ceiling on ragged strides — netsim answers "how
//! much worse under contention", not "was the analytic model too
//! pessimistic".
//!
//! The one entry point is [`Simulation`]: a builder holding
//! [`NetsimOpts`] (execution mode, refill strategy, worker threads,
//! engine reuse) with all environment resolution (`NEST_REFERENCE`,
//! `NEST_NETSIM_MODE`) in exactly one place — [`NetsimOpts::resolve`].
//! [`SimMode::Decomposed`] statically partitions the workload into
//! link-sharing components and fans them across scoped worker threads
//! ([`decompose`]), bit-identical to the monolithic event loop; the
//! `nest netsim` / `netsim-xval` / `netsim-scale` subcommands and
//! [`crate::harness::netsim::netsim_xval`] sit on top. Since the
//! refinement loop ([`crate::solver::refine`], `nest refine`) landed,
//! the simulator is also a *decision-maker*: it re-ranks the DP's
//! analytic top-K shortlist under contention — and, with a seeded
//! background mix from [`flowgen`] injected into the lowered workload
//! ([`flowgen::inject`] before [`Simulation::run_workload`]), under
//! multi-tenant fabric load as well (`nest refine --bg-load`,
//! `nest mix`).

pub mod decompose;
pub mod fairshare;
pub mod faults;
pub mod flowgen;
pub mod flows;
pub mod topo;

pub use fairshare::{
    CapEvent, FairshareEngine, FlowSpec, LinkUtil, NetsimReport, RefillMode, TaskKind, Workload,
};
pub use faults::{FaultScenario, FaultSpec, LinkFault};
pub use flowgen::{BgFlow, BgMix, MixSpec, SizeDist, SpatialMatrix};
pub use topo::{Link, LinkGraph, Node, NodeKind, PathInfo};

use crate::graph::LayerGraph;
use crate::network::Cluster;
use crate::sim::Schedule;
use crate::solver::plan::PlacementPlan;

/// Execution strategy for one simulation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SimMode {
    /// Resolve from the environment once per process
    /// (`NEST_NETSIM_MODE=monolithic|decomposed`; default monolithic).
    #[default]
    Auto,
    /// One event loop over the whole workload.
    Monolithic,
    /// Static partition into link-sharing components, fanned across
    /// scoped worker threads, merged bit-identically ([`decompose`]).
    Decomposed,
}

/// `NEST_NETSIM_MODE` read once per process — the single place the
/// execution-mode environment switch is consulted.
fn env_sim_mode() -> Option<SimMode> {
    static MODE: std::sync::OnceLock<Option<SimMode>> = std::sync::OnceLock::new();
    *MODE.get_or_init(|| match std::env::var("NEST_NETSIM_MODE").ok().as_deref() {
        Some("monolithic") => Some(SimMode::Monolithic),
        Some("decomposed") => Some(SimMode::Decomposed),
        Some(other) if !other.is_empty() => {
            eprintln!(
                "warning: NEST_NETSIM_MODE='{other}' is not 'monolithic' or 'decomposed'; ignored"
            );
            None
        }
        _ => None,
    })
}

impl SimMode {
    /// Collapse `Auto` to the environment's choice (default monolithic).
    pub fn resolve(self) -> SimMode {
        match self {
            SimMode::Auto => env_sim_mode().unwrap_or(SimMode::Monolithic),
            m => m,
        }
    }
}

/// All knobs of a simulation run. `Default` is `Auto` everywhere —
/// env-resolved via [`NetsimOpts::resolve`], which is the *only* place
/// `NEST_REFERENCE` / `NEST_NETSIM_MODE` feed the simulator.
#[derive(Debug, Clone, Copy)]
pub struct NetsimOpts {
    pub mode: SimMode,
    /// Rate-maintenance strategy within each event loop
    /// (`NEST_REFERENCE=1` resolves `Auto` to the full-refill twin).
    pub refill: RefillMode,
    /// Decomposed-mode worker threads (0 = one per core). Monolithic
    /// runs are single-threaded regardless.
    pub threads: usize,
    /// Keep the engine (its per-link buffers) across monolithic runs on
    /// one topology. Decomposed runs build per-worker engines instead.
    pub reuse_engine: bool,
}

impl Default for NetsimOpts {
    fn default() -> Self {
        NetsimOpts {
            mode: SimMode::Auto,
            refill: RefillMode::Auto,
            threads: 0,
            reuse_engine: true,
        }
    }
}

impl NetsimOpts {
    /// Collapse every `Auto` to its environment-resolved value.
    pub fn resolve(self) -> NetsimOpts {
        NetsimOpts {
            mode: self.mode.resolve(),
            refill: self.refill.resolve(),
            ..self
        }
    }
}

/// The unified simulation entry point: configure once, run many plans
/// or workloads. Replaces the accreted `simulate_flows` /
/// `simulate_flows_with` / `fairshare::run_with_mode` surface (kept as
/// thin deprecated wrappers).
///
/// ```ignore
/// let mut sim = Simulation::new().mode(SimMode::Decomposed).threads(8);
/// let report = sim.run(&graph, &cluster, &topo, &plan, Schedule::OneFOneB);
/// ```
///
/// Reports are bit-identical across modes, thread counts, and engine
/// reuse — the property suite pins all three.
#[derive(Debug, Default)]
pub struct Simulation {
    opts: NetsimOpts,
    /// Retained monolithic engine (rebuilt when the topology's link
    /// count changes; see [`NetsimOpts::reuse_engine`]).
    engine: Option<FairshareEngine>,
}

impl Simulation {
    pub fn new() -> Self {
        Simulation::default()
    }

    pub fn with_opts(opts: NetsimOpts) -> Self {
        Simulation {
            opts,
            engine: None,
        }
    }

    /// Builder: execution mode.
    pub fn mode(mut self, mode: SimMode) -> Self {
        self.opts.mode = mode;
        self
    }

    /// Builder: refill strategy.
    pub fn refill(mut self, refill: RefillMode) -> Self {
        self.opts.refill = refill;
        self
    }

    /// Builder: decomposed-mode worker threads (0 = one per core).
    pub fn threads(mut self, threads: usize) -> Self {
        self.opts.threads = threads;
        self
    }

    /// Builder: engine retention across monolithic runs.
    pub fn reuse_engine(mut self, reuse: bool) -> Self {
        self.opts.reuse_engine = reuse;
        self
    }

    /// The configured (unresolved) options.
    pub fn opts(&self) -> NetsimOpts {
        self.opts
    }

    /// Lower one training batch of `plan` onto `topo` and simulate it.
    /// `cluster` is the analytic view the plan was solved against
    /// (compute costs + α accounting). Deterministic: identical inputs
    /// produce bit-identical reports.
    pub fn run(
        &mut self,
        graph: &LayerGraph,
        cluster: &Cluster,
        topo: &LinkGraph,
        plan: &PlacementPlan,
        schedule: Schedule,
    ) -> NetsimReport {
        let wl = flows::lower(graph, cluster, topo, plan, schedule);
        self.run_workload(topo, &wl)
    }

    /// Simulate an already-lowered [`Workload`].
    pub fn run_workload(&mut self, topo: &LinkGraph, wl: &Workload) -> NetsimReport {
        let opts = self.opts.resolve();
        match opts.mode {
            SimMode::Decomposed => decompose::run_decomposed(topo, wl, opts.refill, opts.threads),
            _ => {
                if !opts.reuse_engine {
                    return FairshareEngine::new(topo).run_with_mode(topo, wl, opts.refill);
                }
                let stale = self
                    .engine
                    .as_ref()
                    .map_or(true, |e| e.n_links() != topo.links.len());
                if stale {
                    self.engine = Some(FairshareEngine::new(topo));
                }
                self.engine
                    .as_mut()
                    .expect("engine just ensured")
                    .run_with_mode(topo, wl, opts.refill)
            }
        }
    }
}

/// Deprecated: construct a [`Simulation`] instead (this is a thin
/// delegating wrapper kept so out-of-tree callers don't break).
#[doc(hidden)]
pub fn simulate_flows(
    graph: &LayerGraph,
    cluster: &Cluster,
    topo: &LinkGraph,
    plan: &PlacementPlan,
    schedule: Schedule,
) -> NetsimReport {
    Simulation::new().run(graph, cluster, topo, plan, schedule)
}

/// Deprecated: hold a [`Simulation`] (its retained engine replaces the
/// caller-held [`FairshareEngine`]). Thin delegating wrapper for
/// out-of-tree callers.
#[doc(hidden)]
pub fn simulate_flows_with(
    engine: &mut FairshareEngine,
    graph: &LayerGraph,
    cluster: &Cluster,
    topo: &LinkGraph,
    plan: &PlacementPlan,
    schedule: Schedule,
) -> NetsimReport {
    let wl = flows::lower(graph, cluster, topo, plan, schedule);
    engine.run(topo, &wl)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::solver::{solve, SolverOpts};

    #[test]
    fn end_to_end_on_solver_plan() {
        // Full pipeline: solve → expand → lower → flow-sim, on a small
        // fat-tree. The flow-level batch time tracks the analytic DES
        // from above (never below, up to float dust).
        let g = models::bert_large(1);
        let c = Cluster::fat_tree_tpuv4(64);
        let sol = solve(&g, &c, &SolverOpts::default()).expect("feasible");
        let topo = LinkGraph::from_cluster(&c);
        let ana = crate::sim::simulate(&g, &c, &sol.plan, Schedule::OneFOneB);
        let flow = Simulation::new().run(&g, &c, &topo, &sol.plan, Schedule::OneFOneB);
        assert!(flow.batch_time.is_finite() && flow.batch_time > 0.0);
        assert!(
            flow.batch_time >= ana.batch_time * (1.0 - 1e-9),
            "flow {} < analytic {}",
            flow.batch_time,
            ana.batch_time
        );
        assert!(
            flow.batch_time <= ana.batch_time * 2.0,
            "flow-sim drifted from analytic on an uncontended fat-tree: {} vs {}",
            flow.batch_time,
            ana.batch_time
        );
    }

    #[test]
    fn reports_bit_identical_across_runs() {
        let g = models::bert_large(1);
        let c = Cluster::spine_leaf_h100(64, 2.0);
        let sol = solve(&g, &c, &SolverOpts::default()).expect("feasible");
        let topo = LinkGraph::from_cluster(&c);
        let mut sim = Simulation::new();
        let a = sim.run(&g, &c, &topo, &sol.plan, Schedule::OneFOneB);
        let b = sim.run(&g, &c, &topo, &sol.plan, Schedule::OneFOneB);
        a.assert_bits_eq(&b, "repeated Simulation::run");
    }

    #[test]
    fn all_modes_agree_on_a_solver_plan() {
        // The acceptance bar in miniature: monolithic, decomposed (1 and
        // 4 threads), fresh engine, retained engine, and the deprecated
        // wrapper all produce the same bits on a real lowered plan.
        let g = models::bert_large(1);
        let c = Cluster::spine_leaf_h100(64, 4.0);
        let sol = solve(&g, &c, &SolverOpts::default()).expect("feasible");
        let topo = LinkGraph::from_cluster(&c);
        let mono = Simulation::new()
            .mode(SimMode::Monolithic)
            .run(&g, &c, &topo, &sol.plan, Schedule::OneFOneB);
        for threads in [1, 4] {
            let dec = Simulation::new()
                .mode(SimMode::Decomposed)
                .threads(threads)
                .run(&g, &c, &topo, &sol.plan, Schedule::OneFOneB);
            mono.assert_bits_eq(&dec, &format!("decomposed@{threads} vs monolithic"));
        }
        let fresh = Simulation::new()
            .reuse_engine(false)
            .run(&g, &c, &topo, &sol.plan, Schedule::OneFOneB);
        mono.assert_bits_eq(&fresh, "fresh engine vs retained");
        let wrapped = simulate_flows(&g, &c, &topo, &sol.plan, Schedule::OneFOneB);
        mono.assert_bits_eq(&wrapped, "deprecated wrapper vs Simulation");
    }

    #[test]
    fn retained_engine_rebuilds_on_topology_change() {
        let g = models::bert_large(1);
        let c1 = Cluster::fat_tree_tpuv4(64);
        let c2 = Cluster::spine_leaf_h100(64, 2.0);
        let t1 = LinkGraph::from_cluster(&c1);
        let t2 = LinkGraph::from_cluster(&c2);
        let p1 = solve(&g, &c1, &SolverOpts::default()).expect("feasible").plan;
        let p2 = solve(&g, &c2, &SolverOpts::default()).expect("feasible").plan;
        let mut sim = Simulation::new();
        let a1 = sim.run(&g, &c1, &t1, &p1, Schedule::OneFOneB);
        let b2 = sim.run(&g, &c2, &t2, &p2, Schedule::OneFOneB);
        let a1_again = sim.run(&g, &c1, &t1, &p1, Schedule::OneFOneB);
        a1.assert_bits_eq(&a1_again, "engine swapped across topologies");
        let fresh2 = Simulation::new().run(&g, &c2, &t2, &p2, Schedule::OneFOneB);
        b2.assert_bits_eq(&fresh2, "retained vs fresh on second topology");
    }

    #[test]
    fn background_mix_rides_every_mode_bit_identically() {
        // The multi-tenant acceptance bar in miniature: a seeded
        // background mix injected into a real lowered plan produces the
        // same bits monolithic and decomposed at 1 and 4 threads, and
        // the report splits training vs background accounting.
        let g = models::bert_large(1);
        let c = Cluster::spine_leaf_h100(64, 4.0);
        let sol = solve(&g, &c, &SolverOpts::default()).expect("feasible");
        let topo = LinkGraph::from_cluster(&c);
        let base = Simulation::new().run(&g, &c, &topo, &sol.plan, Schedule::OneFOneB);
        assert_eq!(
            base.train_batch_time.to_bits(),
            base.batch_time.to_bits(),
            "no mix injected: training time is the makespan"
        );
        assert_eq!(base.bg_flows, 0);
        assert_eq!(base.bg_bytes, 0.0);

        let mut wl = flows::lower(&g, &c, &topo, &sol.plan, Schedule::OneFOneB);
        let mix = flowgen::generate(
            &topo,
            &flowgen::MixSpec::at_load(0.5, base.batch_time, 0xB6),
        );
        assert!(flowgen::inject(&mut wl, &mix) > 0);
        let mono = Simulation::new()
            .mode(SimMode::Monolithic)
            .run_workload(&topo, &wl);
        for threads in [1, 4] {
            let dec = Simulation::new()
                .mode(SimMode::Decomposed)
                .threads(threads)
                .run_workload(&topo, &wl);
            mono.assert_bits_eq(&dec, &format!("mixed workload decomposed@{threads}"));
        }
        assert!(mono.bg_flows > 0);
        assert_eq!(mono.n_flows - mono.bg_flows, base.n_flows);
        assert!(mono.bg_bytes > 0.0 && mono.bg_bytes < mono.total_bytes);
        assert!(mono.train_batch_time <= mono.batch_time);
        assert!(mono.train_batch_time > 0.0 && mono.train_batch_time.is_finite());
        // Conservation splits: background bytes drain like any others.
        let bg_injected: f64 = mix
            .flows
            .iter()
            .filter(|f| f.flow.bytes > 0.5)
            .map(|f| f.flow.bytes)
            .sum();
        assert!((mono.bg_bytes - bg_injected).abs() <= 1e-6 * bg_injected.max(1.0));
        assert!(
            (mono.bg_delivered_bytes - mono.bg_bytes).abs()
                <= 0.5 * mono.bg_flows as f64 + 1e-6
        );
    }

    #[test]
    fn opts_resolve_leaves_no_auto() {
        let r = NetsimOpts::default().resolve();
        assert_ne!(r.mode, SimMode::Auto);
        assert_ne!(r.refill, RefillMode::Auto);
        // Explicit choices pass through untouched.
        let e = NetsimOpts {
            mode: SimMode::Decomposed,
            refill: RefillMode::FullRefill,
            threads: 3,
            reuse_engine: false,
        }
        .resolve();
        assert_eq!(e.mode, SimMode::Decomposed);
        assert_eq!(e.refill, RefillMode::FullRefill);
        assert_eq!(e.threads, 3);
        assert!(!e.reuse_engine);
    }
}
