//! Seeded fault injection — the "things break" half of the flow
//! simulator, mirroring [`super::flowgen`]'s determinism discipline.
//!
//! A production fabric loses links, browns out trunks, and grows
//! stragglers. [`draw`] turns a [`FaultSpec`] (severity knob, window,
//! fault budget, seed) into a concrete [`FaultScenario`]: link faults
//! (hard kill, bandwidth brownout, timed flap windows — always applied
//! to *both* directions of a link) and device stragglers (compute
//! slowdown factors, applied during lowering by
//! [`super::flows::lower_faulted`]). [`inject`] materializes the link
//! faults as timed [`CapEvent`]s on an already-lowered [`Workload`];
//! the [`super::fairshare::FairshareEngine`] honors them in both
//! [`super::SimMode::Monolithic`] and [`super::SimMode::Decomposed`]
//! bit-identically — a capacity change dirties only the link-sharing
//! component that owns the link (see `decompose`'s cap-event routing).
//!
//! Everything here is a pure single-threaded function of
//! `(topo, spec)` — same seed, same faults, bit for bit — which is
//! what lets `solver::refine` and `nest chaos` replay the *same*
//! scenario under every candidate plan and compare retention fairly.

use super::fairshare::{CapEvent, Workload};
use super::topo::LinkGraph;
use crate::obs;
use crate::util::rng::Rng;

/// Residual capacity fraction of a hard-killed link. A true zero would
/// strand in-flight bytes forever (the fair-share engine only finishes
/// flows that drain); a 1e-4 trickle keeps every simulation finite
/// while making the kill economically total — any plan still crossing
/// the link pays a ~10 000× slowdown on those bytes.
pub const KILL_FRACTION: f64 = 1e-4;

/// One fault on one directed link. Times are absolute seconds on the
/// simulation clock (the batch starts at 0).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LinkFault {
    /// Hard failure at `at`: capacity drops to
    /// `nominal · KILL_FRACTION` for the rest of the batch.
    Kill { at: f64 },
    /// Bandwidth brownout at `at`: capacity drops to
    /// `nominal · fraction` for the rest of the batch.
    Brownout { at: f64, fraction: f64 },
    /// Timed flap: capacity drops to `nominal · fraction` at `from`
    /// and is restored to nominal at `until`.
    Flap { from: f64, until: f64, fraction: f64 },
}

/// Full specification of one fault scenario. The scenario is a pure
/// function of `(topo, spec)`; `seed` alone distinguishes replicates.
#[derive(Debug, Clone)]
pub struct FaultSpec {
    /// Fault severity in `[0, 1]`: scales how many faults fire, how
    /// deep brownouts cut, and how slow stragglers run. 0 = nothing.
    pub severity: f64,
    /// Scenario window in seconds: faults strike within the first half
    /// of `[0, duration)` so they overlap the work under study. Callers
    /// typically pass the clean (fault-free) batch time.
    pub duration: f64,
    /// Link-fault budget: `ceil(links · severity)` distinct links are
    /// faulted (both directions each).
    pub links: usize,
    /// Straggler budget: `ceil(stragglers · severity)` distinct devices
    /// get a compute slowdown.
    pub stragglers: usize,
    pub seed: u64,
}

impl FaultSpec {
    /// A reasonable default scenario at `severity` over `duration`:
    /// up to 3 faulted links and 2 stragglers, scaled by severity. The
    /// chaos harness and `refine --fault-severity` build on this.
    pub fn at_severity(severity: f64, duration: f64, seed: u64) -> Self {
        FaultSpec {
            severity,
            duration,
            links: 3,
            stragglers: 2,
            seed,
        }
    }
}

/// A drawn fault scenario, ready for [`inject`] (link faults) and
/// [`super::flows::lower_faulted`] (stragglers).
#[derive(Debug, Clone, Default)]
pub struct FaultScenario {
    /// `(link id, fault)` in draw order. Both directions of a faulted
    /// link appear as separate entries carrying the same fault.
    pub link_faults: Vec<(usize, LinkFault)>,
    /// `(device id, slowdown ≥ 1)`: the device's compute stretches by
    /// this factor.
    pub stragglers: Vec<(usize, f64)>,
}

impl FaultScenario {
    pub fn is_empty(&self) -> bool {
        self.link_faults.is_empty() && self.stragglers.is_empty()
    }

    /// Compute slowdown of `device` (1.0 when healthy; the max factor
    /// when a device was drawn more than once across merged scenarios).
    pub fn slowdown_of(&self, device: usize) -> f64 {
        self.stragglers
            .iter()
            .filter(|&&(d, _)| d == device)
            .map(|&(_, s)| s)
            .fold(1.0, f64::max)
    }

    /// Materialize the link faults as timed capacity-change events
    /// against `topo`'s nominal capacities, in draw order (the engine's
    /// heap breaks same-time ties by event index, so this order is part
    /// of the bit-identity contract).
    pub fn cap_events(&self, topo: &LinkGraph) -> Vec<CapEvent> {
        let mut out = Vec::with_capacity(self.link_faults.len() * 2);
        for &(l, fault) in &self.link_faults {
            let nominal = topo.links[l].capacity;
            match fault {
                LinkFault::Kill { at } => out.push(CapEvent {
                    at,
                    link: l as u32,
                    capacity: nominal * KILL_FRACTION,
                }),
                LinkFault::Brownout { at, fraction } => out.push(CapEvent {
                    at,
                    link: l as u32,
                    capacity: nominal * fraction,
                }),
                LinkFault::Flap {
                    from,
                    until,
                    fraction,
                } => {
                    out.push(CapEvent {
                        at: from,
                        link: l as u32,
                        capacity: nominal * fraction,
                    });
                    out.push(CapEvent {
                        at: until,
                        link: l as u32,
                        capacity: nominal,
                    });
                }
            }
        }
        out
    }
}

/// Directed reverse of link `l` (the `(dst, src)` twin), if the
/// topology has one. Tier-stack expansions always do; hand-written
/// edge-lists may be asymmetric.
fn reverse_of(topo: &LinkGraph, l: usize) -> Option<usize> {
    let e = &topo.links[l];
    topo.links
        .iter()
        .position(|r| r.src == e.dst && r.dst == e.src)
}

/// Brownout depth at `severity`: a fraction in `[0.05, 1)` that cuts
/// deeper as severity rises.
fn draw_fraction(severity: f64, rng: &mut Rng) -> f64 {
    (1.0 - severity * (0.5 + 0.5 * rng.gen_f64())).max(0.05)
}

/// Draw the fault scenario for `topo` under `spec`. Pure and
/// single-threaded: the same `(topo, spec)` always yields bit-identical
/// faults, independent of simulator mode or thread count.
///
/// Severity scales three axes at once: the number of faults
/// (`ceil(budget · severity)`), the kind mix (kills become more likely
/// as severity rises), and the magnitudes (brownout depth, straggler
/// slowdown). Fault times land in the first half of the window so they
/// overlap the batch rather than striking after it drains.
pub fn draw(topo: &LinkGraph, spec: &FaultSpec) -> FaultScenario {
    let _span = obs::span_with("faults.draw", "netsim", || {
        vec![
            ("seed", spec.seed.to_string()),
            ("severity", format!("{:.3}", spec.severity)),
        ]
    });
    assert!(
        (0.0..=1.0).contains(&spec.severity) && spec.severity.is_finite(),
        "fault severity must be a fraction in [0, 1]"
    );
    assert!(
        spec.duration > 0.0 && spec.duration.is_finite(),
        "fault window duration must be positive"
    );
    let mut rng = Rng::new(spec.seed);
    let mut sc = FaultScenario::default();
    let n_link_faults = (spec.links as f64 * spec.severity).ceil() as usize;
    let n_stragglers = (spec.stragglers as f64 * spec.severity).ceil() as usize;

    if n_link_faults > 0 {
        assert!(
            !topo.links.is_empty(),
            "cannot fault links on a linkless topology"
        );
        let mut hit = vec![false; topo.links.len()];
        for _ in 0..n_link_faults {
            // Bounded retry keeps the draw deterministic while avoiding
            // double-faulting a link (a later Brownout would otherwise
            // resurrect an earlier Kill). On tiny topologies the budget
            // can exceed the distinct links; we then skip the leftovers.
            let mut l = rng.gen_range(topo.links.len());
            let mut tries = 0;
            while hit[l] && tries < 32 {
                l = rng.gen_range(topo.links.len());
                tries += 1;
            }
            if hit[l] {
                continue;
            }
            let rev = reverse_of(topo, l);
            hit[l] = true;
            if let Some(r) = rev {
                hit[r] = true;
            }
            let at = rng.gen_f64() * 0.5 * spec.duration;
            let u = rng.gen_f64();
            let fault = if u < 0.3 * spec.severity {
                LinkFault::Kill { at }
            } else if u < 0.3 * spec.severity + 0.35 {
                let until = at + (0.1 + 0.4 * rng.gen_f64()) * spec.duration;
                let fraction = draw_fraction(spec.severity, &mut rng);
                LinkFault::Flap {
                    from: at,
                    until,
                    fraction,
                }
            } else {
                let fraction = draw_fraction(spec.severity, &mut rng);
                LinkFault::Brownout { at, fraction }
            };
            sc.link_faults.push((l, fault));
            if let Some(r) = rev {
                sc.link_faults.push((r, fault));
            }
        }
    }

    if n_stragglers > 0 {
        let n = topo.n_devices();
        assert!(n > 0, "cannot straggle devices on a deviceless topology");
        let mut hit = vec![false; n];
        for _ in 0..n_stragglers {
            let mut d = rng.gen_range(n);
            let mut tries = 0;
            while hit[d] && tries < 32 {
                d = rng.gen_range(n);
                tries += 1;
            }
            if hit[d] {
                continue;
            }
            hit[d] = true;
            let slowdown = 1.0 + spec.severity * (0.5 + 1.5 * rng.gen_f64());
            sc.stragglers.push((d, slowdown));
        }
    }

    if obs::enabled() {
        obs::count("faults.link_faults", sc.link_faults.len() as u64);
        obs::count("faults.stragglers", sc.stragglers.len() as u64);
    }
    sc
}

/// Materialize `scenario`'s link faults onto an already-lowered
/// workload as timed capacity-change events. Callable once per
/// workload (faults are cluster state, not per-flow state — merging
/// two scenarios is the caller's job, before injection). Returns the
/// number of capacity events injected. Stragglers are *not* applied
/// here — they act during lowering ([`super::flows::lower_faulted`]).
pub fn inject(wl: &mut Workload, topo: &LinkGraph, scenario: &FaultScenario) -> usize {
    assert!(
        wl.cap_events.is_empty(),
        "a fault scenario was already injected into this workload"
    );
    wl.cap_events = scenario.cap_events(topo);
    if obs::enabled() {
        obs::count("faults.cap_events", wl.cap_events.len() as u64);
    }
    wl.cap_events.len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::fairshare::{FlowSpec, TaskKind};
    use crate::netsim::{topo, SimMode, Simulation};

    fn spec(severity: f64, seed: u64) -> FaultSpec {
        FaultSpec::at_severity(severity, 1e-2, seed)
    }

    fn assert_scenarios_identical(a: &FaultScenario, b: &FaultScenario) {
        assert_eq!(a.link_faults.len(), b.link_faults.len());
        for (x, y) in a.link_faults.iter().zip(&b.link_faults) {
            assert_eq!(x.0, y.0);
            assert_eq!(x.1, y.1);
        }
        assert_eq!(a.stragglers.len(), b.stragglers.len());
        for (x, y) in a.stragglers.iter().zip(&b.stragglers) {
            assert_eq!(x.0, y.0);
            assert_eq!(x.1.to_bits(), y.1.to_bits());
        }
    }

    #[test]
    fn same_seed_reproduces_the_scenario_bitwise() {
        let t = topo::spineleaf(4, 4, 4.0);
        let a = draw(&t, &spec(0.7, 7));
        let b = draw(&t, &spec(0.7, 7));
        assert_scenarios_identical(&a, &b);
        assert!(!a.link_faults.is_empty());
        assert!(!a.stragglers.is_empty());
    }

    #[test]
    fn different_seeds_differ() {
        let t = topo::fattree(4);
        let a = draw(&t, &spec(0.8, 1));
        let b = draw(&t, &spec(0.8, 2));
        let same = a.link_faults.len() == b.link_faults.len()
            && a.link_faults.iter().zip(&b.link_faults).all(|(x, y)| x == y);
        assert!(!same, "distinct seeds drew identical link faults");
    }

    #[test]
    fn zero_severity_is_an_empty_scenario() {
        let t = topo::spineleaf(2, 4, 2.0);
        let sc = draw(&t, &spec(0.0, 3));
        assert!(sc.is_empty());
        assert_eq!(sc.slowdown_of(0), 1.0);
        let mut wl = Workload::new();
        assert_eq!(inject(&mut wl, &t, &sc), 0);
    }

    #[test]
    fn both_directions_of_a_faulted_link_fault_together() {
        let t = topo::spineleaf(4, 4, 4.0);
        let sc = draw(&t, &spec(1.0, 11));
        assert!(!sc.link_faults.is_empty());
        // Tier expansions are symmetric: faults come in mirrored pairs
        // carrying the same fault value.
        assert_eq!(sc.link_faults.len() % 2, 0);
        for pair in sc.link_faults.chunks(2) {
            let (f, ff) = pair[0];
            let (r, rf) = pair[1];
            assert_eq!(ff, rf);
            assert_eq!(t.links[f].src, t.links[r].dst);
            assert_eq!(t.links[f].dst, t.links[r].src);
        }
    }

    #[test]
    fn kill_leaves_a_residual_trickle_and_flap_restores_nominal() {
        let t = topo::spineleaf(2, 4, 2.0);
        let sc = FaultScenario {
            link_faults: vec![
                (0, LinkFault::Kill { at: 1e-3 }),
                (
                    1,
                    LinkFault::Flap {
                        from: 2e-3,
                        until: 5e-3,
                        fraction: 0.25,
                    },
                ),
            ],
            stragglers: vec![],
        };
        let evs = sc.cap_events(&t);
        assert_eq!(evs.len(), 3);
        assert_eq!(evs[0].capacity, t.links[0].capacity * KILL_FRACTION);
        assert!(evs[0].capacity > 0.0, "a kill must leave the sim finite");
        assert_eq!(evs[1].capacity, t.links[1].capacity * 0.25);
        assert_eq!(evs[2].at, 5e-3);
        assert_eq!(evs[2].capacity, t.links[1].capacity);
    }

    #[test]
    fn stragglers_always_slow_down() {
        let t = topo::fattree(4);
        for seed in 0..8u64 {
            let sc = draw(&t, &spec(0.9, 100 + seed));
            for &(d, s) in &sc.stragglers {
                assert!(s >= 1.0, "straggler {d} sped up: {s}");
                assert_eq!(sc.slowdown_of(d), s);
            }
        }
    }

    #[test]
    #[should_panic(expected = "already injected")]
    fn double_injection_panics() {
        let t = topo::spineleaf(2, 4, 2.0);
        let sc = draw(&t, &spec(0.9, 5));
        assert!(!sc.link_faults.is_empty());
        let mut wl = Workload::new();
        inject(&mut wl, &t, &sc);
        inject(&mut wl, &t, &sc);
    }

    #[test]
    fn faulted_workload_rides_every_mode_bit_identically() {
        // The tentpole bar in miniature: a seeded scenario injected into
        // a multi-component workload produces the same bits monolithic
        // and decomposed at 1 and 4 threads.
        let t = topo::spineleaf(4, 8, 4.0);
        let mut wl = Workload::new();
        for r in 0..4 {
            // Independent rack-local chains (separate components) plus
            // one cross-rack flow so trunk faults matter.
            let base = r * 8;
            let c = wl.add(TaskKind::Compute { seconds: 1e-4 }, &[]);
            let x = wl.add(
                TaskKind::Transfer {
                    flows: vec![FlowSpec {
                        src: base,
                        dst: base + 3,
                        bytes: 2e8,
                    }],
                    extra_latency: 0.0,
                },
                &[c],
            );
            wl.add(
                TaskKind::Transfer {
                    flows: vec![FlowSpec {
                        src: base + 1,
                        dst: (base + 9) % 32,
                        bytes: 1e8,
                    }],
                    extra_latency: 0.0,
                },
                &[x],
            );
        }
        let sc = draw(&t, &FaultSpec::at_severity(0.8, 5e-2, 0xFA));
        assert!(!sc.link_faults.is_empty());
        assert!(inject(&mut wl, &t, &sc) > 0);
        let mono = Simulation::new()
            .mode(SimMode::Monolithic)
            .run_workload(&t, &wl);
        for threads in [1, 4] {
            let dec = Simulation::new()
                .mode(SimMode::Decomposed)
                .threads(threads)
                .run_workload(&t, &wl);
            mono.assert_bits_eq(&dec, &format!("faulted workload decomposed@{threads}"));
        }
        // Faults only ever slow the drain relative to a clean run.
        let mut clean = wl.clone();
        clean.cap_events.clear();
        let base = Simulation::new()
            .mode(SimMode::Monolithic)
            .run_workload(&t, &clean);
        assert!(
            mono.batch_time >= base.batch_time * (1.0 - 1e-12),
            "faults sped the batch up: {} vs {}",
            mono.batch_time,
            base.batch_time
        );
    }
}
