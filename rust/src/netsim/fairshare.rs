//! Max-min fair-share flow engine (progressive filling), in the style of
//! Parsimon/flowSim: instead of packet- or message-level simulation, the
//! engine tracks *flows* and recomputes every active flow's bottleneck
//! rate whenever a flow arrives or completes. Between events rates are
//! constant, so completions resolve in closed form — the whole batch
//! simulates in milliseconds while still exposing link contention the
//! level-wise analytic model cannot see.
//!
//! The input is a [`Workload`]: a DAG of [`TaskKind::Compute`] tasks
//! (fixed duration, one per pipeline op) and [`TaskKind::Transfer`] tasks
//! (a set of concurrent flows; the task completes when the last flow
//! drains, plus path latency and any modeled serialization extras).
//! Everything is single-threaded and iteration-order-stable, so reports
//! are bit-identical across runs and `--threads` settings.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::topo::LinkGraph;

/// One flow: `bytes` from device `src` to device `dst` along the
/// topology's deterministic route.
#[derive(Debug, Clone)]
pub struct FlowSpec {
    pub src: usize,
    pub dst: usize,
    pub bytes: f64,
}

/// A schedulable unit of the lowered workload.
#[derive(Debug, Clone)]
pub enum TaskKind {
    /// Occupies its stage for a fixed duration (compute, and cost terms
    /// the lowering keeps analytic).
    Compute { seconds: f64 },
    /// A set of flows launched together; completes when all have
    /// drained, plus the slowest flow's path latency, plus
    /// `extra_latency` (serialization of coalesced ring steps /
    /// per-message α terms the analytic model charges — see
    /// `netsim::flows`).
    Transfer {
        flows: Vec<FlowSpec>,
        extra_latency: f64,
    },
}

/// A DAG of tasks. Dependencies are by task id (the value returned by
/// [`Workload::add`]); a task starts the instant its last prerequisite
/// completes.
#[derive(Debug, Default)]
pub struct Workload {
    tasks: Vec<TaskKind>,
    /// Prerequisites per task.
    deps: Vec<Vec<u32>>,
}

impl Workload {
    pub fn new() -> Self {
        Workload::default()
    }

    /// Add a task depending on `deps`; returns its id.
    pub fn add(&mut self, kind: TaskKind, deps: &[u32]) -> u32 {
        let id = self.tasks.len() as u32;
        self.tasks.push(kind);
        self.deps.push(deps.to_vec());
        id
    }

    pub fn n_tasks(&self) -> usize {
        self.tasks.len()
    }
}

/// Per-link utilization over the simulated batch.
#[derive(Debug, Clone)]
pub struct LinkUtil {
    /// Link id into `LinkGraph::links`.
    pub link: usize,
    /// "src→dst" display name.
    pub name: String,
    /// Mean utilization: transferred bytes / (capacity · makespan).
    pub utilization: f64,
}

/// Flow-simulation outcome for one workload.
#[derive(Debug, Clone)]
pub struct NetsimReport {
    /// Makespan: completion time of the last task (seconds).
    pub batch_time: f64,
    /// Flows that actually crossed the network.
    pub n_flows: usize,
    /// Bytes injected across all flows.
    pub total_bytes: f64,
    /// Bytes actually drained through links (Σ rate·dt per flow). Equal
    /// to `total_bytes` up to the engine's half-byte completion
    /// tolerance — the conservation invariant the fuzz suite checks.
    pub delivered_bytes: f64,
    /// Engine events processed (rate recomputations).
    pub events: usize,
    /// Per-link mean utilization, hottest first (zero-traffic links
    /// omitted).
    pub link_util: Vec<LinkUtil>,
    /// Hottest link's mean utilization.
    pub max_link_util: f64,
}

/// Event-queue time key with a total order (times are finite).
#[derive(Debug, Clone, Copy)]
struct TimeKey(f64);
impl PartialEq for TimeKey {
    fn eq(&self, other: &Self) -> bool {
        self.0.total_cmp(&other.0).is_eq()
    }
}
impl Eq for TimeKey {}
impl PartialOrd for TimeKey {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for TimeKey {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

#[derive(Debug)]
struct ActiveFlow {
    task: u32,
    bytes: f64,
    remaining: f64,
    rate: f64,
    /// Per-flow ceiling (min flow_cap along the path).
    cap: f64,
    links: Vec<usize>,
    path_latency: f64,
}

#[derive(Debug, Clone, Default)]
struct TaskState {
    remaining_deps: u32,
    /// Network flows still draining (Transfer only).
    pending_flows: u32,
    /// Max over completed flows of (drain time + path latency).
    latency_end: f64,
    started: bool,
    done: bool,
}

/// Run `wl` on `topo` and return the contention-aware report.
///
/// Panics if the workload DAG is cyclic (a lowering bug, mirroring the
/// analytic simulator's deadlock assert).
pub fn run(topo: &LinkGraph, wl: &Workload) -> NetsimReport {
    let nt = wl.tasks.len();
    let mut st: Vec<TaskState> = vec![TaskState::default(); nt];
    let mut dependents: Vec<Vec<u32>> = vec![Vec::new(); nt];
    for (i, deps) in wl.deps.iter().enumerate() {
        st[i].remaining_deps = deps.len() as u32;
        for &d in deps {
            dependents[d as usize].push(i as u32);
        }
    }

    // Completion-event heap: (time, seq, task). `seq` keeps pops stable
    // under exact time ties.
    let mut heap: BinaryHeap<Reverse<(TimeKey, u64, u32)>> = BinaryHeap::new();
    let mut seq: u64 = 0;
    let mut active: Vec<ActiveFlow> = Vec::new();
    let mut busy_bytes: Vec<f64> = vec![0.0; topo.links.len()];
    let mut n_flows = 0usize;
    let mut total_bytes = 0.0f64;
    let mut delivered_bytes = 0.0f64;
    let mut events = 0usize;
    let mut done_count = 0usize;

    // Start a task at time `t`: schedule its completion (Compute) or
    // materialize its flows (Transfer).
    macro_rules! start_task {
        ($i:expr, $t:expr) => {{
            let i: u32 = $i;
            let t: f64 = $t;
            let s = &mut st[i as usize];
            debug_assert!(!s.started);
            s.started = true;
            s.latency_end = t;
            match &wl.tasks[i as usize] {
                TaskKind::Compute { seconds } => {
                    seq += 1;
                    heap.push(Reverse((TimeKey(t + seconds), seq, i)));
                }
                TaskKind::Transfer {
                    flows,
                    extra_latency,
                } => {
                    let mut pending = 0u32;
                    for f in flows {
                        if f.src == f.dst || f.bytes <= 0.5 {
                            continue; // no network crossing
                        }
                        let p = topo.path(f.src, f.dst);
                        n_flows += 1;
                        total_bytes += f.bytes;
                        active.push(ActiveFlow {
                            task: i,
                            bytes: f.bytes,
                            remaining: f.bytes,
                            rate: 0.0,
                            cap: p.flow_cap,
                            links: p.links,
                            path_latency: p.latency,
                        });
                        pending += 1;
                    }
                    st[i as usize].pending_flows = pending;
                    if pending == 0 {
                        seq += 1;
                        heap.push(Reverse((TimeKey(t + extra_latency), seq, i)));
                    }
                }
            }
        }};
    }

    let mut t = 0.0f64;
    let mut ready: Vec<u32> = Vec::new();
    for i in 0..nt as u32 {
        if st[i as usize].remaining_deps == 0 {
            ready.push(i);
        }
    }
    for i in ready {
        start_task!(i, t);
    }
    recompute_rates(topo, &mut active);

    loop {
        // Next flow drain under current (constant) rates.
        let mut t_drain = f64::INFINITY;
        for f in &active {
            if f.rate > 0.0 {
                t_drain = t_drain.min(t + f.remaining / f.rate);
            }
        }
        let t_event = heap
            .peek()
            .map(|Reverse((k, _, _))| k.0)
            .unwrap_or(f64::INFINITY);
        let t_next = t_drain.min(t_event);
        if t_next.is_infinite() {
            break;
        }
        events += 1;

        // Advance: drain bytes, accumulate per-link transferred volume.
        let dt = (t_next - t).max(0.0);
        if dt > 0.0 {
            for f in &mut active {
                let moved = f.rate * dt;
                f.remaining -= moved;
                for &l in &f.links {
                    busy_bytes[l] += moved;
                }
            }
        }
        t = t_next;

        let mut changed = false;
        // Flow completions (≤ half a byte left counts as drained).
        let mut i = 0;
        while i < active.len() {
            if active[i].remaining <= 0.5 {
                let f = active.swap_remove(i);
                delivered_bytes += f.bytes - f.remaining.max(0.0);
                let s = &mut st[f.task as usize];
                s.latency_end = s.latency_end.max(t + f.path_latency);
                s.pending_flows -= 1;
                if s.pending_flows == 0 {
                    let extra = match &wl.tasks[f.task as usize] {
                        TaskKind::Transfer { extra_latency, .. } => *extra_latency,
                        TaskKind::Compute { .. } => 0.0,
                    };
                    seq += 1;
                    heap.push(Reverse((TimeKey(s.latency_end + extra), seq, f.task)));
                }
                changed = true;
            } else {
                i += 1;
            }
        }
        // Task completions due now (and any cascade of 0-cost starts).
        while let Some(&Reverse((k, _, _))) = heap.peek() {
            if k.0 > t {
                break;
            }
            let Reverse((_, _, task)) = heap.pop().unwrap();
            let s = &mut st[task as usize];
            if s.done {
                continue;
            }
            s.done = true;
            done_count += 1;
            for &dep in &dependents[task as usize] {
                let ds = &mut st[dep as usize];
                ds.remaining_deps -= 1;
                if ds.remaining_deps == 0 {
                    start_task!(dep, t);
                }
            }
            changed = true;
        }
        if changed {
            recompute_rates(topo, &mut active);
        }
    }

    assert_eq!(
        done_count, nt,
        "flow workload deadlock: {done_count}/{nt} tasks completed (cyclic lowering?)"
    );

    // Utilization report, hottest first, ties by link id.
    let mut link_util: Vec<LinkUtil> = busy_bytes
        .iter()
        .enumerate()
        .filter(|(_, &b)| b > 0.0)
        .map(|(l, &b)| LinkUtil {
            link: l,
            name: topo.link_name(l),
            utilization: if t > 0.0 {
                b / (topo.links[l].capacity * t)
            } else {
                0.0
            },
        })
        .collect();
    link_util.sort_by(|a, b| {
        b.utilization
            .total_cmp(&a.utilization)
            .then(a.link.cmp(&b.link))
    });
    let max_link_util = link_util.first().map(|u| u.utilization).unwrap_or(0.0);

    NetsimReport {
        batch_time: t,
        n_flows,
        total_bytes,
        delivered_bytes,
        events,
        link_util,
        max_link_util,
    }
}

/// Progressive filling: raise every unfrozen flow's rate uniformly;
/// freeze a flow when it hits its per-flow ceiling or a link on its path
/// saturates. The result is the max-min fair allocation with rate caps.
/// Deterministic: pure arithmetic over the active set in index order.
fn recompute_rates(topo: &LinkGraph, active: &mut [ActiveFlow]) {
    if active.is_empty() {
        return;
    }
    let nl = topo.links.len();
    // Only links that carry at least one active flow participate.
    let mut n_unfrozen: Vec<u32> = vec![0; nl];
    let mut used: Vec<f64> = vec![0.0; nl];
    let mut touched: Vec<usize> = Vec::new();
    for f in active.iter() {
        for &l in &f.links {
            if n_unfrozen[l] == 0 {
                touched.push(l);
            }
            n_unfrozen[l] += 1;
        }
    }
    touched.sort_unstable();
    touched.dedup();

    let mut frozen: Vec<bool> = vec![false; active.len()];
    let mut left = active.len();
    let mut fill = 0.0f64;
    while left > 0 {
        // Largest uniform increment before a constraint binds. Track the
        // arg-min so progress is guaranteed even when epsilon tests miss.
        let mut delta = f64::INFINITY;
        let mut bind_link: Option<usize> = None;
        let mut bind_flow: Option<usize> = None;
        for &l in &touched {
            if n_unfrozen[l] > 0 {
                let slack = topo.links[l].capacity - used[l] - n_unfrozen[l] as f64 * fill;
                let d = slack / n_unfrozen[l] as f64;
                if d < delta {
                    delta = d;
                    bind_link = Some(l);
                    bind_flow = None;
                }
            }
        }
        for (i, f) in active.iter().enumerate() {
            if !frozen[i] {
                let d = f.cap - fill;
                if d < delta {
                    delta = d;
                    bind_flow = Some(i);
                    bind_link = None;
                }
            }
        }
        fill += delta.max(0.0);

        // Freeze everything the new fill level saturates.
        let mut froze_any = false;
        for (i, f) in active.iter_mut().enumerate() {
            if frozen[i] {
                continue;
            }
            let at_cap = fill >= f.cap * (1.0 - 1e-12);
            let on_saturated = f.links.iter().any(|&l| {
                let slack = topo.links[l].capacity - used[l] - n_unfrozen[l] as f64 * fill;
                slack <= topo.links[l].capacity * 1e-12
            });
            let forced = bind_flow == Some(i)
                || bind_link.is_some_and(|bl| f.links.contains(&bl));
            if at_cap || on_saturated || forced {
                frozen[i] = true;
                f.rate = fill;
                left -= 1;
                froze_any = true;
                for &l in &f.links {
                    n_unfrozen[l] -= 1;
                    used[l] += fill;
                }
            }
        }
        debug_assert!(froze_any, "progressive filling stalled");
        if !froze_any {
            // Defensive fallback: freeze everything at the current fill.
            for (i, f) in active.iter_mut().enumerate() {
                if !frozen[i] {
                    frozen[i] = true;
                    f.rate = fill;
                    left -= 1;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::GB;
    use crate::network::Cluster;
    use crate::util::prop;

    fn single_flow(topo: &LinkGraph, src: usize, dst: usize, bytes: f64) -> NetsimReport {
        let mut wl = Workload::new();
        wl.add(
            TaskKind::Transfer {
                flows: vec![FlowSpec { src, dst, bytes }],
                extra_latency: 0.0,
            },
            &[],
        );
        run(topo, &wl)
    }

    #[test]
    fn prop_single_flow_reproduces_p2p_time() {
        // Satellite requirement: on a contention-free workload the
        // fair-share engine reproduces Cluster::p2p_time within 1e-9.
        for c in [
            Cluster::fat_tree_tpuv4(64),
            Cluster::spine_leaf_h100(64, 2.0),
            Cluster::v100_cluster(16),
            Cluster::torus2d(8, 8, 50.0 * GB, 1e-6),
        ] {
            let topo = LinkGraph::from_cluster(&c);
            prop::forall(40, 0xF1075, |rng| {
                let a = rng.gen_range(c.n_devices());
                let mut b = rng.gen_range(c.n_devices());
                if a == b {
                    b = (b + 1) % c.n_devices();
                }
                let bytes = 1e6 * (1.0 + rng.gen_f64() * 1e3);
                let mut lca = c.n_levels() - 1;
                for l in 0..c.n_levels() {
                    if a / c.capacity(l) == b / c.capacity(l) {
                        lca = l;
                        break;
                    }
                }
                let expect = c.p2p_time(lca, bytes);
                let got = single_flow(&topo, a, b, bytes).batch_time;
                assert!(
                    (got - expect).abs() / expect < 1e-9,
                    "{}: {a}->{b} {bytes}B: flow-sim {got} vs p2p {expect}",
                    c.name
                );
            });
        }
    }

    #[test]
    fn two_flows_share_a_bottleneck_fairly() {
        // Two cross flows on a dumbbell share the 25 GB/s waist: each
        // gets 12.5 GB/s under max-min fairness.
        let src = r#"{"name": "mini-dumbbell",
            "nodes": ["a", "b", "c", "d",
                      {"id": "s0", "kind": "switch"}, {"id": "s1", "kind": "switch"}],
            "links": [
              {"src": "a", "dst": "s0", "bw_gbps": 100, "latency_us": 1},
              {"src": "b", "dst": "s0", "bw_gbps": 100, "latency_us": 1},
              {"src": "c", "dst": "s1", "bw_gbps": 100, "latency_us": 1},
              {"src": "d", "dst": "s1", "bw_gbps": 100, "latency_us": 1},
              {"src": "s0", "dst": "s1", "bw_gbps": 25, "latency_us": 5}
            ]}"#;
        let topo =
            LinkGraph::from_json(&crate::util::json::parse(src).unwrap()).unwrap();
        let bytes = 1e9;
        // Devices in listing order: a=0, b=1, c=2, d=3.
        let solo = single_flow(&topo, 0, 2, bytes).batch_time;
        let expect_solo = 7e-6 + bytes / (25.0 * GB);
        assert!((solo - expect_solo).abs() / expect_solo < 1e-9);
        let mut wl = Workload::new();
        wl.add(
            TaskKind::Transfer {
                flows: vec![
                    FlowSpec { src: 0, dst: 2, bytes },
                    FlowSpec { src: 1, dst: 3, bytes },
                ],
                extra_latency: 0.0,
            },
            &[],
        );
        let both = run(&topo, &wl).batch_time;
        let expect_both = 7e-6 + bytes / (12.5 * GB);
        assert!(
            (both - expect_both).abs() / expect_both < 1e-9,
            "shared waist: {both} vs {expect_both}"
        );
    }

    #[test]
    fn capped_flow_frees_bandwidth_for_others() {
        // On a spine-leaf, a cross-spine flow is capped at the spine
        // lane rate; an NVLink flow running concurrently (no shared
        // links) must still run at the full NVLink rate.
        let c = Cluster::spine_leaf_h100(64, 2.0);
        let topo = LinkGraph::from_cluster(&c);
        let mut wl = Workload::new();
        let nv = 1e9;
        wl.add(
            TaskKind::Transfer {
                flows: vec![
                    FlowSpec { src: 0, dst: 32, bytes: 1e6 }, // cross-spine, slow lane
                    FlowSpec { src: 1, dst: 2, bytes: nv },   // NVLink pair
                ],
                extra_latency: 0.0,
            },
            &[],
        );
        let rep = run(&topo, &wl);
        // The long NVLink flow sets the makespan, at its solo speed.
        let nv_solo = c.p2p_time(0, nv);
        assert!(
            (rep.batch_time - nv_solo).abs() / nv_solo < 1e-9,
            "NVLink flow throttled: {} vs solo {}",
            rep.batch_time,
            nv_solo
        );
    }

    #[test]
    fn dag_orders_compute_and_transfers() {
        let c = Cluster::fat_tree_tpuv4(64);
        let topo = LinkGraph::from_cluster(&c);
        let mut wl = Workload::new();
        let a = wl.add(TaskKind::Compute { seconds: 1.0 }, &[]);
        let x = wl.add(
            TaskKind::Transfer {
                flows: vec![FlowSpec { src: 0, dst: 8, bytes: 1e9 }],
                extra_latency: 0.0,
            },
            &[a],
        );
        let _b = wl.add(TaskKind::Compute { seconds: 0.5 }, &[x]);
        let rep = run(&topo, &wl);
        let expect = 1.0 + c.p2p_time(1, 1e9) + 0.5;
        assert!(
            (rep.batch_time - expect).abs() / expect < 1e-9,
            "{} vs {}",
            rep.batch_time,
            expect
        );
        assert_eq!(rep.n_flows, 1);
    }

    #[test]
    fn extra_latency_and_degenerate_flows() {
        let c = Cluster::fat_tree_tpuv4(64);
        let topo = LinkGraph::from_cluster(&c);
        let mut wl = Workload::new();
        // All flows degenerate (self-loop / zero bytes): pure latency.
        wl.add(
            TaskKind::Transfer {
                flows: vec![
                    FlowSpec { src: 3, dst: 3, bytes: 1e9 },
                    FlowSpec { src: 0, dst: 1, bytes: 0.0 },
                ],
                extra_latency: 2.5e-6,
            },
            &[],
        );
        let rep = run(&topo, &wl);
        assert!((rep.batch_time - 2.5e-6).abs() < 1e-15);
        assert_eq!(rep.n_flows, 0);
    }

    #[test]
    fn utilization_reported_on_contended_trunk() {
        // Overload the oversubscribed spine trunk: 64 concurrent cross
        // flows from 32 sources share a 32-lane (÷2 oversub) trunk, so
        // each runs below its lane rate and the trunk saturates.
        let c = Cluster::spine_leaf_h100(64, 2.0);
        let topo = LinkGraph::from_cluster(&c);
        let mut wl = Workload::new();
        let mut flows: Vec<FlowSpec> = Vec::new();
        for i in 0..32usize {
            flows.push(FlowSpec {
                src: i,
                dst: 32 + i,
                bytes: 1e9,
            });
            flows.push(FlowSpec {
                src: i,
                dst: 32 + (i + 1) % 32,
                bytes: 1e9,
            });
        }
        wl.add(
            TaskKind::Transfer {
                flows,
                extra_latency: 0.0,
            },
            &[],
        );
        let rep = run(&topo, &wl);
        assert_eq!(rep.n_flows, 64);
        // The leaf→spine trunk should be (near) fully utilized.
        assert!(
            rep.max_link_util > 0.9,
            "max util {}",
            rep.max_link_util
        );
        // And the run is strictly slower than a lone cross flow of the
        // same size (which moves at one uncontended lane's rate).
        let solo = single_flow(&topo, 0, 32, 1e9).batch_time;
        assert!(rep.batch_time > solo * 1.5, "{} vs {solo}", rep.batch_time);
    }

    #[test]
    fn reports_are_bit_identical() {
        let c = Cluster::spine_leaf_h100(64, 2.0);
        let topo = LinkGraph::from_cluster(&c);
        let build = || {
            let mut wl = Workload::new();
            let mut prev: Option<u32> = None;
            for i in 0..8u32 {
                let deps: Vec<u32> = match prev {
                    Some(p) => vec![p],
                    None => Vec::new(),
                };
                let cmp = wl.add(TaskKind::Compute { seconds: 1e-4 }, &deps);
                let xfer = wl.add(
                    TaskKind::Transfer {
                        flows: vec![
                            FlowSpec { src: i as usize, dst: 32 + i as usize, bytes: 1e8 },
                            FlowSpec { src: 32 + i as usize, dst: i as usize, bytes: 5e7 },
                        ],
                        extra_latency: 1e-6,
                    },
                    &[cmp],
                );
                prev = Some(xfer);
            }
            wl
        };
        let a = run(&topo, &build());
        let b = run(&topo, &build());
        assert_eq!(a.batch_time.to_bits(), b.batch_time.to_bits());
        assert_eq!(a.events, b.events);
        assert_eq!(a.link_util.len(), b.link_util.len());
        for (x, y) in a.link_util.iter().zip(&b.link_util) {
            assert_eq!(x.utilization.to_bits(), y.utilization.to_bits());
        }
    }

    #[test]
    #[should_panic(expected = "deadlock")]
    fn cyclic_workload_panics() {
        let c = Cluster::fat_tree_tpuv4(64);
        let topo = LinkGraph::from_cluster(&c);
        let mut wl = Workload::new();
        // 0 depends on 1, 1 depends on 0 (added via manual dep edit).
        let a = wl.add(TaskKind::Compute { seconds: 1.0 }, &[1]);
        let _b = wl.add(TaskKind::Compute { seconds: 1.0 }, &[a]);
        run(&topo, &wl);
    }
}
