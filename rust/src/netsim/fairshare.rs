//! Max-min fair-share flow engine (progressive filling), in the style of
//! Parsimon/flowSim: instead of packet- or message-level simulation, the
//! engine tracks *flows* and recomputes bottleneck rates whenever a flow
//! arrives or completes. Between events rates are constant, so
//! completions resolve in closed form — the whole batch simulates in
//! milliseconds while still exposing link contention the level-wise
//! analytic model cannot see.
//!
//! The input is a [`Workload`]: a DAG of [`TaskKind::Compute`] tasks
//! (fixed duration, one per pipeline op) and [`TaskKind::Transfer`] tasks
//! (a set of concurrent flows; the task completes when the last flow
//! drains, plus path latency and any modeled serialization extras).
//! Everything is single-threaded and iteration-order-stable, so reports
//! are bit-identical across runs and `--threads` settings.
//!
//! # Incremental rate maintenance
//!
//! Max-min fairness decomposes over the *connected components* of the
//! link-sharing graph (flows are adjacent when they share a link): a
//! component's rates are a pure function of its own flows and links.
//! The engine exploits this two ways:
//!
//! * [`FairshareEngine`] keeps per-link active-flow lists and a dirty
//!   set of links touched by arriving/completing flows; at each event
//!   only the affected components are re-solved by progressive filling
//!   ([`RefillMode::Incremental`]). Untouched components keep their
//!   rates — which is *exactly* what a full refill would assign them,
//!   because every component (in either mode) is filled by the same
//!   pure per-component routine over the same canonically-ordered flow
//!   list. [`RefillMode::FullRefill`] (the `NEST_REFERENCE=1` escape
//!   hatch) re-solves every component at every event; the property
//!   suite pins both modes to bit-identical reports.
//! * Flow completions live in the event heap as *predicted drain times*
//!   stamped with a per-flow generation counter; a rate change bumps the
//!   generation and pushes a fresh prediction, and stale entries are
//!   dropped lazily on pop — no per-event scan over the active set, no
//!   re-push/re-peek churn.
//!
//! All link-indexed scratch (`frozen`, `n_unfrozen`, `used`, the
//! component and DFS work lists, the flow slab) lives in the reusable
//! engine struct, so replaying many plans on one topology (the
//! refinement loop, the benches) keeps those buffers warm across runs;
//! only per-workload state (task table, dependency lists, the event
//! heap) is allocated per run.
//!
//! Note the engine's *semantics* changed with this design relative to
//! the eager pre-engine implementation: flows complete exactly at their
//! predicted drain times (the old half-byte early-completion shortcut
//! is gone) and progressive filling runs per component rather than as
//! one global fill, so reports can differ from the old engine's in the
//! last bits (all invariants and tolerance-based expectations are
//! unaffected). `NEST_REFERENCE=1` selects the full-refill scope within
//! *this* engine — the bit-identity proof is Incremental ≡ FullRefill,
//! not new ≡ pre-rewrite.
//!
//! # Decomposed execution
//!
//! [`super::decompose`] hoists the component argument one level further:
//! a *static* pre-simulation partition of the task DAG (dependency edges
//! ∪ link-sharing edges) lets each component run as an independent
//! sub-simulation, possibly on worker threads. To make the merged report
//! bit-identical to a monolithic run regardless of interleaving, the
//! engine separates simulation ([`FairshareEngine::sub_run`], returning
//! a raw [`SubRun`]) from report assembly ([`finalize`]): byte totals
//! are summed over per-flow [`FlowRecord`]s in canonical
//! `(task, flow-index)` order rather than event order, and event rounds
//! are counted from round timestamps. Monolithic runs go through the
//! identical finalize path, so the summation-order change is shared —
//! totals can differ from pre-decomposition builds in the last bits
//! (tolerance-based expectations are unaffected).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::topo::LinkGraph;
use crate::obs;

/// One flow: `bytes` from device `src` to device `dst` along the
/// topology's deterministic route.
#[derive(Debug, Clone)]
pub struct FlowSpec {
    pub src: usize,
    pub dst: usize,
    pub bytes: f64,
}

/// Flows that never cross the network (self-loops, sub-byte payloads)
/// are skipped by the engine. The decomposition partitioner must apply
/// the *same* predicate, so it lives in one place.
pub(super) fn flow_is_degenerate(f: &FlowSpec) -> bool {
    f.src == f.dst || f.bytes <= 0.5
}

/// A timed change of one link's effective capacity, in absolute
/// bytes/second from `at` onward. Materialized by `netsim::faults` from
/// a [`super::faults::FaultScenario`] (hard kills, brownouts, flap
/// windows); the engine honors them in every [`RefillMode`] and
/// execution mode identically. Capacity events apply at the *start* of
/// their scheduling round, before any drain or task completion at the
/// same timestamp.
#[derive(Debug, Clone, Copy)]
pub struct CapEvent {
    /// Simulation time the new capacity takes effect (seconds).
    pub at: f64,
    /// Link id into `LinkGraph::links`.
    pub link: u32,
    /// Effective capacity from `at` onward (bytes/second, > 0).
    pub capacity: f64,
}

/// A schedulable unit of the lowered workload.
#[derive(Debug, Clone)]
pub enum TaskKind {
    /// Occupies its stage for a fixed duration (compute, and cost terms
    /// the lowering keeps analytic).
    Compute { seconds: f64 },
    /// A set of flows launched together; completes when all have
    /// drained, plus the slowest flow's path latency, plus
    /// `extra_latency` (serialization of coalesced ring steps /
    /// per-message α terms the analytic model charges — see
    /// `netsim::flows`).
    Transfer {
        flows: Vec<FlowSpec>,
        extra_latency: f64,
    },
}

/// A DAG of tasks. Dependencies are by task id (the value returned by
/// [`Workload::add`]); a task starts the instant its last prerequisite
/// completes.
#[derive(Debug, Clone)]
pub struct Workload {
    /// Visible to the sibling decomposition pass (`netsim::decompose`),
    /// which partitions tasks without going through the engine.
    pub(super) tasks: Vec<TaskKind>,
    /// Prerequisites per task.
    pub(super) deps: Vec<Vec<u32>>,
    /// First background task id: tasks `>= bg_from` belong to an
    /// injected background mix (`netsim::flowgen::inject`) and are
    /// accounted separately in the report. `u32::MAX` (the default)
    /// means every task is the training job's own.
    pub(super) bg_from: u32,
    /// Timed link-capacity changes (`netsim::faults::inject`), applied
    /// by the engine in event order. Empty for a fault-free run.
    pub(super) cap_events: Vec<CapEvent>,
}

impl Default for Workload {
    fn default() -> Self {
        Workload {
            tasks: Vec::new(),
            deps: Vec::new(),
            bg_from: u32::MAX,
            cap_events: Vec::new(),
        }
    }
}

impl Workload {
    pub fn new() -> Self {
        Workload::default()
    }

    /// Add a task depending on `deps`; returns its id.
    pub fn add(&mut self, kind: TaskKind, deps: &[u32]) -> u32 {
        let id = self.tasks.len() as u32;
        self.tasks.push(kind);
        self.deps.push(deps.to_vec());
        id
    }

    pub fn n_tasks(&self) -> usize {
        self.tasks.len()
    }
}

/// Per-link utilization over the simulated batch.
#[derive(Debug, Clone)]
pub struct LinkUtil {
    /// Link id into `LinkGraph::links`.
    pub link: usize,
    /// "src→dst" display name.
    pub name: String,
    /// Mean utilization: transferred bytes / (capacity · makespan).
    pub utilization: f64,
}

/// Flow-simulation outcome for one workload.
#[derive(Debug, Clone)]
pub struct NetsimReport {
    /// Makespan: completion time of the last task (seconds), background
    /// tasks included.
    pub batch_time: f64,
    /// Completion time of the last *training* task — the number the
    /// refinement loop ranks by under background load. Equals
    /// `batch_time` when no background mix was injected.
    pub train_batch_time: f64,
    /// Flows that actually crossed the network (background included).
    pub n_flows: usize,
    /// Bytes injected across all flows.
    pub total_bytes: f64,
    /// Bytes actually drained through links (Σ rate·dt per flow). Equal
    /// to `total_bytes` up to the engine's completion tolerance — the
    /// conservation invariant the fuzz suite checks.
    pub delivered_bytes: f64,
    /// Background-mix slice of the flow accounting (all zero without an
    /// injected mix): flows, injected bytes, and drained bytes of tasks
    /// past the workload's training/background boundary. The training
    /// job's own totals are the differences from the overall fields.
    pub bg_flows: usize,
    pub bg_bytes: f64,
    pub bg_delivered_bytes: f64,
    /// Scheduling rounds processed (distinct event times at which state
    /// advanced). Identical across [`RefillMode`]s.
    pub events: usize,
    /// Per-link mean utilization, hottest first (zero-traffic links
    /// omitted).
    pub link_util: Vec<LinkUtil>,
    /// Hottest link's mean utilization.
    pub max_link_util: f64,
}

impl NetsimReport {
    /// Assert two reports are field-for-field identical at bit
    /// precision — the comparison every bit-identity suite (unit,
    /// property, cross-mode) must apply in full, kept in one place so a
    /// new report field cannot silently escape coverage.
    #[doc(hidden)]
    pub fn assert_bits_eq(&self, other: &NetsimReport, what: &str) {
        assert_eq!(
            self.batch_time.to_bits(),
            other.batch_time.to_bits(),
            "{what}: batch_time"
        );
        assert_eq!(
            self.train_batch_time.to_bits(),
            other.train_batch_time.to_bits(),
            "{what}: train_batch_time"
        );
        assert_eq!(self.n_flows, other.n_flows, "{what}: n_flows");
        assert_eq!(self.bg_flows, other.bg_flows, "{what}: bg_flows");
        assert_eq!(
            self.bg_bytes.to_bits(),
            other.bg_bytes.to_bits(),
            "{what}: bg_bytes"
        );
        assert_eq!(
            self.bg_delivered_bytes.to_bits(),
            other.bg_delivered_bytes.to_bits(),
            "{what}: bg_delivered_bytes"
        );
        assert_eq!(
            self.total_bytes.to_bits(),
            other.total_bytes.to_bits(),
            "{what}: total_bytes"
        );
        assert_eq!(
            self.delivered_bytes.to_bits(),
            other.delivered_bytes.to_bits(),
            "{what}: delivered_bytes"
        );
        assert_eq!(self.events, other.events, "{what}: events");
        assert_eq!(
            self.max_link_util.to_bits(),
            other.max_link_util.to_bits(),
            "{what}: max_link_util"
        );
        assert_eq!(
            self.link_util.len(),
            other.link_util.len(),
            "{what}: link_util rows"
        );
        for (x, y) in self.link_util.iter().zip(&other.link_util) {
            assert_eq!(x.link, y.link, "{what}: link_util order");
            assert_eq!(
                x.utilization.to_bits(),
                y.utilization.to_bits(),
                "{what}: link_util value"
            );
        }
    }
}

/// Which rate-maintenance strategy [`FairshareEngine`] uses.
///
/// Both produce bit-identical reports — `Incremental` re-solves only
/// the link-sharing components touched by the event, `FullRefill`
/// re-solves everything (the naive reference kept for the property
/// suite and the `NEST_REFERENCE=1` escape hatch).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RefillMode {
    /// Resolve from the environment once per process
    /// ([`crate::util::reference_mode`]).
    #[default]
    Auto,
    Incremental,
    FullRefill,
}

impl RefillMode {
    /// Collapse `Auto` to the environment's choice.
    pub fn resolve(self) -> RefillMode {
        match self {
            RefillMode::Auto => {
                if crate::util::reference_mode() {
                    RefillMode::FullRefill
                } else {
                    RefillMode::Incremental
                }
            }
            m => m,
        }
    }
}

/// Event-queue time key with a total order (times are finite).
#[derive(Debug, Clone, Copy)]
struct TimeKey(f64);
impl PartialEq for TimeKey {
    fn eq(&self, other: &Self) -> bool {
        self.0.total_cmp(&other.0).is_eq()
    }
}
impl Eq for TimeKey {}
impl PartialOrd for TimeKey {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for TimeKey {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// Heap payload: a link-capacity change, a predicted flow drain
/// (validated against the flow's current generation on pop), or a task
/// completion.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum EvPayload {
    /// Index into the workload's `cap_events`.
    Cap(u32),
    Drain { slot: u32, gen: u32 },
    Task(u32),
}

/// Heap entries order by `(time, kind, stable id)` — the stable id is
/// the cap-event index, the flow's arrival number, or the task id, *not*
/// a push counter, so exact-time ties resolve identically no matter
/// which [`RefillMode`] pushed them (push order differs between modes;
/// results must not). Capacity changes sort first within a round so a
/// fault takes effect before any same-instant drain settles.
type HeapEv = Reverse<(TimeKey, u8, u64, EvPayload)>;

const EV_CAP: u8 = 0;
const EV_DRAIN: u8 = 1;
const EV_TASK: u8 = 2;

/// One active flow in the engine's slab. `remaining` is the byte count
/// *as of* `last_t`; bytes are settled lazily whenever the rate changes
/// (and at completion), so unchanged flows cost nothing per event.
/// Per-flow accounting record — the canonical unit byte totals are
/// summed over. `(task, idx)` is globally unique (`idx` = position in
/// the task's flow list), so sorting records fixes one f64 addition
/// order shared by monolithic runs and decomposed merges.
#[derive(Debug, Clone, Copy)]
pub(super) struct FlowRecord {
    pub(super) task: u32,
    pub(super) idx: u32,
    pub(super) bytes: f64,
    pub(super) delivered: f64,
}

/// Per-link transferred-byte accumulator with a touched-link list, so a
/// sub-run's output and reset cost O(links actually used) rather than
/// O(all links) — decomposed mode runs thousands of tiny components on
/// one fabric-sized engine.
#[derive(Debug, Default)]
struct BusyLedger {
    bytes: Vec<f64>,
    touched: Vec<u32>,
}

impl BusyLedger {
    fn add(&mut self, l: usize, moved: f64) {
        if self.bytes[l] == 0.0 {
            self.touched.push(l as u32);
        }
        self.bytes[l] += moved;
    }

    /// Drain to link-sorted `(link, bytes)` pairs and restore the
    /// all-zero invariant. Zero-byte touches are dropped; duplicates in
    /// `touched` collapse because the first drain zeroes the entry.
    fn drain_sorted(&mut self) -> Vec<(u32, f64)> {
        let mut out: Vec<(u32, f64)> = Vec::with_capacity(self.touched.len());
        for &l in &self.touched {
            let b = self.bytes[l as usize];
            if b != 0.0 {
                out.push((l, b));
                self.bytes[l as usize] = 0.0;
            }
        }
        self.touched.clear();
        out.sort_unstable_by_key(|p| p.0);
        out
    }
}

/// Raw outcome of one engine pass — a monolithic run or one decomposed
/// component — before report assembly. Every field is
/// interleaving-independent, which is what lets [`finalize`] produce
/// identical bits from one monolithic pass or a merge of per-component
/// passes.
#[derive(Debug, Default)]
pub(super) struct SubRun {
    /// Completion time of the last task (0.0 for an empty workload).
    pub(super) end_t: f64,
    /// Completion time of the last *training* task (task id below the
    /// workload's `bg_from`); equals `end_t` without a background mix.
    pub(super) train_end_t: f64,
    /// Strictly increasing timestamps of the scheduling rounds.
    pub(super) event_times: Vec<f64>,
    /// Link-sorted `(link, transferred bytes)` pairs, nonzero only.
    pub(super) busy: Vec<(u32, f64)>,
    /// One record per materialized flow, in arrival order.
    pub(super) records: Vec<FlowRecord>,
}

#[derive(Debug, Clone)]
struct ActiveFlow {
    task: u32,
    /// Arrival number — the canonical ordering key for component fills.
    id: u64,
    /// Index of this flow's [`FlowRecord`] in the current sub-run.
    rec: u32,
    /// Bumped on every rate change and slot reuse; stale heap entries
    /// carry an older value and are dropped on pop.
    gen: u32,
    bytes: f64,
    remaining: f64,
    rate: f64,
    last_t: f64,
    /// Per-flow ceiling (min flow_cap along the path).
    cap: f64,
    links: Vec<usize>,
    path_latency: f64,
    alive: bool,
}

#[derive(Debug, Clone, Default)]
struct TaskState {
    remaining_deps: u32,
    /// Network flows still draining (Transfer only).
    pending_flows: u32,
    /// Max over completed flows of (drain time + path latency).
    latency_end: f64,
    started: bool,
    done: bool,
}

/// Reusable scratch for component discovery and progressive filling —
/// sized once per topology, cleared via epoch stamps and touched lists
/// instead of reallocation.
#[derive(Debug, Default)]
struct Scratch {
    /// Links touched by flows that arrived/completed since the last
    /// rate resolve (may contain duplicates; deduped via epoch stamps).
    dirty_links: Vec<usize>,
    link_seen: Vec<u64>,
    flow_seen: Vec<u64>,
    epoch: u64,
    /// Current component's flow slots / links / DFS work list.
    comp: Vec<u32>,
    comp_links: Vec<usize>,
    stack: Vec<usize>,
    /// Progressive-filling state (link-indexed arrays are zeroed
    /// invariantly between fills via `comp_links`).
    n_unfrozen: Vec<u32>,
    used: Vec<f64>,
    frozen: Vec<bool>,
    new_rates: Vec<f64>,
    /// Full-refill canonical iteration order.
    order: Vec<u32>,
}

/// Reusable fair-share engine for one topology (link count). Create
/// with [`FairshareEngine::new`] and call [`FairshareEngine::run`] per
/// workload; all per-link buffers are retained across runs.
#[derive(Debug)]
pub struct FairshareEngine {
    nl: usize,
    slots: Vec<ActiveFlow>,
    free: Vec<u32>,
    /// Per-link list of active flow slots — the structure that makes
    /// component discovery O(component) instead of O(flows × links).
    link_flows: Vec<Vec<u32>>,
    /// Effective per-link capacity: nominal at the start of every
    /// sub-run, updated by [`CapEvent`]s as the clock passes them.
    eff_cap: Vec<f64>,
    scratch: Scratch,
    busy: BusyLedger,
}

impl FairshareEngine {
    pub fn new(topo: &LinkGraph) -> Self {
        let nl = topo.links.len();
        FairshareEngine {
            nl,
            slots: Vec::new(),
            free: Vec::new(),
            link_flows: vec![Vec::new(); nl],
            eff_cap: topo.links.iter().map(|l| l.capacity).collect(),
            scratch: Scratch {
                link_seen: vec![0; nl],
                n_unfrozen: vec![0; nl],
                used: vec![0.0; nl],
                ..Scratch::default()
            },
            busy: BusyLedger {
                bytes: vec![0.0; nl],
                touched: Vec::new(),
            },
        }
    }

    /// Link count the engine was built for (how [`super::Simulation`]
    /// decides whether a retained engine can be reused).
    pub(super) fn n_links(&self) -> usize {
        self.nl
    }

    /// Run `wl` on `topo` with the environment-selected [`RefillMode`].
    pub fn run(&mut self, topo: &LinkGraph, wl: &Workload) -> NetsimReport {
        self.run_with_mode(topo, wl, RefillMode::Auto)
    }

    /// Run `wl` on `topo` under an explicit [`RefillMode`].
    ///
    /// Panics if the workload DAG is cyclic (a lowering bug, mirroring
    /// the analytic simulator's deadlock assert) or if `topo` has a
    /// different link count than the engine was built for.
    pub fn run_with_mode(
        &mut self,
        topo: &LinkGraph,
        wl: &Workload,
        mode: RefillMode,
    ) -> NetsimReport {
        let mode = mode.resolve();
        let _span = obs::span_with("netsim.run", "netsim", || {
            vec![
                ("mode", format!("{mode:?}")),
                ("tasks", wl.tasks.len().to_string()),
            ]
        });
        let sub = self.sub_run(topo, wl, mode);
        let events = sub.event_times.len();
        finalize(
            topo,
            sub.end_t,
            sub.train_end_t,
            events,
            sub.records,
            &sub.busy,
            wl.bg_from,
        )
    }

    /// One engine pass over `wl`, returning the raw [`SubRun`] outcome.
    /// Report assembly lives in [`finalize`] so that a monolithic run
    /// and a merge of decomposed component sub-runs share one code path
    /// (and therefore one set of bits). `mode` must already be resolved.
    pub(super) fn sub_run(&mut self, topo: &LinkGraph, wl: &Workload, mode: RefillMode) -> SubRun {
        assert_eq!(
            topo.links.len(),
            self.nl,
            "engine was built for a different topology"
        );
        let nt = wl.tasks.len();
        // Heap traffic accumulates in plain locals (flushed once after
        // the loop) so the event loop never pays a recorder call per pop.
        let mut heap_pops: u64 = 0;
        let mut stale_drops: u64 = 0;
        let mut st: Vec<TaskState> = vec![TaskState::default(); nt];
        let mut dependents: Vec<Vec<u32>> = vec![Vec::new(); nt];
        for (i, deps) in wl.deps.iter().enumerate() {
            st[i].remaining_deps = deps.len() as u32;
            for &d in deps {
                dependents[d as usize].push(i as u32);
            }
        }

        // Reset per-run state (scratch stamps survive via the epoch).
        self.slots.clear();
        self.free.clear();
        for v in &mut self.link_flows {
            v.clear();
        }
        for (e, l) in self.eff_cap.iter_mut().zip(&topo.links) {
            *e = l.capacity;
        }
        self.scratch.dirty_links.clear();
        self.scratch.flow_seen.clear();

        let mut heap: BinaryHeap<HeapEv> = BinaryHeap::new();
        for (ci, ev) in wl.cap_events.iter().enumerate() {
            heap.push(Reverse((
                TimeKey(ev.at),
                EV_CAP,
                ci as u64,
                EvPayload::Cap(ci as u32),
            )));
        }
        let mut records: Vec<FlowRecord> = Vec::new();
        let mut event_times: Vec<f64> = Vec::new();
        let mut done_count = 0usize;
        let mut task_end = 0.0f64;
        let mut train_end = 0.0f64;
        let mut next_flow_id: u64 = 0;
        let mut flows_changed = false;

        // Start a task at time `t`: schedule its completion (Compute) or
        // materialize its flows (Transfer) into the slab + link lists.
        macro_rules! start_task {
            ($i:expr, $t:expr) => {{
                let i: u32 = $i;
                let t: f64 = $t;
                let s = &mut st[i as usize];
                debug_assert!(!s.started);
                s.started = true;
                s.latency_end = t;
                match &wl.tasks[i as usize] {
                    TaskKind::Compute { seconds } => {
                        heap.push(Reverse((
                            TimeKey(t + seconds),
                            EV_TASK,
                            i as u64,
                            EvPayload::Task(i),
                        )));
                    }
                    TaskKind::Transfer {
                        flows,
                        extra_latency,
                    } => {
                        let mut pending = 0u32;
                        for (fi, f) in flows.iter().enumerate() {
                            if flow_is_degenerate(f) {
                                continue; // no network crossing
                            }
                            let p = topo.path(f.src, f.dst);
                            let rec = records.len() as u32;
                            records.push(FlowRecord {
                                task: i,
                                idx: fi as u32,
                                bytes: f.bytes,
                                delivered: 0.0,
                            });
                            let id = next_flow_id;
                            next_flow_id += 1;
                            let slot = match self.free.pop() {
                                Some(sl) => {
                                    let fl = &mut self.slots[sl as usize];
                                    fl.task = i;
                                    fl.id = id;
                                    fl.rec = rec;
                                    fl.gen = fl.gen.wrapping_add(1);
                                    fl.bytes = f.bytes;
                                    fl.remaining = f.bytes;
                                    fl.rate = 0.0;
                                    fl.last_t = t;
                                    fl.cap = p.flow_cap;
                                    fl.links = p.links;
                                    fl.path_latency = p.latency;
                                    fl.alive = true;
                                    sl
                                }
                                None => {
                                    self.slots.push(ActiveFlow {
                                        task: i,
                                        id,
                                        rec,
                                        gen: 0,
                                        bytes: f.bytes,
                                        remaining: f.bytes,
                                        rate: 0.0,
                                        last_t: t,
                                        cap: p.flow_cap,
                                        links: p.links,
                                        path_latency: p.latency,
                                        alive: true,
                                    });
                                    (self.slots.len() - 1) as u32
                                }
                            };
                            while self.scratch.flow_seen.len() < self.slots.len() {
                                self.scratch.flow_seen.push(0);
                            }
                            for &l in &self.slots[slot as usize].links {
                                self.link_flows[l].push(slot);
                                self.scratch.dirty_links.push(l);
                            }
                            pending += 1;
                            flows_changed = true;
                        }
                        st[i as usize].pending_flows = pending;
                        if pending == 0 {
                            heap.push(Reverse((
                                TimeKey(t + extra_latency),
                                EV_TASK,
                                i as u64,
                                EvPayload::Task(i),
                            )));
                        }
                    }
                }
            }};
        }

        let mut t = 0.0f64;
        for i in 0..nt as u32 {
            if st[i as usize].remaining_deps == 0 {
                start_task!(i, 0.0);
            }
        }
        if flows_changed {
            resolve_rates(
                &self.eff_cap,
                mode,
                &mut self.slots,
                &self.link_flows,
                &mut self.scratch,
                t,
                &mut self.busy,
                &mut heap,
            );
            flows_changed = false;
        }

        loop {
            // Next valid event: drop stale drain predictions lazily.
            let mut t_next: Option<f64> = None;
            while let Some(&Reverse((tk, _, _, ev))) = heap.peek() {
                let stale = match ev {
                    EvPayload::Cap(_) => false,
                    EvPayload::Drain { slot, gen } => {
                        let f = &self.slots[slot as usize];
                        !f.alive || f.gen != gen
                    }
                    EvPayload::Task(task) => st[task as usize].done,
                };
                if stale {
                    heap.pop();
                    stale_drops += 1;
                    continue;
                }
                t_next = Some(tk.0);
                break;
            }
            let Some(t_now) = t_next else { break };
            t = t_now;
            event_times.push(t_now);

            // Process every event due at `t` (ties included; cascades of
            // zero-cost starts land in the same round, like the eager
            // engine this replaced).
            while let Some(&Reverse((tk, _, _, _))) = heap.peek() {
                if tk.0 > t {
                    break;
                }
                let Reverse((_, _, _, ev)) = heap.pop().unwrap();
                heap_pops += 1;
                match ev {
                    EvPayload::Cap(ci) => {
                        // EV_CAP sorts first, so the new capacity is in
                        // place before any same-instant drain settles;
                        // rates re-resolve once at the end of the round.
                        let ev = &wl.cap_events[ci as usize];
                        self.eff_cap[ev.link as usize] = ev.capacity;
                        self.scratch.dirty_links.push(ev.link as usize);
                        flows_changed = true;
                    }
                    EvPayload::Drain { slot, gen } => {
                        let sl = slot as usize;
                        {
                            let f = &self.slots[sl];
                            if !f.alive || f.gen != gen {
                                stale_drops += 1;
                                continue;
                            }
                        }
                        // Settle the final rate epoch and complete.
                        let f = &mut self.slots[sl];
                        let dt = t - f.last_t;
                        if f.rate > 0.0 && dt > 0.0 {
                            let moved = f.rate * dt;
                            f.remaining -= moved;
                            for &l in &f.links {
                                self.busy.add(l, moved);
                            }
                        }
                        f.last_t = t;
                        records[f.rec as usize].delivered = f.bytes - f.remaining.max(0.0);
                        f.alive = false;
                        f.gen = f.gen.wrapping_add(1);
                        let task = f.task as usize;
                        let path_latency = f.path_latency;
                        // The dead slot's route is never read again (slot
                        // reuse overwrites it), so take it to unlink.
                        let links = std::mem::take(&mut self.slots[sl].links);
                        for &l in &links {
                            let v = &mut self.link_flows[l];
                            let pos = v
                                .iter()
                                .position(|&x| x == slot)
                                .expect("completing flow indexed on its links");
                            v.swap_remove(pos);
                            self.scratch.dirty_links.push(l);
                        }
                        self.free.push(slot);
                        let s = &mut st[task];
                        s.latency_end = s.latency_end.max(t + path_latency);
                        s.pending_flows -= 1;
                        if s.pending_flows == 0 {
                            let extra = match &wl.tasks[task] {
                                TaskKind::Transfer { extra_latency, .. } => *extra_latency,
                                TaskKind::Compute { .. } => 0.0,
                            };
                            heap.push(Reverse((
                                TimeKey(s.latency_end + extra),
                                EV_TASK,
                                task as u64,
                                EvPayload::Task(task as u32),
                            )));
                        }
                        flows_changed = true;
                    }
                    EvPayload::Task(task) => {
                        let ti = task as usize;
                        if st[ti].done {
                            continue;
                        }
                        st[ti].done = true;
                        done_count += 1;
                        task_end = task_end.max(t);
                        if task < wl.bg_from {
                            train_end = train_end.max(t);
                        }
                        for &dep in &dependents[ti] {
                            let ds = &mut st[dep as usize];
                            ds.remaining_deps -= 1;
                            if ds.remaining_deps == 0 {
                                start_task!(dep, t);
                            }
                        }
                    }
                }
            }

            if flows_changed {
                resolve_rates(
                    &self.eff_cap,
                    mode,
                    &mut self.slots,
                    &self.link_flows,
                    &mut self.scratch,
                    t,
                    &mut self.busy,
                    &mut heap,
                );
                flows_changed = false;
            }
        }

        assert_eq!(
            done_count, nt,
            "flow workload deadlock: {done_count}/{nt} tasks completed (cyclic lowering?)"
        );

        if obs::enabled() {
            obs::count("netsim.heap.pop", heap_pops);
            obs::count("netsim.heap.stale_drop", stale_drops);
            obs::count("netsim.events", event_times.len() as u64);
        }

        // The makespan is the last *task* completion, not the last event
        // time: capacity events scheduled past the end of the workload
        // (a flap restore after the batch drained) must not stretch the
        // batch clock. Fault-free runs are unchanged — their final event
        // is always a task completion.
        SubRun {
            end_t: task_end,
            train_end_t: train_end,
            event_times,
            busy: self.busy.drain_sorted(),
            records,
        }
    }
}

/// Assemble the user-facing [`NetsimReport`] from sub-run outcomes.
/// `busy` must hold each link at most once — guaranteed for a single
/// sub-run by the engine's ledger, and for decomposed merges because
/// components are link-disjoint. Record order does not matter: totals
/// are summed in canonical `(task, idx)` order, so one monolithic pass
/// and a merge of component passes produce the same bits. Records carry
/// *original* task ids (decomposed merges remap before calling in), so
/// `bg_from` — the caller's original-id training/background boundary —
/// classifies identically in both modes.
pub(super) fn finalize(
    topo: &LinkGraph,
    end_t: f64,
    train_end_t: f64,
    events: usize,
    mut records: Vec<FlowRecord>,
    busy: &[(u32, f64)],
    bg_from: u32,
) -> NetsimReport {
    records.sort_unstable_by_key(|r| (r.task, r.idx));
    let n_flows = records.len();
    let mut total_bytes = 0.0f64;
    let mut delivered_bytes = 0.0f64;
    let mut bg_flows = 0usize;
    let mut bg_bytes = 0.0f64;
    let mut bg_delivered_bytes = 0.0f64;
    for r in &records {
        total_bytes += r.bytes;
        delivered_bytes += r.delivered;
        if r.task >= bg_from {
            bg_flows += 1;
            bg_bytes += r.bytes;
            bg_delivered_bytes += r.delivered;
        }
    }

    // Utilization report, hottest first, ties by link id. Deliberately
    // against *nominal* capacity even under injected faults: a browned
    // out trunk showing low absolute utilization is the signal the
    // chaos harness reads.
    let mut link_util: Vec<LinkUtil> = busy
        .iter()
        .filter(|&&(_, b)| b > 0.0)
        .map(|&(l, b)| {
            let l = l as usize;
            LinkUtil {
                link: l,
                name: topo.link_name(l),
                utilization: if end_t > 0.0 {
                    b / (topo.links[l].capacity * end_t)
                } else {
                    0.0
                },
            }
        })
        .collect();
    link_util.sort_by(|a, b| {
        b.utilization
            .total_cmp(&a.utilization)
            .then(a.link.cmp(&b.link))
    });
    let max_link_util = link_util.first().map(|u| u.utilization).unwrap_or(0.0);

    if obs::enabled() {
        // Per-link utilization snapshot: one histogram sample per
        // active link (integer percent), plus an instant carrying
        // the hottest link for the timeline view.
        for u in &link_util {
            obs::record("netsim.link_util_pct", (u.utilization * 100.0).round() as u64);
        }
        obs::instant("netsim.link_util", "netsim", || {
            vec![
                ("links_active", link_util.len().to_string()),
                (
                    "max_link",
                    link_util.first().map(|u| u.name.clone()).unwrap_or_default(),
                ),
                ("max_util_pct", format!("{:.1}", max_link_util * 100.0)),
            ]
        });
    }

    NetsimReport {
        batch_time: end_t,
        train_batch_time: train_end_t,
        n_flows,
        total_bytes,
        delivered_bytes,
        bg_flows,
        bg_bytes,
        bg_delivered_bytes,
        events,
        link_util,
        max_link_util,
    }
}

/// Run `wl` on `topo` and return the contention-aware report
/// (convenience wrapper constructing a fresh [`FairshareEngine`]).
///
/// Panics if the workload DAG is cyclic (a lowering bug, mirroring the
/// analytic simulator's deadlock assert).
pub fn run(topo: &LinkGraph, wl: &Workload) -> NetsimReport {
    FairshareEngine::new(topo).run(topo, wl)
}

/// [`run`] under an explicit [`RefillMode`] (the property suite compares
/// `Incremental` against `FullRefill` field-for-field).
pub fn run_with_mode(topo: &LinkGraph, wl: &Workload, mode: RefillMode) -> NetsimReport {
    FairshareEngine::new(topo).run_with_mode(topo, wl, mode)
}

/// Re-solve rates after flows arrived/completed or a link's effective
/// capacity changed. `Incremental` walks only the components reachable
/// from the dirty links; `FullRefill` walks every alive flow. Both hand
/// each component — flows in canonical (arrival-id) order — to
/// [`fill_component`], so a flow's rate is the same bits no matter
/// which mode computed it; flows whose rate is unchanged are left
/// untouched (no byte settlement, no heap push), which is what keeps
/// the two modes' event streams identical. `eff_cap` is the engine's
/// current per-link effective capacity (nominal minus any active
/// faults).
#[allow(clippy::too_many_arguments)]
fn resolve_rates(
    eff_cap: &[f64],
    mode: RefillMode,
    slots: &mut [ActiveFlow],
    link_flows: &[Vec<u32>],
    scratch: &mut Scratch,
    t: f64,
    busy: &mut BusyLedger,
    heap: &mut BinaryHeap<HeapEv>,
) {
    let Scratch {
        dirty_links,
        link_seen,
        flow_seen,
        epoch,
        comp,
        comp_links,
        stack,
        n_unfrozen,
        used,
        frozen,
        new_rates,
        order,
    } = scratch;
    *epoch += 1;
    let ep = *epoch;

    // Grow a component from DFS-discovered links (flows adjacent via
    // shared links).
    macro_rules! grow_component {
        () => {
            while let Some(l) = stack.pop() {
                for &slot in &link_flows[l] {
                    if flow_seen[slot as usize] != ep {
                        flow_seen[slot as usize] = ep;
                        comp.push(slot);
                        for &l2 in &slots[slot as usize].links {
                            if link_seen[l2] != ep {
                                link_seen[l2] = ep;
                                stack.push(l2);
                            }
                        }
                    }
                }
            }
        };
    }

    match mode {
        RefillMode::Incremental => {
            for &seed in dirty_links.iter() {
                if link_seen[seed] == ep {
                    continue;
                }
                comp.clear();
                stack.clear();
                link_seen[seed] = ep;
                stack.push(seed);
                grow_component!();
                if comp.is_empty() {
                    continue; // completing flow left the link idle
                }
                comp.sort_unstable_by_key(|&s| slots[s as usize].id);
                if obs::enabled() {
                    obs::record("netsim.dirty_component", comp.len() as u64);
                }
                fill_component(
                    eff_cap, slots, comp, comp_links, n_unfrozen, used, frozen, new_rates, t,
                    busy, heap,
                );
            }
        }
        RefillMode::FullRefill => {
            order.clear();
            for (si, f) in slots.iter().enumerate() {
                if f.alive {
                    order.push(si as u32);
                }
            }
            order.sort_unstable_by_key(|&s| slots[s as usize].id);
            for &slot in order.iter() {
                if flow_seen[slot as usize] == ep {
                    continue;
                }
                comp.clear();
                stack.clear();
                flow_seen[slot as usize] = ep;
                comp.push(slot);
                for &l in &slots[slot as usize].links {
                    if link_seen[l] != ep {
                        link_seen[l] = ep;
                        stack.push(l);
                    }
                }
                grow_component!();
                comp.sort_unstable_by_key(|&s| slots[s as usize].id);
                if obs::enabled() {
                    obs::record("netsim.dirty_component", comp.len() as u64);
                }
                fill_component(
                    eff_cap, slots, comp, comp_links, n_unfrozen, used, frozen, new_rates, t,
                    busy, heap,
                );
            }
        }
        RefillMode::Auto => unreachable!("mode resolved before the run loop"),
    }
    dirty_links.clear();
}

/// Progressive filling over one link-sharing component: raise every
/// unfrozen flow's rate uniformly; freeze a flow when it hits its
/// per-flow ceiling or a link on its path saturates. The result is the
/// max-min fair allocation with rate caps — a pure function of the
/// component's (canonically ordered) flows and links, which is what
/// makes incremental and full refills bit-identical. Flows whose rate
/// is unchanged are not touched; changed flows settle their drained
/// bytes at `t`, bump their generation, and push a fresh predicted
/// drain event. Link constraints come from `eff_cap` — the *effective*
/// capacities, so injected faults reshape the allocation; per-flow
/// ceilings (`ActiveFlow::cap`) stay nominal, which is harmless because
/// a degraded link always binds first through its slack.
#[allow(clippy::too_many_arguments)]
fn fill_component(
    eff_cap: &[f64],
    slots: &mut [ActiveFlow],
    comp: &[u32],
    comp_links: &mut Vec<usize>,
    n_unfrozen: &mut [u32],
    used: &mut [f64],
    frozen: &mut Vec<bool>,
    new_rates: &mut Vec<f64>,
    t: f64,
    busy: &mut BusyLedger,
    heap: &mut BinaryHeap<HeapEv>,
) {
    comp_links.clear();
    for &s in comp {
        for &l in &slots[s as usize].links {
            comp_links.push(l);
            n_unfrozen[l] += 1;
        }
    }
    comp_links.sort_unstable();
    comp_links.dedup();

    frozen.clear();
    frozen.resize(comp.len(), false);
    new_rates.clear();
    new_rates.resize(comp.len(), 0.0);
    let mut left = comp.len();
    let mut fill = 0.0f64;
    while left > 0 {
        // Largest uniform increment before a constraint binds. Track the
        // arg-min so progress is guaranteed even when epsilon tests miss.
        let mut delta = f64::INFINITY;
        let mut bind_link: Option<usize> = None;
        let mut bind_flow: Option<usize> = None;
        for &l in comp_links.iter() {
            if n_unfrozen[l] > 0 {
                let slack = eff_cap[l] - used[l] - n_unfrozen[l] as f64 * fill;
                let d = slack / n_unfrozen[l] as f64;
                if d < delta {
                    delta = d;
                    bind_link = Some(l);
                    bind_flow = None;
                }
            }
        }
        for (ci, &s) in comp.iter().enumerate() {
            if !frozen[ci] {
                let d = slots[s as usize].cap - fill;
                if d < delta {
                    delta = d;
                    bind_flow = Some(ci);
                    bind_link = None;
                }
            }
        }
        fill += delta.max(0.0);

        // Freeze everything the new fill level saturates.
        let mut froze_any = false;
        for (ci, &s) in comp.iter().enumerate() {
            if frozen[ci] {
                continue;
            }
            let f = &slots[s as usize];
            let at_cap = fill >= f.cap * (1.0 - 1e-12);
            let on_saturated = f.links.iter().any(|&l| {
                let slack = eff_cap[l] - used[l] - n_unfrozen[l] as f64 * fill;
                slack <= eff_cap[l] * 1e-12
            });
            let forced =
                bind_flow == Some(ci) || bind_link.is_some_and(|bl| f.links.contains(&bl));
            if at_cap || on_saturated || forced {
                frozen[ci] = true;
                new_rates[ci] = fill;
                left -= 1;
                froze_any = true;
                for &l in &f.links {
                    n_unfrozen[l] -= 1;
                    used[l] += fill;
                }
            }
        }
        debug_assert!(froze_any, "progressive filling stalled");
        if !froze_any {
            // Defensive fallback: freeze everything at the current fill.
            for (fz, r) in frozen.iter_mut().zip(new_rates.iter_mut()) {
                if !*fz {
                    *fz = true;
                    *r = fill;
                    left -= 1;
                }
            }
        }
    }

    // Restore the link-indexed scratch invariant (all zeros).
    for &l in comp_links.iter() {
        n_unfrozen[l] = 0;
        used[l] = 0.0;
    }

    // Apply: settle + re-stamp only flows whose rate actually changed.
    for (ci, &s) in comp.iter().enumerate() {
        let f = &mut slots[s as usize];
        let r = new_rates[ci];
        if r.to_bits() == f.rate.to_bits() {
            continue;
        }
        let dt = t - f.last_t;
        if f.rate > 0.0 && dt > 0.0 {
            let moved = f.rate * dt;
            f.remaining -= moved;
            for &l in &f.links {
                busy.add(l, moved);
            }
        }
        f.last_t = t;
        f.rate = r;
        f.gen = f.gen.wrapping_add(1);
        if r > 0.0 {
            heap.push(Reverse((
                TimeKey(t + f.remaining / r),
                EV_DRAIN,
                f.id,
                EvPayload::Drain {
                    slot: s,
                    gen: f.gen,
                },
            )));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::GB;
    use crate::network::Cluster;
    use crate::util::prop;

    fn single_flow(topo: &LinkGraph, src: usize, dst: usize, bytes: f64) -> NetsimReport {
        let mut wl = Workload::new();
        wl.add(
            TaskKind::Transfer {
                flows: vec![FlowSpec { src, dst, bytes }],
                extra_latency: 0.0,
            },
            &[],
        );
        run(topo, &wl)
    }

    #[test]
    fn prop_single_flow_reproduces_p2p_time() {
        // Satellite requirement: on a contention-free workload the
        // fair-share engine reproduces Cluster::p2p_time within 1e-9.
        for c in [
            Cluster::fat_tree_tpuv4(64),
            Cluster::spine_leaf_h100(64, 2.0),
            Cluster::v100_cluster(16),
            Cluster::torus2d(8, 8, 50.0 * GB, 1e-6),
        ] {
            let topo = LinkGraph::from_cluster(&c);
            prop::forall(40, 0xF1075, |rng| {
                let a = rng.gen_range(c.n_devices());
                let mut b = rng.gen_range(c.n_devices());
                if a == b {
                    b = (b + 1) % c.n_devices();
                }
                let bytes = 1e6 * (1.0 + rng.gen_f64() * 1e3);
                let mut lca = c.n_levels() - 1;
                for l in 0..c.n_levels() {
                    if a / c.capacity(l) == b / c.capacity(l) {
                        lca = l;
                        break;
                    }
                }
                let expect = c.p2p_time(lca, bytes);
                let got = single_flow(&topo, a, b, bytes).batch_time;
                assert!(
                    (got - expect).abs() / expect < 1e-9,
                    "{}: {a}->{b} {bytes}B: flow-sim {got} vs p2p {expect}",
                    c.name
                );
            });
        }
    }

    #[test]
    fn two_flows_share_a_bottleneck_fairly() {
        // Two cross flows on a dumbbell share the 25 GB/s waist: each
        // gets 12.5 GB/s under max-min fairness.
        let src = r#"{"name": "mini-dumbbell",
            "nodes": ["a", "b", "c", "d",
                      {"id": "s0", "kind": "switch"}, {"id": "s1", "kind": "switch"}],
            "links": [
              {"src": "a", "dst": "s0", "bw_gbps": 100, "latency_us": 1},
              {"src": "b", "dst": "s0", "bw_gbps": 100, "latency_us": 1},
              {"src": "c", "dst": "s1", "bw_gbps": 100, "latency_us": 1},
              {"src": "d", "dst": "s1", "bw_gbps": 100, "latency_us": 1},
              {"src": "s0", "dst": "s1", "bw_gbps": 25, "latency_us": 5}
            ]}"#;
        let topo =
            LinkGraph::from_json(&crate::util::json::parse(src).unwrap()).unwrap();
        let bytes = 1e9;
        // Devices in listing order: a=0, b=1, c=2, d=3.
        let solo = single_flow(&topo, 0, 2, bytes).batch_time;
        let expect_solo = 7e-6 + bytes / (25.0 * GB);
        assert!((solo - expect_solo).abs() / expect_solo < 1e-9);
        let mut wl = Workload::new();
        wl.add(
            TaskKind::Transfer {
                flows: vec![
                    FlowSpec { src: 0, dst: 2, bytes },
                    FlowSpec { src: 1, dst: 3, bytes },
                ],
                extra_latency: 0.0,
            },
            &[],
        );
        let both = run(&topo, &wl).batch_time;
        let expect_both = 7e-6 + bytes / (12.5 * GB);
        assert!(
            (both - expect_both).abs() / expect_both < 1e-9,
            "shared waist: {both} vs {expect_both}"
        );
    }

    #[test]
    fn capped_flow_frees_bandwidth_for_others() {
        // On a spine-leaf, a cross-spine flow is capped at the spine
        // lane rate; an NVLink flow running concurrently (no shared
        // links) must still run at the full NVLink rate.
        let c = Cluster::spine_leaf_h100(64, 2.0);
        let topo = LinkGraph::from_cluster(&c);
        let mut wl = Workload::new();
        let nv = 1e9;
        wl.add(
            TaskKind::Transfer {
                flows: vec![
                    FlowSpec { src: 0, dst: 32, bytes: 1e6 }, // cross-spine, slow lane
                    FlowSpec { src: 1, dst: 2, bytes: nv },   // NVLink pair
                ],
                extra_latency: 0.0,
            },
            &[],
        );
        let rep = run(&topo, &wl);
        // The long NVLink flow sets the makespan, at its solo speed.
        let nv_solo = c.p2p_time(0, nv);
        assert!(
            (rep.batch_time - nv_solo).abs() / nv_solo < 1e-9,
            "NVLink flow throttled: {} vs solo {}",
            rep.batch_time,
            nv_solo
        );
    }

    #[test]
    fn dag_orders_compute_and_transfers() {
        let c = Cluster::fat_tree_tpuv4(64);
        let topo = LinkGraph::from_cluster(&c);
        let mut wl = Workload::new();
        let a = wl.add(TaskKind::Compute { seconds: 1.0 }, &[]);
        let x = wl.add(
            TaskKind::Transfer {
                flows: vec![FlowSpec { src: 0, dst: 8, bytes: 1e9 }],
                extra_latency: 0.0,
            },
            &[a],
        );
        let _b = wl.add(TaskKind::Compute { seconds: 0.5 }, &[x]);
        let rep = run(&topo, &wl);
        let expect = 1.0 + c.p2p_time(1, 1e9) + 0.5;
        assert!(
            (rep.batch_time - expect).abs() / expect < 1e-9,
            "{} vs {}",
            rep.batch_time,
            expect
        );
        assert_eq!(rep.n_flows, 1);
    }

    #[test]
    fn extra_latency_and_degenerate_flows() {
        let c = Cluster::fat_tree_tpuv4(64);
        let topo = LinkGraph::from_cluster(&c);
        let mut wl = Workload::new();
        // All flows degenerate (self-loop / zero bytes): pure latency.
        wl.add(
            TaskKind::Transfer {
                flows: vec![
                    FlowSpec { src: 3, dst: 3, bytes: 1e9 },
                    FlowSpec { src: 0, dst: 1, bytes: 0.0 },
                ],
                extra_latency: 2.5e-6,
            },
            &[],
        );
        let rep = run(&topo, &wl);
        assert!((rep.batch_time - 2.5e-6).abs() < 1e-15);
        assert_eq!(rep.n_flows, 0);
    }

    #[test]
    fn utilization_reported_on_contended_trunk() {
        // Overload the oversubscribed spine trunk: 64 concurrent cross
        // flows from 32 sources share a 32-lane (÷2 oversub) trunk, so
        // each runs below its lane rate and the trunk saturates.
        let c = Cluster::spine_leaf_h100(64, 2.0);
        let topo = LinkGraph::from_cluster(&c);
        let mut wl = Workload::new();
        let mut flows: Vec<FlowSpec> = Vec::new();
        for i in 0..32usize {
            flows.push(FlowSpec {
                src: i,
                dst: 32 + i,
                bytes: 1e9,
            });
            flows.push(FlowSpec {
                src: i,
                dst: 32 + (i + 1) % 32,
                bytes: 1e9,
            });
        }
        wl.add(
            TaskKind::Transfer {
                flows,
                extra_latency: 0.0,
            },
            &[],
        );
        let rep = run(&topo, &wl);
        assert_eq!(rep.n_flows, 64);
        // The leaf→spine trunk should be (near) fully utilized.
        assert!(
            rep.max_link_util > 0.9,
            "max util {}",
            rep.max_link_util
        );
        // And the run is strictly slower than a lone cross flow of the
        // same size (which moves at one uncontended lane's rate).
        let solo = single_flow(&topo, 0, 32, 1e9).batch_time;
        assert!(rep.batch_time > solo * 1.5, "{} vs {solo}", rep.batch_time);
    }

    #[test]
    fn reports_are_bit_identical() {
        let c = Cluster::spine_leaf_h100(64, 2.0);
        let topo = LinkGraph::from_cluster(&c);
        let build = || {
            let mut wl = Workload::new();
            let mut prev: Option<u32> = None;
            for i in 0..8u32 {
                let deps: Vec<u32> = match prev {
                    Some(p) => vec![p],
                    None => Vec::new(),
                };
                let cmp = wl.add(TaskKind::Compute { seconds: 1e-4 }, &deps);
                let xfer = wl.add(
                    TaskKind::Transfer {
                        flows: vec![
                            FlowSpec { src: i as usize, dst: 32 + i as usize, bytes: 1e8 },
                            FlowSpec { src: 32 + i as usize, dst: i as usize, bytes: 5e7 },
                        ],
                        extra_latency: 1e-6,
                    },
                    &[cmp],
                );
                prev = Some(xfer);
            }
            wl
        };
        let a = run(&topo, &build());
        let b = run(&topo, &build());
        assert_eq!(a.batch_time.to_bits(), b.batch_time.to_bits());
        assert_eq!(a.events, b.events);
        assert_eq!(a.link_util.len(), b.link_util.len());
        for (x, y) in a.link_util.iter().zip(&b.link_util) {
            assert_eq!(x.utilization.to_bits(), y.utilization.to_bits());
        }
    }

    #[test]
    fn incremental_matches_full_refill_bitwise() {
        // The tentpole invariant: dirty-component rate maintenance must
        // reproduce the naive every-event full refill to the bit —
        // including on workloads with several disjoint components alive
        // at once (NVLink pairs under separate leaves + cross-spine
        // flows), where the incremental path actually skips work.
        let c = Cluster::spine_leaf_h100(64, 2.0);
        let topo = LinkGraph::from_cluster(&c);
        let mut wl = Workload::new();
        let mut prev: Option<u32> = None;
        for i in 0..6u32 {
            let deps: Vec<u32> = prev.into_iter().collect();
            let cmp = wl.add(TaskKind::Compute { seconds: 2e-5 }, &deps);
            let xfer = wl.add(
                TaskKind::Transfer {
                    flows: vec![
                        // Disjoint NVLink pairs in two different leaves.
                        FlowSpec { src: 0, dst: 1, bytes: 3e8 + i as f64 * 1e7 },
                        FlowSpec { src: 8, dst: 9, bytes: 2e8 },
                        // Cross-spine contenders sharing the trunk.
                        FlowSpec { src: (i as usize) % 8, dst: 32 + i as usize, bytes: 1e8 },
                        FlowSpec { src: 16, dst: 48, bytes: 5e7 },
                    ],
                    extra_latency: 1e-6,
                },
                &[cmp],
            );
            prev = Some(xfer);
        }
        let inc = run_with_mode(&topo, &wl, RefillMode::Incremental);
        let full = run_with_mode(&topo, &wl, RefillMode::FullRefill);
        inc.assert_bits_eq(&full, "spine-leaf chain");
        assert!(inc.n_flows > 0 && inc.batch_time > 0.0);
    }

    #[test]
    fn engine_reuse_is_bit_identical() {
        // One engine, many runs: scratch reuse must not leak state
        // between workloads.
        let c = Cluster::spine_leaf_h100(64, 2.0);
        let topo = LinkGraph::from_cluster(&c);
        let mut engine = FairshareEngine::new(&topo);
        let build = |n: u32| {
            let mut wl = Workload::new();
            for i in 0..n {
                wl.add(
                    TaskKind::Transfer {
                        flows: vec![FlowSpec {
                            src: i as usize,
                            dst: 32 + i as usize,
                            bytes: 1e8,
                        }],
                        extra_latency: 0.0,
                    },
                    &[],
                );
            }
            wl
        };
        let a1 = engine.run(&topo, &build(8));
        let b = engine.run(&topo, &build(3)); // different shape in between
        let a2 = engine.run(&topo, &build(8));
        a1.assert_bits_eq(&a2, "engine reuse");
        assert!(b.n_flows == 3);
        // And a fresh engine agrees.
        let a3 = run(&topo, &build(8));
        a1.assert_bits_eq(&a3, "fresh engine");
    }

    #[test]
    fn refill_mode_resolves() {
        assert_ne!(RefillMode::Auto.resolve(), RefillMode::Auto);
        assert_eq!(RefillMode::Incremental.resolve(), RefillMode::Incremental);
        assert_eq!(RefillMode::FullRefill.resolve(), RefillMode::FullRefill);
    }

    /// The mini-dumbbell from `two_flows_share_a_bottleneck_fairly`,
    /// plus the ids of the 25 GB/s waist links (both directions).
    fn mini_dumbbell() -> (LinkGraph, Vec<u32>) {
        let src = r#"{"name": "mini-dumbbell",
            "nodes": ["a", "b", "c", "d",
                      {"id": "s0", "kind": "switch"}, {"id": "s1", "kind": "switch"}],
            "links": [
              {"src": "a", "dst": "s0", "bw_gbps": 100, "latency_us": 1},
              {"src": "b", "dst": "s0", "bw_gbps": 100, "latency_us": 1},
              {"src": "c", "dst": "s1", "bw_gbps": 100, "latency_us": 1},
              {"src": "d", "dst": "s1", "bw_gbps": 100, "latency_us": 1},
              {"src": "s0", "dst": "s1", "bw_gbps": 25, "latency_us": 5}
            ]}"#;
        let topo = LinkGraph::from_json(&crate::util::json::parse(src).unwrap()).unwrap();
        let waist: Vec<u32> = topo
            .links
            .iter()
            .enumerate()
            .filter(|(_, l)| l.capacity == 25.0 * GB)
            .map(|(i, _)| i as u32)
            .collect();
        assert_eq!(waist.len(), 2, "bidirectional waist");
        (topo, waist)
    }

    #[test]
    fn cap_event_brownout_slows_a_flow_in_closed_form() {
        // One flow over the waist; halfway through its drain the waist
        // browns out to half capacity. The completion time is exact:
        // t_half + remaining/(cap/2) + path latency.
        let (topo, waist) = mini_dumbbell();
        let cap = 25.0 * GB;
        let bytes = 1e9;
        let at = bytes / (2.0 * cap); // half the bytes drained
        let mut wl = Workload::new();
        wl.add(
            TaskKind::Transfer {
                flows: vec![FlowSpec { src: 0, dst: 2, bytes }],
                extra_latency: 0.0,
            },
            &[],
        );
        for &l in &waist {
            wl.cap_events.push(CapEvent {
                at,
                link: l,
                capacity: cap * 0.5,
            });
        }
        let rep = run(&topo, &wl);
        let expect = at + (bytes - cap * at) / (cap * 0.5) + 7e-6;
        assert!(
            (rep.batch_time - expect).abs() / expect < 1e-9,
            "browned-out flow: {} vs {expect}",
            rep.batch_time
        );
        // And the fault replays bit-identically under both refill modes.
        let inc = run_with_mode(&topo, &wl, RefillMode::Incremental);
        let full = run_with_mode(&topo, &wl, RefillMode::FullRefill);
        inc.assert_bits_eq(&full, "brownout incremental vs full refill");
    }

    #[test]
    fn cap_event_restore_speeds_the_flow_back_up() {
        // A flap window: degrade at t0, restore at t1. The flow must
        // finish strictly later than a clean run and strictly earlier
        // than under a permanent brownout.
        let (topo, waist) = mini_dumbbell();
        let cap = 25.0 * GB;
        let bytes = 1e9;
        let build = |events: &[(f64, f64)]| {
            let mut wl = Workload::new();
            wl.add(
                TaskKind::Transfer {
                    flows: vec![FlowSpec { src: 0, dst: 2, bytes }],
                    extra_latency: 0.0,
                },
                &[],
            );
            for &(at, frac) in events {
                for &l in &waist {
                    wl.cap_events.push(CapEvent {
                        at,
                        link: l,
                        capacity: cap * frac,
                    });
                }
            }
            wl
        };
        let t0 = bytes / (4.0 * cap);
        let t1 = bytes / (2.0 * cap);
        let clean = run(&topo, &build(&[])).batch_time;
        let flap = run(&topo, &build(&[(t0, 0.1), (t1, 1.0)])).batch_time;
        let brown = run(&topo, &build(&[(t0, 0.1)])).batch_time;
        assert!(clean < flap, "flap must cost time: {clean} vs {flap}");
        assert!(flap < brown, "restore must help: {flap} vs {brown}");
    }

    #[test]
    fn cap_event_past_the_batch_does_not_stretch_the_clock() {
        // A restore scheduled after the last task (flap window outlives
        // the batch) adds an event round but must not move batch_time.
        let (topo, waist) = mini_dumbbell();
        let mut wl = Workload::new();
        wl.add(
            TaskKind::Transfer {
                flows: vec![FlowSpec { src: 0, dst: 2, bytes: 1e9 }],
                extra_latency: 0.0,
            },
            &[],
        );
        let base = run(&topo, &wl);
        wl.cap_events.push(CapEvent {
            at: base.batch_time * 2.0,
            link: waist[0],
            capacity: 25.0 * GB,
        });
        let rep = run(&topo, &wl);
        assert_eq!(rep.batch_time.to_bits(), base.batch_time.to_bits());
        assert_eq!(rep.events, base.events + 1, "the late round is still counted");
    }

    #[test]
    #[should_panic(expected = "deadlock")]
    fn cyclic_workload_panics() {
        let c = Cluster::fat_tree_tpuv4(64);
        let topo = LinkGraph::from_cluster(&c);
        let mut wl = Workload::new();
        // 0 depends on 1, 1 depends on 0 (added via manual dep edit).
        let a = wl.add(TaskKind::Compute { seconds: 1.0 }, &[1]);
        let _b = wl.add(TaskKind::Compute { seconds: 1.0 }, &[a]);
        run(&topo, &wl);
    }
}
