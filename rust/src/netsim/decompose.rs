//! Static decomposition of a flow workload into independent
//! sub-simulations — the fleet-scale execution mode behind
//! [`super::Simulation`].
//!
//! Max-min fair share decomposes exactly over the connected components
//! of the link-sharing graph (a component's rates are a pure function of
//! its own flows and links — the same argument that makes the engine's
//! incremental refill bit-identical to a full refill). This module
//! hoists that argument from per-event maintenance to a *static*
//! pre-simulation partition:
//!
//! 1. [`partition`] unions tasks over (a) workload dependency edges —a
//!    task's start time depends on its prerequisites, so causally
//!    connected tasks must share a clock — and (b) shared directed links
//!    between their flows' deterministic routes. Each resulting
//!    component is a closed sub-workload: nothing outside it can affect
//!    its event evolution.
//! 2. [`run_decomposed`] runs each component on a plain
//!    [`FairshareEngine`] (workers claim components off an atomic index,
//!    mirroring the solver's scoped-thread pool) and merges the raw
//!    [`SubRun`] outcomes into one report via the same
//!    [`fairshare::finalize`] path monolithic runs use.
//!
//! # Why the merge is exact
//!
//! Task ids are remapped *monotonically* (components keep their tasks in
//! ascending original order), so heap tie-breaks `(time, kind, stable
//! id)` and the per-component canonical (arrival-id) fill order resolve
//! identically to the monolithic run restricted to that component. The
//! merged report is then assembled from interleaving-independent pieces:
//! byte totals sum per-flow records in canonical `(original task,
//! flow-index)` order, event rounds are counted from the sorted union of
//! round timestamps, and link utilizations scatter by link id (each link
//! belongs to exactly one component). No step depends on thread schedule
//! or component order — `prop_decomposed_matches_monolithic` pins the
//! whole report to the bit at 1 and 4 threads.

use std::sync::atomic::{AtomicUsize, Ordering};

use super::fairshare::{self, FairshareEngine, NetsimReport, RefillMode, SubRun, TaskKind, Workload};
use super::topo::LinkGraph;
use crate::obs;

/// One closed sub-workload of the partition.
pub struct Component {
    /// Task ids remapped to `0..tasks.len()`; `tasks[local] = original`.
    pub wl: Workload,
    /// Original task ids, ascending (so the remap is monotonic).
    pub tasks: Vec<u32>,
    /// Network-crossing flows in this component.
    pub n_flows: usize,
}

/// Union-find over task ids (path halving, union by attachment to the
/// smaller root so roots stay the smallest member — cheap determinism).
struct Dsu(Vec<u32>);

impl Dsu {
    fn new(n: usize) -> Self {
        Dsu((0..n as u32).collect())
    }

    fn find(&mut self, mut x: u32) -> u32 {
        while self.0[x as usize] != x {
            let parent = self.0[x as usize];
            self.0[x as usize] = self.0[parent as usize];
            x = self.0[x as usize];
        }
        x
    }

    fn union(&mut self, a: u32, b: u32) {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra == rb {
            return;
        }
        let (lo, hi) = if ra < rb { (ra, rb) } else { (rb, ra) };
        self.0[hi as usize] = lo;
    }
}

/// Partition `wl` into closed components: tasks connected by dependency
/// edges or by flows sharing a directed link end up together. Routes are
/// the topology's deterministic paths (the same ones the engine will
/// use); degenerate flows touch no links and add no edges.
///
/// Components are ordered by smallest original task id, and each keeps
/// its tasks in ascending original order.
pub fn partition(topo: &LinkGraph, wl: &Workload) -> Vec<Component> {
    let nt = wl.tasks.len();
    let mut dsu = Dsu::new(nt);
    for (i, deps) in wl.deps.iter().enumerate() {
        for &d in deps {
            dsu.union(i as u32, d);
        }
    }
    let mut link_owner: Vec<u32> = vec![u32::MAX; topo.links.len()];
    for (i, kind) in wl.tasks.iter().enumerate() {
        if let TaskKind::Transfer { flows, .. } = kind {
            for f in flows {
                if fairshare::flow_is_degenerate(f) {
                    continue;
                }
                for &l in &topo.path(f.src, f.dst).links {
                    if link_owner[l] == u32::MAX {
                        link_owner[l] = i as u32;
                    } else {
                        dsu.union(i as u32, link_owner[l]);
                    }
                }
            }
        }
    }

    // Group members by root; first-seen order over ascending task ids
    // yields components sorted by smallest member, members ascending.
    let mut comp_of_root: Vec<u32> = vec![u32::MAX; nt];
    let mut comps: Vec<Component> = Vec::new();
    for i in 0..nt as u32 {
        let r = dsu.find(i) as usize;
        if comp_of_root[r] == u32::MAX {
            comp_of_root[r] = comps.len() as u32;
            comps.push(Component {
                wl: Workload::new(),
                tasks: Vec::new(),
                n_flows: 0,
            });
        }
        comps[comp_of_root[r] as usize].tasks.push(i);
    }

    let mut local: Vec<u32> = vec![0; nt];
    for c in &comps {
        for (li, &t) in c.tasks.iter().enumerate() {
            local[t as usize] = li as u32;
        }
    }
    for c in &mut comps {
        let Component { wl: cwl, tasks, n_flows } = c;
        for &t in tasks.iter() {
            let kind = wl.tasks[t as usize].clone();
            if let TaskKind::Transfer { flows, .. } = &kind {
                *n_flows += flows
                    .iter()
                    .filter(|f| !fairshare::flow_is_degenerate(f))
                    .count();
            }
            let deps: Vec<u32> = wl.deps[t as usize]
                .iter()
                .map(|&d| local[d as usize])
                .collect();
            cwl.add(kind, &deps);
        }
        // The remap is monotonic, so the component's training tasks
        // (original id below the workload's boundary) are exactly the
        // local prefix — carry the boundary so each sub-run tracks its
        // training completion time like the monolithic loop does.
        cwl.bg_from = tasks.partition_point(|&t| t < wl.bg_from) as u32;
    }

    // Route injected capacity events to the component that owns each
    // event's link — a faulted link is shared state of its component
    // *only* (all flows crossing a link land in one component by
    // construction), so this preserves bit-identity with the monolithic
    // run. Events on links no flow ever uses cannot change any rate,
    // but their rounds are still clocked — park them on the first
    // component so the merged event count matches. Per-component order
    // follows the original event order (the heap's stable-id tie-break
    // relies on it for same-time same-link events).
    if !wl.cap_events.is_empty() && !comps.is_empty() {
        for ev in &wl.cap_events {
            let owner = link_owner[ev.link as usize];
            let ci = if owner == u32::MAX {
                0
            } else {
                comp_of_root[dsu.find(owner) as usize] as usize
            };
            comps[ci].wl.cap_events.push(*ev);
        }
    }
    comps
}

/// Run `wl` decomposed: partition, simulate each component on its own
/// engine pass (fanned across up to `threads` scoped workers; 0 = one
/// per core), and merge into a report bit-identical to the monolithic
/// run. Workers each build one [`FairshareEngine`] and reuse it across
/// the components they claim.
pub fn run_decomposed(
    topo: &LinkGraph,
    wl: &Workload,
    refill: RefillMode,
    threads: usize,
) -> NetsimReport {
    let refill = refill.resolve();
    let _span = obs::span_with("netsim.run", "netsim", || {
        vec![
            ("mode", "Decomposed".to_string()),
            ("refill", format!("{refill:?}")),
            ("tasks", wl.n_tasks().to_string()),
        ]
    });
    let comps = partition(topo, wl);
    if obs::enabled() {
        for c in &comps {
            obs::record("netsim.component_flows", c.n_flows as u64);
        }
    }
    // A task-free workload with capacity events has no components to
    // carry them: clock the events through one monolithic pass so the
    // report (event rounds included) still matches SimMode::Monolithic.
    if comps.is_empty() && !wl.cap_events.is_empty() {
        return FairshareEngine::new(topo).run_with_mode(topo, wl, refill);
    }

    let run_one = |engine: &mut FairshareEngine, c: &Component| -> SubRun {
        let _span = obs::span_with("netsim.component", "netsim", || {
            vec![
                ("tasks", c.tasks.len().to_string()),
                ("flows", c.n_flows.to_string()),
            ]
        });
        engine.sub_run(topo, &c.wl, refill)
    };

    let n_threads = crate::util::resolve_threads(threads).min(comps.len().max(1));
    let mut subs: Vec<Option<SubRun>> = Vec::new();
    subs.resize_with(comps.len(), || None);
    if n_threads <= 1 {
        let mut engine = FairshareEngine::new(topo);
        for (i, c) in comps.iter().enumerate() {
            subs[i] = Some(run_one(&mut engine, c));
        }
    } else {
        let next = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..n_threads)
                .map(|_| {
                    scope.spawn(|| {
                        let mut engine = FairshareEngine::new(topo);
                        let mut got: Vec<(usize, SubRun)> = Vec::new();
                        loop {
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= comps.len() {
                                break;
                            }
                            got.push((i, run_one(&mut engine, &comps[i])));
                        }
                        got
                    })
                })
                .collect();
            for h in handles {
                for (i, sub) in h.join().expect("netsim component worker panicked") {
                    subs[i] = Some(sub);
                }
            }
        });
    }

    // Merge. Every step is order-independent: max over end times, sorted
    // union of round timestamps (rounds coincide only at exactly equal
    // times, mirroring the monolithic loop's same-`t` batching), record
    // tasks mapped back to original ids, busy pairs concatenated (links
    // are disjoint across components).
    let mut end_t = 0.0f64;
    let mut train_end_t = 0.0f64;
    let mut times: Vec<f64> = Vec::new();
    let mut busy: Vec<(u32, f64)> = Vec::new();
    let mut records: Vec<fairshare::FlowRecord> = Vec::new();
    for (ci, sub) in subs.into_iter().enumerate() {
        let sub = sub.expect("every component simulated");
        end_t = end_t.max(sub.end_t);
        train_end_t = train_end_t.max(sub.train_end_t);
        times.extend_from_slice(&sub.event_times);
        busy.extend_from_slice(&sub.busy);
        let map = &comps[ci].tasks;
        records.extend(sub.records.into_iter().map(|r| fairshare::FlowRecord {
            task: map[r.task as usize],
            ..r
        }));
    }
    times.sort_unstable_by(f64::total_cmp);
    let mut events = 0usize;
    let mut last = 0.0f64;
    for (i, &t) in times.iter().enumerate() {
        if i == 0 || t != last {
            events += 1;
            last = t;
        }
    }
    fairshare::finalize(topo, end_t, train_end_t, events, records, &busy, wl.bg_from)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::GB;
    use crate::netsim::FlowSpec;

    fn two_rack_topo() -> LinkGraph {
        // Two rack-local device pairs behind their own switches. The
        // trunk keeps the graph all-pairs reachable (a `from_json`
        // invariant) but no rack-local route crosses it.
        let spec = r#"{
            "name": "two-rack",
            "nodes": ["d0", "d1", "d2", "d3",
                      {"id": "s0", "kind": "switch"},
                      {"id": "s1", "kind": "switch"}],
            "links": [
                {"src": "d0", "dst": "s0", "bw_gbps": 80, "latency_us": 1},
                {"src": "d1", "dst": "s0", "bw_gbps": 80, "latency_us": 1},
                {"src": "d2", "dst": "s1", "bw_gbps": 80, "latency_us": 1},
                {"src": "d3", "dst": "s1", "bw_gbps": 80, "latency_us": 1},
                {"src": "s0", "dst": "s1", "bw_gbps": 80, "latency_us": 1}
            ]
        }"#;
        LinkGraph::from_json(&crate::util::json::parse(spec).expect("valid json"))
            .expect("valid edge-list")
    }

    fn rack_local_workload() -> Workload {
        let mut wl = Workload::new();
        // Rack A: chain of two transfers.
        let a0 = wl.add(
            TaskKind::Transfer {
                flows: vec![FlowSpec { src: 0, dst: 1, bytes: GB }],
                extra_latency: 0.0,
            },
            &[],
        );
        wl.add(
            TaskKind::Transfer {
                flows: vec![FlowSpec { src: 1, dst: 0, bytes: 2.0 * GB }],
                extra_latency: 0.0,
            },
            &[a0],
        );
        // Rack B: compute then transfer.
        let b0 = wl.add(TaskKind::Compute { seconds: 1e-3 }, &[]);
        wl.add(
            TaskKind::Transfer {
                flows: vec![FlowSpec { src: 2, dst: 3, bytes: GB }],
                extra_latency: 0.0,
            },
            &[b0],
        );
        wl
    }

    #[test]
    fn partition_splits_rack_local_traffic() {
        let topo = two_rack_topo();
        let wl = rack_local_workload();
        let comps = partition(&topo, &wl);
        assert_eq!(comps.len(), 2);
        // Ordered by smallest original task id, members ascending.
        assert_eq!(comps[0].tasks, vec![0, 1]);
        assert_eq!(comps[1].tasks, vec![2, 3]);
        assert_eq!(comps[0].n_flows, 2);
        assert_eq!(comps[1].n_flows, 1);
        // Remapped deps survive: rack B's transfer depends on its
        // compute under local ids.
        assert_eq!(comps[1].wl.n_tasks(), 2);
        assert_eq!(comps[1].wl.deps[1], vec![0]);
    }

    #[test]
    fn dependency_edges_merge_link_disjoint_tasks() {
        let topo = two_rack_topo();
        let mut wl = Workload::new();
        let a = wl.add(
            TaskKind::Transfer {
                flows: vec![FlowSpec { src: 0, dst: 1, bytes: GB }],
                extra_latency: 0.0,
            },
            &[],
        );
        // Depends on rack A's transfer but sends in rack B: causally one
        // component even though the routes are link-disjoint.
        wl.add(
            TaskKind::Transfer {
                flows: vec![FlowSpec { src: 2, dst: 3, bytes: GB }],
                extra_latency: 0.0,
            },
            &[a],
        );
        let comps = partition(&topo, &wl);
        assert_eq!(comps.len(), 1);
        assert_eq!(comps[0].tasks, vec![0, 1]);
    }

    #[test]
    fn decomposed_matches_monolithic_at_any_thread_count() {
        let topo = two_rack_topo();
        let wl = rack_local_workload();
        let mono = FairshareEngine::new(&topo).run_with_mode(&topo, &wl, RefillMode::Incremental);
        for threads in [1, 4] {
            let dec = run_decomposed(&topo, &wl, RefillMode::Incremental, threads);
            mono.assert_bits_eq(&dec, &format!("decomposed vs monolithic ({threads} threads)"));
        }
        let mono_full = FairshareEngine::new(&topo).run_with_mode(&topo, &wl, RefillMode::FullRefill);
        let dec_full = run_decomposed(&topo, &wl, RefillMode::FullRefill, 2);
        mono_full.assert_bits_eq(&dec_full, "decomposed vs monolithic (full refill)");
    }

    #[test]
    fn cap_events_route_to_their_owning_component_and_replay_identically() {
        let topo = two_rack_topo();
        let mut wl = rack_local_workload();
        let la = topo.path(0, 1).links[0] as u32;
        let lb = topo.path(2, 3).links[0] as u32;
        // A trunk link no rack-local flow uses: parked on the first
        // component purely to clock its event round.
        let cross = topo
            .path(0, 2)
            .links
            .iter()
            .copied()
            .find(|l| !topo.path(0, 1).links.contains(l) && !topo.path(2, 3).links.contains(l))
            .expect("cross-rack route has a trunk link") as u32;
        for (at, link) in [(1e-4, la), (2e-4, lb), (3e-4, cross)] {
            wl.cap_events.push(fairshare::CapEvent {
                at,
                link,
                capacity: GB,
            });
        }
        let comps = partition(&topo, &wl);
        assert_eq!(comps.len(), 2);
        assert_eq!(comps[0].wl.cap_events.len(), 2, "rack A fault + parked trunk");
        assert_eq!(comps[1].wl.cap_events.len(), 1, "rack B fault");
        let mono = FairshareEngine::new(&topo).run_with_mode(&topo, &wl, RefillMode::Incremental);
        for threads in [1, 4] {
            let dec = run_decomposed(&topo, &wl, RefillMode::Incremental, threads);
            mono.assert_bits_eq(&dec, &format!("faulted decomposed ({threads} threads)"));
        }
    }

    #[test]
    fn empty_workload_decomposes_to_empty_report() {
        let topo = two_rack_topo();
        let rep = run_decomposed(&topo, &Workload::new(), RefillMode::Incremental, 4);
        assert_eq!(rep.n_flows, 0);
        assert_eq!(rep.events, 0);
        assert_eq!(rep.batch_time, 0.0);
    }
}
