//! Lowering a [`PlacementPlan`] + pipeline schedule into timestamped
//! flows.
//!
//! The analytic simulator ([`crate::sim`]) folds all communication into
//! per-level α–β costs; here every piece of traffic becomes explicit
//! flows on the [`LinkGraph`] so concurrent transfers — across pipeline
//! stages, data-parallel replicas, and collective phases — actually
//! share links:
//!
//! * **intra-stage collectives** (TP/SP/EP/CP) lower into hierarchical
//!   ring phases: reduce-scatter volumes ascending the topology's ring
//!   levels, all-gather mirroring back down (flat rings on edge-lists),
//!   with per-layer calls of one stage coalesced per (kind, group) and
//!   the analytic per-call α terms carried as an `extra_latency` so
//!   coalescing never *under*-charges latency;
//! * **inter-stage activation/gradient p2p** becomes one flow per
//!   microbatch per boundary between the adjacent stage blocks' edge
//!   devices (the same boundary the solver's `send_level` prices);
//! * **the data-parallel gradient all-reduce** rings over the actual
//!   replica device positions, so spread groups pay the outer tiers
//!   they really cross — and replicas contend with each other, which
//!   the per-replica analytic model structurally cannot see.
//!
//! Compute stays analytic ([`CostModel::stage_phase_compute`]): netsim
//! is a *network* cross-validator, so on an uncontended fabric it
//! reproduces the analytic DES closely, and under contention it is
//! never faster.

use crate::cost::CostModel;
use crate::graph::subgraph::{layer_collectives, CollectiveCall, CollectiveKind, SgConfig};
use crate::graph::LayerGraph;
use crate::network::Cluster;
use crate::sim::{stage_ops, Op, Schedule};
use crate::solver::plan::{PlacementPlan, StagePlan};

use super::fairshare::{FlowSpec, TaskKind, Workload};
use super::faults::FaultScenario;
use super::topo::LinkGraph;

/// One sequential phase of a lowered collective: all flows run
/// concurrently; the next phase starts when the slowest drains.
#[derive(Debug, Clone)]
struct Phase {
    flows: Vec<FlowSpec>,
    /// Max path latency across the phase's flows (structural latency the
    /// engine will charge anyway — used to compute the α top-up).
    latency: f64,
}

/// A stage's per-microbatch collective traffic, pre-lowered once and
/// re-instantiated per op (the phases repeat every microbatch).
#[derive(Debug, Clone)]
struct CollectiveTemplate {
    phases: Vec<Phase>,
    /// α top-up: analytic per-call latency the coalesced phases do not
    /// already pay structurally.
    extra: f64,
}

/// A collective call aggregated over a stage's layers.
struct AggCall {
    kind: CollectiveKind,
    group: usize,
    bytes: f64,
    calls: usize,
}

fn aggregate_stage_collectives(
    graph: &LayerGraph,
    sg: &SgConfig,
    i: usize,
    j: usize,
) -> Vec<AggCall> {
    let mut out: Vec<AggCall> = Vec::new();
    for k in i..j {
        for call in layer_collectives(&graph.layers[k], graph.tokens, sg) {
            match out
                .iter_mut()
                .find(|a| a.kind == call.kind && a.group == call.group)
            {
                Some(a) => {
                    a.bytes += call.bytes;
                    a.calls += 1;
                }
                None => out.push(AggCall {
                    kind: call.kind,
                    group: call.group,
                    bytes: call.bytes,
                    calls: 1,
                }),
            }
        }
    }
    out
}

/// Hierarchical ring pass over `participants` (sorted device ids):
/// ascending the topology's ring levels, each group of `g` co-located
/// members sends `(g−1)/g` of its current shard to its ring successor,
/// then one representative per group carries `shard/g` upward. On
/// edge-lists (one ring level) this degenerates to a single flat ring.
fn ascend_pass(topo: &LinkGraph, participants: &[usize], total: f64) -> Vec<Phase> {
    let mut phases: Vec<Phase> = Vec::new();
    let mut reps: Vec<usize> = participants.to_vec();
    let mut shard: Vec<f64> = vec![total; reps.len()];
    let mut level = 0usize;
    while reps.len() > 1 {
        let flat = level >= topo.n_ring_levels();
        let mut groups: Vec<(usize, usize)> = Vec::new(); // [start, end) into reps
        if flat {
            groups.push((0, reps.len()));
        } else {
            let mut s = 0usize;
            for e in 1..=reps.len() {
                if e == reps.len()
                    || topo.ring_group(reps[e], level) != topo.ring_group(reps[s], level)
                {
                    groups.push((s, e));
                    s = e;
                }
            }
        }
        let mut flows: Vec<FlowSpec> = Vec::new();
        let mut lat: f64 = 0.0;
        let mut new_reps: Vec<usize> = Vec::new();
        let mut new_shard: Vec<f64> = Vec::new();
        for &(s, e) in &groups {
            let g = e - s;
            if g > 1 {
                let gf = g as f64;
                for idx in s..e {
                    let nxt = if idx + 1 == e { s } else { idx + 1 };
                    let (src, dst) = (reps[idx], reps[nxt]);
                    flows.push(FlowSpec {
                        src,
                        dst,
                        bytes: shard[idx] * (gf - 1.0) / gf,
                    });
                    lat = lat.max(topo.path(src, dst).latency);
                }
                new_shard.push(shard[s] / gf);
            } else {
                new_shard.push(shard[s]);
            }
            new_reps.push(reps[s]);
        }
        if !flows.is_empty() {
            phases.push(Phase { flows, latency: lat });
        }
        reps = new_reps;
        shard = new_shard;
        if flat {
            break;
        }
        level += 1;
    }
    phases
}

/// Merge the ascend passes of every `g`-sized sub-block of `devices`
/// (concurrent sub-group collectives, e.g. two TP-4 groups inside an
/// 8-device stage) phase-by-phase.
fn merged_ascend(topo: &LinkGraph, devices: &[usize], g: usize, total: f64) -> Vec<Phase> {
    let mut merged: Vec<Phase> = Vec::new();
    for block in devices.chunks(g) {
        if block.len() < 2 {
            continue;
        }
        for (pi, ph) in ascend_pass(topo, block, total).into_iter().enumerate() {
            if merged.len() <= pi {
                merged.push(Phase {
                    flows: Vec::new(),
                    latency: 0.0,
                });
            }
            merged[pi].flows.extend(ph.flows);
            merged[pi].latency = merged[pi].latency.max(ph.latency);
        }
    }
    merged
}

/// Lower one aggregated collective over a stage's `devices` into
/// sequential phases. `vol` is the per-participant payload of one
/// occurrence (the analytic `CollectiveCall::bytes` convention).
fn lower_collective(
    topo: &LinkGraph,
    devices: &[usize],
    kind: CollectiveKind,
    group: usize,
    vol: f64,
) -> Vec<Phase> {
    if vol <= 0.0 || devices.len() < 2 {
        return Vec::new();
    }
    let g = group.clamp(1, devices.len());
    match kind {
        CollectiveKind::SendRecv => {
            // Exchange between two adjacent g-sized blocks: the flow
            // crosses exactly the boundary the analytic model prices at
            // `boundary_level(g)` (edge device of block 0 → first device
            // of block 1).
            let si = (g - 1).min(devices.len() - 1);
            let di = g.min(devices.len() - 1);
            let (src, dst) = (devices[si], devices[di]);
            if src == dst {
                return Vec::new();
            }
            let latency = topo.path(src, dst).latency;
            vec![Phase {
                flows: vec![FlowSpec {
                    src,
                    dst,
                    bytes: vol,
                }],
                latency,
            }]
        }
        CollectiveKind::AllToAll => {
            if g < 2 {
                return Vec::new();
            }
            let mut flows: Vec<FlowSpec> = Vec::new();
            let mut latency: f64 = 0.0;
            for block in devices.chunks(g) {
                if block.len() < 2 {
                    continue;
                }
                let per = vol / block.len() as f64;
                for &a in block {
                    for &b in block {
                        if a != b {
                            flows.push(FlowSpec {
                                src: a,
                                dst: b,
                                bytes: per,
                            });
                            latency = latency.max(topo.path(a, b).latency);
                        }
                    }
                }
            }
            if flows.is_empty() {
                Vec::new()
            } else {
                vec![Phase { flows, latency }]
            }
        }
        CollectiveKind::AllReduce => {
            // Reduce-scatter up, all-gather mirroring back down: per ring
            // level the two passes together move 2·(g−1)/g·shard, the
            // analytic hierarchical-ring volume.
            let up = merged_ascend(topo, devices, g, vol);
            let mut phases = up.clone();
            phases.extend(up.into_iter().rev());
            phases
        }
        CollectiveKind::AllGather | CollectiveKind::ReduceScatter => {
            // Analytic convention: the gathered/scattered total is
            // bytes · group (see `Cluster::collective_time`).
            merged_ascend(topo, devices, g, vol * g as f64)
        }
    }
}

/// The adjacent device pair across a stage boundary: the edge device of
/// the producing block facing the consuming block, and the consuming
/// block's facing edge device. For the solver's contiguous blocks this
/// is exactly the boundary the DP prices via `boundary_level` /
/// `send_level`, whichever way the blocks are ordered (the uniform
/// solver lays stages out tail-first, so stage k sits *above* stage
/// k+1 in device ids).
fn boundary_pair(producer: &StagePlan, consumer: &StagePlan) -> (usize, usize) {
    if producer.devices[0] <= consumer.devices[0] {
        (*producer.devices.last().unwrap(), consumer.devices[0])
    } else {
        (producer.devices[0], *consumer.devices.last().unwrap())
    }
}

/// Build the collective template of one (stage, replica): all aggregated
/// calls' phases chained, with the α top-up on the tail.
fn stage_template(
    topo: &LinkGraph,
    cluster: &Cluster,
    aggs: &[AggCall],
    devices: &[usize],
) -> CollectiveTemplate {
    let mut phases: Vec<Phase> = Vec::new();
    let mut alpha = 0.0f64;
    for a in aggs {
        // Analytic latency-only cost of all coalesced occurrences; half
        // lands in each of the fwd/bwd halves (mirroring
        // `stage_phase_times` splitting collectives evenly).
        alpha += a.calls as f64
            * cluster.collective_time(&CollectiveCall {
                kind: a.kind,
                bytes: 0.0,
                group: a.group,
            })
            / 2.0;
        phases.extend(lower_collective(topo, devices, a.kind, a.group, a.bytes / 2.0));
    }
    let structural: f64 = phases.iter().map(|ph| ph.latency).sum();
    CollectiveTemplate {
        phases,
        extra: (alpha - structural).max(0.0),
    }
}

/// Lower one training batch of `plan` into a flow-level workload on
/// `topo`. `cluster` is the analytic view the plan was solved on (used
/// for compute costs and α accounting); `topo` must have at least as
/// many devices as the plan uses.
pub fn lower(
    graph: &LayerGraph,
    cluster: &Cluster,
    topo: &LinkGraph,
    plan: &PlacementPlan,
    schedule: Schedule,
) -> Workload {
    lower_faulted(graph, cluster, topo, plan, schedule, None)
}

/// [`lower`] with an optional fault scenario: each straggling device's
/// compute slowdown stretches the fwd/bwd phases of every stage that
/// places any replica on it. Stages run their replicas in lockstep
/// (mirroring the slowest-class rule of `stage_class_mask`), so one
/// straggler slows the whole stage across all replicas — the honest
/// pipeline-parallel cost of a slow device. Link faults are *not*
/// applied here; inject them into the returned workload with
/// [`super::faults::inject`].
pub fn lower_faulted(
    graph: &LayerGraph,
    cluster: &Cluster,
    topo: &LinkGraph,
    plan: &PlacementPlan,
    schedule: Schedule,
    faults: Option<&FaultScenario>,
) -> Workload {
    let p = plan.n_stages();
    let m = plan.n_microbatches;
    let d = plan.dp_width;
    let stride = plan.devices_per_replica;
    assert!(p >= 1 && m >= 1 && d >= 1);
    assert!(
        topo.n_devices() >= plan.used_devices(),
        "topology has {} devices, plan uses {}",
        topo.n_devices(),
        plan.used_devices()
    );

    let mut wl = Workload::new();

    // Per-stage cost models (stages may differ in sg).
    let mut cms: Vec<(SgConfig, CostModel)> = Vec::new();
    let mut cm_idx: Vec<usize> = Vec::with_capacity(p);
    for st in &plan.stages {
        let pos = match cms.iter().position(|(sg, _)| *sg == st.sg) {
            Some(pos) => pos,
            None => {
                cms.push((st.sg, CostModel::new(graph, cluster, st.sg)));
                cms.len() - 1
            }
        };
        cm_idx.push(pos);
    }

    // Static per-stage pieces.
    let mut fwd_s = vec![0.0; p];
    let mut bwd_s = vec![0.0; p];
    let mut act_bytes = vec![0.0; p]; // boundary after stage k (k < p−1)
    let mut grad_bytes = vec![0.0; p];
    for (k, st) in plan.stages.iter().enumerate() {
        let cm = &cms[cm_idx[k]].1;
        // Lockstep on the slowest accelerator class the stage's devices
        // (all replicas) cover — mirrors the analytic DES.
        let mask = crate::solver::assign::stage_class_mask(cluster, &st.devices, d, stride);
        let (f, b) = cm.stage_phase_compute_on(mask, st.layers.0, st.layers.1, &st.mem);
        // Stragglers: lockstep means the slowest participant paces the
        // stage, so take the max slowdown over every replica's devices.
        let mut slow = 1.0f64;
        if let Some(sc) = faults {
            for r in 0..d {
                for &dev in &st.devices {
                    slow = slow.max(sc.slowdown_of(dev + r * stride));
                }
            }
        }
        fwd_s[k] = f * slow;
        bwd_s[k] = b * slow;
        if k + 1 < p {
            act_bytes[k] = cm.boundary_bytes_after(st.layers.1);
        }
        grad_bytes[k] = cm.stage_grad_bytes(st.layers.0, st.layers.1);
    }

    // Collective templates per (stage, replica).
    let mut templates: Vec<Vec<CollectiveTemplate>> = Vec::with_capacity(p);
    for st in &plan.stages {
        let aggs = aggregate_stage_collectives(graph, &st.sg, st.layers.0, st.layers.1);
        let mut per_rep: Vec<CollectiveTemplate> = Vec::with_capacity(d);
        for r in 0..d {
            let mut devices: Vec<usize> =
                st.devices.iter().map(|&dev| dev + r * stride).collect();
            devices.sort_unstable();
            per_rep.push(stage_template(topo, cluster, &aggs, &devices));
        }
        templates.push(per_rep);
    }

    // Emit each replica's pipeline: the same availability-driven sweep
    // the analytic simulator executes, creating tasks once their
    // dependency tasks exist.
    let mut stage_tails: Vec<Vec<u32>> = Vec::with_capacity(d);
    for r in 0..d {
        let ops: Vec<Vec<Op>> = (0..p).map(|k| stage_ops(schedule, k, p, m)).collect();
        let total_ops: usize = ops.iter().map(|o| o.len()).sum();
        let mut next_op = vec![0usize; p];
        let mut last_task: Vec<Option<u32>> = vec![None; p];
        let mut fwd_done: Vec<Vec<Option<u32>>> = vec![vec![None; m]; p];
        let mut fwd_p2p: Vec<Vec<Option<u32>>> = vec![vec![None; m]; p];
        let mut bwd_p2p: Vec<Vec<Option<u32>>> = vec![vec![None; m]; p];
        let mut created = 0usize;
        while created < total_ops {
            let mut progressed = false;
            for k in 0..p {
                while next_op[k] < ops[k].len() {
                    let op = ops[k][next_op[k]];
                    // External dependency (None = ready with no edge;
                    // outer None = producer task not created yet).
                    let ext: Option<Option<u32>> = match op {
                        Op::Fwd(mb) => {
                            if k == 0 {
                                Some(None)
                            } else {
                                fwd_p2p[k - 1][mb].map(Some)
                            }
                        }
                        Op::Bwd(mb) => {
                            if k == p - 1 {
                                fwd_done[k][mb].map(Some)
                            } else {
                                bwd_p2p[k + 1][mb].map(Some)
                            }
                        }
                    };
                    let Some(ext) = ext else { break };
                    let mut deps: Vec<u32> = Vec::new();
                    if let Some(tail) = last_task[k] {
                        deps.push(tail);
                    }
                    if let Some(t) = ext {
                        deps.push(t);
                    }
                    let seconds = match op {
                        Op::Fwd(_) => fwd_s[k],
                        Op::Bwd(_) => bwd_s[k],
                    };
                    let mut tid = wl.add(TaskKind::Compute { seconds }, &deps);
                    // The op's collective phases, serialized on the stage.
                    let tmpl = &templates[k][r];
                    let n_ph = tmpl.phases.len();
                    for (pi, ph) in tmpl.phases.iter().enumerate() {
                        let extra = if pi + 1 == n_ph { tmpl.extra } else { 0.0 };
                        tid = wl.add(
                            TaskKind::Transfer {
                                flows: ph.flows.clone(),
                                extra_latency: extra,
                            },
                            &[tid],
                        );
                    }
                    if n_ph == 0 && tmpl.extra > 0.0 {
                        tid = wl.add(
                            TaskKind::Transfer {
                                flows: Vec::new(),
                                extra_latency: tmpl.extra,
                            },
                            &[tid],
                        );
                    }
                    last_task[k] = Some(tid);
                    match op {
                        Op::Fwd(mb) => {
                            fwd_done[k][mb] = Some(tid);
                            if k + 1 < p {
                                // Activation to the next stage across
                                // the adjacent block edge.
                                let (a, b) =
                                    boundary_pair(&plan.stages[k], &plan.stages[k + 1]);
                                let (src, dst) = (a + r * stride, b + r * stride);
                                fwd_p2p[k][mb] = Some(wl.add(
                                    TaskKind::Transfer {
                                        flows: vec![FlowSpec {
                                            src,
                                            dst,
                                            bytes: act_bytes[k],
                                        }],
                                        extra_latency: 0.0,
                                    },
                                    &[tid],
                                ));
                            }
                        }
                        Op::Bwd(mb) => {
                            if k > 0 {
                                // Gradient back over the same boundary.
                                let (a, b) =
                                    boundary_pair(&plan.stages[k - 1], &plan.stages[k]);
                                let (src, dst) = (b + r * stride, a + r * stride);
                                bwd_p2p[k][mb] = Some(wl.add(
                                    TaskKind::Transfer {
                                        flows: vec![FlowSpec {
                                            src,
                                            dst,
                                            bytes: act_bytes[k - 1],
                                        }],
                                        extra_latency: 0.0,
                                    },
                                    &[tid],
                                ));
                            }
                        }
                    }
                    next_op[k] += 1;
                    created += 1;
                    progressed = true;
                }
            }
            assert!(progressed, "netsim lowering deadlock (schedule bug)");
        }
        stage_tails.push(
            last_task
                .into_iter()
                .map(|t| t.expect("every stage ran at least one op"))
                .collect(),
        );
    }

    // Data-parallel gradient all-reduce: per stage, rings over the
    // actual replica positions, after every replica's last op. All
    // stages' syncs run concurrently — on a shared trunk they contend,
    // which `Cluster::dp_allreduce` prices independently per stage.
    if d > 1 {
        for k in 0..p {
            let participants: Vec<usize> = (0..d)
                .map(|r| plan.stages[k].devices[0] + r * stride)
                .collect();
            let deps: Vec<u32> = (0..d).map(|r| stage_tails[r][k]).collect();
            // Analytic floor: for ragged strides the physical rings can
            // undercut the `spread_shape` approximation the DES charges
            // (its ceils round group sizes up). Netsim is a congestion
            // *cross-check*, so it must never report less than the
            // analytic sync — keep the DES's exact term as a parallel
            // lower bound on the batch end.
            let analytic_sync = cluster.dp_allreduce(grad_bytes[k], d, stride);
            if analytic_sync > 0.0 {
                wl.add(
                    TaskKind::Compute {
                        seconds: analytic_sync,
                    },
                    &deps,
                );
            }
            let phases = lower_collective(
                topo,
                &participants,
                CollectiveKind::AllReduce,
                participants.len(),
                grad_bytes[k],
            );
            if phases.is_empty() {
                continue;
            }
            let structural: f64 = phases.iter().map(|ph| ph.latency).sum();
            let alpha = cluster.dp_allreduce(0.0, d, stride);
            let extra = (alpha - structural).max(0.0);
            let n_ph = phases.len();
            let mut tid: Option<u32> = None;
            for (pi, ph) in phases.into_iter().enumerate() {
                let e = if pi + 1 == n_ph { extra } else { 0.0 };
                let task_deps: Vec<u32> = match tid {
                    Some(t) => vec![t],
                    None => deps.clone(),
                };
                tid = Some(wl.add(
                    TaskKind::Transfer {
                        flows: ph.flows,
                        extra_latency: e,
                    },
                    &task_deps,
                ));
            }
        }
    }

    wl
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::memory::MemSpec;
    use crate::netsim::fairshare;
    use crate::sim::simulate;

    /// Hand-built 2-stage × 2-replica plan on an 8-device V100 cluster
    /// (mirrors `solver::plan::tests::mini_plan`, with contiguous
    /// solver-style device blocks).
    fn mini_setup() -> (LayerGraph, Cluster, LinkGraph, PlacementPlan) {
        let g = models::tiny_transformer(6, 256, 128, 1);
        let c = Cluster::v100_cluster(8);
        let topo = LinkGraph::from_cluster(&c);
        let plan = PlacementPlan {
            model_name: g.model_name.clone(),
            method: "test".into(),
            sg: SgConfig::serial(),
            stages: vec![
                StagePlan {
                    layers: (0, 4),
                    devices: vec![0],
                    sg: SgConfig::serial(),
                    mem: MemSpec::plain(),
                    send_level: Some(0),
                    load: 1.0,
                    accel_class: "v100".into(),
                },
                StagePlan {
                    layers: (4, 8),
                    devices: vec![1],
                    sg: SgConfig::serial(),
                    mem: MemSpec::plain(),
                    send_level: None,
                    load: 1.0,
                    accel_class: "v100".into(),
                },
            ],
            dp_width: 2,
            mbs: 1,
            n_microbatches: 4,
            devices_per_replica: 2,
            bottleneck: 1.0,
            sync_time: 0.1,
            batch_time: 5.1,
        };
        (g, c, topo, plan)
    }

    #[test]
    fn mini_plan_lowers_and_runs() {
        let (g, c, topo, plan) = mini_setup();
        let wl = lower(&g, &c, &topo, &plan, Schedule::OneFOneB);
        // 2 replicas × 2 stages × 8 ops + p2p transfers + dp sync.
        assert!(wl.n_tasks() > 2 * 2 * 8);
        let rep = fairshare::run(&topo, &wl);
        assert!(rep.batch_time.is_finite() && rep.batch_time > 0.0);
        // p2p act+grad flows exist: 2 replicas × 4 mb × 2 directions,
        // plus the dp all-reduce rings.
        assert!(rep.n_flows >= 2 * 4 * 2);
    }

    #[test]
    fn flow_sim_at_least_analytic_on_uncontended_mini() {
        let (g, c, topo, plan) = mini_setup();
        let ana = simulate(&g, &c, &plan, Schedule::OneFOneB);
        let wl = lower(&g, &c, &topo, &plan, Schedule::OneFOneB);
        let flow = fairshare::run(&topo, &wl);
        // Same DAG, same compute, flows never beat the α–β terms: the
        // flow-level batch is bounded below by the analytic DES (up to
        // float dust), and close above it when nothing contends.
        assert!(
            flow.batch_time >= ana.batch_time * (1.0 - 1e-9),
            "flow {} < analytic {}",
            flow.batch_time,
            ana.batch_time
        );
        assert!(
            flow.batch_time <= ana.batch_time * 1.5,
            "uncontended flow-sim drifted: {} vs {}",
            flow.batch_time,
            ana.batch_time
        );
    }

    #[test]
    fn straggler_slows_only_plans_that_touch_it() {
        use crate::netsim::faults::FaultScenario;
        let (g, c, topo, plan) = mini_setup();
        let base = fairshare::run(&topo, &lower(&g, &c, &topo, &plan, Schedule::OneFOneB));
        // Device 1 hosts stage 1 of replica 0: a 2× straggler there must
        // stretch the batch.
        let hit = FaultScenario {
            link_faults: vec![],
            stragglers: vec![(1, 2.0)],
        };
        let slow = fairshare::run(
            &topo,
            &lower_faulted(&g, &c, &topo, &plan, Schedule::OneFOneB, Some(&hit)),
        );
        assert!(
            slow.batch_time > base.batch_time,
            "straggler did not slow the batch: {} vs {}",
            slow.batch_time,
            base.batch_time
        );
        // The plan uses devices 0..4 (2 stages × 2 replicas, stride 2);
        // a straggler on an unused device changes nothing, bit for bit.
        let miss = FaultScenario {
            link_faults: vec![],
            stragglers: vec![(7, 4.0)],
        };
        let same = fairshare::run(
            &topo,
            &lower_faulted(&g, &c, &topo, &plan, Schedule::OneFOneB, Some(&miss)),
        );
        same.assert_bits_eq(&base, "straggler on an unused device");
    }

    #[test]
    fn gpipe_schedule_lowers_too() {
        let (g, c, topo, plan) = mini_setup();
        let wl = lower(&g, &c, &topo, &plan, Schedule::GPipe);
        let rep = fairshare::run(&topo, &wl);
        let wl1 = lower(&g, &c, &topo, &plan, Schedule::OneFOneB);
        let rep1 = fairshare::run(&topo, &wl1);
        // GPipe reorders but moves the same bytes.
        assert_eq!(rep.n_flows, rep1.n_flows);
        assert!((rep.total_bytes - rep1.total_bytes).abs() < 1.0);
        assert!(rep.batch_time >= rep1.batch_time * 0.95);
    }

    #[test]
    fn oversubscription_slows_cross_spine_plan() {
        // Same hand plan whose boundary crosses the spine, on a 1:1 vs a
        // 4:1 spine: the flow simulator must see the thinner trunk.
        let g = models::tiny_transformer(6, 256, 128, 1);
        let mk_plan = || PlacementPlan {
            model_name: g.model_name.clone(),
            method: "test".into(),
            sg: SgConfig::serial(),
            stages: vec![
                StagePlan {
                    layers: (0, 4),
                    devices: vec![0],
                    sg: SgConfig::serial(),
                    mem: MemSpec::plain(),
                    send_level: Some(2),
                    load: 1.0,
                    accel_class: "h100".into(),
                },
                StagePlan {
                    layers: (4, 8),
                    devices: vec![32],
                    sg: SgConfig::serial(),
                    mem: MemSpec::plain(),
                    send_level: None,
                    load: 1.0,
                    accel_class: "h100".into(),
                },
            ],
            dp_width: 4,
            mbs: 1,
            n_microbatches: 8,
            devices_per_replica: 1,
            bottleneck: 1.0,
            sync_time: 0.1,
            batch_time: 9.1,
        };
        let mut times = Vec::new();
        for oversub in [1.0, 4.0] {
            let c = Cluster::spine_leaf_h100(64, oversub);
            let topo = LinkGraph::from_cluster(&c);
            let plan = mk_plan();
            let wl = lower(&g, &c, &topo, &plan, Schedule::OneFOneB);
            times.push(fairshare::run(&topo, &wl).batch_time);
        }
        assert!(
            times[1] > times[0],
            "4:1 spine must be strictly slower: {:?}",
            times
        );
    }

    #[test]
    fn collective_lowering_volumes_match_hierarchical_ring() {
        // An 8-device node-local all-reduce lowers to 2 phases (RS + AG)
        // of 8 flows each carrying (g−1)/g · V.
        let c = Cluster::fat_tree_tpuv4(64);
        let topo = LinkGraph::from_cluster(&c);
        let devices: Vec<usize> = (0..8).collect();
        let v = 1e9;
        let phases = lower_collective(&topo, &devices, CollectiveKind::AllReduce, 8, v);
        assert_eq!(phases.len(), 2);
        for ph in &phases {
            assert_eq!(ph.flows.len(), 8);
            for f in &ph.flows {
                assert!((f.bytes - v * 7.0 / 8.0).abs() < 1.0);
            }
        }
        // A 32-device group spanning 4 nodes: node phase then leaf phase
        // on the way up.
        let devices: Vec<usize> = (0..32).collect();
        let up = lower_collective(&topo, &devices, CollectiveKind::ReduceScatter, 32, v);
        assert_eq!(up.len(), 2);
        assert_eq!(up[0].flows.len(), 32); // 4 node rings × 8
        assert_eq!(up[1].flows.len(), 4); // 1 leaf ring × 4 reps
        // Spread participants (one per node) skip the node phase.
        let spread: Vec<usize> = vec![0, 8, 16, 24];
        let ph = lower_collective(&topo, &spread, CollectiveKind::AllReduce, 4, v);
        assert_eq!(ph.len(), 2);
        assert_eq!(ph[0].flows.len(), 4);
        // Ring neighbors one node apart cross the leaf tier.
        for f in &ph[0].flows {
            assert!(topo.path(f.src, f.dst).links.len() == 4, "{f:?}");
        }
    }

    #[test]
    fn alltoall_and_sendrecv_lowering() {
        let c = Cluster::fat_tree_tpuv4(64);
        let topo = LinkGraph::from_cluster(&c);
        let devices: Vec<usize> = (0..8).collect();
        let ph = lower_collective(&topo, &devices, CollectiveKind::AllToAll, 8, 8e8);
        assert_eq!(ph.len(), 1);
        assert_eq!(ph[0].flows.len(), 8 * 7);
        for f in &ph[0].flows {
            assert!((f.bytes - 1e8).abs() < 1.0);
        }
        // SendRecv between adjacent 4-blocks: devices[3] → devices[4].
        let ph = lower_collective(&topo, &devices, CollectiveKind::SendRecv, 4, 1e8);
        assert_eq!(ph.len(), 1);
        assert_eq!(ph[0].flows.len(), 1);
        assert_eq!((ph[0].flows[0].src, ph[0].flows[0].dst), (3, 4));
        // The CP pair exchange (tp=1 → adjacent 1-blocks) must emit a
        // real flow even on a 2-device stage, not degenerate to nothing.
        let pair: Vec<usize> = vec![0, 1];
        let ph = lower_collective(&topo, &pair, CollectiveKind::SendRecv, 1, 1e8);
        assert_eq!(ph.len(), 1);
        assert_eq!((ph[0].flows[0].src, ph[0].flows[0].dst), (0, 1));
    }
}
