//! Seeded background-flow generation — the multi-tenant half of the
//! flow simulator, in the style of parsimon-eval's workload generator.
//!
//! A production fabric is never empty: the training job under study
//! shares links with other tenants' shuffles, checkpoints, and serving
//! traffic. [`generate`] draws a deterministic background *mix* for a
//! topology — flow sizes from an empirical or lognormal distribution,
//! lognormal inter-arrival gaps, a spatial traffic matrix (uniform /
//! rack-skewed / hotspot) — and then rescales every flow's bytes so the
//! *offered* max per-link load over the window equals the requested
//! target exactly (routing is deterministic, so the per-link byte sums
//! are a pure function of the draw). [`inject`] appends the mix to an
//! already-lowered [`Workload`] as independent delay→transfer task
//! pairs, marking where the background suffix starts so the engine can
//! report the training job's own completion time
//! ([`super::fairshare::NetsimReport::train_batch_time`]) and byte
//! totals separately from the background's.
//!
//! Everything here is a pure single-threaded function of `(topo, spec)`
//! — same seed, same flows, bit for bit — and injected mixes ride the
//! normal [`super::Simulation`] paths: the decomposition partition and
//! merge treat background tasks like any others, so Monolithic and
//! Decomposed runs of a mixed workload stay bit-identical at any thread
//! count (the property suite pins this).

use super::fairshare::{FlowSpec, TaskKind, Workload};
use super::topo::LinkGraph;
use crate::obs;
use crate::util::rng::Rng;

/// Background flow-size distribution.
#[derive(Debug, Clone)]
pub enum SizeDist {
    /// `median_bytes · exp(sigma · z)`, `z` standard normal. Heavy
    ///-tailed for `sigma ≳ 1`, the classic datacenter shape. Samples
    /// are floored at 64 bytes (a packet) so no draw is degenerate.
    Lognormal { median_bytes: f64, sigma: f64 },
    /// Discrete `(bytes, weight)` buckets sampled by CDF walk — how
    /// published traces (web search, Hadoop) are usually tabulated.
    /// Weights need not be normalized; they must be positive.
    Empirical { buckets: Vec<(f64, f64)> },
}

impl SizeDist {
    fn sample(&self, rng: &mut Rng) -> f64 {
        match self {
            SizeDist::Lognormal {
                median_bytes,
                sigma,
            } => (median_bytes * (sigma * std_normal(rng)).exp()).max(64.0),
            SizeDist::Empirical { buckets } => {
                assert!(!buckets.is_empty(), "empirical size distribution is empty");
                let total: f64 = buckets.iter().map(|b| b.1).sum();
                assert!(total > 0.0, "empirical size weights must be positive");
                let mut u = rng.gen_f64() * total;
                for &(bytes, w) in buckets {
                    if u < w {
                        return bytes.max(1.0);
                    }
                    u -= w;
                }
                buckets.last().expect("nonempty").0.max(1.0)
            }
        }
    }
}

/// Spatial traffic matrix: how (src, dst) device pairs are drawn.
#[derive(Debug, Clone)]
pub enum SpatialMatrix {
    /// Every ordered pair equally likely.
    Uniform,
    /// With probability `locality` the destination stays inside the
    /// source's rack (contiguous blocks of `rack_size` devices, the
    /// same convention the scale harness uses); otherwise uniform.
    RackSkewed { rack_size: usize, locality: f64 },
    /// With probability `weight` the destination is one of the first
    /// `hotspots` devices (an incast-prone storage/parameter tier);
    /// otherwise uniform.
    Hotspot { hotspots: usize, weight: f64 },
}

impl SpatialMatrix {
    /// Draw one non-degenerate ordered pair on `n` devices.
    fn pick_pair(&self, n: usize, rng: &mut Rng) -> (usize, usize) {
        let src = rng.gen_range(n);
        // `(dst, base, span)`: the drawn destination and the candidate
        // set `[base, base + span)` it came from.
        let (dst, base, span) = match self {
            SpatialMatrix::Uniform => (rng.gen_range(n), 0, n),
            SpatialMatrix::RackSkewed { rack_size, locality } => {
                let rs = (*rack_size).clamp(1, n);
                if rng.gen_bool(*locality) {
                    let base = src / rs * rs;
                    let span = rs.min(n - base);
                    (base + rng.gen_range(span), base, span)
                } else {
                    (rng.gen_range(n), 0, n)
                }
            }
            SpatialMatrix::Hotspot { hotspots, weight } => {
                let h = (*hotspots).clamp(1, n);
                if rng.gen_bool(*weight) {
                    (rng.gen_range(h), 0, h)
                } else {
                    (rng.gen_range(n), 0, n)
                }
            }
        };
        if dst != src {
            return (src, dst);
        }
        // Self-loops never cross the network: nudge to the next device
        // within the drawn candidate set (preserving rack locality /
        // hotspot membership), falling back to the whole device range
        // when the set is the single source device.
        let nudged = base + (dst - base + 1) % span;
        if nudged != src {
            (src, nudged)
        } else {
            (src, (src + 1) % n)
        }
    }
}

/// Full specification of one background mix. The mix is a pure function
/// of `(topo, spec)`; `seed` alone distinguishes replicates.
#[derive(Debug, Clone)]
pub struct MixSpec {
    /// Target max per-link *offered* load: the hottest link's injected
    /// bytes divided by `capacity · duration`. [`generate`] rescales
    /// flow sizes so this is met exactly (up to float rounding).
    pub target_load: f64,
    /// Arrival window in seconds: background flows arrive in
    /// `[0, duration)`. Callers typically pass the training batch time.
    pub duration: f64,
    /// Approximate flow count — sets the median inter-arrival gap to
    /// `duration / flows`; the realized count varies with the draw.
    pub flows: usize,
    /// Lognormal shape of the inter-arrival gaps (0 = evenly spaced,
    /// 1 ≈ bursty open-loop arrivals).
    pub sigma_arrival: f64,
    pub size: SizeDist,
    pub spatial: SpatialMatrix,
    pub seed: u64,
}

impl MixSpec {
    /// A reasonable default mix at `target_load` over `duration`:
    /// 256 uniform flows, heavy-tailed lognormal sizes, bursty
    /// arrivals. The harness and `refine --bg-load` build on this.
    pub fn at_load(target_load: f64, duration: f64, seed: u64) -> Self {
        MixSpec {
            target_load,
            duration,
            flows: 256,
            sigma_arrival: 1.0,
            size: SizeDist::Lognormal {
                median_bytes: 1e6,
                sigma: 1.5,
            },
            spatial: SpatialMatrix::Uniform,
            seed,
        }
    }
}

/// One background flow: `flow` arrives (its transfer becomes eligible)
/// at absolute time `at`.
#[derive(Debug, Clone)]
pub struct BgFlow {
    pub at: f64,
    pub flow: FlowSpec,
}

/// A generated background mix, ready for [`inject`].
#[derive(Debug, Clone)]
pub struct BgMix {
    /// Flows in arrival order (strictly nondecreasing `at`).
    pub flows: Vec<BgFlow>,
    /// The arrival window the mix was scaled against.
    pub duration: f64,
    /// Max per-link offered load after scaling — equals the spec's
    /// `target_load` up to float rounding (0.0 for an empty draw).
    pub offered_max_load: f64,
    /// Byte scale factor applied to hit the target.
    pub scale: f64,
}

impl BgMix {
    /// Total injected background bytes.
    pub fn total_bytes(&self) -> f64 {
        self.flows.iter().map(|f| f.flow.bytes).sum()
    }
}

/// Standard normal via Box–Muller. `1.0 - gen_f64()` keeps the log
/// argument in `(0, 1]` (gen_f64 is `[0, 1)`), so the draw is finite.
fn std_normal(rng: &mut Rng) -> f64 {
    let u1 = 1.0 - rng.gen_f64();
    let u2 = rng.gen_f64();
    (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
}

/// Max per-link *offered* load of `flows` over `duration`: each flow's
/// bytes are charged to every link on its deterministic route, and the
/// hottest link's byte sum is divided by `capacity · duration`.
/// Self-loop flows touch no links. This is the quantity [`generate`]
/// scales to the target — offered, not simulated: fair-share backlog
/// can stretch actual drains past the window at high loads.
pub fn offered_load(topo: &LinkGraph, flows: &[BgFlow], duration: f64) -> f64 {
    if duration <= 0.0 {
        return 0.0;
    }
    let mut per_link = vec![0.0f64; topo.links.len()];
    for bg in flows {
        if bg.flow.src == bg.flow.dst {
            continue;
        }
        for &l in &topo.path(bg.flow.src, bg.flow.dst).links {
            per_link[l] += bg.flow.bytes;
        }
    }
    per_link
        .iter()
        .enumerate()
        .map(|(l, &b)| b / (topo.links[l].capacity * duration))
        .fold(0.0, f64::max)
}

/// Draw the background mix for `topo` under `spec`. Pure and
/// single-threaded: the same `(topo, spec)` always yields bit-identical
/// flows, independent of simulator mode or thread count.
///
/// Sizes and pairs are drawn open-loop until the arrival clock leaves
/// the window, then every flow's bytes are multiplied by one common
/// factor so the max per-link offered load equals `spec.target_load`
/// exactly — per-link sums are linear in the common scale, so the
/// hottest link stays the hottest and lands on the target.
pub fn generate(topo: &LinkGraph, spec: &MixSpec) -> BgMix {
    let _span = obs::span_with("flowgen.generate", "netsim", || {
        vec![
            ("seed", spec.seed.to_string()),
            ("target_load", format!("{:.3}", spec.target_load)),
        ]
    });
    let n = topo.n_devices();
    assert!(n >= 2, "background traffic needs at least two devices");
    assert!(
        spec.target_load >= 0.0 && spec.target_load.is_finite(),
        "target_load must be a finite nonnegative fraction"
    );
    assert!(
        spec.duration > 0.0 && spec.duration.is_finite(),
        "mix duration must be positive"
    );
    let mut rng = Rng::new(spec.seed);
    let median_gap = spec.duration / spec.flows.max(1) as f64;
    let mut flows: Vec<BgFlow> = Vec::new();
    let mut t = 0.0f64;
    if spec.target_load > 0.0 {
        loop {
            t += median_gap * (spec.sigma_arrival * std_normal(&mut rng)).exp();
            if t >= spec.duration {
                break;
            }
            let (src, dst) = spec.spatial.pick_pair(n, &mut rng);
            let bytes = spec.size.sample(&mut rng);
            flows.push(BgFlow {
                at: t,
                flow: FlowSpec { src, dst, bytes },
            });
        }
    }
    let raw = offered_load(topo, &flows, spec.duration);
    let scale = if raw > 0.0 {
        spec.target_load / raw
    } else {
        0.0
    };
    if scale != 1.0 {
        for f in &mut flows {
            f.flow.bytes *= scale;
        }
    }
    let offered_max_load = offered_load(topo, &flows, spec.duration);
    if obs::enabled() {
        obs::count("flowgen.flows", flows.len() as u64);
    }
    BgMix {
        flows,
        duration: spec.duration,
        offered_max_load,
        scale,
    }
}

/// Append `mix` to an already-lowered workload as background tasks:
/// each flow becomes a root `Compute` delay of its arrival time plus a
/// dependent single-flow `Transfer`, so it enters the fair-share
/// contention set exactly at `at`. Marks the training/background task
/// boundary (everything added before this call counts as training in
/// the report); callable once per workload, after all training tasks.
/// Returns the number of background flows injected.
pub fn inject(wl: &mut Workload, mix: &BgMix) -> usize {
    assert_eq!(
        wl.bg_from,
        u32::MAX,
        "a background mix was already injected into this workload"
    );
    wl.bg_from = wl.n_tasks() as u32;
    let mut injected = 0usize;
    for bg in &mix.flows {
        // Sub-half-byte flows (possible after aggressive down-scaling)
        // would be skipped by the engine anyway; don't materialize them.
        if bg.flow.bytes <= 0.5 {
            continue;
        }
        let delay = wl.add(TaskKind::Compute { seconds: bg.at }, &[]);
        wl.add(
            TaskKind::Transfer {
                flows: vec![bg.flow.clone()],
                extra_latency: 0.0,
            },
            &[delay],
        );
        injected += 1;
    }
    if obs::enabled() {
        obs::count("flowgen.injected", injected as u64);
    }
    injected
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::topo;

    fn spec(seed: u64) -> MixSpec {
        MixSpec::at_load(0.4, 1e-2, seed)
    }

    fn assert_mixes_identical(a: &BgMix, b: &BgMix) {
        assert_eq!(a.flows.len(), b.flows.len());
        for (x, y) in a.flows.iter().zip(&b.flows) {
            assert_eq!(x.at.to_bits(), y.at.to_bits());
            assert_eq!(x.flow.src, y.flow.src);
            assert_eq!(x.flow.dst, y.flow.dst);
            assert_eq!(x.flow.bytes.to_bits(), y.flow.bytes.to_bits());
        }
        assert_eq!(a.offered_max_load.to_bits(), b.offered_max_load.to_bits());
    }

    #[test]
    fn same_seed_reproduces_the_mix_bitwise() {
        let t = topo::spineleaf(4, 4, 4.0);
        let a = generate(&t, &spec(7));
        let b = generate(&t, &spec(7));
        assert_mixes_identical(&a, &b);
        assert!(!a.flows.is_empty(), "default spec draws a nonempty mix");
    }

    #[test]
    fn different_seeds_differ() {
        let t = topo::spineleaf(4, 4, 4.0);
        let a = generate(&t, &spec(7));
        let b = generate(&t, &spec(8));
        let same = a.flows.len() == b.flows.len()
            && a.flows.iter().zip(&b.flows).all(|(x, y)| {
                x.flow.src == y.flow.src
                    && x.flow.dst == y.flow.dst
                    && x.flow.bytes.to_bits() == y.flow.bytes.to_bits()
            });
        assert!(!same, "distinct seeds drew identical mixes");
    }

    #[test]
    fn offered_load_hits_the_target_exactly() {
        let t = topo::fattree(4);
        for load in [0.1, 0.35, 0.8] {
            let mix = generate(&t, &MixSpec::at_load(load, 5e-3, 99));
            assert!(!mix.flows.is_empty());
            assert!(
                (mix.offered_max_load - load).abs() <= load * 1e-9,
                "offered {} vs target {load}",
                mix.offered_max_load
            );
        }
    }

    #[test]
    fn zero_load_is_an_empty_mix() {
        let t = topo::spineleaf(2, 4, 2.0);
        let mix = generate(&t, &MixSpec::at_load(0.0, 1e-2, 3));
        assert!(mix.flows.is_empty());
        assert_eq!(mix.offered_max_load, 0.0);
        let mut wl = Workload::new();
        assert_eq!(inject(&mut wl, &mix), 0);
        assert_eq!(wl.n_tasks(), 0);
    }

    #[test]
    fn rack_skew_keeps_traffic_local() {
        let t = topo::spineleaf(4, 8, 4.0);
        let mut s = spec(21);
        s.spatial = SpatialMatrix::RackSkewed {
            rack_size: 8,
            locality: 1.0,
        };
        let mix = generate(&t, &s);
        assert!(!mix.flows.is_empty());
        for f in &mix.flows {
            assert_eq!(
                f.flow.src / 8,
                f.flow.dst / 8,
                "locality=1.0 drew a cross-rack pair"
            );
        }
    }

    #[test]
    fn hotspot_concentrates_destinations() {
        let t = topo::spineleaf(4, 8, 4.0);
        let mut s = spec(22);
        s.flows = 512;
        s.spatial = SpatialMatrix::Hotspot {
            hotspots: 2,
            weight: 0.9,
        };
        let mix = generate(&t, &s);
        let hot = mix.flows.iter().filter(|f| f.flow.dst < 2).count();
        assert!(
            hot * 2 > mix.flows.len(),
            "only {hot}/{} flows hit the hotspot",
            mix.flows.len()
        );
    }

    #[test]
    fn empirical_sizes_come_from_the_buckets() {
        let t = topo::spineleaf(2, 4, 2.0);
        let mut s = spec(5);
        s.size = SizeDist::Empirical {
            buckets: vec![(1e3, 0.5), (1e6, 0.3), (1e8, 0.2)],
        };
        let mix = generate(&t, &s);
        assert!(!mix.flows.is_empty());
        // After common scaling, sizes stay proportional to the buckets:
        // each flow's bytes / scale must be one of the bucket values.
        for f in &mix.flows {
            let raw = f.flow.bytes / mix.scale;
            assert!(
                [1e3, 1e6, 1e8].iter().any(|b| (raw - b).abs() < 1e-3 * b),
                "unscaled size {raw} not in the empirical buckets"
            );
        }
    }

    #[test]
    fn inject_marks_the_background_boundary() {
        let t = topo::spineleaf(2, 4, 2.0);
        let mix = generate(&t, &spec(11));
        let mut wl = Workload::new();
        wl.add(TaskKind::Compute { seconds: 1e-3 }, &[]);
        let before = wl.n_tasks();
        let injected = inject(&mut wl, &mix);
        assert!(injected > 0);
        assert_eq!(wl.bg_from, before as u32);
        assert_eq!(wl.n_tasks(), before + 2 * injected);
    }

    #[test]
    #[should_panic(expected = "already injected")]
    fn double_injection_panics() {
        let t = topo::spineleaf(2, 4, 2.0);
        let mix = generate(&t, &spec(11));
        let mut wl = Workload::new();
        inject(&mut wl, &mix);
        inject(&mut wl, &mix);
    }
}
