//! Flight recorder for the solve → refine → serve pipeline: RAII spans,
//! monotonically-named counters, and log-bucketed latency histograms,
//! exported as Chrome trace-event JSON (`chrome://tracing` / Perfetto).
//!
//! Zero external dependencies (house style: the vendored [`crate::util`]
//! shims only). Three design rules everything here obeys:
//!
//! 1. **The off path is a branch on a cached bool.** Every public entry
//!    point loads one relaxed [`AtomicBool`] and returns — no
//!    allocation, no clock read, no thread-local touch. Hot loops that
//!    cannot afford even a call per iteration (the DP transition scans,
//!    the fair-share event loop) accumulate plain local `u64`s
//!    unconditionally and flush them once per call behind
//!    [`enabled()`].
//! 2. **Outside the determinism boundary.** Tracing observes; it never
//!    steers. Plans, K-best shortlists, and `NetsimReport`s are
//!    bit-identical with tracing on or off, at any `--threads`
//!    (`prop_tracing_is_outside_the_determinism_boundary` in the
//!    property suite is the proof on random scenarios).
//! 3. **Per-thread buffers, merged post-run.** Each thread records into
//!    its own [`ThreadBuf`] (no locks on the hot path); scoped worker
//!    threads flush to a global collector when they exit, and
//!    [`drain`] merges everything in stable thread-index order.
//!
//! Enablement: the `--trace <path>` CLI flag, or the `NEST_TRACE`
//! environment variable (`NEST_TRACE=out.json`; `NEST_TRACE=1` picks
//! the default `nest_trace.json`; `0`/unset leaves tracing off). The
//! CLI flag wins when both are present. `nest obs-summary --trace
//! <file>` renders a human table from an emitted trace.
//!
//! Naming scheme (`layer.noun[.detail]`) — the full glossary lives in
//! README § Observability: spans `solver.solve_topk`, `solver.config`,
//! `cost.build`, `netsim.run`, `netsim.component` (one per
//! link-sharing component in decomposed runs), `refine.refine`,
//! `refine.replay`, `service.query`, `service.fingerprint`; counters
//! `solver.prune.config_bound`, `solver.prune.dp_state`,
//! `solver.prune.final_cut`, `solver.dp_states`,
//! `solver.incumbent.improved`, `netsim.heap.pop`,
//! `netsim.heap.stale_drop`, `netsim.events`, `service.cache_hit`,
//! `service.cache_miss`, `service.warm_neighbor`, `service.evict`;
//! histograms `netsim.dirty_component`, `netsim.link_util_pct`,
//! `netsim.component_flows` (component-size census of each decomposed
//! run), `service.query_us`.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::Instant;

use crate::util::json::{self, Json};
use crate::util::table::Table;

// ---------------------------------------------------------------------
// Enablement + clock
// ---------------------------------------------------------------------

static ENABLED: AtomicBool = AtomicBool::new(false);

/// The cached-bool gate every recording entry point branches on.
/// Relaxed is enough: enablement is set once before the run and read
/// monotonically; a racing reader at worst drops or keeps one event,
/// never corrupts state.
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn the recorder on or off. Pins the process clock anchor on first
/// enable so `ts` values are relative to (just before) the traced run.
pub fn set_enabled(on: bool) {
    if on {
        anchor();
    }
    ENABLED.store(on, Ordering::Relaxed);
}

/// Resolve `NEST_TRACE` to an output path: unset/`0` → off, `1` or
/// empty → the default `nest_trace.json`, anything else → that path.
pub fn env_trace_path() -> Option<String> {
    match std::env::var("NEST_TRACE") {
        Err(_) => None,
        Ok(v) if v == "0" => None,
        Ok(v) if v.is_empty() || v == "1" => Some("nest_trace.json".to_string()),
        Ok(v) => Some(v),
    }
}

fn anchor() -> Instant {
    static ANCHOR: OnceLock<Instant> = OnceLock::new();
    *ANCHOR.get_or_init(Instant::now)
}

/// Nanoseconds since the process trace anchor. Only meaningful while
/// tracing is (or has been) enabled; callers use it to time sections
/// they feed into [`record`] histograms.
pub fn now_ns() -> u64 {
    anchor().elapsed().as_nanos() as u64
}

// ---------------------------------------------------------------------
// Per-thread recorder
// ---------------------------------------------------------------------

/// A completed span: wall interval plus self-time (duration minus the
/// durations of spans nested inside it on the same thread).
#[derive(Debug, Clone)]
struct SpanEv {
    name: &'static str,
    cat: &'static str,
    start_ns: u64,
    dur_ns: u64,
    self_ns: u64,
    args: Vec<(&'static str, String)>,
}

/// A point event (Chrome `ph:"i"`).
#[derive(Debug, Clone)]
struct InstantEv {
    name: &'static str,
    cat: &'static str,
    ts_ns: u64,
    args: Vec<(&'static str, String)>,
}

/// Log₂-bucketed histogram: bucket 0 holds the value 0, bucket `b ≥ 1`
/// holds `[2^(b-1), 2^b)`. 65 buckets cover the full `u64` range, so
/// recording can never overflow into a panic on the hot path.
#[derive(Debug, Clone)]
struct Hist {
    count: u64,
    total: u64,
    buckets: [u64; 65],
}

impl Hist {
    fn new() -> Self {
        Hist {
            count: 0,
            total: 0,
            buckets: [0; 65],
        }
    }

    fn record(&mut self, v: u64) {
        self.count += 1;
        self.total = self.total.saturating_add(v);
        self.buckets[bucket_of(v)] += 1;
    }

    fn merge(&mut self, other: &Hist) {
        self.count += other.count;
        self.total = self.total.saturating_add(other.total);
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
    }

    /// Lower bound of the bucket containing the q-quantile (0 < q ≤ 1).
    fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((self.count as f64) * q).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (b, &n) in self.buckets.iter().enumerate() {
            cum += n;
            if cum >= target {
                return bucket_lo(b);
            }
        }
        bucket_lo(64)
    }
}

fn bucket_of(v: u64) -> usize {
    (64 - v.leading_zeros()) as usize
}

fn bucket_lo(b: usize) -> u64 {
    if b == 0 {
        0
    } else {
        1u64 << (b - 1)
    }
}

/// One thread's recording buffer. `stack` carries, per open span, the
/// summed duration of its already-closed children — how self-time is
/// computed without any global state.
#[derive(Debug)]
struct ThreadBuf {
    index: usize,
    spans: Vec<SpanEv>,
    instants: Vec<InstantEv>,
    counters: BTreeMap<&'static str, u64>,
    hists: BTreeMap<&'static str, Hist>,
    stack: Vec<u64>,
}

impl ThreadBuf {
    fn with_index(index: usize) -> Self {
        ThreadBuf {
            index,
            spans: Vec::new(),
            instants: Vec::new(),
            counters: BTreeMap::new(),
            hists: BTreeMap::new(),
            stack: Vec::new(),
        }
    }

    fn fresh() -> Self {
        static NEXT_TID: AtomicUsize = AtomicUsize::new(0);
        Self::with_index(NEXT_TID.fetch_add(1, Ordering::Relaxed))
    }

    fn is_empty(&self) -> bool {
        self.spans.is_empty()
            && self.instants.is_empty()
            && self.counters.is_empty()
            && self.hists.is_empty()
    }
}

/// Buffers flushed by exiting threads (the solver's scoped workers all
/// exit before `solve_topk` returns, so a post-run [`drain`] sees every
/// worker's events here).
static COLLECTOR: Mutex<Vec<ThreadBuf>> = Mutex::new(Vec::new());

fn collector() -> MutexGuard<'static, Vec<ThreadBuf>> {
    COLLECTOR.lock().unwrap_or_else(|e| e.into_inner())
}

/// TLS wrapper whose `Drop` flushes the thread's buffer into the global
/// collector when the thread exits.
struct Holder(RefCell<ThreadBuf>);

impl Drop for Holder {
    fn drop(&mut self) {
        let buf = self.0.get_mut();
        if !buf.is_empty() {
            let idx = buf.index;
            let taken = std::mem::replace(buf, ThreadBuf::with_index(idx));
            collector().push(taken);
        }
    }
}

thread_local! {
    static HOLDER: Holder = Holder(RefCell::new(ThreadBuf::fresh()));
}

fn with_buf<R>(f: impl FnOnce(&mut ThreadBuf) -> R) -> R {
    HOLDER.with(|h| f(&mut h.0.borrow_mut()))
}

// ---------------------------------------------------------------------
// Recording API
// ---------------------------------------------------------------------

/// RAII span guard. `None` when tracing is off — constructing and
/// dropping the disabled guard touches nothing (no clock, no TLS).
pub struct Span(Option<OpenSpan>);

struct OpenSpan {
    name: &'static str,
    cat: &'static str,
    start_ns: u64,
    args: Vec<(&'static str, String)>,
}

/// Open a span; it closes (and records) when the guard drops.
#[inline]
pub fn span(name: &'static str, cat: &'static str) -> Span {
    if !enabled() {
        return Span(None);
    }
    open_span(name, cat, Vec::new())
}

/// [`span`] with key/value args rendered into the trace. The closure
/// runs only when tracing is on, so arg formatting costs nothing on the
/// off path.
#[inline]
pub fn span_with(
    name: &'static str,
    cat: &'static str,
    args: impl FnOnce() -> Vec<(&'static str, String)>,
) -> Span {
    if !enabled() {
        return Span(None);
    }
    open_span(name, cat, args())
}

fn open_span(name: &'static str, cat: &'static str, args: Vec<(&'static str, String)>) -> Span {
    with_buf(|b| b.stack.push(0));
    Span(Some(OpenSpan {
        name,
        cat,
        start_ns: now_ns(),
        args,
    }))
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(o) = self.0.take() {
            let dur = now_ns().saturating_sub(o.start_ns);
            with_buf(|b| {
                let child = b.stack.pop().unwrap_or(0);
                if let Some(top) = b.stack.last_mut() {
                    *top += dur;
                }
                b.spans.push(SpanEv {
                    name: o.name,
                    cat: o.cat,
                    start_ns: o.start_ns,
                    dur_ns: dur,
                    self_ns: dur.saturating_sub(child),
                    args: o.args,
                });
            });
        }
    }
}

/// Bump a named counter by `delta`.
#[inline]
pub fn count(name: &'static str, delta: u64) {
    if !enabled() || delta == 0 {
        return;
    }
    with_buf(|b| *b.counters.entry(name).or_insert(0) += delta);
}

/// Record one sample into a log-bucketed histogram.
#[inline]
pub fn record(name: &'static str, value: u64) {
    if !enabled() {
        return;
    }
    with_buf(|b| b.hists.entry(name).or_insert_with(Hist::new).record(value));
}

/// Emit a point event (Chrome instant). Args closure runs only when
/// tracing is on.
#[inline]
pub fn instant(
    name: &'static str,
    cat: &'static str,
    args: impl FnOnce() -> Vec<(&'static str, String)>,
) {
    if !enabled() {
        return;
    }
    let ev = InstantEv {
        name,
        cat,
        ts_ns: now_ns(),
        args: args(),
    };
    with_buf(|b| b.instants.push(ev));
}

// ---------------------------------------------------------------------
// Draining + Chrome export
// ---------------------------------------------------------------------

/// Everything recorded since the last drain, one entry per thread
/// buffer, sorted by stable thread index.
pub struct TraceData {
    threads: Vec<ThreadBuf>,
}

impl TraceData {
    pub fn is_empty(&self) -> bool {
        self.threads.iter().all(|t| t.is_empty())
    }

    pub fn n_spans(&self) -> usize {
        self.threads.iter().map(|t| t.spans.len()).sum()
    }

    /// Merged view of a counter across all thread buffers.
    pub fn counter(&self, name: &str) -> u64 {
        self.threads
            .iter()
            .filter_map(|t| t.counters.get(name))
            .sum()
    }
}

/// Take every buffered event: the calling thread's live buffer (its TLS
/// destructor only runs at thread exit) plus everything exited threads
/// already flushed. Call between runs, not inside an open span — an
/// open span's child-time accounting does not survive the drain.
pub fn drain() -> TraceData {
    with_buf(|b| {
        if !b.is_empty() {
            let idx = b.index;
            let taken = std::mem::replace(b, ThreadBuf::with_index(idx));
            collector().push(taken);
        }
    });
    let mut threads: Vec<ThreadBuf> = std::mem::take(&mut *collector());
    threads.sort_by_key(|b| b.index);
    TraceData { threads }
}

fn args_json(args: &[(&'static str, String)], extra: Vec<(&str, Json)>) -> Json {
    let mut pairs: Vec<(&str, Json)> = args
        .iter()
        .map(|(k, v)| (*k, Json::str(v.clone())))
        .collect();
    pairs.extend(extra);
    Json::obj(pairs)
}

/// Render drained data as Chrome trace-event JSON: spans as complete
/// (`ph:"X"`) events, instants as `ph:"i"`, one `thread_name` metadata
/// record per buffer, and the merged counters/histograms under a
/// `"nest"` top-level key (unknown top-level keys are ignored by the
/// trace viewers).
pub fn to_chrome_json(data: &TraceData) -> Json {
    let mut events: Vec<Json> = Vec::new();
    for b in &data.threads {
        let tid = Json::num(b.index as f64);
        events.push(Json::obj(vec![
            ("ph", Json::str("M")),
            ("name", Json::str("thread_name")),
            ("pid", Json::num(0.0)),
            ("tid", tid.clone()),
            (
                "args",
                Json::obj(vec![("name", Json::str(format!("nest-{}", b.index)))]),
            ),
        ]));
        for s in &b.spans {
            events.push(Json::obj(vec![
                ("ph", Json::str("X")),
                ("name", Json::str(s.name)),
                ("cat", Json::str(s.cat)),
                ("ts", Json::num(s.start_ns as f64 / 1e3)),
                ("dur", Json::num(s.dur_ns as f64 / 1e3)),
                ("pid", Json::num(0.0)),
                ("tid", tid.clone()),
                (
                    "args",
                    args_json(
                        &s.args,
                        vec![("self_us", Json::num(s.self_ns as f64 / 1e3))],
                    ),
                ),
            ]));
        }
        for i in &b.instants {
            events.push(Json::obj(vec![
                ("ph", Json::str("i")),
                ("s", Json::str("t")),
                ("name", Json::str(i.name)),
                ("cat", Json::str(i.cat)),
                ("ts", Json::num(i.ts_ns as f64 / 1e3)),
                ("pid", Json::num(0.0)),
                ("tid", tid.clone()),
                ("args", args_json(&i.args, Vec::new())),
            ]));
        }
    }

    let mut counters: BTreeMap<&'static str, u64> = BTreeMap::new();
    let mut hists: BTreeMap<&'static str, Hist> = BTreeMap::new();
    for b in &data.threads {
        for (k, v) in &b.counters {
            *counters.entry(k).or_insert(0) += v;
        }
        for (k, h) in &b.hists {
            hists.entry(k).or_insert_with(Hist::new).merge(h);
        }
    }
    let counters_json = Json::obj(
        counters
            .iter()
            .map(|(k, v)| (*k, Json::num(*v as f64)))
            .collect(),
    );
    let hists_json = Json::obj(
        hists
            .iter()
            .map(|(k, h)| {
                let buckets: Vec<Json> = h
                    .buckets
                    .iter()
                    .enumerate()
                    .filter(|(_, &n)| n > 0)
                    .map(|(b, &n)| {
                        Json::arr(vec![Json::num(bucket_lo(b) as f64), Json::num(n as f64)])
                    })
                    .collect();
                (
                    *k,
                    Json::obj(vec![
                        ("count", Json::num(h.count as f64)),
                        ("total", Json::num(h.total as f64)),
                        ("p50", Json::num(h.quantile(0.50) as f64)),
                        ("p99", Json::num(h.quantile(0.99) as f64)),
                        ("buckets", Json::arr(buckets)),
                    ]),
                )
            })
            .collect(),
    );

    Json::obj(vec![
        ("traceEvents", Json::arr(events)),
        ("displayTimeUnit", Json::str("ms")),
        (
            "nest",
            Json::obj(vec![
                ("schema", Json::str("nest-trace-v1")),
                ("counters", counters_json),
                ("histograms", hists_json),
            ]),
        ),
    ])
}

/// Drain and write the Chrome trace to `path`. Returns the number of
/// span events written.
pub fn write_trace(path: &str) -> std::io::Result<usize> {
    let data = drain();
    let n = data.n_spans();
    std::fs::write(path, json::to_pretty(&to_chrome_json(&data)))?;
    Ok(n)
}

// ---------------------------------------------------------------------
// Human summary (`nest obs-summary`)
// ---------------------------------------------------------------------

fn fmt_us(us: f64) -> String {
    crate::util::table::fmt_time(us / 1e6)
}

/// Render the `obs-summary` tables from a parsed trace file: top spans
/// by self-time, counters (with prune-site shares and the service cache
/// hit ratio), and histogram quantiles.
pub fn summary_from_json(v: &Json) -> Result<String, String> {
    let events = v
        .get("traceEvents")
        .as_arr()
        .ok_or("trace has no traceEvents array")?;

    struct Agg {
        calls: u64,
        total_us: f64,
        self_us: f64,
    }
    let mut spans: BTreeMap<String, Agg> = BTreeMap::new();
    for e in events {
        if e.get("ph").as_str() != Some("X") {
            continue;
        }
        let name = e.get("name").as_str().unwrap_or("?").to_string();
        let dur = e.get("dur").as_f64().unwrap_or(0.0);
        let self_us = e.get("args").get("self_us").as_f64().unwrap_or(dur);
        let a = spans.entry(name).or_insert(Agg {
            calls: 0,
            total_us: 0.0,
            self_us: 0.0,
        });
        a.calls += 1;
        a.total_us += dur;
        a.self_us += self_us;
    }

    let mut out = String::new();
    let mut ranked: Vec<(&String, &Agg)> = spans.iter().collect();
    ranked.sort_by(|a, b| b.1.self_us.total_cmp(&a.1.self_us));
    let self_sum: f64 = ranked.iter().map(|(_, a)| a.self_us).sum();
    let mut t = Table::new(&["span", "calls", "total", "self", "self%"]);
    for (name, a) in ranked.iter().take(12) {
        t.row(vec![
            (*name).clone(),
            a.calls.to_string(),
            fmt_us(a.total_us),
            fmt_us(a.self_us),
            if self_sum > 0.0 {
                format!("{:5.1}", 100.0 * a.self_us / self_sum)
            } else {
                "-".to_string()
            },
        ]);
    }
    out.push_str("== top spans by self-time ==\n");
    out.push_str(&t.render());

    let nest = v.get("nest");
    if let Some(counters) = nest.get("counters").as_obj() {
        let mut t = Table::new(&["counter", "value"]);
        for (k, val) in counters {
            t.row(vec![k.clone(), format!("{}", val.as_u64().unwrap_or(0))]);
        }
        out.push_str("\n== counters ==\n");
        out.push_str(&t.render());

        let get = |k: &str| counters.get(k).and_then(|v| v.as_u64()).unwrap_or(0);
        let states = get("solver.dp_states");
        let prunes = [
            ("config bound", get("solver.prune.config_bound")),
            ("dp state bound", get("solver.prune.dp_state")),
            ("final cut scan", get("solver.prune.final_cut")),
        ];
        if prunes.iter().any(|(_, n)| *n > 0) || states > 0 {
            out.push_str("\n== prune-site effectiveness ==\n");
            let mut t = Table::new(&["site", "hits", "per dp state"]);
            for (site, n) in prunes {
                t.row(vec![
                    site.to_string(),
                    n.to_string(),
                    if states > 0 {
                        format!("{:.3}", n as f64 / states as f64)
                    } else {
                        "-".to_string()
                    },
                ]);
            }
            t.row(vec!["dp states".to_string(), states.to_string(), "1.000".to_string()]);
            out.push_str(&t.render());
        }

        let (hit, miss) = (get("service.cache_hit"), get("service.cache_miss"));
        if hit + miss > 0 {
            out.push_str(&format!(
                "\ncache hit ratio: {}/{} ({:.1}%), warm-neighbor starts: {}, evictions: {}\n",
                hit,
                hit + miss,
                100.0 * hit as f64 / (hit + miss) as f64,
                get("service.warm_neighbor"),
                get("service.evict"),
            ));
        }
    }

    if let Some(hists) = nest.get("histograms").as_obj() {
        if !hists.is_empty() {
            let mut t = Table::new(&["histogram", "samples", "p50≥", "p99≥"]);
            for (k, h) in hists {
                t.row(vec![
                    k.clone(),
                    format!("{}", h.get("count").as_u64().unwrap_or(0)),
                    format!("{}", h.get("p50").as_u64().unwrap_or(0)),
                    format!("{}", h.get("p99").as_u64().unwrap_or(0)),
                ]);
            }
            out.push_str("\n== histograms (log₂ bucket lower bounds) ==\n");
            out.push_str(&t.render());
        }
    }

    Ok(out)
}

// ---------------------------------------------------------------------
// Test support
// ---------------------------------------------------------------------

/// Serialize tests that toggle the global recorder: the enable flag and
/// the collector are process-wide, so tests that turn tracing on take
/// this lock, drain on entry (discarding other tests' leftovers), and
/// disable + drain before releasing it.
pub fn exclusive() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_bucketing_boundaries_and_quantiles() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(u64::MAX), 64);
        for b in 1..64 {
            // Each bucket's lower bound maps back into the bucket.
            assert_eq!(bucket_of(bucket_lo(b)), b, "bucket {b}");
            assert_eq!(bucket_of(bucket_lo(b + 1) - 1), b, "bucket {b} upper");
        }

        let mut h = Hist::new();
        assert_eq!(h.quantile(0.5), 0);
        for v in [0u64, 1, 1, 2, 3, 100, 100_000] {
            h.record(v);
        }
        assert_eq!(h.count, 7);
        assert_eq!(h.total, 100_107);
        // 7 samples: p50 target = 4th sample = value 2 → bucket lo 2.
        assert_eq!(h.quantile(0.5), 2);
        // p99 target = 7th sample = 100_000 → bucket [65536, 131072).
        assert_eq!(h.quantile(0.99), 65_536);

        let mut other = Hist::new();
        other.record(1);
        h.merge(&other);
        assert_eq!(h.count, 8);
        assert_eq!(h.buckets[1], 3);
    }

    #[test]
    fn disabled_recorder_is_a_noop() {
        let _g = exclusive();
        set_enabled(false);
        let _ = drain();
        {
            let _s = span("test.noop", "test");
            count("test.noop_counter", 3);
            record("test.noop_hist", 7);
            instant("test.noop_instant", "test", Vec::new);
        }
        let data = drain();
        assert!(data.is_empty(), "disabled recorder buffered events");
    }

    #[test]
    fn span_nesting_self_time_and_scoped_worker_merge() {
        let _g = exclusive();
        set_enabled(true);
        let _ = drain();

        {
            let _outer = span("test.outer", "test");
            {
                let _inner = span_with("test.inner", "test", || {
                    vec![("k", "v".to_string())]
                });
                count("test.work", 1);
            }
            std::thread::scope(|s| {
                for _ in 0..2 {
                    s.spawn(|| {
                        let _w = span("test.worker", "test");
                        count("test.work", 10);
                        record("test.hist", 5);
                    });
                }
            });
        }

        set_enabled(false);
        let data = drain();
        // Main-thread buffer + one per scoped worker, merged post-exit.
        assert!(data.threads.len() >= 3, "worker buffers not collected");
        assert_eq!(data.counter("test.work"), 21);

        let find = |name: &str| -> Vec<&SpanEv> {
            data.threads
                .iter()
                .flat_map(|t| t.spans.iter())
                .filter(|s| s.name == name)
                .collect()
        };
        let outer = find("test.outer");
        let inner = find("test.inner");
        assert_eq!(outer.len(), 1);
        assert_eq!(inner.len(), 1);
        assert_eq!(find("test.worker").len(), 2);
        // Self-time: outer excludes exactly its same-thread child. The
        // worker spans ran on other threads and must not be subtracted.
        assert_eq!(
            outer[0].self_ns,
            outer[0].dur_ns - inner[0].dur_ns,
            "self-time accounting"
        );
        assert!(inner[0].start_ns >= outer[0].start_ns);
        assert_eq!(inner[0].args, vec![("k", "v".to_string())]);
    }

    #[test]
    fn chrome_trace_json_is_well_formed_and_reparses() {
        let _g = exclusive();
        set_enabled(true);
        let _ = drain();
        {
            let _s = span("test.span", "test");
            count("test.counter", 4);
            record("test.hist", 1024);
            instant("test.instant", "test", || vec![("why", "because".into())]);
        }
        set_enabled(false);
        let data = drain();
        let rendered = json::to_pretty(&to_chrome_json(&data));
        let back = json::parse(&rendered).expect("trace JSON reparses");

        let events = back.get("traceEvents").as_arr().expect("traceEvents array");
        assert!(!events.is_empty());
        let mut saw_span = false;
        for e in events {
            let ph = e.get("ph").as_str().expect("every event has ph");
            assert!(e.get("name").as_str().is_some());
            if ph == "X" {
                saw_span = true;
                assert!(e.get("ts").as_f64().is_some());
                assert!(e.get("dur").as_f64().unwrap() >= 0.0);
                assert!(e.get("args").get("self_us").as_f64().is_some());
            }
        }
        assert!(saw_span);
        assert_eq!(
            back.get("nest").get("counters").get("test.counter").as_u64(),
            Some(4)
        );
        let h = back.get("nest").get("histograms").get("test.hist");
        assert_eq!(h.get("count").as_u64(), Some(1));
        assert_eq!(h.get("p50").as_u64(), Some(1024));

        // The summary renderer accepts its own output format.
        let summary = summary_from_json(&back).expect("summary renders");
        assert!(summary.contains("test.span"));
        assert!(summary.contains("test.counter"));
        assert!(summary.contains("test.hist"));
    }

    #[test]
    fn env_trace_path_resolution() {
        let _g = exclusive();
        std::env::remove_var("NEST_TRACE");
        assert_eq!(env_trace_path(), None);
        std::env::set_var("NEST_TRACE", "0");
        assert_eq!(env_trace_path(), None);
        std::env::set_var("NEST_TRACE", "1");
        assert_eq!(env_trace_path(), Some("nest_trace.json".to_string()));
        std::env::set_var("NEST_TRACE", "custom.json");
        assert_eq!(env_trace_path(), Some("custom.json".to_string()));
        std::env::remove_var("NEST_TRACE");
    }
}
