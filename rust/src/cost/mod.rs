//! The unified compute–network–memory cost model: the DP's `load(·)` term
//! (§4 "Unified Cost Model and Recurrence").
//!
//! [`CostModel`] pre-characterizes one (graph, cluster, SUB-GRAPH config)
//! triple: per-layer forward+backward compute time, intra-stage collective
//! time (TP/SP/EP/CP traffic at the group's locality), sharded parameter
//! counts, and activation footprints — all as prefix sums so any
//! contiguous stage `[i, j)` is costed in O(1) inside the DP's inner loop.
//! This mirrors the paper's offline SUB-GRAPH profiling (§3.1): local
//! strategies are characterized once and composed analytically during
//! placement.

use crate::graph::subgraph::{layer_collectives, SgConfig};
use crate::graph::LayerGraph;
use crate::hw::{Accelerator, ClassMask};
use crate::memory::{self, MemSpec, ZeroStage};
use crate::network::Cluster;

/// Pre-computed per-layer costs with prefix sums for O(1) range queries.
///
/// Compute prefixes are kept **per accelerator class** of the cluster's
/// [`crate::hw::DevicePool`]: a stage placed on a device range covering
/// classes `mask` runs TP/DP lockstep, so its compute time is the *max*
/// over the covered classes ([`CostModel::stage_load_on`] and friends).
/// The mask-free methods price against the pool-wide worst case (every
/// class), which on homogeneous clusters — a single class — is exactly
/// the old behavior.
#[derive(Debug, Clone)]
pub struct CostModel {
    pub sg: SgConfig,
    /// Devices per stage replica (= sg.group_size()).
    pub group: usize,
    /// Communication level at which a compact group of `group` devices
    /// lives; SUB-GRAPH collectives price at this locality.
    pub group_level: usize,
    n_layers: usize,
    /// Per accelerator class `c` (pool class order):
    /// `fwd_compute[c][i]` = Σ_{k<i} fwd compute seconds of layer k on
    /// class `c` (per microbatch, per device). Backward is 2×;
    /// recompute adds another 1×.
    fwd_compute: Vec<Vec<f64>>,
    /// Mask with every pool class set.
    full_mask: ClassMask,
    /// prefix of per-layer fwd+bwd collective seconds.
    collective: Vec<f64>,
    /// prefix of per-device sharded param counts.
    params_sharded: Vec<f64>,
    /// prefix of activation stash bytes (no recompute / recompute).
    act_plain: Vec<f64>,
    act_rc: Vec<f64>,
    /// per-layer boundary bytes (activation crossing layer k → k+1).
    boundary: Vec<f64>,
    /// ZeRO-3 weight all-gather cost model at the replica-adjacent
    /// locality: `z3_alpha + bytes · z3_beta` (latency + bandwidth terms
    /// kept separate so large payloads don't multiply the α term).
    z3_alpha: f64,
    z3_beta: f64,
    pub tokens: f64,
}

impl CostModel {
    pub fn new(graph: &LayerGraph, cluster: &Cluster, sg: SgConfig) -> Self {
        let n = graph.n_layers();
        let classes = cluster.pool.classes();
        let group = sg.group_size();
        let group_level = cluster.level_of_group(group);
        let tokens = graph.tokens;

        let mut fwd_compute: Vec<Vec<f64>> = classes.iter().map(|_| vec![0.0; n + 1]).collect();
        let mut collective = vec![0.0; n + 1];
        let mut params_sharded = vec![0.0; n + 1];
        let mut act_plain = vec![0.0; n + 1];
        let mut act_rc = vec![0.0; n + 1];
        let mut boundary = vec![0.0; n];

        for (k, layer) in graph.layers.iter().enumerate() {
            for (c, accel) in classes.iter().enumerate() {
                fwd_compute[c][k + 1] =
                    fwd_compute[c][k] + layer_fwd_time(layer, tokens, &sg, accel);
            }
            let coll: f64 = layer_collectives(layer, tokens, &sg)
                .iter()
                .map(|c| cluster.collective_time(c))
                .sum();
            collective[k + 1] = collective[k] + coll;
            params_sharded[k + 1] = params_sharded[k] + layer.param_count_sharded(&sg);
            act_plain[k + 1] = act_plain[k] + layer.act_stash_bytes(tokens, &sg, false);
            act_rc[k + 1] = act_rc[k] + layer.act_stash_bytes(tokens, &sg, true);
            boundary[k] = layer.boundary_bytes(tokens, &sg);
        }

        // ZeRO-3 param all-gather: the sharding group is the z nearest
        // data-parallel replicas; we price it as a gather over a group of
        // size z placed one pipeline-replica stride apart. The stride is
        // unknown during the DP (it depends on the final stage count), so
        // we use the compact-adjacent approximation — identical for all
        // candidate cuts, hence ranking-preserving (DESIGN.md §4).
        let z3_shape = cluster.compact_shape(group * 2);
        let z3_alpha = cluster.allgather(0.0, &z3_shape);
        let z3_beta = cluster.allgather(1e9, &z3_shape) / 1e9 - z3_alpha / 1e9;

        CostModel {
            sg,
            group,
            group_level,
            n_layers: n,
            fwd_compute,
            full_mask: cluster.pool.full_mask(),
            collective,
            params_sharded,
            act_plain,
            act_rc,
            boundary,
            z3_alpha,
            z3_beta,
            tokens,
        }
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    /// Lockstep forward-compute seconds of layers `[i, j)` on a device
    /// group covering `mask`: the slowest covered class sets the pace.
    #[inline]
    fn fwd_range_on(&self, mask: ClassMask, i: usize, j: usize) -> f64 {
        let mut m = mask & self.full_mask;
        debug_assert!(m != 0, "empty accelerator-class mask");
        let mut worst = 0.0f64;
        while m != 0 {
            let c = m.trailing_zeros() as usize;
            m &= m - 1;
            let v = self.fwd_compute[c][j] - self.fwd_compute[c][i];
            if v > worst {
                worst = v;
            }
        }
        worst
    }

    /// Fastest-class forward compute of `[i, j)` — a valid lower bound
    /// for *any* placement of the stage (config-level pruning).
    #[inline]
    fn fwd_range_best(&self, i: usize, j: usize) -> f64 {
        let mut best = f64::INFINITY;
        for pfx in &self.fwd_compute {
            best = best.min(pfx[j] - pfx[i]);
        }
        best
    }

    /// Per-device sharded parameter count of stage `[i, j)`.
    pub fn stage_params(&self, i: usize, j: usize) -> f64 {
        self.params_sharded[j] - self.params_sharded[i]
    }

    /// Activation stash bytes of one microbatch for stage `[i, j)`.
    pub fn stage_act_bytes(&self, i: usize, j: usize, recompute: bool) -> f64 {
        if recompute {
            self.act_rc[j] - self.act_rc[i]
        } else {
            self.act_plain[j] - self.act_plain[i]
        }
    }

    /// Peak memory of stage `[i, j)` under `spec` with `stash` extra
    /// in-flight microbatches (Eq. 1 via prefix sums).
    pub fn stage_peak_bytes(&self, i: usize, j: usize, spec: &MemSpec, stash: usize) -> f64 {
        let p = self.stage_params(i, j);
        let z = spec.zero.degree() as f64;
        let static_bytes = match spec.zero {
            ZeroStage::None => p * 16.0,
            ZeroStage::Z1 { .. } => p * (4.0 + 12.0 / z),
            ZeroStage::Z2 { .. } => p * (2.0 + 14.0 / z),
            ZeroStage::Z3 { .. } => p * 16.0 / z,
        };
        let act = self.stage_act_bytes(i, j, spec.recompute);
        // Transient working set under recompute: the largest single
        // layer's full activations (re-materialized during backward).
        let working = if spec.recompute {
            let mut w: f64 = 0.0;
            for k in i..j {
                w = w.max(self.act_plain[k + 1] - self.act_plain[k]);
            }
            w
        } else {
            0.0
        };
        static_bytes + act * (1.0 + stash as f64) + working
    }

    /// Pick the minimal memory spec for stage `[i, j)` that fits
    /// `capacity`, escalating recompute → ZeRO-1/2/3 exactly as
    /// `memory::choose_spec` but on the O(1) prefix path.
    pub fn stage_choose_spec(
        &self,
        i: usize,
        j: usize,
        stash: usize,
        capacity: f64,
        max_degree: usize,
        recompute: bool,
    ) -> Option<MemSpec> {
        // Allocation-free escalation (this runs once per DP transition —
        // ~10⁷ times per solve; see EXPERIMENTS.md §Perf). Memory terms
        // are assembled inline from the prefix sums rather than through
        // a candidate Vec.
        let p = self.stage_params(i, j);
        let act = self.stage_act_bytes(i, j, recompute) * (1.0 + stash as f64);
        let working = if recompute {
            let mut w: f64 = 0.0;
            for k in i..j {
                w = w.max(self.act_plain[k + 1] - self.act_plain[k]);
            }
            w
        } else {
            0.0
        };
        let dynamic = act + working;

        let fits = |static_bytes: f64| static_bytes + dynamic <= capacity;
        if fits(p * 16.0) {
            return Some(MemSpec {
                zero: ZeroStage::None,
                recompute,
            });
        }
        for kind in 0..3u8 {
            let mut z = 2usize;
            while z <= max_degree {
                let zf = z as f64;
                let (zero, static_bytes) = match kind {
                    0 => (ZeroStage::Z1 { degree: z }, p * (4.0 + 12.0 / zf)),
                    1 => (ZeroStage::Z2 { degree: z }, p * (2.0 + 14.0 / zf)),
                    _ => (ZeroStage::Z3 { degree: z }, p * 16.0 / zf),
                };
                if fits(static_bytes) {
                    return Some(MemSpec { zero, recompute });
                }
                z *= 2;
            }
        }
        None
    }

    /// The DP's `load_l^{sg}(D \ D', a, s)`: per-microbatch latency of
    /// stage `[i, j)` given the forward producer at level `recv_level`
    /// and the consumer at level `send_level` (§4):
    ///
    /// * compute: fwd + 2×bwd (+1× fwd again under recomputation),
    /// * SUB-GRAPH collectives at the group's locality,
    /// * pipeline p2p: activation fwd + gradient bwd at each boundary,
    /// * ZeRO-3 weight all-gathers when the memory spec demands them.
    pub fn stage_load(
        &self,
        i: usize,
        j: usize,
        recv_level: Option<usize>,
        send_level: Option<usize>,
        spec: &MemSpec,
        cluster: &Cluster,
    ) -> f64 {
        self.stage_load_on(self.full_mask, i, j, recv_level, send_level, spec, cluster)
    }

    /// [`Self::stage_load`] for a stage whose lockstep device group
    /// covers accelerator classes `mask` (the solver passes the classes
    /// of the block the stage actually occupies, replicas included).
    #[allow(clippy::too_many_arguments)]
    pub fn stage_load_on(
        &self,
        mask: ClassMask,
        i: usize,
        j: usize,
        recv_level: Option<usize>,
        send_level: Option<usize>,
        spec: &MemSpec,
        cluster: &Cluster,
    ) -> f64 {
        debug_assert!(i < j && j <= self.n_layers);
        let fwd = self.fwd_range_on(mask, i, j);
        let compute_mult = if spec.recompute { 4.0 } else { 3.0 };
        let mut t = fwd * compute_mult;
        t += self.collective[j] - self.collective[i];
        if let ZeroStage::Z3 { .. } = spec.zero {
            // All-gather full (unsharded-on-z) weights once per microbatch
            // for fwd and once for bwd.
            let weight_bytes = self.stage_params(i, j) * memory::WEIGHT_BYTES;
            t += 2.0 * (self.z3_alpha + weight_bytes * self.z3_beta);
        }
        if let Some(l) = recv_level {
            // Activation in (fwd) + gradient out (bwd) across the
            // producer boundary.
            let b = self.boundary[i.saturating_sub(1).min(self.n_layers - 1)];
            t += 2.0 * cluster.p2p_time(l, b);
        }
        if let Some(l) = send_level {
            let b = self.boundary[j - 1];
            t += 2.0 * cluster.p2p_time(l, b);
        }
        t
    }

    /// Cheap lower bound on `stage_load` for `[i, j)`: pure forward+
    /// backward compute, no communication. Strictly increasing in `j` —
    /// the DP uses it for exact cut pruning. The mask-free form prices
    /// the pool-wide worst case; use [`Self::stage_load_lb_on`] when the
    /// stage's block is known and [`Self::stage_load_lb_best`] when it
    /// is not (placement-independent bound).
    #[inline]
    pub fn stage_load_lb(&self, i: usize, j: usize) -> f64 {
        self.stage_load_lb_on(self.full_mask, i, j)
    }

    /// Lower bound on [`Self::stage_load_on`] for a known class mask.
    #[inline]
    pub fn stage_load_lb_on(&self, mask: ClassMask, i: usize, j: usize) -> f64 {
        self.fwd_range_on(mask, i, j) * 3.0
    }

    /// Placement-independent lower bound: even on the pool's fastest
    /// class the stage cannot run faster than this.
    #[inline]
    pub fn stage_load_lb_best(&self, i: usize, j: usize) -> f64 {
        self.fwd_range_best(i, j) * 3.0
    }

    /// Gradient-sync bytes for stage `[i, j)` (bf16 grads).
    pub fn stage_grad_bytes(&self, i: usize, j: usize) -> f64 {
        self.stage_params(i, j) * memory::GRAD_BYTES
    }

    /// Split the stage's per-microbatch occupancy into forward and
    /// backward phases for the discrete-event simulator. Collectives and
    /// ZeRO-3 gathers split evenly; the recomputation re-forward lands in
    /// the backward phase (where 1F1B executes it). Excludes pipeline p2p
    /// — the simulator models transfers as dependency edges.
    pub fn stage_phase_times(
        &self,
        i: usize,
        j: usize,
        spec: &MemSpec,
        cluster: &Cluster,
    ) -> (f64, f64) {
        self.stage_phase_times_on(self.full_mask, i, j, spec, cluster)
    }

    /// [`Self::stage_phase_times`] on a known lockstep class mask.
    pub fn stage_phase_times_on(
        &self,
        mask: ClassMask,
        i: usize,
        j: usize,
        spec: &MemSpec,
        cluster: &Cluster,
    ) -> (f64, f64) {
        let fwd_compute = self.fwd_range_on(mask, i, j);
        let coll = self.collective[j] - self.collective[i];
        let z3 = if let ZeroStage::Z3 { .. } = spec.zero {
            let wb = self.stage_params(i, j) * memory::WEIGHT_BYTES;
            2.0 * (self.z3_alpha + wb * self.z3_beta)
        } else {
            0.0
        };
        let _ = cluster;
        let fwd = fwd_compute + coll / 2.0 + z3 / 2.0;
        let bwd_mult = if spec.recompute { 3.0 } else { 2.0 };
        let bwd = fwd_compute * bwd_mult + coll / 2.0 + z3 / 2.0;
        (fwd, bwd)
    }

    /// Pure-compute phase split for the flow-level simulator
    /// ([`crate::netsim`]): like [`Self::stage_phase_times`] but
    /// *excluding* intra-stage collective time, which netsim lowers into
    /// explicit flows instead of folding into occupancy. ZeRO-3 weight
    /// gathers stay in the compute term: their sharding-group placement
    /// is the same ranking-preserving approximation either way (see
    /// `CostModel::new`).
    pub fn stage_phase_compute(&self, i: usize, j: usize, spec: &MemSpec) -> (f64, f64) {
        self.stage_phase_compute_on(self.full_mask, i, j, spec)
    }

    /// [`Self::stage_phase_compute`] on a known lockstep class mask.
    pub fn stage_phase_compute_on(
        &self,
        mask: ClassMask,
        i: usize,
        j: usize,
        spec: &MemSpec,
    ) -> (f64, f64) {
        let fwd_compute = self.fwd_range_on(mask, i, j);
        let z3 = if let ZeroStage::Z3 { .. } = spec.zero {
            let wb = self.stage_params(i, j) * memory::WEIGHT_BYTES;
            2.0 * (self.z3_alpha + wb * self.z3_beta)
        } else {
            0.0
        };
        let bwd_mult = if spec.recompute { 3.0 } else { 2.0 };
        (fwd_compute + z3 / 2.0, fwd_compute * bwd_mult + z3 / 2.0)
    }

    /// Separate components of a stage's per-microbatch time for
    /// compute/communication breakdowns (Figure 2).
    pub fn stage_breakdown(&self, i: usize, j: usize, spec: &MemSpec) -> (f64, f64) {
        self.stage_breakdown_on(self.full_mask, i, j, spec)
    }

    /// [`Self::stage_breakdown`] on a known lockstep class mask.
    pub fn stage_breakdown_on(
        &self,
        mask: ClassMask,
        i: usize,
        j: usize,
        spec: &MemSpec,
    ) -> (f64, f64) {
        let compute_mult = if spec.recompute { 4.0 } else { 3.0 };
        let compute = self.fwd_range_on(mask, i, j) * compute_mult;
        let mut comm = self.collective[j] - self.collective[i];
        if let ZeroStage::Z3 { .. } = spec.zero {
            let wb = self.stage_params(i, j) * memory::WEIGHT_BYTES;
            comm += 2.0 * (self.z3_alpha + wb * self.z3_beta);
        }
        (compute, comm)
    }

    /// Boundary bytes crossing after layer `j-1` (for the simulator).
    pub fn boundary_bytes_after(&self, j: usize) -> f64 {
        self.boundary[(j - 1).min(self.n_layers - 1)]
    }
}

/// Forward wall-clock of one layer on one device: roofline matmul term
/// plus vector-unit term.
fn layer_fwd_time(
    layer: &crate::graph::Layer,
    tokens: f64,
    sg: &SgConfig,
    accel: &Accelerator,
) -> f64 {
    let mm = layer.matmul_flops_fwd(tokens, sg);
    let hbm = layer.hbm_bytes_fwd(tokens, sg);
    let vec = layer.vector_flops_fwd(tokens, sg);
    accel.matmul_time(mm, hbm) + vec / accel.vector_peak
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::util::prop;

    fn setup() -> (LayerGraph, Cluster) {
        (models::gpt3_175b(1), Cluster::fat_tree_tpuv4(64))
    }

    #[test]
    fn load_additive_over_cuts() {
        let (g, c) = setup();
        let cm = CostModel::new(&g, &c, SgConfig::tp(4));
        let spec = MemSpec::plain();
        // Pure compute (no boundaries) is additive: [2,10) = [2,6)+[6,10).
        let whole = cm.stage_load(2, 10, None, None, &spec, &c);
        let a = cm.stage_load(2, 6, None, None, &spec, &c);
        let b = cm.stage_load(6, 10, None, None, &spec, &c);
        assert!((whole - (a + b)).abs() / whole < 1e-9);
    }

    #[test]
    fn boundaries_add_cost_increasing_with_level() {
        let (g, c) = setup();
        let cm = CostModel::new(&g, &c, SgConfig::tp(4));
        let spec = MemSpec::plain();
        let base = cm.stage_load(4, 8, None, None, &spec, &c);
        let l0 = cm.stage_load(4, 8, Some(0), None, &spec, &c);
        let l2 = cm.stage_load(4, 8, Some(2), None, &spec, &c);
        assert!(base < l0 && l0 < l2);
    }

    #[test]
    fn recompute_multiplies_compute() {
        let (g, c) = setup();
        let cm = CostModel::new(&g, &c, SgConfig::serial());
        let plain = cm.stage_load(1, 9, None, None, &MemSpec::plain(), &c);
        let rc = cm.stage_load(
            1,
            9,
            None,
            None,
            &MemSpec {
                zero: ZeroStage::None,
                recompute: true,
            },
            &c,
        );
        // 4/3 compute ratio (collectives unchanged).
        assert!(rc > plain);
        assert!(rc / plain < 4.0 / 3.0 + 1e-6);
    }

    #[test]
    fn z3_adds_gather_overhead() {
        let (g, c) = setup();
        let cm = CostModel::new(&g, &c, SgConfig::serial());
        let plain = cm.stage_load(1, 9, None, None, &MemSpec::plain(), &c);
        let z3 = cm.stage_load(
            1,
            9,
            None,
            None,
            &MemSpec {
                zero: ZeroStage::Z3 { degree: 8 },
                recompute: false,
            },
            &c,
        );
        assert!(z3 > plain);
    }

    #[test]
    fn peak_bytes_matches_memory_module() {
        let (g, c) = setup();
        let sg = SgConfig::tp(4);
        let cm = CostModel::new(&g, &c, sg);
        let spec = MemSpec::plain();
        for (i, j, stash) in [(0usize, 5usize, 0usize), (3, 12, 4), (90, 98, 2)] {
            let fast = cm.stage_peak_bytes(i, j, &spec, stash);
            let slow =
                memory::stage_peak_bytes(&g.layers[i..j], g.tokens, &sg, &spec, stash);
            assert!(
                (fast - slow).abs() / slow < 1e-9,
                "[{i},{j}) stash={stash}: {fast} vs {slow}"
            );
        }
    }

    #[test]
    fn tp_reduces_compute_adds_collectives() {
        let (g, c) = setup();
        let serial = CostModel::new(&g, &c, SgConfig::serial());
        let tp8 = CostModel::new(&g, &c, SgConfig::tp(8));
        let spec = MemSpec::plain();
        let t1 = serial.stage_load(1, 9, None, None, &spec, &c);
        let t8 = tp8.stage_load(1, 9, None, None, &spec, &c);
        // TP-8 should be meaningfully faster per device but not a full 8×
        // (collectives + memory-bound terms).
        assert!(t8 < t1, "tp8 {t8} < serial {t1}");
        assert!(t1 / t8 < 8.0);
    }

    #[test]
    fn prop_load_monotone_in_range() {
        let (g, c) = setup();
        let cm = CostModel::new(&g, &c, SgConfig::tp(4));
        let spec = MemSpec::plain();
        prop::forall(100, 0xFEED, |rng| {
            let i = rng.gen_range(cm.n_layers() - 2);
            let j = i + 2 + rng.gen_range(cm.n_layers() - i - 2);
            let inner = cm.stage_load(i + 1, j, None, None, &spec, &c);
            let outer = cm.stage_load(i, j, None, None, &spec, &c);
            assert!(outer >= inner, "[{i},{j})");
        });
    }

    #[test]
    fn hetero_lockstep_prices_slowest_class() {
        let g = models::llama2_7b(1);
        let hetero = Cluster::hetero_pool(64); // class 0 = h100, 1 = v100
        let h_only = hetero.with_uniform_accel(crate::hw::Accelerator::h100());
        let v_only = hetero.with_uniform_accel(crate::hw::Accelerator::v100());
        let cm = CostModel::new(&g, &hetero, SgConfig::serial());
        let spec = MemSpec::plain();
        let h = cm.stage_load_on(0b01, 1, 9, None, None, &spec, &hetero);
        let v = cm.stage_load_on(0b10, 1, 9, None, None, &spec, &hetero);
        let both = cm.stage_load_on(0b11, 1, 9, None, None, &spec, &hetero);
        assert!(h < v, "H100 range must be faster than V100 range");
        assert_eq!(both.to_bits(), v.to_bits(), "lockstep = slowest class");
        // Single-class masks agree bit-for-bit with uniform twins.
        let cm_h = CostModel::new(&g, &h_only, SgConfig::serial());
        let cm_v = CostModel::new(&g, &v_only, SgConfig::serial());
        assert_eq!(
            h.to_bits(),
            cm_h.stage_load(1, 9, None, None, &spec, &h_only).to_bits()
        );
        assert_eq!(
            v.to_bits(),
            cm_v.stage_load(1, 9, None, None, &spec, &v_only).to_bits()
        );
        // Mask-free methods price the pool-wide worst case.
        assert_eq!(
            cm.stage_load(1, 9, None, None, &spec, &hetero).to_bits(),
            both.to_bits()
        );
        // Lower bounds bracket the truth.
        assert!(cm.stage_load_lb_best(1, 9) <= cm.stage_load_lb_on(0b01, 1, 9));
        assert!(cm.stage_load_lb_on(0b01, 1, 9) <= cm.stage_load_lb(1, 9));
    }

    #[test]
    fn choose_spec_consistent_with_peak() {
        let g = models::llama3_70b(1);
        let c = Cluster::fat_tree_tpuv4(64);
        let cm = CostModel::new(&g, &c, SgConfig::serial());
        let cap = c.accel().hbm_capacity;
        let spec = cm.stage_choose_spec(1, 11, 6, cap, 8, false);
        if let Some(s) = spec {
            assert!(cm.stage_peak_bytes(1, 11, &s, 6) <= cap);
        }
    }
}
