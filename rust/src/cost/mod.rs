//! The unified compute–network–memory cost model: the DP's `load(·)` term
//! (§4 "Unified Cost Model and Recurrence").
//!
//! [`CostModel`] pre-characterizes one (graph, cluster, SUB-GRAPH config)
//! triple: per-layer forward+backward compute time, intra-stage collective
//! time (TP/SP/EP/CP traffic at the group's locality), sharded parameter
//! counts, and activation footprints — all as prefix sums so any
//! contiguous stage `[i, j)` is costed in O(1) inside the DP's inner
//! loop. The two queries that are *not* prefix differences are tabled
//! too: the recompute working-set max rides a sparse table
//! (O(n log n) once, O(1) per range) and the pipeline-p2p α–β
//! coefficients are cached per level, so no `(i, j)` transition walks
//! layers or tiers. `NEST_REFERENCE=1` (or [`PricingMode::Reference`])
//! swaps back to the naive walks those tables replaced — the property
//! suite pins both paths to identical bits.
//! This mirrors the paper's offline SUB-GRAPH profiling (§3.1): local
//! strategies are characterized once and composed analytically during
//! placement.

use crate::graph::subgraph::{layer_collectives, SgConfig};
use crate::graph::LayerGraph;
use crate::hw::{Accelerator, ClassMask};
use crate::memory::{self, MemSpec, ZeroStage};
use crate::network::Cluster;

/// Which pricing implementation a [`CostModel`] uses for the few range
/// queries that are not plain prefix differences.
///
/// * `Optimized` — O(1) tables: a sparse-table range-max for the
///   recompute working set, cached per-level α–β coefficients for the
///   pipeline p2p terms. This is the production path.
/// * `Reference` — the naive twins those tables replaced: a linear layer
///   walk for the working-set max and per-call `Cluster::p2p_time`
///   tier scans. Kept alive so the property suite can assert
///   optimized ≡ reference bit-for-bit on random inputs, and as a
///   runtime escape hatch (`NEST_REFERENCE=1`).
/// * `Auto` — resolve from the environment once per process
///   ([`crate::util::reference_mode`]); what every default constructor
///   uses.
///
/// Both paths compute mathematically identical values; the property
/// tests pin them to the *same bits* (max is associative and exact, and
/// the cached α–β coefficients are produced by the very tier scans they
/// replace).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PricingMode {
    #[default]
    Auto,
    Optimized,
    Reference,
}

impl PricingMode {
    /// Collapse `Auto` to the environment's choice.
    pub fn resolve(self) -> PricingMode {
        match self {
            PricingMode::Auto => {
                if crate::util::reference_mode() {
                    PricingMode::Reference
                } else {
                    PricingMode::Optimized
                }
            }
            m => m,
        }
    }
}

/// Per-mask range pricer: the accelerator classes of one lockstep device
/// block, resolved once so the DP's inner loops stop re-deriving them
/// from the bitmask on every `(i, j)` query. Built per DP stage context
/// ([`CostModel::pricer`]) and per exact-solver `(k, sg)` block — the
/// class fold runs in the same ascending order as the mask iteration it
/// replaces, so prices are bit-identical.
#[derive(Debug, Clone, Copy)]
pub struct RangePricer {
    /// Ascending class indices covered by the mask.
    classes: [u8; 64],
    n_classes: u8,
}

impl RangePricer {
    #[inline]
    fn classes(&self) -> &[u8] {
        &self.classes[..self.n_classes as usize]
    }
}

/// Pre-computed per-layer costs with prefix sums for O(1) range queries.
///
/// Compute prefixes are kept **per accelerator class** of the cluster's
/// [`crate::hw::DevicePool`]: a stage placed on a device range covering
/// classes `mask` runs TP/DP lockstep, so its compute time is the *max*
/// over the covered classes ([`CostModel::stage_load_on`] and friends).
/// The mask-free methods price against the pool-wide worst case (every
/// class), which on homogeneous clusters — a single class — is exactly
/// the old behavior.
#[derive(Debug, Clone)]
pub struct CostModel {
    pub sg: SgConfig,
    /// Devices per stage replica (= sg.group_size()).
    pub group: usize,
    /// Communication level at which a compact group of `group` devices
    /// lives; SUB-GRAPH collectives price at this locality.
    pub group_level: usize,
    n_layers: usize,
    /// Per accelerator class `c` (pool class order):
    /// `fwd_compute[c][i]` = Σ_{k<i} fwd compute seconds of layer k on
    /// class `c` (per microbatch, per device). Backward is 2×;
    /// recompute adds another 1×.
    fwd_compute: Vec<Vec<f64>>,
    /// Mask with every pool class set.
    full_mask: ClassMask,
    /// prefix of per-layer fwd+bwd collective seconds.
    collective: Vec<f64>,
    /// prefix of per-device sharded param counts.
    params_sharded: Vec<f64>,
    /// prefix of activation stash bytes (no recompute / recompute).
    act_plain: Vec<f64>,
    act_rc: Vec<f64>,
    /// per-layer boundary bytes (activation crossing layer k → k+1).
    boundary: Vec<f64>,
    /// Sparse table over per-layer *full* activation bytes
    /// (`act_plain[k+1] − act_plain[k]`): `act_rmq[lvl][i]` is the max
    /// over layers `[i, i + 2^lvl)`. Turns the recompute working-set
    /// scan — the last O(j−i) walk in the DP's transition — into an
    /// O(1) query ([`Self::working_set_bytes`]).
    act_rmq: Vec<Vec<f64>>,
    /// Cached `Cluster::lat(l)` / `Cluster::bw_eff(l)` per level: the
    /// pipeline-p2p α–β coefficients the tier scans inside
    /// `Cluster::p2p_time` recompute on every DP transition.
    p2p_lat: Vec<f64>,
    p2p_bw: Vec<f64>,
    /// `max_k stage_load_lb_best(k, k+1)` — the heaviest single layer on
    /// the pool's fastest class, hoisted out of the per-config pruning
    /// bound ([`Self::max_single_layer_lb_best`]).
    max_layer_lb_best: f64,
    mode: PricingMode,
    /// ZeRO-3 weight all-gather cost model at the replica-adjacent
    /// locality: `z3_alpha + bytes · z3_beta` (latency + bandwidth terms
    /// kept separate so large payloads don't multiply the α term).
    z3_alpha: f64,
    z3_beta: f64,
    pub tokens: f64,
}

impl CostModel {
    pub fn new(graph: &LayerGraph, cluster: &Cluster, sg: SgConfig) -> Self {
        Self::with_mode(graph, cluster, sg, PricingMode::Auto)
    }

    /// [`Self::new`] with an explicit [`PricingMode`] (the property
    /// suite builds optimized and reference models side by side).
    pub fn with_mode(
        graph: &LayerGraph,
        cluster: &Cluster,
        sg: SgConfig,
        mode: PricingMode,
    ) -> Self {
        let _span = crate::obs::span("cost.build", "cost");
        let mode = mode.resolve();
        let n = graph.n_layers();
        let classes = cluster.pool.classes();
        let group = sg.group_size();
        let group_level = cluster.level_of_group(group);
        let tokens = graph.tokens;

        let mut fwd_compute: Vec<Vec<f64>> = classes.iter().map(|_| vec![0.0; n + 1]).collect();
        let mut collective = vec![0.0; n + 1];
        let mut params_sharded = vec![0.0; n + 1];
        let mut act_plain = vec![0.0; n + 1];
        let mut act_rc = vec![0.0; n + 1];
        let mut boundary = vec![0.0; n];

        for (k, layer) in graph.layers.iter().enumerate() {
            for (c, accel) in classes.iter().enumerate() {
                fwd_compute[c][k + 1] =
                    fwd_compute[c][k] + layer_fwd_time(layer, tokens, &sg, accel);
            }
            let coll: f64 = layer_collectives(layer, tokens, &sg)
                .iter()
                .map(|c| cluster.collective_time(c))
                .sum();
            collective[k + 1] = collective[k] + coll;
            params_sharded[k + 1] = params_sharded[k] + layer.param_count_sharded(&sg);
            act_plain[k + 1] = act_plain[k] + layer.act_stash_bytes(tokens, &sg, false);
            act_rc[k + 1] = act_rc[k] + layer.act_stash_bytes(tokens, &sg, true);
            boundary[k] = layer.boundary_bytes(tokens, &sg);
        }

        // ZeRO-3 param all-gather: the sharding group is the z nearest
        // data-parallel replicas; we price it as a gather over a group of
        // size z placed one pipeline-replica stride apart. The stride is
        // unknown during the DP (it depends on the final stage count), so
        // we use the compact-adjacent approximation — identical for all
        // candidate cuts, hence ranking-preserving (DESIGN.md §4).
        let z3_shape = cluster.compact_shape(group * 2);
        let z3_alpha = cluster.allgather(0.0, &z3_shape);
        let z3_beta = cluster.allgather(1e9, &z3_shape) / 1e9 - z3_alpha / 1e9;

        // Range-max sparse table over per-layer full activation bytes.
        // Level 0 is the per-layer vector itself; level `v` doubles the
        // window. O(n log n) doubles once per (sg) — amortized to zero
        // against the O(n²·s) transitions that query it.
        let act_layer: Vec<f64> = (0..n).map(|k| act_plain[k + 1] - act_plain[k]).collect();
        let mut act_rmq: Vec<Vec<f64>> = vec![act_layer];
        let mut width = 1usize;
        while width * 2 <= n {
            let prev = act_rmq.last().unwrap();
            let next: Vec<f64> = (0..=(n - width * 2))
                .map(|i| prev[i].max(prev[i + width]))
                .collect();
            act_rmq.push(next);
            width *= 2;
        }

        // Pipeline-p2p α–β coefficients per level, produced by the same
        // tier scans `Cluster::p2p_time` runs per call — cached values
        // are bit-identical by construction.
        let p2p_lat: Vec<f64> = (0..cluster.n_levels()).map(|l| cluster.lat(l)).collect();
        let p2p_bw: Vec<f64> = (0..cluster.n_levels()).map(|l| cluster.bw_eff(l)).collect();

        // Heaviest single layer on the fastest class — the same fold the
        // per-config pruning bound used to run per (sg, recompute, p).
        let max_layer_lb_best = (0..n)
            .map(|k| {
                let mut best = f64::INFINITY;
                for pfx in &fwd_compute {
                    best = best.min(pfx[k + 1] - pfx[k]);
                }
                best * 3.0
            })
            .fold(0.0, f64::max);

        CostModel {
            sg,
            group,
            group_level,
            n_layers: n,
            fwd_compute,
            full_mask: cluster.pool.full_mask(),
            collective,
            params_sharded,
            act_plain,
            act_rc,
            boundary,
            act_rmq,
            p2p_lat,
            p2p_bw,
            max_layer_lb_best,
            mode,
            z3_alpha,
            z3_beta,
            tokens,
        }
    }

    /// The pricing implementation this model resolved to (never `Auto`).
    pub fn mode(&self) -> PricingMode {
        self.mode
    }

    /// Resolve a class mask into a [`RangePricer`] once, outside the
    /// DP's `(i, j)` loops.
    pub fn pricer(&self, mask: ClassMask) -> RangePricer {
        let mut m = mask & self.full_mask;
        debug_assert!(m != 0, "empty accelerator-class mask");
        let mut classes = [0u8; 64];
        let mut n_classes = 0u8;
        while m != 0 {
            let c = m.trailing_zeros() as u8;
            m &= m - 1;
            classes[n_classes as usize] = c;
            n_classes += 1;
        }
        RangePricer { classes, n_classes }
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    /// Lockstep forward-compute seconds of layers `[i, j)` on a device
    /// group covering `mask`: the slowest covered class sets the pace.
    #[inline]
    fn fwd_range_on(&self, mask: ClassMask, i: usize, j: usize) -> f64 {
        self.fwd_range_priced(&self.pricer(mask), i, j)
    }

    /// [`Self::fwd_range_on`] with the mask pre-resolved (the fold runs
    /// over the same ascending class order, so values are bit-identical).
    #[inline]
    fn fwd_range_priced(&self, pricer: &RangePricer, i: usize, j: usize) -> f64 {
        let mut worst = 0.0f64;
        for &c in pricer.classes() {
            let pfx = &self.fwd_compute[c as usize];
            let v = pfx[j] - pfx[i];
            if v > worst {
                worst = v;
            }
        }
        worst
    }

    /// Transient working set of a recomputing stage `[i, j)`: the
    /// largest single layer's full activation bytes. O(1) on the sparse
    /// table; the `Reference` mode keeps the linear walk this replaced
    /// (`max` is associative and exact, so both return the same bits).
    #[inline]
    fn working_set_bytes(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < j && j <= self.n_layers);
        if self.mode == PricingMode::Reference {
            let mut w: f64 = 0.0;
            for k in i..j {
                w = w.max(self.act_plain[k + 1] - self.act_plain[k]);
            }
            return w;
        }
        let len = j - i;
        let lvl = (usize::BITS - 1 - len.leading_zeros()) as usize;
        let row = &self.act_rmq[lvl];
        row[i].max(row[j - (1 << lvl)])
    }

    /// Pipeline-p2p α–β cost at level `l` — cached coefficients on the
    /// optimized path, the original per-call tier scan under `Reference`.
    #[inline]
    fn p2p(&self, cluster: &Cluster, l: usize, bytes: f64) -> f64 {
        if self.mode == PricingMode::Reference {
            cluster.p2p_time(l, bytes)
        } else {
            self.p2p_lat[l] + bytes / self.p2p_bw[l]
        }
    }

    /// Fastest-class forward compute of `[i, j)` — a valid lower bound
    /// for *any* placement of the stage (config-level pruning).
    #[inline]
    fn fwd_range_best(&self, i: usize, j: usize) -> f64 {
        let mut best = f64::INFINITY;
        for pfx in &self.fwd_compute {
            best = best.min(pfx[j] - pfx[i]);
        }
        best
    }

    /// Per-device sharded parameter count of stage `[i, j)`.
    pub fn stage_params(&self, i: usize, j: usize) -> f64 {
        self.params_sharded[j] - self.params_sharded[i]
    }

    /// Activation stash bytes of one microbatch for stage `[i, j)`.
    pub fn stage_act_bytes(&self, i: usize, j: usize, recompute: bool) -> f64 {
        if recompute {
            self.act_rc[j] - self.act_rc[i]
        } else {
            self.act_plain[j] - self.act_plain[i]
        }
    }

    /// Peak memory of stage `[i, j)` under `spec` with `stash` extra
    /// in-flight microbatches (Eq. 1 via prefix sums).
    pub fn stage_peak_bytes(&self, i: usize, j: usize, spec: &MemSpec, stash: usize) -> f64 {
        let p = self.stage_params(i, j);
        let z = spec.zero.degree() as f64;
        let static_bytes = match spec.zero {
            ZeroStage::None => p * 16.0,
            ZeroStage::Z1 { .. } => p * (4.0 + 12.0 / z),
            ZeroStage::Z2 { .. } => p * (2.0 + 14.0 / z),
            ZeroStage::Z3 { .. } => p * 16.0 / z,
        };
        let act = self.stage_act_bytes(i, j, spec.recompute);
        // Transient working set under recompute: the largest single
        // layer's full activations (re-materialized during backward).
        let working = if spec.recompute {
            self.working_set_bytes(i, j)
        } else {
            0.0
        };
        static_bytes + act * (1.0 + stash as f64) + working
    }

    /// Pick the minimal memory spec for stage `[i, j)` that fits
    /// `capacity`, escalating recompute → ZeRO-1/2/3 exactly as
    /// `memory::choose_spec` but on the O(1) prefix path.
    pub fn stage_choose_spec(
        &self,
        i: usize,
        j: usize,
        stash: usize,
        capacity: f64,
        max_degree: usize,
        recompute: bool,
    ) -> Option<MemSpec> {
        // Allocation-free escalation (this runs once per DP transition —
        // ~10⁷ times per solve; see EXPERIMENTS.md §Perf). Memory terms
        // are assembled inline from the prefix sums rather than through
        // a candidate Vec; the recompute working set is an O(1)
        // sparse-table query, so no term walks the layer range.
        let p = self.stage_params(i, j);
        let act = self.stage_act_bytes(i, j, recompute) * (1.0 + stash as f64);
        let working = if recompute {
            self.working_set_bytes(i, j)
        } else {
            0.0
        };
        let dynamic = act + working;

        let fits = |static_bytes: f64| static_bytes + dynamic <= capacity;
        if fits(p * 16.0) {
            return Some(MemSpec {
                zero: ZeroStage::None,
                recompute,
            });
        }
        for kind in 0..3u8 {
            let mut z = 2usize;
            while z <= max_degree {
                let zf = z as f64;
                let (zero, static_bytes) = match kind {
                    0 => (ZeroStage::Z1 { degree: z }, p * (4.0 + 12.0 / zf)),
                    1 => (ZeroStage::Z2 { degree: z }, p * (2.0 + 14.0 / zf)),
                    _ => (ZeroStage::Z3 { degree: z }, p * 16.0 / zf),
                };
                if fits(static_bytes) {
                    return Some(MemSpec { zero, recompute });
                }
                z *= 2;
            }
        }
        None
    }

    /// The DP's `load_l^{sg}(D \ D', a, s)`: per-microbatch latency of
    /// stage `[i, j)` given the forward producer at level `recv_level`
    /// and the consumer at level `send_level` (§4):
    ///
    /// * compute: fwd + 2×bwd (+1× fwd again under recomputation),
    /// * SUB-GRAPH collectives at the group's locality,
    /// * pipeline p2p: activation fwd + gradient bwd at each boundary,
    /// * ZeRO-3 weight all-gathers when the memory spec demands them.
    pub fn stage_load(
        &self,
        i: usize,
        j: usize,
        recv_level: Option<usize>,
        send_level: Option<usize>,
        spec: &MemSpec,
        cluster: &Cluster,
    ) -> f64 {
        self.stage_load_on(self.full_mask, i, j, recv_level, send_level, spec, cluster)
    }

    /// [`Self::stage_load`] for a stage whose lockstep device group
    /// covers accelerator classes `mask` (the solver passes the classes
    /// of the block the stage actually occupies, replicas included).
    #[allow(clippy::too_many_arguments)]
    pub fn stage_load_on(
        &self,
        mask: ClassMask,
        i: usize,
        j: usize,
        recv_level: Option<usize>,
        send_level: Option<usize>,
        spec: &MemSpec,
        cluster: &Cluster,
    ) -> f64 {
        self.stage_load_priced(&self.pricer(mask), i, j, recv_level, send_level, spec, cluster)
    }

    /// [`Self::stage_load_on`] with the class mask pre-resolved — the
    /// DP's transition hot path (the solver builds one pricer per stage
    /// context, outside the O(n²) cut scan). Bit-identical to the
    /// mask-based form.
    #[allow(clippy::too_many_arguments)]
    pub fn stage_load_priced(
        &self,
        pricer: &RangePricer,
        i: usize,
        j: usize,
        recv_level: Option<usize>,
        send_level: Option<usize>,
        spec: &MemSpec,
        cluster: &Cluster,
    ) -> f64 {
        debug_assert!(i < j && j <= self.n_layers);
        let fwd = self.fwd_range_priced(pricer, i, j);
        let compute_mult = if spec.recompute { 4.0 } else { 3.0 };
        let mut t = fwd * compute_mult;
        t += self.collective[j] - self.collective[i];
        if let ZeroStage::Z3 { .. } = spec.zero {
            // All-gather full (unsharded-on-z) weights once per microbatch
            // for fwd and once for bwd.
            let weight_bytes = self.stage_params(i, j) * memory::WEIGHT_BYTES;
            t += 2.0 * (self.z3_alpha + weight_bytes * self.z3_beta);
        }
        if let Some(l) = recv_level {
            // Activation in (fwd) + gradient out (bwd) across the
            // producer boundary.
            let b = self.boundary[i.saturating_sub(1).min(self.n_layers - 1)];
            t += 2.0 * self.p2p(cluster, l, b);
        }
        if let Some(l) = send_level {
            let b = self.boundary[j - 1];
            t += 2.0 * self.p2p(cluster, l, b);
        }
        t
    }

    /// Cheap lower bound on `stage_load` for `[i, j)`: pure forward+
    /// backward compute, no communication. Strictly increasing in `j` —
    /// the DP uses it for exact cut pruning. The mask-free form prices
    /// the pool-wide worst case; use [`Self::stage_load_lb_on`] when the
    /// stage's block is known and [`Self::stage_load_lb_best`] when it
    /// is not (placement-independent bound).
    #[inline]
    pub fn stage_load_lb(&self, i: usize, j: usize) -> f64 {
        self.stage_load_lb_on(self.full_mask, i, j)
    }

    /// Lower bound on [`Self::stage_load_on`] for a known class mask.
    #[inline]
    pub fn stage_load_lb_on(&self, mask: ClassMask, i: usize, j: usize) -> f64 {
        self.fwd_range_on(mask, i, j) * 3.0
    }

    /// [`Self::stage_load_lb_on`] with the mask pre-resolved.
    #[inline]
    pub fn stage_load_lb_priced(&self, pricer: &RangePricer, i: usize, j: usize) -> f64 {
        self.fwd_range_priced(pricer, i, j) * 3.0
    }

    /// Placement-independent lower bound: even on the pool's fastest
    /// class the stage cannot run faster than this.
    #[inline]
    pub fn stage_load_lb_best(&self, i: usize, j: usize) -> f64 {
        self.fwd_range_best(i, j) * 3.0
    }

    /// `max_k` [`Self::stage_load_lb_best`]`(k, k+1)` — precomputed in
    /// [`Self::new`] so the per-`(p, d)` config pruning bound stops
    /// re-folding the layer axis.
    #[inline]
    pub fn max_single_layer_lb_best(&self) -> f64 {
        self.max_layer_lb_best
    }

    /// Gradient-sync bytes for stage `[i, j)` (bf16 grads).
    pub fn stage_grad_bytes(&self, i: usize, j: usize) -> f64 {
        self.stage_params(i, j) * memory::GRAD_BYTES
    }

    /// Split the stage's per-microbatch occupancy into forward and
    /// backward phases for the discrete-event simulator. Collectives and
    /// ZeRO-3 gathers split evenly; the recomputation re-forward lands in
    /// the backward phase (where 1F1B executes it). Excludes pipeline p2p
    /// — the simulator models transfers as dependency edges.
    pub fn stage_phase_times(
        &self,
        i: usize,
        j: usize,
        spec: &MemSpec,
        cluster: &Cluster,
    ) -> (f64, f64) {
        self.stage_phase_times_on(self.full_mask, i, j, spec, cluster)
    }

    /// [`Self::stage_phase_times`] on a known lockstep class mask.
    pub fn stage_phase_times_on(
        &self,
        mask: ClassMask,
        i: usize,
        j: usize,
        spec: &MemSpec,
        cluster: &Cluster,
    ) -> (f64, f64) {
        let fwd_compute = self.fwd_range_on(mask, i, j);
        let coll = self.collective[j] - self.collective[i];
        let z3 = if let ZeroStage::Z3 { .. } = spec.zero {
            let wb = self.stage_params(i, j) * memory::WEIGHT_BYTES;
            2.0 * (self.z3_alpha + wb * self.z3_beta)
        } else {
            0.0
        };
        let _ = cluster;
        let fwd = fwd_compute + coll / 2.0 + z3 / 2.0;
        let bwd_mult = if spec.recompute { 3.0 } else { 2.0 };
        let bwd = fwd_compute * bwd_mult + coll / 2.0 + z3 / 2.0;
        (fwd, bwd)
    }

    /// Pure-compute phase split for the flow-level simulator
    /// ([`crate::netsim`]): like [`Self::stage_phase_times`] but
    /// *excluding* intra-stage collective time, which netsim lowers into
    /// explicit flows instead of folding into occupancy. ZeRO-3 weight
    /// gathers stay in the compute term: their sharding-group placement
    /// is the same ranking-preserving approximation either way (see
    /// `CostModel::new`).
    pub fn stage_phase_compute(&self, i: usize, j: usize, spec: &MemSpec) -> (f64, f64) {
        self.stage_phase_compute_on(self.full_mask, i, j, spec)
    }

    /// [`Self::stage_phase_compute`] on a known lockstep class mask.
    pub fn stage_phase_compute_on(
        &self,
        mask: ClassMask,
        i: usize,
        j: usize,
        spec: &MemSpec,
    ) -> (f64, f64) {
        let fwd_compute = self.fwd_range_on(mask, i, j);
        let z3 = if let ZeroStage::Z3 { .. } = spec.zero {
            let wb = self.stage_params(i, j) * memory::WEIGHT_BYTES;
            2.0 * (self.z3_alpha + wb * self.z3_beta)
        } else {
            0.0
        };
        let bwd_mult = if spec.recompute { 3.0 } else { 2.0 };
        (fwd_compute + z3 / 2.0, fwd_compute * bwd_mult + z3 / 2.0)
    }

    /// Separate components of a stage's per-microbatch time for
    /// compute/communication breakdowns (Figure 2).
    pub fn stage_breakdown(&self, i: usize, j: usize, spec: &MemSpec) -> (f64, f64) {
        self.stage_breakdown_on(self.full_mask, i, j, spec)
    }

    /// [`Self::stage_breakdown`] on a known lockstep class mask.
    pub fn stage_breakdown_on(
        &self,
        mask: ClassMask,
        i: usize,
        j: usize,
        spec: &MemSpec,
    ) -> (f64, f64) {
        let compute_mult = if spec.recompute { 4.0 } else { 3.0 };
        let compute = self.fwd_range_on(mask, i, j) * compute_mult;
        let mut comm = self.collective[j] - self.collective[i];
        if let ZeroStage::Z3 { .. } = spec.zero {
            let wb = self.stage_params(i, j) * memory::WEIGHT_BYTES;
            comm += 2.0 * (self.z3_alpha + wb * self.z3_beta);
        }
        (compute, comm)
    }

    /// Boundary bytes crossing after layer `j-1` (for the simulator).
    pub fn boundary_bytes_after(&self, j: usize) -> f64 {
        self.boundary[(j - 1).min(self.n_layers - 1)]
    }
}

/// A shareable pool of [`CostModel`] tables keyed by
/// `(context key, SgConfig)`, so batched-sweep and service queries over
/// the same (graph, cluster) context reuse one set of prefix tables per
/// strategy instead of rebuilding them per query.
///
/// The context key is the caller's content fingerprint of the
/// (graph, cluster) pair (see `crate::service::Query`); the arena never
/// inspects the graph or cluster beyond building a model on a miss, so
/// key collisions are the caller's responsibility. Entries are
/// reference-counted: handed-out models stay valid even if the arena is
/// dropped. Lookup is a linear scan — arenas hold at most a few dozen
/// (context × strategy) pairs, far below hashing break-even, and
/// `SgConfig` is a 4-field POD compare.
#[derive(Debug, Default)]
pub struct CostArena {
    entries: Vec<((u64, SgConfig), std::rc::Rc<CostModel>)>,
}

impl CostArena {
    pub fn new() -> Self {
        Self::default()
    }

    /// The model for `(key, sg)`, building (and caching) it from
    /// `graph`/`cluster` on first use. `graph`/`cluster` MUST be the
    /// pair `key` fingerprints — on a hit they are not even read.
    pub fn get(
        &mut self,
        key: u64,
        graph: &LayerGraph,
        cluster: &Cluster,
        sg: SgConfig,
    ) -> std::rc::Rc<CostModel> {
        if let Some((_, cm)) = self.entries.iter().find(|(k, _)| *k == (key, sg)) {
            return cm.clone();
        }
        let cm = std::rc::Rc::new(CostModel::new(graph, cluster, sg));
        self.entries.push(((key, sg), cm.clone()));
        cm
    }

    /// Number of cached (context × strategy) models.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Forward wall-clock of one layer on one device: roofline matmul term
/// plus vector-unit term.
fn layer_fwd_time(
    layer: &crate::graph::Layer,
    tokens: f64,
    sg: &SgConfig,
    accel: &Accelerator,
) -> f64 {
    let mm = layer.matmul_flops_fwd(tokens, sg);
    let hbm = layer.hbm_bytes_fwd(tokens, sg);
    let vec = layer.vector_flops_fwd(tokens, sg);
    accel.matmul_time(mm, hbm) + vec / accel.vector_peak
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::util::prop;

    fn setup() -> (LayerGraph, Cluster) {
        (models::gpt3_175b(1), Cluster::fat_tree_tpuv4(64))
    }

    #[test]
    fn arena_shares_models_per_key_and_strategy() {
        let (g, c) = setup();
        let mut arena = CostArena::new();
        let a = arena.get(0xABCD, &g, &c, SgConfig::tp(4));
        let b = arena.get(0xABCD, &g, &c, SgConfig::tp(4));
        assert!(std::rc::Rc::ptr_eq(&a, &b), "same (key, sg) must share");
        assert_eq!(arena.len(), 1);

        let other_sg = arena.get(0xABCD, &g, &c, SgConfig::serial());
        assert!(!std::rc::Rc::ptr_eq(&a, &other_sg));
        let other_key = arena.get(0x1234, &g, &c, SgConfig::tp(4));
        assert!(!std::rc::Rc::ptr_eq(&a, &other_key));
        assert_eq!(arena.len(), 3);

        // A shared model prices identically to a fresh one.
        let fresh = CostModel::new(&g, &c, SgConfig::tp(4));
        let spec = MemSpec::plain();
        assert_eq!(
            a.stage_load(2, 10, None, None, &spec, &c).to_bits(),
            fresh.stage_load(2, 10, None, None, &spec, &c).to_bits()
        );
    }

    #[test]
    fn load_additive_over_cuts() {
        let (g, c) = setup();
        let cm = CostModel::new(&g, &c, SgConfig::tp(4));
        let spec = MemSpec::plain();
        // Pure compute (no boundaries) is additive: [2,10) = [2,6)+[6,10).
        let whole = cm.stage_load(2, 10, None, None, &spec, &c);
        let a = cm.stage_load(2, 6, None, None, &spec, &c);
        let b = cm.stage_load(6, 10, None, None, &spec, &c);
        assert!((whole - (a + b)).abs() / whole < 1e-9);
    }

    #[test]
    fn boundaries_add_cost_increasing_with_level() {
        let (g, c) = setup();
        let cm = CostModel::new(&g, &c, SgConfig::tp(4));
        let spec = MemSpec::plain();
        let base = cm.stage_load(4, 8, None, None, &spec, &c);
        let l0 = cm.stage_load(4, 8, Some(0), None, &spec, &c);
        let l2 = cm.stage_load(4, 8, Some(2), None, &spec, &c);
        assert!(base < l0 && l0 < l2);
    }

    #[test]
    fn recompute_multiplies_compute() {
        let (g, c) = setup();
        let cm = CostModel::new(&g, &c, SgConfig::serial());
        let plain = cm.stage_load(1, 9, None, None, &MemSpec::plain(), &c);
        let rc = cm.stage_load(
            1,
            9,
            None,
            None,
            &MemSpec {
                zero: ZeroStage::None,
                recompute: true,
            },
            &c,
        );
        // 4/3 compute ratio (collectives unchanged).
        assert!(rc > plain);
        assert!(rc / plain < 4.0 / 3.0 + 1e-6);
    }

    #[test]
    fn z3_adds_gather_overhead() {
        let (g, c) = setup();
        let cm = CostModel::new(&g, &c, SgConfig::serial());
        let plain = cm.stage_load(1, 9, None, None, &MemSpec::plain(), &c);
        let z3 = cm.stage_load(
            1,
            9,
            None,
            None,
            &MemSpec {
                zero: ZeroStage::Z3 { degree: 8 },
                recompute: false,
            },
            &c,
        );
        assert!(z3 > plain);
    }

    #[test]
    fn peak_bytes_matches_memory_module() {
        let (g, c) = setup();
        let sg = SgConfig::tp(4);
        let cm = CostModel::new(&g, &c, sg);
        let spec = MemSpec::plain();
        for (i, j, stash) in [(0usize, 5usize, 0usize), (3, 12, 4), (90, 98, 2)] {
            let fast = cm.stage_peak_bytes(i, j, &spec, stash);
            let slow =
                memory::stage_peak_bytes(&g.layers[i..j], g.tokens, &sg, &spec, stash);
            assert!(
                (fast - slow).abs() / slow < 1e-9,
                "[{i},{j}) stash={stash}: {fast} vs {slow}"
            );
        }
    }

    #[test]
    fn tp_reduces_compute_adds_collectives() {
        let (g, c) = setup();
        let serial = CostModel::new(&g, &c, SgConfig::serial());
        let tp8 = CostModel::new(&g, &c, SgConfig::tp(8));
        let spec = MemSpec::plain();
        let t1 = serial.stage_load(1, 9, None, None, &spec, &c);
        let t8 = tp8.stage_load(1, 9, None, None, &spec, &c);
        // TP-8 should be meaningfully faster per device but not a full 8×
        // (collectives + memory-bound terms).
        assert!(t8 < t1, "tp8 {t8} < serial {t1}");
        assert!(t1 / t8 < 8.0);
    }

    #[test]
    fn prop_load_monotone_in_range() {
        let (g, c) = setup();
        let cm = CostModel::new(&g, &c, SgConfig::tp(4));
        let spec = MemSpec::plain();
        prop::forall(100, 0xFEED, |rng| {
            let i = rng.gen_range(cm.n_layers() - 2);
            let j = i + 2 + rng.gen_range(cm.n_layers() - i - 2);
            let inner = cm.stage_load(i + 1, j, None, None, &spec, &c);
            let outer = cm.stage_load(i, j, None, None, &spec, &c);
            assert!(outer >= inner, "[{i},{j})");
        });
    }

    #[test]
    fn hetero_lockstep_prices_slowest_class() {
        let g = models::llama2_7b(1);
        let hetero = Cluster::hetero_pool(64); // class 0 = h100, 1 = v100
        let h_only = hetero.with_uniform_accel(crate::hw::Accelerator::h100());
        let v_only = hetero.with_uniform_accel(crate::hw::Accelerator::v100());
        let cm = CostModel::new(&g, &hetero, SgConfig::serial());
        let spec = MemSpec::plain();
        let h = cm.stage_load_on(0b01, 1, 9, None, None, &spec, &hetero);
        let v = cm.stage_load_on(0b10, 1, 9, None, None, &spec, &hetero);
        let both = cm.stage_load_on(0b11, 1, 9, None, None, &spec, &hetero);
        assert!(h < v, "H100 range must be faster than V100 range");
        assert_eq!(both.to_bits(), v.to_bits(), "lockstep = slowest class");
        // Single-class masks agree bit-for-bit with uniform twins.
        let cm_h = CostModel::new(&g, &h_only, SgConfig::serial());
        let cm_v = CostModel::new(&g, &v_only, SgConfig::serial());
        assert_eq!(
            h.to_bits(),
            cm_h.stage_load(1, 9, None, None, &spec, &h_only).to_bits()
        );
        assert_eq!(
            v.to_bits(),
            cm_v.stage_load(1, 9, None, None, &spec, &v_only).to_bits()
        );
        // Mask-free methods price the pool-wide worst case.
        assert_eq!(
            cm.stage_load(1, 9, None, None, &spec, &hetero).to_bits(),
            both.to_bits()
        );
        // Lower bounds bracket the truth.
        assert!(cm.stage_load_lb_best(1, 9) <= cm.stage_load_lb_on(0b01, 1, 9));
        assert!(cm.stage_load_lb_on(0b01, 1, 9) <= cm.stage_load_lb(1, 9));
    }

    #[test]
    fn optimized_pricing_matches_reference_bitwise() {
        // The tentpole invariant: sparse-table working-set maxima,
        // cached p2p coefficients, and pre-resolved pricers must price
        // every (i, j, spec, boundary) query to the same bits as the
        // naive layer-walking reference.
        for (g, c) in [
            (models::llama2_7b(1), Cluster::fat_tree_tpuv4(64)),
            (models::llama2_7b(1), Cluster::hetero_pool(64)),
            (models::gpt3_35b(1), Cluster::spine_leaf_h100(64, 2.0)),
        ] {
            for sg in [SgConfig::serial(), SgConfig::tp(4)] {
                let opt = CostModel::with_mode(&g, &c, sg, PricingMode::Optimized);
                let refm = CostModel::with_mode(&g, &c, sg, PricingMode::Reference);
                let cap = c.pool.min_capacity_all();
                prop::forall(60, 0x0C0DE, |rng| {
                    let i = rng.gen_range(opt.n_layers() - 1);
                    let j = i + 1 + rng.gen_range(opt.n_layers() - i - 1);
                    let rc = rng.gen_bool(0.5);
                    let spec = MemSpec {
                        zero: ZeroStage::None,
                        recompute: rc,
                    };
                    let recv = if rng.gen_bool(0.5) {
                        Some(rng.gen_range(c.n_levels()))
                    } else {
                        None
                    };
                    let send = if rng.gen_bool(0.5) {
                        Some(rng.gen_range(c.n_levels()))
                    } else {
                        None
                    };
                    let mask = c.pool.full_mask();
                    let a = opt.stage_load_on(mask, i, j, recv, send, &spec, &c);
                    let b = refm.stage_load_on(mask, i, j, recv, send, &spec, &c);
                    assert_eq!(a.to_bits(), b.to_bits(), "load [{i},{j}) rc={rc}");
                    let pricer = opt.pricer(mask);
                    let p = opt.stage_load_priced(&pricer, i, j, recv, send, &spec, &c);
                    assert_eq!(p.to_bits(), a.to_bits(), "pricer [{i},{j})");
                    let stash = rng.gen_range(8);
                    let pa = opt.stage_peak_bytes(i, j, &spec, stash);
                    let pb = refm.stage_peak_bytes(i, j, &spec, stash);
                    assert_eq!(pa.to_bits(), pb.to_bits(), "peak [{i},{j})");
                    assert_eq!(
                        opt.stage_choose_spec(i, j, stash, cap, 8, rc),
                        refm.stage_choose_spec(i, j, stash, cap, 8, rc),
                        "spec [{i},{j})"
                    );
                    assert_eq!(
                        opt.stage_load_lb_priced(&pricer, i, j).to_bits(),
                        refm.stage_load_lb_on(mask, i, j).to_bits()
                    );
                });
                // The hoisted single-layer bound equals the fold it replaced.
                let n = refm.n_layers();
                let folded = (0..n)
                    .map(|k| refm.stage_load_lb_best(k, k + 1))
                    .fold(0.0, f64::max);
                assert_eq!(opt.max_single_layer_lb_best().to_bits(), folded.to_bits());
            }
        }
    }

    #[test]
    fn pricing_mode_resolves() {
        assert_ne!(PricingMode::Auto.resolve(), PricingMode::Auto);
        assert_eq!(PricingMode::Optimized.resolve(), PricingMode::Optimized);
        assert_eq!(PricingMode::Reference.resolve(), PricingMode::Reference);
    }

    #[test]
    fn choose_spec_consistent_with_peak() {
        let g = models::llama3_70b(1);
        let c = Cluster::fat_tree_tpuv4(64);
        let cm = CostModel::new(&g, &c, SgConfig::serial());
        let cap = c.accel().hbm_capacity;
        let spec = cm.stage_choose_spec(1, 11, 6, cap, 8, false);
        if let Some(s) = spec {
            assert!(cm.stage_peak_bytes(1, 11, &s, 6) <= cap);
        }
    }
}
