//! Real pipeline-parallel training over thread-devices (the §5.4
//! validation substitute, DESIGN.md §Hardware-Adaptation).
//!
//! Each pipeline stage runs on its own OS thread with its own PJRT
//! engine and the stage's AOT artifacts (`stage{k}_{fwd,bwd,update}`);
//! activations/gradients flow through channels following the 1F1B
//! schedule (warmup `p−1−k` forwards, then one-forward-one-backward,
//! blocking receives — the same deadlock-free order Megatron uses on
//! real clusters). Data parallelism replicates the whole pipeline
//! `dp_width` times and all-reduces gradients across replicas at the
//! step boundary (a shared-memory barrier plays the role of the
//! collective). Losses come from the last stage's fused loss+backward
//! artifact; the synthetic task is the learnable successor language
//! `t+1 = (3·t + 7) mod V`, so the loss curve demonstrably drops from
//! ln V toward 0 — proving L1 (Pallas kernel), L2 (JAX stages), and L3
//! (this coordinator) compose end-to-end.

use anyhow::{Context, Result};
use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Barrier, Condvar, Mutex};
use std::time::Instant;

use crate::runtime::manifest::{Manifest, StageSpec};
use crate::runtime::{literal_f32, literal_i32, scalar_i32, Engine};
use crate::util::rng::Rng;

/// Trainer options.
#[derive(Debug, Clone)]
pub struct TrainOpts {
    /// Optimizer steps to run.
    pub steps: usize,
    /// Microbatches per step per replica (≥ pipeline depth for good
    /// utilization; the paper's m in `bottleneck·(m+s−1)`).
    pub microbatches: usize,
    /// Data-parallel replicas of the whole pipeline.
    pub dp_width: usize,
    /// Injected per-hop link delay in seconds (0 = off) — lets the
    /// trainer emulate the topology's p2p latency.
    pub link_delay: f64,
    pub seed: u64,
    /// Print loss every n steps (0 = silent).
    pub log_every: usize,
}

impl Default for TrainOpts {
    fn default() -> Self {
        TrainOpts {
            steps: 20,
            microbatches: 8,
            dp_width: 1,
            link_delay: 0.0,
            seed: 42,
            log_every: 5,
        }
    }
}

/// Training outcome.
#[derive(Debug, Clone)]
pub struct TrainReport {
    /// Mean loss per step (averaged over replicas and microbatches).
    pub losses: Vec<f64>,
    /// Wall time per step.
    pub step_times: Vec<f64>,
    /// Tokens processed per second (all replicas).
    pub tokens_per_s: f64,
    /// Per-stage busy fraction of replica 0.
    pub stage_busy: Vec<f64>,
}

/// Cross-replica gradient all-reduce point for one stage: replicas
/// deposit their accumulated gradients; the last arrival averages; all
/// pick up the result (keeps replicas bit-identical, like a real
/// all-reduce).
struct GradSync {
    slots: Mutex<(usize, Vec<Vec<f32>>)>,
    ready: Condvar,
    width: usize,
}

impl GradSync {
    fn new(width: usize) -> Self {
        GradSync {
            slots: Mutex::new((0, Vec::new())),
            ready: Condvar::new(),
            width,
        }
    }

    /// All-reduce-average `grads` in place.
    fn allreduce(&self, grads: &mut [Vec<f32>], generation: usize) {
        if self.width <= 1 {
            return;
        }
        let mut guard = self.slots.lock().unwrap();
        if guard.1.is_empty() {
            guard.1 = grads.to_vec();
        } else {
            for (acc, g) in guard.1.iter_mut().zip(grads.iter()) {
                for (a, b) in acc.iter_mut().zip(g.iter()) {
                    *a += b;
                }
            }
        }
        guard.0 += 1;
        if guard.0 == self.width {
            let w = self.width as f32;
            for acc in guard.1.iter_mut() {
                for a in acc.iter_mut() {
                    *a /= w;
                }
            }
            self.ready.notify_all();
        } else {
            let gen_target = generation;
            while guard.0 < self.width {
                guard = self.ready.wait(guard).unwrap();
                let _ = gen_target;
            }
        }
        for (g, acc) in grads.iter_mut().zip(guard.1.iter()) {
            g.copy_from_slice(acc);
        }
        guard.0 += 1;
        // Last reader resets for the next step.
        if guard.0 == 2 * self.width {
            guard.0 = 0;
            guard.1.clear();
        }
    }
}

/// Deterministic parameter init mirroring the python initializer:
/// layernorm gains → 1, biases → 0, matrices → N(0, 0.02).
fn init_leaf(rng: &mut Rng, path: &str, n: usize) -> Vec<f32> {
    if path.contains("ln") && path.ends_with("_g") {
        return vec![1.0; n];
    }
    if path.ends_with("_b") || path.starts_with("b_") || path.contains(".b_") {
        return vec![0.0; n];
    }
    // Box–Muller normals.
    let mut out = Vec::with_capacity(n);
    while out.len() < n {
        let u1 = rng.gen_f64().max(1e-12);
        let u2 = rng.gen_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        out.push((r * theta.cos() * 0.02) as f32);
        if out.len() < n {
            out.push((r * theta.sin() * 0.02) as f32);
        }
    }
    out
}

/// Generate one microbatch of the successor-language task.
fn gen_batch(rng: &mut Rng, mbs: usize, seq: usize, vocab: usize) -> (Vec<i32>, Vec<i32>) {
    let mut x = Vec::with_capacity(mbs * seq);
    let mut y = Vec::with_capacity(mbs * seq);
    for _ in 0..mbs {
        let mut cur = rng.gen_range(vocab) as i64;
        for _ in 0..seq {
            x.push(cur as i32);
            cur = (3 * cur + 7) % vocab as i64;
            y.push(cur as i32);
        }
    }
    (x, y)
}

enum ToFirst {
    Tokens(Vec<i32>),
}
enum ToLast {
    Targets(Vec<i32>),
}

struct StageCtx {
    spec: StageSpec,
    dir: PathBuf,
    act_rx: Option<Receiver<Vec<f32>>>,
    act_tx: Option<Sender<Vec<f32>>>,
    grad_rx: Option<Receiver<Vec<f32>>>,
    grad_tx: Option<Sender<Vec<f32>>>,
    tokens_rx: Option<Receiver<ToFirst>>,
    targets_rx: Option<Receiver<ToLast>>,
    loss_tx: Option<Sender<f64>>,
    sync: Arc<GradSync>,
    start_barrier: Arc<Barrier>,
    opts: TrainOpts,
    p: usize,
    k: usize,
    replica: usize,
    busy_tx: Sender<(usize, usize, f64, f64)>, // (replica, stage, busy, total)
}

fn stage_thread(ctx: StageCtx) -> Result<()> {
    let engine = Engine::cpu()?;
    let fwd = engine.load(ctx.dir.join(&ctx.spec.fwd))?;
    let bwd = engine.load(ctx.dir.join(&ctx.spec.bwd))?;
    let update = engine.load(ctx.dir.join(&ctx.spec.update))?;

    // Initialize params + Adam state (same seed across replicas keeps
    // them in lockstep, like a synchronized init broadcast).
    let mut params: Vec<Vec<f32>> = Vec::new();
    for (li, leaf) in ctx.spec.params.iter().enumerate() {
        let mut rng = Rng::new(ctx.opts.seed ^ ((ctx.k as u64) << 32) ^ li as u64);
        params.push(init_leaf(&mut rng, &leaf.path, leaf.numel()));
    }
    let mut adam_m: Vec<Vec<f32>> = params.iter().map(|p| vec![0.0; p.len()]).collect();
    let mut adam_v: Vec<Vec<f32>> = params.iter().map(|p| vec![0.0; p.len()]).collect();

    let m = ctx.opts.microbatches;
    let p = ctx.p;
    let k = ctx.k;
    let x_dims: Vec<i64> = ctx.spec.x_shape.iter().map(|&d| d as i64).collect();
    let delay = ctx.opts.link_delay;

    ctx.start_barrier.wait();
    let t_run = Instant::now();
    let mut busy = 0.0f64;

    for step in 1..=ctx.opts.steps {
        // Per-step state.
        let mut grads_acc: Vec<Vec<f32>> =
            params.iter().map(|p| vec![0.0; p.len()]).collect();
        let mut stash: VecDeque<Vec<f32>> = VecDeque::new(); // f32 inputs
        let mut stash_tokens: VecDeque<Vec<i32>> = VecDeque::new();
        let mut targets_q: VecDeque<Vec<i32>> = VecDeque::new();
        let mut loss_sum = 0.0f64;

        // Hoist parameter literals out of the microbatch loop: params
        // only change at the step boundary, so upload them once per step
        // instead of once per fwd/bwd call (§Perf in EXPERIMENTS.md —
        // this removes p·m redundant host→device copies per step).
        let param_lits: Vec<xla::Literal> = ctx
            .spec
            .params
            .iter()
            .zip(params.iter())
            .map(|(leaf, data)| literal_f32(data, &leaf.dims_i64()))
            .collect::<Result<_>>()?;

        let do_fwd = |param_lits: &[xla::Literal],
                          stash: &mut VecDeque<Vec<f32>>,
                          stash_tokens: &mut VecDeque<Vec<i32>>,
                          targets_q: &mut VecDeque<Vec<i32>>,
                          busy: &mut f64|
         -> Result<()> {
            let x_lit;
            if ctx.spec.first {
                let ToFirst::Tokens(x) = ctx
                    .tokens_rx
                    .as_ref()
                    .unwrap()
                    .recv()
                    .context("tokens channel closed")?;
                x_lit = literal_i32(&x, &x_dims)?;
                stash_tokens.push_back(x);
            } else {
                let x = ctx
                    .act_rx
                    .as_ref()
                    .unwrap()
                    .recv()
                    .context("act channel closed")?;
                x_lit = literal_f32(&x, &x_dims)?;
                stash.push_back(x);
            }
            if ctx.spec.last {
                // Last stage defers compute to the fused loss+bwd call;
                // stash targets for it.
                let ToLast::Targets(t) = ctx
                    .targets_rx
                    .as_ref()
                    .unwrap()
                    .recv()
                    .context("targets channel closed")?;
                targets_q.push_back(t);
                return Ok(());
            }
            let mut args: Vec<&xla::Literal> = param_lits.iter().collect();
            args.push(&x_lit);
            let t0 = Instant::now();
            let out = fwd.run_refs(&args)?;
            *busy += t0.elapsed().as_secs_f64();
            let y: Vec<f32> = out[0].to_vec()?;
            if delay > 0.0 {
                std::thread::sleep(std::time::Duration::from_secs_f64(delay));
            }
            ctx.act_tx
                .as_ref()
                .unwrap()
                .send(y)
                .ok()
                .context("act send failed")?;
            Ok(())
        };

        let do_bwd = |param_lits: &[xla::Literal],
                          grads_acc: &mut [Vec<f32>],
                          stash: &mut VecDeque<Vec<f32>>,
                          stash_tokens: &mut VecDeque<Vec<i32>>,
                          targets_q: &mut VecDeque<Vec<i32>>,
                          loss_sum: &mut f64,
                          busy: &mut f64|
         -> Result<()> {
            let x_lit = if ctx.spec.first {
                let x = stash_tokens.pop_front().context("empty token stash")?;
                literal_i32(&x, &x_dims)?
            } else {
                let x = stash.pop_front().context("empty act stash")?;
                literal_f32(&x, &x_dims)?
            };
            let mut args: Vec<&xla::Literal> = param_lits.iter().collect();
            args.push(&x_lit);
            let n_par = ctx.spec.params.len();
            let tail_lit;
            let outputs = if ctx.spec.last {
                let t = targets_q.pop_front().context("empty targets")?;
                tail_lit = literal_i32(&t, &x_dims[..2].to_vec())?;
                args.push(&tail_lit);
                let t0 = Instant::now();
                let out = bwd.run_refs(&args)?;
                *busy += t0.elapsed().as_secs_f64();
                // (loss, gparams..., gx)
                let loss: f32 = out[0].get_first_element()?;
                *loss_sum += loss as f64;
                out[1..].to_vec()
            } else {
                let gy = ctx
                    .grad_rx
                    .as_ref()
                    .unwrap()
                    .recv()
                    .context("grad channel closed")?;
                let y_dims: Vec<i64> = ctx.spec.y_shape.iter().map(|&d| d as i64).collect();
                tail_lit = literal_f32(&gy, &y_dims)?;
                args.push(&tail_lit);
                let t0 = Instant::now();
                let out = bwd.run_refs(&args)?;
                *busy += t0.elapsed().as_secs_f64();
                out
            };
            for (li, lit) in outputs[..n_par].iter().enumerate() {
                let g: Vec<f32> = lit.to_vec()?;
                for (a, b) in grads_acc[li].iter_mut().zip(g.iter()) {
                    *a += b;
                }
            }
            if !ctx.spec.first {
                let gx: Vec<f32> = outputs[n_par].to_vec()?;
                if delay > 0.0 {
                    std::thread::sleep(std::time::Duration::from_secs_f64(delay));
                }
                ctx.grad_tx
                    .as_ref()
                    .unwrap()
                    .send(gx)
                    .ok()
                    .context("grad send failed")?;
            }
            Ok(())
        };

        // 1F1B: warmup forwards, then alternate, then drain.
        let warmup = (p - 1 - k).min(m);
        for _ in 0..warmup {
            do_fwd(&param_lits, &mut stash, &mut stash_tokens, &mut targets_q, &mut busy)?;
        }
        let mut nf = warmup;
        let mut nb = 0;
        while nb < m {
            if nf < m {
                do_fwd(&param_lits, &mut stash, &mut stash_tokens, &mut targets_q, &mut busy)?;
                nf += 1;
            }
            do_bwd(
                &param_lits,
                &mut grads_acc,
                &mut stash,
                &mut stash_tokens,
                &mut targets_q,
                &mut loss_sum,
                &mut busy,
            )?;
            nb += 1;
        }

        // Average over microbatches, all-reduce across replicas, update.
        let scale = 1.0 / m as f32;
        for g in grads_acc.iter_mut() {
            for v in g.iter_mut() {
                *v *= scale;
            }
        }
        ctx.sync.allreduce(&mut grads_acc, step);
        let mut owned: Vec<xla::Literal> = Vec::with_capacity(3 * ctx.spec.params.len() + 1);
        for (leaf, g) in ctx.spec.params.iter().zip(grads_acc.iter()) {
            owned.push(literal_f32(g, &leaf.dims_i64())?);
        }
        for (leaf, mm) in ctx.spec.params.iter().zip(adam_m.iter()) {
            owned.push(literal_f32(mm, &leaf.dims_i64())?);
        }
        for (leaf, vv) in ctx.spec.params.iter().zip(adam_v.iter()) {
            owned.push(literal_f32(vv, &leaf.dims_i64())?);
        }
        owned.push(scalar_i32(step as i32));
        let mut args: Vec<&xla::Literal> = param_lits.iter().collect();
        args.extend(owned.iter());
        let t0 = Instant::now();
        let out = update.run_refs(&args)?;
        busy += t0.elapsed().as_secs_f64();
        let n_par = ctx.spec.params.len();
        for li in 0..n_par {
            params[li] = out[li].to_vec()?;
            adam_m[li] = out[n_par + li].to_vec()?;
            adam_v[li] = out[2 * n_par + li].to_vec()?;
        }

        if ctx.spec.last {
            ctx.loss_tx
                .as_ref()
                .unwrap()
                .send(loss_sum / m as f64)
                .ok()
                .context("loss send failed")?;
        }
    }

    let total = t_run.elapsed().as_secs_f64();
    let _ = ctx.busy_tx.send((ctx.replica, ctx.k, busy, total));
    Ok(())
}

/// Run pipeline-parallel training from the AOT artifacts in `dir`.
pub fn train(dir: impl Into<PathBuf>, opts: &TrainOpts) -> Result<TrainReport> {
    let dir: PathBuf = dir.into();
    let man = Manifest::load(dir.join("manifest.json"))?;
    let p = man.stages.len();
    let d = opts.dp_width.max(1);
    let cfg = &man.config;

    let (busy_tx, busy_rx) = channel::<(usize, usize, f64, f64)>();
    let start_barrier = Arc::new(Barrier::new(p * d));
    let syncs: Vec<Arc<GradSync>> = (0..p).map(|_| Arc::new(GradSync::new(d))).collect();

    let mut token_txs = Vec::new();
    let mut target_txs = Vec::new();
    let mut loss_rxs = Vec::new();
    let mut handles = Vec::new();

    for r in 0..d {
        // Channels within this replica.
        let mut act: Vec<(Option<Sender<Vec<f32>>>, Option<Receiver<Vec<f32>>>)> =
            (0..p).map(|_| (None, None)).collect();
        let mut grad: Vec<(Option<Sender<Vec<f32>>>, Option<Receiver<Vec<f32>>>)> =
            (0..p).map(|_| (None, None)).collect();
        for k in 0..p.saturating_sub(1) {
            let (tx, rx) = channel();
            act[k].0 = Some(tx);
            act[k + 1].1 = Some(rx);
            let (tx, rx) = channel();
            grad[k + 1].0 = Some(tx);
            grad[k].1 = Some(rx);
        }
        let (tok_tx, tok_rx) = channel::<ToFirst>();
        let (tar_tx, tar_rx) = channel::<ToLast>();
        let (loss_tx, loss_rx) = channel::<f64>();
        token_txs.push(tok_tx);
        target_txs.push(tar_tx);
        loss_rxs.push(loss_rx);

        let mut tok_rx = Some(tok_rx);
        let mut tar_rx = Some(tar_rx);
        let mut loss_tx = Some(loss_tx);
        for (k, (a, g)) in act.drain(..).zip(grad.drain(..)).enumerate() {
            let ctx = StageCtx {
                spec: man.stages[k].clone(),
                dir: dir.clone(),
                act_rx: a.1,
                act_tx: a.0,
                grad_rx: g.1,
                grad_tx: g.0,
                tokens_rx: if k == 0 { tok_rx.take() } else { None },
                targets_rx: if k == p - 1 { tar_rx.take() } else { None },
                loss_tx: if k == p - 1 { loss_tx.take() } else { None },
                sync: syncs[k].clone(),
                start_barrier: start_barrier.clone(),
                opts: opts.clone(),
                p,
                k,
                replica: r,
                busy_tx: busy_tx.clone(),
            };
            handles.push(std::thread::spawn(move || {
                let (r, k) = (ctx.replica, ctx.k);
                let res = stage_thread(ctx);
                if let Err(e) = &res {
                    eprintln!("stage thread (replica {r}, stage {k}) failed: {e:#}");
                }
                res
            }));
        }
    }
    drop(busy_tx);

    // Driver: feed data and collect losses.
    let mut rng = Rng::new(opts.seed);
    let mut losses = Vec::with_capacity(opts.steps);
    let mut step_times = Vec::with_capacity(opts.steps);
    let t_total = Instant::now();
    for step in 0..opts.steps {
        let t0 = Instant::now();
        for r in 0..d {
            for _ in 0..opts.microbatches {
                let (x, y) = gen_batch(&mut rng, cfg.mbs, cfg.seq, cfg.vocab);
                token_txs[r].send(ToFirst::Tokens(x)).ok().context("driver tokens")?;
                target_txs[r].send(ToLast::Targets(y)).ok().context("driver targets")?;
            }
        }
        let mut loss = 0.0;
        for rx in &loss_rxs {
            loss += rx.recv().context("loss channel closed")?;
        }
        loss /= d as f64;
        losses.push(loss);
        step_times.push(t0.elapsed().as_secs_f64());
        if opts.log_every > 0 && (step + 1) % opts.log_every == 0 {
            println!(
                "step {:4}  loss {:.4}  ({:.2}s/step)",
                step + 1,
                loss,
                step_times.last().unwrap()
            );
        }
    }
    let total = t_total.elapsed().as_secs_f64();

    for h in handles {
        h.join().expect("stage thread panicked")?;
    }
    let mut stage_busy = vec![0.0; p];
    for (r, k, busy, tot) in busy_rx.iter() {
        if r == 0 {
            stage_busy[k] = busy / tot.max(1e-9);
        }
    }

    let tokens = (opts.steps * d * opts.microbatches * cfg.mbs * cfg.seq) as f64;
    Ok(TrainReport {
        losses,
        step_times,
        tokens_per_s: tokens / total,
        stage_busy,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts_dir;

    #[test]
    fn gen_batch_is_successor_language() {
        let mut rng = Rng::new(1);
        let (x, y) = gen_batch(&mut rng, 2, 8, 97);
        assert_eq!(x.len(), 16);
        for i in 0..16 {
            assert_eq!(y[i], (3 * x[i] + 7) % 97);
        }
        // Within a sequence, x[t+1] == y[t].
        for t in 0..7 {
            assert_eq!(x[t + 1], y[t]);
        }
    }

    #[test]
    fn init_leaf_rules() {
        let mut rng = Rng::new(2);
        assert!(init_leaf(&mut rng, "blocks.0.ln1_g", 4).iter().all(|&v| v == 1.0));
        assert!(init_leaf(&mut rng, "blocks.0.b_in", 4).iter().all(|&v| v == 0.0));
        let w = init_leaf(&mut rng, "blocks.0.wqkv", 1000);
        let mean: f32 = w.iter().sum::<f32>() / 1000.0;
        assert!(mean.abs() < 0.01);
        assert!(w.iter().any(|&v| v != 0.0));
    }

    #[test]
    fn grad_sync_averages() {
        let sync = Arc::new(GradSync::new(2));
        let s2 = sync.clone();
        let h = std::thread::spawn(move || {
            let mut g = vec![vec![2.0f32, 4.0]];
            s2.allreduce(&mut g, 1);
            g
        });
        let mut g = vec![vec![0.0f32, 2.0]];
        sync.allreduce(&mut g, 1);
        let other = h.join().unwrap();
        assert_eq!(g, vec![vec![1.0, 3.0]]);
        assert_eq!(other, vec![vec![1.0, 3.0]]);
    }

    #[test]
    fn pipeline_trains_and_loss_drops() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let opts = TrainOpts {
            steps: 8,
            microbatches: 8,
            dp_width: 1,
            link_delay: 0.0,
            seed: 7,
            log_every: 0,
        };
        let rep = train(&dir, &opts).unwrap();
        assert_eq!(rep.losses.len(), 8);
        // Initial loss ≈ ln(vocab); after a few Adam steps it must move
        // down measurably on the deterministic successor task.
        let first = rep.losses[0];
        let last = *rep.losses.last().unwrap();
        assert!(first > 6.0, "initial loss {first} (ln 4096 ≈ 8.3)");
        assert!(last < first * 0.95, "no learning: {first} -> {last}");
        assert!(rep.tokens_per_s > 0.0);
    }
}
