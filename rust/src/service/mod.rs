//! Placement as a service: the solve→refine pipeline behind a
//! cache-warm, incremental query layer.
//!
//! One-shot `solve` calls fit a research harness; a production
//! placement service fields many concurrent, overlapping
//! (model, cluster) queries — co-design sweeps, autoscaling
//! controllers, elasticity events — where most queries are near-misses
//! of earlier ones. This module packages the solver for that workload:
//!
//! * [`Query`] — a (graph, cluster, [`SolverOpts`]) triple with a
//!   canonical content **fingerprint** (FNV-1a over every field that
//!   can reach a plan). Two queries with equal fingerprints are
//!   guaranteed to produce bit-identical plans, so the fingerprint is
//!   a sound cache key.
//! * [`PlacementService`] — an LRU cache of solved top-K shortlists
//!   keyed by fingerprint. A hit returns the cached plans without
//!   touching the solver; a miss solves **warm-started** from the best
//!   cached plan of a *neighboring* query (same graph on a scaled
//!   cluster, or same cluster under a different model). Warm starts
//!   reorder the solver's evaluation queue only — the winner is
//!   provably unchanged (see `solver` module docs, "# Warm starting").
//! * [`PlacementService::reconcile`] — incremental re-solve: apply a
//!   [`ClusterDelta`] (device failure, link degradation, pool resize),
//!   re-solve warm, and price what the move costs as a
//!   [`PlanDelta`](crate::solver::plan::PlanDelta): stages re-homed,
//!   parameter bytes to migrate, migration seconds through the
//!   cluster's α–β levels. On infeasibility it walks a
//!   graceful-degradation ladder — allow recompute, lift the query's
//!   stage-count cap, finally concede outer groups (shrink the replica
//!   set) — and reports what it gave up as a
//!   [`ReconcileOutcome::Degraded`] with explicit [`Concession`]s,
//!   erring ([`ServiceError`]) only when nothing feasible exists at the
//!   bottom of the ladder.
//!
//! ## Fingerprint semantics
//!
//! The fingerprint *includes* everything plan-relevant: every layer
//! (kind, MoE config, dimensions), batch geometry, the allowed
//! SUB-GRAPH degree lists, tier shapes (arity, bandwidth, latency,
//! oversubscription), the device pool's accelerator profiles and run
//! layout, and the pruning-relevant [`SolverOpts`] fields
//! (`max_stages`, `zero_max_degree`, recompute branches). It
//! *excludes* fields proven plan-invariant — `threads`, `pricing`, and
//! `warm_start` (the property suite pins all three) — plus pure labels
//! that never reach a plan (`Cluster::name`, tier names). Mutating any
//! included field invalidates the cache entry; flipping thread counts
//! or re-labelling a cluster does not.
//!
//! Everything here is deterministic: cached, warm-started, and cold
//! paths return field-for-field identical plans (`rust/tests/
//! property.rs` proves it at 1 and 4 threads on random scenarios).

use crate::cost::CostArena;
use crate::graph::{Layer, LayerGraph, LayerKind};
use crate::netsim::{LinkGraph, Simulation};
use crate::network::Cluster;
use crate::obs;
use crate::solver::plan::{diff_plans_in, PlacementPlan, PlanDelta};
use crate::solver::refine::{rerank, RefineReport};
use crate::solver::{solve_topk, SolverOpts, WarmStart};

// ---------------------------------------------------------------------
// Typed errors
// ---------------------------------------------------------------------

/// Everything the service can refuse to do, matchable instead of
/// string-sniffed. [`std::fmt::Display`] renders the operator-facing
/// message the old `Result<_, String>` plumbing carried.
#[derive(Debug, Clone, PartialEq)]
pub enum ServiceError {
    /// No feasible placement exists on the original (pre-delta)
    /// cluster — the query was already unanswerable.
    InfeasibleOriginal,
    /// No feasible placement on the post-delta cluster, even after the
    /// full degradation ladder. `devices` is the count at the ladder's
    /// bottom rung.
    InfeasibleAfterDelta { devices: usize },
    /// The [`ClusterDelta`] itself is invalid against this cluster
    /// (empty/out-of-range device ids, emptying failure counts, bad
    /// degradation fractions, …).
    InvalidDelta(String),
}

impl std::fmt::Display for ServiceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServiceError::InfeasibleOriginal => {
                write!(f, "reconcile: no feasible placement on the original cluster")
            }
            ServiceError::InfeasibleAfterDelta { devices } => write!(
                f,
                "reconcile: no feasible placement on the post-delta cluster \
                 ({devices} devices), even after the degradation ladder"
            ),
            ServiceError::InvalidDelta(reason) => write!(f, "invalid cluster delta: {reason}"),
        }
    }
}

impl std::error::Error for ServiceError {}

// ---------------------------------------------------------------------
// Content fingerprints
// ---------------------------------------------------------------------

/// FNV-1a 64-bit content hasher. Hand-rolled (no `std::hash`) so the
/// byte stream — and therefore every fingerprint — is pinned across
/// Rust releases and platforms; golden tests may embed fingerprints.
struct Fp(u64);

impl Fp {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;

    fn new() -> Self {
        Fp(Self::OFFSET)
    }

    fn byte(&mut self, b: u8) {
        self.0 ^= b as u64;
        self.0 = self.0.wrapping_mul(Self::PRIME);
    }

    fn u64(&mut self, v: u64) {
        for b in v.to_le_bytes() {
            self.byte(b);
        }
    }

    fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }

    /// Bit-exact: distinguishes -0.0 from 0.0 and every NaN payload,
    /// matching the solver's bit-identity contract.
    fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    fn bool(&mut self, v: bool) {
        self.byte(v as u8);
    }

    /// Enum discriminant / structural tag — keeps adjacent fields from
    /// aliasing across variants.
    fn tag(&mut self, t: u8) {
        self.byte(t);
    }

    /// Length-prefixed so `["ab","c"]` and `["a","bc"]` differ.
    fn str(&mut self, s: &str) {
        self.usize(s.len());
        for b in s.bytes() {
            self.byte(b);
        }
    }

    fn usizes(&mut self, vs: &[usize]) {
        self.usize(vs.len());
        for &v in vs {
            self.usize(v);
        }
    }

    fn finish(self) -> u64 {
        self.0
    }
}

fn hash_layer(fp: &mut Fp, layer: &Layer) {
    fp.str(&layer.name);
    match layer.kind {
        LayerKind::Embedding => fp.tag(0),
        LayerKind::Block => fp.tag(1),
        LayerKind::MoeBlock(cfg) => {
            fp.tag(2);
            fp.usize(cfg.experts);
            fp.usize(cfg.top_k);
        }
        LayerKind::Head => fp.tag(3),
    }
    let d = &layer.dims;
    fp.usize(d.hidden);
    fp.usize(d.heads);
    fp.usize(d.kv_heads);
    fp.usize(d.intermediate);
    fp.usize(d.seq);
    fp.usize(d.vocab);
    fp.bool(d.gated_mlp);
}

/// Content fingerprint of a model graph: layers, batch geometry, and
/// the allowed SUB-GRAPH degree lists.
pub fn graph_fingerprint(graph: &LayerGraph) -> u64 {
    let mut fp = Fp::new();
    fp.tag(b'g');
    fp.str(&graph.model_name);
    fp.usize(graph.layers.len());
    for layer in &graph.layers {
        hash_layer(&mut fp, layer);
    }
    fp.usize(graph.mbs);
    fp.f64(graph.tokens);
    fp.usize(graph.global_batch);
    fp.usizes(&graph.tp_widths);
    fp.usizes(&graph.ep_degrees);
    fp.usizes(&graph.cp_degrees);
    fp.finish()
}

/// Content fingerprint of a cluster: tier shapes and the device pool.
/// `Cluster::name` and tier names are labels that never reach a plan —
/// deliberately excluded, so re-labelling does not invalidate caches.
pub fn cluster_fingerprint(cluster: &Cluster) -> u64 {
    let mut fp = Fp::new();
    fp.tag(b'c');
    fp.usize(cluster.tiers.len());
    for tier in &cluster.tiers {
        fp.usize(tier.arity);
        fp.f64(tier.link_bw);
        fp.f64(tier.latency);
        fp.f64(tier.oversub);
    }
    let runs = cluster.pool.runs();
    fp.usize(runs.len());
    for run in runs {
        // Accelerator *name* is included: it reaches plans through
        // `StagePlan::accel_class`.
        fp.str(&run.accel.name);
        fp.f64(run.accel.matmul_peak);
        fp.f64(run.accel.matmul_eff);
        fp.f64(run.accel.vector_peak);
        fp.f64(run.accel.hbm_bw);
        fp.f64(run.accel.hbm_capacity);
        fp.usize(run.count);
        match run.access_bw {
            None => fp.tag(0),
            Some(bw) => {
                fp.tag(1);
                fp.f64(bw);
            }
        }
    }
    fp.finish()
}

/// One placement query: solve `graph` on `cluster` under `opts`.
#[derive(Debug, Clone)]
pub struct Query {
    pub graph: LayerGraph,
    pub cluster: Cluster,
    pub opts: SolverOpts,
}

impl Query {
    pub fn new(graph: LayerGraph, cluster: Cluster, opts: SolverOpts) -> Self {
        Query {
            graph,
            cluster,
            opts,
        }
    }

    /// See [`graph_fingerprint`].
    pub fn graph_fingerprint(&self) -> u64 {
        graph_fingerprint(&self.graph)
    }

    /// See [`cluster_fingerprint`].
    pub fn cluster_fingerprint(&self) -> u64 {
        cluster_fingerprint(&self.cluster)
    }

    /// Canonical content fingerprint of the whole query (see module
    /// docs for inclusion/exclusion semantics). Plan-invariant
    /// [`SolverOpts`] fields (`threads`, `pricing`, `warm_start`) are
    /// excluded: a warm-started 4-thread re-run of a cached query IS a
    /// cache hit, and returning the cached plan is sound because the
    /// solver's plans are independent of all three.
    pub fn fingerprint(&self) -> u64 {
        let _span = obs::span("service.fingerprint", "service");
        let mut fp = Fp::new();
        fp.tag(b'q');
        fp.u64(self.graph_fingerprint());
        fp.u64(self.cluster_fingerprint());
        fp.usize(self.opts.max_stages);
        fp.usize(self.opts.zero_max_degree);
        fp.bool(self.opts.try_recompute);
        fp.bool(self.opts.try_no_recompute);
        fp.finish()
    }

    /// Key for shared cost-table contexts: the (graph, cluster) pair
    /// without solver options (cost tables do not depend on them).
    fn context_key(&self) -> u64 {
        let mut fp = Fp::new();
        fp.tag(b'x');
        fp.u64(self.graph_fingerprint());
        fp.u64(self.cluster_fingerprint());
        fp.finish()
    }
}

// ---------------------------------------------------------------------
// The service
// ---------------------------------------------------------------------

/// Service counters, cumulative since construction.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServiceStats {
    /// Queries answered (cache hits + solves), including the internal
    /// queries each `reconcile` issues (two on the clean path, plus one
    /// per degradation-ladder rung).
    pub queries: u64,
    pub cache_hits: u64,
    /// Solves seeded from a neighboring cached plan.
    pub warm_solves: u64,
    /// Solves with no usable neighbor.
    pub cold_solves: u64,
    /// `reconcile` calls.
    pub reconciles: u64,
}

impl ServiceStats {
    /// Fraction of queries answered from cache (0.0 before any query).
    pub fn hit_rate(&self) -> f64 {
        if self.queries == 0 {
            0.0
        } else {
            self.cache_hits as f64 / self.queries as f64
        }
    }
}

/// How a query was answered.
#[derive(Debug, Clone)]
pub struct Served {
    /// The analytic top-K shortlist (index 0 = the winner); empty when
    /// no feasible placement exists. Bit-identical to what a cold
    /// `solve_topk` returns for the same query.
    pub plans: Vec<PlacementPlan>,
    /// Answered from cache without solving.
    pub cache_hit: bool,
    /// Solved with a neighbor-seeded warm start.
    pub warm_started: bool,
    /// Solver wall-clock for this query (0.0 on a cache hit).
    pub solve_seconds: f64,
    /// DP states of the solve that produced the plans (the original
    /// solve, on a hit).
    pub dp_states: u64,
    pub configs_tried: u64,
}

struct Entry {
    fp: u64,
    graph_fp: u64,
    cluster_fp: u64,
    /// Shortlist width this entry was solved at — a cached K=8 entry
    /// serves any request up to K=8; a K=1 entry cannot serve K=4.
    k: usize,
    plans: Vec<PlacementPlan>,
    dp_states: u64,
    configs_tried: u64,
}

/// An LRU cache of solved placement queries with warm-started misses.
/// See the module docs for the full story.
pub struct PlacementService {
    capacity: usize,
    /// Most-recently-used first. Linear scans: service caches hold tens
    /// of entries, far below hashing break-even, and eviction order
    /// falls out of the Vec for free.
    entries: Vec<Entry>,
    arena: CostArena,
    stats: ServiceStats,
}

impl PlacementService {
    /// A service caching up to `capacity` solved queries (min 1).
    pub fn new(capacity: usize) -> Self {
        PlacementService {
            capacity: capacity.max(1),
            entries: Vec::new(),
            arena: CostArena::new(),
            stats: ServiceStats::default(),
        }
    }

    pub fn stats(&self) -> ServiceStats {
        self.stats
    }

    /// Cached entries currently held.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Answer `query` with the single best plan (`None` = infeasible).
    pub fn solve(&mut self, query: &Query) -> Option<Served> {
        let served = self.solve_topk(query, 1);
        if served.plans.is_empty() {
            None
        } else {
            Some(served)
        }
    }

    /// Answer `query` with its analytic top-`k` shortlist: from cache
    /// on a fingerprint hit, warm-started from a neighboring entry
    /// (same graph or same cluster) otherwise. The returned plans are
    /// bit-identical to a cold `solve_topk` in every path.
    pub fn solve_topk(&mut self, query: &Query, k: usize) -> Served {
        // Per-query span + latency histogram (µs). The flight recorder
        // mirrors `ServiceStats` (which stays authoritative) so traces
        // are self-contained.
        let _span = obs::span_with("service.query", "service", || {
            vec![("k", k.max(1).to_string())]
        });
        let q_start = obs::enabled().then(obs::now_ns);
        let finish = |served: Served| -> Served {
            if let Some(s) = q_start {
                obs::record("service.query_us", (obs::now_ns() - s) / 1_000);
            }
            served
        };
        self.stats.queries += 1;
        let fp = query.fingerprint();
        if let Some(pos) = self
            .entries
            .iter()
            .position(|e| e.fp == fp && e.k >= k.max(1))
        {
            self.stats.cache_hits += 1;
            obs::count("service.cache_hit", 1);
            let entry = self.entries.remove(pos);
            let served = Served {
                plans: entry.plans.iter().take(k.max(1)).cloned().collect(),
                cache_hit: true,
                warm_started: false,
                solve_seconds: 0.0,
                dp_states: entry.dp_states,
                configs_tried: entry.configs_tried,
            };
            self.entries.insert(0, entry); // refresh LRU position
            return finish(served);
        }
        obs::count("service.cache_miss", 1);

        let graph_fp = query.graph_fingerprint();
        let cluster_fp = query.cluster_fingerprint();
        // Neighbor = most recent cached query sharing the graph (solved
        // on a scaled cluster) or the cluster (solved for another
        // model). Its winner's (sg, recompute) is a strong first guess;
        // evaluating it first tightens the incumbent early.
        let warm = self
            .entries
            .iter()
            .find(|e| (e.graph_fp == graph_fp || e.cluster_fp == cluster_fp) && !e.plans.is_empty())
            .map(|e| WarmStart::from_plan(&e.plans[0]));
        let warm_started = warm.is_some();
        if warm_started {
            self.stats.warm_solves += 1;
            obs::count("service.warm_neighbor", 1);
        } else {
            self.stats.cold_solves += 1;
        }

        let opts = SolverOpts {
            warm_start: warm,
            ..query.opts.clone()
        };
        let top = solve_topk(&query.graph, &query.cluster, &opts, k.max(1));

        self.entries.insert(
            0,
            Entry {
                fp,
                graph_fp,
                cluster_fp,
                k: k.max(1),
                plans: top.plans.clone(),
                dp_states: top.dp_states,
                configs_tried: top.configs_tried,
            },
        );
        let evicted = self.entries.len().saturating_sub(self.capacity);
        if evicted > 0 {
            obs::count("service.evict", evicted as u64);
            obs::instant("service.evict", "service", || {
                vec![("evicted", evicted.to_string())]
            });
        }
        self.entries.truncate(self.capacity);

        finish(Served {
            plans: top.plans,
            cache_hit: false,
            warm_started,
            solve_seconds: top.solve_seconds,
            dp_states: top.dp_states,
            configs_tried: top.configs_tried,
        })
    }

    /// Batched sweep evaluation: answer every query in order through
    /// the shared cache, warm-start chain, and cost-table arena —
    /// the (model sizes × cluster scales) co-design workload. Results
    /// are in query order.
    pub fn sweep(&mut self, queries: &[Query], k: usize) -> Vec<Served> {
        queries.iter().map(|q| self.solve_topk(q, k)).collect()
    }

    /// Contention-aware refinement through the cache: the analytic
    /// shortlist comes from [`Self::solve_topk`] (cached or
    /// warm-started), then is re-ranked on `topo` by the flow
    /// simulator — so a repeated refine of a cached query skips the
    /// solver entirely and pays only the K flow replays.
    pub fn refine(&mut self, query: &Query, topo: &LinkGraph, k: usize) -> Option<RefineReport> {
        let served = self.solve_topk(query, k);
        if served.plans.is_empty() {
            return None;
        }
        let mut sim = Simulation::new();
        let ranked = rerank(&mut sim, &query.graph, &query.cluster, topo, served.plans);
        Some(RefineReport {
            ranked,
            bg_loads: Vec::new(),
            solve_seconds: served.solve_seconds,
            dp_states: served.dp_states,
            configs_tried: served.configs_tried,
        })
    }

    /// Incremental re-solve after an elasticity or failure event: apply
    /// `delta` to the query's cluster, re-solve (warm-started from the
    /// original plan — same graph fingerprint), and price the migration
    /// between the two plans.
    ///
    /// When the post-delta cluster has no feasible placement under the
    /// query's own options, a graceful-degradation ladder progressively
    /// relaxes the query instead of erroring: (1) allow activation
    /// recomputation if the query had it off, (2) lift the query's
    /// stage-count cap, (3) concede outermost groups one at a time
    /// (shrink the replica set, leaving devices idle) down to a single
    /// group. The first feasible rung wins and every relaxation taken is
    /// reported as a [`Concession`] on a [`ReconcileOutcome::Degraded`];
    /// a plan found with no concessions is
    /// [`ReconcileOutcome::Clean`]. Errors only when the original query
    /// is infeasible, the delta is invalid, or nothing fits at the
    /// ladder's bottom.
    pub fn reconcile(
        &mut self,
        query: &Query,
        delta: &ClusterDelta,
    ) -> Result<ReconcileOutcome, ServiceError> {
        let _span = obs::span("service.reconcile", "service");
        self.stats.reconciles += 1;
        let before = self.solve(query).ok_or(ServiceError::InfeasibleOriginal)?;
        let old_plan = before.plans[0].clone();

        let mut cluster = delta.apply(&query.cluster)?;
        let mut opts = query.opts.clone();
        let mut concessions: Vec<Concession> = Vec::new();
        let mut after = self.solve_topk(
            &Query::new(query.graph.clone(), cluster.clone(), opts.clone()),
            1,
        );
        if after.plans.is_empty() && !opts.try_recompute {
            opts.try_recompute = true;
            concessions.push(Concession::AllowRecompute);
            after = self.solve_topk(
                &Query::new(query.graph.clone(), cluster.clone(), opts.clone()),
                1,
            );
        }
        if after.plans.is_empty() && opts.max_stages != 0 {
            concessions.push(Concession::WidenStages {
                from: opts.max_stages,
            });
            opts.max_stages = 0;
            after = self.solve_topk(
                &Query::new(query.graph.clone(), cluster.clone(), opts.clone()),
                1,
            );
        }
        while after.plans.is_empty()
            && cluster.tiers.last().map_or(false, |t| t.arity > 1)
        {
            let from_devices = cluster.n_devices();
            cluster = ClusterDelta::FailOuterGroups { groups: 1 }.apply(&cluster)?;
            concessions.push(Concession::ShrinkReplicas {
                from_devices,
                to_devices: cluster.n_devices(),
            });
            after = self.solve_topk(
                &Query::new(query.graph.clone(), cluster.clone(), opts.clone()),
                1,
            );
        }
        let plan = after
            .plans
            .first()
            .cloned()
            .ok_or(ServiceError::InfeasibleAfterDelta {
                devices: cluster.n_devices(),
            })?;

        let final_query = Query::new(query.graph.clone(), cluster.clone(), opts);
        let plan_delta = diff_plans_in(
            &mut self.arena,
            final_query.context_key(),
            &old_plan,
            &plan,
            &query.graph,
            &cluster,
        );
        let report = ReconcileReport {
            plan,
            delta: plan_delta,
            cluster,
            warm_started: after.warm_started,
            cache_hit: after.cache_hit,
            solve_seconds: after.solve_seconds,
        };
        if concessions.is_empty() {
            Ok(ReconcileOutcome::Clean(report))
        } else {
            obs::count("service.degraded_reconcile", 1);
            Ok(ReconcileOutcome::Degraded {
                report,
                concessions,
            })
        }
    }
}

// ---------------------------------------------------------------------
// Elasticity deltas
// ---------------------------------------------------------------------

/// An elasticity or failure event against a cluster. Whole-group
/// events act on the *outermost* tier — the unit real clusters grow
/// and shrink by (a rack or switch-group at a time); device ids pack
/// compactly, so the removed/added groups sit at the tail of the id
/// space. [`ClusterDelta::FailDevices`] accepts *arbitrary* device
/// ids and quantizes them to their outermost groups (see its docs);
/// [`ClusterDelta::DegradeLinks`] leaves the population alone and
/// thins a tier's bandwidth instead.
#[derive(Debug, Clone, PartialEq)]
pub enum ClusterDelta {
    /// `groups` outermost-tier groups fail (their devices leave the
    /// pool).
    FailOuterGroups { groups: usize },
    /// Arbitrary devices fail. The uniform tier stack cannot hold
    /// holes, so each failed device takes its whole outermost-tier
    /// group out (the blast-radius convention schedulers apply when a
    /// host dies); symmetric tiers make *which* groups fail irrelevant
    /// to pricing, so this is exactly `FailOuterGroups` over the
    /// distinct groups the ids land in.
    FailDevices { ids: Vec<usize> },
    /// Brownout of one tier: multiply tier `level`'s per-link bandwidth
    /// by `fraction` in `(0, 1]`. The population is untouched.
    DegradeLinks { level: usize, fraction: f64 },
    /// Resize the outermost tier to exactly `arity` groups (grow or
    /// shrink).
    ResizeOuter { arity: usize },
}

impl ClusterDelta {
    /// The cluster after this event. For population events the
    /// outermost tier's arity changes and the device pool is rebuilt by
    /// truncating runs from the tail (shrink) or extending the last run
    /// (grow); tier shapes below the outermost are untouched. For
    /// [`ClusterDelta::DegradeLinks`] only the tier's bandwidth moves.
    pub fn apply(&self, cluster: &Cluster) -> Result<Cluster, ServiceError> {
        let invalid = |msg: String| Err(ServiceError::InvalidDelta(msg));
        let n_tiers = cluster.tiers.len();
        if n_tiers == 0 {
            return invalid("cluster has no tiers".into());
        }
        let old_arity = cluster.tiers[n_tiers - 1].arity;
        let new_arity = match self {
            ClusterDelta::FailOuterGroups { groups } => {
                let groups = *groups;
                if groups == 0 {
                    return invalid("FailOuterGroups: zero groups is a no-op delta".into());
                }
                if groups >= old_arity {
                    return invalid(format!(
                        "FailOuterGroups: failing {groups} of {old_arity} outer groups \
                         would empty the cluster"
                    ));
                }
                old_arity - groups
            }
            ClusterDelta::FailDevices { ids } => {
                if ids.is_empty() {
                    return invalid("FailDevices: empty device list is a no-op delta".into());
                }
                let n = cluster.n_devices();
                let per_group = (n / old_arity).max(1);
                let mut hit = vec![false; old_arity];
                for &id in ids {
                    if id >= n {
                        return invalid(format!(
                            "FailDevices: device {id} out of range (cluster has {n})"
                        ));
                    }
                    hit[(id / per_group).min(old_arity - 1)] = true;
                }
                let groups = hit.iter().filter(|&&h| h).count();
                if groups >= old_arity {
                    return invalid(format!(
                        "FailDevices: the {} failed devices touch every one of the \
                         {old_arity} outer groups — nothing would remain",
                        ids.len()
                    ));
                }
                old_arity - groups
            }
            ClusterDelta::DegradeLinks { level, fraction } => {
                let (level, fraction) = (*level, *fraction);
                if level >= n_tiers {
                    return invalid(format!(
                        "DegradeLinks: tier level {level} out of range \
                         (cluster has {n_tiers} tiers)"
                    ));
                }
                if !(fraction > 0.0 && fraction <= 1.0 && fraction.is_finite()) {
                    return invalid(format!(
                        "DegradeLinks: fraction {fraction} must be in (0, 1]"
                    ));
                }
                let mut tiers = cluster.tiers.clone();
                tiers[level].link_bw *= fraction;
                return Ok(Cluster {
                    name: cluster.name.clone(),
                    pool: cluster.pool.clone(),
                    tiers,
                });
            }
            ClusterDelta::ResizeOuter { arity } => {
                if *arity == 0 {
                    return invalid("ResizeOuter: zero arity would empty the cluster".into());
                }
                *arity
            }
        };

        let old_n = cluster.n_devices();
        let per_group = old_n / old_arity;
        let new_n = per_group * new_arity;

        let mut runs = cluster.pool.runs().to_vec();
        if new_n < old_n {
            let mut excess = old_n - new_n;
            while excess > 0 {
                let last = runs.last_mut().expect("pool runs cover all devices");
                if last.count > excess {
                    last.count -= excess;
                    excess = 0;
                } else {
                    excess -= last.count;
                    runs.pop();
                }
            }
        } else if new_n > old_n {
            // Grown capacity arrives as more of whatever the tail run
            // already is (racks are bought in like kind).
            runs.last_mut()
                .expect("pool runs cover all devices")
                .count += new_n - old_n;
        }

        let mut tiers = cluster.tiers.clone();
        tiers[n_tiers - 1].arity = new_arity;
        Ok(Cluster {
            name: cluster.name.clone(),
            pool: crate::hw::DevicePool::from_runs(runs),
            tiers,
        })
    }
}

/// One rung of the degradation ladder [`PlacementService::reconcile`]
/// had to take to find a feasible plan, in the order granted.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Concession {
    /// Enabled the activation-recomputation branch the query had off.
    AllowRecompute,
    /// Lifted the query's stage-count cap (`max_stages: from` → 0,
    /// i.e. up to one stage per layer).
    WidenStages { from: usize },
    /// Conceded one outermost group — shrank the replica set, leaving
    /// `from_devices − to_devices` healthy devices idle — because
    /// nothing fit the full post-delta population.
    ShrinkReplicas {
        from_devices: usize,
        to_devices: usize,
    },
}

impl std::fmt::Display for Concession {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Concession::AllowRecompute => write!(f, "allowed activation recomputation"),
            Concession::WidenStages { from } => {
                write!(f, "lifted the stage cap (was {from})")
            }
            Concession::ShrinkReplicas {
                from_devices,
                to_devices,
            } => write!(f, "shrank the replica set {from_devices}→{to_devices} devices"),
        }
    }
}

/// How [`PlacementService::reconcile`] answered: cleanly, or only by
/// degrading the query. Both carry a valid [`ReconcileReport`]; the
/// distinction is matchable (the `timed_out`-style flag is
/// [`ReconcileOutcome::degraded`]).
#[derive(Debug, Clone)]
pub enum ReconcileOutcome {
    /// The post-delta cluster fit the query's own options untouched.
    Clean(ReconcileReport),
    /// Feasible only after relaxations; `concessions` lists every rung
    /// taken, in order.
    Degraded {
        report: ReconcileReport,
        concessions: Vec<Concession>,
    },
}

impl ReconcileOutcome {
    pub fn report(&self) -> &ReconcileReport {
        match self {
            ReconcileOutcome::Clean(r) => r,
            ReconcileOutcome::Degraded { report, .. } => report,
        }
    }

    pub fn into_report(self) -> ReconcileReport {
        match self {
            ReconcileOutcome::Clean(r) => r,
            ReconcileOutcome::Degraded { report, .. } => report,
        }
    }

    /// Did the ladder have to give anything up?
    pub fn degraded(&self) -> bool {
        matches!(self, ReconcileOutcome::Degraded { .. })
    }

    pub fn concessions(&self) -> &[Concession] {
        match self {
            ReconcileOutcome::Clean(_) => &[],
            ReconcileOutcome::Degraded { concessions, .. } => concessions,
        }
    }
}

/// The reconciled plan and its migration price (carried by every
/// [`ReconcileOutcome`]).
#[derive(Debug, Clone)]
pub struct ReconcileReport {
    /// The re-solved plan on the post-delta cluster.
    pub plan: PlacementPlan,
    /// What moving from the old plan to `plan` costs.
    pub delta: PlanDelta,
    /// The post-delta cluster the plan runs on.
    pub cluster: Cluster,
    /// The re-solve was warm-started (it is, whenever the original
    /// query's entry is still cached — same graph fingerprint).
    pub warm_started: bool,
    /// The post-delta query was itself already cached.
    pub cache_hit: bool,
    pub solve_seconds: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;

    fn opts() -> SolverOpts {
        SolverOpts {
            threads: 1,
            ..Default::default()
        }
    }

    fn query(devices: usize) -> Query {
        Query::new(
            models::bert_large(1),
            Cluster::v100_cluster(devices),
            opts(),
        )
    }

    #[test]
    fn fingerprint_is_stable_and_content_sensitive() {
        let q = query(8);
        assert_eq!(q.fingerprint(), q.clone().fingerprint());

        let mut batch = q.clone();
        batch.graph.mbs += 1;
        assert_ne!(q.fingerprint(), batch.fingerprint());
        assert_ne!(q.graph_fingerprint(), batch.graph_fingerprint());
        assert_eq!(q.cluster_fingerprint(), batch.cluster_fingerprint());

        let mut fabric = q.clone();
        fabric.cluster.tiers[1].link_bw *= 2.0;
        assert_ne!(q.fingerprint(), fabric.fingerprint());
        assert_eq!(q.graph_fingerprint(), fabric.graph_fingerprint());

        let mut solver = q.clone();
        solver.opts.max_stages = 2;
        assert_ne!(q.fingerprint(), solver.fingerprint());
    }

    #[test]
    fn fingerprint_ignores_plan_invariant_fields_and_labels() {
        let q = query(8);
        let mut twin = q.clone();
        twin.opts.threads = 7;
        twin.opts.pricing = crate::cost::PricingMode::Reference;
        twin.opts.warm_start = Some(WarmStart {
            sg: crate::graph::subgraph::SgConfig::serial(),
            recompute: true,
        });
        twin.cluster.name = "renamed".into();
        twin.cluster.tiers[0].name = "relabelled".into();
        assert_eq!(q.fingerprint(), twin.fingerprint());
        assert_eq!(q.cluster_fingerprint(), twin.cluster_fingerprint());
    }

    #[test]
    fn cache_hit_returns_identical_plans_and_counts() {
        let mut svc = PlacementService::new(8);
        let q = query(8);
        let cold = svc.solve_topk(&q, 4);
        assert!(!cold.cache_hit);
        let hit = svc.solve_topk(&q, 4);
        assert!(hit.cache_hit);
        assert!(!hit.warm_started);
        assert_eq!(hit.solve_seconds, 0.0);
        assert_eq!(cold.plans, hit.plans);
        // Narrower K is served from the same entry, truncated.
        let narrow = svc.solve_topk(&q, 1);
        assert!(narrow.cache_hit);
        assert_eq!(narrow.plans.len(), 1);
        assert_eq!(narrow.plans[0], cold.plans[0]);
        // Wider K cannot be served from a narrower entry.
        let wide = svc.solve_topk(&q, 8);
        assert!(!wide.cache_hit);
        assert_eq!(svc.stats().queries, 4);
        assert_eq!(svc.stats().cache_hits, 2);
    }

    #[test]
    fn lru_evicts_oldest_at_capacity() {
        let mut svc = PlacementService::new(1);
        let a = query(8);
        let b = query(16);
        svc.solve_topk(&a, 1);
        assert_eq!(svc.len(), 1);
        svc.solve_topk(&b, 1); // evicts a
        assert_eq!(svc.len(), 1);
        let again = svc.solve_topk(&a, 1);
        assert!(!again.cache_hit, "evicted entry must not hit");
        // b was warm-startable from a (same graph), and a's re-solve
        // from b likewise.
        assert_eq!(svc.stats().warm_solves, 2);
        assert_eq!(svc.stats().cold_solves, 1);
    }

    #[test]
    fn warm_started_solve_matches_cold_solve() {
        let mut svc = PlacementService::new(4);
        let small = query(8);
        let big = query(16);
        svc.solve_topk(&small, 1);
        let warm = svc.solve_topk(&big, 1);
        assert!(warm.warm_started, "same graph on scaled cluster must warm");
        let cold = solve_topk(&big.graph, &big.cluster, &big.opts, 1);
        assert_eq!(warm.plans, cold.plans);
    }

    #[test]
    fn cluster_delta_fail_and_resize_adjust_device_count() {
        let c = Cluster::v100_cluster(16); // node arity 2 × switch arity 8
        let shrunk = ClusterDelta::FailOuterGroups { groups: 2 }
            .apply(&c)
            .unwrap();
        assert_eq!(shrunk.n_devices(), 12);
        assert_eq!(shrunk.tiers[1].arity, 6);
        assert_eq!(shrunk.tiers[0].arity, 2, "inner tiers untouched");

        let grown = ClusterDelta::ResizeOuter { arity: 16 }.apply(&c).unwrap();
        assert_eq!(grown.n_devices(), 32);

        assert!(ClusterDelta::FailOuterGroups { groups: 8 }.apply(&c).is_err());
        assert!(ClusterDelta::FailOuterGroups { groups: 0 }.apply(&c).is_err());
        assert!(ClusterDelta::ResizeOuter { arity: 0 }.apply(&c).is_err());
    }

    #[test]
    fn cluster_delta_preserves_hetero_run_structure() {
        let c = Cluster::hetero_pool(64);
        let n_runs = c.pool.runs().len();
        let shrunk = ClusterDelta::FailOuterGroups { groups: 1 }
            .apply(&c)
            .unwrap();
        assert!(shrunk.n_devices() < 64);
        // The tail run shrank (or vanished); earlier runs are intact.
        assert!(shrunk.pool.runs().len() <= n_runs);
        assert_eq!(shrunk.pool.runs()[0].accel, c.pool.runs()[0].accel);
    }

    #[test]
    fn reconcile_reprices_migration_after_failure() {
        let mut svc = PlacementService::new(8);
        let q = query(16);
        let outcome = svc
            .reconcile(&q, &ClusterDelta::FailOuterGroups { groups: 4 })
            .expect("feasible on 8 devices");
        assert!(!outcome.degraded(), "a clean fit must not concede anything");
        assert!(outcome.concessions().is_empty());
        let report = outcome.report();
        assert_eq!(report.cluster.n_devices(), 8);
        report
            .plan
            .validate(&q.graph, &report.cluster)
            .expect("reconciled plan valid on shrunk cluster");
        assert!(
            report.warm_started,
            "re-solve warms from the just-cached original"
        );
        // The shrunk plan is exactly what a cold solve on the shrunk
        // cluster produces — reconcile never invents a different plan.
        let shrunk = ClusterDelta::FailOuterGroups { groups: 4 }.apply(&q.cluster).unwrap();
        let cold = solve_topk(&q.graph, &shrunk, &q.opts, 1);
        assert_eq!(report.plan, cold.plans[0]);
        assert_eq!(svc.stats().reconciles, 1);
    }

    #[test]
    fn fail_devices_quantizes_to_outer_groups() {
        let c = Cluster::v100_cluster(16); // node arity 2 × switch arity 8
        // Two ids in one group (devices 0,1 share outer group 0): one
        // group fails.
        let one = ClusterDelta::FailDevices { ids: vec![0, 1] }.apply(&c).unwrap();
        assert_eq!(one.n_devices(), 14);
        assert_eq!(one.tiers[1].arity, 7);
        // Ids spread over two groups: both fail — and the result equals
        // the whole-group delta (symmetric tiers: which groups is moot).
        let two = ClusterDelta::FailDevices { ids: vec![0, 15, 1] }.apply(&c).unwrap();
        let twin = ClusterDelta::FailOuterGroups { groups: 2 }.apply(&c).unwrap();
        assert_eq!(two.n_devices(), twin.n_devices());
        assert_eq!(two.tiers[1].arity, twin.tiers[1].arity);

        // Typed rejections.
        match ClusterDelta::FailDevices { ids: vec![] }.apply(&c) {
            Err(ServiceError::InvalidDelta(msg)) => assert!(msg.contains("empty")),
            other => panic!("expected InvalidDelta, got {other:?}"),
        }
        match ClusterDelta::FailDevices { ids: vec![16] }.apply(&c) {
            Err(ServiceError::InvalidDelta(msg)) => assert!(msg.contains("out of range")),
            other => panic!("expected InvalidDelta, got {other:?}"),
        }
        let all: Vec<usize> = (0..16).collect();
        assert!(matches!(
            ClusterDelta::FailDevices { ids: all }.apply(&c),
            Err(ServiceError::InvalidDelta(_))
        ));
    }

    #[test]
    fn degrade_links_thins_one_tier_only() {
        let c = Cluster::v100_cluster(16);
        let d = ClusterDelta::DegradeLinks {
            level: 1,
            fraction: 0.5,
        }
        .apply(&c)
        .unwrap();
        assert_eq!(d.n_devices(), c.n_devices(), "population untouched");
        assert_eq!(d.tiers[1].link_bw, c.tiers[1].link_bw * 0.5);
        assert_eq!(d.tiers[0].link_bw, c.tiers[0].link_bw);
        assert!(matches!(
            ClusterDelta::DegradeLinks { level: 9, fraction: 0.5 }.apply(&c),
            Err(ServiceError::InvalidDelta(_))
        ));
        for bad in [0.0, -0.5, 1.5, f64::NAN] {
            assert!(matches!(
                ClusterDelta::DegradeLinks { level: 0, fraction: bad }.apply(&c),
                Err(ServiceError::InvalidDelta(_))
            ));
        }
    }

    #[test]
    fn reconcile_under_fail_devices_returns_a_valid_plan() {
        // The acceptance bar: arbitrary failed devices produce a valid
        // (possibly degraded) plan, not an error, whenever anything fits.
        let mut svc = PlacementService::new(8);
        let q = query(16);
        let outcome = svc
            .reconcile(&q, &ClusterDelta::FailDevices { ids: vec![3, 9] })
            .expect("a 12-device fit exists");
        let report = outcome.report();
        assert_eq!(report.cluster.n_devices(), 12);
        report
            .plan
            .validate(&q.graph, &report.cluster)
            .expect("plan valid on the post-failure cluster");
        if outcome.degraded() {
            assert!(!outcome.concessions().is_empty());
        }
    }

    #[test]
    fn reconcile_errors_are_typed_and_displayable() {
        let mut svc = PlacementService::new(8);
        let q = query(16);
        // An invalid delta surfaces as InvalidDelta, not a panic or a
        // degraded plan.
        let err = svc
            .reconcile(&q, &ClusterDelta::FailDevices { ids: vec![99] })
            .unwrap_err();
        assert!(matches!(err, ServiceError::InvalidDelta(_)));
        assert!(err.to_string().contains("out of range"));
        assert_eq!(
            ServiceError::InfeasibleAfterDelta { devices: 4 }.to_string(),
            "reconcile: no feasible placement on the post-delta cluster \
             (4 devices), even after the degradation ladder"
        );
        assert!(ServiceError::InfeasibleOriginal.to_string().contains("original cluster"));
    }

    #[test]
    fn degradation_ladder_reports_what_it_gave_up() {
        // A deliberately over-constrained query: one pipeline stage, no
        // ZeRO, no recompute. Whether the post-delta cluster fits it
        // directly or only via the ladder, reconcile must return a
        // valid plan — and any concessions must be real relaxations in
        // ladder order (recompute before stage-widening before
        // replica-shrinking).
        let graph = models::bert_large(1);
        let cluster = Cluster::v100_cluster(16);
        let tight = SolverOpts {
            threads: 1,
            max_stages: 1,
            zero_max_degree: 1,
            try_recompute: false,
            ..Default::default()
        };
        let q = Query::new(graph, cluster, tight);
        let mut svc = PlacementService::new(8);
        if svc.solve(&q).is_none() {
            // The original query itself doesn't fit this cell — the
            // ladder is out of scope here (covered by chaos harness).
            return;
        }
        match svc.reconcile(&q, &ClusterDelta::FailOuterGroups { groups: 6 }) {
            Ok(outcome) => {
                let report = outcome.report();
                report
                    .plan
                    .validate(&q.graph, &report.cluster)
                    .expect("ladder plan validates");
                let mut last_rung = 0usize;
                for c in outcome.concessions() {
                    let rung = match c {
                        Concession::AllowRecompute => 1,
                        Concession::WidenStages { from } => {
                            assert_eq!(*from, 1);
                            2
                        }
                        Concession::ShrinkReplicas {
                            from_devices,
                            to_devices,
                        } => {
                            assert!(to_devices < from_devices);
                            3
                        }
                    };
                    assert!(rung >= last_rung, "ladder out of order");
                    last_rung = rung;
                    assert!(!c.to_string().is_empty());
                }
            }
            Err(ServiceError::InfeasibleAfterDelta { devices }) => {
                // Allowed only at the true bottom: a single outer group.
                assert_eq!(devices, 2);
            }
            Err(e) => panic!("unexpected reconcile error: {e}"),
        }
    }
}
