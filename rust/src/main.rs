//! `nest` — CLI for the NEST reproduction.
//!
//! Subcommands (see README):
//!   solve      solve placement for one (model, cluster) and print the plan
//!   simulate   run the DES on the solved plan and report throughput
//!   netsim     flow-level contention cross-check of a plan on an explicit
//!              link graph (tier stacks or arbitrary edge-list JSON)
//!   netsim-xval  analytic-vs-flow-sim error table across topology families
//!   netsim-scale decomposed flow simulation on a generated fat-tree, with
//!              the monolithic twin as a bit-identity gate
//!   refine     top-K analytic shortlist re-ranked by the flow simulator
//!              (`--bg-load` replays the shortlist under background traffic)
//!   refine-xval  cross-topology refinement table (where the ranking flips)
//!   mix        multi-tenant harness: shortlist refined under background
//!              load across topology families (plan flips per load level)
//!   chaos      fault-injection survival table: shortlist replayed under
//!              seeded link/straggler faults per severity, plus the
//!              service's reconcile-under-failure column
//!   bench-smoke  deterministic perf smoke + CI bench-regression gate
//!   serve-bench  placement-service throughput (queries/s, cache hit rate,
//!              warm-start speedup, elasticity migration cost)
//!   obs-summary  human tables from a `--trace` flight-recorder file
//!              (top spans by self-time, prune effectiveness, cache hits)
//!   train      real pipeline-parallel training from AOT artifacts
//!   profile    calibrate the compute model against PJRT probe runs
//!   figure2|5|6|7|10|11, table2|4|6|7, v100   — paper reproductions
//!   all        every figure + table (the full evaluation)

use nest::graph::models;
use nest::harness::{figures, tables, HarnessOpts};
use nest::netsim::{LinkGraph, SimMode, Simulation};
use nest::network::Cluster;
use nest::sim::{simulate, Schedule};
use nest::solver::refine::{refine_under_load, RefineOpts};
use nest::solver::{solve, SolverOpts};
use nest::trainer::{train, TrainOpts};
use nest::util::cli::Args;

fn cluster_by_name(name: &str, devices: usize, oversub: f64) -> Result<Cluster, String> {
    match name {
        "fat-tree" | "tpuv4" => Ok(Cluster::fat_tree_tpuv4(devices)),
        "spine-leaf" | "h100" => Ok(Cluster::spine_leaf_h100(devices, oversub)),
        "v100" => Ok(Cluster::v100_cluster(devices)),
        "hetero" => Ok(Cluster::hetero_pool(devices)),
        "torus2d" => {
            let side = (devices as f64).sqrt() as usize;
            Ok(Cluster::torus2d(side, devices / side, 50.0 * 1e9, 1e-6))
        }
        path if path.ends_with(".json") => {
            let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
            let v = nest::util::json::parse(&text)?;
            Cluster::from_json(&v)
        }
        other => {
            // Bare name fallback: a shipped config under configs/
            // (`--config dgx_superpod` ≡ `--cluster configs/dgx_superpod.json`).
            let shipped = format!("configs/{other}.json");
            if std::path::Path::new(&shipped).is_file() {
                let text = std::fs::read_to_string(&shipped).map_err(|e| e.to_string())?;
                let v = nest::util::json::parse(&text)?;
                return Cluster::from_json(&v);
            }
            Err(format!(
                "unknown cluster '{other}' (fat-tree, spine-leaf, v100, hetero, torus2d, \
                 a configs/ name, or a .json file)"
            ))
        }
    }
}

/// Resolve a `netsim` topology argument: a tier-stack or edge-list JSON
/// file, or a named preset cluster. Returns the explicit link graph and
/// the analytic cluster the solver searches on (for edge-lists, the
/// optimistic flat abstraction — see `LinkGraph::approx_cluster`).
fn netsim_topology(
    config: &str,
    devices: usize,
    oversub: f64,
) -> Result<(Cluster, LinkGraph), String> {
    if config.ends_with(".json") {
        let text = std::fs::read_to_string(config).map_err(|e| format!("{config}: {e}"))?;
        let v = nest::util::json::parse(&text)?;
        if v.get("links").as_arr().is_some() {
            let topo = LinkGraph::from_json(&v)?;
            let accel_name = v.get("accelerator").as_str().unwrap_or("h100");
            let accel = nest::hw::Accelerator::by_name(accel_name)
                .ok_or_else(|| format!("unknown accelerator '{accel_name}'"))?;
            let cluster = topo.approx_cluster(accel);
            Ok((cluster, topo))
        } else {
            let cluster = Cluster::from_json(&v)?;
            let topo = LinkGraph::from_cluster(&cluster);
            Ok((cluster, topo))
        }
    } else {
        let cluster = cluster_by_name(config, devices, oversub)?;
        let topo = LinkGraph::from_cluster(&cluster);
        Ok((cluster, topo))
    }
}

/// Parse a `--key 0.3,0.6` comma-separated list of fractional levels
/// (`--bg-load` background loads, `--fault-severity` fault severities).
/// Every element is validated through `Args::get_f64_in_range`, so list
/// elements reject with the exact message a scalar flag would.
/// Empty/absent ⇒ no levels (the caller's default applies).
fn parse_level_list(
    args: &mut Args,
    key: &str,
    min: f64,
    max: f64,
) -> Result<Vec<f64>, String> {
    let Some(raw) = args.get_opt(key) else {
        return Ok(Vec::new());
    };
    let mut levels = Vec::new();
    for part in raw.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        // The `=` form survives any element text (even a stray `--`),
        // so garbage always reaches the numeric validator.
        let mut one = Args::parse(vec![format!("--{key}={part}")]);
        let v = one.get_f64_in_range(key, min, min, max);
        one.check()?;
        levels.push(v);
    }
    if levels.is_empty() {
        return Err(format!(
            "--{key}: expected at least one level, e.g. 0.3,0.6"
        ));
    }
    Ok(levels)
}

/// Parse a `--bg-load 0.3,0.6` list of target max per-link background
/// loads (fractions of capacity, each in [0, 1]).
fn parse_bg_loads(args: &mut Args) -> Result<Vec<f64>, String> {
    parse_level_list(args, "bg-load", 0.0, 1.0)
}

fn main() {
    let mut args = Args::from_env();
    let cmd = args
        .positional()
        .first()
        .cloned()
        .unwrap_or_else(|| "help".into());

    // Common options.
    let model = args.get("model", "llama2-7b");
    let devices = args.get_usize("devices", 64);
    let mbs = args.get_usize("mbs", 1);
    let cluster_name = args.get("cluster", "fat-tree");
    let oversub = args.get_f64("oversub", 2.0);
    let quick = args.has_flag("quick");
    let results_dir = args.get("results", "results");
    // Solver worker threads (omit for one per core); plans are identical
    // for every thread count — see nest::solver docs. An explicit
    // `--threads 0` is a clean error, not a silent hang. The same count
    // drives the flow simulator's decomposed-mode workers.
    let threads = args.get_usize_nonzero("threads", 0);
    // Flow-simulator execution mode, shared by every sim-touching
    // subcommand (netsim, netsim-xval, refine, refine-xval; netsim-scale
    // always runs both modes). Reports are bit-identical across modes.
    let sim_mode = match args
        .get_choice("mode", &["auto", "monolithic", "decomposed"], "auto")
        .as_str()
    {
        "monolithic" => SimMode::Monolithic,
        "decomposed" => SimMode::Decomposed,
        _ => SimMode::Auto,
    };
    // Flight recorder: `--trace <path>` (path-validated) wins over the
    // NEST_TRACE environment variable. `obs-summary` *reads* a trace
    // instead of recording one, so it opts out here and parses the flag
    // itself. Tracing is strictly observational: plans and reports are
    // bit-identical with it on or off (see nest::obs).
    let trace = if cmd == "obs-summary" {
        None
    } else {
        args.get_out_path("trace").or_else(nest::obs::env_trace_path)
    };
    if trace.is_some() {
        nest::obs::set_enabled(true);
    }
    // Fail fast on malformed common flags before any solve starts.
    if let Err(e) = args.check() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }

    let mut hopts = if quick {
        HarnessOpts::quick()
    } else {
        HarnessOpts::default()
    }
    .with_threads(threads);
    hopts.netsim.mode = sim_mode;
    hopts.results_dir = results_dir;

    let run = |args: &mut Args| -> Result<(), String> {
        match cmd.as_str() {
            "solve" | "simulate" => {
                let graph = models::by_name(&model, mbs)
                    .ok_or_else(|| format!("unknown model '{model}'"))?;
                // `--config` is accepted as an alias for `--cluster`
                // (matching the netsim/refine subcommands' spelling).
                let cluster_src = args.get_opt("config").unwrap_or_else(|| cluster_name.clone());
                let cluster = cluster_by_name(&cluster_src, devices, oversub)?;
                println!("{}", cluster.describe());
                let sopts = SolverOpts {
                    threads,
                    ..Default::default()
                };
                let sol = solve(&graph, &cluster, &sopts).ok_or("no feasible placement")?;
                if let Some(out) = args.get_opt("out") {
                    std::fs::write(
                        &out,
                        nest::util::json::to_pretty(&sol.plan.to_json()),
                    )
                    .map_err(|e| e.to_string())?;
                    println!("plan written to {out}");
                }
                println!(
                    "solved in {} ({} DP states, {} configs)",
                    nest::util::table::fmt_time(sol.solve_seconds),
                    sol.dp_states,
                    sol.configs_tried
                );
                println!("{}", sol.plan.describe());
                if cmd == "simulate" {
                    let rep = simulate(&graph, &cluster, &sol.plan, Schedule::OneFOneB);
                    println!(
                        "DES: batch {} | {:.1} samples/s | comm {:.1}% | bubble {:.1}%",
                        nest::util::table::fmt_time(rep.batch_time),
                        rep.throughput,
                        rep.comm_fraction * 100.0,
                        rep.bubble_fraction * 100.0
                    );
                }
                Ok(())
            }
            "train" => {
                let dir = nest::runtime::artifacts_dir()
                    .ok_or("artifacts/ missing — run `make artifacts`")?;
                let opts = TrainOpts {
                    steps: args.get_usize("steps", 20),
                    microbatches: args.get_usize("microbatches", 8),
                    dp_width: args.get_usize("dp", 1),
                    link_delay: args.get_f64("link-delay", 0.0),
                    seed: args.get_usize("seed", 42) as u64,
                    log_every: args.get_usize("log-every", 1),
                };
                args.check()?;
                let rep = train(&dir, &opts).map_err(|e| format!("{e:#}"))?;
                println!(
                    "trained {} steps | {:.0} tokens/s | loss {:.4} → {:.4}",
                    rep.losses.len(),
                    rep.tokens_per_s,
                    rep.losses.first().unwrap_or(&0.0),
                    rep.losses.last().unwrap_or(&0.0)
                );
                println!("stage busy fractions: {:?}", rep.stage_busy);
                Ok(())
            }
            "profile" => {
                let dir = nest::runtime::artifacts_dir()
                    .ok_or("artifacts/ missing — run `make artifacts`")?;
                let reps = args.get_usize("reps", 10);
                args.check()?;
                let cal = nest::profiler::calibrate(&dir, reps).map_err(|e| format!("{e:#}"))?;
                for p in &cal.probes {
                    println!(
                        "probe h={:4}: {} median, {:.2} GFLOP/s achieved",
                        p.hidden,
                        nest::util::table::fmt_time(p.median_seconds),
                        p.achieved_flops_per_s / 1e9
                    );
                }
                println!(
                    "calibrated cpu-sim matmul rate: {:.2} GFLOP/s",
                    cal.accel.matmul_peak / 1e9
                );
                Ok(())
            }
            "netsim" => {
                let graph = models::by_name(&model, mbs)
                    .ok_or_else(|| format!("unknown model '{model}'"))?;
                let config = args.get("config", &cluster_name);
                let (cluster, topo) = netsim_topology(&config, devices, oversub)?;
                println!("{}", cluster.describe());
                println!("{}", topo.describe());
                let sopts = SolverOpts {
                    threads,
                    ..Default::default()
                };
                let sol = solve(&graph, &cluster, &sopts).ok_or("no feasible placement")?;
                println!("{}", sol.plan.describe());
                let ana = simulate(&graph, &cluster, &sol.plan, Schedule::OneFOneB);
                let flow = Simulation::with_opts(hopts.netsim)
                    .run(&graph, &cluster, &topo, &sol.plan, Schedule::OneFOneB);
                let err = (flow.batch_time - ana.batch_time) / ana.batch_time;
                println!(
                    "analytic DES: batch {} | {:.1} samples/s",
                    nest::util::table::fmt_time(ana.batch_time),
                    ana.throughput,
                );
                println!(
                    "flow-sim:     batch {} | {:.1} samples/s | {} flows, {:.2} GB, {} events | error {:+.1}%",
                    nest::util::table::fmt_time(flow.batch_time),
                    graph.global_batch as f64 / flow.batch_time,
                    flow.n_flows,
                    flow.total_bytes / 1e9,
                    flow.events,
                    err * 100.0,
                );
                println!("hottest links (mean utilization over the batch):");
                for u in flow.link_util.iter().take(8) {
                    println!("  {:>6.1}%  {}", u.utilization * 100.0, u.name);
                }
                Ok(())
            }
            "netsim-scale" => {
                let k = args.get_usize_nonzero("k", if quick { 4 } else { 16 });
                let flows = args.get_usize_nonzero("flows", if quick { 2_000 } else { 200_000 });
                let seed = args.get_usize("seed", 42) as u64;
                let locality = args.get_f64_in_range("locality", 0.9, 0.0, 1.0);
                args.check()?;
                if k % 2 != 0 {
                    return Err(format!("--k must be even (fat-tree arity), got {k}"));
                }
                let out = nest::harness::scale::netsim_scale(&nest::harness::scale::ScaleOpts {
                    k,
                    flows,
                    seed,
                    threads,
                    locality,
                });
                if out.ok {
                    Ok(())
                } else {
                    Err("netsim-scale: decomposed report diverged from the monolithic twin"
                        .into())
                }
            }
            "netsim-xval" => {
                if nest::harness::netsim::netsim_xval_quick(&hopts, quick) {
                    Ok(())
                } else {
                    Err("netsim cross-validation regression: flow-sim undercut \
                         the analytic DES on a contended topology"
                        .into())
                }
            }
            "refine" => {
                let graph = models::by_name(&model, mbs)
                    .ok_or_else(|| format!("unknown model '{model}'"))?;
                let config = args.get("config", &cluster_name);
                let topk = args.get_usize_nonzero("topk", 4);
                let bg_loads = parse_bg_loads(args)?;
                // Fault axis: `--fault-severity 0.4,0.8` replays the
                // shortlist under seeded fault scenarios per level and
                // re-ranks by throughput retention.
                let fault_severities = parse_level_list(args, "fault-severity", 0.0, 1.0)?;
                let fault_scenarios = args.get_usize_nonzero("fault-scenarios", 2);
                let fault_seed = args.get_usize("fault-seed", 0xFA17) as u64;
                // `--rank mean` averages degradation across levels instead
                // of taking the worst case (the default).
                let worst_case =
                    args.get_choice("rank", &["worst", "mean"], "worst") == "worst";
                args.check()?;
                let (cluster, topo) = netsim_topology(&config, devices, oversub)?;
                println!("{}", cluster.describe());
                println!("{}", topo.describe());
                let sopts = SolverOpts {
                    threads,
                    ..Default::default()
                };
                let ropts = RefineOpts {
                    topk,
                    netsim: hopts.netsim,
                    bg_loads,
                    worst_case,
                    fault_severities,
                    fault_scenarios,
                    fault_seed,
                    ..Default::default()
                };
                let report = refine_under_load(&graph, &cluster, &topo, &sopts, &ropts)
                    .ok_or("no feasible placement")?;
                println!(
                    "shortlist of {} solved in {} ({} DP states, {} configs)",
                    report.ranked.len(),
                    nest::util::table::fmt_time(report.solve_seconds),
                    report.dp_states,
                    report.configs_tried
                );
                println!("{}", report.render_table());
                // Consistency cross-check (CI smoke): the shortlist's
                // analytic rank-1 plan must be exactly what plain
                // `solve` returns, at any K.
                let direct = solve(&graph, &cluster, &sopts).ok_or("no feasible placement")?;
                if report.analytic_winner().plan != direct.plan {
                    return Err(
                        "refine shortlist disagrees with solve(): the analytic rank-1 \
                         plan differs from the plain solver's winner"
                            .into(),
                    );
                }
                if report.winner_changed() {
                    if report.bg_loads.is_empty() {
                        println!(
                            "re-ranked winner: {} (dp rank {}) — {:.1}% faster than the \
                             analytic winner under link contention",
                            report.winner().plan.strategy_string(),
                            report.winner().analytic_rank + 1,
                            report.sim_improvement() * 100.0
                        );
                    } else {
                        println!(
                            "re-ranked winner: {} (dp rank {}) — degrades less under \
                             background load than the analytic rank-1",
                            report.winner().plan.strategy_string(),
                            report.winner().analytic_rank + 1,
                        );
                    }
                } else {
                    println!(
                        "re-ranking confirms the analytic winner: {}",
                        report.winner().plan.strategy_string()
                    );
                }
                if !report.bg_loads.is_empty() {
                    println!(
                        "background replay at {} load level(s): winner degrades \
                         {:+.1}% ({}) vs {:+.1}% for the analytic rank-1",
                        report.bg_loads.len(),
                        report.winner().degradation * 100.0,
                        if worst_case { "worst-case" } else { "mean" },
                        report.analytic_winner().degradation * 100.0,
                    );
                    // CI gate: re-ranking under load must never pick a plan
                    // that degrades *more* than the analytic rank-1.
                    if report.winner().degradation > report.analytic_winner().degradation {
                        return Err(
                            "refine --bg-load regression: the re-ranked winner degrades \
                             more under background load than the analytic rank-1 plan"
                                .into(),
                        );
                    }
                }
                if !report.fault_severities.is_empty() {
                    println!(
                        "fault replay at {} severity level(s) × {fault_scenarios} \
                         scenario(s): winner retains {:.0}% ({}) vs {:.0}% for the \
                         analytic rank-1",
                        report.fault_severities.len(),
                        report.winner().retention * 100.0,
                        if worst_case { "worst-case" } else { "mean" },
                        report.analytic_winner().retention * 100.0,
                    );
                    // CI gate: the fault-aware winner must never retain less
                    // throughput under faults than the analytic rank-1.
                    if report.winner().retention < report.analytic_winner().retention {
                        return Err(
                            "refine --fault-severity regression: the fault-aware winner \
                             retains less throughput under faults than the analytic \
                             rank-1 plan"
                                .into(),
                        );
                    }
                }
                println!("{}", report.winner().plan.describe());
                Ok(())
            }
            "mix" => {
                let topk = args.get_usize_nonzero("topk", 4);
                let bg_loads = parse_bg_loads(args)?;
                args.check()?;
                let bg_loads = if bg_loads.is_empty() {
                    nest::harness::mix::DEFAULT_BG_LOADS.to_vec()
                } else {
                    bg_loads
                };
                if nest::harness::mix::mix_table(&hopts, &bg_loads, topk, quick) {
                    Ok(())
                } else {
                    Err("workload-mix regression: a robust winner degraded more than \
                         the analytic rank-1 under background load (or a family was \
                         infeasible)"
                        .into())
                }
            }
            "chaos" => {
                let topk = args.get_usize_nonzero("topk", 4);
                let severities = parse_level_list(args, "fault-severity", 0.0, 1.0)?;
                let scenarios = args.get_usize_nonzero("fault-scenarios", 2);
                let seed = args.get_usize("fault-seed", 0xFA17) as u64;
                args.check()?;
                let severities = if severities.is_empty() {
                    nest::harness::chaos::DEFAULT_FAULT_SEVERITIES.to_vec()
                } else {
                    severities
                };
                if nest::harness::chaos::chaos_table(
                    &hopts, &severities, scenarios, seed, topk, quick,
                ) {
                    Ok(())
                } else {
                    Err("chaos regression: the fault-aware winner retained less \
                         throughput under faults than the analytic rank-1, a faulted \
                         replay was unsound, or reconcile failed on a survivable \
                         fault (or a family was infeasible)"
                        .into())
                }
            }
            "refine-xval" => {
                let topk = args.get_usize_nonzero("topk", 4);
                args.check()?;
                if nest::harness::refine::refine_table(&hopts, topk, quick) {
                    Ok(())
                } else {
                    Err("refinement regression: a shortlisted plan's flow sim undercut \
                         its analytic DES on a contended family (or a family was \
                         infeasible)"
                        .into())
                }
            }
            "bench-smoke" => {
                let out = args.get("out", "BENCH_PR.json");
                let baseline = args.get_opt("baseline");
                let tolerance = args.get_f64("tolerance", 0.25);
                let refresh = args.has_flag("write-baseline");
                args.check()?;
                let smoke = nest::harness::perf::run_smoke(quick);
                std::fs::write(&out, nest::util::json::to_pretty(&smoke.to_json()))
                    .map_err(|e| format!("{out}: {e}"))?;
                println!("bench report written to {out}");
                if refresh {
                    // Merge measured metrics into the committed baseline,
                    // preserving hand-added keys (refuses --quick runs).
                    nest::harness::perf::write_baseline(&smoke, "BENCH_BASELINE.json")?;
                }
                if let Some(path) = baseline {
                    let text =
                        std::fs::read_to_string(&path).map_err(|e| format!("{path}: {e}"))?;
                    let base = nest::util::json::parse(&text)?;
                    nest::harness::perf::gate(&smoke, &base, tolerance)?;
                    println!(
                        "bench gate passed against {path} (tolerance {:.0}%)",
                        tolerance * 100.0
                    );
                }
                Ok(())
            }
            "obs-summary" => {
                let path = args.get("trace", "nest_trace.json");
                args.check()?;
                let text =
                    std::fs::read_to_string(&path).map_err(|e| format!("{path}: {e}"))?;
                let v = nest::util::json::parse(&text)?;
                let summary = nest::obs::summary_from_json(&v)?;
                println!("flight-recorder summary for {path}:");
                print!("{summary}");
                Ok(())
            }
            "serve-bench" => {
                let queries = args.get_usize("queries", 16);
                args.check()?;
                let report = nest::harness::service::serve_bench(&hopts, queries, false);
                if report.mismatches > 0 {
                    Err(format!(
                        "placement service unsound: {} served answer(s) were not \
                         bit-identical to their cold twins",
                        report.mismatches
                    ))
                } else {
                    Ok(())
                }
            }
            "figure2" => {
                figures::figure2(&hopts);
                Ok(())
            }
            "figure5" => {
                let sizes: Vec<usize> = if quick {
                    vec![64, 256]
                } else {
                    vec![64, 128, 256, 512, 1024]
                };
                figures::figure5(&hopts, &sizes);
                Ok(())
            }
            "figure6" => {
                figures::microbatch_sweep(&hopts, 256, "figure6");
                Ok(())
            }
            "figure7" => {
                figures::figure7(&hopts, if quick { 256 } else { 1024 });
                Ok(())
            }
            "figure10" => {
                figures::figure10(&hopts);
                Ok(())
            }
            "figure11" => {
                figures::microbatch_sweep(&hopts, 512, "figure11");
                Ok(())
            }
            "table2" => {
                tables::table2(&hopts);
                Ok(())
            }
            "table4" => {
                tables::table4(&hopts, if quick { 256 } else { 1024 });
                Ok(())
            }
            "table6" => {
                tables::table6(&hopts);
                Ok(())
            }
            "table7" => {
                tables::table7(&hopts);
                Ok(())
            }
            "v100" => {
                tables::v100_validation(&hopts);
                Ok(())
            }
            "hetero" => {
                if tables::hetero(&hopts) {
                    Ok(())
                } else {
                    Err("heterogeneous-pool regression: the mixed-pool solve is not \
                         strictly faster than the all-V100-constrained solve"
                        .into())
                }
            }
            "torus" => {
                figures::torus(&hopts, if quick { 64 } else { 256 });
                Ok(())
            }
            "all" => {
                figures::figure2(&hopts);
                let sizes: Vec<usize> = if quick {
                    vec![64, 256]
                } else {
                    vec![64, 128, 256, 512, 1024]
                };
                figures::figure5(&hopts, &sizes);
                figures::microbatch_sweep(&hopts, 256, "figure6");
                figures::figure7(&hopts, if quick { 256 } else { 1024 });
                figures::figure10(&hopts);
                figures::microbatch_sweep(&hopts, 512, "figure11");
                tables::table2(&hopts);
                tables::table4(&hopts, if quick { 256 } else { 1024 });
                tables::table6(&hopts);
                tables::table7(&hopts);
                tables::v100_validation(&hopts);
                figures::torus(&hopts, if quick { 64 } else { 256 });
                if !tables::hetero(&hopts) {
                    return Err("heterogeneous-pool regression: the mixed-pool solve is \
                         not strictly faster than the all-V100-constrained solve"
                        .into());
                }
                if !nest::harness::netsim::netsim_xval_quick(&hopts, quick) {
                    return Err("netsim cross-validation regression: flow-sim undercut \
                         the analytic DES on a contended topology"
                        .into());
                }
                if !nest::harness::refine::refine_table(&hopts, 4, quick) {
                    return Err("refinement regression: a shortlisted plan's flow sim \
                         undercut its analytic DES on a contended family (or a \
                         family was infeasible)"
                        .into());
                }
                if !nest::harness::mix::mix_table(
                    &hopts,
                    &nest::harness::mix::DEFAULT_BG_LOADS,
                    4,
                    quick,
                ) {
                    return Err("workload-mix regression: a robust winner degraded more \
                         than the analytic rank-1 under background load (or a family \
                         was infeasible)"
                        .into());
                }
                if !nest::harness::chaos::chaos_table(
                    &hopts,
                    &nest::harness::chaos::DEFAULT_FAULT_SEVERITIES,
                    if quick { 1 } else { 2 },
                    0xFA17,
                    4,
                    quick,
                ) {
                    return Err("chaos regression: the fault-aware winner retained less \
                         throughput under faults than the analytic rank-1, a faulted \
                         replay was unsound, or reconcile failed on a survivable fault \
                         (or a family was infeasible)"
                        .into());
                }
                Ok(())
            }
            _ => {
                println!(
                    "nest — NEST device-placement reproduction (MLSys 2026)\n\n\
                     usage: nest <command> [options]\n\n\
                     commands:\n\
                     \x20 solve      --model <name> --cluster <fat-tree|spine-leaf|v100|hetero|torus2d|file.json> --devices N [--mbs N]\n\
                     \x20 simulate   same as solve, plus a DES evaluation of the plan\n\
                     \x20 netsim     --config <tier-or-edge-list.json | cluster name>: solve, then cross-check the plan\n\
                     \x20            under flow-level link contention (reports batch-time error + per-link utilization)\n\
                     \x20 netsim-xval  analytic-vs-flow-sim table across topology families (fat-tree, 4:1 spine, torus, edge-list)\n\
                     \x20 netsim-scale  decomposed flow simulation at fabric scale: --k <even fat-tree arity> --flows N\n\
                     \x20            --seed S --locality F (rack-local batch fraction); runs decomposed + monolithic,\n\
                     \x20            reports wall-clock and flows/sec, exits nonzero unless the reports are bit-identical\n\
                     \x20 refine     --config <topo> --model <m> --topk K: solve the analytic top-K shortlist, replay each\n\
                     \x20            plan under flow-level contention, and re-rank (exits nonzero if the K=1 shortlist\n\
                     \x20            ever disagrees with plain solve). --bg-load 0.3,0.6 additionally replays every plan\n\
                     \x20            under seeded background traffic at each max per-link load level and re-ranks by\n\
                     \x20            degradation (--rank <worst|mean>; exits nonzero if the robust winner degrades\n\
                     \x20            more than the analytic rank-1). --fault-severity 0.4,0.8 replays every plan under\n\
                     \x20            seeded fault scenarios (link kills/brownouts/flaps + stragglers; --fault-scenarios N\n\
                     \x20            --fault-seed S) and re-ranks by throughput retention (exits nonzero if the\n\
                     \x20            fault-aware winner retains less than the analytic rank-1)\n\
                     \x20 refine-xval  cross-topology refinement table: where the re-ranked winner flips (--topk K)\n\
                     \x20 mix        multi-tenant harness: refine the top-K shortlist under background load on fat-tree,\n\
                     \x20            4:1 spine-leaf, and the dumbbell edge-list (--bg-load 0.2,0.4,0.6 --topk K);\n\
                     \x20            prints plan flips per load level, writes results/mix.csv, exits nonzero on regression\n\
                     \x20 chaos      fault-injection survival table over the same families (--fault-severity 0.3,0.6,0.9\n\
                     \x20            --fault-scenarios N --fault-seed S --topk K): throughput retention of the analytic\n\
                     \x20            vs fault-aware winner per severity, plus reconcile-under-failed-devices; writes\n\
                     \x20            results/chaos.csv, exits nonzero if the fault-aware winner retains less than the\n\
                     \x20            analytic rank-1 or reconcile fails a survivable fault\n\
                     \x20 bench-smoke  perf smoke --out BENCH_PR.json [--baseline BENCH_BASELINE.json --tolerance 0.25]\n\
                     \x20            [--write-baseline: merge measured metrics into BENCH_BASELINE.json, keeping other keys]\n\
                     \x20 serve-bench  placement-as-a-service throughput: stream --queries N (default 16) over a model x\n\
                     \x20            cluster grid; reports queries/s, cache hit rate, warm/hit speedups, migration cost\n\
                     \x20            (exits nonzero if any served plan differs from a cold solve)\n\
                     \x20 obs-summary  --trace <file.json>: human tables from a recorded trace (top spans by\n\
                     \x20            self-time, prune-site effectiveness, cache hit ratio, histogram quantiles)\n\
                     \x20 train      --steps N --microbatches N --dp N   (needs `make artifacts`)\n\
                     \x20 profile    --reps N\n\
                     \x20 figure2|figure5|figure6|figure7|figure10|figure11\n\
                     \x20 table2|table4|table6|table7 | v100 | torus\n\
                     \x20 hetero     mixed H100+V100 pool vs single-class twins (exits nonzero if the\n\
                     \x20            mixed solve is not strictly faster than the all-V100 constraint)\n\
                     \x20 all        run the complete evaluation\n\n\
                     global: --quick (smaller sweeps), --results <dir>, --threads N (solver + netsim workers, N ≥ 1; omit for all cores),\n\
                     \x20       --mode <auto|monolithic|decomposed> (flow-simulator execution mode; reports are bit-identical either way),\n\
                     \x20       --trace <file.json> (flight recorder: Chrome-trace spans/counters/histograms; also NEST_TRACE=<path>;\n\
                     \x20       zero overhead when off, bit-identical plans when on)\n\n\
                     models: llama2-7b llama3-70b bertlarge gpt3-175b gpt3-35b mixtral-8x7b mixtral-790m"
                );
                Ok(())
            }
        }
    };

    let result = run(&mut args).and_then(|_| args.finish());

    // Emit the flight-recorder trace (also on error — a trace of a
    // failed run is exactly when you want one). Merges every worker
    // thread's buffer in stable thread-index order.
    if let Some(path) = &trace {
        match nest::obs::write_trace(path) {
            Ok(n) => {
                println!(
                    "trace written to {path} ({n} spans) — load in chrome://tracing or \
                     ui.perfetto.dev, or run `nest obs-summary --trace {path}`"
                );
                // The full-evaluation path renders the summary inline.
                if cmd == "all" && result.is_ok() {
                    if let Ok(text) = std::fs::read_to_string(path) {
                        if let Ok(v) = nest::util::json::parse(&text) {
                            if let Ok(s) = nest::obs::summary_from_json(&v) {
                                println!("flight-recorder summary:");
                                print!("{s}");
                            }
                        }
                    }
                }
            }
            Err(e) => eprintln!("warning: failed to write trace {path}: {e}"),
        }
    }

    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
