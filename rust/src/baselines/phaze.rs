//! Phaze baseline (§5.1 baseline 2): a network-*unaware* dynamic program
//! built on Piper (Tarnawski et al. 2021; Wang et al. 2024).
//!
//! Phaze's DP balances computation and models memory, but assumes a flat
//! uniform interconnect: every link looks like the cluster's *fastest*
//! tier. We reproduce that by running the same DP machinery NEST uses on
//! a flattened twin of the cluster, then re-costing the chosen plan on
//! the real topology (the paper evaluates all methods under the shared
//! real-network cost model). The throughput loss relative to NEST comes
//! exactly from where the paper says it does: stage boundaries and
//! collectives landing on oversubscribed links the search never saw
//! (§5.2.1 "Comparison with Phaze").

use super::build_plan;
use crate::graph::LayerGraph;
use crate::network::Cluster;
use crate::solver::plan::PlacementPlan;
use crate::solver::{solve as nest_solve, SolverOpts};

/// Flat twin: same accelerators (the full device pool) and device
/// count, one tier at the innermost (fastest) bandwidth — the uniform
/// network Phaze assumes. Network-unaware, not device-unaware: the
/// pool's per-device classes carry over.
pub fn flat_twin(cluster: &Cluster) -> Cluster {
    let mut flat = Cluster::flat(
        cluster.accel().clone(),
        cluster.n_devices(),
        cluster.tiers[0].link_bw,
        cluster.tiers[0].latency,
    );
    flat.pool = cluster.pool.clone();
    flat
}

/// Run Phaze: solve on the flat twin, realize on the real cluster.
pub fn solve(graph: &LayerGraph, cluster: &Cluster, opts: &SolverOpts) -> Option<PlacementPlan> {
    let flat = flat_twin(cluster);
    let sol = nest_solve(graph, &flat, opts)?;
    // Re-cost the chosen structure (sg, cuts, d, recompute) on the real
    // topology.
    let cuts: Vec<usize> = {
        let mut c: Vec<usize> = sol.plan.stages.iter().map(|s| s.layers.0).collect();
        c.push(graph.n_layers());
        c
    };
    let rc = sol.plan.stages.iter().any(|s| s.mem.recompute);
    let mut plan = build_plan(
        graph,
        cluster,
        "phaze",
        sol.plan.sg,
        &cuts,
        sol.plan.dp_width,
        rc,
        opts.zero_max_degree,
    )?;
    plan.method = "phaze".into();
    Some(plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;

    #[test]
    fn phaze_plan_validates() {
        let g = models::llama2_7b(1);
        let c = Cluster::fat_tree_tpuv4(64);
        let plan = solve(&g, &c, &SolverOpts::default()).unwrap();
        plan.validate(&g, &c).unwrap();
    }

    #[test]
    fn nest_at_least_as_good_as_phaze() {
        // NEST searches with the real topology; Phaze with a flat one.
        // On the oversubscribed spine-leaf cluster NEST must be ≥ Phaze.
        let opts = SolverOpts::default();
        for model in ["llama2-7b", "gpt3-175b"] {
            let g = models::by_name(model, 1).unwrap();
            let c = Cluster::spine_leaf_h100(64, 2.0);
            let nest = nest_solve(&g, &c, &opts).unwrap().plan;
            if let Some(ph) = solve(&g, &c, &opts) {
                assert!(
                    nest.batch_time <= ph.batch_time * 1.0001,
                    "{model}: nest {} > phaze {}",
                    nest.batch_time,
                    ph.batch_time
                );
            }
        }
    }

    #[test]
    fn flat_twin_preserves_size() {
        let c = Cluster::spine_leaf_h100(128, 2.0);
        let f = flat_twin(&c);
        assert_eq!(f.n_devices(), 128);
        assert_eq!(f.n_levels(), 1);
        assert_eq!(f.accel().name, c.accel().name);
        assert_eq!(f.pool, c.pool);
    }
}
