//! Mist baseline (§5.1 baseline 5, §5.3): memory–parallelism
//! co-optimization via hierarchical MILP + brute-force enumeration
//! (Zhu et al. 2025), per Table 1:
//!
//! * **Integrated memory modeling** (like NEST: ZeRO + recompute are part
//!   of the search, not post hoc) — Mist's strength;
//! * **uneven layer partitioning** across stages to balance memory and
//!   overlap (§5.3 "Mist supports uneven layer partitioning");
//! * **no network awareness** — "it treats network topology as a
//!   secondary consideration": candidates are scored on a flat
//!   average-bandwidth abstraction of the cluster;
//! * **brute-force enumeration** over (tp, p, d) — the scalability cost
//!   the paper measures in Table 4;
//! * **model support limits**: no MoE, no hidden dim > 8192 (§5.3 — the
//!   "X" entries for GPT3-175B and Mixtral in Figure 7).

use super::{balanced_cuts, build_plan};
use crate::cost::CostModel;
use crate::graph::subgraph::SgConfig;
use crate::graph::LayerGraph;
use crate::hw::GB;
use crate::memory::MemSpec;
use crate::network::Cluster;
use crate::solver::plan::PlacementPlan;

/// Models Mist cannot run (§5.3).
pub fn supports(graph: &LayerGraph) -> bool {
    let dims = &graph.layers[1].dims;
    let is_moe = graph
        .layers
        .iter()
        .any(|l| matches!(l.kind, crate::graph::LayerKind::MoeBlock(_)));
    dims.hidden <= 8192 && !is_moe
}

/// Flat average-bandwidth twin: Mist's secondary treatment of topology —
/// one uniform tier at the device-count-weighted mean effective bandwidth.
fn averaged_twin(cluster: &Cluster) -> Cluster {
    let mut bw_sum = 0.0;
    for l in 0..cluster.n_levels() {
        bw_sum += cluster.bw_eff(l);
    }
    let avg = bw_sum / cluster.n_levels() as f64;
    let mut flat = Cluster::flat(
        cluster.accel().clone(),
        cluster.n_devices(),
        avg.max(1.0 * GB),
        cluster.lat(cluster.n_levels() - 1) / 2.0,
    );
    flat.pool = cluster.pool.clone();
    flat
}

/// Search statistics (Table 4 compares solver runtimes).
#[derive(Debug, Clone, Default)]
pub struct MistStats {
    pub candidates: u64,
}

/// Run the Mist-style search. Returns `None` for unsupported models or
/// when nothing fits.
pub fn solve(graph: &LayerGraph, cluster: &Cluster) -> Option<PlacementPlan> {
    solve_with_stats(graph, cluster).map(|(p, _)| p)
}

pub fn solve_with_stats(
    graph: &LayerGraph,
    cluster: &Cluster,
) -> Option<(PlacementPlan, MistStats)> {
    if !supports(graph) {
        return None;
    }
    let k = cluster.n_devices();
    let n = graph.n_layers();
    let twin = averaged_twin(cluster);
    let mut stats = MistStats::default();
    let mut best: Option<(f64, PlacementPlan)> = None;

    // Brute-force over (tp, p, d, recompute): the hierarchical-MILP outer
    // loop. Memory is *integrated*: per-stage ZeRO escalation inside
    // build_plan, uneven memory-balanced cuts.
    for &tp in &graph.tp_widths {
        let sg = SgConfig {
            tp,
            sp: tp > 1,
            ep: 1,
            cp: 1,
        };
        let g = sg.group_size();
        let cm = CostModel::new(graph, &twin, sg);
        // Per-layer weights mixing compute and memory pressure (Mist
        // balances both; weights on the twin → network-blind).
        let weights: Vec<f64> = (0..n)
            .map(|i| {
                let t = cm.stage_load(i, i + 1, None, None, &MemSpec::plain(), &twin);
                let m = cm.stage_peak_bytes(i, i + 1, &MemSpec::plain(), 0);
                t * (1.0 + 0.1 * m / cluster.pool.min_capacity_all())
            })
            .collect();
        let mut p = 1;
        while p <= n && p * g <= k {
            let d_max = k / (p * g);
            for d in divisors_upto(d_max) {
                for rc in [false, true] {
                    stats.candidates += 1;
                    let cuts = balanced_cuts(&weights, p);
                    // Score on the twin (network-blind selection)...
                    let Some(twin_plan) =
                        build_plan(graph, &twin, "mist", sg, &cuts, d, rc, 8)
                    else {
                        continue;
                    };
                    // ...but realize on the real cluster.
                    let Some(real_plan) =
                        build_plan(graph, cluster, "mist", sg, &cuts, d, rc, 8)
                    else {
                        continue;
                    };
                    let score = twin_plan.batch_time;
                    if best.as_ref().map(|(b, _)| score < *b).unwrap_or(true) {
                        best = Some((score, real_plan));
                    }
                }
            }
            p += 1;
        }
    }
    best.map(|(_, plan)| (plan, stats))
}

fn divisors_upto(d_max: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut d = 1;
    while d <= d_max {
        out.push(d);
        d *= 2;
    }
    if !out.contains(&d_max) && d_max > 0 {
        out.push(d_max);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::solver::{solve as nest_solve, SolverOpts};

    #[test]
    fn mist_rejects_gpt3_175b_and_moe() {
        assert!(!supports(&models::gpt3_175b(1)));
        assert!(!supports(&models::mixtral_8x7b(1)));
        assert!(supports(&models::gpt3_35b(1)));
        assert!(supports(&models::llama2_7b(1)));
        assert!(solve(&models::mixtral_8x7b(1), &Cluster::spine_leaf_h100(64, 2.0)).is_none());
    }

    #[test]
    fn mist_plan_validates() {
        let g = models::llama2_7b(1);
        let c = Cluster::spine_leaf_h100(64, 2.0);
        let plan = solve(&g, &c).expect("mist plan");
        plan.validate(&g, &c).unwrap();
    }

    #[test]
    fn mist_memory_integrated_zero() {
        // Unlike Alpa, Mist should find a plan where memory needs ZeRO or
        // recompute (integrated memory optimization).
        let g = models::llama3_70b(1);
        let c = Cluster::spine_leaf_h100(64, 2.0);
        if let Some(plan) = solve(&g, &c) {
            plan.validate(&g, &c).unwrap();
        }
    }

    #[test]
    fn nest_beats_mist_on_oversubscribed() {
        // §5.3: NEST 1.49× over Mist on average — directionally, NEST
        // must not lose on the oversubscribed spine-leaf.
        let g = models::gpt3_35b(1);
        let c = Cluster::spine_leaf_h100(64, 2.0);
        let nest = nest_solve(&g, &c, &SolverOpts::default()).unwrap().plan;
        let mist = solve(&g, &c).unwrap();
        assert!(
            nest.batch_time <= mist.batch_time * 1.0001,
            "nest {} vs mist {}",
            nest.batch_time,
            mist.batch_time
        );
    }

    #[test]
    fn divisors_cover_range() {
        assert_eq!(divisors_upto(8), vec![1, 2, 4, 8]);
        assert_eq!(divisors_upto(6), vec![1, 2, 4, 6]);
        assert_eq!(divisors_upto(1), vec![1]);
    }
}
