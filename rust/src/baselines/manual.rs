//! Manual placement baseline (§5.1 baseline 1): the expert recipes of
//! Megatron-LM practice (Narayanan et al. 2021; Table 2 "Manual" column),
//! scaling data parallelism with cluster size.
//!
//! Recipes fix the pipeline depth and tensor-parallel width per model;
//! remaining devices go to data parallelism. Layers are split evenly
//! across stages (manual plans do not topology-balance — that is NEST's
//! contribution). Activation recomputation follows Table 2's
//! "Recomputation vs. Stashing" column.

use super::{build_plan, even_cuts};
use crate::graph::subgraph::SgConfig;
use crate::graph::LayerGraph;
use crate::network::Cluster;
use crate::solver::plan::PlacementPlan;

/// Table 2 manual recipe for a model: (pipeline depth, tp width, expert
/// degree, recompute).
fn recipe(model: &str) -> Option<(usize, usize, usize, bool)> {
    match model {
        "llama2-7b" => Some((8, 1, 1, true)),
        "llama3-70b" => Some((80, 1, 1, true)),
        "bertlarge" => Some((8, 1, 1, false)),
        "gpt3-175b" => Some((32, 4, 1, true)),
        "gpt3-35b" => Some((16, 4, 1, true)),
        "mixtral-8x7b" => Some((32, 1, 4, true)),
        "mixtral-790m" => Some((4, 1, 2, true)),
        _ => None,
    }
}

/// Produce the manual plan for `graph` on `cluster`, or `None` when the
/// recipe does not fit (too few devices, or memory-infeasible — the ✗
/// marks in Figures 5–7).
pub fn solve(graph: &LayerGraph, cluster: &Cluster) -> Option<PlacementPlan> {
    let (mut p, tp, ep, rc) = recipe(&graph.model_name)?;
    let k = cluster.n_devices();
    let sg = SgConfig {
        tp,
        sp: tp > 1,
        ep,
        cp: 1,
    };
    let g = sg.group_size();
    // Shrink the pipeline if the cluster can't hold one replica (manual
    // practice: halve p until it fits).
    while p > 1 && p * g > k {
        p /= 2;
    }
    p = p.min(graph.n_layers());
    let d = k / (p * g);
    if d == 0 {
        return None;
    }
    let cuts = even_cuts(graph.n_layers(), p);
    build_plan(graph, cluster, "manual", sg, &cuts, d, rc, 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;

    #[test]
    fn manual_matches_table2_at_512() {
        // Table 2: Llama2-7B manual = {8, 64, 1, 1} at 512 devices.
        let g = models::llama2_7b(1);
        let c = Cluster::fat_tree_tpuv4(512);
        let plan = solve(&g, &c).unwrap();
        plan.validate(&g, &c).unwrap();
        assert_eq!(plan.n_stages(), 8);
        assert_eq!(plan.dp_width, 64);
    }

    #[test]
    fn manual_gpt3_uses_tp4() {
        let g = models::gpt3_175b(1);
        let c = Cluster::fat_tree_tpuv4(512);
        let plan = solve(&g, &c).unwrap();
        plan.validate(&g, &c).unwrap();
        assert_eq!(plan.sg.tp, 4);
        assert_eq!(plan.n_stages(), 32);
        assert_eq!(plan.dp_width, 4);
    }

    #[test]
    fn manual_scales_dp_with_cluster() {
        let g = models::bert_large(1);
        let d64 = solve(&g, &Cluster::fat_tree_tpuv4(64)).unwrap().dp_width;
        let d512 = solve(&g, &Cluster::fat_tree_tpuv4(512)).unwrap().dp_width;
        assert_eq!(d512, d64 * 8);
    }

    #[test]
    fn manual_llama3_shrinks_pipeline_on_small_cluster() {
        // p=80 doesn't fit 64 devices; the recipe halves to 40.
        let g = models::llama3_70b(1);
        let c = Cluster::fat_tree_tpuv4(64);
        if let Some(plan) = solve(&g, &c) {
            plan.validate(&g, &c).unwrap();
            assert!(plan.n_stages() <= 64);
        }
        // (None is also acceptable: 70B on 64×64 GB without ZeRO is tight.)
    }

    #[test]
    fn unknown_model_is_none() {
        let g = models::tiny_transformer(4, 128, 64, 1);
        assert!(solve(&g, &Cluster::fat_tree_tpuv4(64)).is_none());
    }
}
