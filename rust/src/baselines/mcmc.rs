//! MCMC baseline (§5.1 baseline 3): TopoOpt-style Markov-Chain Monte
//! Carlo placement search (Wang et al. 2023), "implemented to explore the
//! same parallelization strategies as NEST".
//!
//! The chain walks over (SUB-GRAPH config, pipeline depth, cut points,
//! recomputation) with simulated-annealing acceptance; candidates are
//! costed with the same real-topology model NEST uses (TopoOpt is
//! topology-aware — its weakness is the *search*, not the cost model:
//! no optimality guarantees, sensitivity to initialization, poor scaling
//! with the number of parallelization dimensions). Following §5.1 we run
//! 10 independently seeded chains and report the best.

use super::{build_plan_ordered, even_cuts};
use crate::graph::subgraph::{enumerate_sg, SgConfig};
use crate::graph::LayerGraph;
use crate::network::Cluster;
use crate::solver::plan::PlacementPlan;
use crate::util::rng::Rng;

/// MCMC options.
#[derive(Debug, Clone)]
pub struct McmcOpts {
    pub iters: usize,
    pub restarts: usize,
    pub seed: u64,
    pub zero_max_degree: usize,
}

impl Default for McmcOpts {
    fn default() -> Self {
        McmcOpts {
            iters: 2000,
            restarts: 10,
            seed: 0x705_0709,
            zero_max_degree: 8,
        }
    }
}

#[derive(Clone)]
struct State {
    sg_idx: usize,
    p: usize,
    cuts: Vec<usize>,
    /// Stage → device-block assignment (TopoOpt searches *placement*,
    /// not just partitioning — random layouts start with pipeline
    /// neighbors scattered across racks).
    blocks: Vec<usize>,
    recompute: bool,
}

/// Random cut vector: p−1 distinct interior cut points (TopoOpt-style
/// random initialization — the source of the paper's "highly sensitive
/// to initialization" observation; chains must *discover* balanced cuts
/// through single-layer moves).
fn random_cuts(rng: &mut Rng, n: usize, p: usize) -> Vec<usize> {
    let mut interior: Vec<usize> = (1..n).collect();
    rng.shuffle(&mut interior);
    let mut cuts: Vec<usize> = interior[..p - 1].to_vec();
    cuts.push(0);
    cuts.push(n);
    cuts.sort_unstable();
    cuts
}

fn random_blocks(rng: &mut Rng, p: usize) -> Vec<usize> {
    let mut blocks: Vec<usize> = (0..p).collect();
    rng.shuffle(&mut blocks);
    blocks
}

fn random_state(rng: &mut Rng, n: usize, sgs: &[SgConfig], k: usize) -> State {
    let sg_idx = rng.gen_range(sgs.len());
    let g = sgs[sg_idx].group_size();
    let p_max = (k / g).min(n).max(1);
    let p = 1 + rng.gen_range(p_max);
    State {
        sg_idx,
        p,
        cuts: random_cuts(rng, n, p),
        blocks: random_blocks(rng, p),
        recompute: rng.gen_bool(0.5),
    }
}

fn perturb(rng: &mut Rng, st: &State, n: usize, sgs: &[SgConfig], k: usize) -> State {
    let mut s = st.clone();
    match rng.gen_range(5) {
        0 => {
            // Re-draw the SUB-GRAPH config (keep depth if it still fits).
            s.sg_idx = rng.gen_range(sgs.len());
            let g = sgs[s.sg_idx].group_size();
            let p_max = (k / g).min(n).max(1);
            if s.p > p_max {
                s.p = p_max;
                s.cuts = even_cuts(n, s.p);
                s.blocks = random_blocks(rng, s.p);
            }
        }
        1 => {
            // Grow/shrink the pipeline by inserting/removing one cut.
            let g = sgs[s.sg_idx].group_size();
            let p_max = (k / g).min(n).max(1);
            if rng.gen_bool(0.5) && s.p < p_max {
                // Insert a random new interior cut.
                let candidates: Vec<usize> =
                    (1..n).filter(|c| !s.cuts.contains(c)).collect();
                if !candidates.is_empty() {
                    s.cuts.push(*rng.choose(&candidates));
                    s.cuts.sort_unstable();
                    s.blocks.push(s.p);
                    s.p += 1;
                }
            } else if s.p > 1 {
                let ci = 1 + rng.gen_range(s.p - 1);
                s.cuts.remove(ci);
                // Drop the highest block id to keep blocks a permutation
                // of 0..p−1.
                let drop = s.blocks.iter().position(|&b| b == s.p - 1).unwrap();
                s.blocks.remove(drop);
                s.p -= 1;
            }
        }
        2 if s.p > 1 => {
            // Move one interior cut by one layer.
            let ci = 1 + rng.gen_range(s.p - 1);
            let lo = s.cuts[ci - 1] + 1;
            let hi = s.cuts[ci + 1] - 1;
            if hi >= lo {
                let delta: isize = if rng.gen_bool(0.5) { 1 } else { -1 };
                let moved = (s.cuts[ci] as isize + delta).clamp(lo as isize, hi as isize);
                s.cuts[ci] = moved as usize;
            }
        }
        3 if s.p > 1 => {
            // Swap two stages' device blocks (placement move).
            let a = rng.gen_range(s.p);
            let b = rng.gen_range(s.p);
            s.blocks.swap(a, b);
        }
        _ => s.recompute = !s.recompute,
    }
    s
}

fn eval(
    graph: &LayerGraph,
    cluster: &Cluster,
    sgs: &[SgConfig],
    st: &State,
    zero_max: usize,
) -> Option<PlacementPlan> {
    let sg = sgs[st.sg_idx];
    let g = sg.group_size();
    let d = cluster.n_devices() / (st.p * g);
    if d == 0 {
        return None;
    }
    build_plan_ordered(
        graph,
        cluster,
        "mcmc",
        sg,
        &st.cuts,
        &st.blocks,
        d,
        st.recompute,
        zero_max,
    )
}

/// Run the MCMC search; returns the best plan found across restarts.
pub fn solve(graph: &LayerGraph, cluster: &Cluster, opts: &McmcOpts) -> Option<PlacementPlan> {
    let n = graph.n_layers();
    let k = cluster.n_devices();
    let sgs = enumerate_sg(&graph.tp_widths, &graph.ep_degrees, &graph.cp_degrees, k);
    let mut best: Option<PlacementPlan> = None;

    for restart in 0..opts.restarts {
        let mut rng = Rng::new(opts.seed.wrapping_add(restart as u64));
        let mut cur = random_state(&mut rng, n, &sgs, k);
        let mut cur_cost = eval(graph, cluster, &sgs, &cur, opts.zero_max_degree)
            .map(|p| p.batch_time)
            .unwrap_or(f64::INFINITY);
        // Geometric annealing: T from 20% of current cost to ~0.1%.
        for it in 0..opts.iters {
            let cand = perturb(&mut rng, &cur, n, &sgs, k);
            let cand_plan = eval(graph, cluster, &sgs, &cand, opts.zero_max_degree);
            let cand_cost = cand_plan.as_ref().map(|p| p.batch_time).unwrap_or(f64::INFINITY);
            let frac = it as f64 / opts.iters as f64;
            let temp = 0.20 * (1.0 - frac) + 0.001;
            let accept = cand_cost < cur_cost || {
                cur_cost.is_finite()
                    && cand_cost.is_finite()
                    && rng.gen_f64() < (-(cand_cost - cur_cost) / (temp * cur_cost)).exp()
            };
            if accept {
                cur = cand;
                cur_cost = cand_cost;
            }
            if let Some(p) = cand_plan {
                if best
                    .as_ref()
                    .map(|b| p.batch_time < b.batch_time)
                    .unwrap_or(true)
                {
                    best = Some(p);
                }
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::solver::{solve as nest_solve, SolverOpts};

    fn quick_opts() -> McmcOpts {
        McmcOpts {
            iters: 300,
            restarts: 3,
            ..Default::default()
        }
    }

    #[test]
    fn mcmc_finds_valid_plan() {
        let g = models::llama2_7b(1);
        let c = Cluster::fat_tree_tpuv4(64);
        let plan = solve(&g, &c, &quick_opts()).expect("mcmc plan");
        plan.validate(&g, &c).unwrap();
    }

    #[test]
    fn mcmc_deterministic_per_seed() {
        let g = models::bert_large(1);
        let c = Cluster::fat_tree_tpuv4(64);
        let a = solve(&g, &c, &quick_opts()).unwrap().batch_time;
        let b = solve(&g, &c, &quick_opts()).unwrap().batch_time;
        assert_eq!(a, b);
    }

    #[test]
    fn nest_never_worse_than_mcmc() {
        // MCMC explores a subset of NEST's space with the same cost
        // model, so the DP (optimal in that space) must be ≤.
        let g = models::llama2_7b(1);
        let c = Cluster::fat_tree_tpuv4(64);
        let nest = nest_solve(&g, &c, &SolverOpts::default()).unwrap().plan;
        let mcmc = solve(&g, &c, &quick_opts()).unwrap();
        assert!(
            nest.batch_time <= mcmc.batch_time * 1.0001,
            "nest {} > mcmc {}",
            nest.batch_time,
            mcmc.batch_time
        );
    }

    #[test]
    fn more_iterations_no_worse() {
        let g = models::bert_large(1);
        let c = Cluster::fat_tree_tpuv4(64);
        let short = solve(
            &g,
            &c,
            &McmcOpts {
                iters: 50,
                restarts: 1,
                ..Default::default()
            },
        )
        .unwrap()
        .batch_time;
        let long = solve(
            &g,
            &c,
            &McmcOpts {
                iters: 500,
                restarts: 1,
                ..Default::default()
            },
        )
        .unwrap()
        .batch_time;
        assert!(long <= short * 1.0001);
    }
}
