//! Alpa-E baseline (§5.1 baseline 4): Alpa's inter-/intra-operator DP
//! (Zheng et al. 2022) with its hardware profiler replaced by the shared
//! estimator ("Alpa-E"), faithful to the three behaviours the paper
//! attributes to it:
//!
//! 1. **Stages optimized independently, single pipeline** — additional
//!    devices deepen intra-operator sharding instead of replicating
//!    pipelines (§5.2.1 "Effects of Over-sharding"): `d = 1` always, and
//!    every device is used even when that lowers per-device efficiency
//!    ("Alpa enforces full device usage").
//! 2. **Uniform 2D-mesh network assumption** — the search prices
//!    communication at a single flat bandwidth; hierarchy and
//!    oversubscription are invisible until the plan runs on the real
//!    cluster.
//! 3. **Post-hoc memory feasibility** — plans are generated from the
//!    compute/communication DP first; memory is checked afterwards and
//!    repaired by *sharding more* (raising the intra-op degree), not by
//!    ZeRO or recomputation choices inside the search.

use super::{balanced_cuts, build_plan};
use crate::cost::CostModel;
use crate::graph::subgraph::SgConfig;
use crate::graph::LayerGraph;
use crate::memory::MemSpec;
use crate::network::Cluster;
use crate::solver::plan::PlacementPlan;

/// Intra-operator sharding degree Alpa would pick for a stage of
/// `devices` devices: use them all (cap at the attention-head count,
/// beyond which row/col sharding of a transformer layer stops dividing).
fn intra_op_degree(graph: &LayerGraph, devices: usize) -> usize {
    let heads = graph.layers[1].dims.heads;
    let mut t = 1;
    while t * 2 <= devices.min(heads) {
        t *= 2;
    }
    t
}

/// Run Alpa-E. Returns `None` when no memory-feasible plan exists even at
/// maximum sharding (the ✗ entries: e.g. GPT3-175B on 64 devices, §5.2.1
/// "Memory Modeling").
pub fn solve(graph: &LayerGraph, cluster: &Cluster) -> Option<PlacementPlan> {
    let k = cluster.n_devices();
    let n = graph.n_layers();
    let flat = super::phaze::flat_twin(cluster);

    let mut best: Option<(f64, PlacementPlan)> = None;
    // Enumerate pipeline depths that divide the cluster; each stage gets
    // k/p devices, fully consumed by intra-op sharding.
    let mut p = 1;
    while p <= n.min(k) {
        if k % p == 0 {
            let stage_devices = k / p;
            let t = intra_op_degree(graph, stage_devices);
            let sg = SgConfig {
                tp: t,
                sp: t > 1,
                ep: 1,
                cp: 1,
            };
            // Balanced compute cuts under the flat-mesh cost model
            // (stages optimized independently = per-stage compute
            // balancing, no cross-stage network reasoning).
            let cm_flat = CostModel::new(graph, &flat, sg);
            let weights: Vec<f64> = (0..n)
                .map(|i| cm_flat.stage_load(i, i + 1, None, None, &MemSpec::plain(), &flat))
                .collect();
            let cuts = balanced_cuts(&weights, p);
            // Post-hoc memory check: Alpa can only re-shard (already
            // maximal here) — no ZeRO, no recompute escalation. We pass
            // recompute=false and zero cap 1; build_plan returns None if
            // any stage overflows.
            if let Some(plan) =
                build_plan(graph, cluster, "alpa-e", sg, &cuts, 1, false, 1)
            {
                // Selection happens under the flat model (Alpa never sees
                // the hierarchy) — rebuild the candidate on the flat twin
                // for scoring.
                let flat_score = build_plan(graph, &flat, "alpa-e", sg, &cuts, 1, false, 1)
                    .map(|fp| fp.batch_time)
                    .unwrap_or(f64::INFINITY);
                if best
                    .as_ref()
                    .map(|(b, _)| flat_score < *b)
                    .unwrap_or(true)
                {
                    best = Some((flat_score, plan));
                }
            }
        }
        p += 1;
    }
    best.map(|(_, plan)| plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::solver::{solve as nest_solve, SolverOpts};

    #[test]
    fn alpa_single_pipeline() {
        let g = models::bert_large(1);
        let c = Cluster::fat_tree_tpuv4(64);
        let plan = solve(&g, &c).expect("alpa plan");
        plan.validate(&g, &c).unwrap();
        assert_eq!(plan.dp_width, 1, "Alpa never replicates pipelines");
        assert_eq!(plan.used_devices(), plan.devices_per_replica);
    }

    #[test]
    fn alpa_gpt3_on_64_fails_or_overshards() {
        // §5.2.1: without ZeRO/recompute Alpa either fails GPT3-175B on a
        // 64-device cluster or is forced into extreme sharding (t ≥ 32
        // across node boundaries) to fit memory — far behind NEST.
        let g = models::gpt3_175b(1);
        let c = Cluster::fat_tree_tpuv4(64);
        match solve(&g, &c) {
            None => {}
            Some(plan) => {
                plan.validate(&g, &c).unwrap();
                assert!(plan.sg.tp >= 16, "expected over-sharding, got {}", plan.strategy_string());
                let nest = nest_solve(&g, &c, &SolverOpts::default()).unwrap().plan;
                assert!(
                    nest.batch_time < plan.batch_time,
                    "nest {} vs alpa {}",
                    nest.batch_time,
                    plan.batch_time
                );
            }
        }
    }

    #[test]
    fn alpa_oversharding_hurts_at_scale() {
        // BertLarge at 512: Alpa shards a 350M model across all devices →
        // much worse than NEST's {1, 512} data parallelism (§5.2.2).
        let g = models::bert_large(1);
        let c = Cluster::fat_tree_tpuv4(512);
        let alpa = solve(&g, &c).unwrap();
        let nest = nest_solve(&g, &c, &SolverOpts::default()).unwrap().plan;
        assert!(
            nest.batch_time < alpa.batch_time,
            "nest {} vs alpa {}",
            nest.batch_time,
            alpa.batch_time
        );
    }

    #[test]
    fn intra_op_degree_capped_by_heads() {
        let g = models::bert_large(1); // 16 heads
        assert_eq!(intra_op_degree(&g, 64), 16);
        assert_eq!(intra_op_degree(&g, 8), 8);
        assert_eq!(intra_op_degree(&g, 3), 2);
        assert_eq!(intra_op_degree(&g, 1), 1);
    }
}
