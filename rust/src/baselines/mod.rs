//! Baseline placement methods (§5.1): Manual, MCMC (TopoOpt-style),
//! Phaze, Alpa-E, and Mist.
//!
//! All baselines emit the same [`PlacementPlan`] type and are evaluated
//! with the same cost model and simulator as NEST ("For fairness, NEST
//! and baselines use PipeDream-Flush schedule and shared cost model").
//! What differs is *how each one searches*: flat-network assumptions
//! (Phaze, Alpa, Mist), stochastic exploration (MCMC), or fixed recipes
//! (Manual). `build_plan` is the shared constructor that realizes a
//! candidate (sg, cuts, d) on the real cluster with compact tail-first
//! packing — identical to the NEST solver's layout — so comparisons
//! isolate search quality, not layout plumbing.

pub mod alpa;
pub mod manual;
pub mod mcmc;
pub mod mist;
pub mod phaze;

use crate::cost::CostModel;
use crate::graph::subgraph::SgConfig;
use crate::graph::LayerGraph;
use crate::network::Cluster;
use crate::solver::assign::stage_devices;
use crate::solver::plan::{PlacementPlan, StagePlan};

/// Build (and memory-check) a plan from explicit decisions: SUB-GRAPH
/// config, stage cut points (`cuts[k]..cuts[k+1]` = stage k's layers),
/// data-parallel width, and the recomputation flag. Memory specs are
/// chosen per stage exactly as the NEST solver does (escalating ZeRO),
/// with the degree capped by `d`. Returns `None` if any stage cannot be
/// made to fit — the "baseline failed to find a valid placement" ✗ in
/// Figures 5–7.
pub fn build_plan(
    graph: &LayerGraph,
    cluster: &Cluster,
    method: &str,
    sg: SgConfig,
    cuts: &[usize],
    d: usize,
    recompute: bool,
    zero_max_degree: usize,
) -> Option<PlacementPlan> {
    // Default compact tail-first layout: stage k on block p−1−k.
    let p = cuts.len() - 1;
    let blocks: Vec<usize> = (0..p).map(|k| p - 1 - k).collect();
    build_plan_ordered(
        graph,
        cluster,
        method,
        sg,
        cuts,
        &blocks,
        d,
        recompute,
        zero_max_degree,
    )
}

/// Like [`build_plan`] but with an explicit stage→device-block
/// assignment (`blocks[k]` is the index of the `g`-device block stage
/// `k` occupies). Inter-stage levels are derived per block pair, so
/// non-compact layouts price their cross-rack boundaries honestly.
/// Used by placement-searching baselines (MCMC/TopoOpt).
#[allow(clippy::too_many_arguments)]
pub fn build_plan_ordered(
    graph: &LayerGraph,
    cluster: &Cluster,
    method: &str,
    sg: SgConfig,
    cuts: &[usize],
    blocks: &[usize],
    d: usize,
    recompute: bool,
    zero_max_degree: usize,
) -> Option<PlacementPlan> {
    let p = cuts.len() - 1;
    assert!(p >= 1 && cuts[0] == 0 && cuts[p] == graph.n_layers());
    assert_eq!(blocks.len(), p, "one device block per stage");
    let g = sg.group_size();
    if p * g * d > cluster.n_devices() || d == 0 {
        return None;
    }
    let cm = CostModel::new(graph, cluster, sg);
    let zero_cap = zero_max_degree.min(crate::solver::pow2_floor(d));
    let stride = p * g;

    let mut stages = Vec::with_capacity(p);
    let mut bottleneck: f64 = 0.0;
    for k in 0..p {
        let (i, j) = (cuts[k], cuts[k + 1]);
        if j <= i {
            return None;
        }
        let stash = p - 1 - k;
        // Lockstep pricing and memory bound on the accelerator classes
        // this stage's block (and its replicas) actually covers.
        let (lo, hi) = (blocks[k] * g, (blocks[k] + 1) * g);
        if hi + (d - 1) * stride > cluster.n_devices() {
            return None; // block index out of the replicated range
        }
        let mask = cluster.pool.replicated_mask(lo, hi, d, stride);
        let cap = cluster.pool.min_capacity(mask);
        let spec = cm.stage_choose_spec(i, j, stash, cap, zero_cap.min(d), recompute)?;
        let send_level = if k + 1 < p {
            Some(crate::solver::assign::block_pair_level(
                cluster,
                blocks[k],
                blocks[k + 1],
                g,
            ))
        } else {
            None
        };
        let recv_level = if k > 0 {
            Some(crate::solver::assign::block_pair_level(
                cluster,
                blocks[k - 1],
                blocks[k],
                g,
            ))
        } else {
            None
        };
        let load = cm.stage_load_on(mask, i, j, recv_level, send_level, &spec, cluster);
        bottleneck = bottleneck.max(load);
        stages.push(StagePlan {
            layers: (i, j),
            devices: stage_devices(blocks[k], g),
            sg,
            mem: spec,
            send_level,
            load,
            accel_class: cluster.pool.class_names(mask),
        });
    }

    let m = graph.global_batch.div_ceil(d * graph.mbs);
    let sync = stages
        .iter()
        .map(|st| cluster.dp_allreduce(cm.stage_grad_bytes(st.layers.0, st.layers.1), d, stride))
        .fold(0.0, f64::max);
    let batch_time = bottleneck * (m as f64 + p as f64 - 1.0) + sync;

    Some(PlacementPlan {
        model_name: graph.model_name.clone(),
        method: method.into(),
        sg,
        stages,
        dp_width: d,
        mbs: graph.mbs,
        n_microbatches: m,
        devices_per_replica: stride,
        bottleneck,
        sync_time: sync,
        batch_time,
    })
}

/// Evenly split `n` layers into `p` contiguous stages.
pub fn even_cuts(n: usize, p: usize) -> Vec<usize> {
    assert!(p >= 1 && p <= n);
    let mut cuts = Vec::with_capacity(p + 1);
    for k in 0..=p {
        cuts.push(k * n / p);
    }
    cuts
}

/// Split `n` layers into `p` stages balancing a per-layer weight.
pub fn balanced_cuts(weights: &[f64], p: usize) -> Vec<usize> {
    let n = weights.len();
    assert!(p >= 1 && p <= n);
    let total: f64 = weights.iter().sum();
    let target = total / p as f64;
    let mut cuts = vec![0usize];
    let mut acc = 0.0;
    for (k, w) in weights.iter().enumerate() {
        acc += w;
        // Leave enough layers for the remaining stages.
        let stages_left = p - cuts.len();
        let layers_left = n - (k + 1);
        if cuts.len() < p && acc >= target * cuts.len() as f64 && layers_left >= stages_left {
            cuts.push(k + 1);
        }
    }
    while cuts.len() < p {
        // Degenerate fallback: even split of the remainder.
        let last = *cuts.last().unwrap();
        cuts.push(last + (n - last) / (p + 1 - cuts.len()));
    }
    cuts.push(n);
    cuts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;

    #[test]
    fn build_plan_validates() {
        let g = models::llama2_7b(1);
        let c = Cluster::fat_tree_tpuv4(64);
        let cuts = even_cuts(g.n_layers(), 8);
        let plan = build_plan(&g, &c, "test", SgConfig::serial(), &cuts, 8, true, 8).unwrap();
        plan.validate(&g, &c).unwrap();
        assert_eq!(plan.n_stages(), 8);
        assert_eq!(plan.dp_width, 8);
    }

    #[test]
    fn build_plan_rejects_oversize() {
        let g = models::llama2_7b(1);
        let c = Cluster::fat_tree_tpuv4(64);
        let cuts = even_cuts(g.n_layers(), 8);
        assert!(build_plan(&g, &c, "t", SgConfig::serial(), &cuts, 9, true, 8).is_none());
    }

    #[test]
    fn even_cuts_cover() {
        for (n, p) in [(34, 8), (26, 3), (98, 16), (10, 10)] {
            let cuts = even_cuts(n, p);
            assert_eq!(cuts.len(), p + 1);
            assert_eq!(cuts[0], 0);
            assert_eq!(cuts[p], n);
            assert!(cuts.windows(2).all(|w| w[1] > w[0]));
        }
    }

    #[test]
    fn balanced_cuts_balance() {
        // Heavy head: balanced cuts should give the heavy layer its own
        // small stage.
        let mut w = vec![1.0; 10];
        w[0] = 9.0;
        let cuts = balanced_cuts(&w, 2);
        assert_eq!(cuts.len(), 3);
        let s0: f64 = w[cuts[0]..cuts[1]].iter().sum();
        let s1: f64 = w[cuts[1]..cuts[2]].iter().sum();
        assert!((s0 - s1).abs() <= 9.0);
        assert!(cuts[1] <= 2);
    }
}
