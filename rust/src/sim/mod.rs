//! Discrete-event pipeline-training simulator — the "testbed" of this
//! reproduction (DESIGN.md §Hardware-Adaptation).
//!
//! Given a [`PlacementPlan`], the simulator executes one training batch
//! at microbatch granularity: every stage is a resource processing its
//! 1F1B (PipeDream-Flush, the schedule the paper fixes for all methods,
//! §5.1) or GPipe operation sequence in order; inter-stage activation /
//! gradient transfers are dependency edges weighted by the topology's
//! level costs; the batch ends with the data-parallel gradient
//! all-reduce. Unlike the DP's closed form `bottleneck·(m+s−1)+sync`,
//! the DES tracks per-stage heterogeneity, warmup/drain bubbles, and
//! transfer latencies event-by-event — it is how we *evaluate* every
//! method's plan (NEST and baselines share it, like the paper's shared
//! cost model), and how we validate the DP's bottleneck approximation.

use crate::cost::CostModel;
use crate::graph::subgraph::SgConfig;
use crate::graph::LayerGraph;
use crate::network::Cluster;
use crate::solver::plan::PlacementPlan;

/// Pipeline schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Schedule {
    /// PipeDream-Flush / 1F1B (paper default).
    OneFOneB,
    /// GPipe: all forwards, then all backwards.
    GPipe,
}

/// Simulation result for one training batch.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// End-to-end batch (iteration) time in seconds.
    pub batch_time: f64,
    /// Samples per second at the plan's global batch.
    pub throughput: f64,
    /// Fraction of the bottleneck stage's makespan spent communicating
    /// (intra-stage collectives + inter-stage transfers + grad sync).
    pub comm_fraction: f64,
    /// Pipeline bubble fraction: idle time of the bottleneck stage.
    pub bubble_fraction: f64,
    /// Per-stage busy time.
    pub stage_busy: Vec<f64>,
    /// Gradient sync time.
    pub sync_time: f64,
}

/// One operation in a stage's schedule order. Public so [`crate::netsim`]
/// lowers the exact same op sequences into flow workloads.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op {
    /// Forward of a microbatch (by id).
    Fwd(usize),
    /// Backward of a microbatch (by id).
    Bwd(usize),
}

/// Build a stage's operation sequence under `schedule` for a `p`-stage
/// pipeline running `m` microbatches.
pub fn stage_ops(schedule: Schedule, stage: usize, p: usize, m: usize) -> Vec<Op> {
    match schedule {
        Schedule::GPipe => {
            let mut ops: Vec<Op> = (0..m).map(Op::Fwd).collect();
            ops.extend((0..m).map(Op::Bwd));
            ops
        }
        Schedule::OneFOneB => {
            // Warmup: p−1−stage forwards, then steady 1F1B, then drain.
            let warmup = (p - 1 - stage).min(m);
            let mut ops = Vec::with_capacity(2 * m);
            for mb in 0..warmup {
                ops.push(Op::Fwd(mb));
            }
            // Steady state: one forward then one backward (Megatron
            // PipeDream-Flush), draining backwards once forwards run out.
            let mut next_f = warmup;
            let mut next_b = 0;
            while next_b < m {
                if next_f < m {
                    ops.push(Op::Fwd(next_f));
                    next_f += 1;
                }
                ops.push(Op::Bwd(next_b));
                next_b += 1;
            }
            ops
        }
    }
}

/// Simulate one training batch of `plan` on `cluster`.
pub fn simulate(
    graph: &LayerGraph,
    cluster: &Cluster,
    plan: &PlacementPlan,
    schedule: Schedule,
) -> SimReport {
    let p = plan.n_stages();
    let m = plan.n_microbatches;
    assert!(p >= 1 && m >= 1);

    // Per-stage cost models (stages may differ in sg).
    let mut cms: Vec<(SgConfig, CostModel)> = Vec::new();
    let mut fwd_t = vec![0.0; p];
    let mut bwd_t = vec![0.0; p];
    let mut send_t = vec![0.0; p]; // activation transfer to next stage
    let mut comm_within = vec![0.0; p];
    for (k, st) in plan.stages.iter().enumerate() {
        let pos = match cms.iter().position(|(sg, _)| *sg == st.sg) {
            Some(pos) => pos,
            None => {
                cms.push((st.sg, CostModel::new(graph, cluster, st.sg)));
                cms.len() - 1
            }
        };
        let cm = &cms[pos].1;
        // Lockstep class coverage of the stage's devices across every
        // data-parallel replica: heterogeneous stages run at their
        // slowest covered accelerator.
        let mask = crate::solver::assign::stage_class_mask(
            cluster,
            &st.devices,
            plan.dp_width,
            plan.devices_per_replica,
        );
        let (f, b) = cm.stage_phase_times_on(mask, st.layers.0, st.layers.1, &st.mem, cluster);
        fwd_t[k] = f;
        bwd_t[k] = b;
        let (_, comm) = cm.stage_breakdown_on(mask, st.layers.0, st.layers.1, &st.mem);
        comm_within[k] = comm;
        if let Some(lvl) = st.send_level {
            let bytes = cm.boundary_bytes_after(st.layers.1);
            send_t[k] = cluster.p2p_time(lvl, bytes);
        }
    }

    // Event-driven execution: each stage runs its op sequence in order;
    // an op starts when the stage is free AND its dependency is done.
    let mut fwd_done = vec![vec![f64::INFINITY; m]; p];
    let mut bwd_done = vec![vec![f64::INFINITY; m]; p];
    let mut clock = vec![0.0f64; p];
    let mut busy = vec![0.0f64; p];
    let mut next_op = vec![0usize; p];
    let ops: Vec<Vec<Op>> = (0..p).map(|k| stage_ops(schedule, k, p, m)).collect();

    let total_ops: usize = ops.iter().map(|o| o.len()).sum();
    let mut done = 0usize;
    while done < total_ops {
        let mut progressed = false;
        for k in 0..p {
            while next_op[k] < ops[k].len() {
                let op = ops[k][next_op[k]];
                // Dependency readiness.
                let ready = match op {
                    Op::Fwd(mb) => {
                        if k == 0 {
                            Some(0.0)
                        } else {
                            let dep = fwd_done[k - 1][mb];
                            if dep.is_finite() {
                                Some(dep + send_t[k - 1])
                            } else {
                                None
                            }
                        }
                    }
                    Op::Bwd(mb) => {
                        if k == p - 1 {
                            let dep = fwd_done[k][mb];
                            if dep.is_finite() {
                                Some(dep)
                            } else {
                                None
                            }
                        } else {
                            let dep = bwd_done[k + 1][mb];
                            if dep.is_finite() {
                                // Gradient flows backward over the same
                                // boundary (same volume as activations).
                                Some(dep + send_t[k])
                            } else {
                                None
                            }
                        }
                    }
                };
                let Some(ready) = ready else { break };
                let dur = match op {
                    Op::Fwd(_) => fwd_t[k],
                    Op::Bwd(_) => bwd_t[k],
                };
                let start = clock[k].max(ready);
                let end = start + dur;
                clock[k] = end;
                busy[k] += dur;
                match op {
                    Op::Fwd(mb) => fwd_done[k][mb] = end,
                    Op::Bwd(mb) => bwd_done[k][mb] = end,
                }
                next_op[k] += 1;
                done += 1;
                progressed = true;
            }
        }
        assert!(progressed, "pipeline deadlock (schedule bug)");
    }

    // Gradient sync: each stage all-reduces its gradients across the d
    // replicas after its last backward.
    let d = plan.dp_width;
    let stride = plan.devices_per_replica;
    let mut batch_end: f64 = 0.0;
    let mut max_sync: f64 = 0.0;
    for (k, st) in plan.stages.iter().enumerate() {
        let pos = cms.iter().position(|(sg, _)| *sg == st.sg).unwrap();
        let cm = &cms[pos].1;
        let sync = cluster.dp_allreduce(cm.stage_grad_bytes(st.layers.0, st.layers.1), d, stride);
        batch_end = batch_end.max(clock[k] + sync);
        max_sync = max_sync.max(sync);
    }

    // Bottleneck-stage accounting.
    let (bk, _) = busy
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap();
    let comm_time = comm_within[bk] * m as f64
        + send_t[bk] * 2.0 * m as f64
        + max_sync;
    let comm_fraction = (comm_time / batch_end).min(1.0);
    let bubble_fraction = 1.0 - busy[bk] / batch_end;

    SimReport {
        batch_time: batch_end,
        throughput: graph.global_batch as f64 / batch_end,
        comm_fraction,
        bubble_fraction,
        stage_busy: busy,
        sync_time: max_sync,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::solver::{solve, SolverOpts};

    fn setup(n_dev: usize) -> (LayerGraph, Cluster, PlacementPlan) {
        let g = models::llama2_7b(1);
        let c = Cluster::fat_tree_tpuv4(n_dev);
        let plan = solve(&g, &c, &SolverOpts::default()).unwrap().plan;
        (g, c, plan)
    }

    #[test]
    fn sim_time_bounded_below_by_work() {
        let (g, c, plan) = setup(64);
        let r = simulate(&g, &c, &plan, Schedule::OneFOneB);
        // The batch can't finish faster than the bottleneck stage's total
        // work.
        let min_work: f64 = r
            .stage_busy
            .iter()
            .cloned()
            .fold(0.0, f64::max);
        assert!(r.batch_time >= min_work);
        assert!(r.batch_time.is_finite() && r.batch_time > 0.0);
    }

    #[test]
    fn sim_close_to_dp_estimate() {
        // The DP's closed form bottleneck·(m+s−1)+sync should approximate
        // the DES within a modest factor (the DES excludes p2p from
        // occupancy and tracks real bubbles).
        let (g, c, plan) = setup(64);
        let r = simulate(&g, &c, &plan, Schedule::OneFOneB);
        let ratio = r.batch_time / plan.batch_time;
        assert!(
            (0.5..1.25).contains(&ratio),
            "sim {} vs dp {} (ratio {ratio})",
            r.batch_time,
            plan.batch_time
        );
    }

    #[test]
    fn gpipe_no_faster_than_1f1b_and_both_finish() {
        let (g, c, plan) = setup(64);
        let a = simulate(&g, &c, &plan, Schedule::OneFOneB);
        let b = simulate(&g, &c, &plan, Schedule::GPipe);
        // Same total work; GPipe only changes stash/bubbles. Times should
        // be within a small factor and both positive.
        assert!(b.batch_time >= a.batch_time * 0.95);
    }

    #[test]
    fn deeper_pipeline_has_more_bubble() {
        let g = models::llama2_7b(1);
        let c = Cluster::fat_tree_tpuv4(64);
        let sol = solve(&g, &c, &SolverOpts::default()).unwrap();
        let mut plan = sol.plan.clone();
        let r1 = simulate(&g, &c, &plan, Schedule::OneFOneB);
        // Artificially reduce microbatch count → more bubble.
        plan.n_microbatches = plan.n_microbatches.max(8) / 8;
        let r2 = simulate(&g, &c, &plan, Schedule::OneFOneB);
        if plan.n_stages() > 1 {
            assert!(r2.bubble_fraction >= r1.bubble_fraction * 0.99);
        }
    }

    #[test]
    fn comm_fraction_higher_on_oversubscribed() {
        let g = models::mixtral_8x7b(1);
        let fat = Cluster::fat_tree_tpuv4(64);
        let thin = Cluster::spine_leaf_h100(64, 2.0);
        let p1 = solve(&g, &fat, &SolverOpts::default()).unwrap().plan;
        let p2 = solve(&g, &thin, &SolverOpts::default()).unwrap().plan;
        let r1 = simulate(&g, &fat, &p1, Schedule::OneFOneB);
        let r2 = simulate(&g, &thin, &p2, Schedule::OneFOneB);
        // §5.3: Mixtral comm share ~10% on constrained network vs ~1% on
        // fat-tree. Directionally: oversubscribed H100 cluster shows a
        // higher comm fraction than the fat-tree (H100 compute is also
        // much faster, compressing compute time).
        assert!(
            r2.comm_fraction > r1.comm_fraction,
            "thin {} <= fat {}",
            r2.comm_fraction,
            r1.comm_fraction
        );
    }

    #[test]
    fn single_stage_has_no_bubble() {
        let g = models::bert_large(1);
        let c = Cluster::fat_tree_tpuv4(64);
        let sol = solve(&g, &c, &SolverOpts::default()).unwrap();
        if sol.plan.n_stages() == 1 {
            let r = simulate(&g, &c, &sol.plan, Schedule::OneFOneB);
            assert!(r.bubble_fraction < 0.05, "bubble {}", r.bubble_fraction);
        }
    }

    #[test]
    fn ops_sequences_well_formed() {
        for p in 1..=4 {
            for m in 1..=6 {
                for k in 0..p {
                    let ops = stage_ops(Schedule::OneFOneB, k, p, m);
                    assert_eq!(ops.len(), 2 * m);
                    // Each microbatch's bwd comes after its fwd.
                    for mb in 0..m {
                        let fi = ops.iter().position(|o| *o == Op::Fwd(mb)).unwrap();
                        let bi = ops.iter().position(|o| *o == Op::Bwd(mb)).unwrap();
                        assert!(fi < bi, "p={p} m={m} k={k} mb={mb}");
                    }
                    // In-flight bound: ≤ p−k microbatches outstanding.
                    let mut inflight: i32 = 0;
                    let mut max_inflight: i32 = 0;
                    for op in &ops {
                        match op {
                            Op::Fwd(_) => inflight += 1,
                            Op::Bwd(_) => inflight -= 1,
                        }
                        max_inflight = max_inflight.max(inflight);
                    }
                    assert!(
                        max_inflight as usize <= (p - k).max(1),
                        "1F1B memory bound violated: p={p} k={k} m={m} inflight={max_inflight}"
                    );
                }
            }
        }
    }
}
