//! Per-device accelerator profiles: the [`DevicePool`].
//!
//! Real datacenter pools mix generations — a V100 island next to an H100
//! island behind the same spine (the setting hardware/placement
//! co-search works like *Integrated Hardware Architecture and Device
//! Placement Search* optimize over). A [`DevicePool`] maps runs of
//! `(Accelerator, count)` onto contiguous device-id ranges, so every
//! layer that prices compute or memory can ask "which accelerator
//! classes does this device range cover?" and apply TP/DP **lockstep
//! semantics**: a group advances at its slowest member, and a stage is
//! memory-feasible only on its smallest-HBM member.
//!
//! Class coverage is expressed as a [`ClassMask`] — a bitmask over the
//! pool's *distinct* accelerator profiles — so the solver's hot loops
//! stay allocation-free.

use super::Accelerator;

/// Bitmask over a pool's distinct accelerator classes (bit `c` set ⇔
/// class `c` is present in the queried device range). Pools are capped
/// at 64 distinct classes, far beyond any real deployment.
pub type ClassMask = u64;

/// One contiguous run of identical accelerators.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceRun {
    pub accel: Accelerator,
    pub count: usize,
    /// Optional per-run access-link bandwidth (bytes/s) for the
    /// innermost tier — e.g. V100 NVLink at 300 GB/s inside a pool
    /// whose H100 nodes run 900 GB/s. `None` = use the tier's
    /// configured bandwidth. Only the explicit link-graph expansion
    /// ([`crate::netsim::topo`]) sees this; the level-wise analytic
    /// model keeps one (optimistic) bandwidth per tier, which is
    /// exactly the blind spot the flow simulator exposes.
    pub access_bw: Option<f64>,
}

/// Per-device accelerator profiles: runs of `(Accelerator, count)`
/// mapped to contiguous device ranges (run 0 owns devices
/// `[0, count₀)`, run 1 the next `count₁` ids, ...).
#[derive(Debug, Clone, PartialEq)]
pub struct DevicePool {
    runs: Vec<DeviceRun>,
    /// `starts[i]` = first device id of run `i`; `starts[len]` = total.
    starts: Vec<usize>,
    /// Distinct accelerator profiles (classes), in first-seen run order.
    classes: Vec<Accelerator>,
    /// Run index → class index.
    run_class: Vec<usize>,
}

impl DevicePool {
    /// A homogeneous pool of `n` identical accelerators — the former
    /// single-`accel` cluster, expressed in the new vocabulary.
    pub fn uniform(accel: Accelerator, n: usize) -> Self {
        Self::from_runs(vec![DeviceRun {
            accel,
            count: n,
            access_bw: None,
        }])
    }

    /// Build a pool from explicit runs. Zero-count runs are dropped;
    /// identical adjacent profiles stay separate runs (harmless).
    pub fn from_runs(runs: Vec<DeviceRun>) -> Self {
        let runs: Vec<DeviceRun> = runs.into_iter().filter(|r| r.count > 0).collect();
        assert!(!runs.is_empty(), "device pool has no devices");
        let mut starts = Vec::with_capacity(runs.len() + 1);
        let mut classes: Vec<Accelerator> = Vec::new();
        let mut run_class = Vec::with_capacity(runs.len());
        let mut total = 0usize;
        for r in &runs {
            starts.push(total);
            total += r.count;
            let c = match classes.iter().position(|a| *a == r.accel) {
                Some(c) => c,
                None => {
                    classes.push(r.accel.clone());
                    classes.len() - 1
                }
            };
            run_class.push(c);
        }
        starts.push(total);
        assert!(
            classes.len() <= 64,
            "device pool has more than 64 distinct accelerator classes"
        );
        DevicePool {
            runs,
            starts,
            classes,
            run_class,
        }
    }

    pub fn n_devices(&self) -> usize {
        *self.starts.last().unwrap()
    }

    pub fn runs(&self) -> &[DeviceRun] {
        &self.runs
    }

    /// Distinct accelerator profiles, indexed by class id (= mask bit).
    pub fn classes(&self) -> &[Accelerator] {
        &self.classes
    }

    pub fn n_classes(&self) -> usize {
        self.classes.len()
    }

    /// One accelerator class everywhere — the solver's homogeneous fast
    /// path (shared DP tables, forced data-parallel width).
    pub fn is_homogeneous(&self) -> bool {
        self.classes.len() == 1
    }

    /// Mask with every class bit set.
    pub fn full_mask(&self) -> ClassMask {
        if self.classes.len() >= 64 {
            u64::MAX
        } else {
            (1u64 << self.classes.len()) - 1
        }
    }

    /// Run index owning device `dev`.
    fn run_of(&self, dev: usize) -> usize {
        debug_assert!(dev < self.n_devices(), "device {dev} out of pool");
        // partition_point: first run whose start exceeds dev, minus one.
        self.starts.partition_point(|&s| s <= dev) - 1
    }

    /// Accelerator of device `dev`.
    pub fn accel_of(&self, dev: usize) -> &Accelerator {
        &self.runs[self.run_of(dev)].accel
    }

    /// Class index of device `dev`.
    pub fn class_of(&self, dev: usize) -> usize {
        self.run_class[self.run_of(dev)]
    }

    /// Access-link bandwidth override of device `dev` (innermost tier).
    pub fn access_bw_of(&self, dev: usize) -> Option<f64> {
        self.runs[self.run_of(dev)].access_bw
    }

    /// Classes covering the contiguous device range `[lo, hi)`.
    pub fn block_mask(&self, lo: usize, hi: usize) -> ClassMask {
        debug_assert!(lo < hi && hi <= self.n_devices(), "bad range [{lo},{hi})");
        let mut mask = 0u64;
        for ri in self.run_of(lo)..self.runs.len() {
            if self.starts[ri] >= hi {
                break;
            }
            mask |= 1u64 << self.run_class[ri];
        }
        mask
    }

    /// Classes covering the block `[lo, hi)` and its `d` data-parallel
    /// replicas spaced `stride` devices apart (replica `r` covers
    /// `[lo + r·stride, hi + r·stride)`) — the full lockstep group of a
    /// replicated pipeline stage.
    pub fn replicated_mask(&self, lo: usize, hi: usize, d: usize, stride: usize) -> ClassMask {
        let mut mask = 0u64;
        for r in 0..d.max(1) {
            mask |= self.block_mask(lo + r * stride, hi + r * stride);
        }
        mask
    }

    /// Classes covering an explicit device list and its replicas.
    pub fn devices_mask(&self, devices: &[usize], d: usize, stride: usize) -> ClassMask {
        let mut mask = 0u64;
        for &dev in devices {
            for r in 0..d.max(1) {
                mask |= 1u64 << self.class_of(dev + r * stride);
            }
        }
        mask
    }

    /// Smallest HBM capacity among the classes in `mask` — the memory
    /// bound a lockstep group must fit (Eq. 1 on the weakest member).
    pub fn min_capacity(&self, mask: ClassMask) -> f64 {
        let mut cap = f64::INFINITY;
        let mut m = mask & self.full_mask();
        debug_assert!(m != 0, "min_capacity of empty mask");
        while m != 0 {
            let c = m.trailing_zeros() as usize;
            m &= m - 1;
            cap = cap.min(self.classes[c].hbm_capacity);
        }
        cap
    }

    /// Smallest HBM capacity across the whole pool.
    pub fn min_capacity_all(&self) -> f64 {
        self.min_capacity(self.full_mask())
    }

    /// Human-readable class set of `mask`, run order, "+"-joined
    /// (e.g. `"h100+v100"`); the per-stage device-class record plans
    /// carry.
    pub fn class_names(&self, mask: ClassMask) -> String {
        let mut names: Vec<&str> = Vec::new();
        let mut m = mask & self.full_mask();
        while m != 0 {
            let c = m.trailing_zeros() as usize;
            m &= m - 1;
            names.push(&self.classes[c].name);
        }
        names.join("+")
    }

    /// Map every run's accelerator (capacity ablations: Table 7 shrinks
    /// all devices alike).
    pub fn map_accels(&self, mut f: impl FnMut(&Accelerator) -> Accelerator) -> Self {
        Self::from_runs(
            self.runs
                .iter()
                .map(|r| DeviceRun {
                    accel: f(&r.accel),
                    count: r.count,
                    access_bw: r.access_bw,
                })
                .collect(),
        )
    }

    /// Short pool summary: `"64×h100"` or `"32×h100 + 32×v100"`.
    pub fn describe(&self) -> String {
        self.runs
            .iter()
            .map(|r| format!("{}×{}", r.count, r.accel.name))
            .collect::<Vec<_>>()
            .join(" + ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::GIB;

    fn mixed() -> DevicePool {
        DevicePool::from_runs(vec![
            DeviceRun {
                accel: Accelerator::h100(),
                count: 32,
                access_bw: None,
            },
            DeviceRun {
                accel: Accelerator::v100(),
                count: 32,
                access_bw: Some(300.0e9),
            },
        ])
    }

    #[test]
    fn uniform_pool_single_class() {
        let p = DevicePool::uniform(Accelerator::tpu_v4(), 64);
        assert_eq!(p.n_devices(), 64);
        assert!(p.is_homogeneous());
        assert_eq!(p.full_mask(), 1);
        assert_eq!(p.block_mask(0, 64), 1);
        assert_eq!(p.accel_of(63).name, "tpuv4");
        assert_eq!(p.class_names(1), "tpuv4");
    }

    #[test]
    fn mixed_pool_maps_ranges_to_classes() {
        let p = mixed();
        assert_eq!(p.n_devices(), 64);
        assert_eq!(p.n_classes(), 2);
        assert!(!p.is_homogeneous());
        assert_eq!(p.class_of(0), 0);
        assert_eq!(p.class_of(31), 0);
        assert_eq!(p.class_of(32), 1);
        assert_eq!(p.accel_of(40).name, "v100");
        assert_eq!(p.block_mask(0, 32), 0b01);
        assert_eq!(p.block_mask(32, 64), 0b10);
        assert_eq!(p.block_mask(16, 48), 0b11);
        assert_eq!(p.class_names(0b11), "h100+v100");
        assert_eq!(p.access_bw_of(0), None);
        assert_eq!(p.access_bw_of(33), Some(300.0e9));
    }

    #[test]
    fn replicated_mask_unions_replica_coverage() {
        let p = mixed();
        // Block [0, 8) replicated 2× at stride 32: replica 1 sits on
        // V100s.
        assert_eq!(p.replicated_mask(0, 8, 2, 32), 0b11);
        assert_eq!(p.replicated_mask(0, 8, 1, 32), 0b01);
        assert_eq!(p.devices_mask(&[0, 1, 2], 2, 32), 0b11);
        assert_eq!(p.devices_mask(&[0, 1, 2], 1, 32), 0b01);
    }

    #[test]
    fn min_capacity_takes_weakest_member() {
        let p = mixed();
        assert_eq!(p.min_capacity(0b01), 80.0 * GIB);
        assert_eq!(p.min_capacity(0b10), 32.0 * GIB);
        assert_eq!(p.min_capacity(0b11), 32.0 * GIB);
        assert_eq!(p.min_capacity_all(), 32.0 * GIB);
    }

    #[test]
    fn map_accels_preserves_layout() {
        let p = mixed().map_accels(|a| a.with_capacity(16.0 * GIB));
        assert_eq!(p.n_devices(), 64);
        assert_eq!(p.n_classes(), 2);
        assert_eq!(p.min_capacity_all(), 16.0 * GIB);
        assert_eq!(p.access_bw_of(33), Some(300.0e9));
    }

    #[test]
    fn duplicate_profiles_share_a_class() {
        let p = DevicePool::from_runs(vec![
            DeviceRun {
                accel: Accelerator::v100(),
                count: 8,
                access_bw: None,
            },
            DeviceRun {
                accel: Accelerator::h100(),
                count: 8,
                access_bw: None,
            },
            DeviceRun {
                accel: Accelerator::v100(),
                count: 8,
                access_bw: None,
            },
        ]);
        assert_eq!(p.n_classes(), 2);
        assert_eq!(p.class_of(0), p.class_of(20));
        assert_eq!(p.block_mask(8, 16), 0b10);
        assert_eq!(p.block_mask(0, 24), 0b11);
    }
}
