//! Accelerator compute models.
//!
//! The paper derives operator latencies from hardware-validated estimators
//! (Sunstone/Tandem for TPUv4-like tensor/vector cores, the PyTorch
//! profiler for H100/V100, §5.1). We reproduce that with a two-term
//! roofline per accelerator: matmul-class FLOPs run at
//! `matmul_peak × matmul_eff` and everything else is bounded by HBM
//! bandwidth (vector ops on transformer layers are memory-bound). The
//! `cpu_sim` preset is calibrated at runtime by `profiler::calibrate`
//! against real PJRT executions of the probe HLOs (see DESIGN.md
//! §Hardware-Adaptation).

pub mod pool;

pub use pool::{ClassMask, DevicePool, DeviceRun};

/// An accelerator model: peak rates plus achieved-efficiency factors.
#[derive(Debug, Clone, PartialEq)]
pub struct Accelerator {
    pub name: String,
    /// Peak dense-matmul throughput (FLOP/s) at the training dtype (bf16).
    pub matmul_peak: f64,
    /// Achieved fraction of `matmul_peak` for large GEMMs (model FLOPs
    /// utilization at the operator level).
    pub matmul_eff: f64,
    /// Peak vector-unit throughput (FLOP/s); elementwise/softmax/norms.
    pub vector_peak: f64,
    /// HBM bandwidth (bytes/s).
    pub hbm_bw: f64,
    /// HBM capacity (bytes).
    pub hbm_capacity: f64,
}

pub const GIB: f64 = 1024.0 * 1024.0 * 1024.0;
pub const GB: f64 = 1e9;
pub const TFLOPS: f64 = 1e12;

impl Accelerator {
    /// TPUv4-like accelerator (§5.2): 275 TFLOP/s bf16 MXU, 1.2 TB/s HBM.
    /// The paper's Table 7 describes these with 64 GB HBM.
    pub fn tpu_v4() -> Self {
        Accelerator {
            name: "tpuv4".into(),
            matmul_peak: 275.0 * TFLOPS,
            matmul_eff: 0.55,
            vector_peak: 4.0 * TFLOPS,
            hbm_bw: 1200.0 * GB,
            hbm_capacity: 64.0 * GIB,
        }
    }

    /// NVIDIA H100-SXM 80GB (§5.3): 989 TFLOP/s bf16, 3.35 TB/s HBM3.
    pub fn h100() -> Self {
        Accelerator {
            name: "h100".into(),
            matmul_peak: 989.0 * TFLOPS,
            matmul_eff: 0.45,
            vector_peak: 67.0 * TFLOPS,
            hbm_bw: 3350.0 * GB,
            hbm_capacity: 80.0 * GIB,
        }
    }

    /// NVIDIA V100-SXM2 32GB (§5.4): 125 TFLOP/s fp16 tensor cores.
    pub fn v100() -> Self {
        Accelerator {
            name: "v100".into(),
            matmul_peak: 125.0 * TFLOPS,
            matmul_eff: 0.40,
            vector_peak: 15.7 * TFLOPS,
            hbm_bw: 900.0 * GB,
            hbm_capacity: 32.0 * GIB,
        }
    }

    /// CPU-thread "device" used by the real pipeline trainer. Defaults are
    /// rough; `profiler::calibrate` replaces them with measured values.
    pub fn cpu_sim() -> Self {
        Accelerator {
            name: "cpu-sim".into(),
            matmul_peak: 50e9,
            matmul_eff: 1.0,
            vector_peak: 10e9,
            hbm_bw: 20.0 * GB,
            hbm_capacity: 4.0 * GIB,
        }
    }

    /// Look a preset up by its CLI/config name (the `accelerator` field
    /// of topology JSON files).
    pub fn by_name(name: &str) -> Option<Accelerator> {
        match name {
            "tpuv4" => Some(Accelerator::tpu_v4()),
            "h100" => Some(Accelerator::h100()),
            "v100" => Some(Accelerator::v100()),
            "cpu-sim" => Some(Accelerator::cpu_sim()),
            _ => None,
        }
    }

    /// Copy with a reduced HBM capacity (Table 7 memory-constrained
    /// ablations: 24 GB Llama3 run, 120 MB BertLarge run).
    pub fn with_capacity(&self, bytes: f64) -> Self {
        let mut a = self.clone();
        a.hbm_capacity = bytes;
        a.name = format!("{}-{}", a.name, crate::util::table::fmt_bytes(bytes));
        a
    }

    /// Time to execute `flops` of dense matmul work that also moves
    /// `bytes` through HBM: roofline max of the two terms.
    pub fn matmul_time(&self, flops: f64, bytes: f64) -> f64 {
        debug_assert!(flops >= 0.0 && bytes >= 0.0);
        (flops / (self.matmul_peak * self.matmul_eff)).max(bytes / self.hbm_bw)
    }

    /// Time for vector-class work (elementwise, softmax, norms): bounded
    /// by the vector unit or HBM, whichever is slower.
    pub fn vector_time(&self, flops: f64, bytes: f64) -> f64 {
        (flops / self.vector_peak).max(bytes / self.hbm_bw)
    }

    /// Effective achieved matmul FLOP/s.
    pub fn achieved_matmul(&self) -> f64 {
        self.matmul_peak * self.matmul_eff
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_are_ordered_by_generation() {
        let (v, t, h) = (
            Accelerator::v100(),
            Accelerator::tpu_v4(),
            Accelerator::h100(),
        );
        assert!(v.achieved_matmul() < t.achieved_matmul());
        assert!(t.achieved_matmul() < h.achieved_matmul());
        assert!(h.hbm_bw > t.hbm_bw);
    }

    #[test]
    fn roofline_picks_slower_term() {
        let a = Accelerator::h100();
        // Compute-bound: 1 PFLOP, tiny bytes.
        let t1 = a.matmul_time(1e15, 1.0);
        assert!((t1 - 1e15 / a.achieved_matmul()).abs() / t1 < 1e-12);
        // Memory-bound: tiny flops, 1 TB.
        let t2 = a.matmul_time(1.0, 1e12);
        assert!((t2 - 1e12 / a.hbm_bw).abs() / t2 < 1e-12);
    }

    #[test]
    fn with_capacity_changes_only_capacity() {
        let a = Accelerator::tpu_v4();
        let b = a.with_capacity(24.0 * GIB);
        assert_eq!(b.hbm_capacity, 24.0 * GIB);
        assert_eq!(b.matmul_peak, a.matmul_peak);
        assert!(b.name.contains("tpuv4"));
    }

    #[test]
    fn times_monotone_in_work() {
        let a = Accelerator::v100();
        assert!(a.matmul_time(2e12, 1e9) > a.matmul_time(1e12, 1e9));
        assert!(a.vector_time(1e9, 2e9) > a.vector_time(1e9, 1e9));
    }
}
