//! NEST: network-, compute-, and memory-aware device placement for
//! distributed deep learning (MLSys 2026) — a from-scratch reproduction.
//!
//! The crate is organized bottom-up (see DESIGN.md):
//!
//! * substrates: [`hw`] accelerator models, [`graph`] operator graphs +
//!   model zoo + SUB-GRAPH parallelism, [`network`] topologies with the
//!   level-wise abstraction and collective cost models, [`memory`] the
//!   Eq. 1 peak-memory model with ZeRO.
//! * [`cost`]: the unified `load(·)` term consumed by the solvers.
//! * [`solver`]: NEST's network-aware dynamic program (Algorithm 1),
//!   plan reconstruction/device assignment, the K-best shortlist
//!   enumeration, and the contention-aware refinement loop
//!   (`solver::refine`: shortlist × flow-sim re-rank).
//! * [`baselines`]: Manual, MCMC (TopoOpt-style), Phaze, Alpa-E, Mist.
//! * [`sim`]: discrete-event pipeline simulator (the "testbed").
//! * [`netsim`]: flow-level contention-aware network simulator —
//!   explicit link graphs (tier expansion + arbitrary edge-lists),
//!   plan→flow lowering, max-min fair-share engine.
//! * [`service`]: placement-as-a-service — fingerprinted queries over
//!   an LRU plan cache with warm-started solves and incremental
//!   `reconcile` after elasticity events.
//! * [`obs`]: the flight recorder — zero-dep spans/counters/histograms
//!   across solver, netsim, and service, merged per-thread post-run and
//!   exported as Chrome trace-event JSON (strictly outside the
//!   determinism boundary; compiled to a cached-bool branch when off).
//! * [`runtime`]: PJRT engine loading AOT HLO artifacts.
//! * [`profiler`]: calibrates the compute model against real executions.
//! * [`trainer`]: real pipeline-parallel training over thread-devices.
//! * [`harness`]: regenerates every paper table and figure.

pub mod baselines;
pub mod cost;
pub mod netsim;
pub mod obs;
pub mod profiler;
pub mod runtime;
pub mod trainer;
pub mod service;
pub mod sim;
pub mod solver;
pub mod graph;
pub mod harness;
pub mod hw;
pub mod memory;
pub mod network;
pub mod util;
