//! Operator profiler: calibrate the analytical compute model against
//! *measured* PJRT executions (§5.1 "Runtime Estimation").
//!
//! The paper annotates operator graphs with profiled runtimes (PyTorch
//! profiler on GPUs, Sunstone/Tandem estimators for TPUv4). Our testbed
//! is the CPU PJRT backend, so we measure the probe artifacts —
//! single transformer-block forwards at several widths with known
//! analytical FLOPs — and fit the `cpu_sim` accelerator's achieved
//! matmul rate. The calibrated accelerator feeds the same roofline the
//! large-scale experiments use, closing the loop between the analytical
//! model and real execution (Table 6 / Figure 10 methodology).

use anyhow::{Context, Result};
use std::path::Path;
use std::time::Instant;

use crate::hw::Accelerator;
use crate::runtime::{literal_f32, manifest::Manifest, Engine};
use crate::util::stats;

/// One probe's measurement.
#[derive(Debug, Clone)]
pub struct ProbeResult {
    pub hidden: usize,
    pub tokens: usize,
    pub flops: f64,
    pub median_seconds: f64,
    pub achieved_flops_per_s: f64,
}

/// Calibration outcome.
#[derive(Debug, Clone)]
pub struct Calibration {
    pub probes: Vec<ProbeResult>,
    /// `cpu_sim` accelerator with the measured matmul rate.
    pub accel: Accelerator,
}

impl Calibration {
    /// Accelerator calibrated for a model of width `hidden`: uses the
    /// rate of the probe closest in width (small matmuls achieve far
    /// lower FLOP rates than the asymptotic best probe — using the max
    /// rate over-predicts small-model throughput).
    pub fn accel_for_hidden(&self, hidden: usize) -> Accelerator {
        let probe = self
            .probes
            .iter()
            .min_by_key(|p| p.hidden.abs_diff(hidden))
            .expect("no probes");
        let mut a = self.accel.clone();
        a.matmul_peak = probe.achieved_flops_per_s;
        a.vector_peak = probe.achieved_flops_per_s / 4.0;
        a.name = format!("cpu-sim-h{}", probe.hidden);
        a
    }
}

/// Run each probe `reps` times (after one warmup) and fit the achieved
/// FLOP rate. The fitted rate is the *best* probe's (largest width —
/// closest to the asymptotic rate the analytical model wants).
pub fn calibrate(dir: impl AsRef<Path>, reps: usize) -> Result<Calibration> {
    let dir = dir.as_ref();
    let man = Manifest::load(dir.join("manifest.json"))?;
    anyhow::ensure!(!man.probes.is_empty(), "manifest has no probes");
    let engine = Engine::cpu()?;

    let mut probes = Vec::new();
    for p in &man.probes {
        let exe = engine
            .load(dir.join(&p.file))
            .with_context(|| format!("loading probe {}", p.file))?;
        let n: usize = p.x_shape.iter().product();
        let dims: Vec<i64> = p.x_shape.iter().map(|&d| d as i64).collect();
        let x = literal_f32(&vec![0.05f32; n], &dims)?;
        // Warmup (compile caches, allocator).
        exe.run(std::slice::from_ref(&x))?;
        let mut times = Vec::with_capacity(reps);
        for _ in 0..reps {
            let t0 = Instant::now();
            exe.run(std::slice::from_ref(&x))?;
            times.push(t0.elapsed().as_secs_f64());
        }
        let med = stats::median(&times);
        probes.push(ProbeResult {
            hidden: p.hidden,
            tokens: p.tokens,
            flops: p.flops,
            median_seconds: med,
            achieved_flops_per_s: p.flops / med,
        });
    }

    let peak = probes
        .iter()
        .map(|p| p.achieved_flops_per_s)
        .fold(0.0, f64::max);
    let mut accel = Accelerator::cpu_sim();
    accel.matmul_peak = peak;
    accel.matmul_eff = 1.0;
    // Vector rate: scale with the measured matmul rate conservatively.
    accel.vector_peak = peak / 4.0;
    Ok(Calibration { probes, accel })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts_dir;

    #[test]
    fn calibration_produces_sane_rates() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let cal = calibrate(&dir, 3).unwrap();
        assert!(!cal.probes.is_empty());
        for p in &cal.probes {
            assert!(p.median_seconds > 0.0);
            // CPU XLA lands between 0.1 GFLOP/s and 2 TFLOP/s.
            assert!(
                p.achieved_flops_per_s > 1e8 && p.achieved_flops_per_s < 2e12,
                "{:e}",
                p.achieved_flops_per_s
            );
        }
        assert!(cal.accel.matmul_peak >= cal.probes[0].achieved_flops_per_s);
        // The calibrated accelerator must predict a probe's own runtime
        // within a loose factor (it *is* the fit).
        let p = cal
            .probes
            .iter()
            .max_by(|a, b| a.hidden.cmp(&b.hidden))
            .unwrap();
        let predicted = p.flops / cal.accel.achieved_matmul();
        let ratio = predicted / p.median_seconds;
        assert!(
            (0.2..=1.5).contains(&ratio),
            "prediction off: {predicted} vs {} (ratio {ratio})",
            p.median_seconds
        );
    }
}
