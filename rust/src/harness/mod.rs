//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation (§5, Appendix C) — see DESIGN.md §3 for the index.
//!
//! Each entry point prints the paper's rows/series to stdout and writes a
//! CSV under `results/`. All methods are *evaluated* with the shared
//! discrete-event simulator ([`crate::sim`]) regardless of what cost
//! abstraction they *searched* with — mirroring the paper's shared cost
//! model protocol (§5.1).

pub mod chaos;
pub mod figures;
pub mod mix;
pub mod netsim;
pub mod perf;
pub mod refine;
pub mod scale;
pub mod service;
pub mod tables;

use crate::baselines::{alpa, manual, mcmc, mist, phaze};
use crate::graph::LayerGraph;
use crate::netsim::NetsimOpts;
use crate::network::Cluster;
use crate::sim::{simulate, Schedule, SimReport};
use crate::solver::plan::PlacementPlan;
use crate::solver::{solve as nest_solve, SolverOpts};

/// The placement methods compared in §5.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    Manual,
    Mcmc,
    Phaze,
    AlpaE,
    Mist,
    Nest,
}

impl Method {
    pub fn name(&self) -> &'static str {
        match self {
            Method::Manual => "manual",
            Method::Mcmc => "mcmc",
            Method::Phaze => "phaze",
            Method::AlpaE => "alpa-e",
            Method::Mist => "mist",
            Method::Nest => "nest",
        }
    }
}

/// Harness-wide knobs.
#[derive(Debug, Clone)]
pub struct HarnessOpts {
    /// MCMC iterations (paper-scale: 2000×10; --quick shrinks it).
    pub mcmc: mcmc::McmcOpts,
    pub solver: SolverOpts,
    /// Flow-simulator options for every sim-touching harness path
    /// (netsim cross-validation, refine tables) — the CLI's `--mode` /
    /// `--threads` land here.
    pub netsim: NetsimOpts,
    /// Write CSVs under this directory.
    pub results_dir: String,
}

impl Default for HarnessOpts {
    fn default() -> Self {
        HarnessOpts {
            mcmc: mcmc::McmcOpts::default(),
            solver: SolverOpts::default(),
            netsim: NetsimOpts::default(),
            results_dir: "results".into(),
        }
    }
}

impl HarnessOpts {
    /// Fast mode for tests / smoke runs.
    pub fn quick() -> Self {
        HarnessOpts {
            mcmc: mcmc::McmcOpts {
                iters: 200,
                restarts: 2,
                ..Default::default()
            },
            ..Default::default()
        }
    }

    /// Same options with the NEST solver's worker-thread count overridden
    /// (0 = one per core). Plans are unaffected — the solver is
    /// thread-count-invariant; only Table 4 wall-clock changes.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.solver.threads = threads;
        self.netsim.threads = threads;
        self
    }
}

/// One method's outcome on one (model, cluster) cell.
#[derive(Debug, Clone)]
pub struct MethodResult {
    pub method: Method,
    /// `None` = the method failed to find a valid placement (the ✗ marks).
    pub plan: Option<PlacementPlan>,
    pub sim: Option<SimReport>,
    pub solve_seconds: f64,
}

impl MethodResult {
    /// Samples/s under the shared simulator; 0.0 when failed.
    pub fn throughput(&self) -> f64 {
        self.sim.as_ref().map(|s| s.throughput).unwrap_or(0.0)
    }

    pub fn strategy(&self) -> String {
        self.plan
            .as_ref()
            .map(|p| p.strategy_string())
            .unwrap_or_else(|| "✗".into())
    }
}

/// Run one method on one cell and evaluate it with the DES.
pub fn run_method(
    graph: &LayerGraph,
    cluster: &Cluster,
    method: Method,
    opts: &HarnessOpts,
) -> MethodResult {
    let t0 = std::time::Instant::now();
    let plan = match method {
        Method::Manual => manual::solve(graph, cluster),
        Method::Mcmc => mcmc::solve(graph, cluster, &opts.mcmc),
        Method::Phaze => phaze::solve(graph, cluster, &opts.solver),
        Method::AlpaE => alpa::solve(graph, cluster),
        Method::Mist => mist::solve(graph, cluster),
        Method::Nest => nest_solve(graph, cluster, &opts.solver).map(|s| s.plan),
    };
    let solve_seconds = t0.elapsed().as_secs_f64();
    // Defense in depth: plans that fail validation count as method
    // failures, never as throughput.
    let plan = plan.filter(|p| {
        p.validate(graph, cluster)
            .map_err(|e| eprintln!("[harness] {} produced invalid plan: {e}", method.name()))
            .is_ok()
    });
    let sim = plan
        .as_ref()
        .map(|p| simulate(graph, cluster, p, Schedule::OneFOneB));
    MethodResult {
        method,
        plan,
        sim,
        solve_seconds,
    }
}

/// Run a set of methods on one cell.
pub fn run_methods(
    graph: &LayerGraph,
    cluster: &Cluster,
    methods: &[Method],
    opts: &HarnessOpts,
) -> Vec<MethodResult> {
    methods
        .iter()
        .map(|&m| run_method(graph, cluster, m, opts))
        .collect()
}

/// Geometric-mean speedup of `a` over `b` across cells where both exist.
pub fn geomean_speedup(pairs: &[(f64, f64)]) -> f64 {
    let ratios: Vec<f64> = pairs
        .iter()
        .filter(|(a, b)| *a > 0.0 && *b > 0.0)
        .map(|(a, b)| a / b)
        .collect();
    crate::util::stats::geomean(&ratios)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;

    #[test]
    fn run_method_all_variants() {
        let g = models::llama2_7b(1);
        let c = Cluster::fat_tree_tpuv4(64);
        let opts = HarnessOpts::quick();
        for m in [
            Method::Manual,
            Method::Mcmc,
            Method::Phaze,
            Method::AlpaE,
            Method::Mist,
            Method::Nest,
        ] {
            let r = run_method(&g, &c, m, &opts);
            if let Some(p) = &r.plan {
                p.validate(&g, &c).unwrap();
                assert!(r.throughput() > 0.0, "{}", m.name());
            }
        }
    }

    #[test]
    fn nest_wins_or_ties_every_method_on_oversubscribed() {
        // The paper's core claim, as an invariant under the shared DES:
        // NEST's plan is never slower than any baseline's by more than
        // the DP-vs-DES modeling gap (10%).
        let g = models::gpt3_35b(1);
        let c = Cluster::spine_leaf_h100(64, 2.0);
        let opts = HarnessOpts::quick();
        let rs = run_methods(
            &g,
            &c,
            &[Method::Manual, Method::Phaze, Method::Mist, Method::Nest],
            &opts,
        );
        let nest = rs.last().unwrap().throughput();
        assert!(nest > 0.0);
        for r in &rs[..rs.len() - 1] {
            assert!(
                nest >= r.throughput() * 0.90,
                "nest {} vs {} {}",
                nest,
                r.method.name(),
                r.throughput()
            );
        }
    }

    #[test]
    fn geomean_speedup_ignores_failures() {
        let s = geomean_speedup(&[(2.0, 1.0), (8.0, 1.0), (0.0, 1.0), (3.0, 0.0)]);
        assert!((s - 4.0).abs() < 1e-9);
    }
}
