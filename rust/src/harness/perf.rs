//! Deterministic perf smoke for the CI bench-regression gate.
//!
//! `nest bench-smoke` runs a small, fixed set of wall-clock metrics —
//! the placement solve at 1 and 4 worker threads on a mid-size model,
//! the top-8 shortlist + flow-level re-ranking (`refine`) on the
//! shipped dumbbell, and the fair-share engine on the dumbbell and the
//! 4:1 spine-leaf edge-lists — writes them as `BENCH_PR.json`, and
//! (with `--baseline`) fails if any metric regressed more than the
//! tolerance against the committed `BENCH_BASELINE.json`. Each metric
//! is the **minimum** over its repetitions, the standard noise-robust
//! statistic for regression gating. Refresh the baseline with one line:
//!
//! ```text
//! cargo run --release -- bench-smoke --out BENCH_BASELINE.json
//! ```

use crate::graph::models;
use crate::netsim::{simulate_flows_with, FairshareEngine};
use crate::network::Cluster;
use crate::sim::Schedule;
use crate::solver::refine::refine;
use crate::solver::{solve, SolverOpts};
use crate::util::bench::{bench_n, report_speedup};
use crate::util::json::Json;

use super::netsim::{dumbbell_topology, spineleaf_topology};

/// One gated wall-clock metric.
#[derive(Debug, Clone)]
pub struct PerfMetric {
    pub name: String,
    /// Minimum wall-clock seconds over the metric's repetitions.
    pub seconds: f64,
}

/// The smoke's full metric set.
#[derive(Debug, Clone)]
pub struct PerfSmoke {
    /// `"full"` (what CI gates) or `"quick"` (shrunk sizes/reps for
    /// tests). [`gate`] refuses to compare across modes — the workloads
    /// differ, so cross-mode deltas are meaningless.
    pub mode: &'static str,
    pub metrics: Vec<PerfMetric>,
}

impl PerfSmoke {
    /// Serialize to the `BENCH_PR.json` / `BENCH_BASELINE.json` schema.
    pub fn to_json(&self) -> Json {
        let metrics = Json::Obj(
            self.metrics
                .iter()
                .map(|m| (m.name.clone(), Json::num(m.seconds)))
                .collect(),
        );
        Json::obj(vec![
            ("schema", Json::str("nest-bench-smoke-v1")),
            ("mode", Json::str(self.mode)),
            (
                "refresh",
                Json::str("cargo run --release -- bench-smoke --out BENCH_BASELINE.json"),
            ),
            ("metrics", metrics),
        ])
    }

    fn get(&self, name: &str) -> Option<f64> {
        self.metrics
            .iter()
            .find(|m| m.name == name)
            .map(|m| m.seconds)
    }
}

/// Solver options pinned to `threads` (everything else default, like the
/// benches — the smoke must measure the same code path CI users run).
fn sopts(threads: usize) -> SolverOpts {
    SolverOpts {
        threads,
        ..Default::default()
    }
}

/// Run the perf smoke. `quick` shrinks sizes and repetitions for unit
/// tests; CI runs the full set.
pub fn run_smoke(quick: bool) -> PerfSmoke {
    let mut metrics = Vec::new();
    let reps = if quick { 1 } else { 3 };
    let devices = if quick { 64 } else { 128 };

    // Solver wall clock, single- and multi-thread, mid-size model: the
    // shared-incumbent fan-out is perf-critical and both paths must stay
    // fast (the 4t run also guards the parallel path against lock
    // contention creep).
    let graph = models::llama2_7b(1);
    let cluster = Cluster::fat_tree_tpuv4(devices);
    let single = bench_n("bench_smoke_solve_llama2_7b_1t", reps, || {
        solve(&graph, &cluster, &sopts(1))
    });
    metrics.push(PerfMetric {
        name: "solve_llama2_7b_fattree_1t".into(),
        seconds: single.min.as_secs_f64(),
    });
    let multi = bench_n("bench_smoke_solve_llama2_7b_4t", reps, || {
        solve(&graph, &cluster, &sopts(4))
    });
    metrics.push(PerfMetric {
        name: "solve_llama2_7b_fattree_4t".into(),
        seconds: multi.min.as_secs_f64(),
    });
    report_speedup("bench_smoke_solve_4t_over_1t", &single, &multi);

    // Flow-level fair-share engine on the shipped dumbbell edge-list:
    // the netsim hot path (plan solved once, untimed; the engine is
    // reused across reps like the refine loop reuses it across plans).
    let (ecluster, topo) = dumbbell_topology();
    let sol = solve(&graph, &ecluster, &sopts(0)).expect("dumbbell placement feasible");
    let mut engine = FairshareEngine::new(&topo);
    let net = bench_n(
        "bench_smoke_netsim_fairshare_dumbbell",
        if quick { 1 } else { 5 },
        || {
            simulate_flows_with(&mut engine, &graph, &ecluster, &topo, &sol.plan, Schedule::OneFOneB)
        },
    );
    metrics.push(PerfMetric {
        name: "netsim_fairshare_dumbbell".into(),
        seconds: net.min.as_secs_f64(),
    });

    // Fair-share on the 4:1 spine-leaf edge-list: many concurrent flows
    // share (and split around) the oversubscribed trunks, so this is
    // the metric that moves when the incremental component re-solve or
    // the lazy drain heap regress.
    let (scluster, stopo) = spineleaf_topology();
    let ssol = solve(&graph, &scluster, &sopts(0)).expect("spine-leaf placement feasible");
    let mut sengine = FairshareEngine::new(&stopo);
    let snet = bench_n(
        "bench_smoke_netsim_fairshare_spineleaf",
        if quick { 1 } else { 5 },
        || {
            simulate_flows_with(
                &mut sengine,
                &graph,
                &scluster,
                &stopo,
                &ssol.plan,
                Schedule::OneFOneB,
            )
        },
    );
    metrics.push(PerfMetric {
        name: "netsim_fairshare_spineleaf".into(),
        seconds: snet.min.as_secs_f64(),
    });

    // End-to-end solve → top-8 shortlist → flow-level re-rank on the
    // dumbbell: the full `solve → solve_topk → refine` pipeline the
    // range-pricing tables and the incremental engine accelerate. K is
    // 8 in both modes so the metric name always describes the workload;
    // quick mode only shrinks the repetitions.
    let rf = bench_n(
        "bench_smoke_solve_topk8_refine_dumbbell",
        if quick { 1 } else { 3 },
        || refine(&graph, &ecluster, &topo, &sopts(0), 8),
    );
    metrics.push(PerfMetric {
        name: "solve_topk8_refine_dumbbell".into(),
        seconds: rf.min.as_secs_f64(),
    });

    PerfSmoke {
        mode: if quick { "quick" } else { "full" },
        metrics,
    }
}

/// Gate `pr` against a parsed baseline document: every baseline metric
/// must exist in `pr` and must not exceed `baseline · (1 + tolerance)`.
/// `Err` carries the full list of violations.
pub fn gate(pr: &PerfSmoke, baseline: &Json, tolerance: f64) -> Result<(), String> {
    // A missing mode field (pre-mode baselines) is treated as "full".
    let base_mode = baseline.get("mode").as_str().unwrap_or("full");
    if base_mode != pr.mode {
        return Err(format!(
            "bench mode mismatch: this run is `{}` but the baseline is `{base_mode}` — \
             the workloads differ, so the comparison is meaningless (refresh the \
             baseline without --quick)",
            pr.mode
        ));
    }
    let Some(base_metrics) = baseline.get("metrics").as_obj() else {
        return Err("baseline has no `metrics` object — refresh it with \
                    `cargo run --release -- bench-smoke --out BENCH_BASELINE.json`"
            .into());
    };
    let mut violations = Vec::new();
    for (name, v) in base_metrics {
        let Some(base) = v.as_f64() else {
            violations.push(format!("baseline metric `{name}` is not a number"));
            continue;
        };
        match pr.get(name) {
            None => violations.push(format!("metric `{name}` missing from this run")),
            Some(got) if got > base * (1.0 + tolerance) => violations.push(format!(
                "{name}: {:.3}s vs baseline {:.3}s ({:+.0}% > {:.0}% tolerance)",
                got,
                base,
                (got / base - 1.0) * 100.0,
                tolerance * 100.0
            )),
            Some(got) => println!(
                "BENCH-GATE ok {name}: {:.3}s vs baseline {:.3}s ({:+.0}%)",
                got,
                base,
                (got / base - 1.0) * 100.0
            ),
        }
    }
    // The inverse gap: a metric this run produced that the baseline
    // doesn't know about is NOT gated — make that visible so new
    // run_smoke metrics get a baseline refresh instead of silent
    // non-coverage.
    for m in &pr.metrics {
        if !base_metrics.contains_key(&m.name) {
            println!(
                "BENCH-GATE warn {}: not in the baseline — ungated until it is \
                 refreshed ({:.3}s this run)",
                m.name, m.seconds
            );
        }
    }
    if violations.is_empty() {
        Ok(())
    } else {
        Err(format!(
            "bench regression gate failed:\n  {}",
            violations.join("\n  ")
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn smoke(pairs: &[(&str, f64)]) -> PerfSmoke {
        PerfSmoke {
            mode: "full",
            metrics: pairs
                .iter()
                .map(|(n, s)| PerfMetric {
                    name: n.to_string(),
                    seconds: *s,
                })
                .collect(),
        }
    }

    #[test]
    fn gate_passes_within_tolerance() {
        let base = parse(r#"{"metrics": {"a": 1.0, "b": 0.5}}"#).unwrap();
        let pr = smoke(&[("a", 1.2), ("b", 0.4), ("extra", 9.0)]);
        assert!(gate(&pr, &base, 0.25).is_ok());
    }

    #[test]
    fn gate_fails_on_regression() {
        let base = parse(r#"{"metrics": {"a": 1.0}}"#).unwrap();
        let pr = smoke(&[("a", 1.3)]);
        let err = gate(&pr, &base, 0.25).unwrap_err();
        assert!(err.contains("a:"), "unexpected message: {err}");
    }

    #[test]
    fn gate_fails_on_missing_metric() {
        let base = parse(r#"{"metrics": {"a": 1.0, "gone": 1.0}}"#).unwrap();
        let pr = smoke(&[("a", 1.0)]);
        assert!(gate(&pr, &base, 0.25).is_err());
    }

    #[test]
    fn gate_rejects_baseline_without_metrics() {
        let base = parse(r#"{"oops": true}"#).unwrap();
        assert!(gate(&smoke(&[]), &base, 0.25).is_err());
    }

    #[test]
    fn gate_refuses_cross_mode_comparison() {
        // quick-vs-full numbers come from different workloads; comparing
        // them must be a clear error, not a bogus pass/fail.
        let base = parse(r#"{"mode": "full", "metrics": {"a": 1.0}}"#).unwrap();
        let mut pr = smoke(&[("a", 0.1)]);
        pr.mode = "quick";
        let err = gate(&pr, &base, 0.25).unwrap_err();
        assert!(err.contains("mode mismatch"), "unexpected message: {err}");
        // A baseline without a mode field is treated as full.
        let legacy = parse(r#"{"metrics": {"a": 1.0}}"#).unwrap();
        assert!(gate(&smoke(&[("a", 1.0)]), &legacy, 0.25).is_ok());
    }

    #[test]
    fn smoke_json_roundtrips() {
        let s = smoke(&[("a", 1.5)]);
        let text = crate::util::json::to_pretty(&s.to_json());
        let v = parse(&text).unwrap();
        assert_eq!(v.get("metrics").get("a").as_f64(), Some(1.5));
        assert_eq!(v.get("schema").as_str(), Some("nest-bench-smoke-v1"));
        assert_eq!(v.get("mode").as_str(), Some("full"));
        // The committed baseline stays refreshable with one command.
        assert!(v.get("refresh").as_str().unwrap().contains("bench-smoke"));
    }

    #[test]
    fn quick_smoke_produces_all_gated_metrics() {
        let s = run_smoke(true);
        assert_eq!(s.mode, "quick");
        for name in [
            "solve_llama2_7b_fattree_1t",
            "solve_llama2_7b_fattree_4t",
            "netsim_fairshare_dumbbell",
            "netsim_fairshare_spineleaf",
            "solve_topk8_refine_dumbbell",
        ] {
            assert!(s.get(name).unwrap() > 0.0, "missing metric {name}");
        }
    }
}
