//! Deterministic perf smoke for the CI bench-regression gate.
//!
//! `nest bench-smoke` runs a small, fixed set of wall-clock metrics —
//! the placement solve at 1 and 4 worker threads on a mid-size model,
//! the top-8 shortlist + flow-level re-ranking (`refine`) on the
//! shipped dumbbell, and the fair-share engine on the dumbbell and the
//! 4:1 spine-leaf edge-lists — writes them as `BENCH_PR.json`, and
//! (with `--baseline`) fails if any metric regressed more than the
//! tolerance against the committed `BENCH_BASELINE.json`. Each metric
//! is the **minimum** over its repetitions, the standard noise-robust
//! statistic for regression gating (`_qps` / `_per_sec` throughput
//! metrics gate in the opposite direction — see [`gate`]). Refresh only the measured
//! metrics, preserving hand-added keys, with one line:
//!
//! ```text
//! cargo run --release -- bench-smoke --write-baseline
//! ```

use crate::graph::models;
use crate::netsim::{faults, flowgen, flows, topo, FaultSpec, MixSpec, SimMode, Simulation};
use crate::network::Cluster;
use crate::sim::Schedule;
use crate::solver::refine::refine;
use crate::solver::{solve, SolverOpts};
use crate::util::bench::{bench_n, report_speedup};
use crate::util::json::Json;

use super::netsim::{dumbbell_topology, spineleaf_topology};
use super::scale::scale_workload;

/// One gated wall-clock metric.
#[derive(Debug, Clone)]
pub struct PerfMetric {
    pub name: String,
    /// Minimum wall-clock seconds over the metric's repetitions — or,
    /// for metrics whose name ends in `_qps` / `_per_sec`, a throughput
    /// (larger is better; [`gate`] flips direction on the suffix).
    pub seconds: f64,
}

/// The smoke's full metric set.
#[derive(Debug, Clone)]
pub struct PerfSmoke {
    /// `"full"` (what CI gates) or `"quick"` (shrunk sizes/reps for
    /// tests). [`gate`] refuses to compare across modes — the workloads
    /// differ, so cross-mode deltas are meaningless.
    pub mode: &'static str,
    pub metrics: Vec<PerfMetric>,
}

impl PerfSmoke {
    /// Serialize to the `BENCH_PR.json` / `BENCH_BASELINE.json` schema.
    pub fn to_json(&self) -> Json {
        let metrics = Json::Obj(
            self.metrics
                .iter()
                .map(|m| (m.name.clone(), Json::num(m.seconds)))
                .collect(),
        );
        Json::obj(vec![
            ("schema", Json::str("nest-bench-smoke-v1")),
            ("mode", Json::str(self.mode)),
            (
                "refresh",
                Json::str("cargo run --release -- bench-smoke --write-baseline"),
            ),
            ("metrics", metrics),
        ])
    }

    fn get(&self, name: &str) -> Option<f64> {
        self.metrics
            .iter()
            .find(|m| m.name == name)
            .map(|m| m.seconds)
    }
}

/// Solver options pinned to `threads` (everything else default, like the
/// benches — the smoke must measure the same code path CI users run).
fn sopts(threads: usize) -> SolverOpts {
    SolverOpts {
        threads,
        ..Default::default()
    }
}

/// Run the perf smoke. `quick` shrinks sizes and repetitions for unit
/// tests; CI runs the full set.
pub fn run_smoke(quick: bool) -> PerfSmoke {
    let mut metrics = Vec::new();
    let reps = if quick { 1 } else { 3 };
    let devices = if quick { 64 } else { 128 };

    // Solver wall clock, single- and multi-thread, mid-size model: the
    // shared-incumbent fan-out is perf-critical and both paths must stay
    // fast (the 4t run also guards the parallel path against lock
    // contention creep).
    let graph = models::llama2_7b(1);
    let cluster = Cluster::fat_tree_tpuv4(devices);
    let single = bench_n("bench_smoke_solve_llama2_7b_1t", reps, || {
        solve(&graph, &cluster, &sopts(1))
    });
    metrics.push(PerfMetric {
        name: "solve_llama2_7b_fattree_1t".into(),
        seconds: single.min.as_secs_f64(),
    });
    let multi = bench_n("bench_smoke_solve_llama2_7b_4t", reps, || {
        solve(&graph, &cluster, &sopts(4))
    });
    metrics.push(PerfMetric {
        name: "solve_llama2_7b_fattree_4t".into(),
        seconds: multi.min.as_secs_f64(),
    });
    report_speedup("bench_smoke_solve_4t_over_1t", &single, &multi);

    // Flow-level fair-share engine on the shipped dumbbell edge-list:
    // the netsim hot path (plan solved once, untimed; the engine is
    // reused across reps like the refine loop reuses it across plans).
    let (ecluster, dtopo) = dumbbell_topology();
    let sol = solve(&graph, &ecluster, &sopts(0)).expect("dumbbell placement feasible");
    let mut sim = Simulation::new();
    let net = bench_n(
        "bench_smoke_netsim_fairshare_dumbbell",
        if quick { 1 } else { 5 },
        || sim.run(&graph, &ecluster, &dtopo, &sol.plan, Schedule::OneFOneB),
    );
    metrics.push(PerfMetric {
        name: "netsim_fairshare_dumbbell".into(),
        seconds: net.min.as_secs_f64(),
    });

    // Fair-share on the 4:1 spine-leaf edge-list: many concurrent flows
    // share (and split around) the oversubscribed trunks, so this is
    // the metric that moves when the incremental component re-solve or
    // the lazy drain heap regress.
    let (scluster, stopo) = spineleaf_topology();
    let ssol = solve(&graph, &scluster, &sopts(0)).expect("spine-leaf placement feasible");
    let mut ssim = Simulation::new();
    let snet = bench_n(
        "bench_smoke_netsim_fairshare_spineleaf",
        if quick { 1 } else { 5 },
        || ssim.run(&graph, &scluster, &stopo, &ssol.plan, Schedule::OneFOneB),
    );
    metrics.push(PerfMetric {
        name: "netsim_fairshare_spineleaf".into(),
        seconds: snet.min.as_secs_f64(),
    });

    // Decomposed flow simulation at fabric scale: a generated fat-tree
    // plus the rack-local `netsim-scale` workload, reported as a
    // throughput so the gate flips direction (`_per_sec`, like `_qps`:
    // the baseline seeds LOW and only a throughput *drop* trips it).
    let sk = if quick { 4 } else { 8 };
    let sflows = if quick { 2_000 } else { 50_000 };
    let fabric = topo::fattree(sk);
    let swl = scale_workload(
        fabric.n_devices(),
        sk / 2,
        sk * sk / 4,
        sflows,
        0.9,
        42,
    );
    let mut dsim = Simulation::new().mode(SimMode::Decomposed).threads(0);
    let scale = bench_n(
        "bench_smoke_netsim_scale_decomposed",
        if quick { 1 } else { 3 },
        || dsim.run_workload(&fabric, &swl),
    );
    let wall = scale.min.as_secs_f64();
    metrics.push(PerfMetric {
        name: "netsim_scale_flows_per_sec".into(),
        seconds: if wall > 0.0 { sflows as f64 / wall } else { 0.0 },
    });

    // Background-flow generation + injection + mixed replay on the 4:1
    // spine-leaf: the `nest mix` / `refine --bg-load` hot path (one
    // level of the sweep, generate → lower → inject → fair-share).
    // Reported as flows/s of injected background traffic (`_per_sec`:
    // the gate trips only on a throughput drop).
    let mix_flows = if quick { 256 } else { 2_048 };
    let base_rep = ssim.run(&graph, &scluster, &stopo, &ssol.plan, Schedule::OneFOneB);
    let mspec = MixSpec {
        flows: mix_flows,
        ..MixSpec::at_load(0.5, base_rep.batch_time, 0xB6)
    };
    let mut msim = Simulation::new();
    let mixb = bench_n("bench_smoke_mix_spineleaf", if quick { 1 } else { 3 }, || {
        let mix = flowgen::generate(&stopo, &mspec);
        let mut wl = flows::lower(&graph, &scluster, &stopo, &ssol.plan, Schedule::OneFOneB);
        flowgen::inject(&mut wl, &mix);
        msim.run_workload(&stopo, &wl)
    });
    let mwall = mixb.min.as_secs_f64();
    metrics.push(PerfMetric {
        name: "mix_flows_per_sec".into(),
        seconds: if mwall > 0.0 { mix_flows as f64 / mwall } else { 0.0 },
    });

    // Seeded fault draw + straggler lowering + capacity-event replay on
    // the 4:1 spine-leaf: the `nest chaos` / `refine --fault-severity`
    // hot path (one severity level: draw → lower_faulted → inject →
    // fair-share). Reported as fault scenarios replayed per second
    // (`_per_sec`: the gate trips only on a throughput drop).
    let chaos_scenarios = if quick { 4 } else { 16 };
    let mut csim = Simulation::new();
    let chaosb = bench_n(
        "bench_smoke_chaos_spineleaf",
        if quick { 1 } else { 3 },
        || {
            let mut last = 0.0;
            for j in 0..chaos_scenarios {
                let spec = FaultSpec::at_severity(0.6, base_rep.batch_time, 0xFA17 + j as u64);
                let sc = faults::draw(&stopo, &spec);
                let mut wl = flows::lower_faulted(
                    &graph,
                    &scluster,
                    &stopo,
                    &ssol.plan,
                    Schedule::OneFOneB,
                    Some(&sc),
                );
                faults::inject(&mut wl, &stopo, &sc);
                last = csim.run_workload(&stopo, &wl).train_batch_time;
            }
            last
        },
    );
    let cwall = chaosb.min.as_secs_f64();
    metrics.push(PerfMetric {
        name: "chaos_scenarios_per_sec".into(),
        seconds: if cwall > 0.0 {
            chaos_scenarios as f64 / cwall
        } else {
            0.0
        },
    });

    // End-to-end solve → top-8 shortlist → flow-level re-rank on the
    // dumbbell: the full `solve → solve_topk → refine` pipeline the
    // range-pricing tables and the incremental engine accelerate. K is
    // 8 in both modes so the metric name always describes the workload;
    // quick mode only shrinks the repetitions.
    let rf = bench_n(
        "bench_smoke_solve_topk8_refine_dumbbell",
        if quick { 1 } else { 3 },
        || refine(&graph, &ecluster, &dtopo, &sopts(0), 8),
    );
    metrics.push(PerfMetric {
        name: "solve_topk8_refine_dumbbell".into(),
        seconds: rf.min.as_secs_f64(),
    });

    // Placement-service throughput over the serve-bench query stream
    // (cache hits + warm starts included — the production headline).
    // The `_qps` suffix flips the gate: higher is better, so the
    // committed baseline seeds this LOW and the 25% gate only trips if
    // throughput *drops* below baseline/(1+tol).
    let sopts_h = super::HarnessOpts::default().with_threads(0);
    let serve = crate::harness::service::serve_bench(&sopts_h, if quick { 8 } else { 16 }, true);
    assert_eq!(
        serve.mismatches, 0,
        "serve-bench answers diverged from cold twins"
    );
    println!(
        "bench_smoke_serve_bench: {:.1} queries/s ({:.0}% hit rate)",
        serve.qps,
        serve.stats.hit_rate() * 100.0
    );
    metrics.push(PerfMetric {
        name: "serve_qps".into(),
        seconds: serve.qps,
    });

    PerfSmoke {
        mode: if quick { "quick" } else { "full" },
        metrics,
    }
}

/// Gate `pr` against a parsed baseline document: every baseline metric
/// must exist in `pr` and must not exceed `baseline · (1 + tolerance)`.
/// `Err` carries the full list of violations.
pub fn gate(pr: &PerfSmoke, baseline: &Json, tolerance: f64) -> Result<(), String> {
    // A missing mode field (pre-mode baselines) is treated as "full".
    let base_mode = baseline.get("mode").as_str().unwrap_or("full");
    if base_mode != pr.mode {
        return Err(format!(
            "bench mode mismatch: this run is `{}` but the baseline is `{base_mode}` — \
             the workloads differ, so the comparison is meaningless (refresh the \
             baseline without --quick)",
            pr.mode
        ));
    }
    let Some(base_metrics) = baseline.get("metrics").as_obj() else {
        return Err("baseline has no `metrics` object — refresh it with \
                    `cargo run --release -- bench-smoke --write-baseline`"
            .into());
    };
    let mut violations = Vec::new();
    for (name, v) in base_metrics {
        let Some(base) = v.as_f64() else {
            violations.push(format!("baseline metric `{name}` is not a number"));
            continue;
        };
        // `_pct` keys are derived gates, not measured metrics: the
        // baseline value is an *absolute percentage ceiling* computed
        // from other metrics in this run. `obs_overhead_pct` bounds the
        // flight recorder's tracing-OFF cost on the 4-thread solve —
        // run-vs-baseline drift on that anchor beyond the ceiling fails
        // the gate (tighter than the generic wall-clock tolerance).
        // They never appear in run metrics, so `--write-baseline`
        // preserves them untouched.
        if name.ends_with("_pct") {
            if name == "obs_overhead_pct" {
                let anchor = "solve_llama2_7b_fattree_4t";
                let base_anchor = base_metrics.get(anchor).and_then(|j| j.as_f64());
                match (pr.get(anchor), base_anchor) {
                    (Some(run), Some(b)) if b > 0.0 => {
                        let pct = (run / b - 1.0) * 100.0;
                        if pct > base {
                            violations.push(format!(
                                "{name}: {anchor} ran {pct:+.1}% vs baseline — beyond \
                                 the {base:.1}% tracing-off overhead ceiling"
                            ));
                        } else {
                            println!(
                                "BENCH-GATE ok {name}: {anchor} {pct:+.1}% vs ceiling {base:.1}%"
                            );
                        }
                    }
                    _ => println!(
                        "BENCH-GATE warn {name}: anchor metric `{anchor}` missing from \
                         the run or baseline — overhead gate skipped"
                    ),
                }
            } else {
                println!("BENCH-GATE warn {name}: unknown `_pct` gate — ignored");
            }
            continue;
        }
        // Time metrics regress upward; `_qps` / `_per_sec` throughputs
        // regress downward (the mirrored bound keeps the tolerance
        // symmetric: base/(1+t), not base·(1−t)).
        let rate = name.ends_with("_qps") || name.ends_with("_per_sec");
        let unit = if rate { "/s" } else { "s" };
        match pr.get(name) {
            None => violations.push(format!("metric `{name}` missing from this run")),
            Some(got)
                if (!rate && got > base * (1.0 + tolerance))
                    || (rate && got < base / (1.0 + tolerance)) =>
            {
                violations.push(format!(
                    "{name}: {:.3}{unit} vs baseline {:.3}{unit} ({:+.0}% beyond {:.0}% tolerance)",
                    got,
                    base,
                    (got / base - 1.0) * 100.0,
                    tolerance * 100.0
                ))
            }
            Some(got) => println!(
                "BENCH-GATE ok {name}: {:.3}{unit} vs baseline {:.3}{unit} ({:+.0}%)",
                got,
                base,
                (got / base - 1.0) * 100.0
            ),
        }
    }
    // The inverse gap: a metric this run produced that the baseline
    // doesn't know about is NOT gated — make that visible so new
    // run_smoke metrics get a baseline refresh instead of silent
    // non-coverage.
    for m in &pr.metrics {
        if !base_metrics.contains_key(&m.name) {
            println!(
                "BENCH-GATE warn {}: not in the baseline — ungated until it is \
                 refreshed ({:.3}s this run)",
                m.name, m.seconds
            );
        }
    }
    if violations.is_empty() {
        Ok(())
    } else {
        Err(format!(
            "bench regression gate failed:\n  {}",
            violations.join("\n  ")
        ))
    }
}

/// The baseline document after refreshing `existing` with this run's
/// metrics: measured metrics are overwritten, every *unknown* key —
/// top-level (e.g. `note`) and per-metric — is preserved, so a
/// hand-annotated baseline survives `--write-baseline` round trips.
/// Quick-mode runs are refused: their shrunk workloads would poison
/// the full-mode gate.
pub fn merged_baseline(pr: &PerfSmoke, existing: Option<&Json>) -> Result<Json, String> {
    if pr.mode != "full" {
        return Err(
            "refusing to write a baseline from a --quick run — quick workloads are \
             shrunk, so their numbers would poison the full-mode gate"
                .into(),
        );
    }
    let mut doc = match existing {
        None => std::collections::BTreeMap::new(),
        Some(j) => match j.as_obj() {
            Some(m) => m.clone(),
            None => return Err("existing baseline is not a JSON object".into()),
        },
    };
    let mut metrics = doc
        .get("metrics")
        .and_then(|m| m.as_obj())
        .cloned()
        .unwrap_or_default();
    for m in &pr.metrics {
        metrics.insert(m.name.clone(), Json::num(m.seconds));
    }
    doc.insert("metrics".into(), Json::Obj(metrics));
    doc.insert("schema".into(), Json::str("nest-bench-smoke-v1"));
    doc.insert("mode".into(), Json::str(pr.mode));
    doc.insert(
        "refresh".into(),
        Json::str("cargo run --release -- bench-smoke --write-baseline"),
    );
    Ok(Json::Obj(doc))
}

/// `nest bench-smoke --write-baseline`: merge this run's metrics into
/// the baseline file at `path` (see [`merged_baseline`]).
pub fn write_baseline(pr: &PerfSmoke, path: &str) -> Result<(), String> {
    let existing = match std::fs::read_to_string(path) {
        Ok(text) => Some(crate::util::json::parse(&text).map_err(|e| format!("{path}: {e}"))?),
        Err(_) => None,
    };
    let doc = merged_baseline(pr, existing.as_ref())?;
    std::fs::write(path, crate::util::json::to_pretty(&doc)).map_err(|e| format!("{path}: {e}"))?;
    println!(
        "BENCH-BASELINE refreshed {} metric(s) in {path}",
        pr.metrics.len()
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn smoke(pairs: &[(&str, f64)]) -> PerfSmoke {
        PerfSmoke {
            mode: "full",
            metrics: pairs
                .iter()
                .map(|(n, s)| PerfMetric {
                    name: n.to_string(),
                    seconds: *s,
                })
                .collect(),
        }
    }

    #[test]
    fn gate_passes_within_tolerance() {
        let base = parse(r#"{"metrics": {"a": 1.0, "b": 0.5}}"#).unwrap();
        let pr = smoke(&[("a", 1.2), ("b", 0.4), ("extra", 9.0)]);
        assert!(gate(&pr, &base, 0.25).is_ok());
    }

    #[test]
    fn gate_fails_on_regression() {
        let base = parse(r#"{"metrics": {"a": 1.0}}"#).unwrap();
        let pr = smoke(&[("a", 1.3)]);
        let err = gate(&pr, &base, 0.25).unwrap_err();
        assert!(err.contains("a:"), "unexpected message: {err}");
    }

    #[test]
    fn gate_fails_on_missing_metric() {
        let base = parse(r#"{"metrics": {"a": 1.0, "gone": 1.0}}"#).unwrap();
        let pr = smoke(&[("a", 1.0)]);
        assert!(gate(&pr, &base, 0.25).is_err());
    }

    #[test]
    fn gate_rejects_baseline_without_metrics() {
        let base = parse(r#"{"oops": true}"#).unwrap();
        assert!(gate(&smoke(&[]), &base, 0.25).is_err());
    }

    #[test]
    fn gate_refuses_cross_mode_comparison() {
        // quick-vs-full numbers come from different workloads; comparing
        // them must be a clear error, not a bogus pass/fail.
        let base = parse(r#"{"mode": "full", "metrics": {"a": 1.0}}"#).unwrap();
        let mut pr = smoke(&[("a", 0.1)]);
        pr.mode = "quick";
        let err = gate(&pr, &base, 0.25).unwrap_err();
        assert!(err.contains("mode mismatch"), "unexpected message: {err}");
        // A baseline without a mode field is treated as full.
        let legacy = parse(r#"{"metrics": {"a": 1.0}}"#).unwrap();
        assert!(gate(&smoke(&[("a", 1.0)]), &legacy, 0.25).is_ok());
    }

    #[test]
    fn smoke_json_roundtrips() {
        let s = smoke(&[("a", 1.5)]);
        let text = crate::util::json::to_pretty(&s.to_json());
        let v = parse(&text).unwrap();
        assert_eq!(v.get("metrics").get("a").as_f64(), Some(1.5));
        assert_eq!(v.get("schema").as_str(), Some("nest-bench-smoke-v1"));
        assert_eq!(v.get("mode").as_str(), Some("full"));
        // The committed baseline stays refreshable with one command.
        assert!(v.get("refresh").as_str().unwrap().contains("bench-smoke"));
    }

    #[test]
    fn quick_smoke_produces_all_gated_metrics() {
        let s = run_smoke(true);
        assert_eq!(s.mode, "quick");
        for name in [
            "solve_llama2_7b_fattree_1t",
            "solve_llama2_7b_fattree_4t",
            "netsim_fairshare_dumbbell",
            "netsim_fairshare_spineleaf",
            "netsim_scale_flows_per_sec",
            "mix_flows_per_sec",
            "chaos_scenarios_per_sec",
            "solve_topk8_refine_dumbbell",
            "serve_qps",
        ] {
            assert!(s.get(name).unwrap() > 0.0, "missing metric {name}");
        }
    }

    #[test]
    fn gate_treats_qps_metrics_as_higher_is_better() {
        let base = parse(r#"{"metrics": {"serve_qps": 10.0}}"#).unwrap();
        // Faster service: far above baseline — fine.
        assert!(gate(&smoke(&[("serve_qps", 100.0)]), &base, 0.25).is_ok());
        // Within the mirrored tolerance band: 10/1.25 = 8.0.
        assert!(gate(&smoke(&[("serve_qps", 8.5)]), &base, 0.25).is_ok());
        // A real throughput drop must trip the gate.
        let err = gate(&smoke(&[("serve_qps", 5.0)]), &base, 0.25).unwrap_err();
        assert!(err.contains("serve_qps"), "unexpected message: {err}");
    }

    #[test]
    fn gate_treats_per_sec_metrics_as_higher_is_better() {
        let base = parse(r#"{"metrics": {"netsim_scale_flows_per_sec": 1000.0}}"#).unwrap();
        // Faster than baseline and inside the mirrored band: both pass.
        assert!(gate(&smoke(&[("netsim_scale_flows_per_sec", 9e5)]), &base, 0.25).is_ok());
        assert!(gate(&smoke(&[("netsim_scale_flows_per_sec", 850.0)]), &base, 0.25).is_ok());
        // A throughput collapse trips the gate.
        let err = gate(&smoke(&[("netsim_scale_flows_per_sec", 100.0)]), &base, 0.25).unwrap_err();
        assert!(
            err.contains("netsim_scale_flows_per_sec"),
            "unexpected message: {err}"
        );
    }

    #[test]
    fn obs_overhead_gate_passes_within_ceiling() {
        // 10.1s vs a 10.0s anchor is +1.0% — inside the 2% ceiling.
        let base = parse(
            r#"{"metrics": {"solve_llama2_7b_fattree_4t": 10.0,
                            "obs_overhead_pct": 2.0}}"#,
        )
        .unwrap();
        let pr = smoke(&[("solve_llama2_7b_fattree_4t", 10.1)]);
        assert!(gate(&pr, &base, 0.25).is_ok());
        // A missing anchor downgrades the overhead gate to a warning,
        // but the anchor itself still trips the missing-metric check.
        let err = gate(&smoke(&[("serve_qps", 1.0)]), &base, 0.25).unwrap_err();
        assert!(!err.contains("obs_overhead_pct"), "unexpected: {err}");
        assert!(err.contains("solve_llama2_7b_fattree_4t"), "unexpected: {err}");
    }

    #[test]
    fn obs_overhead_gate_fails_beyond_ceiling() {
        // 10.5s vs 10.0s is +5.0% — beyond the 2% ceiling, even though
        // the generic 25% wall-clock tolerance would wave it through.
        let base = parse(
            r#"{"metrics": {"solve_llama2_7b_fattree_4t": 10.0,
                            "obs_overhead_pct": 2.0}}"#,
        )
        .unwrap();
        let pr = smoke(&[("solve_llama2_7b_fattree_4t", 10.5)]);
        let err = gate(&pr, &base, 0.25).unwrap_err();
        assert!(err.contains("obs_overhead_pct"), "unexpected message: {err}");
    }

    #[test]
    fn pct_gates_survive_baseline_refresh() {
        // `_pct` keys are never run metrics, so --write-baseline must
        // carry them forward untouched.
        let existing = parse(
            r#"{"metrics": {"solve_llama2_7b_fattree_4t": 10.0,
                            "obs_overhead_pct": 2.0}}"#,
        )
        .unwrap();
        let merged = merged_baseline(
            &smoke(&[("solve_llama2_7b_fattree_4t", 9.0)]),
            Some(&existing),
        )
        .unwrap();
        assert_eq!(merged.get("metrics").get("obs_overhead_pct").as_f64(), Some(2.0));
        assert_eq!(
            merged.get("metrics").get("solve_llama2_7b_fattree_4t").as_f64(),
            Some(9.0)
        );
    }

    #[test]
    fn merged_baseline_preserves_unknown_keys() {
        let existing = parse(
            r#"{"note": "hand-tuned", "mode": "full",
                "metrics": {"a": 9.0, "legacy_metric": 3.0}}"#,
        )
        .unwrap();
        let merged = merged_baseline(&smoke(&[("a", 1.0), ("b", 2.0)]), Some(&existing)).unwrap();
        assert_eq!(merged.get("note").as_str(), Some("hand-tuned"));
        assert_eq!(merged.get("metrics").get("a").as_f64(), Some(1.0));
        assert_eq!(merged.get("metrics").get("b").as_f64(), Some(2.0));
        // A metric this run didn't measure keeps its old value.
        assert_eq!(merged.get("metrics").get("legacy_metric").as_f64(), Some(3.0));
        assert_eq!(merged.get("schema").as_str(), Some("nest-bench-smoke-v1"));

        // From scratch (no existing file) also works.
        let fresh = merged_baseline(&smoke(&[("a", 1.0)]), None).unwrap();
        assert_eq!(fresh.get("metrics").get("a").as_f64(), Some(1.0));
    }

    #[test]
    fn merged_baseline_refuses_quick_mode() {
        let mut pr = smoke(&[("a", 0.1)]);
        pr.mode = "quick";
        let err = merged_baseline(&pr, None).unwrap_err();
        assert!(err.contains("quick"), "unexpected message: {err}");
    }
}
